//! `cargo xtask` — repo automation. One command today:
//!
//! ```text
//! cargo xtask lint [--root <repo-root>]
//! ```
//!
//! runs the repo-invariant static pass over `rust/src` (see `lint.rs` for
//! the rules) and exits non-zero when any invariant is violated. The repo
//! root defaults to the workspace root (this crate's parent directory).

use std::path::PathBuf;
use std::process::ExitCode;

mod lint;

fn default_root() -> PathBuf {
    // xtask lives at <repo>/xtask, so the manifest dir's parent is the root.
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    manifest.parent().map(PathBuf::from).unwrap_or(manifest)
}

fn usage() {
    eprintln!("usage: cargo xtask lint [--root <repo-root>]");
}

fn run_lint(args: &[String]) -> ExitCode {
    let mut root = default_root();
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--root" => match it.next() {
                Some(p) => root = PathBuf::from(p),
                None => {
                    eprintln!("--root requires a path");
                    return ExitCode::FAILURE;
                }
            },
            other => {
                eprintln!("unknown lint option `{other}`");
                usage();
                return ExitCode::FAILURE;
            }
        }
    }
    match lint::lint_tree(&root) {
        Ok((files, violations)) => {
            for v in &violations {
                println!("{v}");
            }
            if violations.is_empty() {
                println!("xtask lint: {files} files scanned, 0 violations");
                ExitCode::SUCCESS
            } else {
                eprintln!("xtask lint: {files} files scanned, {} violations", violations.len());
                ExitCode::FAILURE
            }
        }
        Err(e) => {
            eprintln!("xtask lint: cannot scan {}: {e}", root.display());
            ExitCode::FAILURE
        }
    }
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint") => run_lint(&args[1..]),
        Some("help") | Some("--help") | None => {
            usage();
            ExitCode::SUCCESS
        }
        Some(other) => {
            eprintln!("unknown xtask command `{other}`");
            usage();
            ExitCode::FAILURE
        }
    }
}
