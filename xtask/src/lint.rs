//! The repo-invariant lint pass behind `cargo xtask lint`.
//!
//! Three rules over `rust/src` (see DESIGN.md "Concurrency model &
//! verification" for the rationale and the full allowlists):
//!
//! * **R1 `unsafe-allowlist`** — every `unsafe` keyword must sit in an
//!   allowlisted file ([`UNSAFE_ALLOWLIST`]) and carry a `// SAFETY:`
//!   comment within the preceding [`SAFETY_WINDOW`] lines.
//! * **R2 `bare-cast`** — no bare `as` numeric casts in the datapath
//!   modules ([`DATAPATH_DIRS`]): narrowing must go through
//!   `try_from(..).expect(..)`; deliberate casts (widening, float
//!   statistics) are annotated in place with `// as-ok: <reason>`.
//! * **R3 `alloc-in-into`** — no allocating calls ([`ALLOC_PATTERNS`])
//!   inside `*_into` hot-path functions, enforcing the zero-alloc
//!   steady-state statically; unavoidable sites (e.g. lifetime-bound
//!   dispatch scaffolding) are annotated with `// alloc-ok: <reason>`.
//!
//! `syn` is unavailable offline, so the scanner is hand-rolled: source is
//! masked (comments, strings, char literals blanked, geometry preserved)
//! and then tokenized; `#[cfg(test)]`-gated items are excluded by brace
//! matching on the masked text. Markers (`as-ok:` / `alloc-ok:` /
//! `SAFETY:`) are looked up on the *raw* lines, since masking erases them.

use std::fmt;
use std::path::{Path, PathBuf};

/// Files allowed to contain `unsafe` (repo-relative, forward slashes).
/// Today: only the pool's lifetime-erasing transmute, whose protocol is
/// loom- and Miri-checked (`rust/tests/loom_sync.rs`, `rust/tests/miri_lane.rs`).
pub const UNSAFE_ALLOWLIST: &[&str] = &["rust/src/accel/workers.rs"];

/// How many lines above an `unsafe` the `// SAFETY:` comment may sit.
pub const SAFETY_WINDOW: usize = 12;

/// Datapath directories where bare `as` numeric casts are forbidden (R2).
pub const DATAPATH_DIRS: &[&str] = &["rust/src/units/", "rust/src/spike/", "rust/src/accel/"];

/// Numeric primitive types that make an `as` cast a lint target.
const NUMERIC_TYPES: &[&str] = &[
    "u8", "u16", "u32", "u64", "u128", "usize", "i8", "i16", "i32", "i64", "i128", "isize",
    "f32", "f64",
];

/// Substrings that count as allocation inside a `*_into` function (R3).
pub const ALLOC_PATTERNS: &[&str] =
    &["Vec::new", "vec!", "Box::new", ".to_vec", ".collect", "with_capacity"];

/// One lint finding, printed as `file:line: [rule] message`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    /// Repo-relative path (forward slashes).
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// Stable rule identifier (`unsafe-allowlist`, `bare-cast`, `alloc-in-into`).
    pub rule: &'static str,
    /// Human-readable description of the finding.
    pub message: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

fn is_ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Blank out comments, string/char literals (geometry preserved: every
/// `\n` survives, everything masked becomes a space). Lifetimes keep their
/// tick so generic code stays tokenizable.
fn mask_source(src: &str) -> String {
    let chars: Vec<char> = src.chars().collect();
    let mut out: Vec<char> = Vec::with_capacity(chars.len());
    let mut i = 0;
    let blank = |c: char| if c == '\n' { '\n' } else { ' ' };
    while i < chars.len() {
        let c = chars[i];
        if c == '/' && chars.get(i + 1) == Some(&'/') {
            while i < chars.len() && chars[i] != '\n' {
                out.push(' ');
                i += 1;
            }
            continue;
        }
        if c == '/' && chars.get(i + 1) == Some(&'*') {
            let mut depth = 1usize;
            out.push(' ');
            out.push(' ');
            i += 2;
            while i < chars.len() && depth > 0 {
                if chars[i] == '/' && chars.get(i + 1) == Some(&'*') {
                    depth += 1;
                    out.push(' ');
                    out.push(' ');
                    i += 2;
                } else if chars[i] == '*' && chars.get(i + 1) == Some(&'/') {
                    depth -= 1;
                    out.push(' ');
                    out.push(' ');
                    i += 2;
                } else {
                    out.push(blank(chars[i]));
                    i += 1;
                }
            }
            continue;
        }
        // Raw (and raw byte) strings: r"..", r#".."#, br".." ...
        if (c == 'r' || c == 'b') && (i == 0 || !is_ident_char(chars[i - 1])) {
            let mut j = i;
            if chars[j] == 'b' && chars.get(j + 1) == Some(&'r') {
                j += 1;
            }
            if chars[j] == 'r' {
                let mut k = j + 1;
                let mut hashes = 0usize;
                while chars.get(k) == Some(&'#') {
                    hashes += 1;
                    k += 1;
                }
                if chars.get(k) == Some(&'"') {
                    for idx in i..=k {
                        out.push(blank(chars[idx]));
                    }
                    i = k + 1;
                    while i < chars.len() {
                        if chars[i] == '"'
                            && (0..hashes).all(|h| chars.get(i + 1 + h) == Some(&'#'))
                        {
                            for _ in 0..=hashes {
                                out.push(' ');
                            }
                            i += 1 + hashes;
                            break;
                        }
                        out.push(blank(chars[i]));
                        i += 1;
                    }
                    continue;
                }
            }
        }
        // Byte strings: the `b` masks, the quote path below handles the rest.
        if c == 'b' && chars.get(i + 1) == Some(&'"') && (i == 0 || !is_ident_char(chars[i - 1]))
        {
            out.push(' ');
            i += 1;
            mask_str_literal(&chars, &mut i, &mut out);
            continue;
        }
        if c == '"' {
            mask_str_literal(&chars, &mut i, &mut out);
            continue;
        }
        if c == '\'' {
            if chars.get(i + 1) == Some(&'\\') {
                // Escaped char literal: '\n', '\'', '\u{..}'.
                out.push(' ');
                out.push(' ');
                i += 2;
                while i < chars.len() {
                    if chars[i] == '\\' {
                        out.push(' ');
                        i += 1;
                        if i < chars.len() {
                            out.push(blank(chars[i]));
                            i += 1;
                        }
                    } else if chars[i] == '\'' {
                        out.push(' ');
                        i += 1;
                        break;
                    } else {
                        out.push(blank(chars[i]));
                        i += 1;
                    }
                }
            } else if chars.get(i + 2) == Some(&'\'') {
                // Plain char literal 'x'.
                out.push(' ');
                out.push(' ');
                out.push(' ');
                i += 3;
            } else {
                // Lifetime tick.
                out.push('\'');
                i += 1;
            }
            continue;
        }
        out.push(c);
        i += 1;
    }
    out.into_iter().collect()
}

/// Mask a `"`-delimited string literal starting at `chars[*i] == '"'`.
fn mask_str_literal(chars: &[char], i: &mut usize, out: &mut Vec<char>) {
    out.push(' ');
    *i += 1;
    while *i < chars.len() {
        let c = chars[*i];
        if c == '\\' {
            out.push(' ');
            *i += 1;
            if *i < chars.len() {
                out.push(if chars[*i] == '\n' { '\n' } else { ' ' });
                *i += 1;
            }
        } else if c == '"' {
            out.push(' ');
            *i += 1;
            return;
        } else {
            out.push(if c == '\n' { '\n' } else { ' ' });
            *i += 1;
        }
    }
}

/// A code token of the masked source: an identifier/keyword or one
/// punctuation character, with its 1-based line.
#[derive(Debug)]
struct Tok {
    text: String,
    line: usize,
}

fn tokenize(masked: &str) -> Vec<Tok> {
    let mut toks = Vec::new();
    let mut line = 1usize;
    let mut chars = masked.chars().peekable();
    while let Some(c) = chars.next() {
        if c == '\n' {
            line += 1;
            continue;
        }
        if c.is_whitespace() {
            continue;
        }
        if is_ident_char(c) {
            let mut text = String::new();
            text.push(c);
            while let Some(&n) = chars.peek() {
                if is_ident_char(n) {
                    text.push(n);
                    chars.next();
                } else {
                    break;
                }
            }
            toks.push(Tok { text, line });
        } else {
            toks.push(Tok { text: c.to_string(), line });
        }
    }
    toks
}

/// Lines covered by `#[cfg(test)]`-gated items (1-based, inclusive),
/// found by brace-matching the masked text. A gated item without braces
/// (e.g. a `use`) ends at its `;` and excludes nothing beyond itself.
fn test_excluded_lines(masked: &str, total_lines: usize) -> Vec<bool> {
    let mut excluded = vec![false; total_lines + 1];
    let mut flat_line = Vec::new(); // line number per char
    {
        let mut line = 1usize;
        for c in masked.chars() {
            flat_line.push(line);
            if c == '\n' {
                line += 1;
            }
        }
    }
    let flat: Vec<char> = masked.chars().collect();
    let needle: Vec<char> = "#[cfg(test)]".chars().collect();
    let mut i = 0;
    while i + needle.len() <= flat.len() {
        if flat[i..i + needle.len()] != needle[..] {
            i += 1;
            continue;
        }
        let mut j = i + needle.len();
        // Find the gated item's opening brace; a `;` first means a
        // brace-less item.
        let mut depth = 0usize;
        let mut open = None;
        while j < flat.len() {
            match flat[j] {
                '{' => {
                    open = Some(j);
                    break;
                }
                ';' => break,
                _ => {}
            }
            j += 1;
        }
        if let Some(open_at) = open {
            let mut k = open_at;
            while k < flat.len() {
                match flat[k] {
                    '{' => depth += 1,
                    '}' => {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    _ => {}
                }
                k += 1;
            }
            let (a, b) = (flat_line[i], flat_line[k.min(flat.len() - 1)]);
            for item in excluded.iter_mut().take(b + 1).skip(a) {
                *item = true;
            }
            i = k;
        }
        i += 1;
    }
    excluded
}

/// Does the masked line invoke allocation pattern `pat`? Requires a call
/// or turbofish right after the match, so `.collect_stats()` is not
/// `.collect` and `Vec::new_in` is not `Vec::new`.
fn alloc_hit(code: &str, pat: &str) -> bool {
    let mut start = 0;
    while let Some(p) = code[start..].find(pat) {
        let end = start + p + pat.len();
        let next = code[end..].chars().next();
        let hit = match pat {
            "vec!" => true,
            ".collect" => matches!(next, Some('(') | Some(':')),
            _ => matches!(next, Some('(')),
        };
        if hit {
            return true;
        }
        start = end;
    }
    false
}

/// Does the raw line carry `marker` followed by a non-empty reason?
fn has_marker(raw_line: &str, marker: &str) -> bool {
    raw_line
        .find(marker)
        .map(|p| !raw_line[p + marker.len()..].trim().is_empty())
        .unwrap_or(false)
}

/// Lint a single file's source. `rel_path` is repo-relative with forward
/// slashes (rule applicability and allowlists key off it).
pub fn lint_source(rel_path: &str, src: &str) -> Vec<Violation> {
    let masked = mask_source(src);
    let raw_lines: Vec<&str> = src.lines().collect();
    let excluded = test_excluded_lines(&masked, raw_lines.len());
    let toks = tokenize(&masked);
    let mut out = Vec::new();

    let is_datapath = DATAPATH_DIRS.iter().any(|d| rel_path.starts_with(d));
    let unsafe_allowed = UNSAFE_ALLOWLIST.contains(&rel_path);
    let is_excluded = |line: usize| excluded.get(line).copied().unwrap_or(false);

    // R1: unsafe allowlist + SAFETY comment.
    for t in toks.iter().filter(|t| t.text == "unsafe" && !is_excluded(t.line)) {
        if !unsafe_allowed {
            out.push(Violation {
                file: rel_path.to_string(),
                line: t.line,
                rule: "unsafe-allowlist",
                message: "`unsafe` outside the allowlisted files (see xtask UNSAFE_ALLOWLIST)"
                    .to_string(),
            });
            continue;
        }
        let lo = t.line.saturating_sub(SAFETY_WINDOW);
        let documented = (lo..=t.line)
            .filter_map(|ln| raw_lines.get(ln.wrapping_sub(1)))
            .any(|l| l.contains("SAFETY:"));
        if !documented {
            out.push(Violation {
                file: rel_path.to_string(),
                line: t.line,
                rule: "unsafe-allowlist",
                message: format!(
                    "`unsafe` without a `// SAFETY:` comment in the preceding {SAFETY_WINDOW} lines"
                ),
            });
        }
    }

    // R2: bare numeric `as` casts in datapath modules.
    if is_datapath {
        for w in toks.windows(2) {
            let (a, b) = (&w[0], &w[1]);
            if a.text != "as" || is_excluded(a.line) {
                continue;
            }
            if !NUMERIC_TYPES.contains(&b.text.as_str()) {
                continue;
            }
            let raw = raw_lines.get(a.line - 1).copied().unwrap_or("");
            if has_marker(raw, "as-ok:") {
                continue;
            }
            out.push(Violation {
                file: rel_path.to_string(),
                line: a.line,
                rule: "bare-cast",
                message: format!(
                    "bare `as {}` cast in a datapath module — use `{}::try_from(..)` or \
                     annotate with `// as-ok: <reason>`",
                    b.text, b.text
                ),
            });
        }
    }

    // R3: allocation inside `*_into` functions.
    let masked_lines: Vec<&str> = masked.lines().collect();
    let mut idx = 0;
    while idx + 1 < toks.len() {
        if toks[idx].text != "fn" || !toks[idx + 1].text.ends_with("_into") {
            idx += 1;
            continue;
        }
        let fn_name = toks[idx + 1].text.clone();
        let fn_line = toks[idx + 1].line;
        // Find the body's opening brace (a `;` first = trait signature).
        let mut j = idx + 2;
        let mut open = None;
        while j < toks.len() {
            match toks[j].text.as_str() {
                "{" => {
                    open = Some(j);
                    break;
                }
                ";" => break,
                _ => j += 1,
            }
        }
        let Some(open_at) = open else {
            idx += 2;
            continue;
        };
        let mut depth = 0usize;
        let mut close_at = open_at;
        for (k, t) in toks.iter().enumerate().skip(open_at) {
            match t.text.as_str() {
                "{" => depth += 1,
                "}" => {
                    depth -= 1;
                    if depth == 0 {
                        close_at = k;
                        break;
                    }
                }
                _ => {}
            }
        }
        let (body_start, body_end) = (toks[open_at].line, toks[close_at].line);
        if !is_excluded(fn_line) {
            for ln in body_start..=body_end {
                let code = masked_lines.get(ln - 1).copied().unwrap_or("");
                let raw = raw_lines.get(ln - 1).copied().unwrap_or("");
                for pat in ALLOC_PATTERNS {
                    if alloc_hit(code, pat) && !has_marker(raw, "alloc-ok:") {
                        out.push(Violation {
                            file: rel_path.to_string(),
                            line: ln,
                            rule: "alloc-in-into",
                            message: format!(
                                "`{pat}` allocates inside hot-path fn `{fn_name}` — route \
                                 through ExecScratch or annotate with `// alloc-ok: <reason>`"
                            ),
                        });
                    }
                }
            }
        }
        idx = close_at.max(idx + 1);
    }

    out.sort_by(|x, y| (x.line, x.rule).cmp(&(y.line, y.rule)));
    out
}

/// Recursively collect `.rs` files under `dir` (sorted for stable output).
fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let mut entries = std::fs::read_dir(dir)?.collect::<Result<Vec<_>, _>>()?;
    entries.sort_by_key(|e| e.path());
    for e in entries {
        let p = e.path();
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Lint every `.rs` file under `<root>/rust/src`. Returns `(files_scanned,
/// violations)`.
pub fn lint_tree(root: &Path) -> std::io::Result<(usize, Vec<Violation>)> {
    let src_root = root.join("rust").join("src");
    let mut files = Vec::new();
    collect_rs(&src_root, &mut files)?;
    let mut all = Vec::new();
    for f in &files {
        let rel = f
            .strip_prefix(root)
            .unwrap_or(f)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        let src = std::fs::read_to_string(f)?;
        all.extend(lint_source(&rel, &src));
    }
    Ok((files.len(), all))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules(v: &[Violation]) -> Vec<&'static str> {
        v.iter().map(|x| x.rule).collect()
    }

    #[test]
    fn unsafe_outside_allowlist_fires() {
        let src = "fn f() { unsafe { core::hint::unreachable_unchecked() } }\n";
        let v = lint_source("rust/src/units/foo.rs", src);
        assert_eq!(rules(&v), ["unsafe-allowlist"]);
        assert_eq!(v[0].line, 1);
    }

    #[test]
    fn unsafe_in_allowlisted_file_needs_safety_comment() {
        let bare = "fn f() {\n    unsafe { danger() }\n}\n";
        let v = lint_source("rust/src/accel/workers.rs", bare);
        assert_eq!(rules(&v), ["unsafe-allowlist"], "missing SAFETY comment must fire");
        let ok = "fn f() {\n    // SAFETY: scope joins every task first.\n    unsafe { danger() }\n}\n";
        assert!(lint_source("rust/src/accel/workers.rs", ok).is_empty());
    }

    #[test]
    fn safety_comment_window_is_bounded() {
        let far = format!(
            "fn f() {{\n    // SAFETY: too far away.\n{}    unsafe {{ danger() }}\n}}\n",
            "    let x = 1;\n".repeat(SAFETY_WINDOW)
        );
        let v = lint_source("rust/src/accel/workers.rs", &far);
        assert_eq!(rules(&v), ["unsafe-allowlist"]);
    }

    #[test]
    fn bare_numeric_cast_fires_in_datapath_only() {
        let src = "fn f(x: usize) -> u16 { x as u16 }\n";
        let v = lint_source("rust/src/spike/foo.rs", src);
        assert_eq!(rules(&v), ["bare-cast"]);
        assert!(v[0].message.contains("as u16"), "{}", v[0].message);
        // Same source outside the datapath dirs: clean.
        assert!(lint_source("rust/src/io/foo.rs", src).is_empty());
    }

    #[test]
    fn as_ok_marker_requires_a_reason() {
        let with_reason = "fn f(x: u16) -> usize { x as usize } // as-ok: u16 -> usize widening\n";
        assert!(lint_source("rust/src/units/foo.rs", with_reason).is_empty());
        let empty_reason = "fn f(x: u16) -> usize { x as usize } // as-ok:\n";
        assert_eq!(rules(&lint_source("rust/src/units/foo.rs", empty_reason)), ["bare-cast"]);
    }

    #[test]
    fn non_numeric_as_is_not_a_cast() {
        let src = "use std::fmt as f;\nfn g(d: &dyn std::any::Any) { let _ = d as &dyn std::any::Any; }\n";
        assert!(lint_source("rust/src/units/foo.rs", src).is_empty());
    }

    #[test]
    fn casts_in_cfg_test_modules_are_exempt() {
        let src = "fn f() {}\n#[cfg(test)]\nmod tests {\n    fn g(x: usize) -> u8 { x as u8 }\n}\n";
        assert!(lint_source("rust/src/units/foo.rs", src).is_empty());
    }

    #[test]
    fn casts_in_strings_and_comments_are_masked() {
        let src = "fn f() -> &'static str {\n    // looks like x as u16 but is a comment\n    \"y as u32\"\n}\n";
        assert!(lint_source("rust/src/units/foo.rs", src).is_empty());
    }

    #[test]
    fn alloc_in_into_fn_fires() {
        let src = "fn run_into(out: &mut Vec<u32>) {\n    let v: Vec<u32> = Vec::new();\n    out.extend(v);\n}\n";
        let v = lint_source("rust/src/units/foo.rs", src);
        assert_eq!(rules(&v), ["alloc-in-into"]);
        assert!(v[0].message.contains("run_into"), "{}", v[0].message);
    }

    #[test]
    fn alloc_ok_marker_suppresses_with_reason() {
        let src = "fn run_into(out: &mut Vec<u32>) {\n    let v: Vec<u32> = Vec::new(); // alloc-ok: lifetime-bound scaffolding\n    out.extend(v);\n}\n";
        assert!(lint_source("rust/src/units/foo.rs", src).is_empty());
    }

    #[test]
    fn alloc_outside_into_fn_is_fine() {
        let src = "fn build() -> Vec<u32> {\n    let mut v = Vec::new();\n    v.collect_stats();\n    v\n}\n";
        assert!(lint_source("rust/src/units/foo.rs", src).is_empty());
    }

    #[test]
    fn every_alloc_pattern_is_detected() {
        for pat in ["Vec::new()", "vec![0; 4]", "Box::new(x)", "y.to_vec()", "it.collect()", "Vec::with_capacity(4)"] {
            let src = format!("fn f_into(x: u32) {{\n    let _ = {pat};\n}}\n");
            let v = lint_source("rust/src/accel/foo.rs", &src);
            assert_eq!(rules(&v), ["alloc-in-into"], "pattern `{pat}` must fire");
        }
    }

    #[test]
    fn lookalike_method_names_do_not_fire() {
        let src = "fn f_into(v: &mut V) {\n    v.collect_stats();\n    v.fill_with_capacity_hint();\n}\n";
        assert!(lint_source("rust/src/units/foo.rs", src).is_empty());
    }

    #[test]
    fn raw_strings_and_char_literals_mask_cleanly() {
        let src = "fn f() {\n    let _ = r#\"a as u8 \"#;\n    let _ = 'x';\n    let _: Option<&'static str> = None;\n}\n";
        assert!(lint_source("rust/src/units/foo.rs", src).is_empty());
    }

    #[test]
    fn delta_kernel_hot_paths_are_covered() {
        // The temporal-delta emitters (`rust/src/spike/delta.rs`) follow
        // the same `*_into` zero-alloc contract as every other hot-path
        // producer: R3 must fire on an allocating delta kernel and R2 on
        // an unannotated cast in the same file.
        let bad = "pub fn xor_delta_into(a: &B, b: &B, out: &mut E) {\n    \
                   let tmp: Vec<u64> = a.words().to_vec();\n    \
                   let _ = tmp.len() as u64;\n    out.use_words(&tmp);\n}\n";
        let v = lint_source("rust/src/spike/delta.rs", bad);
        assert_eq!(rules(&v), ["alloc-in-into", "bare-cast"]);
        let ok = "pub fn xor_delta_into(a: &B, b: &B, out: &mut E) {\n    \
                  for (wi, w) in a.words().iter().enumerate() {\n        \
                  let l = wi + w.trailing_zeros() as usize; // as-ok: u32 bit index widening\n        \
                  out.push(0, l);\n    }\n}\n";
        assert!(lint_source("rust/src/spike/delta.rs", ok).is_empty());
    }

    #[test]
    fn kvcache_hot_paths_are_covered() {
        // The KV-cache appenders (`rust/src/spike/kvcache.rs`) sit on the
        // per-token decode path: R3 must fire on an allocating `*_into`
        // append and R2 on an unannotated widening cast in the same file,
        // and the annotated shapes the real file uses must pass clean.
        let bad = "pub fn append_into(&mut self, k: &E, v: &E) -> Stats {\n    \
                   let row: Vec<u16> = k.addrs().to_vec();\n    \
                   let words = row.len() as u64;\n    self.store(&row);\n    \
                   Stats { words }\n}\n";
        let v = lint_source("rust/src/spike/kvcache.rs", bad);
        assert_eq!(rules(&v), ["alloc-in-into", "bare-cast"]);
        let ok = "pub fn append_into(&mut self, k: &E, v: &E) -> Stats {\n    \
                  self.row_buf.clear();\n    \
                  self.row_buf.extend_from_slice(k.addrs());\n    \
                  let words = self.row_buf.len() as u64; // as-ok: widening spike count for stats\n    \
                  self.store();\n    Stats { words }\n}\n";
        assert!(lint_source("rust/src/spike/kvcache.rs", ok).is_empty());
    }

    #[test]
    fn display_format_is_stable() {
        let v = Violation {
            file: "rust/src/x.rs".into(),
            line: 3,
            rule: "bare-cast",
            message: "msg".into(),
        };
        assert_eq!(v.to_string(), "rust/src/x.rs:3: [bare-cast] msg");
    }
}
