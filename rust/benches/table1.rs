//! Bench T1 — regenerates Table I: "COMPARISON WITH OTHER SNN
//! ACCELERATORS". Prints the published baseline columns, our modelled
//! column (resources from the calibrated resource model, peak GSOP/s from
//! lanes x clock, peak GSOP/W from the energy model), the improvement
//! factors the paper headlines (13.24x throughput, 1.33x efficiency), and
//! the same-framework simulated baseline styles as a consistency check.
//!
//! ```bash
//! cargo bench --bench table1
//! ```

use spikeformer_accel::accel::Accelerator;
use spikeformer_accel::baselines::{
    aicas23_row, iscas22_row, tcad22_row, EventDrivenFcModel, SkydiverCnnModel,
};
use spikeformer_accel::hw::{AccelConfig, EnergyModel, ResourceModel};
use spikeformer_accel::metrics::{format_table1, improvement, AccelRow};
use spikeformer_accel::model::{QuantizedModel, SdtModelConfig};
use spikeformer_accel::util::Prng;

fn main() -> anyhow::Result<()> {
    let cfg = SdtModelConfig::paper();
    let model = QuantizedModel::random(&cfg, 42);
    let hw = AccelConfig::paper();
    let energy = EnergyModel::default();
    let res = ResourceModel::default().estimate(&hw);

    // Run the paper-scale workload for the achieved-rate footnote.
    let mut accel = Accelerator::new(model, hw);
    let mut rng = Prng::new(1);
    let image: Vec<f32> = (0..3 * 32 * 32).map(|_| rng.next_f32_signed()).collect();
    let report = accel.infer(&image)?;

    let ours = AccelRow {
        name: "Ours".into(),
        year: 2024,
        network: "Trans.*".into(),
        dataset: "Cifar-10".into(),
        platform: "Virtex Ultra.".into(),
        lut: res.lut,
        ff: res.ff,
        bram: res.bram,
        freq_mhz: hw.freq_mhz,
        gsops: hw.peak_gsops(),
        gsop_per_w: energy.peak_gsop_per_w(&hw),
    };

    let rows = vec![iscas22_row(), tcad22_row(), aicas23_row(), ours.clone()];
    println!("TABLE I — COMPARISON WITH OTHER SNN ACCELERATORS\n");
    println!("{}", format_table1(&rows));

    println!("improvement factors (paper: up to 13.24x GSOP/s, up to 1.33x GSOP/W):");
    for r in &rows[..3] {
        println!(
            "  vs {:<10}  {:>6.2}x GSOP/s   {:>5.2}x GSOP/W",
            r.name,
            improvement(ours.gsops, r.gsops),
            improvement(ours.gsop_per_w, r.gsop_per_w)
        );
    }

    println!("\nachieved on the paper-scale SDT workload (D=384, T=4, 2 blocks):");
    println!(
        "  busy-time basis: {:.1} GSOP/s, {:.2} GSOP/W, {} unit-busy cycles ({:.3} ms @ 200 MHz)",
        report.gsops,
        report.gsop_per_w,
        report.total.cycles,
        report.seconds * 1e3
    );
    let exec = report.pipeline.as_ref().expect("default path executes the overlap");
    println!(
        "  executed SPS/SDEB overlap (double-buffered ESS): {} wall cycles ({:.3} ms, {:.1} GSOP/s, {:.2}x vs serializing this run's phases, bottleneck: {})",
        exec.executed_cycles,
        report.wall_seconds() * 1e3,
        report.wall_gsops(),
        exec.speedup(),
        exec.bottleneck()
    );
    let pipe = spikeformer_accel::accel::pipeline_estimate(&report.phases, cfg.timesteps);
    println!(
        "  analytic cross-check: {} pipelined cycles (reconciles within fill bound: {})",
        pipe.pipelined_cycles,
        exec.reconciles_with(&pipe)
    );

    println!("\nsame-framework baseline style models (consistency check):");
    let fc = EventDrivenFcModel::iscas22_like();
    let fc_stats = fc.run(4, 0.3, 7);
    println!(
        "  event-driven FC (ISCAS'22-like):  {:>7.1} GSOP/s (published 179*)",
        fc.gsops(&fc_stats)
    );
    let cnn = SkydiverCnnModel::tcad22_like();
    let cnn_stats = cnn.run(4, 0.25, 7);
    println!(
        "  balanced CNN (Skydiver-like):     {:>7.1} GSOP/s (published 22.6)",
        cnn.gsops(&cnn_stats)
    );
    Ok(())
}
