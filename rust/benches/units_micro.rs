//! Bench U1 — per-unit microbenchmarks: modelled cycles AND host wall-time
//! for the SMU, SMAM, and SLU against their dense/bitmap baselines across
//! a sparsity sweep, plus an encode+SDSA case comparing the flat CSR
//! spike-stream arena against the previous list-of-lists representation.
//! This is the unit-level version of the paper's redundancy-elimination
//! claim.
//!
//! The density-sweep **crossover** case compares the CSR engine against
//! the packed-`u64` bitmap engine (modelled cycles, both executed paths)
//! and reports the density where the word engine starts winning — the
//! calibration behind `EngineSelect::Adaptive`'s default threshold.
//!
//! The **delta** case times the XOR-delta kernel against a full re-encode
//! across a density sweep at three temporal-correlation levels (identical
//! / 5%-flipped / independent frames) and records the ESS words the
//! per-channel `DeltaPlan` would move — the unit-level calibration behind
//! `--temporal-delta`.
//!
//! ```bash
//! cargo bench --bench units_micro              # full sweep
//! cargo bench --bench units_micro -- --quick   # CI smoke mode
//! cargo bench --bench units_micro -- --json    # also write BENCH_encoding.json
//! ```

use spikeformer_accel::accel::Mapper;
use spikeformer_accel::benchlib::{bench, black_box, section, BenchResult};
use spikeformer_accel::hw::{AccelConfig, EngineSelect, UnitStats, DEFAULT_ADAPTIVE_THRESHOLD};
use spikeformer_accel::model::SdtModelConfig;
use spikeformer_accel::quant::QuantizedLinear;
use spikeformer_accel::scratch::ExecScratch;
use spikeformer_accel::spike::{
    xor_delta_into, EncodedSpikes, PackedBitmap, SpikeMatrix, TokenGrid,
};
use spikeformer_accel::units::{SpikeLinearUnit, SpikeMaskAddModule, SpikeMaxpoolUnit};
use spikeformer_accel::util::{div_ceil, Prng};

fn random_bitmap(rng: &mut Prng, c: usize, l: usize, p: f64) -> SpikeMatrix {
    let mut m = SpikeMatrix::zeros(c, l);
    for ci in 0..c {
        for li in 0..l {
            if rng.bernoulli(p) {
                m.set(ci, li, true);
            }
        }
    }
    m
}

fn random_encoded(rng: &mut Prng, c: usize, l: usize, p: f64) -> EncodedSpikes {
    EncodedSpikes::from_bitmap(&random_bitmap(rng, c, l, p))
}

// ---------------------------------------------------------------------------
// The seed's list-of-lists representation, kept here as the "before"
// baseline for the CSR arena: one heap Vec per channel, per-channel clones
// through the SDSA mask gate. `sdsa` mirrors the seed `SpikeMaskAddModule::
// run` line for line (comparator/match counters, acc vector, UnitStats
// construction) so the two bench closures time identical modelled work and
// differ only in the spike-stream representation.
// ---------------------------------------------------------------------------

struct LegacyEncoded {
    channels: usize,
    lists: Vec<Vec<u16>>,
}

impl LegacyEncoded {
    fn from_bitmap(m: &SpikeMatrix) -> Self {
        let mut lists = vec![Vec::new(); m.channels];
        for (c, list) in lists.iter_mut().enumerate() {
            for (l, &fired) in m.channel(c).iter().enumerate() {
                if fired {
                    list.push(l as u16);
                }
            }
        }
        Self { channels: m.channels, lists }
    }

    fn count_spikes(&self) -> usize {
        self.lists.iter().map(Vec::len).sum()
    }

    /// The seed SMAM: two-pointer merge-join per channel with the same
    /// stats accounting as `SpikeMaskAddModule::run`, then clone-or-clear
    /// V's per-channel list.
    fn sdsa(
        &self,
        k: &LegacyEncoded,
        v: &LegacyEncoded,
        v_th: u32,
        cfg: &AccelConfig,
    ) -> (Vec<bool>, Vec<u32>, Vec<Vec<u16>>, UnitStats) {
        let c = self.channels;
        let mut mask = vec![false; c];
        let mut acc = vec![0u32; c];
        let mut masked_v: Vec<Vec<u16>> = vec![Vec::new(); c];
        let mut comparator_steps: u64 = 0;
        let mut matches: u64 = 0;
        for ch in 0..c {
            let (ql, kl) = (&self.lists[ch], &k.lists[ch]);
            let (mut i, mut j) = (0usize, 0usize);
            let mut count = 0u32;
            while i < ql.len() && j < kl.len() {
                comparator_steps += 1;
                match ql[i].cmp(&kl[j]) {
                    std::cmp::Ordering::Equal => {
                        count += 1;
                        matches += 1;
                        i += 1;
                        j += 1;
                    }
                    std::cmp::Ordering::Less => i += 1,
                    std::cmp::Ordering::Greater => j += 1,
                }
            }
            acc[ch] = count;
            mask[ch] = count >= v_th;
            if mask[ch] {
                masked_v[ch] = v.lists[ch].clone();
            }
        }
        let q_spikes = self.count_spikes() as u64;
        let k_spikes = k.count_spikes() as u64;
        let retained: u64 = masked_v.iter().map(|l| l.len() as u64).sum();
        let stats = UnitStats {
            cycles: div_ceil(comparator_steps, cfg.smam_comparators as u64).max(1)
                + div_ceil(c as u64, cfg.smam_comparators as u64),
            sops: q_spikes + k_spikes + retained,
            adds: matches,
            cmps: comparator_steps + c as u64,
            sram_reads: q_spikes + k_spikes + retained,
            sram_writes: retained,
            ..Default::default()
        };
        (mask, acc, masked_v, stats)
    }
}

struct EncodeSdsaRow {
    sparsity: f64,
    csr: BenchResult,
    legacy: BenchResult,
}

/// The measured operating point, recorded alongside the numbers so the
/// emitted JSON can never claim a config that was not run.
struct EncodeSdsaCase {
    channels: usize,
    tokens: usize,
    attn_v_th: u32,
    rows: Vec<EncodeSdsaRow>,
}

fn encode_sdsa_case(quick: bool) -> EncodeSdsaCase {
    // Paper operating point: D=384 channels, 64 tokens per head tensor.
    let model_cfg = SdtModelConfig::paper();
    let (c, l) = (model_cfg.embed_dim, model_cfg.num_tokens());
    let hw = AccelConfig::paper();
    let smam = SpikeMaskAddModule::new(model_cfg.attn_v_th);
    let (warmup, iters) = if quick { (1, 3) } else { (3, 50) };
    // Fig-6 regime: the paper reports SDSA/linear sparsities of ~0.8-0.97.
    let sparsities: &[f64] = if quick { &[0.9] } else { &[0.8, 0.9, 0.95] };

    section(&format!(
        "encode + SDSA: CSR arena vs list-of-lists ({c}ch, {l} tok, paper config)"
    ));
    let mut rows = Vec::new();
    let mut rng = Prng::new(23);
    for &s in sparsities {
        let p = 1.0 - s;
        let qm = random_bitmap(&mut rng, c, l, p);
        let km = random_bitmap(&mut rng, c, l, p);
        let vm = random_bitmap(&mut rng, c, l, p);

        let csr = bench(&format!("csr    encode+sdsa @{s:.2} sparsity"), warmup, iters, || {
            let q = EncodedSpikes::from_bitmap(&qm);
            let k = EncodedSpikes::from_bitmap(&km);
            let v = EncodedSpikes::from_bitmap(&vm);
            let (out, stats) = smam.run(&q, &k, &v, &hw);
            black_box((out, stats));
        });
        let legacy = bench(&format!("legacy encode+sdsa @{s:.2} sparsity"), warmup, iters, || {
            let q = LegacyEncoded::from_bitmap(&qm);
            let k = LegacyEncoded::from_bitmap(&km);
            let v = LegacyEncoded::from_bitmap(&vm);
            let out = q.sdsa(&k, &v, model_cfg.attn_v_th, &hw);
            black_box(out);
        });
        println!(
            "  -> csr/legacy median ratio {:.2}x",
            legacy.median_s / csr.median_s.max(1e-12)
        );
        rows.push(EncodeSdsaRow { sparsity: s, csr, legacy });
    }
    EncodeSdsaCase {
        channels: c,
        tokens: l,
        attn_v_th: model_cfg.attn_v_th,
        rows,
    }
}

fn write_json(case: &EncodeSdsaCase) {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_encoding.json");
    let mut entry = String::from("{\n");
    entry.push_str(&format!(
        "    \"config\": {{\"channels\": {}, \"tokens\": {}, \"accel\": \"paper\", \"attn_v_th\": {}}},\n",
        case.channels, case.tokens, case.attn_v_th
    ));
    entry.push_str("    \"units\": \"seconds (median wall time per iteration, release build)\",\n");
    entry.push_str("    \"results\": [\n");
    for (i, r) in case.rows.iter().enumerate() {
        entry.push_str(&format!(
            "      {{\"sparsity\": {:.2}, \"csr_arena_s\": {:.9}, \"list_of_lists_s\": {:.9}, \"speedup\": {:.3}}}{}\n",
            r.sparsity,
            r.csr.median_s,
            r.legacy.median_s,
            r.legacy.median_s / r.csr.median_s.max(1e-12),
            if i + 1 == case.rows.len() { "" } else { "," }
        ));
    }
    entry.push_str("    ]\n  }");
    // Merge under this bench's key so other sections of the file survive.
    match spikeformer_accel::benchlib::merge_bench_json(path, "encode+sdsa", &entry) {
        Ok(()) => println!("\nwrote {path} (section \"encode+sdsa\")"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
}

// ---------------------------------------------------------------------------
// Dual-engine crossover: modelled cycles of the CSR address-stream engine
// vs the packed-u64 bitmap engine across a density sweep, for the SLU and
// the SMAM. Deterministic (cycle model, not wall time); the reported
// crossover calibrates `EngineSelect::Adaptive`'s default threshold.
// ---------------------------------------------------------------------------

struct CrossoverRow {
    density: f64,
    slu_csr: u64,
    slu_bitmap: u64,
    smam_csr: u64,
    smam_bitmap: u64,
}

/// First swept density at which the bitmap engine's cycles stop exceeding
/// the CSR engine's (None: the word engine never wins in this sweep).
fn first_win(rows: &[CrossoverRow], f: impl Fn(&CrossoverRow) -> (u64, u64)) -> Option<f64> {
    rows.iter().find(|r| {
        let (csr, bitmap) = f(r);
        bitmap <= csr
    }).map(|r| r.density)
}

fn crossover_case(quick: bool) -> Vec<CrossoverRow> {
    let model_cfg = SdtModelConfig::paper();
    let (c, l) = (model_cfg.embed_dim, model_cfg.num_tokens());
    let mut csr_cfg = AccelConfig::paper();
    csr_cfg.engine = EngineSelect::Csr;
    let mut bm_cfg = AccelConfig::paper();
    bm_cfg.engine = EngineSelect::Bitmap;
    let densities: &[f64] = if quick {
        &[0.005, 0.02, 0.1]
    } else {
        &[0.001, 0.002, 0.005, 0.01, 0.02, 0.05, 0.1, 0.2, 0.5]
    };

    section(&format!(
        "dual-engine crossover: CSR vs packed-u64 bitmap ({c}ch, {l} tok, paper config)"
    ));
    let wf: Vec<f32> = {
        let mut wrng = Prng::new(31);
        (0..c * c).map(|_| wrng.next_f32_signed() * 0.1).collect()
    };
    let layer = QuantizedLinear::from_f32(&wf, &vec![0.0; c], c, c, 0);
    let smam = SpikeMaskAddModule::new(model_cfg.attn_v_th);
    let serial = Mapper::serial();
    let mut scratch = ExecScratch::new();
    let mut rng = Prng::new(29);

    println!(
        "{:<12}{:>14}{:>14}{:>14}{:>14}",
        "density", "slu csr", "slu bitmap", "smam csr", "smam bitmap"
    );
    let mut rows = Vec::new();
    for &d in densities {
        let x = random_bitmap(&mut rng, c, l, d);
        let enc = EncodedSpikes::from_bitmap(&x);
        let packed = PackedBitmap::from_encoded(&enc);
        let mut slu = SpikeLinearUnit::new();
        let (_, s_csr) = slu.forward(&enc, &layer, &csr_cfg);
        let mut slu = SpikeLinearUnit::new();
        let (_, s_bm) = slu.forward_bitmap(&packed, &layer, &csr_cfg);

        let q = random_encoded(&mut rng, c, l, d);
        let k = random_encoded(&mut rng, c, l, d);
        let v = random_encoded(&mut rng, c, l, d);
        let (_, m_csr) = smam.run_mapped_into(&q, &k, &v, &csr_cfg, &serial, 0, None, &mut scratch);
        let (_, m_bm) = smam.run_mapped_into(&q, &k, &v, &bm_cfg, &serial, 0, None, &mut scratch);

        println!(
            "{:<12.3}{:>14}{:>14}{:>14}{:>14}",
            d, s_csr.cycles, s_bm.cycles, m_csr.cycles, m_bm.cycles
        );
        rows.push(CrossoverRow {
            density: d,
            slu_csr: s_csr.cycles,
            slu_bitmap: s_bm.cycles,
            smam_csr: m_csr.cycles,
            smam_bitmap: m_bm.cycles,
        });
    }
    let slu_x = first_win(&rows, |r| (r.slu_csr, r.slu_bitmap));
    let smam_x = first_win(&rows, |r| (r.smam_csr, r.smam_bitmap));
    println!(
        "  -> bitmap engine wins from density {} (SLU) / {} (SMAM); default adaptive threshold {DEFAULT_ADAPTIVE_THRESHOLD}",
        slu_x.map_or("never".into(), |d| format!("{d}")),
        smam_x.map_or("never".into(), |d| format!("{d}")),
    );
    rows
}

fn write_crossover_json(rows: &[CrossoverRow], channels: usize, tokens: usize) {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_encoding.json");
    let fmt_x = |x: Option<f64>| x.map_or("null".to_string(), |d| format!("{d}"));
    let mut entry = String::from("{\n");
    entry.push_str(&format!(
        "    \"config\": {{\"channels\": {channels}, \"tokens\": {tokens}, \"accel\": \"paper\"}},\n"
    ));
    entry.push_str("    \"units\": \"modelled cycles per call (deterministic)\",\n");
    entry.push_str(&format!(
        "    \"default_adaptive_threshold\": {DEFAULT_ADAPTIVE_THRESHOLD},\n"
    ));
    entry.push_str("    \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        entry.push_str(&format!(
            "      {{\"density\": {}, \"slu_csr_cycles\": {}, \"slu_bitmap_cycles\": {}, \"smam_csr_cycles\": {}, \"smam_bitmap_cycles\": {}}}{}\n",
            r.density,
            r.slu_csr,
            r.slu_bitmap,
            r.smam_csr,
            r.smam_bitmap,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    entry.push_str("    ],\n");
    entry.push_str(&format!(
        "    \"bitmap_wins_from_density\": {{\"slu\": {}, \"smam\": {}}}\n",
        fmt_x(first_win(rows, |r| (r.slu_csr, r.slu_bitmap))),
        fmt_x(first_win(rows, |r| (r.smam_csr, r.smam_bitmap))),
    ));
    entry.push_str("  }");
    match spikeformer_accel::benchlib::merge_bench_json(path, "crossover", &entry) {
        Ok(()) => println!("wrote {path} (section \"crossover\")"),
        Err(e) => eprintln!("failed to write {path}: {e}"),
    }
}

// ---------------------------------------------------------------------------
// Temporal delta: the XOR-delta kernel vs a full re-encode of the next
// frame, across a density sweep at three temporal-correlation levels
// (identical frames / 5%-of-positions flipped / independent frames). Host
// wall time plus the modelled ESS word traffic the per-channel DeltaPlan
// would move — the unit-level version of the `--temporal-delta` claim.
// ---------------------------------------------------------------------------

struct DeltaRow {
    density: f64,
    correlation: &'static str,
    xor_delta: BenchResult,
    reencode: BenchResult,
    moved_words: usize,
    full_words: usize,
}

fn delta_case(quick: bool) -> Vec<DeltaRow> {
    let model_cfg = SdtModelConfig::paper();
    let (c, l) = (model_cfg.embed_dim, model_cfg.num_tokens());
    let densities: &[f64] = if quick {
        &[0.02, 0.1, 0.5]
    } else {
        &[0.001, 0.002, 0.005, 0.01, 0.02, 0.05, 0.1, 0.2, 0.5]
    };
    // (label, flip probability): negative = fresh independent frame.
    let correlations: &[(&'static str, f64)] =
        &[("identical", 0.0), ("flip5", 0.05), ("independent", -1.0)];
    let (warmup, iters) = if quick { (1, 3) } else { (3, 50) };

    section(&format!(
        "temporal delta: XOR-delta kernel vs full re-encode ({c}ch, {l} tok, paper config)"
    ));
    println!(
        "{:<10}{:<14}{:>14}{:>14}{:>12}{:>12}",
        "density", "correlation", "delta s", "re-encode s", "moved wd", "full wd"
    );
    let mut scratch = ExecScratch::new();
    let mut rng = Prng::new(37);
    let mut rows = Vec::new();
    for &d in densities {
        let prev_m = random_bitmap(&mut rng, c, l, d);
        let prev = EncodedSpikes::from_bitmap(&prev_m);
        let pb = PackedBitmap::from_encoded(&prev);
        for &(label, flip) in correlations {
            let mut curr_m = prev_m.clone();
            if flip < 0.0 {
                curr_m = random_bitmap(&mut rng, c, l, d);
            } else if flip > 0.0 {
                for ci in 0..c {
                    for li in 0..l {
                        if rng.bernoulli(flip) {
                            let v = curr_m.get(ci, li);
                            curr_m.set(ci, li, !v);
                        }
                    }
                }
            }
            let curr = EncodedSpikes::from_bitmap(&curr_m);
            let cb = PackedBitmap::from_encoded(&curr);
            let full_words = curr.storage_words();
            let moved_words = spikeformer_accel::spike::delta::moved_words(&pb, &cb, &curr);
            assert!(moved_words <= full_words, "DeltaPlan must never move more than full");
            if label == "identical" {
                assert_eq!(moved_words, 0, "identical frames must move zero words");
            }
            let xor_delta =
                bench(&format!("xor-delta @d={d} {label}"), warmup, iters, || {
                    let mut out = scratch.take_enc(c, l);
                    xor_delta_into(&pb, &cb, &mut out);
                    black_box(&out);
                    scratch.put_enc(out);
                });
            let reencode =
                bench(&format!("re-encode @d={d} {label}"), warmup, iters, || {
                    let e = EncodedSpikes::from_bitmap(&curr_m);
                    black_box(e);
                });
            println!(
                "{:<10.3}{:<14}{:>14.9}{:>14.9}{:>12}{:>12}",
                d, label, xor_delta.median_s, reencode.median_s, moved_words, full_words
            );
            rows.push(DeltaRow {
                density: d,
                correlation: label,
                xor_delta,
                reencode,
                moved_words,
                full_words,
            });
        }
    }
    rows
}

fn write_delta_json(rows: &[DeltaRow], channels: usize, tokens: usize) {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_encoding.json");
    let mut entry = String::from("{\n");
    entry.push_str(&format!(
        "    \"config\": {{\"channels\": {channels}, \"tokens\": {tokens}, \"accel\": \"paper\"}},\n"
    ));
    entry.push_str(
        "    \"units\": \"seconds (median wall time per iteration, release build); moved_words = ESS words the per-channel DeltaPlan ships vs a full re-store\",\n",
    );
    entry.push_str("    \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        entry.push_str(&format!(
            "      {{\"density\": {}, \"correlation\": \"{}\", \"xor_delta_s\": {:.9}, \"reencode_s\": {:.9}, \"moved_words\": {}, \"full_words\": {}}}{}\n",
            r.density,
            r.correlation,
            r.xor_delta.median_s,
            r.reencode.median_s,
            r.moved_words,
            r.full_words,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    entry.push_str("    ]\n  }");
    match spikeformer_accel::benchlib::merge_bench_json(path, "delta", &entry) {
        Ok(()) => println!("wrote {path} (section \"delta\")"),
        Err(e) => eprintln!("failed to write {path}: {e}"),
    }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick");
    let json = args.iter().any(|a| a == "--json");

    let cfg = AccelConfig::paper();
    let mut rng = Prng::new(11);
    let sweep: &[f64] = if quick { &[0.1] } else { &[0.05, 0.1, 0.2, 0.3, 0.5] };

    section("SMU: spike maxpool vs dense maxpool (384ch, 32x32, k2s2)");
    let grid = TokenGrid::new(32, 32);
    let smu = SpikeMaxpoolUnit::new(2, 2);
    println!(
        "{:<12}{:>16}{:>16}{:>10}",
        "sparsity", "enc cycles", "dense cycles", "saving"
    );
    for &p in sweep {
        let enc = random_encoded(&mut rng, 384, 1024, p);
        let (_, s1) = smu.pool(&enc, grid, &cfg);
        let (_, s2) = smu.pool_dense_baseline(&enc, grid, &cfg);
        println!(
            "{:<12.2}{:>16}{:>16}{:>9.1}x",
            1.0 - p,
            s1.cycles,
            s2.cycles,
            s2.cycles as f64 / s1.cycles as f64
        );
    }

    section("SMAM: merge-join vs dense Hadamard (384ch, 64 tokens)");
    let smam = SpikeMaskAddModule::new(2);
    println!(
        "{:<12}{:>16}{:>16}{:>10}",
        "sparsity", "enc cycles", "dense cycles", "saving"
    );
    for &p in sweep {
        let q = random_encoded(&mut rng, 384, 64, p);
        let k = random_encoded(&mut rng, 384, 64, p);
        let v = random_encoded(&mut rng, 384, 64, p);
        let (_, s1) = smam.run(&q, &k, &v, &cfg);
        let (_, s2) = smam.run_dense_baseline(&q, &k, &v, &cfg);
        println!(
            "{:<12.2}{:>16}{:>16}{:>9.1}x",
            1.0 - p,
            s1.cycles,
            s2.cycles,
            s2.cycles as f64 / s1.cycles as f64
        );
    }

    section("SLU: encoded vs bitmap vs dense linear (384 -> 384, 64 tokens)");
    let wf: Vec<f32> = (0..384 * 384).map(|_| rng.next_f32_signed() * 0.1).collect();
    let layer = QuantizedLinear::from_f32(&wf, &vec![0.0; 384], 384, 384, 0);
    println!(
        "{:<12}{:>14}{:>14}{:>14}{:>12}{:>12}",
        "sparsity", "enc cycles", "bitmap cyc", "dense cyc", "vs bitmap", "vs dense"
    );
    for &p in sweep {
        let x = random_encoded(&mut rng, 384, 64, p);
        let mut slu = SpikeLinearUnit::new();
        let (_, s1) = slu.forward(&x, &layer, &cfg);
        let (_, s2) = slu.forward_bitmap_baseline(&x, &layer, &cfg);
        let (_, s3) = slu.forward_dense_baseline(&x, &layer, &cfg);
        println!(
            "{:<12.2}{:>14}{:>14}{:>14}{:>11.2}x{:>11.2}x",
            1.0 - p,
            s1.cycles,
            s2.cycles,
            s3.cycles,
            s2.cycles as f64 / s1.cycles as f64,
            s3.cycles as f64 / s1.cycles as f64
        );
    }

    // The dual-engine density sweep (adaptive-threshold calibration).
    let model_cfg = SdtModelConfig::paper();
    let rows = crossover_case(quick);

    // The temporal-delta kernel sweep (`--temporal-delta` calibration).
    let delta_rows = delta_case(quick);

    // The CSR-vs-legacy before/after case (perf trajectory anchor).
    let case = encode_sdsa_case(quick);
    if json {
        write_json(&case);
        write_crossover_json(&rows, model_cfg.embed_dim, model_cfg.num_tokens());
        write_delta_json(&delta_rows, model_cfg.embed_dim, model_cfg.num_tokens());
    }

    if quick {
        println!("\n--quick: skipping host wall-time section");
        return;
    }

    section("host wall-time (release): the simulator's own hot paths");
    let x = random_encoded(&mut rng, 384, 64, 0.2);
    let mut slu = SpikeLinearUnit::new();
    bench("slu.forward 384x384 @20% spikes", 3, 30, || {
        let (out, _) = slu.forward(&x, &layer, &cfg);
        black_box(out);
    });
    let q = random_encoded(&mut rng, 384, 64, 0.2);
    let k = random_encoded(&mut rng, 384, 64, 0.2);
    let v = random_encoded(&mut rng, 384, 64, 0.2);
    bench("smam.run 384ch @20% spikes", 3, 100, || {
        let (out, _) = smam.run(&q, &k, &v, &cfg);
        black_box(out);
    });
    let enc = random_encoded(&mut rng, 384, 1024, 0.2);
    bench("smu.pool 384ch 32x32 @20% spikes", 3, 100, || {
        let (out, _) = smu.pool(&enc, grid, &cfg);
        black_box(out);
    });
}
