//! Bench U1 — per-unit microbenchmarks: modelled cycles AND host wall-time
//! for the SMU, SMAM, and SLU against their dense/bitmap baselines across
//! a sparsity sweep. This is the unit-level version of the paper's
//! redundancy-elimination claim.
//!
//! ```bash
//! cargo bench --bench units_micro
//! ```

use spikeformer_accel::benchlib::{bench, black_box, section};
use spikeformer_accel::hw::AccelConfig;
use spikeformer_accel::quant::QuantizedLinear;
use spikeformer_accel::spike::{EncodedSpikes, SpikeMatrix, TokenGrid};
use spikeformer_accel::units::{SpikeLinearUnit, SpikeMaskAddModule, SpikeMaxpoolUnit};
use spikeformer_accel::util::Prng;

fn random_encoded(rng: &mut Prng, c: usize, l: usize, p: f64) -> EncodedSpikes {
    let mut m = SpikeMatrix::zeros(c, l);
    for ci in 0..c {
        for li in 0..l {
            if rng.bernoulli(p) {
                m.set(ci, li, true);
            }
        }
    }
    EncodedSpikes::from_bitmap(&m)
}

fn main() {
    let cfg = AccelConfig::paper();
    let mut rng = Prng::new(11);

    section("SMU: spike maxpool vs dense maxpool (384ch, 32x32, k2s2)");
    let grid = TokenGrid::new(32, 32);
    let smu = SpikeMaxpoolUnit::new(2, 2);
    println!(
        "{:<12}{:>16}{:>16}{:>10}",
        "sparsity", "enc cycles", "dense cycles", "saving"
    );
    for &p in &[0.05, 0.1, 0.2, 0.3, 0.5] {
        let enc = random_encoded(&mut rng, 384, 1024, p);
        let (_, s1) = smu.pool(&enc, grid, &cfg);
        let (_, s2) = smu.pool_dense_baseline(&enc, grid, &cfg);
        println!(
            "{:<12.2}{:>16}{:>16}{:>9.1}x",
            1.0 - p,
            s1.cycles,
            s2.cycles,
            s2.cycles as f64 / s1.cycles as f64
        );
    }

    section("SMAM: merge-join vs dense Hadamard (384ch, 64 tokens)");
    let smam = SpikeMaskAddModule::new(2);
    println!(
        "{:<12}{:>16}{:>16}{:>10}",
        "sparsity", "enc cycles", "dense cycles", "saving"
    );
    for &p in &[0.05, 0.1, 0.2, 0.3, 0.5] {
        let q = random_encoded(&mut rng, 384, 64, p);
        let k = random_encoded(&mut rng, 384, 64, p);
        let v = random_encoded(&mut rng, 384, 64, p);
        let (_, s1) = smam.run(&q, &k, &v, &cfg);
        let (_, s2) = smam.run_dense_baseline(&q, &k, &v, &cfg);
        println!(
            "{:<12.2}{:>16}{:>16}{:>9.1}x",
            1.0 - p,
            s1.cycles,
            s2.cycles,
            s2.cycles as f64 / s1.cycles as f64
        );
    }

    section("SLU: encoded vs bitmap vs dense linear (384 -> 384, 64 tokens)");
    let wf: Vec<f32> = (0..384 * 384).map(|_| rng.next_f32_signed() * 0.1).collect();
    let layer = QuantizedLinear::from_f32(&wf, &vec![0.0; 384], 384, 384, 0);
    println!(
        "{:<12}{:>14}{:>14}{:>14}{:>12}{:>12}",
        "sparsity", "enc cycles", "bitmap cyc", "dense cyc", "vs bitmap", "vs dense"
    );
    for &p in &[0.05, 0.1, 0.2, 0.3, 0.5] {
        let x = random_encoded(&mut rng, 384, 64, p);
        let mut slu = SpikeLinearUnit::new();
        let (_, s1) = slu.forward(&x, &layer, &cfg);
        let (_, s2) = slu.forward_bitmap_baseline(&x, &layer, &cfg);
        let (_, s3) = slu.forward_dense_baseline(&x, &layer, &cfg);
        println!(
            "{:<12.2}{:>14}{:>14}{:>14}{:>11.2}x{:>11.2}x",
            1.0 - p,
            s1.cycles,
            s2.cycles,
            s3.cycles,
            s2.cycles as f64 / s1.cycles as f64,
            s3.cycles as f64 / s1.cycles as f64
        );
    }

    section("host wall-time (release): the simulator's own hot paths");
    let x = random_encoded(&mut rng, 384, 64, 0.2);
    let mut slu = SpikeLinearUnit::new();
    bench("slu.forward 384x384 @20% spikes", 3, 30, || {
        let (out, _) = slu.forward(&x, &layer, &cfg);
        black_box(out);
    });
    let q = random_encoded(&mut rng, 384, 64, 0.2);
    let k = random_encoded(&mut rng, 384, 64, 0.2);
    let v = random_encoded(&mut rng, 384, 64, 0.2);
    bench("smam.run 384ch @20% spikes", 3, 100, || {
        let (out, _) = smam.run(&q, &k, &v, &cfg);
        black_box(out);
    });
    let enc = random_encoded(&mut rng, 384, 1024, 0.2);
    bench("smu.pool 384ch 32x32 @20% spikes", 3, 100, || {
        let (out, _) = smu.pool(&enc, grid, &cfg);
        black_box(out);
    });
}
