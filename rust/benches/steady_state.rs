//! Bench S1 — steady-state serving runtime: host requests/sec and heap
//! allocations-per-inference of the pooled accelerator (persistent worker
//! pool + recycled scratch + batched forward) against fresh-allocation
//! execution (a new accelerator per batch: cold scratch pools, new pool
//! threads, cloned model — what a coordinator without persistent backends
//! would pay).
//!
//! Allocation counts come from a counting global allocator wrapped around
//! the system allocator, so they measure the real heap traffic of the
//! whole inference (scratch pools included), not just the modelled units.
//! Logits are asserted bit-identical between every mode.
//!
//! ```bash
//! cargo bench --bench steady_state                 # full sweep
//! cargo bench --bench steady_state -- --quick      # CI smoke mode
//! cargo bench --bench steady_state -- --json       # merge into BENCH_steady_state.json
//! cargo bench --bench steady_state -- --workers N  # size the SDEB worker pool
//! cargo bench --bench steady_state -- --sdeb-cores N --pipeline-depth N --mapping POLICY
//! ```

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use spikeformer_accel::accel::{Accelerator, DatapathMode, ExecMode, MappingPolicy};
use spikeformer_accel::benchlib::{apply_topology_args, arg_value, merge_bench_json, section};
use spikeformer_accel::hw::AccelConfig;
use spikeformer_accel::model::{QuantizedModel, SdtModelConfig};
use spikeformer_accel::util::Prng;

/// System allocator wrapper counting every allocation (and growth
/// reallocation) — the "allocations per inference" measurement.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs_now() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

struct CaseResult {
    mode: &'static str,
    batch: usize,
    req_per_s: f64,
    allocs_per_inference: f64,
}

/// Fresh-allocation baseline: a new accelerator (cold pools, new worker
/// threads, cloned model) per batch.
fn run_fresh(
    model: &QuantizedModel,
    hw: AccelConfig,
    pool_workers: usize,
    mapping: MappingPolicy,
    imgs: &[Vec<f32>],
    batch: usize,
) -> (CaseResult, Vec<Vec<f32>>) {
    let mut logits = Vec::new();
    let a0 = allocs_now();
    let t0 = Instant::now();
    for chunk in imgs.chunks(batch) {
        let mut accel = Accelerator::with_runtime(
            model.clone(),
            hw,
            DatapathMode::Encoded,
            ExecMode::Overlapped,
            pool_workers,
        )
        .with_mapping(mapping);
        for r in accel.infer_batch(chunk).expect("inference failed") {
            logits.push(r.logits);
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    let da = allocs_now() - a0;
    (
        CaseResult {
            mode: "fresh",
            batch,
            req_per_s: imgs.len() as f64 / dt.max(1e-12),
            allocs_per_inference: da as f64 / imgs.len() as f64,
        },
        logits,
    )
}

/// Pooled steady state: one persistent accelerator, warmed before timing.
fn run_pooled(
    accel: &mut Accelerator,
    imgs: &[Vec<f32>],
    batch: usize,
) -> (CaseResult, Vec<Vec<f32>>) {
    // Warm-up pass populates the scratch pools and batch lanes.
    for chunk in imgs.chunks(batch) {
        accel.infer_batch(chunk).expect("warm-up failed");
    }
    let mut logits = Vec::new();
    let a0 = allocs_now();
    let t0 = Instant::now();
    for chunk in imgs.chunks(batch) {
        for r in accel.infer_batch(chunk).expect("inference failed") {
            logits.push(r.logits);
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    let da = allocs_now() - a0;
    (
        CaseResult {
            mode: "pooled",
            batch,
            req_per_s: imgs.len() as f64 / dt.max(1e-12),
            allocs_per_inference: da as f64 / imgs.len() as f64,
        },
        logits,
    )
}

fn write_json(model_name: &str, pool_workers: usize, results: &[CaseResult]) {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_steady_state.json");
    let mut entry = String::from("{\n");
    entry.push_str(&format!(
        "    \"config\": {{\"model\": \"{model_name}\", \"accel\": \"paper\", \"pool_workers\": {pool_workers}}},\n"
    ));
    entry.push_str(
        "    \"units\": \"req_per_s = completed inferences per host second (release build); allocs_per_inference = heap allocations per inference via a counting global allocator; fresh = new accelerator per batch, pooled = persistent warmed accelerator\",\n",
    );
    entry.push_str("    \"results\": [\n");
    for (i, r) in results.iter().enumerate() {
        entry.push_str(&format!(
            "      {{\"mode\": \"{}\", \"batch\": {}, \"req_per_s\": {:.3}, \"allocs_per_inference\": {:.1}}}{}\n",
            r.mode,
            r.batch,
            r.req_per_s,
            r.allocs_per_inference,
            if i + 1 == results.len() { "" } else { "," }
        ));
    }
    entry.push_str("    ]\n  }");
    match merge_bench_json(path, "steady_state", &entry) {
        Ok(()) => println!("\nwrote {path} (section \"steady_state\")"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick");
    let json = args.iter().any(|a| a == "--json");
    let pool_workers = arg_value(&args, "--workers").unwrap_or(0);

    // Tiny-scale fabric but a multi-head, multi-block model: the bench
    // measures *host* runtime behaviour (fresh-vs-pooled contrast stays
    // visible in seconds) and the `--sdeb-cores`/`--mapping` topology
    // path actually exercises head mapping (a single head would clamp
    // every topology to 1 effective core).
    let cfg = SdtModelConfig {
        name: "steady".into(),
        num_blocks: 2,
        num_heads: 8,
        ..SdtModelConfig::tiny()
    };
    let model = QuantizedModel::random(&cfg, 42);
    // Topology knobs: SDEB-core count, ring depth, head->core policy.
    let mut hw = AccelConfig::paper();
    let mapping = apply_topology_args(&args, &mut hw);
    let n_req = if quick { 8 } else { 32 };
    let mut rng = Prng::new(17);
    let imgs: Vec<Vec<f32>> = (0..n_req)
        .map(|_| (0..3 * 32 * 32).map(|_| rng.next_f32_signed()).collect())
        .collect();

    let mut accel = Accelerator::with_runtime(
        model.clone(),
        hw,
        DatapathMode::Encoded,
        ExecMode::Overlapped,
        pool_workers,
    )
    .with_mapping(mapping);

    section(&format!(
        "steady-state serving: fresh vs pooled, {} requests (model `{}`, pool workers {})",
        n_req,
        cfg.name,
        accel.pool_workers()
    ));
    println!(
        "{:<8}{:<8}{:>14}{:>22}",
        "mode", "batch", "req/s", "allocs/inference"
    );
    let mut results = Vec::new();
    let batches: &[usize] = if quick { &[1, 8] } else { &[1, 4, 8] };
    for &batch in batches {
        let (fresh, fresh_logits) = run_fresh(&model, hw, pool_workers, mapping, &imgs, batch);
        let (pooled, pooled_logits) = run_pooled(&mut accel, &imgs, batch);
        assert_eq!(fresh_logits, pooled_logits, "pooled runtime must be bit-exact");
        for r in [fresh, pooled] {
            println!(
                "{:<8}{:<8}{:>14.2}{:>22.1}",
                r.mode, r.batch, r.req_per_s, r.allocs_per_inference
            );
            results.push(r);
        }
    }

    let stats = accel.scratch_stats();
    println!(
        "\nscratch pools after run: hits={} misses={} (hit rate {:.4})",
        stats.hits,
        stats.misses,
        stats.hit_rate()
    );

    if json {
        write_json(&cfg.name, pool_workers, &results);
    }
}
