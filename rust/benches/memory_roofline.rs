//! Bench M1 — the memory roofline of the executed pipeline: wall cycles
//! and weight-streaming stall fraction as the external-memory bandwidth
//! (`--dram-bw`) sweeps across the paper fabric, per topology point.
//!
//! The compute-cycle traces of a run are bandwidth-independent, so the
//! bench executes the paper-scale model **once**, then re-times the
//! recorded traces through the schedule recurrence at every
//! (bandwidth × SPS-core) point — exact, fast, and cross-checked against
//! one real inference at the most bandwidth-hungry point. The expected
//! shape is a roofline: compute-bound (zero stall) at high bandwidth, a
//! knee where the per-timestep weight streams (2 × ~3.5 MB at paper
//! scale) outgrow the compute period, and bandwidth-bound growth below
//! it. Scaling the SPS stage to more cores shrinks the compute period
//! and pushes the knee to higher bandwidths — at 4 SPS cores the paper's
//! own 16 B/cycle interface is already past it (nonzero stall), which is
//! the acceptance point `tests/memory_system.rs` pins.
//!
//! ```bash
//! cargo bench --bench memory_roofline             # full sweep
//! cargo bench --bench memory_roofline -- --quick  # CI smoke
//! cargo bench --bench memory_roofline -- --json   # merge into BENCH_memory.json
//! ```

use spikeformer_accel::accel::{Accelerator, DmaEngine, PipelineExecution};
use spikeformer_accel::benchlib::{merge_bench_json, section};
use spikeformer_accel::hw::{AccelConfig, CoreTopology};
use spikeformer_accel::model::{QuantizedModel, SdtModelConfig};
use spikeformer_accel::util::Prng;

struct Row {
    sps_cores: usize,
    dram_bw: usize,
    wall_cycles: u64,
    stall_cycles: u64,
    stall_fraction: f64,
    bus_utilization: f64,
    streamed_bytes_full: u64,
    streamed_bytes_delta: u64,
}

fn bw_label(bw: usize) -> String {
    if bw == usize::MAX { "inf".into() } else { bw.to_string() }
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick");

    let cfg = SdtModelConfig::paper();
    let model = QuantizedModel::random(&cfg, 42);
    let mut rng = Prng::new(2);
    let image: Vec<f32> = (0..3 * 32 * 32).map(|_| rng.next_f32_signed()).collect();

    // One executed run at the paper point records the (bandwidth- and
    // SPS-core-independent) stage traces; every sweep point below is an
    // exact re-timing of those traces. The SDEB-core count stays at the
    // paper's 2 throughout — it shapes the traces themselves.
    section("recording the paper-point traces (one executed inference)");
    let hw = AccelConfig::paper();
    let mut accel = Accelerator::new(model.clone(), hw);
    let r = accel.infer(&image)?;
    let p = r.pipeline.as_ref().expect("overlapped run records its schedule");
    println!(
        "paper point: wall={} cycles, stall={} ({:.2}%), weights streamed = {:.2} MB/inference",
        p.executed_cycles,
        p.stall_cycles,
        100.0 * p.stall_fraction(),
        r.memory().map(|m| m.weight_bytes() as f64 / 1e6).unwrap_or(0.0)
    );

    // A second executed inference with `--temporal-delta` on: values must
    // be bit-identical, and the SDEB input stores must move no more than
    // the full re-store baseline. The spike-traffic pair is
    // bandwidth-independent (it is measured by the cores, not the bus),
    // so it rides along as a column pair on every sweep row below.
    section("delta pass: executed inference with --temporal-delta on");
    let mut hw_delta = AccelConfig::paper();
    hw_delta.temporal_delta = true;
    let mut accel_delta = Accelerator::new(model.clone(), hw_delta);
    let rd = accel_delta.infer(&image)?;
    assert_eq!(r.logits, rd.logits, "--temporal-delta must not change values");
    let m_off = r.memory().expect("memory lane active");
    let m_on = rd.memory().expect("memory lane active");
    assert_eq!(
        m_off.spike_bytes_moved, m_off.spike_bytes_full,
        "flag off must move the full stores"
    );
    assert!(m_on.spike_bytes_moved <= m_on.spike_bytes_full, "delta must never move more");
    let (spike_full, spike_delta) = (m_on.spike_bytes_full, m_on.spike_bytes_moved);
    println!(
        "spike input stores: full={:.3} MB, delta-moved={:.3} MB ({:.1}% saved); regimes resident={} thrash={} streaming={}",
        spike_full as f64 / 1e6,
        spike_delta as f64 / 1e6,
        100.0 * (1.0 - spike_delta as f64 / spike_full.max(1) as f64),
        m_on.resident_blocks,
        m_on.thrash_blocks,
        m_on.streaming_blocks,
    );

    let bws: &[usize] = if quick {
        &[4, 16, usize::MAX]
    } else {
        &[1, 2, 4, 6, 8, 10, 12, 16, 24, 32, 48, 64, 128, 256, usize::MAX]
    };
    // (SPS cores, ring depth): scaling the producer pushes the knee up.
    let topo_points: &[(usize, usize)] = &[(1, 2), (2, 4), (4, 6)];

    // The classification is bandwidth-independent (and block→core
    // affinity does not depend on the SPS-core count), so one plan
    // retargets across the whole sweep.
    let dma_plan = DmaEngine::new(accel.model(), &hw);
    let mut rows: Vec<Row> = Vec::new();
    for &(sps_cores, depth) in topo_points {
        let topo = CoreTopology {
            sps_cores,
            pipeline_depth: depth,
            ..CoreTopology::paper()
        };
        section(&format!("--dram-bw sweep @ sps_cores={sps_cores} depth={depth}"));
        println!(
            "{:<10}{:>14}{:>14}{:>12}{:>12}",
            "bw B/cyc", "wall cyc", "stall cyc", "stall %", "bus util %"
        );
        let mut last_wall = None;
        for &bw in bws {
            let dma = dma_plan.clone().with_bandwidth(bw);
            let e = PipelineExecution::with_memory(
                p.io_input_cycles,
                p.io_output_cycles,
                p.sps_per_timestep.clone(),
                p.sdeb_segments.clone(),
                &topo,
                Some(&dma),
            );
            let m = e.memory.as_ref().expect("memory lane active");
            let row = Row {
                sps_cores,
                dram_bw: bw,
                wall_cycles: e.executed_cycles,
                stall_cycles: e.stall_cycles,
                stall_fraction: e.stall_fraction(),
                bus_utilization: m.bus_utilization(e.executed_cycles),
                streamed_bytes_full: m.weight_bytes() + spike_full,
                streamed_bytes_delta: m.weight_bytes() + spike_delta,
            };
            println!(
                "{:<10}{:>14}{:>14}{:>11.2}%{:>11.2}%",
                bw_label(bw),
                row.wall_cycles,
                row.stall_cycles,
                100.0 * row.stall_fraction,
                100.0 * row.bus_utilization
            );
            // Wall cycles must be monotone non-increasing in bandwidth.
            if let Some(prev) = last_wall {
                assert!(
                    row.wall_cycles <= prev,
                    "bw {bw}: wall {} > previous {prev}",
                    row.wall_cycles
                );
            }
            last_wall = Some(row.wall_cycles);
            rows.push(row);
        }
        // The unlimited end of every sweep is stall-free by construction.
        assert_eq!(rows.last().unwrap().stall_cycles, 0);
    }

    // Roofline shape: bandwidth-bound at the low end of the default
    // sweep, and — the acceptance point — the paper's own 16 B/cycle
    // interface already stalls the 4-SPS-core topology.
    let knee_point = rows
        .iter()
        .find(|r| r.sps_cores == 4 && r.dram_bw == 16)
        .expect("swept point present");
    assert!(
        knee_point.stall_cycles > 0,
        "paper bandwidth must be past the knee at 4 SPS cores"
    );
    if !quick {
        let slow = rows.iter().find(|r| r.sps_cores == 1 && r.dram_bw == 1).unwrap();
        let fast = rows.iter().find(|r| r.sps_cores == 1 && r.dram_bw == usize::MAX).unwrap();
        assert!(
            slow.wall_cycles > fast.wall_cycles && slow.stall_cycles > 0,
            "the sweep must cross from bandwidth-bound to compute-bound"
        );
    }

    // Cross-check the re-timed schedule against one real executed run at
    // the most bandwidth-hungry topology point.
    section("cross-check: executed inference at sps_cores=4, --dram-bw 16");
    let topo4 = CoreTopology { sps_cores: 4, pipeline_depth: 6, ..CoreTopology::paper() };
    let mut accel4 = Accelerator::new(model, hw.with_topology(topo4));
    let r4 = accel4.infer(&image)?;
    let p4 = r4.pipeline.as_ref().unwrap();
    let retimed = rows
        .iter()
        .find(|r| r.sps_cores == 4 && r.dram_bw == 16)
        .unwrap();
    assert_eq!(r.logits, r4.logits, "topology must not change values");
    assert_eq!(
        p4.executed_cycles, retimed.wall_cycles,
        "re-timed schedule must match the executed one"
    );
    println!(
        "executed wall={} stall={} — matches the re-timed sweep point",
        p4.executed_cycles, p4.stall_cycles
    );

    if args.iter().any(|a| a == "--json") {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_memory.json");
        let mut entry = String::from("{\n");
        entry.push_str(
            "    \"config\": {\"model\": \"paper\", \"accel\": \"paper fabric, sdeb_cores=2\", \"image_seed\": 2, \"weight_set_mb_per_block\": 3.546},\n",
        );
        entry.push_str(
            "    \"units\": \"wall_cycles = executed schedule finish time with the memory lane; stall_cycles = cycles compute waited on weight streaming; dram_bw in bytes/cycle (-1 = unlimited); stall_fraction = stall/wall; bus_utilization = bus busy/wall; logits invariant across all rows\",\n",
        );
        entry.push_str("    \"results\": [\n");
        for (i, row) in rows.iter().enumerate() {
            let bw = if row.dram_bw == usize::MAX { -1i64 } else { row.dram_bw as i64 };
            entry.push_str(&format!(
                "      {{\"sps_cores\": {}, \"dram_bw\": {}, \"wall_cycles\": {}, \"stall_cycles\": {}, \"stall_fraction\": {:.4}, \"bus_utilization\": {:.4}, \"streamed_bytes_full\": {}, \"streamed_bytes_delta\": {}}}{}\n",
                row.sps_cores,
                bw,
                row.wall_cycles,
                row.stall_cycles,
                row.stall_fraction,
                row.bus_utilization,
                row.streamed_bytes_full,
                row.streamed_bytes_delta,
                if i + 1 == rows.len() { "" } else { "," }
            ));
        }
        entry.push_str("    ]\n  }");
        match merge_bench_json(path, "memory_roofline", &entry) {
            Ok(()) => println!("\nwrote {path} (section \"memory_roofline\")"),
            Err(e) => eprintln!("\nfailed to write {path}: {e}"),
        }

        // Temporal-reuse headline: streamed bytes per inference at the
        // paper point, delta-on vs the full-re-store baseline.
        let baseline = m_off.streamed_bytes();
        let with_delta = m_on.streamed_bytes();
        let temporal = format!(
            "{{\n    \"config\": {{\"model\": \"paper\", \"accel\": \"paper fabric, sdeb_cores=2, dram_bw=16\", \"image_seed\": 2}},\n    \"units\": \"bytes per inference over the external bus + ESS input stores; baseline = every SDEB input re-stored in full (PR 5 behaviour), delta = --temporal-delta per-channel XOR deltas; logits bit-identical between the two runs\",\n    \"results\": [\n      {{\"streamed_bytes_baseline\": {}, \"streamed_bytes_delta\": {}, \"reduction\": {:.4}, \"resident_blocks\": {}, \"thrash_blocks\": {}, \"streaming_blocks\": {}, \"resident_bytes\": {}}}\n    ]\n  }}",
            baseline,
            with_delta,
            1.0 - with_delta as f64 / baseline.max(1) as f64,
            m_on.resident_blocks,
            m_on.thrash_blocks,
            m_on.streaming_blocks,
            m_on.resident_bytes,
        );
        match merge_bench_json(path, "temporal", &temporal) {
            Ok(()) => println!("wrote {path} (section \"temporal\")"),
            Err(e) => eprintln!("failed to write {path}: {e}"),
        }
    }

    Ok(())
}
