//! Bench D1 — autoregressive decode over the spike-stream KV cache:
//! TTFT (prefill cycles), inter-token latency and tokens/s across
//! hardware shape x spike engine.
//!
//! All latency numbers are *modelled accelerator cycles* converted to
//! seconds at the shape's clock, so every cell replays bit-identically —
//! which is what lets `--quick` assert the decode path's headline
//! properties instead of eyeballing them: the generated tokens are
//! identical across every engine (the engines are bit-identical by
//! construction), and the inter-token latency grows with the causal
//! prefix (each step masks the new Q row against a longer cached K
//! stream).
//!
//! ```bash
//! cargo bench --bench decode_bench                   # full sweep
//! cargo bench --bench decode_bench -- --quick        # CI smoke: small sweep + assertions
//! cargo bench --bench decode_bench -- --json         # merge into BENCH_decode.json
//! cargo bench --bench decode_bench -- --prompt-len N --gen-len N
//! ```

use std::time::Instant;

use spikeformer_accel::accel::{Accelerator, DatapathMode, DecodeReport, ExecMode};
use spikeformer_accel::benchlib::{arg_value, merge_bench_json, section};
use spikeformer_accel::hw::{AccelConfig, EngineSelect};
use spikeformer_accel::model::{QuantizedModel, SdtModelConfig};
use spikeformer_accel::util::Prng;

const SEED: u64 = 0xdec0;

/// One swept cell's outcome row.
struct Row {
    shape: &'static str,
    engine: &'static str,
    prompt_len: usize,
    gen_len: usize,
    ttft_cycles: u64,
    itl_mean_cycles: f64,
    itl_p99_cycles: u64,
    tokens_per_s: f64,
    cache_words: u64,
    host_s: f64,
}

fn percentile(sorted: &[u64], p: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let idx = ((sorted.len() - 1) as f64 * p).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

fn run_cell(
    shape: &'static str,
    engine: &'static str,
    model: &QuantizedModel,
    hw: AccelConfig,
    prompt: &[usize],
    gen_len: usize,
) -> (Row, DecodeReport) {
    let mut accel = Accelerator::with_runtime(
        model.clone(),
        hw,
        DatapathMode::Encoded,
        ExecMode::Overlapped,
        0,
    );
    let t0 = Instant::now();
    let r = accel.decode(prompt, gen_len).expect("decode failed");
    let host_s = t0.elapsed().as_secs_f64();
    let gen_cycles: u64 = r.token_cycles.iter().sum();
    let mut sorted = r.token_cycles.clone();
    sorted.sort_unstable();
    let row = Row {
        shape,
        engine,
        prompt_len: r.prompt_len,
        gen_len: r.gen_len,
        ttft_cycles: r.prefill_cycles,
        itl_mean_cycles: gen_cycles as f64 / r.token_cycles.len().max(1) as f64,
        itl_p99_cycles: percentile(&sorted, 0.99),
        tokens_per_s: r.gen_len as f64 / hw.seconds(gen_cycles.max(1)),
        cache_words: r.cache_words,
        host_s,
    };
    (row, r)
}

fn print_row(r: &Row) {
    println!(
        "{:<12} {:<9} prompt={:<3} gen={:<3} ttft={:>9} cyc  itl mean={:>9.0} p99={:>9} cyc  {:>10.1} tok/s  kv={:>6} words  host {:.3} s",
        r.shape,
        r.engine,
        r.prompt_len,
        r.gen_len,
        r.ttft_cycles,
        r.itl_mean_cycles,
        r.itl_p99_cycles,
        r.tokens_per_s,
        r.cache_words,
        r.host_s,
    );
}

fn write_json(model_name: &str, rows: &[Row]) {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_decode.json");
    let mut entry = String::from("{\n");
    entry.push_str(&format!("    \"config\": {{\"model\": \"{model_name}\"}},\n"));
    entry.push_str(
        "    \"units\": \"modelled accelerator cycles at the shape clock; ttft_cycles = prefill (time to first token); itl_* = per-generated-token cycles (inter-token latency, grows with the causal prefix); tokens_per_s = generated tokens over modelled generation seconds; cache_words = final KV-cache CSR storage words; host_s = host wall seconds for the whole session (not a hardware number)\",\n",
    );
    entry.push_str("    \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        entry.push_str(&format!(
            "      {{\"shape\": \"{}\", \"engine\": \"{}\", \"prompt_len\": {}, \"gen_len\": {}, \"ttft_cycles\": {}, \"itl_mean_cycles\": {:.1}, \"itl_p99_cycles\": {}, \"tokens_per_s\": {:.1}, \"cache_words\": {}, \"host_s\": {:.6e}}}{}\n",
            r.shape,
            r.engine,
            r.prompt_len,
            r.gen_len,
            r.ttft_cycles,
            r.itl_mean_cycles,
            r.itl_p99_cycles,
            r.tokens_per_s,
            r.cache_words,
            r.host_s,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    entry.push_str("    ]\n  }");
    match merge_bench_json(path, "decode", &entry) {
        Ok(()) => println!("\nwrote {path} (section \"decode\")"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick");
    let json = args.iter().any(|a| a == "--json");

    // Multi-block, multi-head decoder so the swept engines see real head
    // bucketing; `tiny_decoder` scale keeps the quick lane CI-friendly.
    let cfg = if quick {
        SdtModelConfig::tiny_decoder()
    } else {
        SdtModelConfig {
            name: "decode-bench".into(),
            num_blocks: 2,
            num_heads: 8,
            ..SdtModelConfig::tiny_decoder()
        }
    };
    let model = QuantizedModel::random(&cfg, 42);
    let max_seq = cfg.decoder_shape().expect("decoder config").max_seq_len;
    let prompt_len = arg_value(&args, "--prompt-len").unwrap_or(if quick { 4 } else { 16 });
    let gen_len = arg_value(&args, "--gen-len").unwrap_or(if quick { 6 } else { 32 });
    assert!(
        prompt_len >= 1 && gen_len >= 1 && prompt_len + gen_len <= max_seq,
        "need prompt >= 1, gen >= 1, prompt+gen <= max_seq_len {max_seq}"
    );
    let vocab = cfg.vocab() as u64;
    let mut rng = Prng::new(SEED);
    let prompt: Vec<usize> = (0..prompt_len).map(|_| (rng.next_u64() % vocab) as usize).collect();

    let paper = AccelConfig::paper();
    let half = AccelConfig::with_lanes(paper.lanes / 2);
    let shapes: &[(&'static str, AccelConfig)] = &[("paper", paper), ("half-lanes", half)];
    let engines: &[(&'static str, EngineSelect)] = &[
        ("csr", EngineSelect::Csr),
        ("bitmap", EngineSelect::Bitmap),
        ("adaptive", EngineSelect::adaptive()),
    ];

    section("decode sweep: shape x engine (modelled cycles)");
    let mut rows = Vec::new();
    let mut per_engine_tokens: Vec<Vec<usize>> = Vec::new();
    let mut paper_csr: Option<DecodeReport> = None;
    for &(shape, hw) in shapes {
        for &(engine, eng) in engines {
            let mut hw = hw;
            hw.engine = eng;
            hw.validate().expect("swept shape must validate");
            let (row, report) = run_cell(shape, engine, &model, hw, &prompt, gen_len);
            print_row(&row);
            rows.push(row);
            if shape == "paper" {
                per_engine_tokens.push(report.generated.clone());
                if engine == "csr" {
                    paper_csr = Some(report);
                }
            }
        }
    }

    // Headline checks on the deterministic model (always on: they are
    // cheap relative to the sweep itself).
    for toks in &per_engine_tokens[1..] {
        assert_eq!(
            toks, &per_engine_tokens[0],
            "engines must generate identical tokens (bit-identical datapaths)"
        );
    }
    let r = paper_csr.expect("paper/csr cell ran");
    let (first, last) = (r.token_cycles[0], *r.token_cycles.last().unwrap());
    assert!(
        last >= first,
        "inter-token latency must not shrink as the causal prefix grows ({last} < {first})"
    );
    println!(
        "\nchecks: engines agree on {} generated tokens; itl grows {} -> {} cycles",
        r.gen_len, first, last
    );

    if json {
        write_json(&cfg.name, &rows);
    }
}
