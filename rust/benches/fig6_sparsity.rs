//! Bench F6 — regenerates Fig. 6: "The average sparsity of SDSA and
//! subsequent linear layers", measured on the trained model's real
//! activations over held-out images (falls back to the random paper-scale
//! model when artifacts are absent).
//!
//! ```bash
//! cargo bench --bench fig6_sparsity
//! ```

use std::path::Path;

use spikeformer_accel::accel::Accelerator;
use spikeformer_accel::hw::AccelConfig;
use spikeformer_accel::model::{load_model, loader::load_test_split, QuantizedModel, SdtModelConfig};
use spikeformer_accel::util::Prng;

fn main() -> anyhow::Result<()> {
    let dir = Path::new("artifacts/weights");
    let (model, images): (QuantizedModel, Vec<Vec<f32>>) = if dir.join("manifest.txt").exists() {
        let model = load_model(dir)?;
        let (flat, shape, _) = load_test_split(dir)?;
        let img_len = shape[1] * shape[2] * shape[3];
        let n = shape[0].min(64);
        let imgs = (0..n).map(|i| flat[i * img_len..(i + 1) * img_len].to_vec()).collect();
        println!("trained tiny model, {n} held-out images");
        (model, imgs)
    } else {
        println!("no artifacts; random paper-scale model, 16 synthetic images");
        let mut rng = Prng::new(5);
        let imgs = (0..16)
            .map(|_| (0..3 * 32 * 32).map(|_| rng.next_f32_signed()).collect())
            .collect();
        (QuantizedModel::random(&SdtModelConfig::paper(), 42), imgs)
    };

    let mut accel = Accelerator::new(model, AccelConfig::paper());
    let mut table: Vec<(String, f64, usize)> = Vec::new();
    for img in &images {
        let r = accel.infer(img)?;
        for (name, s) in r.sparsity {
            if let Some(e) = table.iter_mut().find(|e| e.0 == name) {
                e.1 += s;
                e.2 += 1;
            } else {
                table.push((name, s, 1));
            }
        }
    }

    println!("\nFIG. 6 — AVERAGE SPARSITY OF SDSA AND SUBSEQUENT LINEAR LAYERS\n");
    println!("{:<28}{:>12}   (bar)", "module", "sparsity");
    for (name, total, n) in &table {
        let s = total / *n as f64;
        let bar = "#".repeat((s * 40.0).round() as usize);
        println!("{name:<28}{:>11.1}%   {bar}", s * 100.0);
    }
    println!("\n(the paper reports SDSA-output sparsity > 90% on CIFAR-10 — the mask");
    println!(" clears whole V channels, which this reproduction shows as block*.sdsa)");
    Ok(())
}
