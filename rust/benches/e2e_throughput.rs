//! Bench E1 — end-to-end serving: the L3 coordinator under load with
//! golden and simulator workers, across worker counts and batch policies.
//! Reports host throughput/latency plus the modelled accelerator cycles —
//! which, for the default simulator workers, are the **executed** two-core
//! overlapped pipeline's wall cycles (pass `--serial` for the serial
//! charging ablation).
//!
//! ```bash
//! cargo bench --bench e2e_throughput                 # full sweep
//! cargo bench --bench e2e_throughput -- --quick      # CI smoke mode
//! cargo bench --bench e2e_throughput -- --serial     # serial-charging ablation
//! cargo bench --bench e2e_throughput -- --workers N  # size each simulator's SDEB worker pool
//! cargo bench --bench e2e_throughput -- --sdeb-cores N --pipeline-depth N --mapping POLICY
//! cargo bench --bench e2e_throughput -- --dram-bw N    # external-memory bus bytes/cycle (`max` = unlimited)
//! ```

use std::time::{Duration, Instant};

use spikeformer_accel::accel::{DatapathMode, ExecMode};
use spikeformer_accel::benchlib::{apply_topology_args, arg_value, section};
use spikeformer_accel::coordinator::{
    BackendFactory, BatchPolicy, Coordinator, GoldenBackend, Request, SimulatorBackend,
};
use spikeformer_accel::hw::AccelConfig;
use spikeformer_accel::model::{QuantizedModel, SdtModelConfig};
use spikeformer_accel::util::Prng;

fn images(n: usize) -> Vec<Vec<f32>> {
    let mut rng = Prng::new(9);
    (0..n).map(|_| (0..3 * 32 * 32).map(|_| rng.next_f32_signed()).collect()).collect()
}

fn drive(
    factories: Vec<BackendFactory>,
    policy: BatchPolicy,
    imgs: &[Vec<f32>],
) -> anyhow::Result<spikeformer_accel::coordinator::ServeReport> {
    let started = Instant::now();
    let mut co = Coordinator::new(factories, policy);
    for (i, img) in imgs.iter().enumerate() {
        co.submit(Request::new(i as u64, img.clone()));
    }
    let (_, report) = co.finish(started)?;
    Ok(report)
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick");
    let serial = args.iter().any(|a| a == "--serial");
    // Sizes each simulator backend's persistent SDEB worker pool
    // (0 keeps the model-derived default).
    let pool_workers = arg_value(&args, "--workers").unwrap_or(0);
    let exec = if serial { ExecMode::Serial } else { ExecMode::Overlapped };

    // Tiny-scale fabric but a multi-head, multi-block model, so the
    // `--sdeb-cores`/`--mapping` topology path actually exercises head
    // mapping (tiny's single head would clamp every topology to 1 core).
    let cfg = SdtModelConfig {
        name: "e2e".into(),
        num_blocks: 2,
        num_heads: 8,
        ..SdtModelConfig::tiny()
    };
    let model = QuantizedModel::random(&cfg, 42);
    let imgs = images(if quick { 24 } else { 96 });
    let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) };

    section("golden workers (host-throughput scaling)");
    let worker_counts: &[usize] = if quick { &[1, 2] } else { &[1, 2, 4, 8] };
    for &workers in worker_counts {
        let report = drive(GoldenBackend::factories(workers, &model), policy, &imgs)?;
        println!("workers={workers}  {}", report.summary());
    }

    section("simulator workers (modelled accelerator throughput, overlapped pipeline)");
    // Topology knobs: SDEB-core count, ring depth, head->core policy.
    let mut hw = AccelConfig::paper();
    let mapping = apply_topology_args(&args, &mut hw);
    println!(
        "topology: sdeb_cores={} depth={} mapping={}",
        hw.topology.sdeb_cores,
        hw.topology.pipeline_depth,
        mapping.name()
    );
    let sim_counts: &[usize] = if quick { &[1] } else { &[1, 2, 4] };
    for &workers in sim_counts {
        let report = drive(
            SimulatorBackend::factories_with_mapping(workers, &model, hw, DatapathMode::Encoded, exec, pool_workers, mapping),
            policy,
            &imgs,
        )?;
        let modelled_s = report.modelled_cycles as f64 / (hw.freq_mhz * 1e6);
        println!(
            "workers={workers} exec={exec:?}  {}  modelled={:.3}ms total ({:.3}ms/img @200MHz)",
            report.summary(),
            modelled_s * 1e3,
            modelled_s * 1e3 / imgs.len() as f64
        );
    }

    section("overlapped vs serial charging (single simulator worker)");
    let sample = &imgs[..imgs.len().min(8)];
    let over = drive(
        SimulatorBackend::factories_with_mapping(1, &model, hw, DatapathMode::Encoded, ExecMode::Overlapped, pool_workers, mapping),
        policy,
        sample,
    )?;
    let ser = drive(
        SimulatorBackend::factories_with_mapping(1, &model, hw, DatapathMode::Encoded, ExecMode::Serial, pool_workers, mapping),
        policy,
        sample,
    )?;
    println!(
        "overlapped: {} modelled cycles   serial: {} modelled cycles   speedup: {:.2}x",
        over.modelled_cycles,
        ser.modelled_cycles,
        ser.modelled_cycles as f64 / over.modelled_cycles.max(1) as f64
    );
    assert!(
        over.modelled_cycles < ser.modelled_cycles,
        "overlapped executor must beat serial charging"
    );

    if quick {
        println!("\n--quick: skipping batch-policy sensitivity section");
        return Ok(());
    }

    section("batch-policy sensitivity (2 golden workers)");
    for (batch, wait_ms) in [(1usize, 0u64), (4, 1), (8, 1), (16, 2), (32, 4)] {
        let report = drive(
            GoldenBackend::factories(2, &model),
            BatchPolicy { max_batch: batch, max_wait: Duration::from_millis(wait_ms) },
            &imgs,
        )?;
        println!("max_batch={batch:<3} max_wait={wait_ms}ms  {}", report.summary());
    }
    Ok(())
}
