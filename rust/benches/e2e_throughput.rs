//! Bench E1 — end-to-end serving: the L3 coordinator under load with
//! golden and simulator workers, across worker counts and batch policies.
//! Reports host throughput/latency plus the modelled accelerator cycles.
//!
//! ```bash
//! cargo bench --bench e2e_throughput
//! ```

use std::time::{Duration, Instant};

use spikeformer_accel::benchlib::section;
use spikeformer_accel::coordinator::{
    BackendFactory, BatchPolicy, Coordinator, GoldenBackend, InferBackend, Request, SimulatorBackend,
};
use spikeformer_accel::hw::AccelConfig;
use spikeformer_accel::model::{QuantizedModel, SdtModelConfig};
use spikeformer_accel::util::Prng;

fn images(n: usize) -> Vec<Vec<f32>> {
    let mut rng = Prng::new(9);
    (0..n).map(|_| (0..3 * 32 * 32).map(|_| rng.next_f32_signed()).collect()).collect()
}

fn main() -> anyhow::Result<()> {
    let cfg = SdtModelConfig::tiny();
    let model = QuantizedModel::random(&cfg, 42);
    let imgs = images(96);

    section("golden workers (host-throughput scaling)");
    for workers in [1usize, 2, 4, 8] {
        let factories: Vec<BackendFactory> = (0..workers)
            .map(|_| {
                let m = model.clone();
                Box::new(move || -> anyhow::Result<Box<dyn InferBackend>> { Ok(Box::new(GoldenBackend::new(m))) }) as BackendFactory
            })
            .collect();
        let started = Instant::now();
        let mut co = Coordinator::new(
            factories,
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
        );
        for (i, img) in imgs.iter().enumerate() {
            co.submit(Request { id: i as u64, image: img.clone() });
        }
        let (_, report) = co.finish(started)?;
        println!("workers={workers}  {}", report.summary());
    }

    section("simulator workers (modelled accelerator throughput)");
    for workers in [1usize, 2, 4] {
        let factories: Vec<BackendFactory> = (0..workers)
            .map(|_| {
                let m = model.clone();
                Box::new(move || -> anyhow::Result<Box<dyn InferBackend>> {
                    Ok(Box::new(SimulatorBackend::new(m, AccelConfig::paper())))
                }) as BackendFactory
            })
            .collect();
        let started = Instant::now();
        let mut co = Coordinator::new(
            factories,
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
        );
        for (i, img) in imgs.iter().enumerate() {
            co.submit(Request { id: i as u64, image: img.clone() });
        }
        let (_, report) = co.finish(started)?;
        let hw = AccelConfig::paper();
        let modelled_s = report.modelled_cycles as f64 / (hw.freq_mhz * 1e6);
        println!(
            "workers={workers}  {}  modelled={:.3}ms total ({:.3}ms/img @200MHz)",
            report.summary(),
            modelled_s * 1e3,
            modelled_s * 1e3 / imgs.len() as f64
        );
    }

    section("batch-policy sensitivity (2 golden workers)");
    for (batch, wait_ms) in [(1usize, 0u64), (4, 1), (8, 1), (16, 2), (32, 4)] {
        let factories: Vec<BackendFactory> = (0..2)
            .map(|_| {
                let m = model.clone();
                Box::new(move || -> anyhow::Result<Box<dyn InferBackend>> { Ok(Box::new(GoldenBackend::new(m))) }) as BackendFactory
            })
            .collect();
        let started = Instant::now();
        let mut co = Coordinator::new(
            factories,
            BatchPolicy { max_batch: batch, max_wait: Duration::from_millis(wait_ms) },
        );
        for (i, img) in imgs.iter().enumerate() {
            co.submit(Request { id: i as u64, image: img.clone() });
        }
        let (_, report) = co.finish(started)?;
        println!("max_batch={batch:<3} max_wait={wait_ms}ms  {}", report.summary());
    }
    Ok(())
}
