//! Bench A1 — design-choice ablations at the whole-network level:
//!
//!  1. encoded-spike datapath vs conventional bitmap datapath (the paper's
//!     core redundancy-elimination claim) at the paper scale;
//!  2. encoded-spike *storage* cost vs bitmap storage across sparsity
//!     (the paper's "additional memory resource" discussion);
//!  3. SDSA threshold sensitivity (mask density vs attn_v_th);
//!  4. executed two-core overlap vs serial charging (A1.4);
//!  5. steady-state host runtime: pooled scratch/worker-pool accelerator
//!     vs fresh allocation per request, at batch 1/4/8 (A1.5);
//!  6. core-topology and mapping-policy sweep at fixed fabric (A1.6):
//!     SDEB-core count x SDSA head->core policy, wall cycles and SMAM
//!     phase cycles, logits asserted invariant (`--json` merges the table
//!     into `BENCH_topology.json`).
//!
//! ```bash
//! cargo bench --bench ablations
//! cargo bench --bench ablations -- --json   # write BENCH_topology.json
//! ```

use spikeformer_accel::accel::{Accelerator, DatapathMode, ExecMode, MappingPolicy};
use spikeformer_accel::benchlib::merge_bench_json;
use spikeformer_accel::hw::{AccelConfig, CoreTopology};
use spikeformer_accel::model::{QuantizedModel, SdtModelConfig};
use spikeformer_accel::quant::ADDR_BITS;
use spikeformer_accel::spike::{EncodedSpikes, SpikeMatrix};
use spikeformer_accel::units::SpikeMaskAddModule;
use spikeformer_accel::util::Prng;

fn random_encoded(rng: &mut Prng, c: usize, l: usize, p: f64) -> EncodedSpikes {
    let mut m = SpikeMatrix::zeros(c, l);
    for ci in 0..c {
        for li in 0..l {
            if rng.bernoulli(p) {
                m.set(ci, li, true);
            }
        }
    }
    EncodedSpikes::from_bitmap(&m)
}

fn main() -> anyhow::Result<()> {
    let mut rng = Prng::new(2);
    let image: Vec<f32> = (0..3 * 32 * 32).map(|_| rng.next_f32_signed()).collect();

    println!("A1.1 — whole-network: encoded vs bitmap datapath (paper scale, D=384 T=4)\n");
    let cfg = SdtModelConfig::paper();
    let model = QuantizedModel::random(&cfg, 42);
    let hw = AccelConfig::paper();
    // Both sides charge serially so the ratios isolate the encoding claim;
    // the overlap/sharding win is measured separately in A1.4.
    let mut enc =
        Accelerator::with_modes(model.clone(), hw, DatapathMode::Encoded, ExecMode::Serial);
    let mut bmp =
        Accelerator::with_modes(model.clone(), hw, DatapathMode::Bitmap, ExecMode::Serial);
    let r_enc = enc.infer(&image)?;
    let r_bmp = bmp.infer(&image)?;
    assert_eq!(r_enc.logits, r_bmp.logits, "modes must agree numerically");
    println!("{:<22}{:>14}{:>14}{:>10}", "phase", "encoded cyc", "bitmap cyc", "saving");
    for (name, s1) in &r_enc.phases.phases {
        let s2 = r_bmp.phases.get(name);
        if s2.cycles > 0 {
            println!(
                "{:<22}{:>14}{:>14}{:>9.2}x",
                name,
                s1.cycles,
                s2.cycles,
                s2.cycles as f64 / s1.cycles.max(1) as f64
            );
        }
    }
    println!(
        "{:<22}{:>14}{:>14}{:>9.2}x   <- end-to-end",
        "TOTAL",
        r_enc.total.cycles,
        r_bmp.total.cycles,
        r_bmp.total.cycles as f64 / r_enc.total.cycles as f64
    );
    // The dense conv front-end (Tile Engine) is identical in both modes and
    // dominates end-to-end cycles; the paper's contribution lives in the
    // spike-consuming phases. Report the subtotal the encoding targets.
    let spike_phases = ["sps.maxpool", "sdeb.qkv", "sdeb.smam", "sdeb.proj", "sdeb.mlp"];
    let sub = |r: &spikeformer_accel::accel::RunReport| -> u64 {
        spike_phases.iter().map(|p| r.phases.get(p).cycles).sum()
    };
    let (se, sb) = (sub(&r_enc), sub(&r_bmp));
    println!(
        "{:<22}{:>14}{:>14}{:>9.2}x   <- spike-consuming phases only",
        "SPIKE PHASES",
        se,
        sb,
        sb as f64 / se as f64
    );
    // Extension (refs [7]-[10]): an event-driven conv engine would also
    // skip zero spike inputs in the SPS stages. Estimate its effect from
    // the recorded conv SOPs (spike x fan-out) vs dense MAC cycles.
    let conv = r_enc.phases.get("sps.conv");
    let event_conv_cycles = conv.sops / AccelConfig::paper().tile_macs as u64;
    println!(
        "\nextension estimate — event-driven conv front-end (not in the paper):\n  dense Tile Engine: {} cycles;  event-driven: ~{} cycles ({:.2}x)",
        conv.cycles,
        event_conv_cycles,
        conv.cycles as f64 / event_conv_cycles.max(1) as f64
    );

    println!("\nA1.2 — storage: encoded words (8-bit) vs bitmap bits (384ch x 64 tok)\n");
    println!("{:<12}{:>16}{:>16}{:>12}", "sparsity", "encoded bits", "bitmap bits", "ratio");
    for &p in &[0.02, 0.05, 0.1, 0.125, 0.2, 0.3, 0.5] {
        let e = random_encoded(&mut rng, 384, 64, p);
        let enc_bits = e.storage_words() as u64 * ADDR_BITS as u64;
        let bmp_bits = (384 * 64) as u64;
        println!(
            "{:<12.3}{:>16}{:>16}{:>12.2}",
            1.0 - p,
            enc_bits,
            bmp_bits,
            enc_bits as f64 / bmp_bits as f64
        );
    }
    println!("(crossover near 1/8 spike rate: encoding wins only in the sparse regime,");
    println!(" which is why the paper pairs it with spiking networks)");

    println!("\nA1.3 — SDSA mask density vs firing threshold (384ch, 64 tok, 20% spikes)\n");
    println!("{:<10}{:>14}{:>18}", "v_th", "mask fired", "V spikes kept");
    let q = random_encoded(&mut rng, 384, 64, 0.2);
    let k = random_encoded(&mut rng, 384, 64, 0.2);
    let v = random_encoded(&mut rng, 384, 64, 0.2);
    for v_th in [0u32, 1, 2, 3, 4, 6, 8] {
        let (out, _) = SpikeMaskAddModule::new(v_th).run(&q, &k, &v, &AccelConfig::paper());
        let fired = out.mask.iter().filter(|&&m| m).count();
        println!(
            "{:<10}{:>11}/384{:>13}/{}",
            v_th,
            fired,
            out.masked_v.count_spikes(),
            v.count_spikes()
        );
    }

    println!("\nA1.4 — executed two-core overlap vs serial charging (paper scale)\n");
    // r_enc above is the serial-charging run; execute the overlap fresh.
    let mut over = Accelerator::new(model.clone(), hw);
    let r_over = over.infer(&image)?;
    let exec = r_over.pipeline.as_ref().expect("overlapped run carries its schedule");
    assert_eq!(r_over.logits, r_enc.logits, "exec strategy must not change values");
    println!("serial charging      : {:>12} cycles", r_enc.total.cycles);
    println!(
        "overlapped (executed): {:>12} cycles  ({:.2}x, bottleneck {}, fill {})",
        exec.executed_cycles,
        r_enc.total.cycles as f64 / exec.executed_cycles as f64,
        exec.bottleneck(),
        exec.fill_cycles()
    );
    let est = spikeformer_accel::accel::pipeline_estimate(&r_over.phases, cfg.timesteps);
    println!(
        "analytic cross-check : {:>12} cycles  (reconciles: {})",
        est.pipelined_cycles,
        exec.reconciles_with(&est)
    );

    println!("\nA1.5 — steady-state host runtime: pooled vs fresh allocation (paper scale)\n");
    // Host-throughput ablation: identical modelled work, different host
    // memory/thread behaviour. "fresh" constructs a new accelerator per
    // batch (cold scratch pools, new worker-pool threads, cloned model);
    // "pooled" reuses one warmed accelerator and its batched forward.
    let n_req = 8usize;
    let imgs: Vec<Vec<f32>> = (0..n_req)
        .map(|_| (0..3 * 32 * 32).map(|_| rng.next_f32_signed()).collect())
        .collect();
    println!(
        "{:<8}{:>16}{:>16}{:>10}",
        "batch", "fresh req/s", "pooled req/s", "speedup"
    );
    for &batch in &[1usize, 4, 8] {
        let t0 = std::time::Instant::now();
        let mut fresh_logits = Vec::new();
        for chunk in imgs.chunks(batch) {
            let mut accel = Accelerator::new(model.clone(), hw);
            for r in accel.infer_batch(chunk)? {
                fresh_logits.push(r.logits);
            }
        }
        let fresh_s = t0.elapsed().as_secs_f64();

        let mut accel = Accelerator::new(model.clone(), hw);
        accel.infer_batch(&imgs[..batch])?; // warm the scratch pools
        let t0 = std::time::Instant::now();
        let mut pooled_logits = Vec::new();
        for chunk in imgs.chunks(batch) {
            for r in accel.infer_batch(chunk)? {
                pooled_logits.push(r.logits);
            }
        }
        let pooled_s = t0.elapsed().as_secs_f64();
        assert_eq!(fresh_logits, pooled_logits, "steady-state runtime must be bit-exact");

        println!(
            "{:<8}{:>16.2}{:>16.2}{:>9.2}x",
            batch,
            n_req as f64 / fresh_s,
            n_req as f64 / pooled_s,
            fresh_s / pooled_s.max(1e-12)
        );
    }
    let stats = {
        let mut accel = Accelerator::new(model.clone(), hw);
        accel.infer(&image)?;
        let warm = accel.scratch_stats();
        accel.infer(&image)?;
        let after = accel.scratch_stats();
        (warm, after)
    };
    println!(
        "scratch pool: warm-up misses={}, steady-state misses={} (+{} hits/request)",
        stats.0.misses,
        stats.1.misses,
        stats.1.hits - stats.0.hits
    );

    println!("\nA1.6 — core topology x mapping policy at fixed fabric (paper scale)\n");
    // Same compute fabric (paper lanes/comparators) throughout; only the
    // SDEB-core count and the SDSA head->core policy vary. Values must be
    // bit-identical everywhere — the topology is a schedule, not a
    // numeric — and modelled wall cycles must not increase with core
    // count under the default policy (each added core is a full
    // replicated comparator array).
    let baseline_logits = r_over.logits.clone();
    struct TopoRow {
        cores: usize,
        policy: &'static str,
        wall_cycles: u64,
        smam_cycles: u64,
        speedup: f64,
    }
    let mut rows: Vec<TopoRow> = Vec::new();
    println!(
        "{:<8}{:<16}{:>14}{:>14}{:>10}",
        "cores", "mapping", "wall cyc", "smam cyc", "speedup"
    );
    for &cores in &[1usize, 2, 4, 8] {
        for policy in MappingPolicy::ALL {
            let hw_t = hw.with_topology(CoreTopology::with_sdeb_cores(cores));
            let mut accel = Accelerator::new(model.clone(), hw_t).with_mapping(policy);
            let r = accel.infer(&image)?;
            assert_eq!(r.logits, baseline_logits, "topology/policy must not change values");
            rows.push(TopoRow {
                cores,
                policy: policy.name(),
                wall_cycles: r.wall_cycles(),
                smam_cycles: r.phases.get("sdeb.smam").cycles,
                speedup: r_enc.total.cycles as f64 / r.wall_cycles() as f64,
            });
            let row = rows.last().unwrap();
            println!(
                "{:<8}{:<16}{:>14}{:>14}{:>9.2}x",
                row.cores, row.policy, row.wall_cycles, row.smam_cycles, row.speedup
            );
        }
    }
    // Monotonicity under the default policy: more replicated cores never
    // cost modelled cycles (the ISSUE 4 acceptance criterion).
    let rr: Vec<u64> = rows
        .iter()
        .filter(|r| r.policy == MappingPolicy::HeadRoundRobin.name())
        .map(|r| r.wall_cycles)
        .collect();
    assert!(
        rr.windows(2).all(|w| w[1] <= w[0]),
        "wall cycles must be monotonically non-increasing in core count: {rr:?}"
    );

    println!("\nA1.7 — weight-streaming stall fraction per topology point (paper scale)\n");
    // The memory lane re-times the recorded paper-point traces (which are
    // bandwidth- and SPS-core-independent) under different bus/topology
    // points: at the paper's 16 B/cycle the default schedule is
    // compute-bound, and scaling the SPS stage to 4 cores pushes it past
    // the roofline knee. `memory_roofline` sweeps the full axis.
    let p_over = r_over.pipeline.as_ref().expect("overlapped run carries its schedule");
    let dma_paper = spikeformer_accel::accel::DmaEngine::new(over.model(), &hw);
    println!(
        "{:<12}{:<12}{:>14}{:>14}{:>12}",
        "sps_cores", "dram_bw", "wall cyc", "stall cyc", "stall %"
    );
    let mut scaled_stall = None;
    for &(sps_cores, bw) in &[(1usize, 16usize), (1, 4), (4, 16), (4, usize::MAX)] {
        let topo = CoreTopology {
            sps_cores,
            pipeline_depth: 2 * sps_cores,
            ..CoreTopology::paper()
        };
        let e = spikeformer_accel::accel::PipelineExecution::with_memory(
            p_over.io_input_cycles,
            p_over.io_output_cycles,
            p_over.sps_per_timestep.clone(),
            p_over.sdeb_segments.clone(),
            &topo,
            Some(&dma_paper.clone().with_bandwidth(bw)),
        );
        println!(
            "{:<12}{:<12}{:>14}{:>14}{:>11.2}%",
            sps_cores,
            if bw == usize::MAX { "inf".to_string() } else { bw.to_string() },
            e.executed_cycles,
            e.stall_cycles,
            100.0 * e.stall_fraction()
        );
        if (sps_cores, bw) == (4, 16) {
            scaled_stall = Some(e.stall_cycles);
        }
        if bw == usize::MAX {
            assert_eq!(e.stall_cycles, 0, "an unlimited bus never stalls");
        }
    }
    assert!(
        scaled_stall.unwrap_or(0) > 0,
        "paper bandwidth must stall the 4-SPS-core point (the roofline knee)"
    );

    if std::env::args().any(|a| a == "--json") {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_topology.json");
        let mut entry = String::from("{\n");
        entry.push_str(
            "    \"config\": {\"model\": \"paper\", \"accel\": \"paper (fixed fabric)\", \"image_seed\": 2},\n",
        );
        entry.push_str(
            "    \"units\": \"wall_cycles = executed overlapped-schedule finish time; smam_cycles = SDSA phase busy cycles (max over cores); speedup = serial-charging cycles / wall_cycles; logits bit-identical across all rows\",\n",
        );
        entry.push_str("    \"results\": [\n");
        for (i, r) in rows.iter().enumerate() {
            entry.push_str(&format!(
                "      {{\"sdeb_cores\": {}, \"mapping\": \"{}\", \"wall_cycles\": {}, \"smam_cycles\": {}, \"speedup\": {:.3}}}{}\n",
                r.cores,
                r.policy,
                r.wall_cycles,
                r.smam_cycles,
                r.speedup,
                if i + 1 == rows.len() { "" } else { "," }
            ));
        }
        entry.push_str("    ]\n  }");
        match merge_bench_json(path, "topology", &entry) {
            Ok(()) => println!("\nwrote {path} (section \"topology\")"),
            Err(e) => eprintln!("\nfailed to write {path}: {e}"),
        }
    }

    Ok(())
}
