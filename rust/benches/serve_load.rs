//! Bench S2 — open-loop serving latency under load: continuous in-flight
//! batching vs release-a-batch-and-wait across arrival rates and fleet
//! shapes.
//!
//! Latency numbers come from the deterministic virtual-clock fleet model
//! (`coordinator::loadsim`), fed with *measured* per-request service
//! times: each request's service demand is an accelerator inference's
//! modelled wall cycles converted to seconds at the shape's clock, and
//! heterogeneous worker speeds are probed cycle ratios between shapes.
//! Arrivals come from the seeded open-loop generator
//! (`benchlib::ArrivalSpec`), so every cell of the sweep replays
//! bit-identically — no wall-clock flake, which is what lets `--quick`
//! *assert* that continuous batching beats closed batching on p99.
//!
//! A small real-`Coordinator` burst cross-check runs at the end (host
//! wall-clock, printed but never asserted) to tie the model back to the
//! actual serving stack.
//!
//! ```bash
//! cargo bench --bench serve_load                    # full sweep
//! cargo bench --bench serve_load -- --quick         # CI smoke: small sweep + p99 assertion
//! cargo bench --bench serve_load -- --json          # merge into BENCH_serving.json
//! cargo bench --bench serve_load -- --arrival burst:8:0.05   # override the arrival process
//! cargo bench --bench serve_load -- --requests N    # offered load per cell
//! ```

use std::time::{Duration, Instant};

use spikeformer_accel::accel::{Accelerator, DatapathMode, ExecMode, MappingPolicy};
use spikeformer_accel::benchlib::{
    arg_str, arg_value, arrival_offsets, merge_bench_json, section, ArrivalSpec,
};
use spikeformer_accel::coordinator::loadsim::{
    simulate, SimConfig, SimMode, SimOutcome, SimRequest,
};
use spikeformer_accel::coordinator::{
    BatchPolicy, Coordinator, Priority, Request, SchedulerConfig, ServeMode, SimulatorBackend,
};
use spikeformer_accel::hw::AccelConfig;
use spikeformer_accel::model::{QuantizedModel, SdtModelConfig};
use spikeformer_accel::util::Prng;

/// Seed for probe images and arrival draws.
const SEED: u64 = 0x10ad;

/// One swept cell's outcome row.
struct Row {
    fleet: &'static str,
    mode: &'static str,
    arrival: String,
    util: f64,
    offered: usize,
    served: usize,
    shed: usize,
    mean_s: f64,
    p50_s: f64,
    p99_s: f64,
    attainment: Option<f64>,
}

/// Measure per-request service seconds on the reference shape: modelled
/// wall cycles of real inferences at the shape's clock.
fn probe_services(model: &QuantizedModel, hw: &AccelConfig, n: usize) -> Vec<f64> {
    let mut accel = Accelerator::with_runtime(
        model.clone(),
        *hw,
        DatapathMode::Encoded,
        ExecMode::Overlapped,
        0,
    );
    let cfg = &model.cfg;
    let mut rng = Prng::new(SEED);
    (0..n)
        .map(|_| {
            let img: Vec<f32> = (0..cfg.in_channels * cfg.img_size * cfg.img_size)
                .map(|_| rng.next_f32_signed())
                .collect();
            let report = accel.infer(&img).expect("probe inference failed");
            hw.seconds(report.wall_cycles())
        })
        .collect()
}

/// Probe a shape's relative speed against the reference shape (same
/// probe image, cycle ratio) — mirrors `SimulatorBackend::fleet_factories`.
fn probe_speed(model: &QuantizedModel, reference: &AccelConfig, hw: &AccelConfig) -> f64 {
    let cfg = &model.cfg;
    let img: Vec<f32> = {
        let mut rng = Prng::new(SEED);
        (0..cfg.in_channels * cfg.img_size * cfg.img_size)
            .map(|_| rng.next_f32_signed())
            .collect()
    };
    let cycles = |shape: &AccelConfig| {
        let mut accel = Accelerator::with_runtime(
            model.clone(),
            *shape,
            DatapathMode::Encoded,
            ExecMode::Overlapped,
            0,
        );
        accel.infer(&img).expect("speed probe failed").wall_cycles().max(1) as f64
    };
    cycles(reference) / cycles(hw)
}

/// Deterministic priority mix: every 4th request High (with the SLO as a
/// hard deadline), every 5th Low, the rest Normal.
fn class_of(i: usize) -> Priority {
    if i % 4 == 0 {
        Priority::High
    } else if i % 5 == 4 {
        Priority::Low
    } else {
        Priority::Normal
    }
}

fn build_requests(
    offsets: &[f64],
    services: &[f64],
    slo_s: f64,
) -> Vec<SimRequest> {
    offsets
        .iter()
        .enumerate()
        .map(|(i, &arrival)| {
            let class = class_of(i);
            SimRequest {
                id: i as u64,
                class,
                arrival,
                service: services[i % services.len()],
                deadline: if class == Priority::High { Some(slo_s) } else { None },
            }
        })
        .collect()
}

#[allow(clippy::too_many_arguments)]
fn run_cell(
    fleet: &'static str,
    speeds: &[f64],
    mode_name: &'static str,
    mode: SimMode,
    arrival: &str,
    util: f64,
    reqs: &[SimRequest],
    timesteps: u32,
    slo_s: f64,
) -> (Row, SimOutcome) {
    let cfg = SimConfig {
        mode,
        speeds: speeds.to_vec(),
        admission: None,
        age_after: Some(slo_s * 4.0),
        timesteps,
    };
    let out = simulate(&cfg, reqs);
    let row = Row {
        fleet,
        mode: mode_name,
        arrival: arrival.to_string(),
        util,
        offered: reqs.len(),
        served: out.served(),
        shed: out.shed(),
        mean_s: out.mean_s(),
        p50_s: out.p50_s(),
        p99_s: out.p99_s(),
        attainment: out.attainment(Some(slo_s)),
    };
    (row, out)
}

fn print_row(r: &Row) {
    println!(
        "{:<12} {:<11} {:<14} util={:<4.2} served={:<4} shed={:<3} p50={:>9.3} ms  p99={:>9.3} ms  slo={}",
        r.fleet,
        r.mode,
        r.arrival,
        r.util,
        r.served,
        r.shed,
        r.p50_s * 1e3,
        r.p99_s * 1e3,
        r.attainment.map_or_else(|| "-".to_string(), |a| format!("{:.0}%", a * 100.0)),
    );
}

fn write_json(model_name: &str, mean_service_s: f64, rows: &[Row]) {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serving.json");
    let mut entry = String::from("{\n");
    entry.push_str(&format!(
        "    \"config\": {{\"model\": \"{model_name}\", \"accel\": \"paper\", \"mean_service_s\": {mean_service_s:.6e}}},\n"
    ));
    entry.push_str(
        "    \"units\": \"virtual-clock fleet model over measured service times (modelled accelerator cycles at the shape clock); util = offered rate / fleet capacity; p50_s/p99_s/mean_s = end-to-end served latency in seconds; attainment = fraction of SLO-targeted requests served in time (null when untargeted)\",\n",
    );
    entry.push_str("    \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        entry.push_str(&format!(
            "      {{\"fleet\": \"{}\", \"mode\": \"{}\", \"arrival\": \"{}\", \"util\": {:.2}, \"offered\": {}, \"served\": {}, \"shed\": {}, \"mean_s\": {:.6e}, \"p50_s\": {:.6e}, \"p99_s\": {:.6e}, \"attainment\": {}}}{}\n",
            r.fleet,
            r.mode,
            r.arrival,
            r.util,
            r.offered,
            r.served,
            r.shed,
            r.mean_s,
            r.p50_s,
            r.p99_s,
            r.attainment.map_or_else(|| "null".to_string(), |a| format!("{a:.4}")),
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    entry.push_str("    ]\n  }");
    match merge_bench_json(path, "serve_load", &entry) {
        Ok(()) => println!("\nwrote {path} (section \"serve_load\")"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
}

/// Real-`Coordinator` burst cross-check: a small closed-vs-continuous run
/// on the actual serving stack with simulator backends. Host wall-clock,
/// printed for context, never asserted (that is what the virtual clock is
/// for).
fn coordinator_cross_check(model: &QuantizedModel, n_req: usize) {
    section("real-coordinator cross-check (host wall-clock, not asserted)");
    let cfg = &model.cfg;
    let mut rng = Prng::new(SEED ^ 0xc0de);
    let imgs: Vec<Vec<f32>> = (0..n_req)
        .map(|_| {
            (0..cfg.in_channels * cfg.img_size * cfg.img_size)
                .map(|_| rng.next_f32_signed())
                .collect()
        })
        .collect();
    for (name, mode) in
        [("closed-batch", ServeMode::ClosedBatch), ("continuous", ServeMode::Continuous)]
    {
        let (factories, speeds) = SimulatorBackend::fleet_factories(
            model,
            &[AccelConfig::paper(), AccelConfig::paper()],
            DatapathMode::Encoded,
            ExecMode::Overlapped,
            0,
            MappingPolicy::default(),
        )
        .expect("fleet construction failed");
        let sched = SchedulerConfig {
            mode,
            lane_capacity: 4,
            slo: Some(Duration::from_secs(30)),
            worker_speeds: speeds,
            ..SchedulerConfig::default()
        };
        let mut coord = Coordinator::with_scheduler(factories, BatchPolicy::default(), sched);
        let started = Instant::now();
        for (i, img) in imgs.iter().enumerate() {
            coord
                .submit(Request::new(i as u64, img.clone()).with_priority(class_of(i)));
        }
        let (responses, report) = coord.finish(started).expect("serving failed");
        assert_eq!(responses.len(), n_req);
        assert!(responses.iter().all(|r| r.is_ok()), "cross-check must serve everything");
        println!("{name:<13} {}", report.summary());
    }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick");
    let json = args.iter().any(|a| a == "--json");

    // Same shape as the e2e bench: multi-head, multi-block so the probed
    // service time reflects a pipeline with real head mapping.
    let cfg = SdtModelConfig {
        name: "serve".into(),
        num_blocks: 2,
        num_heads: 8,
        ..SdtModelConfig::tiny()
    };
    let model = QuantizedModel::random(&cfg, 42);
    let timesteps = u32::try_from(cfg.timesteps.max(1)).unwrap_or(u32::MAX);
    let paper = AccelConfig::paper();
    let half = AccelConfig::with_lanes(paper.lanes / 2);

    section("probing service times (modelled cycles at the shape clock)");
    let services = probe_services(&model, &paper, if quick { 3 } else { 8 });
    let mean_service: f64 = services.iter().sum::<f64>() / services.len() as f64;
    let half_speed = probe_speed(&model, &paper, &half);
    println!(
        "mean service {:.3} ms on paper shape; half-lane shape speed {:.2}x",
        mean_service * 1e3,
        half_speed
    );
    let slo_s = 8.0 * mean_service;

    // Fleet shapes: homogeneous single/dual and a heterogeneous pair.
    let fleets: Vec<(&'static str, Vec<f64>)> = vec![
        ("1x-paper", vec![1.0]),
        ("2x-paper", vec![1.0, 1.0]),
        ("paper+half", vec![1.0, half_speed]),
    ];
    let utils: &[f64] = if quick { &[0.7] } else { &[0.3, 0.5, 0.7, 0.9] };
    let n_req = arg_value(&args, "--requests").unwrap_or(if quick { 96 } else { 512 });
    let arrival_override = arg_str(&args, "--arrival");

    let mut rows = Vec::new();
    let mut quick_pair: Option<(f64, f64)> = None; // (closed p99, continuous p99)
    section("virtual-clock sweep: arrival rate x fleet x scheduling mode");
    for (fleet, speeds) in &fleets {
        let fleet = *fleet;
        let capacity_rps = speeds.iter().sum::<f64>() / mean_service;
        for &util in utils {
            let rate = util * capacity_rps;
            let spec_str = arrival_override
                .clone()
                .unwrap_or_else(|| format!("poisson:{rate:.3}"));
            let spec = ArrivalSpec::parse(&spec_str).expect("bad --arrival spec");
            let offsets = arrival_offsets(&spec, n_req, SEED);
            let reqs = build_requests(&offsets, &services, slo_s);
            let closed = SimMode::Closed { max_batch: 8, max_wait: 2.0 * mean_service };
            let cont = SimMode::Continuous { lane_capacity: 4 };
            let (row_c, out_c) = run_cell(
                fleet, speeds, "closed", closed, &spec_str, util, &reqs, timesteps, slo_s,
            );
            let (row_k, out_k) = run_cell(
                fleet, speeds, "continuous", cont, &spec_str, util, &reqs, timesteps, slo_s,
            );
            print_row(&row_c);
            print_row(&row_k);
            if fleet == "1x-paper" && (util - 0.7).abs() < 1e-9 && arrival_override.is_none() {
                quick_pair = Some((out_c.p99_s(), out_k.p99_s()));
            }
            rows.push(row_c);
            rows.push(row_k);
        }
    }

    // The bench's headline claim, asserted on the deterministic model:
    // at a fixed Poisson rate, continuous batching strictly beats the
    // closed-batch discipline on p99.
    if let Some((closed_p99, cont_p99)) = quick_pair {
        assert!(
            cont_p99 < closed_p99,
            "continuous p99 {cont_p99} must be strictly below closed p99 {closed_p99}"
        );
        println!(
            "\np99 check: continuous {:.3} ms < closed {:.3} ms at util 0.70 (poisson, 1x-paper)",
            cont_p99 * 1e3,
            closed_p99 * 1e3
        );
    }

    coordinator_cross_check(&model, if quick { 6 } else { 16 });

    if json {
        write_json(&cfg.name, mean_service, &rows);
    }
}
