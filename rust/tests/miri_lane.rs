//! The Miri lane: a scaled-down subset of the concurrency/aliasing-critical
//! tests, small enough for the `cargo +nightly miri test --test miri_lane`
//! interpreter (~100× slower than native) yet covering every `unsafe` and
//! every aliasing-heavy protocol in the crate:
//!
//! * the pool's lifetime-erasing transmute (`accel/workers.rs`) — scoped
//!   borrowed writes, scope reuse, and panic unwinding, all under Miri's
//!   borrow tracking;
//! * SMAM's `split_at_mut` head sharding — disjoint `&mut` windows into
//!   shared output vectors, dispatched across real pool threads;
//! * the CSR spike arena's borrow/push/reset lifecycle (`spike/encoding.rs`);
//! * the [`SlotRing`] release/acquire handoff across two real threads
//!   (Miri's weak-memory emulation can surface misordered publication).
//!
//! The same tests run (fast) under plain `cargo test`, so the lane also
//! guards against drift between the Miri job and the native suite.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use spikeformer_accel::accel::{SlotRing, WorkerPool};
use spikeformer_accel::hw::AccelConfig;
use spikeformer_accel::scratch::ExecScratch;
use spikeformer_accel::spike::EncodedSpikes;
use spikeformer_accel::units::{HeadShard, SpikeMaskAddModule};

#[test]
fn pool_scope_writes_through_borrowed_slots() {
    let pool = WorkerPool::new(2);
    let mut slots = [0usize; 4];
    pool.scope(|s| {
        for (i, slot) in slots.iter_mut().enumerate() {
            s.spawn(move || *slot = i + 1);
        }
    });
    assert_eq!(slots, [1, 2, 3, 4]);
}

#[test]
fn pool_scopes_reuse_without_stale_borrows() {
    // Each scope's tasks borrow a *different* stack frame; any lingering
    // access from a previous scope's transmuted task is UB Miri would flag.
    let pool = WorkerPool::new(1);
    for round in 0..3usize {
        let mut value = 0usize;
        pool.scope(|s| s.spawn(|| value = round + 1));
        assert_eq!(value, round + 1);
    }
}

#[test]
fn pool_task_panic_unwinds_cleanly() {
    let pool = WorkerPool::new(1);
    let ran = Arc::new(AtomicUsize::new(0));
    let ran2 = Arc::clone(&ran);
    let result = catch_unwind(AssertUnwindSafe(|| {
        pool.scope(|s| {
            s.spawn(|| panic!("injected task panic"));
            s.spawn(move || {
                ran2.fetch_add(1, Ordering::SeqCst);
            });
        });
    }));
    assert!(result.is_err(), "scope re-panics after the scope drained");
    assert_eq!(ran.load(Ordering::SeqCst), 1, "sibling task still completed");
    // The pool survives a poisoned scope: the next scope is clean.
    let mut x = 0;
    pool.scope(|s| s.spawn(|| x = 7));
    assert_eq!(x, 7);
}

/// A tiny deterministic encoded tensor: channel `c` spikes wherever
/// `(l + c * stride) % 3 == 0`.
fn tiny_spikes(channels: usize, tokens: usize, stride: usize) -> EncodedSpikes {
    let mut e = EncodedSpikes::empty(channels, tokens);
    for c in 0..channels {
        for l in 0..tokens {
            if (l + c * stride) % 3 == 0 {
                e.push(c, l);
            }
        }
    }
    assert!(e.is_well_formed());
    e
}

#[test]
fn smam_sharded_split_at_mut_is_disjoint() {
    // 4 heads carved out of shared mask/acc vectors via `split_at_mut`,
    // dispatched onto 2 real pool threads — the aliasing shape Miri checks.
    let cfg = AccelConfig::small();
    let smam = SpikeMaskAddModule::new(2);
    let (q, k, v) = (tiny_spikes(8, 16, 1), tiny_spikes(8, 16, 2), tiny_spikes(8, 16, 5));
    let (serial, serial_stats) = smam.run(&q, &k, &v, &cfg);
    let pool = WorkerPool::new(2);
    let mut scratch = ExecScratch::new();
    let shard = HeadShard { heads: 4, cores: 2 };
    let (sharded, stats) =
        smam.run_sharded_into(&q, &k, &v, &cfg, shard, Some(&pool), &mut scratch);
    assert_eq!(sharded.mask, serial.mask, "sharding is bit-exact on the mask");
    assert_eq!(sharded.acc, serial.acc, "sharding is bit-exact on the counts");
    for c in 0..8 {
        assert_eq!(
            sharded.masked_v.channel_addrs(c),
            serial.masked_v.channel_addrs(c),
            "sharding is bit-exact on masked V (channel {c})"
        );
    }
    assert_eq!(stats.cmps, serial_stats.cmps);
}

#[test]
fn csr_arena_push_borrow_reset_lifecycle() {
    let mut e = EncodedSpikes::empty(3, 32);
    e.push(0, 1);
    e.push(0, 9);
    e.push(2, 4);
    assert_eq!(e.channel_addrs(0), &[1, 9]);
    assert_eq!(e.channel_addrs(1), &[] as &[u16]);
    assert_eq!(e.channel_addrs(2), &[4]);
    assert!(e.is_well_formed());

    // Borrow-then-mutate across the retain path used by the SMAM gate.
    let src = tiny_spikes(3, 32, 1);
    let mut gated = EncodedSpikes::empty(3, 32);
    gated.extend_channel_from(0, &src, 0);
    gated.extend_channel_from(2, &src, 2);
    assert_eq!(gated.channel_addrs(0), src.channel_addrs(0));
    assert_eq!(gated.channel_addrs(2), src.channel_addrs(2));
    assert!(gated.is_well_formed());

    // Pool-reuse primitives: drain in place, then reshape.
    gated.clear_reuse();
    assert_eq!(gated.count_spikes(), 0);
    assert!(gated.is_well_formed());
    gated.reset(5, 16);
    assert_eq!((gated.channels, gated.tokens), (5, 16));
    gated.push(4, 15);
    assert_eq!(gated.channel_addrs(4), &[15]);
    assert!(gated.is_well_formed());
}

#[test]
fn slot_ring_handoff_across_threads() {
    let ring = Arc::new(SlotRing::new(2));
    let r2 = Arc::clone(&ring);
    let consumer = std::thread::spawn(move || {
        let mut got = Vec::new();
        while got.len() < 8 {
            match r2.try_consume() {
                Some(v) => got.push(v),
                None => std::thread::yield_now(),
            }
        }
        got
    });
    let mut sent = 0u64;
    while sent < 8 {
        if ring.try_publish(100 + sent) {
            sent += 1;
        } else {
            std::thread::yield_now();
        }
    }
    assert_eq!(consumer.join().unwrap(), (100..108).collect::<Vec<u64>>());
}
