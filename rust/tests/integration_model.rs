//! Integration: trained-artifact loading, quantized accuracy (H1), and the
//! quantization error budget. Skips gracefully before `make artifacts`.

use std::path::Path;

use spikeformer_accel::accel::Accelerator;
use spikeformer_accel::hw::AccelConfig;
use spikeformer_accel::model::{load_model, loader::load_test_split, GoldenExecutor};

fn artifacts() -> Option<&'static Path> {
    let dir = Path::new("artifacts/weights");
    dir.join("manifest.txt").exists().then_some(dir)
}

#[test]
fn trained_model_loads_with_expected_shapes() {
    let Some(dir) = artifacts() else { return };
    let model = load_model(dir).unwrap();
    assert_eq!(model.cfg.embed_dim, 64);
    assert_eq!(model.sps_convs.len(), 5);
    assert_eq!(model.blocks.len(), model.cfg.num_blocks);
    for blk in &model.blocks {
        assert_eq!(blk.q.in_dim, 64);
        assert_eq!(blk.mlp1.out_dim, model.cfg.mlp_hidden);
        assert_eq!(blk.mlp2.out_dim, 64);
    }
}

#[test]
fn quantized_accuracy_beats_chance_by_far() {
    // The paper's H1: quantization costs little accuracy. Our tiny model
    // hits 100% float on the synthetic corpus; require >= 90% quantized.
    let Some(dir) = artifacts() else { return };
    let model = load_model(dir).unwrap();
    let (imgs, shape, labels) = load_test_split(dir).unwrap();
    let n = shape[0].min(64);
    let img_len = shape[1] * shape[2] * shape[3];
    let golden = GoldenExecutor::new(&model);
    let mut ok = 0;
    for i in 0..n {
        let r = golden.infer(&imgs[i * img_len..(i + 1) * img_len]);
        let pred = r
            .logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        ok += (pred == labels[i] as usize) as usize;
    }
    let acc = ok as f64 / n as f64;
    assert!(acc >= 0.9, "quantized accuracy {acc:.3} < 0.9");
}

#[test]
fn simulator_bit_exact_on_trained_weights() {
    let Some(dir) = artifacts() else { return };
    let model = load_model(dir).unwrap();
    let (imgs, shape, _) = load_test_split(dir).unwrap();
    let img_len = shape[1] * shape[2] * shape[3];
    let golden = GoldenExecutor::new(&model);
    let mut accel = Accelerator::new(model.clone(), AccelConfig::paper());
    for i in 0..shape[0].min(8) {
        let img = &imgs[i * img_len..(i + 1) * img_len];
        assert_eq!(accel.infer(img).unwrap().logits, golden.infer(img).logits, "image {i}");
    }
}

#[test]
fn trained_activations_are_sparse() {
    // Fig. 6's premise: trained SNN activations are strongly sparse.
    let Some(dir) = artifacts() else { return };
    let model = load_model(dir).unwrap();
    let (imgs, shape, _) = load_test_split(dir).unwrap();
    let img_len = shape[1] * shape[2] * shape[3];
    let golden = GoldenExecutor::new(&model);
    let r = golden.infer(&imgs[..img_len]);
    let sdsa = r.sparsity.iter().find(|(n, _)| n == "block0.sdsa.spikes").unwrap().1;
    assert!(sdsa > 0.5, "SDSA output should be sparse, got {sdsa:.3}");
    for (name, s) in &r.sparsity {
        assert!(*s > 0.2, "{name} suspiciously dense: {s:.3}");
    }
}
