//! Property tests over the compute units (own harness; proptest is
//! unavailable offline): for random spike tensors, layer shapes and
//! hardware configs, every encoded-path unit must equal its dense oracle.

use spikeformer_accel::hw::AccelConfig;
use spikeformer_accel::quant::{rshift_round, sat, QuantizedLinear, ACT_FRAC, MEM_BITS};
use spikeformer_accel::spike::{EncodedSpikes, SpikeMatrix, TokenGrid};
use spikeformer_accel::units::{
    slu::dense_reference, SpikeLinearUnit, SpikeMaskAddModule, SpikeMaxpoolUnit,
};
use spikeformer_accel::util::{proptest::check, Prng};
use spikeformer_accel::{prop_assert, prop_assert_eq};

fn random_encoded(rng: &mut Prng, c: usize, l: usize, p: f64) -> EncodedSpikes {
    let mut m = SpikeMatrix::zeros(c, l);
    for ci in 0..c {
        for li in 0..l {
            if rng.bernoulli(p) {
                m.set(ci, li, true);
            }
        }
    }
    EncodedSpikes::from_bitmap(&m)
}

fn random_hw(rng: &mut Prng) -> AccelConfig {
    let lanes = [16, 64, 256, 1536][rng.gen_range(0, 4)];
    AccelConfig::with_lanes(lanes)
}

#[test]
fn prop_smu_equals_dense_or_pool() {
    check("smu == dense OR pool", 60, |rng| {
        let h = rng.gen_range(2, 12);
        let w = rng.gen_range(2, 12);
        let kernel = rng.gen_range(1, 3.min(h.min(w)) + 1);
        let stride = rng.gen_range(1, kernel + 1);
        let grid = TokenGrid::new(h, w);
        let channels = rng.gen_range(1, 8);
        let p = rng.next_f64();
        let enc = random_encoded(rng, channels, grid.tokens(), p);
        let smu = SpikeMaxpoolUnit::new(kernel, stride);
        let hw = random_hw(rng);
        let (sparse, _) = smu.pool(&enc, grid, &hw);
        let (dense, _) = smu.pool_dense_baseline(&enc, grid, &hw);
        prop_assert_eq!(sparse, dense);
        Ok(())
    });
}

#[test]
fn prop_smam_equals_bitmap_intersection() {
    check("smam == bitmap hadamard-sum", 60, |rng| {
        let c = rng.gen_range(1, 24);
        let l = rng.gen_range(1, 200);
        let v_th = rng.gen_range(0, 5) as u32;
        let (pq, pk, pv) = (rng.next_f64(), rng.next_f64(), rng.next_f64());
        let q = random_encoded(rng, c, l, pq);
        let k = random_encoded(rng, c, l, pk);
        let v = random_encoded(rng, c, l, pv);
        let smam = SpikeMaskAddModule::new(v_th);
        let hw = random_hw(rng);
        let (a, sa) = smam.run(&q, &k, &v, &hw);
        let (b, sb) = smam.run_dense_baseline(&q, &k, &v, &hw);
        prop_assert_eq!(a.mask, b.mask);
        prop_assert_eq!(a.acc, b.acc);
        prop_assert_eq!(a.masked_v, b.masked_v);
        prop_assert!(
            sa.cycles <= sb.cycles + 1,
            "encoded may never be slower: {} vs {}",
            sa.cycles,
            sb.cycles
        );
        Ok(())
    });
}

#[test]
fn prop_slu_equals_dense_linear() {
    check("slu == dense linear", 40, |rng| {
        let c_in = rng.gen_range(1, 48);
        let c_out = rng.gen_range(1, 48);
        let l = rng.gen_range(1, 32);
        let px = rng.next_f64();
        let x = random_encoded(rng, c_in, l, px);
        let w: Vec<f32> = (0..c_in * c_out).map(|_| rng.next_f32_signed()).collect();
        let b: Vec<f32> = (0..c_out).map(|_| rng.next_f32_signed()).collect();
        let layer = QuantizedLinear::from_f32(&w, &b, c_in, c_out, 0);
        let mut slu = SpikeLinearUnit::new();
        let hw = random_hw(rng);
        let (out, stats) = slu.forward(&x, &layer, &hw);
        let want = dense_reference(&x, &layer);
        for (i, (&got, &acc)) in out.data.iter().zip(want.iter()).enumerate() {
            let expect = sat(rshift_round(acc, layer.acc_frac() - ACT_FRAC), MEM_BITS);
            prop_assert!(got == expect, "element {i}: {got} != {expect}");
        }
        let spikes = x.count_spikes() as u64;
        prop_assert!(stats.sops == spikes * c_out as u64, "sop count wrong");
        Ok(())
    });
}

#[test]
fn prop_smam_mask_monotone_in_threshold() {
    // Raising v_th can only clear more channels, never fire more.
    check("smam mask monotone in v_th", 40, |rng| {
        let c = rng.gen_range(1, 16);
        let l = rng.gen_range(1, 100);
        let q = random_encoded(rng, c, l, 0.4);
        let k = random_encoded(rng, c, l, 0.4);
        let v = random_encoded(rng, c, l, 0.4);
        let hw = AccelConfig::small();
        let mut prev_fired = usize::MAX;
        for v_th in 0..6u32 {
            let (out, _) = SpikeMaskAddModule::new(v_th).run(&q, &k, &v, &hw);
            let fired = out.mask.iter().filter(|&&m| m).count();
            prop_assert!(fired <= prev_fired, "v_th {v_th}: {fired} > {prev_fired}");
            prev_fired = fired;
        }
        Ok(())
    });
}

#[test]
fn prop_slu_cycles_monotone_in_spike_count() {
    check("slu cycles monotone in spikes", 30, |rng| {
        let c_in = 32;
        let c_out = 32;
        let l = 32;
        let w: Vec<f32> = (0..c_in * c_out).map(|_| rng.next_f32_signed()).collect();
        let layer = QuantizedLinear::from_f32(&w, &vec![0.0; c_out], c_in, c_out, 0);
        let hw = AccelConfig::paper();
        let p1 = rng.next_f64() * 0.5;
        let p2 = p1 + 0.4;
        let sparse = random_encoded(rng, c_in, l, p1);
        let dense = random_encoded(rng, c_in, l, p2);
        if dense.count_spikes() <= sparse.count_spikes() {
            return Ok(()); // rare sampling inversion: vacuous case
        }
        let mut slu = SpikeLinearUnit::new();
        let (_, s1) = slu.forward(&sparse, &layer, &hw);
        let (_, s2) = slu.forward(&dense, &layer, &hw);
        prop_assert!(s2.cycles >= s1.cycles, "{} < {}", s2.cycles, s1.cycles);
        Ok(())
    });
}

#[test]
fn prop_smu_output_well_formed() {
    check("smu output is well-formed encoding", 40, |rng| {
        let h = rng.gen_range(2, 16);
        let w = rng.gen_range(2, 16);
        let grid = TokenGrid::new(h, w);
        let (nc, pe) = (rng.gen_range(1, 6), rng.next_f64());
        let enc = random_encoded(rng, nc, grid.tokens(), pe);
        let (out, _) = SpikeMaxpoolUnit::new(2, 1).pool(&enc, grid, &AccelConfig::small());
        prop_assert!(out.is_well_formed(), "malformed output encoding");
        Ok(())
    });
}

#[test]
fn prop_mapping_policies_cover_all_work_units_exactly_once() {
    use spikeformer_accel::accel::{Mapper, MappingPolicy};
    use spikeformer_accel::hw::CoreTopology;
    check("every mapping policy covers block x head x timestep once", 60, |rng| {
        let heads = rng.gen_range(1, 17);
        let cores = rng.gen_range(1, 9);
        let blocks = rng.gen_range(1, 5);
        let timesteps = rng.gen_range(1, 5);
        let policy = MappingPolicy::ALL[rng.gen_range(0, 3)];
        let mapper = Mapper::new(heads, CoreTopology::with_sdeb_cores(cores), policy);
        let plan = mapper.plan(blocks, timesteps);
        prop_assert_eq!(plan.len(), heads * blocks * timesteps);
        let eff_cores = mapper.effective_cores(heads);
        let mut seen = vec![0usize; heads * blocks * timesteps];
        for (unit, core) in &plan {
            prop_assert!(*core < eff_cores, "core {} out of range {}", core, eff_cores);
            let idx = (unit.timestep * blocks + unit.block) * heads + unit.head;
            seen[idx] += 1;
        }
        prop_assert!(
            seen.iter().all(|&n| n == 1),
            "some work unit covered {:?} times",
            seen.iter().find(|&&n| n != 1)
        );
        Ok(())
    });
}

#[test]
fn prop_mapped_smam_value_invariant_under_random_topology() {
    use spikeformer_accel::accel::{Mapper, MappingPolicy};
    use spikeformer_accel::hw::{CoreTopology, FabricPartition};
    use spikeformer_accel::scratch::ExecScratch;
    check("mapped SMAM values independent of topology/policy", 30, |rng| {
        let c = rng.gen_range(4, 64);
        let l = rng.gen_range(4, 64);
        let p = rng.next_f64() * 0.6;
        let q = random_encoded(rng, c, l, p);
        let k = random_encoded(rng, c, l, p);
        let v = random_encoded(rng, c, l, p);
        let hw = random_hw(rng);
        let smam = SpikeMaskAddModule::new(rng.gen_range(0, 4) as u32);
        let (want, want_stats) = smam.run(&q, &k, &v, &hw);
        let heads = rng.gen_range(1, 12);
        let cores = rng.gen_range(1, 6);
        let policy = MappingPolicy::ALL[rng.gen_range(0, 3)];
        let partition = if rng.bernoulli(0.5) {
            FabricPartition::Replicated
        } else {
            FabricPartition::Split
        };
        let topo = CoreTopology { partition, ..CoreTopology::with_sdeb_cores(cores) };
        let mapper = Mapper::new(heads, topo, policy);
        let mut scratch = ExecScratch::new();
        let (out, stats) =
            smam.run_mapped_into(&q, &k, &v, &hw, &mapper, rng.gen_range(0, 4), None, &mut scratch);
        prop_assert_eq!(out.mask, want.mask);
        prop_assert_eq!(out.acc, want.acc);
        prop_assert_eq!(out.masked_v, want.masked_v);
        prop_assert_eq!(stats.sops, want_stats.sops);
        prop_assert_eq!(stats.adds, want_stats.adds);
        prop_assert_eq!(stats.cmps, want_stats.cmps);
        Ok(())
    });
}
