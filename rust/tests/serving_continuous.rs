//! Integration: continuous in-flight batching end-to-end — bursty
//! arrivals served exactly once with logits bit-identical to a fresh
//! serial backend, SLO machinery (admission shed, aging), and the
//! lane-level worker error path.

use std::time::{Duration, Instant};

use spikeformer_accel::benchlib::{arrival_offsets, ArrivalSpec};
use spikeformer_accel::coordinator::{
    BackendFactory, BatchPolicy, Coordinator, DynamicBatcher, GoldenBackend, InferBackend,
    Outcome, Priority, Request, SchedulerConfig, ServeMode,
};
use spikeformer_accel::model::{QuantizedModel, SdtModelConfig};
use spikeformer_accel::util::Prng;

fn image(seed: u64) -> Vec<f32> {
    let mut rng = Prng::new(seed);
    (0..3 * 32 * 32).map(|_| rng.next_f32_signed()).collect()
}

fn golden_factory(model: &QuantizedModel) -> BackendFactory {
    let m = model.clone();
    Box::new(move || Ok(Box::new(GoldenBackend::new(m)) as _))
}

/// The tentpole property: under seeded bursty open-loop arrivals with a
/// random priority mix, a continuous-batching fleet serves every request
/// exactly once and each response is bit-identical to running that image
/// alone through a fresh serial backend.
#[test]
fn bursty_continuous_serving_is_bit_identical_to_serial() {
    let cfg = SdtModelConfig::tiny();
    let model = QuantizedModel::random(&cfg, 77);
    for seed in [11u64, 23, 47] {
        let n = 18usize;
        // Compressed Poisson burst: offsets land within a few tens of ms.
        let offsets = arrival_offsets(&ArrivalSpec::Poisson { rate_rps: 600.0 }, n, seed);
        let mut rng = Prng::new(seed ^ 0xabcd);
        let sched = SchedulerConfig {
            mode: ServeMode::Continuous,
            lane_capacity: 3,
            slo: Some(Duration::from_secs(5)),
            ..SchedulerConfig::default()
        };
        let started = Instant::now();
        let mut co = Coordinator::with_scheduler(
            vec![golden_factory(&model), golden_factory(&model)],
            BatchPolicy::default(),
            sched,
        );
        for (i, &off) in offsets.iter().enumerate() {
            let target = Duration::from_secs_f64(off);
            let elapsed = started.elapsed();
            if target > elapsed {
                std::thread::sleep(target - elapsed);
            }
            let class = match rng.gen_range(0, 3) {
                0 => Priority::High,
                1 => Priority::Low,
                _ => Priority::Normal,
            };
            co.submit(Request::new(i as u64, image(seed * 1000 + i as u64)).with_priority(class));
        }
        let (responses, report) = co.finish(started).unwrap();

        // Exactly once: one response per id, all Ok, none shed or errored.
        assert_eq!(responses.len(), n, "seed {seed}: every request answered");
        let ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        assert_eq!(ids, (0..n as u64).collect::<Vec<_>>(), "seed {seed}: ids unique+sorted");
        assert_eq!(report.completed, n, "seed {seed}");
        assert_eq!(report.shed + report.errors, 0, "seed {seed}");

        // Bit-identical to a fresh serial backend per image.
        let mut serial = GoldenBackend::new(model.clone());
        for resp in &responses {
            let want = InferBackend::infer_batch(
                &mut serial,
                std::slice::from_ref(&image(seed * 1000 + resp.id)),
            )
            .unwrap();
            assert_eq!(resp.logits, want[0], "seed {seed}: response {} diverged", resp.id);
            assert_eq!(resp.outcome, Outcome::Ok);
        }
    }
}

/// Continuous mode with a bounded admission queue: overflow sheds the
/// oldest low-priority requests, everything else is served.
#[test]
fn continuous_admission_bound_sheds_low_priority() {
    let cfg = SdtModelConfig::tiny();
    let model = QuantizedModel::random(&cfg, 78);
    let sched = SchedulerConfig {
        mode: ServeMode::Continuous,
        lane_capacity: 1,
        admission: Some(3),
        ..SchedulerConfig::default()
    };
    let started = Instant::now();
    let mut co = Coordinator::with_scheduler(
        vec![golden_factory(&model)],
        BatchPolicy::default(),
        sched,
    );
    // Burst of 8 Low requests into one single-lane worker with a 3-deep
    // queue: the overflow must shed rather than queue without bound.
    for i in 0..8u64 {
        co.submit(Request::new(i, image(500 + i)).with_priority(Priority::Low));
    }
    let (responses, report) = co.finish(started).unwrap();
    assert_eq!(responses.len(), 8);
    assert!(report.shed > 0, "queue bound must shed under the burst");
    assert_eq!(report.completed + report.shed, 8);
    assert!(responses
        .iter()
        .all(|r| matches!(r.outcome, Outcome::Ok | Outcome::Shed)));
}

/// A backend whose lane engine accepts work and then dies mid-pass.
struct LaneFailBackend;

impl InferBackend for LaneFailBackend {
    fn name(&self) -> &'static str {
        "lane-fail"
    }

    fn infer_batch(&mut self, _images: &[Vec<f32>]) -> anyhow::Result<Vec<Vec<f32>>> {
        anyhow::bail!("batch path unused here")
    }

    fn lane_capacity(&self) -> usize {
        4
    }

    fn lane_admit(&mut self, _id: u64, _image: &[f32]) -> anyhow::Result<()> {
        Ok(())
    }

    fn lane_step(&mut self) -> anyhow::Result<Vec<(u64, Vec<f32>)>> {
        anyhow::bail!("injected lane failure")
    }
}

/// A lane-engine failure drains every in-flight request to a per-request
/// error response instead of hanging `finish()`.
#[test]
fn lane_step_failure_drains_inflight_to_errors() {
    let sched = SchedulerConfig {
        mode: ServeMode::Continuous,
        lane_capacity: 4,
        ..SchedulerConfig::default()
    };
    let started = Instant::now();
    let mut co = Coordinator::with_scheduler(
        vec![Box::new(|| Ok(Box::new(LaneFailBackend) as _)) as BackendFactory],
        BatchPolicy::default(),
        sched,
    );
    for i in 0..4u64 {
        co.submit(Request::new(i, vec![0.2; 3 * 32 * 32]));
    }
    let (responses, report) = co.finish(started).unwrap();
    assert_eq!(responses.len(), 4);
    assert_eq!(report.errors, 4);
    assert!(responses
        .iter()
        .all(|r| matches!(&r.outcome, Outcome::Error(m) if m.contains("injected lane failure"))));
}

/// Deterministic starvation check on the scheduler core: a Low request
/// that has aged past the promotion threshold is popped ahead of fresher
/// High traffic (virtual timestamps, no sleeping).
#[test]
fn aged_low_priority_request_overtakes_fresh_high_traffic() {
    let policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(10) };
    let mut b = DynamicBatcher::new(policy);
    let t0 = Instant::now();
    b.push_at(Request::new(0, vec![0.0; 4]).with_priority(Priority::Low), t0);
    // Fresh High arrivals long after: without aging they would win forever.
    let late = t0 + Duration::from_millis(200);
    for i in 1..4u64 {
        b.push_at(Request::new(i, vec![0.0; 4]).with_priority(Priority::High), late);
    }
    // At t0 + 200ms the Low request has waited 20x max_wait — far past
    // the 8x aging threshold — so it is scheduled as High and, being
    // oldest, pops first.
    let (first, _) = b.pop_next(late).expect("queue is non-empty");
    assert_eq!(first.id, 0, "aged Low request must not be starved");
    assert_eq!(first.priority, Priority::Low, "class is preserved, only scheduling rank ages");
}
