//! Pool panic propagation: a task that panics inside `WorkerPool::scope`
//! must poison/propagate without deadlocking waiters, and a panicking SPS
//! stage must surface as an inference *error* — never a hang, never a
//! poisoned pool — on both the overlapped executor path and `infer_batch`
//! (the "panic parity" contract documented in `accel/executor.rs`).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use spikeformer_accel::accel::{Accelerator, WorkerPool};
use spikeformer_accel::hw::AccelConfig;
use spikeformer_accel::model::{QuantizedModel, SdtModelConfig};
use spikeformer_accel::util::Prng;

/// A tiny model whose stage-0 conv panics (slice out of bounds in the
/// scatter walk) the moment the SPS stage touches it.
fn corrupted_model(seed: u64) -> QuantizedModel {
    let cfg = SdtModelConfig::tiny();
    let mut model = QuantizedModel::random(&cfg, seed);
    // Truncate both scatter layouts so whichever accumulator width the
    // tile engine picks, the first nonzero input pixel indexes past the
    // end of the weight row.
    model.sps_convs[0].wt.truncate(1);
    model.sps_convs[0].wt32.truncate(1);
    model
}

fn test_image(seed: u64) -> Vec<f32> {
    let mut rng = Prng::new(seed);
    (0..3 * 32 * 32).map(|_| rng.next_f32_signed()).collect()
}

#[test]
fn overlapped_infer_reports_sps_panic_as_error() {
    let mut accel = Accelerator::new(corrupted_model(11), AccelConfig::small());
    let img = test_image(1);

    // The producer task panics on the pool; the contract is an error on
    // the calling thread, not a deadlocked consumer or a crashed test.
    let err = accel.infer(&img).unwrap_err();
    assert!(
        format!("{err:#}").contains("SPS pipeline stage panicked"),
        "unexpected error: {err:#}"
    );

    // The pool must not be poisoned by the caught panic: a second call on
    // the same accelerator fails the same way instead of hanging.
    let err = accel.infer(&img).unwrap_err();
    assert!(
        format!("{err:#}").contains("SPS pipeline stage panicked"),
        "second call diverged: {err:#}"
    );
}

#[test]
fn infer_batch_reports_sps_panic_as_error() {
    let mut accel = Accelerator::new(corrupted_model(12), AccelConfig::small());
    let images = vec![test_image(2), test_image(3)];

    // Batches of >= 2 take the stage-major `run_batched` path; panic
    // parity means it fails exactly like the per-call path above.
    let err = accel.infer_batch(&images).unwrap_err();
    assert!(
        format!("{err:#}").contains("SPS pipeline stage panicked"),
        "unexpected error: {err:#}"
    );

    // And the accelerator (its pool included) stays usable afterwards.
    let err = accel.infer_batch(&images).unwrap_err();
    assert!(
        format!("{err:#}").contains("SPS pipeline stage panicked"),
        "second batch diverged: {err:#}"
    );
}

#[test]
fn pool_task_panic_propagates_at_scope_exit() {
    let pool = WorkerPool::new(2);
    let res = catch_unwind(AssertUnwindSafe(|| {
        pool.scope(|s| {
            s.spawn(|| panic!("injected task panic"));
        });
    }));
    let payload = res.expect_err("scope must re-panic when a task panicked");
    let msg = payload
        .downcast_ref::<&str>()
        .copied()
        .unwrap_or("<non-str panic payload>");
    assert_eq!(msg, "worker pool task panicked");
}

#[test]
fn panicking_task_does_not_deadlock_siblings_or_later_scopes() {
    let pool = WorkerPool::new(2);
    let ran = Arc::new(AtomicUsize::new(0));

    // One poisoned task among healthy siblings: every sibling still runs
    // to completion and the scope returns (by panicking) rather than
    // deadlocking its caller-helping waiter.
    let res = catch_unwind(AssertUnwindSafe(|| {
        pool.scope(|s| {
            for _ in 0..4 {
                let ran = Arc::clone(&ran);
                s.spawn(move || {
                    ran.fetch_add(1, Ordering::SeqCst);
                });
            }
            s.spawn(|| panic!("poisoned sibling"));
        });
    }));
    assert!(res.is_err(), "scope must propagate the sibling's panic");
    assert_eq!(ran.load(Ordering::SeqCst), 4, "healthy siblings must still run");

    // The workers survive the caught panic: a later scope on the same
    // pool completes normally and returns its value.
    let total = pool.scope(|s| {
        for _ in 0..8 {
            let ran = Arc::clone(&ran);
            s.spawn(move || {
                ran.fetch_add(1, Ordering::SeqCst);
            });
        }
        7usize
    });
    assert_eq!(total, 7);
    assert_eq!(ran.load(Ordering::SeqCst), 12);
}
