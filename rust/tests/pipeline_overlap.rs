//! Integration: the executed two-core overlapped pipeline against the
//! serial-charging baseline and the analytic schedule estimator.
//!
//! The overlapped executor must (a) change no value anywhere — logits stay
//! bit-identical to serial mode and the golden executor — and (b) produce
//! cycle accounting that reconciles with `PipelineEstimate` within the
//! fill-latency bound, making the estimator a cross-check rather than the
//! only source of truth.

use spikeformer_accel::accel::{
    pipeline_estimate, Accelerator, DatapathMode, ExecMode, MappingPolicy,
};
use spikeformer_accel::hw::{AccelConfig, CoreTopology};
use spikeformer_accel::model::{GoldenExecutor, QuantizedModel, SdtModelConfig};
use spikeformer_accel::util::Prng;

fn random_image(seed: u64) -> Vec<f32> {
    let mut rng = Prng::new(seed);
    (0..3 * 32 * 32).map(|_| rng.next_f32_signed()).collect()
}

/// A config that exercises head sharding (8 heads over 2 SDEB cores) and
/// odd timestep parity, at test-friendly scale.
fn sharded_cfg() -> SdtModelConfig {
    SdtModelConfig {
        name: "overlap-test".into(),
        timesteps: 3,
        num_blocks: 2,
        num_heads: 8,
        ..SdtModelConfig::tiny()
    }
}

#[test]
fn overlapped_and_serial_logits_bit_identical() {
    for cfg in [SdtModelConfig::tiny(), sharded_cfg()] {
        for seed in [1u64, 2] {
            let model = QuantizedModel::random(&cfg, seed);
            let img = random_image(seed + 10);
            let golden = GoldenExecutor::new(&model).infer(&img);
            let mut over = Accelerator::new(model.clone(), AccelConfig::small());
            let mut serial = Accelerator::with_modes(
                model,
                AccelConfig::small(),
                DatapathMode::Encoded,
                ExecMode::Serial,
            );
            let r_over = over.infer(&img).unwrap();
            let r_serial = serial.infer(&img).unwrap();
            assert_eq!(r_over.logits, r_serial.logits, "cfg {} seed {seed}", cfg.name);
            assert_eq!(r_over.logits, golden.logits, "cfg {} seed {seed}", cfg.name);
        }
    }
}

#[test]
fn executed_schedule_reconciles_with_estimator() {
    for (cfg, hw) in [
        (SdtModelConfig::tiny(), AccelConfig::small()),
        (sharded_cfg(), AccelConfig::small()),
        (SdtModelConfig::paper(), AccelConfig::paper()),
    ] {
        let timesteps = cfg.timesteps;
        let model = QuantizedModel::random(&cfg, 7);
        let mut accel = Accelerator::new(model, hw);
        let r = accel.infer(&random_image(3)).unwrap();
        let exec = r.pipeline.as_ref().expect("overlapped run records its schedule");

        // The per-timestep traces must account for exactly the recorded
        // phase cycles, stage by stage.
        assert_eq!(exec.sps_cycles(), r.phases.cycles_matching("sps."), "cfg {}", cfg.name);
        assert_eq!(
            exec.sdeb_cycles(),
            r.phases.cycles_matching("sdeb.") + r.phases.cycles_matching("head."),
            "cfg {}",
            cfg.name
        );
        // Serial-equivalent cost is the sum of every phase.
        assert_eq!(exec.serialized_cycles, r.total.cycles, "cfg {}", cfg.name);

        // Hard schedule invariants.
        assert!(exec.executed_cycles >= exec.bottleneck_cycles(), "cfg {}", cfg.name);
        assert!(exec.executed_cycles <= exec.serialized_cycles, "cfg {}", cfg.name);

        // The analytic re-timer must agree within the fill-latency bound.
        let est = pipeline_estimate(&r.phases, timesteps);
        assert!(
            exec.reconciles_with(&est),
            "cfg {}: executed {} vs estimated {} (bound {})",
            cfg.name,
            exec.executed_cycles,
            est.pipelined_cycles,
            exec.fill_latency_bound()
        );
    }
}

#[test]
fn overlap_strictly_faster_than_serial_charging() {
    let cfg = sharded_cfg();
    let model = QuantizedModel::random(&cfg, 11);
    let img = random_image(5);
    let mut over = Accelerator::new(model.clone(), AccelConfig::small());
    let mut serial = Accelerator::with_modes(
        model,
        AccelConfig::small(),
        DatapathMode::Encoded,
        ExecMode::Serial,
    );
    let r_over = over.infer(&img).unwrap();
    let r_serial = serial.infer(&img).unwrap();
    assert!(
        r_over.wall_cycles() < r_serial.wall_cycles(),
        "overlapped {} !< serial {}",
        r_over.wall_cycles(),
        r_serial.wall_cycles()
    );
    // Head sharding across the 2 SDEB cores must also cut the SDSA
    // phase's busy cycles relative to one serial comparator array.
    assert!(
        r_over.phases.get("sdeb.smam").cycles < r_serial.phases.get("sdeb.smam").cycles,
        "sharded SMAM {} !< serial SMAM {}",
        r_over.phases.get("sdeb.smam").cycles,
        r_serial.phases.get("sdeb.smam").cycles
    );
}

/// Tentpole acceptance: every SDEB-core count produces bit-identical
/// logits (vs serial charging *and* the golden executor), and modelled
/// wall cycles are monotonically non-increasing in the core count under
/// the default (replicated-fabric, round-robin) topology.
#[test]
fn sdeb_core_counts_bit_identical_logits_monotone_cycles() {
    let cfg = sharded_cfg();
    let model = QuantizedModel::random(&cfg, 17);
    let img = random_image(21);
    let golden = GoldenExecutor::new(&model).infer(&img);
    let mut serial = Accelerator::with_modes(
        model.clone(),
        AccelConfig::small(),
        DatapathMode::Encoded,
        ExecMode::Serial,
    );
    let r_serial = serial.infer(&img).unwrap();
    let mut last_wall = None;
    for cores in [1usize, 2, 4] {
        let hw = AccelConfig::small().with_topology(CoreTopology::with_sdeb_cores(cores));
        let mut accel = Accelerator::new(model.clone(), hw);
        let r = accel.infer(&img).unwrap();
        assert_eq!(r.logits, r_serial.logits, "cores={cores}: logits vs serial");
        assert_eq!(r.logits, golden.logits, "cores={cores}: logits vs golden");
        // Serial-equivalent op accounting is topology-invariant.
        assert_eq!(r.total.sops, r_serial.total.sops, "cores={cores}: sops");
        let exec = r.pipeline.as_ref().expect("overlapped run records its schedule");
        assert_eq!(exec.serialized_cycles, r.total.cycles, "cores={cores}");
        if let Some(prev) = last_wall {
            assert!(
                r.wall_cycles() <= prev,
                "cores={cores}: wall {} > previous {} — replicated cores must \
                 never cost modelled cycles",
                r.wall_cycles(),
                prev
            );
        }
        last_wall = Some(r.wall_cycles());
    }
}

/// The default topology (sdeb_cores = 2, depth 2, round-robin) must
/// reproduce the paper's two-core executor exactly: same logits, same
/// executed schedule as an explicitly-constructed two-core instance.
#[test]
fn default_topology_is_the_two_core_paper_instance() {
    let cfg = sharded_cfg();
    let model = QuantizedModel::random(&cfg, 19);
    let img = random_image(23);
    let mut default = Accelerator::new(model.clone(), AccelConfig::small());
    let explicit_hw = AccelConfig::small().with_topology(CoreTopology::paper());
    let mut explicit = Accelerator::new(model, explicit_hw)
        .with_mapping(MappingPolicy::HeadRoundRobin);
    let a = default.infer(&img).unwrap();
    let b = explicit.infer(&img).unwrap();
    assert_eq!(a.logits, b.logits);
    assert_eq!(a.total, b.total);
    assert_eq!(a.wall_cycles(), b.wall_cycles());
    let (pa, pb) = (a.pipeline.unwrap(), b.pipeline.unwrap());
    assert_eq!(pa.sps_per_timestep, pb.sps_per_timestep);
    assert_eq!(pa.sdeb_per_timestep, pb.sdeb_per_timestep);
    assert_eq!(pa.depth, 2);
    assert_eq!(pa.sps_cores, 1);
}

/// Every mapping policy is value-invariant end to end, and the executed
/// schedule still reconciles with the analytic estimator.
#[test]
fn mapping_policies_bit_identical_end_to_end() {
    let cfg = sharded_cfg();
    let timesteps = cfg.timesteps;
    let model = QuantizedModel::random(&cfg, 29);
    let img = random_image(31);
    let hw = AccelConfig::small().with_topology(CoreTopology::with_sdeb_cores(4));
    let mut base = Accelerator::new(model.clone(), hw);
    let want = base.infer(&img).unwrap();
    for policy in MappingPolicy::ALL {
        let mut accel = Accelerator::new(model.clone(), hw).with_mapping(policy);
        let r = accel.infer(&img).unwrap();
        assert_eq!(r.logits, want.logits, "{policy:?}");
        assert_eq!(r.total.sops, want.total.sops, "{policy:?}: ops conserved");
        let exec = r.pipeline.as_ref().unwrap();
        let est = pipeline_estimate(&r.phases, timesteps);
        assert!(exec.reconciles_with(&est), "{policy:?}");
    }
}

/// Deeper buffer rings are schedule-only: logits identical, wall cycles
/// never worse than the ping/pong default.
#[test]
fn deeper_pipeline_rings_value_invariant() {
    let cfg = sharded_cfg();
    let model = QuantizedModel::random(&cfg, 37);
    let img = random_image(41);
    let mut d2 = Accelerator::new(model.clone(), AccelConfig::small());
    let r2 = d2.infer(&img).unwrap();
    for depth in [3usize, 4] {
        let topo = CoreTopology { pipeline_depth: depth, ..CoreTopology::paper() };
        let mut accel = Accelerator::new(model.clone(), AccelConfig::small().with_topology(topo));
        let r = accel.infer(&img).unwrap();
        assert_eq!(r.logits, r2.logits, "depth {depth}");
        assert!(
            r.wall_cycles() <= r2.wall_cycles(),
            "depth {depth}: deeper ring must never cost cycles"
        );
        assert_eq!(r.pipeline.as_ref().unwrap().depth, depth);
    }
}

#[test]
fn overlapped_runs_are_deterministic_across_instances() {
    let cfg = sharded_cfg();
    let model = QuantizedModel::random(&cfg, 13);
    let img = random_image(9);
    let mut a = Accelerator::new(model.clone(), AccelConfig::small());
    let mut b = Accelerator::new(model, AccelConfig::small());
    let ra = a.infer(&img).unwrap();
    let rb = b.infer(&img).unwrap();
    assert_eq!(ra.logits, rb.logits);
    assert_eq!(ra.wall_cycles(), rb.wall_cycles());
    let (pa, pb) = (ra.pipeline.unwrap(), rb.pipeline.unwrap());
    assert_eq!(pa.sps_per_timestep, pb.sps_per_timestep);
    assert_eq!(pa.sdeb_per_timestep, pb.sdeb_per_timestep);
}
