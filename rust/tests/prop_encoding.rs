//! Property tests over the spike-encoding substrate and the integer LIF:
//! round-trips, storage accounting, grid coverage, and the fixed-point
//! neuron against an exact float reference on the quantization grid.

use spikeformer_accel::lif::{LifArray, LifParams};
use spikeformer_accel::quant::{QFormat, ACT_FRAC, MEM_BITS, SEGMENT_TOKENS};
use spikeformer_accel::spike::{EncodedSpikes, SpikeMatrix, TokenGrid};
use spikeformer_accel::util::{proptest::check, Prng};
use spikeformer_accel::{prop_assert, prop_assert_eq};

fn random_bitmap(rng: &mut Prng, c: usize, l: usize, p: f64) -> SpikeMatrix {
    let mut m = SpikeMatrix::zeros(c, l);
    for ci in 0..c {
        for li in 0..l {
            if rng.bernoulli(p) {
                m.set(ci, li, true);
            }
        }
    }
    m
}

#[test]
fn prop_encoding_roundtrip() {
    check("bitmap -> encoded -> bitmap", 100, |rng| {
        let c = rng.gen_range(1, 32);
        let l = rng.gen_range(1, 1500);
        let p = rng.next_f64();
        let m = random_bitmap(rng, c, l, p);
        let enc = EncodedSpikes::from_bitmap(&m);
        prop_assert!(enc.is_well_formed(), "not well-formed");
        prop_assert_eq!(enc.to_bitmap(), m);
        Ok(())
    });
}

/// Seed-semantics reference: per-channel lists plus a per-channel segment
/// scan, exactly what the pre-CSR `Vec<Vec<u16>>` representation computed.
fn reference_lists(m: &SpikeMatrix) -> Vec<Vec<u16>> {
    (0..m.channels)
        .map(|c| {
            m.channel(c)
                .iter()
                .enumerate()
                .filter_map(|(l, &f)| f.then_some(l as u16))
                .collect()
        })
        .collect()
}

fn reference_storage_words(lists: &[Vec<u16>]) -> usize {
    let mut words = 0;
    for list in lists {
        words += list.len();
        let mut seg_prev = usize::MAX;
        for &l in list {
            let seg = l as usize / SEGMENT_TOKENS;
            if seg != seg_prev {
                words += 1;
                seg_prev = seg;
            }
        }
    }
    words
}

#[test]
fn prop_csr_arena_matches_list_of_lists_semantics() {
    // The flat arena must expose exactly the per-channel slices the seed's
    // Vec<Vec<u16>> held, with the flat stream being their concatenation
    // and storage_words matching the seed's per-channel segment scan —
    // including multi-segment token spaces (l up to ~6 segments).
    check("csr arena == list-of-lists semantics", 80, |rng| {
        let c = rng.gen_range(1, 24);
        let l = rng.gen_range(1, 1600);
        let p = rng.next_f64();
        let m = random_bitmap(rng, c, l, p);
        let enc = EncodedSpikes::from_bitmap(&m);
        let reference = reference_lists(&m);
        let flat: Vec<u16> = reference.iter().flatten().copied().collect();
        prop_assert_eq!(enc.addrs(), &flat[..]);
        for (ci, want) in reference.iter().enumerate() {
            prop_assert_eq!(enc.channel_addrs(ci), &want[..]);
            prop_assert!(
                enc.channel_len(ci) == want.len(),
                "channel {ci} len {} != {}",
                enc.channel_len(ci),
                want.len()
            );
        }
        prop_assert_eq!(enc.storage_words(), reference_storage_words(&reference));
        prop_assert_eq!(enc.count_spikes(), m.count_spikes());
        Ok(())
    });
}

#[test]
fn prop_builder_pushes_equal_from_bitmap() {
    // Building the arena spike by spike through the Builder/push API must
    // be indistinguishable from the one-shot bitmap encode, and stay
    // well-formed at every step (adversarial-but-legal push sequences:
    // random gaps of empty channels, random segment jumps).
    check("builder pushes == from_bitmap", 60, |rng| {
        let c = rng.gen_range(1, 16);
        let l = rng.gen_range(1, 1200);
        let p = rng.next_f64() * 0.3;
        let m = random_bitmap(rng, c, l, p);
        let mut b = EncodedSpikes::builder(c, l);
        for ci in 0..c {
            for li in 0..l {
                if m.get(ci, li) {
                    b.push(ci, li);
                }
            }
        }
        let enc = b.finish();
        prop_assert!(enc.is_well_formed(), "builder output malformed");
        prop_assert_eq!(enc, EncodedSpikes::from_bitmap(&m));
        Ok(())
    });
}

#[test]
fn prop_extend_channel_from_preserves_well_formedness() {
    // The SMAM retain path: copying random channel subsets out of a source
    // arena must keep the destination well-formed with exact storage
    // accounting (the header counts travel with the slice).
    check("extend_channel_from well-formed", 60, |rng| {
        let c = rng.gen_range(1, 16);
        let l = rng.gen_range(1, 1500);
        let p = rng.next_f64() * 0.5;
        let src = EncodedSpikes::from_bitmap(&random_bitmap(rng, c, l, p));
        let mut dst = EncodedSpikes::empty(c, l);
        let mut kept_words = 0usize;
        for ch in 0..c {
            if rng.bernoulli(0.5) {
                dst.extend_channel_from(ch, &src, ch);
                kept_words += src.channel_len(ch);
                let list = src.channel_addrs(ch);
                let mut seg_prev = usize::MAX;
                for &a in list {
                    let seg = a as usize / SEGMENT_TOKENS;
                    if seg != seg_prev {
                        kept_words += 1;
                        seg_prev = seg;
                    }
                }
                prop_assert_eq!(dst.channel_addrs(ch), list);
            }
        }
        prop_assert!(dst.is_well_formed(), "destination malformed");
        prop_assert_eq!(dst.storage_words(), kept_words);
        Ok(())
    });
}

#[test]
fn prop_storage_words_bounds() {
    // words >= spikes (every spike stored) and
    // words <= spikes + non-empty-segment count (one header per segment).
    check("storage word bounds", 80, |rng| {
        let c = rng.gen_range(1, 16);
        let l = rng.gen_range(1, 2000);
        let p = rng.next_f64() * 0.5;
        let m = random_bitmap(rng, c, l, p);
        let enc = EncodedSpikes::from_bitmap(&m);
        let spikes = enc.count_spikes();
        let words = enc.storage_words();
        prop_assert!(words >= spikes, "words {words} < spikes {spikes}");
        let max_headers = c * (l.div_ceil(SEGMENT_TOKENS));
        prop_assert!(
            words <= spikes + max_headers,
            "words {words} > spikes {spikes} + headers {max_headers}"
        );
        Ok(())
    });
}

#[test]
fn prop_sparsity_consistent() {
    check("sparsity agrees between representations", 60, |rng| {
        let c = rng.gen_range(1, 16);
        let l = rng.gen_range(1, 500);
        let p = rng.next_f64();
        let m = random_bitmap(rng, c, l, p);
        let enc = EncodedSpikes::from_bitmap(&m);
        prop_assert!(
            (m.sparsity() - enc.sparsity()).abs() < 1e-12,
            "{} vs {}",
            m.sparsity(),
            enc.sparsity()
        );
        Ok(())
    });
}

#[test]
fn prop_grid_coverage_matches_bruteforce() {
    check("covering_outputs == brute force", 60, |rng| {
        let h = rng.gen_range(2, 14);
        let w = rng.gen_range(2, 14);
        let kmax = 4.min(h.min(w));
        let kernel = rng.gen_range(1, kmax + 1);
        let stride = rng.gen_range(1, kernel + 1);
        let g = TokenGrid::new(h, w);
        let og = g.pooled(kernel, stride);
        let y = rng.gen_range(0, h);
        let x = rng.gen_range(0, w);
        let mut got = Vec::new();
        g.covering_outputs(y, x, kernel, stride, &mut got);
        let mut brute = Vec::new();
        for oy in 0..og.height {
            for ox in 0..og.width {
                let (y0, x0) = (oy * stride, ox * stride);
                if y >= y0 && y < y0 + kernel && x >= x0 && x < x0 + kernel {
                    brute.push(og.addr(oy, ox));
                }
            }
        }
        prop_assert_eq!(got, brute);
        Ok(())
    });
}

#[test]
fn prop_lif_matches_grid_reference() {
    // Independent reimplementation of the Eq. (1)-(3) recurrence with the
    // same grid semantics (decay rounded to the fixed-point grid, ties
    // away from zero) — the integer LifArray must match it exactly.
    check("integer LIF == grid reference", 60, |rng| {
        let params = LifParams::from_f32(1.0, 0.0, 0.5);
        let mut arr = LifArray::new(1, params);
        let grid = (1i64 << ACT_FRAC) as f64;
        let mut temp_f = 0.0f64;
        for step in 0..100 {
            let raw = rng.gen_range(0, 513) as i32 - 256; // +-4.0 at Q.6
            let spa_f = raw as f64 / grid;
            let mem_f = spa_f + temp_f;
            let fired_f = mem_f >= 1.0;
            temp_f = if fired_f {
                0.0
            } else {
                // gamma=0.5 decay, rounded to the grid ties-away-from-zero
                let half = mem_f * 0.5 * grid;
                let rounded = if half >= 0.0 { (half + 0.5).floor() } else { (half - 0.5).ceil() };
                rounded / grid
            };
            let fired = arr.step_one(0, raw);
            prop_assert!(fired == fired_f, "step {step}: int {fired} float {fired_f}");
        }
        Ok(())
    });
}

#[test]
fn prop_lif_spike_rate_decreasing_in_threshold() {
    check("lif rate monotone in v_th", 30, |rng| {
        let n = 256;
        let spa: Vec<i32> = (0..n)
            .map(|_| {
                let fmt = QFormat::new(MEM_BITS, ACT_FRAC);
                fmt.from_f32(rng.next_f32_signed() * 2.0)
            })
            .collect();
        let mut prev = usize::MAX;
        for v_th in [0.25f32, 0.5, 1.0, 2.0] {
            let mut arr = LifArray::new(n, LifParams::from_f32(v_th, 0.0, 0.5));
            let mut fired = Vec::new();
            arr.step(&spa, &mut fired);
            let count = fired.iter().filter(|&&f| f).count();
            prop_assert!(count <= prev, "v_th {v_th}: {count} > {prev}");
            prev = count;
        }
        Ok(())
    });
}
