//! Integration: the L3 coordinator end-to-end — responses match the
//! reference executor, ordering, batching policy effects, and mixed
//! worker pools.

use std::time::{Duration, Instant};

use spikeformer_accel::coordinator::{
    BackendFactory, BatchPolicy, Coordinator, GoldenBackend, Request, SimulatorBackend,
};
use spikeformer_accel::hw::AccelConfig;
use spikeformer_accel::model::{GoldenExecutor, QuantizedModel, SdtModelConfig};
use spikeformer_accel::util::Prng;

fn images(n: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Prng::new(seed);
    (0..n).map(|_| (0..3 * 32 * 32).map(|_| rng.next_f32_signed()).collect()).collect()
}

fn golden_factory(model: &QuantizedModel) -> BackendFactory {
    let m = model.clone();
    Box::new(move || Ok(Box::new(GoldenBackend::new(m)) as _))
}

fn sim_factory(model: &QuantizedModel) -> BackendFactory {
    let m = model.clone();
    Box::new(move || Ok(Box::new(SimulatorBackend::new(m, AccelConfig::small())) as _))
}

#[test]
fn coordinator_results_match_direct_execution() {
    let cfg = SdtModelConfig::tiny();
    let model = QuantizedModel::random(&cfg, 31);
    let imgs = images(12, 1);

    // direct reference
    let exec = GoldenExecutor::new(&model);
    let want: Vec<Vec<f32>> = imgs.iter().map(|i| exec.infer(i).logits).collect();

    let started = Instant::now();
    let mut co = Coordinator::new(
        vec![golden_factory(&model), golden_factory(&model)],
        BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
    );
    for (i, img) in imgs.iter().enumerate() {
        co.submit(Request::new(i as u64, img.clone()));
    }
    let (responses, report) = co.finish(started).unwrap();
    assert_eq!(report.completed, imgs.len());
    for (i, resp) in responses.iter().enumerate() {
        assert_eq!(resp.id, i as u64);
        assert_eq!(resp.logits, want[i], "response {i} wrong");
    }
}

#[test]
fn mixed_simulator_and_golden_workers_agree() {
    // The simulator is bit-exact vs golden, so a mixed pool must produce
    // identical logits regardless of which worker served which request.
    let cfg = SdtModelConfig::tiny();
    let model = QuantizedModel::random(&cfg, 32);
    let imgs = images(10, 2);
    let exec = GoldenExecutor::new(&model);
    let want: Vec<Vec<f32>> = imgs.iter().map(|i| exec.infer(i).logits).collect();

    let started = Instant::now();
    let mut co = Coordinator::new(
        vec![sim_factory(&model), golden_factory(&model)],
        BatchPolicy { max_batch: 2, max_wait: Duration::from_millis(1) },
    );
    for (i, img) in imgs.iter().enumerate() {
        co.submit(Request::new(i as u64, img.clone()));
    }
    let (responses, report) = co.finish(started).unwrap();
    for (i, resp) in responses.iter().enumerate() {
        assert_eq!(resp.logits, want[i], "response {i}");
    }
    assert!(report.modelled_cycles > 0, "simulator worker should have served work");
}

#[test]
fn single_request_is_released_by_timeout() {
    let cfg = SdtModelConfig::tiny();
    let model = QuantizedModel::random(&cfg, 33);
    let started = Instant::now();
    let mut co = Coordinator::new(
        vec![golden_factory(&model)],
        BatchPolicy { max_batch: 64, max_wait: Duration::from_millis(1) },
    );
    co.submit(Request::new(0, images(1, 3).pop().unwrap()));
    let (responses, _) = co.finish(started).unwrap();
    assert_eq!(responses.len(), 1);
}

#[test]
fn large_burst_all_served() {
    let cfg = SdtModelConfig::tiny();
    let model = QuantizedModel::random(&cfg, 34);
    let imgs = images(40, 4);
    let started = Instant::now();
    let mut co = Coordinator::new(
        vec![golden_factory(&model), golden_factory(&model), golden_factory(&model)],
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
    );
    for (i, img) in imgs.iter().enumerate() {
        co.submit(Request::new(i as u64, img.clone()));
    }
    let (responses, report) = co.finish(started).unwrap();
    assert_eq!(responses.len(), 40);
    assert!(report.mean_batch >= 1.0);
    assert!(report.latency_p99_s >= report.latency_p50_s);
}
