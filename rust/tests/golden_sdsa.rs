//! Golden-vector snapshot test: the committed `.npy` fixtures under
//! `tests/fixtures/` lock the SDSA head outputs byte-for-byte — both the
//! CSR engine and the packed-bitmap engine must reproduce the mask,
//! accumulator and masked-V planes that `make_fixtures.py`'s independent
//! Python reference computed. Regenerate (only when the SDSA semantics
//! intentionally change) with:
//!
//! ```bash
//! python3 rust/tests/fixtures/make_fixtures.py
//! ```

use std::path::Path;

use spikeformer_accel::accel::Mapper;
use spikeformer_accel::hw::{AccelConfig, EngineSelect};
use spikeformer_accel::io::npy::NpyArray;
use spikeformer_accel::scratch::ExecScratch;
use spikeformer_accel::spike::{EncodedSpikes, SpikeMatrix};
use spikeformer_accel::units::SpikeMaskAddModule;

/// The fixtures' operating point (see make_fixtures.py).
const V_TH: u32 = 6;

fn fixture(name: &str) -> NpyArray {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(name);
    NpyArray::load(&path).unwrap_or_else(|e| panic!("loading fixture {name}: {e:#}"))
}

fn encoded_from_plane(arr: &NpyArray) -> EncodedSpikes {
    assert_eq!(arr.shape.len(), 2, "spike plane must be 2-D");
    let (c, l) = (arr.shape[0], arr.shape[1]);
    let data = arr.as_i32().unwrap();
    let mut m = SpikeMatrix::zeros(c, l);
    for ci in 0..c {
        for li in 0..l {
            if data[ci * l + li] != 0 {
                m.set(ci, li, true);
            }
        }
    }
    EncodedSpikes::from_bitmap(&m)
}

/// Decode an encoding back to a flat 0/1 plane for byte-exact comparison
/// with the fixture payload.
fn plane_from_encoded(enc: &EncodedSpikes) -> Vec<i32> {
    let mut out = vec![0i32; enc.channels * enc.tokens];
    for c in 0..enc.channels {
        for &a in enc.channel_addrs(c) {
            out[c * enc.tokens + a as usize] = 1;
        }
    }
    out
}

#[test]
fn sdsa_head_outputs_match_golden_vectors_on_both_engines() {
    let q = encoded_from_plane(&fixture("sdsa_q.npy"));
    let k = encoded_from_plane(&fixture("sdsa_k.npy"));
    let v = encoded_from_plane(&fixture("sdsa_v.npy"));
    let want_mask = fixture("sdsa_mask.npy").as_i32().unwrap();
    let want_acc = fixture("sdsa_acc.npy").as_i32().unwrap();
    let want_masked_v = fixture("sdsa_masked_v.npy").as_i32().unwrap();
    assert!(
        want_mask.iter().any(|&m| m == 0) && want_mask.iter().any(|&m| m == 1),
        "fixture mask must exercise both branches"
    );

    let smam = SpikeMaskAddModule::new(V_TH);
    let serial = Mapper::serial();
    let mut scratch = ExecScratch::new();
    for engine in [EngineSelect::Csr, EngineSelect::Bitmap, EngineSelect::adaptive()] {
        let mut hw = AccelConfig::small();
        hw.engine = engine;
        let (out, _) = smam.run_mapped_into(&q, &k, &v, &hw, &serial, 0, None, &mut scratch);
        let got_mask: Vec<i32> = out.mask.iter().map(|&m| i32::from(m)).collect();
        let got_acc: Vec<i32> = out.acc.iter().map(|&a| a as i32).collect();
        assert_eq!(got_mask, want_mask, "mask snapshot broken ({})", engine.name());
        assert_eq!(got_acc, want_acc, "acc snapshot broken ({})", engine.name());
        assert_eq!(
            plane_from_encoded(&out.masked_v),
            want_masked_v,
            "masked-V snapshot broken ({})",
            engine.name()
        );
    }
}
