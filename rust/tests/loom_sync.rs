//! Loom model checking of the concurrency core. Compiled only under
//! `--cfg loom`; a normal `cargo test` sees an empty crate.
//!
//! What is being proven, per test, by exhaustively exploring thread
//! interleavings (bounded by `LOOM_MAX_PREEMPTIONS`):
//!
//! * the scoped spawn / `drain_and_wait` protocol of
//!   [`WorkerPool`](spikeformer_accel::accel::WorkerPool) — the soundness
//!   argument behind the lifetime-erasing `unsafe` in
//!   `accel/workers.rs`: under **no** interleaving does `scope` return
//!   before every spawned task finished writing through its `'env` borrows;
//! * caller-helping non-deadlock: a scope completes even when the entire
//!   pool is saturated by a task that blocks until the caller releases it;
//! * the stale-notification path: injector entries left by a drained scope
//!   are harmless no-ops for the next scope;
//! * the ping/pong [`SlotRing`](spikeformer_accel::accel::SlotRing)'s
//!   release/acquire publication — payloads cross threads in FIFO order
//!   with no stale reads, through a ring shallower than the stream.
//!
//! Run (networked machine; loom is deliberately not in the offline
//! lockfile — see `util::sync` docs):
//!
//! ```text
//! cargo add loom@0.7 --package spikeformer_accel --target 'cfg(loom)'
//! RUSTFLAGS="--cfg loom" LOOM_MAX_PREEMPTIONS=3 \
//!     cargo test --release --test loom_sync
//! ```

#![cfg(loom)]

use spikeformer_accel::accel::{SlotRing, WorkerPool};
use spikeformer_accel::util::sync::atomic::{AtomicUsize, Ordering};
use spikeformer_accel::util::sync::{thread, Arc, Condvar, Mutex};

#[test]
fn scope_spawn_drain_protocol_is_sound() {
    loom::model(|| {
        let pool = WorkerPool::new(1);
        let mut slots = [0usize; 2];
        pool.scope(|s| {
            for (i, slot) in slots.iter_mut().enumerate() {
                s.spawn(move || *slot = i + 1);
            }
        });
        // `scope` returned, so under this interleaving every task has
        // finished writing through its borrow — the transmute's contract.
        assert_eq!(slots, [1, 2]);
        drop(pool);
    });
}

#[test]
fn caller_helps_when_pool_is_saturated() {
    loom::model(|| {
        let pool = WorkerPool::new(1);
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let hits = Arc::new(AtomicUsize::new(0));
        pool.scope(|s| {
            let gate2 = Arc::clone(&gate);
            s.spawn(move || {
                // Saturates the lone worker (when a worker picks it up)
                // until the caller opens the gate below.
                let (lock, cv) = &*gate2;
                let mut open = lock.lock().unwrap();
                while !*open {
                    open = cv.wait(open).unwrap();
                }
            });
            for _ in 0..2 {
                let hits2 = Arc::clone(&hits);
                s.spawn(move || {
                    hits2.fetch_add(1, Ordering::SeqCst);
                });
            }
            let (lock, cv) = &*gate;
            *lock.lock().unwrap() = true;
            cv.notify_all();
        });
        assert_eq!(hits.load(Ordering::SeqCst), 2);
        drop(pool);
    });
}

#[test]
fn stale_injector_entries_are_noops_for_later_scopes() {
    loom::model(|| {
        let pool = WorkerPool::new(1);
        let hits = Arc::new(AtomicUsize::new(0));
        for _ in 0..2 {
            let hits2 = Arc::clone(&hits);
            pool.scope(|s| {
                s.spawn(move || {
                    hits2.fetch_add(1, Ordering::SeqCst);
                });
            });
        }
        // Schedules where the caller drained scope 1's task leave a stale
        // injector entry behind; the worker popping it during scope 2 must
        // not double-run anything.
        assert_eq!(hits.load(Ordering::SeqCst), 2);
        drop(pool);
    });
}

#[test]
fn slot_ring_release_acquire_orders_payloads() {
    loom::model(|| {
        let ring = Arc::new(SlotRing::new(2));
        let r2 = Arc::clone(&ring);
        let consumer = thread::spawn(move || {
            let mut got = Vec::new();
            while got.len() < 3 {
                match r2.try_consume() {
                    Some(v) => got.push(v),
                    None => thread::yield_now(),
                }
            }
            got
        });
        let mut sent = 0u64;
        while sent < 3 {
            if ring.try_publish(10 + sent) {
                sent += 1;
            } else {
                thread::yield_now();
            }
        }
        // 3 payloads through a depth-2 ring force a wrap: slot 0 is reused
        // while the consumer may still be behind. A stale read (too-weak
        // ordering) would surface as a wrong or duplicated value here.
        assert_eq!(consumer.join().unwrap(), vec![10, 11, 12]);
    });
}
