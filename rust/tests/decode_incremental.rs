//! Differential harness for the autoregressive decode path: the
//! incremental spike-stream KV-cache session must be **bit-identical to
//! full recompute**, three ways at once —
//!
//! 1. per-step logits equal the dense [`GoldenDecoder`] oracle replaying
//!    the whole prefix from scratch (full recompute, no cache);
//! 2. the incremental session's logits, cumulative phase charges
//!    (`UnitStats` per phase, cycles, SRAM traffic) and cache state equal
//!    a *fresh* session replaying the same prefix — no hidden state may
//!    leak between steps beyond the defined session state (LIF membranes
//!    plus the KV cache);
//! 3. every spike engine (CSR, bitmap, adaptive) generates the same
//!    values, over random decoder shapes and random token sequences.
//!
//! Plus KV-cache invariants at the session level: the cache holds exactly
//! `blocks x timesteps` lanes of `pos()` positions after every step, its
//! storage grows monotonically, and `reset()` replays bit-exactly with
//! zero steady-state allocation (arena reuse).

use spikeformer_accel::accel::DecodeSession;
use spikeformer_accel::hw::{AccelConfig, EngineSelect};
use spikeformer_accel::model::{DecoderShape, GoldenDecoder, QuantizedModel, SdtModelConfig};
use spikeformer_accel::spike::KvCache;
use spikeformer_accel::util::{proptest::check, Prng};
use spikeformer_accel::{prop_assert, prop_assert_eq};

/// A random valid decoder config: heads divide the embedding, every
/// dimension small enough that the dense oracle stays fast.
fn random_decoder_cfg(rng: &mut Prng) -> SdtModelConfig {
    let heads = [1usize, 2, 4][rng.gen_range(0, 3)];
    let mut cfg = SdtModelConfig::tiny();
    cfg.name = "prop-decoder".into();
    cfg.num_heads = heads;
    cfg.embed_dim = heads * [4usize, 8, 12][rng.gen_range(0, 3)];
    cfg.num_blocks = rng.gen_range(1, 3);
    cfg.timesteps = rng.gen_range(1, 4);
    cfg.mlp_hidden = 16 * rng.gen_range(1, 4);
    cfg.attn_v_th = u32::try_from(rng.gen_range(1, 4)).unwrap();
    cfg.num_classes = rng.gen_range(2, 8);
    cfg.decoder = Some(DecoderShape { max_seq_len: rng.gen_range(8, 17) });
    cfg.validate().expect("random decoder config must validate");
    cfg
}

fn random_engine(rng: &mut Prng) -> EngineSelect {
    [EngineSelect::Csr, EngineSelect::Bitmap, EngineSelect::adaptive()][rng.gen_range(0, 3)]
}

#[test]
fn prop_incremental_decode_is_bit_identical_to_full_recompute() {
    check("decode: incremental == fresh replay == dense golden", 10, |rng| {
        let cfg = random_decoder_cfg(rng);
        let model = QuantizedModel::random(&cfg, rng.next_u64());
        let mut hw = AccelConfig::small();
        hw.engine = random_engine(rng);
        hw.validate().expect("hw config");
        let n = rng.gen_range(2, 6);
        let seq: Vec<usize> = (0..n).map(|_| rng.gen_range(0, cfg.vocab())).collect();

        let golden = GoldenDecoder::new(&model).expect("decoder model");
        let mut inc = DecodeSession::new(&model, &hw).expect("session");
        let mut last_words = 0u64;
        for p in 0..n {
            let logits = inc.step(&model, &hw, seq[p]).expect("step");
            prop_assert_eq!(inc.pos(), p + 1);

            // (1) dense full recompute of the whole prefix, every step.
            let dense = golden.run(&seq[..=p]).expect("golden run");
            prop_assert_eq!(&logits, &dense.logits[p]);

            // (2) a fresh session replaying the prefix: logits, cycles,
            // per-phase UnitStats and cache storage all bit-identical.
            let mut fresh = DecodeSession::new(&model, &hw).expect("fresh session");
            let replay = fresh.prefill(&model, &hw, &seq[..=p]).expect("replay");
            prop_assert_eq!(&logits, &replay);
            prop_assert_eq!(inc.cycles(), fresh.cycles());
            prop_assert_eq!(inc.cache_words(), fresh.cache_words());
            prop_assert_eq!(&inc.sink().phases.phases, &fresh.sink().phases.phases);

            // Cache storage can only grow as positions append.
            prop_assert!(inc.cache_words() >= last_words);
            last_words = inc.cache_words();
        }
        Ok(())
    });
}

#[test]
fn prop_every_engine_decodes_the_same_values() {
    check("decode: csr == bitmap == adaptive", 8, |rng| {
        let cfg = random_decoder_cfg(rng);
        let model = QuantizedModel::random(&cfg, rng.next_u64());
        let n = rng.gen_range(2, 6);
        let seq: Vec<usize> = (0..n).map(|_| rng.gen_range(0, cfg.vocab())).collect();
        let mut per_engine: Vec<Vec<Vec<f32>>> = Vec::new();
        for engine in [EngineSelect::Csr, EngineSelect::Bitmap, EngineSelect::adaptive()] {
            let mut hw = AccelConfig::small();
            hw.engine = engine;
            hw.validate().expect("hw config");
            let mut s = DecodeSession::new(&model, &hw).expect("session");
            let logits: Vec<Vec<f32>> =
                seq.iter().map(|&t| s.step(&model, &hw, t).expect("step")).collect();
            per_engine.push(logits);
        }
        prop_assert_eq!(&per_engine[0], &per_engine[1]);
        prop_assert_eq!(&per_engine[0], &per_engine[2]);
        Ok(())
    });
}

#[test]
fn session_reset_reuses_arenas_and_replays_bit_exactly() {
    let cfg = SdtModelConfig::tiny_decoder();
    let model = QuantizedModel::random(&cfg, 5);
    let hw = AccelConfig::small();
    let mut s = DecodeSession::new(&model, &hw).expect("session");
    let seq = [1usize, 4, 2, 0, 3];
    let first: Vec<Vec<f32>> =
        seq.iter().map(|&t| s.step(&model, &hw, t).expect("step")).collect();
    let cycles = s.cycles();
    let words = s.cache_words();
    s.reset();
    assert_eq!(s.pos(), 0);
    assert_eq!(s.cache_words(), 0);
    let again: Vec<Vec<f32>> =
        seq.iter().map(|&t| s.step(&model, &hw, t).expect("step")).collect();
    assert_eq!(first, again, "reset session must replay bit-exactly");
    assert_eq!(s.cycles(), cycles);
    assert_eq!(s.cache_words(), words, "arena reuse must not change modelled storage");
}

#[test]
fn kv_cache_length_equals_tokens_emitted_across_sessions() {
    // The structural invariant at the cache level: every (block,
    // timestep) lane holds exactly `tokens()` positions after each
    // `finish_token`, across reset/reuse cycles.
    let (blocks, timesteps, max_seq, d) = (2usize, 3usize, 6usize, 20usize);
    let mut cache = KvCache::new(blocks, timesteps, max_seq, d);
    let row = |chans: &[u16]| {
        let mut e = spikeformer_accel::spike::EncodedSpikes::empty(d, 1);
        for &c in chans {
            e.push(usize::from(c), 0);
        }
        e
    };
    for session in 0..2 {
        for tok in 0..max_seq {
            for b in 0..blocks {
                for t in 0..timesteps {
                    let k = row(&[1, 3 + u16::try_from(tok % 4).unwrap()]);
                    let v = row(&[0]);
                    cache.stream_mut(b, t).append_into(&k, &v);
                }
            }
            cache.finish_token().expect("lanes aligned");
            assert_eq!(cache.tokens(), tok + 1, "session {session}");
            for b in 0..blocks {
                for t in 0..timesteps {
                    assert_eq!(cache.stream(b, t).len(), cache.tokens());
                }
            }
        }
        cache.reset();
        assert_eq!(cache.tokens(), 0);
        assert_eq!(cache.storage_words(), 0);
    }

    // A lane left short is an invariant violation, not a silent skew.
    let mut bad = KvCache::new(1, 2, 4, d);
    bad.stream_mut(0, 0).append_into(&row(&[2]), &row(&[5]));
    let err = bad.finish_token().unwrap_err().to_string();
    assert!(err.contains("positions after token"), "unexpected error: {err}");
}
