//! Integration: the encoded-spike accelerator datapath against the dense
//! golden executor, across configurations, seeds and datapath modes.

use spikeformer_accel::accel::{Accelerator, DatapathMode};
use spikeformer_accel::hw::{AccelConfig, ResourceModel};
use spikeformer_accel::model::{GoldenExecutor, QuantizedModel, SdtModelConfig};
use spikeformer_accel::util::Prng;

fn random_image(seed: u64) -> Vec<f32> {
    let mut rng = Prng::new(seed);
    (0..3 * 32 * 32).map(|_| rng.next_f32_signed()).collect()
}

#[test]
fn bit_exact_vs_golden_many_seeds() {
    let cfg = SdtModelConfig::tiny();
    for model_seed in [1u64, 2, 3] {
        let model = QuantizedModel::random(&cfg, model_seed);
        let golden = GoldenExecutor::new(&model);
        let mut accel = Accelerator::new(model.clone(), AccelConfig::small());
        for img_seed in [10u64, 11, 12, 13] {
            let img = random_image(img_seed);
            let g = golden.infer(&img);
            let r = accel.infer(&img).unwrap();
            assert_eq!(r.logits, g.logits, "model {model_seed}, image {img_seed}");
        }
    }
}

#[test]
fn bit_exact_vs_golden_multiblock_config() {
    // A custom config with 2 blocks and more timesteps exercises LIF-state
    // carry and block chaining.
    let cfg = SdtModelConfig {
        name: "test2b".into(),
        timesteps: 3,
        num_blocks: 2,
        ..SdtModelConfig::tiny()
    };
    let model = QuantizedModel::random(&cfg, 5);
    let golden = GoldenExecutor::new(&model);
    let mut accel = Accelerator::new(model.clone(), AccelConfig::small());
    let img = random_image(20);
    assert_eq!(accel.infer(&img).unwrap().logits, golden.infer(&img).logits);
}

#[test]
fn sparsity_tables_match_golden() {
    let cfg = SdtModelConfig::tiny();
    let model = QuantizedModel::random(&cfg, 7);
    let golden = GoldenExecutor::new(&model);
    let mut accel = Accelerator::new(model.clone(), AccelConfig::small());
    let img = random_image(30);
    let g = golden.infer(&img);
    let r = accel.infer(&img).unwrap();
    for (name, s_accel) in &r.sparsity {
        if let Some((_, s_gold)) = g.sparsity.iter().find(|(n, _)| n == name) {
            assert!(
                (s_accel - s_gold).abs() < 1e-12,
                "sparsity mismatch for {name}: {s_accel} vs {s_gold}"
            );
        }
    }
}

#[test]
fn encoded_strictly_cheaper_than_bitmap_at_realistic_sparsity() {
    let cfg = SdtModelConfig::tiny();
    let model = QuantizedModel::random(&cfg, 9);
    let img = random_image(40);
    let mut enc = Accelerator::with_mode(model.clone(), AccelConfig::paper(), DatapathMode::Encoded);
    let mut bmp = Accelerator::with_mode(model, AccelConfig::paper(), DatapathMode::Bitmap);
    let r1 = enc.infer(&img).unwrap();
    let r2 = bmp.infer(&img).unwrap();
    assert_eq!(r1.logits, r2.logits);
    assert!(r2.total.cycles > r1.total.cycles);
    // the spike-consuming phases specifically must shrink
    for phase in ["sdeb.qkv", "sdeb.mlp", "sps.maxpool"] {
        assert!(
            r2.phases.get(phase).cycles >= r1.phases.get(phase).cycles,
            "phase {phase}"
        );
    }
}

#[test]
fn paper_scale_runs_and_reports() {
    let cfg = SdtModelConfig::paper();
    let model = QuantizedModel::random(&cfg, 42);
    let mut accel = Accelerator::new(model, AccelConfig::paper());
    let r = accel.infer(&random_image(1)).unwrap();
    assert_eq!(r.logits.len(), 10);
    assert!(r.total.cycles > 0);
    assert!(r.total.sops > 1_000_000, "paper-scale SDT should be >1M SOPs");
    assert!(r.gsops > 0.0 && r.gsops <= AccelConfig::paper().peak_gsops() + 1e-9);
    // Fig-6 modules present for both blocks
    for b in 0..2 {
        for site in ["q", "k", "v", "sdsa"] {
            assert!(
                r.sparsity.iter().any(|(n, _)| n == &format!("block{b}.{site}.spikes")),
                "missing block{b}.{site}"
            );
        }
    }
}

#[test]
fn lane_scaling_monotone() {
    let cfg = SdtModelConfig::tiny();
    let model = QuantizedModel::random(&cfg, 3);
    let img = random_image(2);
    let mut prev_cycles = u64::MAX;
    for lanes in [128usize, 512, 1536] {
        let mut accel = Accelerator::new(model.clone(), AccelConfig::with_lanes(lanes));
        let r = accel.infer(&img).unwrap();
        assert!(
            r.total.cycles <= prev_cycles,
            "more lanes must not be slower ({lanes} lanes)"
        );
        prev_cycles = r.total.cycles;
    }
}

#[test]
fn resource_estimate_matches_paper_at_operating_point() {
    let r = ResourceModel::default().estimate(&AccelConfig::paper());
    assert!((r.lut as f64 - 453_266.0).abs() / 453_266.0 < 0.02);
    assert_eq!(r.ff, 94_120);
    assert_eq!(r.bram, 784);
}
