//! Failure injection: corrupted artifacts, capacity violations, and
//! worker-failure behaviour must produce loud, actionable errors — never
//! silent mis-measurement.

use std::fs;
use std::time::{Duration, Instant};

use spikeformer_accel::accel::Accelerator;
use spikeformer_accel::coordinator::{
    BackendFactory, BatchPolicy, Coordinator, GoldenBackend, InferBackend, Request,
};
use spikeformer_accel::hw::AccelConfig;
use spikeformer_accel::io::{Manifest, NpyArray};
use spikeformer_accel::model::{load_checkpoint, load_model, QuantizedModel, SdtModelConfig};
use spikeformer_accel::util::Prng;

fn tmpdir(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("sfa_fi_{}_{}", std::process::id(), name));
    fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn corrupted_npy_payload_is_detected() {
    let d = tmpdir("npy");
    // valid-looking header, truncated payload
    let mut npy = b"\x93NUMPY\x01\x00".to_vec();
    let header = "{'descr': '<f4', 'fortran_order': False, 'shape': (100,), }\n";
    npy.extend((header.len() as u16).to_le_bytes());
    npy.extend(header.as_bytes());
    npy.extend([0u8; 16]); // 4 of 100 floats
    let p = d.join("bad.npy");
    fs::write(&p, &npy).unwrap();
    let err = NpyArray::load(&p).unwrap_err();
    assert!(format!("{err:#}").contains("too short"), "{err:#}");
    fs::remove_dir_all(&d).ok();
}

#[test]
fn manifest_shape_mismatch_is_detected() {
    let d = tmpdir("manifest");
    // file is [2], manifest claims [3]
    let mut npy = b"\x93NUMPY\x01\x00".to_vec();
    let header = "{'descr': '<f4', 'fortran_order': False, 'shape': (2,), }\n";
    npy.extend((header.len() as u16).to_le_bytes());
    npy.extend(header.as_bytes());
    npy.extend(1.0f32.to_le_bytes());
    npy.extend(2.0f32.to_le_bytes());
    fs::write(d.join("x.npy"), &npy).unwrap();
    fs::write(d.join("manifest.txt"), "x f32 1 3 x.npy\n").unwrap();
    let m = Manifest::load(&d).unwrap();
    let err = m.load_array("x").unwrap_err();
    assert!(err.to_string().contains("shape mismatch"), "{err}");
    fs::remove_dir_all(&d).ok();
}

#[test]
fn missing_weight_in_manifest_is_loud() {
    let d = tmpdir("missing");
    fs::write(d.join("manifest.txt"), "").unwrap();
    fs::write(d.join("config.txt"), "name tiny\nimg_size 32\nin_channels 3\nnum_classes 10\ntimesteps 2\nembed_dim 64\nnum_blocks 1\nnum_heads 1\nmlp_hidden 128\nattn_v_th 2\nlif_v_th 1.0\nlif_v_reset 0.0\nlif_gamma 0.5\n").unwrap();
    let err = load_model(&d).unwrap_err();
    assert!(format!("{err:#}").contains("not in manifest"), "{err:#}");
    fs::remove_dir_all(&d).ok();
}

#[test]
fn ess_capacity_violation_fails_inference() {
    // An accelerator config with absurdly small ESS must error, not
    // silently mis-count.
    let cfg = SdtModelConfig::tiny();
    let model = QuantizedModel::random(&cfg, 5);
    let mut hw = AccelConfig::small();
    hw.ess_banks = 1;
    hw.ess_bank_words = 8;
    let mut accel = Accelerator::new(model, hw);
    let mut rng = Prng::new(1);
    let img: Vec<f32> = (0..3 * 32 * 32).map(|_| rng.next_f32_signed()).collect();
    let err = accel.infer(&img).unwrap_err();
    assert!(format!("{err:#}").contains("overflow"), "{err:#}");
}

#[test]
fn checkpoint_garbage_rejected() {
    let d = tmpdir("ckpt");
    let p = d.join("garbage.bin");
    fs::write(&p, vec![0xAB; 256]).unwrap();
    assert!(load_checkpoint(&p).is_err());
    fs::remove_dir_all(&d).ok();
}

/// A backend that fails on every batch.
struct FailingBackend;

impl InferBackend for FailingBackend {
    fn name(&self) -> &'static str {
        "failing"
    }

    fn infer_batch(&mut self, _images: &[Vec<f32>]) -> anyhow::Result<Vec<Vec<f32>>> {
        anyhow::bail!("injected backend failure")
    }
}

#[test]
fn healthy_worker_carries_load_when_peer_fails() {
    // One failing worker + one healthy worker: requests routed to the
    // failing worker are lost (logged), but the healthy worker's results
    // are still correct and the coordinator does not deadlock on them.
    let cfg = SdtModelConfig::tiny();
    let model = QuantizedModel::random(&cfg, 6);
    let healthy: BackendFactory = {
        let m = model.clone();
        Box::new(move || Ok(Box::new(GoldenBackend::new(m)) as _))
    };
    // Single healthy worker, batch=1: all 4 requests must complete.
    let started = Instant::now();
    let mut co = Coordinator::new(
        vec![healthy],
        BatchPolicy { max_batch: 1, max_wait: Duration::ZERO },
    );
    let mut rng = Prng::new(2);
    for i in 0..4u64 {
        let img: Vec<f32> = (0..3 * 32 * 32).map(|_| rng.next_f32_signed()).collect();
        co.submit(Request { id: i, image: img });
    }
    let (responses, report) = co.finish(started).unwrap();
    assert_eq!(responses.len(), 4);
    assert_eq!(report.completed, 4);
}

#[test]
fn failing_backend_logs_and_does_not_panic() {
    // All-failing pool: finish() would wait forever for lost responses,
    // so this test exercises the worker error path directly.
    let mut b = FailingBackend;
    let err = b.infer_batch(&[vec![0.0; 4]]).unwrap_err();
    assert!(err.to_string().contains("injected"));
}
