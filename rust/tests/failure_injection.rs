//! Failure injection: corrupted artifacts, capacity violations, and
//! worker-failure behaviour must produce loud, actionable errors — never
//! silent mis-measurement.

use std::fs;
use std::time::{Duration, Instant};

use spikeformer_accel::accel::Accelerator;
use spikeformer_accel::coordinator::{
    BackendFactory, BatchPolicy, Coordinator, GoldenBackend, InferBackend, Outcome, Request,
};
use spikeformer_accel::hw::AccelConfig;
use spikeformer_accel::io::{Manifest, NpyArray};
use spikeformer_accel::model::{load_checkpoint, load_model, QuantizedModel, SdtModelConfig};
use spikeformer_accel::util::Prng;

fn tmpdir(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("sfa_fi_{}_{}", std::process::id(), name));
    fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn corrupted_npy_payload_is_detected() {
    let d = tmpdir("npy");
    // valid-looking header, truncated payload
    let mut npy = b"\x93NUMPY\x01\x00".to_vec();
    let header = "{'descr': '<f4', 'fortran_order': False, 'shape': (100,), }\n";
    npy.extend((header.len() as u16).to_le_bytes());
    npy.extend(header.as_bytes());
    npy.extend([0u8; 16]); // 4 of 100 floats
    let p = d.join("bad.npy");
    fs::write(&p, &npy).unwrap();
    let err = NpyArray::load(&p).unwrap_err();
    assert!(format!("{err:#}").contains("too short"), "{err:#}");
    fs::remove_dir_all(&d).ok();
}

#[test]
fn manifest_shape_mismatch_is_detected() {
    let d = tmpdir("manifest");
    // file is [2], manifest claims [3]
    let mut npy = b"\x93NUMPY\x01\x00".to_vec();
    let header = "{'descr': '<f4', 'fortran_order': False, 'shape': (2,), }\n";
    npy.extend((header.len() as u16).to_le_bytes());
    npy.extend(header.as_bytes());
    npy.extend(1.0f32.to_le_bytes());
    npy.extend(2.0f32.to_le_bytes());
    fs::write(d.join("x.npy"), &npy).unwrap();
    fs::write(d.join("manifest.txt"), "x f32 1 3 x.npy\n").unwrap();
    let m = Manifest::load(&d).unwrap();
    let err = m.load_array("x").unwrap_err();
    assert!(err.to_string().contains("shape mismatch"), "{err}");
    fs::remove_dir_all(&d).ok();
}

#[test]
fn missing_weight_in_manifest_is_loud() {
    let d = tmpdir("missing");
    fs::write(d.join("manifest.txt"), "").unwrap();
    fs::write(d.join("config.txt"), "name tiny\nimg_size 32\nin_channels 3\nnum_classes 10\ntimesteps 2\nembed_dim 64\nnum_blocks 1\nnum_heads 1\nmlp_hidden 128\nattn_v_th 2\nlif_v_th 1.0\nlif_v_reset 0.0\nlif_gamma 0.5\n").unwrap();
    let err = load_model(&d).unwrap_err();
    assert!(format!("{err:#}").contains("not in manifest"), "{err:#}");
    fs::remove_dir_all(&d).ok();
}

#[test]
fn ess_capacity_violation_fails_inference() {
    // An accelerator config with absurdly small ESS must error, not
    // silently mis-count.
    let cfg = SdtModelConfig::tiny();
    let model = QuantizedModel::random(&cfg, 5);
    let mut hw = AccelConfig::small();
    hw.ess_banks = 1;
    hw.ess_bank_words = 8;
    let mut accel = Accelerator::new(model, hw);
    let mut rng = Prng::new(1);
    let img: Vec<f32> = (0..3 * 32 * 32).map(|_| rng.next_f32_signed()).collect();
    let err = accel.infer(&img).unwrap_err();
    assert!(format!("{err:#}").contains("overflow"), "{err:#}");
}

#[test]
fn checkpoint_garbage_rejected() {
    let d = tmpdir("ckpt");
    let p = d.join("garbage.bin");
    fs::write(&p, vec![0xAB; 256]).unwrap();
    assert!(load_checkpoint(&p).is_err());
    fs::remove_dir_all(&d).ok();
}

/// A backend that fails on every batch.
struct FailingBackend;

impl InferBackend for FailingBackend {
    fn name(&self) -> &'static str {
        "failing"
    }

    fn infer_batch(&mut self, _images: &[Vec<f32>]) -> anyhow::Result<Vec<Vec<f32>>> {
        anyhow::bail!("injected backend failure")
    }
}

#[test]
fn healthy_worker_carries_load_when_peer_fails() {
    // One failing worker + one healthy worker: requests routed to the
    // failing worker come back as per-request `Outcome::Error` responses
    // (they are never silently lost), the healthy worker's results are
    // bit-correct, and `finish()` terminates.
    let cfg = SdtModelConfig::tiny();
    let model = QuantizedModel::random(&cfg, 6);
    let failing: BackendFactory = Box::new(|| Ok(Box::new(FailingBackend) as _));
    let healthy: BackendFactory = {
        let m = model.clone();
        Box::new(move || Ok(Box::new(GoldenBackend::new(m)) as _))
    };
    let started = Instant::now();
    let mut co = Coordinator::new(
        vec![failing, healthy],
        BatchPolicy { max_batch: 1, max_wait: Duration::ZERO },
    );
    let mut rng = Prng::new(2);
    let imgs: Vec<Vec<f32>> =
        (0..6).map(|_| (0..3 * 32 * 32).map(|_| rng.next_f32_signed()).collect()).collect();
    for (i, img) in imgs.iter().enumerate() {
        co.submit(Request::new(i as u64, img.clone()));
    }
    let (responses, report) = co.finish(started).unwrap();
    assert_eq!(responses.len(), 6, "every request gets a response");
    assert_eq!(report.completed + report.errors, 6);
    // The first dispatch goes to the (first-listed, equally-idle) failing
    // worker, so at least one per-request error must surface.
    assert!(report.errors >= 1, "failing worker's requests surface as errors");
    let mut serial = GoldenBackend::new(model);
    for resp in &responses {
        match &resp.outcome {
            Outcome::Ok => {
                let want = InferBackend::infer_batch(
                    &mut serial,
                    std::slice::from_ref(&imgs[usize::try_from(resp.id).unwrap()]),
                )
                .unwrap();
                assert_eq!(resp.logits, want[0], "healthy response {} wrong", resp.id);
            }
            Outcome::Error(msg) => {
                assert!(msg.contains("injected"), "error carries the backend text: {msg}")
            }
            Outcome::Shed => panic!("nothing should be shed here"),
        }
    }
}

#[test]
fn all_failing_pool_reports_errors_without_hanging() {
    // Every worker fails on every batch: `finish()` must still terminate
    // with one `Outcome::Error` response per request (this used to hang
    // forever waiting for responses that never came).
    let started = Instant::now();
    let mut co = Coordinator::new(
        vec![
            Box::new(|| Ok(Box::new(FailingBackend) as _)) as BackendFactory,
            Box::new(|| Ok(Box::new(FailingBackend) as _)) as BackendFactory,
        ],
        BatchPolicy { max_batch: 2, max_wait: Duration::ZERO },
    );
    for i in 0..5u64 {
        co.submit(Request::new(i, vec![0.1; 3 * 32 * 32]));
    }
    let (responses, report) = co.finish(started).unwrap();
    assert_eq!(responses.len(), 5);
    assert_eq!(report.errors, 5);
    assert_eq!(report.completed, 0);
    assert!(responses.iter().all(|r| matches!(&r.outcome, Outcome::Error(m) if m.contains("injected"))));
}

#[test]
fn backend_construction_failure_fails_finish_loudly() {
    // A worker whose backend factory errors answers its traffic with
    // per-request errors and then makes `finish()` return `Err` so the
    // deployment failure cannot be mistaken for a healthy run.
    let broken: BackendFactory = Box::new(|| anyhow::bail!("no such device"));
    let started = Instant::now();
    let mut co = Coordinator::new(
        vec![broken],
        BatchPolicy { max_batch: 1, max_wait: Duration::ZERO },
    );
    for i in 0..3u64 {
        co.submit(Request::new(i, vec![0.0; 3 * 32 * 32]));
    }
    let err = co.finish(started).unwrap_err();
    assert!(
        format!("{err:#}").contains("no such device"),
        "factory error must propagate: {err:#}"
    );
}
