//! Steady-state runtime invariants: the persistent worker pool, the
//! recycled scratch pools and the batched forward change *host*
//! performance only. Logits, `UnitStats`, phase breakdowns and executed
//! pipeline schedules must be bit-identical to a fresh accelerator
//! running one request per call — the classic reuse bug this guards
//! against is a stale arena or membrane leaking into the next inference.

use spikeformer_accel::accel::{Accelerator, DatapathMode, ExecMode};
use spikeformer_accel::hw::AccelConfig;
use spikeformer_accel::model::{QuantizedModel, SdtModelConfig};
use spikeformer_accel::util::Prng;

fn random_image(rng: &mut Prng) -> Vec<f32> {
    (0..3 * 32 * 32).map(|_| rng.next_f32_signed()).collect()
}

/// A config that exercises head sharding (8 heads over 2 SDEB cores) and
/// odd timestep parity, at test-friendly scale.
fn sharded_cfg() -> SdtModelConfig {
    SdtModelConfig {
        name: "steady-test".into(),
        timesteps: 3,
        num_blocks: 2,
        num_heads: 8,
        ..SdtModelConfig::tiny()
    }
}

/// Every report field the steady-state work must not perturb.
fn assert_reports_identical(
    got: &spikeformer_accel::accel::RunReport,
    want: &spikeformer_accel::accel::RunReport,
    ctx: &str,
) {
    assert_eq!(got.logits, want.logits, "{ctx}: logits");
    assert_eq!(got.total, want.total, "{ctx}: total UnitStats");
    assert_eq!(got.phases.phases, want.phases.phases, "{ctx}: phase breakdown");
    assert_eq!(got.wall_cycles(), want.wall_cycles(), "{ctx}: wall cycles");
    match (&got.pipeline, &want.pipeline) {
        (Some(a), Some(b)) => {
            assert_eq!(a.sps_per_timestep, b.sps_per_timestep, "{ctx}: sps trace");
            assert_eq!(a.sdeb_per_timestep, b.sdeb_per_timestep, "{ctx}: sdeb trace");
            assert_eq!(a.executed_cycles, b.executed_cycles, "{ctx}: executed cycles");
            assert_eq!(a.serialized_cycles, b.serialized_cycles, "{ctx}: serialized cycles");
        }
        (None, None) => {}
        _ => panic!("{ctx}: pipeline record presence differs"),
    }
}

/// Satellite: randomized request sequences through ONE pooled accelerator
/// must be bit-identical to a fresh accelerator per request.
#[test]
fn pooled_accelerator_matches_fresh_per_request() {
    for cfg in [SdtModelConfig::tiny(), sharded_cfg()] {
        for seed in [1u64, 9] {
            let model = QuantizedModel::random(&cfg, seed);
            let mut rng = Prng::new(seed * 101 + 7);
            let mut pooled = Accelerator::new(model.clone(), AccelConfig::small());
            for req in 0..6 {
                let img = random_image(&mut rng);
                let warm = pooled.infer(&img).unwrap();
                let mut fresh = Accelerator::new(model.clone(), AccelConfig::small());
                let cold = fresh.infer(&img).unwrap();
                assert_reports_identical(
                    &warm,
                    &cold,
                    &format!("cfg {} seed {seed} req {req}", cfg.name),
                );
            }
        }
    }
}

/// Serial-mode (no overlap) scratch reuse must be just as invisible.
#[test]
fn pooled_serial_mode_matches_fresh_per_request() {
    let cfg = sharded_cfg();
    let model = QuantizedModel::random(&cfg, 4);
    let mut rng = Prng::new(31);
    let mut pooled = Accelerator::with_modes(
        model.clone(),
        AccelConfig::small(),
        DatapathMode::Encoded,
        ExecMode::Serial,
    );
    for req in 0..4 {
        let img = random_image(&mut rng);
        let warm = pooled.infer(&img).unwrap();
        let mut fresh = Accelerator::with_modes(
            model.clone(),
            AccelConfig::small(),
            DatapathMode::Encoded,
            ExecMode::Serial,
        );
        let cold = fresh.infer(&img).unwrap();
        assert_reports_identical(&warm, &cold, &format!("serial req {req}"));
    }
}

/// The batched forward (block-major weight reuse) must produce per-image
/// reports bit-identical to the per-call path — including the executed
/// pipeline schedule.
#[test]
fn batched_forward_matches_per_call_reports() {
    let cfg = sharded_cfg();
    let model = QuantizedModel::random(&cfg, 3);
    let mut rng = Prng::new(5);
    let imgs: Vec<Vec<f32>> = (0..5).map(|_| random_image(&mut rng)).collect();
    let mut batched = Accelerator::new(model.clone(), AccelConfig::small());
    let reports = batched.infer_batch(&imgs).unwrap();
    assert_eq!(reports.len(), imgs.len());
    let mut per_call = Accelerator::new(model, AccelConfig::small());
    for (i, img) in imgs.iter().enumerate() {
        let want = per_call.infer(img).unwrap();
        assert_reports_identical(&reports[i], &want, &format!("batched req {i}"));
    }
}

/// Randomized mixed batch sizes through one pooled accelerator (the
/// serving pattern: whatever the dynamic batcher released) stay
/// bit-identical to fresh per-request accelerators.
#[test]
fn randomized_mixed_batches_match_fresh_accelerators() {
    let cfg = SdtModelConfig::tiny();
    let model = QuantizedModel::random(&cfg, 23);
    let mut rng = Prng::new(77);
    let mut pooled = Accelerator::new(model.clone(), AccelConfig::small());
    for round in 0..4 {
        let batch = rng.gen_range(1, 5);
        let imgs: Vec<Vec<f32>> = (0..batch).map(|_| random_image(&mut rng)).collect();
        let reports = pooled.infer_batch(&imgs).unwrap();
        for (i, img) in imgs.iter().enumerate() {
            let mut fresh = Accelerator::new(model.clone(), AccelConfig::small());
            let want = fresh.infer(img).unwrap();
            assert_reports_identical(&reports[i], &want, &format!("round {round} req {i}"));
        }
    }
}

/// The steady-state claim itself: after warm-up, per-call inference takes
/// every arena/tensor from the scratch pools (zero new allocations).
#[test]
fn warm_inference_performs_no_scratch_allocations() {
    let cfg = sharded_cfg();
    let model = QuantizedModel::random(&cfg, 5);
    let mut accel = Accelerator::new(model, AccelConfig::small());
    let mut rng = Prng::new(9);
    // Two warm-up requests: the first populates the pools, the second
    // confirms the live-set converged.
    accel.infer(&random_image(&mut rng)).unwrap();
    accel.infer(&random_image(&mut rng)).unwrap();
    let warm = accel.scratch_stats();
    let warm_objects = accel.pooled_scratch_objects();
    for _ in 0..3 {
        accel.infer(&random_image(&mut rng)).unwrap();
    }
    let after = accel.scratch_stats();
    assert_eq!(
        after.misses, warm.misses,
        "steady-state inference must not allocate new scratch objects"
    );
    assert_eq!(
        accel.pooled_scratch_objects(),
        warm_objects,
        "free lists must stay a constant size (no put/take leak)"
    );
    assert!(after.hits > warm.hits, "steady-state inference must hit the scratch pools");
    assert!(after.hit_rate() > 0.9, "hit rate {:.4} too low after warm-up", after.hit_rate());
}

/// The bitmap-mode ablation datapath must keep the same take/put balance
/// as the encoded path: the free lists stay a constant size across warm
/// requests (growth means dense-baseline outputs leak into the pools).
#[test]
fn bitmap_mode_scratch_pools_stay_balanced() {
    let cfg = sharded_cfg();
    let model = QuantizedModel::random(&cfg, 14);
    let mut accel =
        Accelerator::with_mode(model, AccelConfig::small(), DatapathMode::Bitmap);
    let mut rng = Prng::new(41);
    accel.infer(&random_image(&mut rng)).unwrap();
    accel.infer(&random_image(&mut rng)).unwrap();
    let warm_objects = accel.pooled_scratch_objects();
    let warm = accel.scratch_stats();
    for _ in 0..3 {
        accel.infer(&random_image(&mut rng)).unwrap();
    }
    assert_eq!(
        accel.pooled_scratch_objects(),
        warm_objects,
        "bitmap-mode free lists must not grow across warm requests"
    );
    assert_eq!(accel.scratch_stats().misses, warm.misses);
}

/// Same claim for the batched path at a fixed batch size.
#[test]
fn warm_batched_inference_performs_no_scratch_allocations() {
    let cfg = sharded_cfg();
    let model = QuantizedModel::random(&cfg, 6);
    let mut accel = Accelerator::new(model, AccelConfig::small());
    let mut rng = Prng::new(13);
    let batch = |rng: &mut Prng| -> Vec<Vec<f32>> { (0..4).map(|_| random_image(rng)).collect() };
    accel.infer_batch(&batch(&mut rng)).unwrap();
    accel.infer_batch(&batch(&mut rng)).unwrap();
    let warm = accel.scratch_stats();
    for _ in 0..3 {
        accel.infer_batch(&batch(&mut rng)).unwrap();
    }
    let after = accel.scratch_stats();
    assert_eq!(
        after.misses, warm.misses,
        "steady-state batched inference must not allocate new scratch objects"
    );
    assert!(after.hits > warm.hits);
}

/// Pool sizing must not change results (oversized, undersized, default).
#[test]
fn pool_size_does_not_change_results() {
    let cfg = sharded_cfg();
    let model = QuantizedModel::random(&cfg, 8);
    let mut rng = Prng::new(21);
    let img = random_image(&mut rng);
    let mut base = Accelerator::new(model.clone(), AccelConfig::small());
    let want = base.infer(&img).unwrap();
    for workers in [1usize, 3, 8] {
        let mut accel =
            Accelerator::new(model.clone(), AccelConfig::small()).with_pool_workers(workers);
        let got = accel.infer(&img).unwrap();
        assert_reports_identical(&got, &want, &format!("workers {workers}"));
    }
}
