//! Integration: the PJRT runtime against the AOT artifacts — batch
//! consistency, SDSA kernel equivalence with the rust SMAM, and agreement
//! between the float JAX model and the quantized pipeline.

use std::path::Path;

use spikeformer_accel::hw::AccelConfig;
use spikeformer_accel::model::{load_model, loader::load_test_split, GoldenExecutor};
use spikeformer_accel::runtime::PjrtRuntime;
use spikeformer_accel::spike::{EncodedSpikes, SpikeMatrix};
use spikeformer_accel::units::SpikeMaskAddModule;
use spikeformer_accel::util::Prng;

fn artifacts() -> Option<&'static Path> {
    let dir = Path::new("artifacts");
    dir.join("model.hlo.txt").exists().then_some(dir)
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0
}

#[test]
fn batch1_and_batch8_hlo_agree() {
    let Some(dir) = artifacts() else { return };
    if !dir.join("model_b8.hlo.txt").exists() {
        return;
    }
    let rt = PjrtRuntime::cpu().unwrap();
    let b1 = rt.load_hlo(&dir.join("model.hlo.txt")).unwrap();
    let b8 = rt.load_hlo(&dir.join("model_b8.hlo.txt")).unwrap();
    let mut rng = Prng::new(33);
    let imgs: Vec<f32> = (0..8 * 3 * 32 * 32).map(|_| rng.next_f32_signed()).collect();
    let o8 = b8.run_f32(&[(&imgs, &[8, 3, 32, 32])]).unwrap();
    for i in 0..8 {
        let img = &imgs[i * 3 * 32 * 32..(i + 1) * 3 * 32 * 32];
        let o1 = b1.run_f32(&[(img, &[1, 3, 32, 32])]).unwrap();
        for (a, b) in o1[0].iter().zip(&o8[0][i * 10..(i + 1) * 10]) {
            assert!((a - b).abs() < 1e-4, "image {i}: {a} vs {b}");
        }
    }
}

#[test]
fn sdsa_hlo_equals_rust_smam_on_random_spikes() {
    // The L1 Pallas kernel (through AOT + PJRT) and the L3 SMAM must
    // implement the same SDSA semantics.
    let Some(dir) = artifacts() else { return };
    let rt = PjrtRuntime::cpu().unwrap();
    let sdsa = rt.load_hlo(&dir.join("sdsa.hlo.txt")).unwrap();
    let (l, c) = (64usize, 64usize);
    let mut rng = Prng::new(44);
    for trial in 0..5 {
        // random binary spike matrices, token-major [L, C] f32 for the HLO
        let mut q_lc = vec![0f32; l * c];
        let mut k_lc = vec![0f32; l * c];
        let mut v_lc = vec![0f32; l * c];
        let mut qm = SpikeMatrix::zeros(c, l);
        let mut km = SpikeMatrix::zeros(c, l);
        let mut vm = SpikeMatrix::zeros(c, l);
        for tok in 0..l {
            for ch in 0..c {
                if rng.bernoulli(0.2) {
                    q_lc[tok * c + ch] = 1.0;
                    qm.set(ch, tok, true);
                }
                if rng.bernoulli(0.2) {
                    k_lc[tok * c + ch] = 1.0;
                    km.set(ch, tok, true);
                }
                if rng.bernoulli(0.2) {
                    v_lc[tok * c + ch] = 1.0;
                    vm.set(ch, tok, true);
                }
            }
        }
        let hlo_out =
            sdsa.run_f32(&[(&q_lc, &[l, c]), (&k_lc, &[l, c]), (&v_lc, &[l, c])]).unwrap();

        let smam = SpikeMaskAddModule::new(2); // tiny config attn_v_th
        let (out, _) = smam.run(
            &EncodedSpikes::from_bitmap(&qm),
            &EncodedSpikes::from_bitmap(&km),
            &EncodedSpikes::from_bitmap(&vm),
            &AccelConfig::small(),
        );
        let got = out.masked_v.to_bitmap();
        for tok in 0..l {
            for ch in 0..c {
                let want = hlo_out[0][tok * c + ch] != 0.0;
                assert_eq!(got.get(ch, tok), want, "trial {trial} tok {tok} ch {ch}");
            }
        }
    }
}

#[test]
fn float_and_quantized_predictions_agree_on_test_split() {
    let Some(dir) = artifacts() else { return };
    let wdir = Path::new("artifacts/weights");
    if !wdir.join("manifest.txt").exists() {
        return;
    }
    let rt = PjrtRuntime::cpu().unwrap();
    let float_model = rt.load_hlo(&dir.join("model.hlo.txt")).unwrap();
    let model = load_model(wdir).unwrap();
    let golden = GoldenExecutor::new(&model);
    let (imgs, shape, _) = load_test_split(wdir).unwrap();
    let img_len = shape[1] * shape[2] * shape[3];
    let n = shape[0].min(24);
    let mut agree = 0;
    for i in 0..n {
        let img = &imgs[i * img_len..(i + 1) * img_len];
        let f = float_model.run_f32(&[(img, &[1, 3, 32, 32])]).unwrap();
        let q = golden.infer(img);
        agree += (argmax(&f[0]) == argmax(&q.logits)) as usize;
    }
    assert!(
        agree as f64 / n as f64 >= 0.9,
        "float/quantized agreement too low: {agree}/{n}"
    );
}
