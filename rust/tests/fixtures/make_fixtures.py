#!/usr/bin/env python3
"""Regenerate the golden SDSA fixtures (q/k/v spike planes and the expected
mask / accumulator / masked-V outputs) as .npy files.

Pure stdlib on purpose — the npy v1.0 container is hand-assembled so the
script runs in any environment, and the expected outputs are computed by an
independent reference implementation of the SDSA semantics (per-channel
Q∩K popcount, threshold mask, V pass-through), not by the Rust code under
test. The Rust snapshot test (tests/golden_sdsa.rs) locks both engines to
these bytes.

Usage:  python3 rust/tests/fixtures/make_fixtures.py
"""

import os
import struct

C, L = 32, 70  # 70 tokens spans a u64 word boundary in the bitmap engine
V_TH = 6  # chosen so the golden mask has both fired and cleared channels
DENSITY_PCT = 30  # per-position spike probability, percent
SEED = 0x5EED_CAFE


def lcg(state):
    while True:
        state = (state * 6364136223846793005 + 1442695040888963407) % (1 << 64)
        yield state >> 33


def npy_bytes(descr, shape, payload):
    header = "{'descr': '%s', 'fortran_order': False, 'shape': %s, }" % (
        descr,
        "(" + ", ".join(str(d) for d in shape) + ("," if len(shape) == 1 else "") + ")",
    )
    total = 10 + len(header) + 1
    header += " " * ((64 - total % 64) % 64) + "\n"
    return b"\x93NUMPY\x01\x00" + struct.pack("<H", len(header)) + header.encode() + payload


def write(path, descr, shape, payload):
    with open(path, "wb") as f:
        f.write(npy_bytes(descr, shape, payload))
    print("wrote %s (%s %s)" % (path, descr, shape))


def main():
    rng = lcg(SEED)
    planes = {}
    for name in ("q", "k", "v"):
        planes[name] = [[1 if next(rng) % 100 < DENSITY_PCT else 0 for _ in range(L)] for _ in range(C)]

    # Reference SDSA: acc[c] = |Q[c] ∩ K[c]|, mask[c] = acc[c] >= V_TH,
    # masked_v[c] = V[c] when masked else zeros.
    acc = [sum(planes["q"][c][l] & planes["k"][c][l] for l in range(L)) for c in range(C)]
    mask = [1 if a >= V_TH else 0 for a in acc]
    masked_v = [[planes["v"][c][l] if mask[c] else 0 for l in range(L)] for c in range(C)]

    here = os.path.dirname(os.path.abspath(__file__))
    for name in ("q", "k", "v"):
        flat = bytes(b for row in planes[name] for b in row)
        write(os.path.join(here, "sdsa_%s.npy" % name), "|u1", (C, L), flat)
    write(os.path.join(here, "sdsa_mask.npy"), "|u1", (C,), bytes(mask))
    write(
        os.path.join(here, "sdsa_acc.npy"),
        "<i4",
        (C,),
        b"".join(struct.pack("<i", a) for a in acc),
    )
    write(
        os.path.join(here, "sdsa_masked_v.npy"),
        "|u1",
        (C, L),
        bytes(b for row in masked_v for b in row),
    )


if __name__ == "__main__":
    main()
