//! Differential test harness for the dual-engine datapath: for random
//! shapes and densities — including all-zero, single-spike and fully-dense
//! inputs — the CSR address-stream engine, the packed-`u64` bitmap engine
//! and the dense reference must produce bit-identical outputs for the
//! SLU, the SMU and the SMAM; and a full inference under
//! `EngineSelect::Adaptive` must produce the same logits as pure CSR on
//! random topologies.

use spikeformer_accel::accel::{Accelerator, Mapper, MappingPolicy};
use spikeformer_accel::hw::{AccelConfig, CoreTopology, EngineSelect};
use spikeformer_accel::model::{QuantizedModel, SdtModelConfig};
use spikeformer_accel::quant::QuantizedLinear;
use spikeformer_accel::scratch::ExecScratch;
use spikeformer_accel::spike::{EncodedSpikes, PackedBitmap, SpikeMatrix, TokenGrid};
use spikeformer_accel::units::{
    slu::dense_reference, SpikeLinearUnit, SpikeMaskAddModule, SpikeMaxpoolUnit,
};
use spikeformer_accel::util::{proptest::check, Prng};
use spikeformer_accel::{prop_assert, prop_assert_eq};

fn random_encoded(rng: &mut Prng, c: usize, l: usize, p: f64) -> EncodedSpikes {
    let mut m = SpikeMatrix::zeros(c, l);
    for ci in 0..c {
        for li in 0..l {
            if rng.bernoulli(p) {
                m.set(ci, li, true);
            }
        }
    }
    EncodedSpikes::from_bitmap(&m)
}

/// The density grid every property sweeps: the two degenerate extremes
/// plus a random interior point drawn per case.
fn density(rng: &mut Prng, case: usize) -> f64 {
    match case % 4 {
        0 => 0.0,             // all-zero
        1 => 1.0,             // fully dense
        2 => rng.next_f64(),  // random interior
        _ => 0.02,            // around the adaptive default threshold
    }
}

/// A single-spike tensor: exactly one set bit at a random position.
fn single_spike(rng: &mut Prng, c: usize, l: usize) -> EncodedSpikes {
    let mut m = SpikeMatrix::zeros(c, l);
    m.set(rng.gen_range(0, c), rng.gen_range(0, l), true);
    EncodedSpikes::from_bitmap(&m)
}

#[test]
fn prop_slu_engines_and_dense_reference_agree() {
    check("slu: csr == bitmap == dense", 60, |rng| {
        let c_in = rng.gen_range(1, 96);
        let c_out = rng.gen_range(1, 48);
        let l = rng.gen_range(1, 140);
        let x = if rng.bernoulli(0.15) {
            single_spike(rng, c_in, l)
        } else {
            let p = density(rng, rng.gen_range(0, 4));
            random_encoded(rng, c_in, l, p)
        };
        let w: Vec<f32> = (0..c_in * c_out).map(|_| rng.next_f32_signed()).collect();
        let b: Vec<f32> = (0..c_out).map(|_| rng.next_f32_signed()).collect();
        let layer = QuantizedLinear::from_f32(&w, &b, c_in, c_out, 0);
        let hw = AccelConfig::with_lanes([16, 64, 1536][rng.gen_range(0, 3)]);

        let mut slu_csr = SpikeLinearUnit::new();
        let (out_csr, s_csr) = slu_csr.forward(&x, &layer, &hw);
        let mut slu_bm = SpikeLinearUnit::new();
        let packed = PackedBitmap::from_encoded(&x);
        let (out_bm, s_bm) = slu_bm.forward_bitmap(&packed, &layer, &hw);

        prop_assert_eq!(&out_csr, &out_bm);
        // Same accumulation order, so the saturation telemetry matches too.
        prop_assert_eq!(slu_csr.sat.saturations, slu_bm.sat.saturations);
        // Workload stats are engine-independent; only cost fields differ.
        prop_assert_eq!(s_csr.sops, s_bm.sops);
        prop_assert_eq!(s_csr.adds, s_bm.adds);

        let want = dense_reference(&x, &layer);
        let sat = saturate_reference(&want, &layer);
        prop_assert_eq!(&out_csr.data, &sat);
        Ok(())
    });
}

/// Saturate a dense i64 accumulator exactly as the SLU output stage does.
fn saturate_reference(acc: &[i64], layer: &QuantizedLinear) -> Vec<i32> {
    use spikeformer_accel::quant::{rshift_round, sat, ACT_FRAC, MEM_BITS};
    acc.iter()
        .map(|&a| sat(rshift_round(a, layer.acc_frac() - ACT_FRAC), MEM_BITS))
        .collect()
}

#[test]
fn prop_smu_engines_and_dense_baseline_agree() {
    check("smu: csr == bitmap == dense", 60, |rng| {
        let h = rng.gen_range(2, 14);
        let w = rng.gen_range(2, 14);
        let kernel = rng.gen_range(1, 4.min(h.min(w)) + 1);
        let stride = rng.gen_range(1, kernel + 1);
        let grid = TokenGrid::new(h, w);
        let channels = rng.gen_range(1, 10);
        let enc = if rng.bernoulli(0.15) {
            single_spike(rng, channels, grid.tokens())
        } else {
            let p = density(rng, rng.gen_range(0, 4));
            random_encoded(rng, channels, grid.tokens(), p)
        };
        let smu = SpikeMaxpoolUnit::new(kernel, stride);
        let hw = AccelConfig::with_lanes([16, 256][rng.gen_range(0, 2)]);
        let mut scratch = ExecScratch::new();

        let (out_csr, _) = smu.pool(&enc, grid, &hw);
        let packed = PackedBitmap::from_encoded(&enc);
        let (out_bm, _) = smu.pool_bitmap_into(&packed, grid, &hw, &mut scratch);
        let (out_dense, _) = smu.pool_dense_baseline(&enc, grid, &hw);

        prop_assert_eq!(&out_csr, &out_bm);
        prop_assert_eq!(&out_csr, &out_dense);
        prop_assert!(out_bm.is_well_formed(), "bitmap engine emitted malformed encoding");
        Ok(())
    });
}

#[test]
fn prop_smam_engines_and_dense_baseline_agree() {
    check("smam: csr == bitmap == adaptive == dense", 50, |rng| {
        let c = rng.gen_range(1, 48);
        let l = rng.gen_range(1, 200);
        let v_th = rng.gen_range(0, 5) as u32;
        let mk = |rng: &mut Prng| {
            if rng.bernoulli(0.1) {
                single_spike(rng, c, l)
            } else {
                let p = density(rng, rng.gen_range(0, 4));
                random_encoded(rng, c, l, p)
            }
        };
        let q = mk(rng);
        let k = mk(rng);
        let v = mk(rng);
        let smam = SpikeMaskAddModule::new(v_th);
        let mut hw = AccelConfig::with_lanes([16, 1536][rng.gen_range(0, 2)]);
        let cores = rng.gen_range(1, 5);
        let policy = MappingPolicy::ALL[rng.gen_range(0, 3)];
        let mapper = Mapper::new(
            rng.gen_range(1, 9),
            CoreTopology::with_sdeb_cores(cores),
            policy,
        );
        let mut scratch = ExecScratch::new();

        let (want, _) = smam.run(&q, &k, &v, &hw);
        let (dense, _) = smam.run_dense_baseline(&q, &k, &v, &hw);
        prop_assert_eq!(&want.mask, &dense.mask);
        prop_assert_eq!(&want.acc, &dense.acc);
        prop_assert_eq!(&want.masked_v, &dense.masked_v);

        for engine in [
            EngineSelect::Bitmap,
            EngineSelect::Adaptive { threshold: rng.next_f64() },
        ] {
            hw.engine = engine;
            let (got, _) =
                smam.run_mapped_into(&q, &k, &v, &hw, &mapper, 0, None, &mut scratch);
            prop_assert_eq!(&want.mask, &got.mask);
            prop_assert_eq!(&want.acc, &got.acc);
            prop_assert_eq!(&want.masked_v, &got.masked_v);
        }
        Ok(())
    });
}

#[test]
fn prop_adaptive_inference_matches_csr_on_random_topologies() {
    let cfg = SdtModelConfig::tiny();
    let model = QuantizedModel::random(&cfg, 17);
    check("e2e: adaptive logits == csr logits", 6, |rng| {
        let img: Vec<f32> = (0..3 * 32 * 32).map(|_| rng.next_f32_signed()).collect();
        let cores = rng.gen_range(1, 4);
        let policy = MappingPolicy::ALL[rng.gen_range(0, 3)];
        let threshold = rng.next_f64();
        let run = |engine: EngineSelect, img: &[f32]| {
            let mut hw = AccelConfig::small();
            hw.topology = CoreTopology::with_sdeb_cores(cores);
            hw.engine = engine;
            hw.validate().unwrap();
            let mut accel =
                Accelerator::new(model.clone(), hw).with_mapping(policy);
            accel.infer(img).unwrap()
        };
        let base = run(EngineSelect::Csr, &img);
        for engine in [EngineSelect::Bitmap, EngineSelect::Adaptive { threshold }] {
            let r = run(engine, &img);
            prop_assert_eq!(&base.logits, &r.logits);
        }
        Ok(())
    });
}
