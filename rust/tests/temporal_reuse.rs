//! Integration: temporal reuse — delta-encoded spike streams and the
//! weight-resident timestep schedule against the PR 5 memory system.
//!
//! Both halves of the temporal-reuse path are *accounting and schedule*
//! changes, never value paths, so the suite pins four invariances:
//!
//! 1. identical consecutive frames produce exactly zero delta traffic
//!    (the kernels, the counting pass, and the per-channel plan agree);
//! 2. `--temporal-delta` is bit-exact: logits, phase breakdown, unit
//!    stats, and the wall schedule are identical flag on vs off, across
//!    both PR 7 engines and random topologies — only the ESS store
//!    charge (moved words) may shrink;
//! 3. at the paper point (16 B/cycle, two-core topology, T = 4) the
//!    delta path streams strictly fewer bytes per inference than the
//!    PR 5 full-restore baseline;
//! 4. the weight-resident schedule never regresses: wall cycles are
//!    `<=` the PR 5 stream-per-use schedule at every bandwidth on the
//!    ladder, and stay monotone non-increasing in bandwidth.

use spikeformer_accel::accel::{Accelerator, DmaEngine, PipelineExecution};
use spikeformer_accel::hw::{AccelConfig, CoreTopology, EngineSelect};
use spikeformer_accel::model::{GoldenExecutor, QuantizedModel, SdtModelConfig};
use spikeformer_accel::spike::{delta, EncodedSpikes, PackedBitmap, SpikeMatrix};
use spikeformer_accel::util::Prng;

fn random_image(seed: u64) -> Vec<f32> {
    let mut rng = Prng::new(seed);
    (0..3 * 32 * 32).map(|_| rng.next_f32_signed()).collect()
}

/// Multi-block, multi-head config at test scale (mirrors the memory
/// suite's sharded config; 3 timesteps so the delta path sees frames
/// with and without a predecessor).
fn sharded_cfg() -> SdtModelConfig {
    SdtModelConfig {
        name: "temporal-test".into(),
        timesteps: 3,
        num_blocks: 2,
        num_heads: 8,
        ..SdtModelConfig::tiny()
    }
}

/// A random paper-shaped spike frame (the SDEB input tensor shape).
fn random_frame(rng: &mut Prng, channels: usize, tokens: usize, p: f64) -> EncodedSpikes {
    let mut m = SpikeMatrix::zeros(channels, tokens);
    for c in 0..channels {
        for l in 0..tokens {
            if rng.bernoulli(p) {
                m.set(c, l, true);
            }
        }
    }
    EncodedSpikes::from_bitmap(&m)
}

#[test]
fn identical_consecutive_frames_move_zero_delta_traffic() {
    // The ISSUE acceptance at kernel granularity, at the paper tensor
    // shape: a frame diffed against itself ships nothing — no changed
    // addresses, no segment headers, an empty materialized delta from
    // both engines — while the full re-store it replaces is nonzero.
    let mut rng = Prng::new(71);
    let frame = random_frame(&mut rng, 384, 64, 0.1);
    let bm = PackedBitmap::from_encoded(&frame);
    assert!(frame.storage_words() > 0, "a dense-ish frame must cost a full re-store");
    assert_eq!(delta::moved_words(&bm, &bm, &frame), 0);
    for c in 0..frame.channels {
        assert_eq!(delta::channel_delta_words(&bm, &bm, c), 0, "channel {c}");
    }
    let mut via_xor = EncodedSpikes::empty(384, 64);
    delta::xor_delta_into(&bm, &bm, &mut via_xor);
    assert_eq!(via_xor.count_spikes(), 0, "the XOR kernel must emit nothing");
    let mut via_csr = EncodedSpikes::empty(384, 64);
    delta::csr_delta_into(&frame, &frame, &mut via_csr);
    assert_eq!(via_csr.count_spikes(), 0, "the CSR kernel must emit nothing");
}

#[test]
fn delta_flag_is_bit_exact_across_engines() {
    let cfg = sharded_cfg();
    let model = QuantizedModel::random(&cfg, 73);
    let img = random_image(79);
    let golden = GoldenExecutor::new(&model).infer(&img);
    for engine in [EngineSelect::Csr, EngineSelect::Bitmap, EngineSelect::adaptive()] {
        let mut hw = AccelConfig::small();
        hw.engine = engine;
        let mut off = Accelerator::new(model.clone(), hw);
        let r_off = off.infer(&img).unwrap();
        hw.temporal_delta = true;
        let mut on = Accelerator::new(model.clone(), hw);
        let r_on = on.infer(&img).unwrap();
        let tag = engine.name();
        assert_eq!(r_on.logits, golden.logits, "{tag}: logits vs golden");
        assert_eq!(r_on.logits, r_off.logits, "{tag}: logits flag on vs off");
        assert_eq!(r_on.total, r_off.total, "{tag}: unit stats are flag-invariant");
        assert_eq!(r_on.phases.phases, r_off.phases.phases, "{tag}: phase breakdown");
        assert_eq!(r_on.wall_cycles(), r_off.wall_cycles(), "{tag}: the schedule never moves");
        let (m_off, m_on) = (r_off.memory().unwrap(), r_on.memory().unwrap());
        // Flag off: every SDEB input re-stored in full. Flag on: the
        // same denominator, never more words moved than a full store.
        assert_eq!(m_off.spike_bytes_moved, m_off.spike_bytes_full, "{tag}: off = full restore");
        assert!(m_off.spike_bytes_full > 0, "{tag}: SDEB inputs are charged");
        assert_eq!(m_on.spike_bytes_full, m_off.spike_bytes_full, "{tag}: same denominator");
        assert!(
            m_on.spike_bytes_moved <= m_on.spike_bytes_full,
            "{tag}: delta can only shrink the store"
        );
        // Weight-side accounting is flag-independent and sums to the
        // block count (satellite: regime counts in the memory report).
        assert_eq!(
            (m_on.resident_blocks, m_on.thrash_blocks, m_on.streaming_blocks),
            (m_off.resident_blocks, m_off.thrash_blocks, m_off.streaming_blocks),
            "{tag}"
        );
        assert_eq!(
            m_on.resident_blocks + m_on.thrash_blocks + m_on.streaming_blocks,
            cfg.num_blocks,
            "{tag}: every block is classified"
        );
        // Test scale: both working sets fit their slots and stay hosted.
        assert_eq!(m_on.resident_blocks, cfg.num_blocks, "{tag}");
        assert!(m_on.resident_bytes > 0, "{tag}");
        assert!(r_on.summary().contains("temporal: regimes"), "{tag}: summary line");
    }
}

#[test]
fn delta_flag_is_bit_exact_over_random_topologies() {
    let cfg = sharded_cfg();
    let model = QuantizedModel::random(&cfg, 83);
    let img = random_image(89);
    let mut rng = Prng::new(97);
    for case in 0..8u64 {
        let topo = CoreTopology {
            sps_cores: 1 + (rng.next_u64() % 3) as usize,
            sdeb_cores: 1 + (rng.next_u64() % 4) as usize,
            pipeline_depth: 2 + (rng.next_u64() % 3) as usize,
            ..CoreTopology::paper()
        };
        let mut hw = AccelConfig::small().with_topology(topo);
        if rng.next_u64() % 2 == 0 {
            hw.weight_buffer_words = 40_000; // slot 20k < 33k-word sets -> streaming
        }
        if rng.next_u64() % 2 == 0 {
            hw.dram_bytes_per_cycle = 1 + (rng.next_u64() % 16) as usize;
        }
        let mut off = Accelerator::new(model.clone(), hw);
        let r_off = off.infer(&img).unwrap();
        hw.temporal_delta = true;
        let mut on = Accelerator::new(model.clone(), hw);
        let r_on = on.infer(&img).unwrap();
        assert_eq!(r_on.logits, r_off.logits, "case {case}: logits");
        assert_eq!(r_on.total, r_off.total, "case {case}: unit stats");
        assert_eq!(r_on.phases.phases, r_off.phases.phases, "case {case}: phases");
        assert_eq!(r_on.wall_cycles(), r_off.wall_cycles(), "case {case}: schedule");
        let (m_off, m_on) = (r_off.memory().unwrap(), r_on.memory().unwrap());
        assert_eq!(m_off.spike_bytes_moved, m_off.spike_bytes_full, "case {case}");
        assert!(m_on.spike_bytes_moved <= m_on.spike_bytes_full, "case {case}");
        // The report's regime fields are exactly the DMA plan's own
        // classification (bandwidth-independent).
        let dma = DmaEngine::new(on.model(), &hw);
        assert_eq!(
            (m_on.resident_blocks, m_on.thrash_blocks, m_on.streaming_blocks),
            dma.regime_counts(),
            "case {case}"
        );
        assert_eq!(m_on.resident_bytes, dma.resident_bytes(), "case {case}");
    }
}

/// Acceptance: at the paper point (16 B/cycle bus, the default two-core
/// topology, T = 4) the delta path must stream measurably fewer bytes
/// per inference than the PR 5 baseline. Flag off *is* that baseline:
/// the paper working sets (1.77 M words) exceed one 2 MiB slot, so both
/// blocks classify Streaming and the weight traffic equals PR 5's
/// stream-per-use plan, while every SDEB input re-stores in full.
#[test]
fn paper_point_streams_fewer_bytes_than_the_full_restore_baseline() {
    let cfg = SdtModelConfig::paper();
    let model = QuantizedModel::random(&cfg, 42);
    let img = random_image(3);
    let hw = AccelConfig::paper();
    let mut off = Accelerator::new(model.clone(), hw);
    let r_off = off.infer(&img).unwrap();
    let mut hw_on = hw;
    hw_on.temporal_delta = true;
    let mut on = Accelerator::new(model, hw_on);
    let r_on = on.infer(&img).unwrap();
    assert_eq!(r_on.logits, r_off.logits, "the delta path must stay value-exact");
    let (m_off, m_on) = (r_off.memory().unwrap(), r_on.memory().unwrap());
    assert_eq!((m_on.resident_blocks, m_on.thrash_blocks), (0, 0));
    assert_eq!(m_on.streaming_blocks, cfg.num_blocks, "paper blocks exceed a slot");
    assert_eq!(m_on.resident_bytes, 0);
    assert_eq!(
        m_off.spike_bytes_moved, m_off.spike_bytes_full,
        "flag off is the PR 5 full-restore baseline"
    );
    assert_eq!(m_on.weight_bytes(), m_off.weight_bytes(), "weight traffic is flag-invariant");
    // T = 4 timesteps of one image are temporally correlated: the
    // per-channel XOR delta undercuts re-storing every input in full.
    assert!(
        m_on.spike_bytes_moved < m_on.spike_bytes_full,
        "delta must beat the full restore: moved {} vs full {}",
        m_on.spike_bytes_moved,
        m_on.spike_bytes_full
    );
    assert!(
        m_on.streamed_bytes() < m_off.streamed_bytes(),
        "streamed bytes per inference must drop: {} vs baseline {}",
        m_on.streamed_bytes(),
        m_off.streamed_bytes()
    );
}

/// The weight-resident schedule against PR 5 at every bandwidth on the
/// ladder, over random topologies. The PR 5 plan is reconstructed by
/// forcing `slots = 1` on a retargeted clone: the Streaming head/tail
/// split degenerates to the single unsplit request released at the
/// previous use — exactly the PR 5 stream — and the once-streamed
/// Resident/Thrash transfers release no earlier than under PR 5's
/// tighter one-slot ring, so `new <= pr5` bounds the real regression.
#[test]
fn wall_cycles_never_regress_vs_the_pr5_schedule_on_the_ladder() {
    let cfg = sharded_cfg();
    let model = QuantizedModel::random(&cfg, 101);
    let img = random_image(103);
    let mut rng = Prng::new(107);
    for case in 0..10u64 {
        let topo = CoreTopology {
            sps_cores: 1 + (rng.next_u64() % 3) as usize,
            sdeb_cores: 1 + (rng.next_u64() % 4) as usize,
            pipeline_depth: 2 + (rng.next_u64() % 3) as usize,
            ..CoreTopology::paper()
        };
        let mut hw = AccelConfig::small().with_topology(topo);
        if rng.next_u64() % 2 == 0 {
            hw.weight_buffer_words = 40_000; // slot 20k < 33k-word sets -> streaming
        }
        let mut accel = Accelerator::new(model.clone(), hw);
        let r = accel.infer(&img).unwrap();
        let p = r.pipeline.as_ref().unwrap();
        let dma = DmaEngine::new(accel.model(), &hw);
        let mut last = None;
        for bw in [1usize, 2, 3, 5, 8, 13, 64, 4096, usize::MAX] {
            let retime = |d: &DmaEngine| {
                PipelineExecution::with_memory(
                    p.io_input_cycles,
                    p.io_output_cycles,
                    p.sps_per_timestep.clone(),
                    p.sdeb_segments.clone(),
                    &topo,
                    Some(d),
                )
            };
            let new = retime(&dma.clone().with_bandwidth(bw));
            let mut pr5 = dma.clone().with_bandwidth(bw);
            pr5.slots = 1;
            let old = retime(&pr5);
            assert!(
                new.executed_cycles <= old.executed_cycles,
                "case {case} bw {bw}: wall {} regressed past the PR 5 schedule {}",
                new.executed_cycles,
                old.executed_cycles
            );
            if bw == hw.dram_bytes_per_cycle {
                assert_eq!(
                    new.executed_cycles, p.executed_cycles,
                    "case {case}: the re-timed schedule must reproduce the executed one"
                );
            }
            if let Some(prev) = last {
                assert!(
                    new.executed_cycles <= prev,
                    "case {case} bw {bw}: wall {} > previous {prev}",
                    new.executed_cycles
                );
            }
            last = Some(new.executed_cycles);
        }
    }
}
