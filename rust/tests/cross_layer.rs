//! Cross-layer validation of the Fig.-6 measurement: the module sparsities
//! the *float JAX model* reports (written by `python -m compile.analysis`
//! during `make artifacts`) must match the rust *quantized* pipeline's
//! sparsities on the same held-out images within a small quantization
//! tolerance. This closes the L1/L2 <-> L3 loop on activations, not just
//! on logits.

use std::collections::HashMap;
use std::path::Path;

use spikeformer_accel::model::{load_model, loader::load_test_split, GoldenExecutor};

fn load_jax_sparsity(path: &Path) -> Option<HashMap<String, f64>> {
    let text = std::fs::read_to_string(path).ok()?;
    let mut map = HashMap::new();
    for line in text.lines() {
        let mut it = line.split_whitespace();
        if let (Some(k), Some(v)) = (it.next(), it.next()) {
            map.insert(k.to_string(), v.parse().ok()?);
        }
    }
    Some(map)
}

#[test]
fn quantized_sparsity_matches_float_jax_within_tolerance() {
    let jax_path = Path::new("artifacts/fig6_jax.txt");
    let wdir = Path::new("artifacts/weights");
    let (Some(jax), true) = (load_jax_sparsity(jax_path), wdir.join("manifest.txt").exists())
    else {
        eprintln!("skip: run `make artifacts` first");
        return;
    };

    let model = load_model(wdir).unwrap();
    let (imgs, shape, _) = load_test_split(wdir).unwrap();
    let img_len = shape[1] * shape[2] * shape[3];
    let n = shape[0].min(64); // must match analysis.py --limit
    let golden = GoldenExecutor::new(&model);

    // accumulate rust-side sparsity over the same images
    let mut acc: HashMap<String, (f64, usize)> = HashMap::new();
    for i in 0..n {
        let r = golden.infer(&imgs[i * img_len..(i + 1) * img_len]);
        for (name, s) in r.sparsity {
            let e = acc.entry(name).or_insert((0.0, 0));
            e.0 += s;
            e.1 += 1;
        }
    }

    let mut compared = 0;
    for (name, jx) in &jax {
        if let Some((total, count)) = acc.get(name) {
            let rs = total / *count as f64;
            assert!(
                (rs - jx).abs() < 0.08,
                "{name}: rust quantized {rs:.4} vs jax float {jx:.4}"
            );
            compared += 1;
        }
    }
    assert!(compared >= 8, "only {compared} modules compared — name drift?");
}
