//! Integration: the bandwidth-aware memory system against the PR 4
//! (memory-blind) executed schedule.
//!
//! The weight-streaming DMA and the shared DRAM bus are a *schedule*
//! lane, never a value path, so three invariances pin the model down:
//!
//! 1. logits are bit-identical to the pre-memory executor at **any**
//!    bandwidth (and the phase breakdown — compute busy-time — does not
//!    depend on bandwidth at all);
//! 2. at `dram_bytes_per_cycle = usize::MAX` (the unlimited-bus
//!    idealization) stalls are exactly zero and wall cycles equal the
//!    PR 4 schedule bit-for-bit;
//! 3. wall cycles are monotonically non-increasing in
//!    `dram_bytes_per_cycle` (property-tested over random topologies).
//!
//! Plus the acceptance half of the roofline claim: at the paper's 16
//! B/cycle interface, scaling the SPS compute up (more SPS cores) tips
//! the paper-scale schedule bandwidth-bound — a nonzero stall fraction.

use spikeformer_accel::accel::{
    Accelerator, DatapathMode, DmaEngine, ExecMode, PipelineExecution,
};
use spikeformer_accel::hw::{AccelConfig, CoreTopology};
use spikeformer_accel::model::{GoldenExecutor, QuantizedModel, SdtModelConfig};
use spikeformer_accel::util::Prng;

fn random_image(seed: u64) -> Vec<f32> {
    let mut rng = Prng::new(seed);
    (0..3 * 32 * 32).map(|_| rng.next_f32_signed()).collect()
}

/// Multi-block, multi-head config at test scale (mirrors the overlap
/// suite's sharded config).
fn sharded_cfg() -> SdtModelConfig {
    SdtModelConfig {
        name: "memory-test".into(),
        timesteps: 3,
        num_blocks: 2,
        num_heads: 8,
        ..SdtModelConfig::tiny()
    }
}

fn hw_at(bw: usize) -> AccelConfig {
    let mut hw = AccelConfig::small();
    hw.dram_bytes_per_cycle = bw;
    hw
}

/// The PR 4 schedule: the same stage traces re-timed without a memory
/// plan.
fn pr4_schedule(p: &PipelineExecution, topo: &CoreTopology) -> PipelineExecution {
    PipelineExecution::with_topology(
        p.io_input_cycles,
        p.io_output_cycles,
        p.sps_per_timestep.clone(),
        p.sdeb_per_timestep.clone(),
        topo,
    )
}

#[test]
fn logits_and_phases_bit_identical_across_bandwidths() {
    let cfg = sharded_cfg();
    let model = QuantizedModel::random(&cfg, 5);
    let img = random_image(7);
    let golden = GoldenExecutor::new(&model).infer(&img);
    let mut serial = Accelerator::with_modes(
        model.clone(),
        AccelConfig::small(),
        DatapathMode::Encoded,
        ExecMode::Serial,
    );
    let r_serial = serial.infer(&img).unwrap();
    let mut reference: Option<spikeformer_accel::accel::RunReport> = None;
    for bw in [1usize, 8, 1024, usize::MAX] {
        let mut accel = Accelerator::new(model.clone(), hw_at(bw));
        let r = accel.infer(&img).unwrap();
        assert_eq!(r.logits, golden.logits, "bw {bw}: logits vs golden");
        assert_eq!(r.logits, r_serial.logits, "bw {bw}: logits vs serial");
        assert!(r.memory().is_some(), "bw {bw}: overlapped runs carry memory accounting");
        if let Some(want) = &reference {
            // The compute phases are a bandwidth-independent quantity —
            // only the schedule (wall cycles, stalls) may move.
            assert_eq!(r.total, want.total, "bw {bw}: phase totals");
            assert_eq!(r.phases.phases, want.phases.phases, "bw {bw}: phase breakdown");
        } else {
            reference = Some(r);
        }
    }
}

#[test]
fn unlimited_bandwidth_recovers_the_pr4_schedule() {
    let cfg = sharded_cfg();
    let model = QuantizedModel::random(&cfg, 11);
    let img = random_image(13);
    let mut accel = Accelerator::new(model, hw_at(usize::MAX));
    let r = accel.infer(&img).unwrap();
    let p = r.pipeline.as_ref().unwrap();
    assert_eq!(p.stall_cycles, 0, "an unlimited bus can never stall");
    let pr4 = pr4_schedule(p, &CoreTopology::paper());
    assert_eq!(
        p.executed_cycles, pr4.executed_cycles,
        "wall cycles must equal the memory-blind schedule"
    );
    assert_eq!(r.wall_cycles(), pr4.executed_cycles);
    // The traffic is still real and still charged.
    let m = r.memory().unwrap();
    assert!(m.weight_bytes() > 0, "weights are streamed even on an ideal bus");
}

#[test]
fn small_scale_paper_bandwidth_has_no_stalls_and_matches_pr4() {
    // At test scale the working sets are slot-resident and tiny next to
    // the conv front-end: the default-bandwidth schedule must already be
    // stall-free and bit-identical to PR 4 (this is what keeps every
    // pre-memory cycle assertion in the suite valid).
    let cfg = sharded_cfg();
    let model = QuantizedModel::random(&cfg, 17);
    let img = random_image(19);
    let mut accel = Accelerator::new(model, AccelConfig::small());
    let r = accel.infer(&img).unwrap();
    let p = r.pipeline.as_ref().unwrap();
    assert_eq!(p.stall_cycles, 0);
    assert_eq!(p.executed_cycles, pr4_schedule(p, &CoreTopology::paper()).executed_cycles);
}

#[test]
fn wall_cycles_monotone_in_bandwidth_over_random_topologies() {
    // The stage traces are bandwidth-independent, so one inference per
    // topology yields the exact schedule at every bandwidth by re-timing
    // the recorded traces through the recurrence with a retargeted plan.
    let cfg = sharded_cfg();
    let model = QuantizedModel::random(&cfg, 23);
    let img = random_image(29);
    let mut rng = Prng::new(31);
    for case in 0..12u64 {
        let topo = CoreTopology {
            sps_cores: 1 + (rng.next_u64() % 3) as usize,
            sdeb_cores: 1 + (rng.next_u64() % 4) as usize,
            pipeline_depth: 2 + (rng.next_u64() % 3) as usize,
            ..CoreTopology::paper()
        };
        // Random residency pressure: occasionally shrink the weight
        // buffer so the sets stream per use.
        let mut hw = AccelConfig::small().with_topology(topo);
        if rng.next_u64() % 2 == 0 {
            hw.weight_buffer_words = 40_000; // slot 20k < 33k-word sets
        }
        let mut accel = Accelerator::new(model.clone(), hw);
        let r = accel.infer(&img).unwrap();
        let p = r.pipeline.as_ref().unwrap();
        let dma = DmaEngine::new(accel.model(), &hw);
        let mut last = None;
        for bw in [1usize, 2, 3, 5, 8, 13, 64, 4096, usize::MAX] {
            let e = PipelineExecution::with_memory(
                p.io_input_cycles,
                p.io_output_cycles,
                p.sps_per_timestep.clone(),
                p.sdeb_segments.clone(),
                &topo,
                Some(&dma.clone().with_bandwidth(bw)),
            );
            if bw == hw.dram_bytes_per_cycle {
                assert_eq!(
                    e.executed_cycles, p.executed_cycles,
                    "case {case}: re-timed schedule must reproduce the executed one"
                );
            }
            if let Some(prev) = last {
                assert!(
                    e.executed_cycles <= prev,
                    "case {case} bw {bw}: wall {} > previous {prev}",
                    e.executed_cycles
                );
            }
            last = Some(e.executed_cycles);
        }
        // The unlimited end of the sweep is the PR 4 schedule.
        let ideal = PipelineExecution::with_memory(
            p.io_input_cycles,
            p.io_output_cycles,
            p.sps_per_timestep.clone(),
            p.sdeb_segments.clone(),
            &topo,
            Some(&dma.clone().with_bandwidth(usize::MAX)),
        );
        assert_eq!(ideal.stall_cycles, 0, "case {case}");
        assert_eq!(
            ideal.executed_cycles,
            pr4_schedule(p, &topo).executed_cycles,
            "case {case}"
        );
    }
}

#[test]
fn bandwidth_bound_schedule_stalls_and_stays_value_exact() {
    // Force the bandwidth-bound regime at test scale: streaming residency
    // (shrunken weight buffer), a 1 B/cycle bus, and doubled SPS compute
    // so the bus is the bottleneck.
    let cfg = SdtModelConfig {
        name: "membound".into(),
        timesteps: 3,
        num_blocks: 4,
        num_heads: 8,
        ..SdtModelConfig::tiny()
    };
    let model = QuantizedModel::random(&cfg, 37);
    let img = random_image(41);
    let golden = GoldenExecutor::new(&model).infer(&img);
    let mut hw = AccelConfig::small().with_topology(CoreTopology {
        sps_cores: 2,
        sdeb_cores: 2,
        pipeline_depth: 4,
        ..CoreTopology::paper()
    });
    hw.weight_buffer_words = 40_000; // slot 20k < 33k-word sets -> streaming
    hw.dram_bytes_per_cycle = 1;
    let mut accel = Accelerator::new(model, hw);
    let r = accel.infer(&img).unwrap();
    assert_eq!(r.logits, golden.logits, "stalling must not change values");
    let p = r.pipeline.as_ref().unwrap();
    assert!(p.stall_cycles > 0, "1 B/cycle must starve the consumer");
    assert!(p.stall_fraction() > 0.0);
    assert!(
        p.executed_cycles > pr4_schedule(p, &hw.topology).executed_cycles,
        "stalls must show up in wall cycles"
    );
    let m = r.memory().unwrap();
    assert_eq!(m.stall_cycles(), p.stall_cycles);
    assert!(m.bus_utilization(p.executed_cycles) > 0.0);
}

/// Acceptance: at the paper's 16 B/cycle interface, at least one swept
/// topology point of the roofline is bandwidth-bound. Scaling the SPS
/// stage to 4 cores roughly quarters the compute period while the
/// paper-scale working sets (1.77 M words > the 1 M-word ping/pong slot)
/// re-stream every timestep — the schedule stalls.
#[test]
fn paper_bandwidth_stalls_on_the_scaled_sps_topology() {
    let cfg = SdtModelConfig::paper();
    let model = QuantizedModel::random(&cfg, 42);
    let img = random_image(3);
    let topo = CoreTopology {
        sps_cores: 4,
        sdeb_cores: 2,
        pipeline_depth: 6,
        ..CoreTopology::paper()
    };
    let hw = AccelConfig::paper().with_topology(topo);
    let mut accel = Accelerator::new(model, hw);
    let r = accel.infer(&img).unwrap();
    let p = r.pipeline.as_ref().unwrap();
    assert!(
        p.stall_cycles > 0,
        "paper bandwidth must stall the compute-scaled topology (stall {})",
        p.stall_cycles
    );
    // Re-timing the same run on an unlimited bus removes every stall.
    let dma = DmaEngine::new(accel.model(), &hw).with_bandwidth(usize::MAX);
    let ideal = PipelineExecution::with_memory(
        p.io_input_cycles,
        p.io_output_cycles,
        p.sps_per_timestep.clone(),
        p.sdeb_segments.clone(),
        &topo,
        Some(&dma),
    );
    assert_eq!(ideal.stall_cycles, 0);
    assert!(ideal.executed_cycles < p.executed_cycles);
}

#[test]
fn batched_inference_reports_match_per_call_with_memory() {
    let cfg = sharded_cfg();
    let model = QuantizedModel::random(&cfg, 43);
    let imgs: Vec<Vec<f32>> = (0..3).map(|s| random_image(50 + s)).collect();
    let mut batched = Accelerator::new(model.clone(), AccelConfig::small());
    let batch_reports = batched.infer_batch(&imgs).unwrap();
    for (i, img) in imgs.iter().enumerate() {
        let mut fresh = Accelerator::new(model.clone(), AccelConfig::small());
        let want = fresh.infer(img).unwrap();
        let got = &batch_reports[i];
        assert_eq!(got.logits, want.logits, "image {i}");
        assert_eq!(got.wall_cycles(), want.wall_cycles(), "image {i}");
        let (gp, wp) = (got.pipeline.as_ref().unwrap(), want.pipeline.as_ref().unwrap());
        assert_eq!(gp.sdeb_segments, wp.sdeb_segments, "image {i}");
        assert_eq!(gp.stall_cycles, wp.stall_cycles, "image {i}");
        assert_eq!(got.memory(), want.memory(), "image {i}");
    }
}
