//! Spike Mask-Add Module (SMAM, Fig. 4): the dual-spike-input engine for
//! Spike-Driven Self-Attention.
//!
//! Per channel c, the Hadamard product of binary Q_s[:,c] and K_s[:,c]
//! accumulated along the token dimension equals the size of the
//! intersection of their encoded address lists. The hardware realises it as
//! a two-pointer comparator (Fig. 4(a)): take one encoded spike from each
//! memory; on address match output '1' (one accumulation, Fig. 4(b)) and
//! advance both; otherwise retain the larger address and advance the
//! smaller — each comparison consumes exactly one encoded spike, so a
//! channel finishes in |Q_c| + |K_c| comparator steps. The accumulated
//! count is compared against the firing threshold to produce the mask bit
//! S[c]; V_s's per-channel ESS bank is then cleared or retained (Fig. 4(c)).
//! Retention is an offset-range copy out of V's CSR arena — no per-channel
//! heap clones.

use std::ops::Range;

use crate::hw::{AccelConfig, UnitStats};
use crate::spike::EncodedSpikes;
use crate::util::div_ceil;

/// Assignment of attention heads to physical SDEB cores for the SDSA pass.
///
/// The SDSA mask is channel-local (each channel's Q∩K count and mask bit
/// depend on that channel alone), so a head is simply a contiguous channel
/// range and sharding heads across cores is bit-exact. During block `b`'s
/// SDSA phase the other blocks' SMAM comparator arrays are idle, so the
/// controller farms head `h` out to core `h % cores` — each core runs its
/// assigned heads back to back on its own comparator array, and the phase
/// finishes when the busiest core does (cycles = max over cores).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HeadShard {
    /// Attention heads (`SdtModelConfig::num_heads`); each head is a
    /// contiguous channel range.
    pub heads: usize,
    /// Physical SDEB cores whose SMAM arrays process heads concurrently.
    pub cores: usize,
}

impl HeadShard {
    /// The degenerate plan: one head on one core (identical to the serial
    /// [`SpikeMaskAddModule::run`] accounting).
    pub fn serial() -> Self {
        Self { heads: 1, cores: 1 }
    }

    /// Balanced contiguous channel range of head `h` out of `heads` over
    /// `channels` channels (first `channels % heads` heads get one extra).
    pub fn head_channels(h: usize, heads: usize, channels: usize) -> Range<usize> {
        let base = channels / heads;
        let rem = channels % heads;
        let start = h * base + h.min(rem);
        let len = base + usize::from(h < rem);
        start..start + len
    }
}

/// Spike Mask-Add Module — see the module docs for the Fig. 4 dataflow.
#[derive(Clone, Copy, Debug)]
pub struct SpikeMaskAddModule {
    /// Integer firing threshold of the mask neuron (accumulation counts).
    pub v_th: u32,
}

/// Per-head partial result produced by one core's comparator array.
struct HeadResult {
    range: Range<usize>,
    mask: Vec<bool>,
    acc: Vec<u32>,
    steps: u64,
    matches: u64,
}

/// Below this many Q+K spikes the merge-join is too small to amortise
/// spawning per-core worker threads; the cores are then walked
/// sequentially (bit-identical results, same cycle accounting).
const SHARD_SPAWN_MIN_SPIKES: usize = 4096;

/// Result of an SDSA pass.
#[derive(Clone, Debug)]
pub struct SmamOutput {
    /// Per-channel mask S (Fig. 4(b)).
    pub mask: Vec<bool>,
    /// Per-channel Q.K intersection counts (the token-dim accumulation).
    pub acc: Vec<u32>,
    /// Masked V_s: channels with S=0 cleared, others retained verbatim.
    pub masked_v: EncodedSpikes,
}

impl SpikeMaskAddModule {
    /// A module with mask-neuron threshold `v_th`.
    pub fn new(v_th: u32) -> Self {
        Self { v_th }
    }

    fn check_shapes(q: &EncodedSpikes, k: &EncodedSpikes, v: &EncodedSpikes) {
        assert_eq!(q.channels, k.channels);
        assert_eq!(q.channels, v.channels);
        assert_eq!(q.tokens, k.tokens);
        // A mismatched V token space would silently produce a masked_v
        // whose declared token range disagrees with Q/K's address space.
        assert_eq!(q.tokens, v.tokens, "SMAM V token space mismatch");
    }

    /// Run SDSA mask-add over encoded Q_s, K_s, V_s (all `[C, L]`) on one
    /// serial comparator array.
    ///
    /// Delegates to [`Self::run_sharded`] with the degenerate one-head /
    /// one-core plan, so the serial and sharded paths share one merge-join
    /// and one stats formula by construction.
    pub fn run(
        &self,
        q: &EncodedSpikes,
        k: &EncodedSpikes,
        v: &EncodedSpikes,
        cfg: &AccelConfig,
    ) -> (SmamOutput, UnitStats) {
        self.run_sharded(q, k, v, cfg, HeadShard::serial())
    }

    /// Two-pointer merge-join of Q and K over one contiguous channel
    /// range: per-channel intersection counts, fire decisions, and the
    /// comparator-step/match totals for that range.
    fn intersect_range(
        &self,
        q: &EncodedSpikes,
        k: &EncodedSpikes,
        range: Range<usize>,
    ) -> (Vec<bool>, Vec<u32>, u64, u64) {
        let mut mask = vec![false; range.len()];
        let mut acc = vec![0u32; range.len()];
        let mut steps: u64 = 0;
        let mut matches: u64 = 0;
        for (slot, ch) in range.enumerate() {
            let (ql, kl) = (q.channel_addrs(ch), k.channel_addrs(ch));
            let (mut i, mut j) = (0usize, 0usize);
            let mut count = 0u32;
            while i < ql.len() && j < kl.len() {
                steps += 1;
                match ql[i].cmp(&kl[j]) {
                    std::cmp::Ordering::Equal => {
                        count += 1;
                        matches += 1;
                        i += 1;
                        j += 1;
                    }
                    std::cmp::Ordering::Less => i += 1,
                    std::cmp::Ordering::Greater => j += 1,
                }
            }
            acc[slot] = count;
            mask[slot] = count >= self.v_th;
        }
        (mask, acc, steps, matches)
    }

    /// Run SDSA with attention heads sharded across SDEB-core comparator
    /// arrays (the overlapped executor's default path).
    ///
    /// Head `h` (a contiguous channel range, [`HeadShard::head_channels`])
    /// is assigned to core `h % cores`. Each core streams its heads back
    /// to back through its own comparator array, so cycles are charged
    /// per **core** (one ceiling over the core's total comparator steps
    /// and one threshold compare per assigned channel — never worse than
    /// the serial single-array cost), and the phase finishes when the
    /// busiest core does (cycles = max over cores) while op counts (SOPs,
    /// adds, compares, SRAM traffic) sum over all heads. Outputs are
    /// bit-identical to the serial path because the mask is channel-local;
    /// with `heads == cores == 1` the accounting is the serial formula.
    /// Cores run on real host threads when the workload is large enough
    /// to amortise the spawn (`SHARD_SPAWN_MIN_SPIKES`); results and
    /// accounting are identical either way.
    pub fn run_sharded(
        &self,
        q: &EncodedSpikes,
        k: &EncodedSpikes,
        v: &EncodedSpikes,
        cfg: &AccelConfig,
        shard: HeadShard,
    ) -> (SmamOutput, UnitStats) {
        Self::check_shapes(q, k, v);
        let c = q.channels;
        let heads = shard.heads.max(1).min(c.max(1));
        let cores = shard.cores.max(1).min(heads);
        let comps = cfg.smam_comparators as u64;

        // One core's serial pass over its assigned heads.
        let run_core = |core: usize| -> Vec<(usize, HeadResult)> {
            let mut out = Vec::new();
            let mut h = core;
            while h < heads {
                let range = HeadShard::head_channels(h, heads, c);
                let (mask, acc, steps, matches) = self.intersect_range(q, k, range.clone());
                out.push((h, HeadResult { range, mask, acc, steps, matches }));
                h += cores;
            }
            out
        };

        let mut per_head: Vec<Option<HeadResult>> = (0..heads).map(|_| None).collect();
        let spawn = cores > 1 && q.count_spikes() + k.count_spikes() >= SHARD_SPAWN_MIN_SPIKES;
        if spawn {
            std::thread::scope(|s| {
                let run_core = &run_core;
                let handles: Vec<_> =
                    (0..cores).map(|core| s.spawn(move || run_core(core))).collect();
                for handle in handles {
                    for (h, r) in handle.join().expect("SMAM head-shard worker panicked") {
                        per_head[h] = Some(r);
                    }
                }
            });
        } else {
            for core in 0..cores {
                for (h, r) in run_core(core) {
                    per_head[h] = Some(r);
                }
            }
        }

        // Deterministic merge in head (== channel) order.
        let mut mask = vec![false; c];
        let mut acc = vec![0u32; c];
        let mut core_steps = vec![0u64; cores];
        let mut core_channels = vec![0u64; cores];
        let (mut steps, mut matches) = (0u64, 0u64);
        for (h, slot) in per_head.into_iter().enumerate() {
            let r = slot.expect("every head computed");
            mask[r.range.clone()].copy_from_slice(&r.mask);
            acc[r.range.clone()].copy_from_slice(&r.acc);
            steps += r.steps;
            matches += r.matches;
            core_steps[h % cores] += r.steps;
            core_channels[h % cores] += r.range.len() as u64;
        }
        let mut masked_v = EncodedSpikes::empty(v.channels, v.tokens);
        for ch in 0..c {
            if mask[ch] {
                masked_v.extend_channel_from(ch, v, ch);
            }
        }

        // Per-core cost: its comparator steps spread over its array, plus
        // one threshold compare per assigned channel (Fig. 4(b)). With one
        // core this is exactly the serial single-array formula, and a
        // core's cost never exceeds it (its steps/channels are subsets).
        let core_cycles = |i: usize| -> u64 {
            div_ceil(core_steps[i], comps).max(1) + div_ceil(core_channels[i], comps)
        };
        let q_spikes = q.count_spikes() as u64;
        let k_spikes = k.count_spikes() as u64;
        let retained = masked_v.count_spikes() as u64;
        let stats = UnitStats {
            cycles: (0..cores).map(core_cycles).max().unwrap_or(1),
            // SOPs: every Q/K spike traverses the comparator once; every
            // retained V spike traverses the mask gate.
            sops: q_spikes + k_spikes + retained,
            adds: matches, // token-dim accumulation increments
            cmps: steps + c as u64,
            sram_reads: q_spikes + k_spikes + retained,
            sram_writes: retained,
            ..Default::default()
        };
        (SmamOutput { mask, acc, masked_v }, stats)
    }

    /// Dense bitmap baseline: walks all C*L Hadamard positions (ablation A1).
    pub fn run_dense_baseline(
        &self,
        q: &EncodedSpikes,
        k: &EncodedSpikes,
        v: &EncodedSpikes,
        cfg: &AccelConfig,
    ) -> (SmamOutput, UnitStats) {
        Self::check_shapes(q, k, v);
        let (qb, kb) = (q.to_bitmap(), k.to_bitmap());
        let c = q.channels;
        let l = q.tokens;
        let mut mask = vec![false; c];
        let mut acc = vec![0u32; c];
        let mut masked_v = EncodedSpikes::empty(v.channels, v.tokens);
        for ch in 0..c {
            let mut count = 0u32;
            for t in 0..l {
                if qb.get(ch, t) && kb.get(ch, t) {
                    count += 1;
                }
            }
            acc[ch] = count;
            mask[ch] = count >= self.v_th;
            if mask[ch] {
                masked_v.extend_channel_from(ch, v, ch);
            }
        }
        let positions = (c * l) as u64;
        let retained = masked_v.count_spikes() as u64;
        let stats = UnitStats {
            cycles: div_ceil(positions, cfg.smam_comparators as u64).max(1)
                + div_ceil(c as u64, cfg.smam_comparators as u64),
            sops: q.count_spikes() as u64 + k.count_spikes() as u64 + retained,
            adds: acc.iter().map(|&x| x as u64).sum(),
            cmps: positions + c as u64,
            sram_reads: 2 * positions + retained,
            sram_writes: retained,
            ..Default::default()
        };
        (SmamOutput { mask, acc, masked_v }, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spike::SpikeMatrix;
    use crate::util::Prng;

    fn random_encoded(rng: &mut Prng, c: usize, l: usize, p: f64) -> EncodedSpikes {
        let mut m = SpikeMatrix::zeros(c, l);
        for ci in 0..c {
            for li in 0..l {
                if rng.bernoulli(p) {
                    m.set(ci, li, true);
                }
            }
        }
        EncodedSpikes::from_bitmap(&m)
    }

    #[test]
    fn intersection_counts_match_hadamard_sum() {
        let mut rng = Prng::new(7);
        let cfg = AccelConfig::small();
        let smam = SpikeMaskAddModule::new(2);
        for &p in &[0.1, 0.3, 0.7] {
            let q = random_encoded(&mut rng, 6, 64, p);
            let k = random_encoded(&mut rng, 6, 64, p);
            let v = random_encoded(&mut rng, 6, 64, p);
            let (out, _) = smam.run(&q, &k, &v, &cfg);
            let (qb, kb) = (q.to_bitmap(), k.to_bitmap());
            for ch in 0..6 {
                let want: u32 = (0..64).filter(|&t| qb.get(ch, t) && kb.get(ch, t)).count() as u32;
                assert_eq!(out.acc[ch], want, "channel {ch}");
                assert_eq!(out.mask[ch], want >= 2);
            }
        }
    }

    #[test]
    fn masked_v_clears_or_retains_whole_channels() {
        let mut rng = Prng::new(8);
        let cfg = AccelConfig::small();
        let q = random_encoded(&mut rng, 4, 32, 0.5);
        let k = random_encoded(&mut rng, 4, 32, 0.5);
        let v = random_encoded(&mut rng, 4, 32, 0.4);
        let (out, _) = SpikeMaskAddModule::new(3).run(&q, &k, &v, &cfg);
        for ch in 0..4 {
            if out.mask[ch] {
                assert_eq!(out.masked_v.channel_addrs(ch), v.channel_addrs(ch));
            } else {
                assert!(out.masked_v.channel_addrs(ch).is_empty());
            }
        }
    }

    #[test]
    fn dense_baseline_agrees() {
        let mut rng = Prng::new(9);
        let cfg = AccelConfig::small();
        let smam = SpikeMaskAddModule::new(2);
        let q = random_encoded(&mut rng, 8, 64, 0.2);
        let k = random_encoded(&mut rng, 8, 64, 0.2);
        let v = random_encoded(&mut rng, 8, 64, 0.2);
        let (a, s_sparse) = smam.run(&q, &k, &v, &cfg);
        let (b, s_dense) = smam.run_dense_baseline(&q, &k, &v, &cfg);
        assert_eq!(a.mask, b.mask);
        assert_eq!(a.acc, b.acc);
        assert_eq!(a.masked_v, b.masked_v);
        // At 80% sparsity the encoded path must be far cheaper.
        assert!(s_sparse.cycles < s_dense.cycles);
    }

    #[test]
    fn comparator_steps_bounded_by_list_lengths() {
        let mut rng = Prng::new(10);
        let cfg = AccelConfig::paper();
        let q = random_encoded(&mut rng, 1, 64, 0.5);
        let k = random_encoded(&mut rng, 1, 64, 0.5);
        let v = EncodedSpikes::empty(1, 64);
        let (_, stats) = SpikeMaskAddModule::new(1).run(&q, &k, &v, &cfg);
        let bound = (q.count_spikes() + k.count_spikes()) as u64 + 1;
        assert!(stats.cmps <= bound + 1, "cmps {} > bound {}", stats.cmps, bound);
    }

    #[test]
    fn empty_q_or_k_never_fires() {
        let cfg = AccelConfig::small();
        let q = EncodedSpikes::empty(3, 16);
        let mut k = EncodedSpikes::empty(3, 16);
        k.push(0, 5);
        let mut v = EncodedSpikes::empty(3, 16);
        v.push(0, 1);
        let (out, _) = SpikeMaskAddModule::new(1).run(&q, &k, &v, &cfg);
        assert!(out.mask.iter().all(|&m| !m));
        assert_eq!(out.masked_v.count_spikes(), 0);
    }

    #[test]
    fn threshold_zero_always_fires() {
        let cfg = AccelConfig::small();
        let q = EncodedSpikes::empty(2, 8);
        let k = EncodedSpikes::empty(2, 8);
        let mut v = EncodedSpikes::empty(2, 8);
        v.push(1, 3);
        let (out, _) = SpikeMaskAddModule::new(0).run(&q, &k, &v, &cfg);
        assert!(out.mask.iter().all(|&m| m));
        assert_eq!(out.masked_v.channel_addrs(1), &[3u16][..]);
    }

    #[test]
    fn sharded_outputs_bit_identical_to_serial() {
        let mut rng = Prng::new(21);
        let cfg = AccelConfig::paper();
        let smam = SpikeMaskAddModule::new(2);
        let q = random_encoded(&mut rng, 384, 64, 0.2);
        let k = random_encoded(&mut rng, 384, 64, 0.2);
        let v = random_encoded(&mut rng, 384, 64, 0.2);
        let (serial, s_serial) = smam.run(&q, &k, &v, &cfg);
        for shard in [
            HeadShard { heads: 8, cores: 2 },
            HeadShard { heads: 8, cores: 8 },
            HeadShard { heads: 3, cores: 2 }, // uneven head split
            HeadShard { heads: 500, cores: 4 }, // more heads than channels: clamped
        ] {
            let (out, st) = smam.run_sharded(&q, &k, &v, &cfg, shard);
            assert_eq!(out.mask, serial.mask, "{shard:?}");
            assert_eq!(out.acc, serial.acc, "{shard:?}");
            assert_eq!(out.masked_v, serial.masked_v, "{shard:?}");
            // Same work, concurrent arrays: ops identical, cycles no worse
            // than one core running all heads back to back.
            assert_eq!(st.sops, s_serial.sops, "{shard:?}");
            assert_eq!(st.adds, s_serial.adds, "{shard:?}");
            assert_eq!(st.cmps, s_serial.cmps, "{shard:?}");
        }
    }

    #[test]
    fn sharded_degenerate_plan_matches_serial_cycles() {
        let mut rng = Prng::new(22);
        let cfg = AccelConfig::small();
        let smam = SpikeMaskAddModule::new(2);
        let q = random_encoded(&mut rng, 64, 64, 0.3);
        let k = random_encoded(&mut rng, 64, 64, 0.3);
        let v = random_encoded(&mut rng, 64, 64, 0.3);
        let (_, s1) = smam.run(&q, &k, &v, &cfg);
        let (_, s2) = smam.run_sharded(&q, &k, &v, &cfg, HeadShard::serial());
        assert_eq!(s1, s2, "heads=1/cores=1 must reproduce serial accounting");
    }

    #[test]
    fn sharding_across_cores_cuts_cycles() {
        let mut rng = Prng::new(23);
        let cfg = AccelConfig::paper();
        let smam = SpikeMaskAddModule::new(2);
        let q = random_encoded(&mut rng, 384, 64, 0.3);
        let k = random_encoded(&mut rng, 384, 64, 0.3);
        let v = random_encoded(&mut rng, 384, 64, 0.3);
        let (_, one_core) = smam.run_sharded(&q, &k, &v, &cfg, HeadShard { heads: 8, cores: 1 });
        let (_, two_core) = smam.run_sharded(&q, &k, &v, &cfg, HeadShard { heads: 8, cores: 2 });
        assert!(
            two_core.cycles < one_core.cycles,
            "2 cores {} !< 1 core {}",
            two_core.cycles,
            one_core.cycles
        );
    }

    #[test]
    fn head_channel_ranges_partition_exactly() {
        for (heads, channels) in [(1usize, 64usize), (8, 384), (3, 64), (5, 7)] {
            let mut next = 0;
            for h in 0..heads {
                let r = HeadShard::head_channels(h, heads, channels);
                assert_eq!(r.start, next, "heads={heads} channels={channels} h={h}");
                next = r.end;
            }
            assert_eq!(next, channels);
        }
    }

    #[test]
    #[should_panic(expected = "SMAM V token space mismatch")]
    fn mismatched_v_token_space_panics() {
        let cfg = AccelConfig::small();
        let q = EncodedSpikes::empty(2, 16);
        let k = EncodedSpikes::empty(2, 16);
        let mut v = EncodedSpikes::empty(2, 8); // wrong token space
        v.push(0, 7);
        SpikeMaskAddModule::new(0).run(&q, &k, &v, &cfg);
    }

    #[test]
    #[should_panic(expected = "SMAM V token space mismatch")]
    fn dense_baseline_checks_v_token_space_too() {
        let cfg = AccelConfig::small();
        let q = EncodedSpikes::empty(2, 16);
        let k = EncodedSpikes::empty(2, 16);
        let v = EncodedSpikes::empty(2, 32);
        SpikeMaskAddModule::new(0).run_dense_baseline(&q, &k, &v, &cfg);
    }
}
