//! Spike Mask-Add Module (SMAM, Fig. 4): the dual-spike-input engine for
//! Spike-Driven Self-Attention.
//!
//! Per channel c, the Hadamard product of binary Q_s[:,c] and K_s[:,c]
//! accumulated along the token dimension equals the size of the
//! intersection of their encoded address lists. The hardware realises it as
//! a two-pointer comparator (Fig. 4(a)): take one encoded spike from each
//! memory; on address match output '1' (one accumulation, Fig. 4(b)) and
//! advance both; otherwise retain the larger address and advance the
//! smaller — each comparison consumes exactly one encoded spike, so a
//! channel finishes in |Q_c| + |K_c| comparator steps. The accumulated
//! count is compared against the firing threshold to produce the mask bit
//! S[c]; V_s's per-channel ESS bank is then cleared or retained (Fig. 4(c)).
//! Retention is an offset-range copy out of V's CSR arena — no per-channel
//! heap clones.

use std::ops::Range;

use crate::accel::mapper::Mapper;
use crate::accel::workers::WorkerPool;
use crate::hw::{AccelConfig, EngineKind, EngineSelect, UnitStats};
use crate::scratch::ExecScratch;
use crate::spike::bitmap::WORD_BITS;
use crate::spike::{EncodedSpikes, KvCacheStream, PackedBitmap};
use crate::util::div_ceil;

/// Assignment of attention heads to physical SDEB cores for the SDSA pass.
///
/// The SDSA mask is channel-local (each channel's Q∩K count and mask bit
/// depend on that channel alone), so a head is simply a contiguous channel
/// range and sharding heads across cores is bit-exact. During block `b`'s
/// SDSA phase the other SDEB cores' SMAM comparator arrays are idle, so
/// the scheduler farms heads out across them — each core runs its
/// assigned heads back to back on its own comparator array, and the phase
/// finishes when the busiest core does (cycles = max over cores).
///
/// This struct is the legacy fixed round-robin plan (`h % cores`); the
/// policy-driven head→core assignment lives in
/// [`Mapper`](crate::accel::Mapper) and enters through
/// [`SpikeMaskAddModule::run_mapped_into`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HeadShard {
    /// Attention heads (`SdtModelConfig::num_heads`); each head is a
    /// contiguous channel range.
    pub heads: usize,
    /// Physical SDEB cores whose SMAM arrays process heads concurrently.
    pub cores: usize,
}

impl HeadShard {
    /// The degenerate plan: one head on one core (identical to the serial
    /// [`SpikeMaskAddModule::run`] accounting).
    pub fn serial() -> Self {
        Self { heads: 1, cores: 1 }
    }

    /// Balanced contiguous channel range of head `h` out of `heads` over
    /// `channels` channels (first `channels % heads` heads get one extra).
    pub fn head_channels(h: usize, heads: usize, channels: usize) -> Range<usize> {
        let base = channels / heads;
        let rem = channels % heads;
        let start = h * base + h.min(rem);
        let len = base + usize::from(h < rem);
        start..start + len
    }
}

/// Spike Mask-Add Module — see the module docs for the Fig. 4 dataflow.
#[derive(Clone, Copy, Debug)]
pub struct SpikeMaskAddModule {
    /// Integer firing threshold of the mask neuron (accumulation counts).
    pub v_th: u32,
}

/// One head's disjoint slice of the SDSA output, ready to dispatch to a
/// comparator array: the channel range plus `&mut` windows into the
/// shared mask/acc vectors and this head's comparator tally
/// (`tally[0]` = comparator steps, `tally[1]` = address matches).
struct HeadJob<'a> {
    range: Range<usize>,
    mask: &'a mut [bool],
    acc: &'a mut [u32],
    tally: &'a mut [u64],
    /// Run this head on the word-parallel bitmap engine instead of the
    /// CSR merge-join (the per-head [`EngineSelect`] resolution).
    bitmap: bool,
}

/// Per-pass engine resolution handed from
/// [`SpikeMaskAddModule::run_mapped_into`] to the assigned runner:
/// which heads run on the word
/// engine, the materialized Q/K bitmaps (present iff any head does), and
/// the Q/K SRAM read count under the mixed plan (`None` = the pure-CSR
/// per-spike address reads).
struct EnginePlan<'a> {
    bitmap_heads: &'a [bool],
    bitmaps: Option<(&'a PackedBitmap, &'a PackedBitmap)>,
    qk_reads: Option<u64>,
}

impl EnginePlan<'_> {
    /// The pure-CSR plan (every legacy entry point).
    fn csr() -> EnginePlan<'static> {
        EnginePlan { bitmap_heads: &[], bitmaps: None, qk_reads: None }
    }
}

/// Result of an SDSA pass.
#[derive(Clone, Debug)]
pub struct SmamOutput {
    /// Per-channel mask S (Fig. 4(b)).
    pub mask: Vec<bool>,
    /// Per-channel Q.K intersection counts (the token-dim accumulation).
    pub acc: Vec<u32>,
    /// Masked V_s: channels with S=0 cleared, others retained verbatim.
    pub masked_v: EncodedSpikes,
}

impl SpikeMaskAddModule {
    /// A module with mask-neuron threshold `v_th`.
    pub fn new(v_th: u32) -> Self {
        Self { v_th }
    }

    fn check_shapes(q: &EncodedSpikes, k: &EncodedSpikes, v: &EncodedSpikes) {
        assert_eq!(q.channels, k.channels);
        assert_eq!(q.channels, v.channels);
        assert_eq!(q.tokens, k.tokens);
        // A mismatched V token space would silently produce a masked_v
        // whose declared token range disagrees with Q/K's address space.
        assert_eq!(q.tokens, v.tokens, "SMAM V token space mismatch");
    }

    /// Run SDSA mask-add over encoded Q_s, K_s, V_s (all `[C, L]`) on one
    /// serial comparator array.
    ///
    /// Delegates to [`Self::run_sharded`] with the degenerate one-head /
    /// one-core plan, so the serial and sharded paths share one merge-join
    /// and one stats formula by construction.
    pub fn run(
        &self,
        q: &EncodedSpikes,
        k: &EncodedSpikes,
        v: &EncodedSpikes,
        cfg: &AccelConfig,
    ) -> (SmamOutput, UnitStats) {
        self.run_sharded(q, k, v, cfg, HeadShard::serial())
    }

    /// Two-pointer merge-join of Q and K over one head's contiguous
    /// channel range, writing fire decisions, intersection counts and the
    /// comparator-step/match tallies straight into the job's disjoint
    /// output slices (no per-head heap storage).
    fn intersect_head(&self, q: &EncodedSpikes, k: &EncodedSpikes, job: &mut HeadJob<'_>) {
        for (slot, ch) in job.range.clone().enumerate() {
            let (ql, kl) = (q.channel_addrs(ch), k.channel_addrs(ch));
            let (mut i, mut j) = (0usize, 0usize);
            let mut count = 0u32;
            while i < ql.len() && j < kl.len() {
                job.tally[0] += 1;
                match ql[i].cmp(&kl[j]) {
                    std::cmp::Ordering::Equal => {
                        count += 1;
                        job.tally[1] += 1;
                        i += 1;
                        j += 1;
                    }
                    std::cmp::Ordering::Less => i += 1,
                    std::cmp::Ordering::Greater => j += 1,
                }
            }
            job.acc[slot] = count;
            job.mask[slot] = count >= self.v_th;
        }
    }

    /// Word-parallel twin of [`Self::intersect_head`]
    /// ([`EngineKind::Bitmap`]): per channel, the Q∩K count is the
    /// popcount of the AND of the two packed rows — `ceil(L/64)` word
    /// ops replace `|Q_c|+|K_c|` comparator steps, and those word ops
    /// are what `tally[0]` charges (word ALUs retire one op per
    /// comparator slot per cycle, so the shared per-core cycle formula
    /// applies unchanged). Match counts (`tally[1]`), acc and mask are
    /// bit-identical to the merge-join by construction.
    fn intersect_head_bitmap(&self, q: &PackedBitmap, k: &PackedBitmap, job: &mut HeadJob<'_>) {
        let wpr = q.words_per_row() as u64; // as-ok: widening for 64-bit stat/cycle math
        for (slot, ch) in job.range.clone().enumerate() {
            let count = q.and_popcount_row(ch, k, ch);
            job.tally[0] += wpr;
            job.tally[1] += count as u64; // as-ok: widening for 64-bit stat/cycle math
            job.acc[slot] = count;
            job.mask[slot] = count >= self.v_th;
        }
    }

    /// Run SDSA with attention heads sharded across SDEB-core comparator
    /// arrays (the overlapped executor's default path).
    ///
    /// Allocates its outputs and walks the cores sequentially on the
    /// calling thread; the hot loop uses [`Self::run_sharded_into`] with
    /// the persistent [`WorkerPool`]. Results and accounting are
    /// identical either way.
    pub fn run_sharded(
        &self,
        q: &EncodedSpikes,
        k: &EncodedSpikes,
        v: &EncodedSpikes,
        cfg: &AccelConfig,
        shard: HeadShard,
    ) -> (SmamOutput, UnitStats) {
        self.run_sharded_into(q, k, v, cfg, shard, None, &mut ExecScratch::new())
    }

    /// Run SDSA with attention heads sharded across SDEB-core comparator
    /// arrays, with output storage recycled through `scratch` and the
    /// per-core head batches dispatched on `pool` when one is given.
    ///
    /// Head `h` (a contiguous channel range, [`HeadShard::head_channels`])
    /// is assigned to core `h % cores` — the legacy round-robin
    /// assignment; [`Self::run_mapped_into`] is the policy-driven
    /// generalization this wrapper delegates to.
    pub fn run_sharded_into(
        &self,
        q: &EncodedSpikes,
        k: &EncodedSpikes,
        v: &EncodedSpikes,
        cfg: &AccelConfig,
        shard: HeadShard,
        pool: Option<&WorkerPool>,
        scratch: &mut ExecScratch,
    ) -> (SmamOutput, UnitStats) {
        Self::check_shapes(q, k, v);
        let c = q.channels;
        let heads = shard.heads.max(1).min(c.max(1));
        let cores = shard.cores.max(1).min(heads);
        let mut assign = scratch.take_usize();
        assign.clear();
        assign.extend((0..heads).map(|h| h % cores));
        let out = self.run_assigned_into(
            q,
            k,
            v,
            cfg.smam_comparators as u64, // as-ok: widening for 64-bit stat/cycle math
            heads,
            cores,
            &assign,
            &EnginePlan::csr(),
            pool,
            scratch,
        );
        scratch.put_usize(assign);
        out
    }

    /// Run SDSA under a [`Mapper`]'s policy for encoder block `block`:
    /// the mapper produces this pass's head→core assignment (reading the
    /// actual per-head Q+K spike loads for
    /// [`LoadBalanced`](crate::accel::MappingPolicy::LoadBalanced)) and
    /// the topology decides each core's comparator width.
    ///
    /// Each core streams its assigned heads back to back through its own
    /// comparator array, so cycles are charged per **core** (one ceiling
    /// over the core's total comparator steps and one threshold compare
    /// per assigned channel — never worse than the serial single-array
    /// cost under a replicated fabric), and the phase finishes when the
    /// busiest core does (cycles = max over cores) while op counts (SOPs,
    /// adds, compares, SRAM traffic) sum over all heads. Outputs are
    /// bit-identical for every assignment because the mask is
    /// channel-local: every head writes a disjoint slice of the output,
    /// so values never depend on which core (or thread) ran which head.
    ///
    /// `pool: Some(_)` hands the non-first cores to the persistent worker
    /// pool (no thread spawn; if every worker is busy the caller runs
    /// them inline at scope end); `None` walks all cores on the calling
    /// thread.
    ///
    /// This is also the dual-engine dispatch point: `cfg.engine`
    /// ([`EngineSelect`]) resolves per head — from the same measured
    /// Q+K spike loads the LoadBalanced mapper reads — whether that
    /// head's intersection runs on the CSR merge-join or the
    /// word-parallel bitmap engine, and the cycle/SRAM accounting
    /// charges whichever engine ran each head.
    #[allow(clippy::too_many_arguments)]
    pub fn run_mapped_into(
        &self,
        q: &EncodedSpikes,
        k: &EncodedSpikes,
        v: &EncodedSpikes,
        cfg: &AccelConfig,
        mapper: &Mapper,
        block: usize,
        pool: Option<&WorkerPool>,
        scratch: &mut ExecScratch,
    ) -> (SmamOutput, UnitStats) {
        Self::check_shapes(q, k, v);
        let c = q.channels;
        let l = q.tokens;
        let heads = mapper.effective_heads(c);
        let cores = mapper.effective_cores(heads);
        let adaptive = matches!(cfg.engine, EngineSelect::Adaptive { .. });
        // Per-head Q+K spike loads: the LoadBalanced assignment and the
        // adaptive engine selector share one measurement pass.
        let mut loads = scratch.take_u64(0);
        if adaptive
            || (matches!(mapper.policy, crate::accel::MappingPolicy::LoadBalanced) && cores > 1)
        {
            Mapper::head_loads_into(q, k, heads, &mut loads);
        }
        let mut assign = scratch.take_usize();
        mapper.assign_heads_into(block, heads, cores, &loads, &mut assign);

        // Resolve the engine per head from its measured spike density
        // (`load / (2 * head_channels * L)`; an empty head divides by
        // nothing and is defined as density 0.0 => CSR).
        let mut bitmap_heads = scratch.take_bool(heads);
        let mut any_bitmap = false;
        match cfg.engine {
            EngineSelect::Csr => {}
            EngineSelect::Bitmap => {
                bitmap_heads.fill(true);
                any_bitmap = true;
            }
            EngineSelect::Adaptive { .. } => {
                for (h, flag) in bitmap_heads.iter_mut().enumerate() {
                    let span = HeadShard::head_channels(h, heads, c);
                    let positions = 2 * span.len() * l;
                    let density = if positions == 0 {
                        0.0
                    } else {
                        loads[h] as f64 / positions as f64 // as-ok: measured-density ratio
                    };
                    *flag = cfg.engine.pick(density) == EngineKind::Bitmap;
                    any_bitmap |= *flag;
                }
            }
        }

        // Mixed-plan Q/K SRAM traffic: bitmap heads read their packed
        // word rows (2 tensors x words/row x channels), CSR heads their
        // per-spike addresses.
        let qk_reads = if any_bitmap {
            let wpr = l.div_ceil(WORD_BITS) as u64; // as-ok: widening for 64-bit stat/cycle math
            let mut reads = 0u64;
            for h in 0..heads {
                let span = HeadShard::head_channels(h, heads, c);
                reads += if bitmap_heads[h] {
                    2 * wpr * span.len() as u64 // as-ok: widening for 64-bit stat/cycle math
                } else {
                    loads[h]
                };
            }
            Some(reads)
        } else {
            None
        };

        // Materialize the packed Q/K bitmaps once per pass iff any head
        // picked the word engine (scratch-pooled: steady state reuses
        // the word arenas).
        let qk_bitmaps = if any_bitmap {
            let mut qb = scratch.take_bitmap(c, l);
            qb.fill_from_encoded(q);
            let mut kb = scratch.take_bitmap(c, l);
            kb.fill_from_encoded(k);
            Some((qb, kb))
        } else {
            None
        };
        let plan = EnginePlan {
            bitmap_heads: &bitmap_heads,
            bitmaps: qk_bitmaps.as_ref().map(|(qb, kb)| (qb, kb)),
            qk_reads,
        };

        let out = self.run_assigned_into(
            q,
            k,
            v,
            mapper.comparators_per_core(cfg) as u64, // as-ok: widening for 64-bit stat/cycle math
            heads,
            cores,
            &assign,
            &plan,
            pool,
            scratch,
        );
        if let Some((qb, kb)) = qk_bitmaps {
            scratch.put_bitmap(qb);
            scratch.put_bitmap(kb);
        }
        scratch.put_bool(bitmap_heads);
        scratch.put_usize(assign);
        scratch.put_u64(loads);
        out
    }

    /// The shared execution path behind [`Self::run_sharded_into`] and
    /// [`Self::run_mapped_into`]: run `heads` contiguous head ranges on
    /// `cores` comparator arrays of `comps` comparators each, with head
    /// `h` on core `assign[h]`.
    #[allow(clippy::too_many_arguments)]
    fn run_assigned_into(
        &self,
        q: &EncodedSpikes,
        k: &EncodedSpikes,
        v: &EncodedSpikes,
        comps: u64,
        heads: usize,
        cores: usize,
        assign: &[usize],
        plan: &EnginePlan<'_>,
        pool: Option<&WorkerPool>,
        scratch: &mut ExecScratch,
    ) -> (SmamOutput, UnitStats) {
        let c = q.channels;
        debug_assert_eq!(assign.len(), heads);
        debug_assert!(assign.iter().all(|&core| core < cores));
        // Spike counts read once up front (dispatch used to re-count them
        // for the spawn decision and again for the stats).
        let q_spikes = q.count_spikes() as u64; // as-ok: widening for 64-bit stat/cycle math
        let k_spikes = k.count_spikes() as u64; // as-ok: widening for 64-bit stat/cycle math

        let mut mask = scratch.take_bool(c);
        let mut acc = scratch.take_u32(c);
        // Interleaved per-head [steps, matches] tallies.
        let mut head_tally = scratch.take_u64(2 * heads);

        {
            // Carve the shared outputs into disjoint per-head jobs; heads
            // partition the channel range contiguously and in order.
            // The HeadJob scaffolding borrows from this stack frame, so it
            // cannot live in the 'static ExecScratch pool; heads/cores are
            // tiny (<= fabric width) and the Vecs die with the scope.
            let mut jobs: Vec<HeadJob<'_>> = Vec::with_capacity(heads); // alloc-ok: lifetime-bound dispatch scaffolding
            let mut mask_rest = &mut mask[..];
            let mut acc_rest = &mut acc[..];
            for (h, tally) in head_tally.chunks_mut(2).enumerate() {
                let range = HeadShard::head_channels(h, heads, c);
                let (m, rest) = std::mem::take(&mut mask_rest).split_at_mut(range.len());
                mask_rest = rest;
                let (a, rest) = std::mem::take(&mut acc_rest).split_at_mut(range.len());
                acc_rest = rest;
                let bitmap = plan.bitmap_heads.get(h).copied().unwrap_or(false);
                jobs.push(HeadJob { range, mask: m, acc: a, tally, bitmap });
            }
            let mut per_core: Vec<Vec<HeadJob<'_>>> = (0..cores).map(|_| Vec::new()).collect(); // alloc-ok: lifetime-bound dispatch scaffolding
            for (h, job) in jobs.into_iter().enumerate() {
                per_core[assign[h]].push(job);
            }

            let me = *self;
            // Copyable per-job dispatcher so every core closure (pool
            // workers and the calling thread alike) routes each head to
            // the engine its plan flag picked.
            let bitmaps = plan.bitmaps;
            let run_job = move |job: &mut HeadJob<'_>| {
                if job.bitmap {
                    let (qb, kb) = bitmaps.expect("bitmap head without materialized bitmaps");
                    me.intersect_head_bitmap(qb, kb, job);
                } else {
                    me.intersect_head(q, k, job);
                }
            };
            match pool {
                Some(pool) if cores > 1 => {
                    let mut rest = per_core.into_iter();
                    let mut own = rest.next().expect("at least one core");
                    pool.scope(|s| {
                        for mut core_jobs in rest {
                            s.spawn(move || {
                                for job in &mut core_jobs {
                                    run_job(job);
                                }
                            });
                        }
                        // Core 0 runs on the calling thread.
                        for job in &mut own {
                            run_job(job);
                        }
                    });
                }
                _ => {
                    for mut core_jobs in per_core {
                        for job in &mut core_jobs {
                            run_job(job);
                        }
                    }
                }
            }
        }

        // Deterministic merge in head (== channel) order; cycles are the
        // busiest core's total. Per-core cost: its comparator steps spread
        // over its array, plus one threshold compare per assigned channel
        // (Fig. 4(b)). With one core this is exactly the serial
        // single-array formula, and a core's cost never exceeds it (its
        // steps/channels are subsets).
        let (mut steps, mut matches) = (0u64, 0u64);
        for h in 0..heads {
            steps += head_tally[2 * h];
            matches += head_tally[2 * h + 1];
        }
        let mut cycles = 0u64;
        for core in 0..cores {
            let (mut core_steps, mut core_channels) = (0u64, 0u64);
            for h in (0..heads).filter(|&h| assign[h] == core) {
                core_steps += head_tally[2 * h];
                core_channels += HeadShard::head_channels(h, heads, c).len() as u64; // as-ok: widening for 64-bit stat/cycle math
            }
            cycles = cycles.max(div_ceil(core_steps, comps).max(1) + div_ceil(core_channels, comps));
        }
        scratch.put_u64(head_tally);

        let mut masked_v = scratch.take_enc(v.channels, v.tokens);
        for ch in 0..c {
            if mask[ch] {
                masked_v.extend_channel_from(ch, v, ch);
            }
        }

        let retained = masked_v.count_spikes() as u64; // as-ok: widening for 64-bit stat/cycle math
        // Under a mixed engine plan the Q/K read traffic is word-based
        // for bitmap heads (precomputed by the caller); the workload
        // SOPs are engine-independent.
        let qk_reads = plan.qk_reads.unwrap_or(q_spikes + k_spikes);
        let stats = UnitStats {
            cycles,
            // SOPs: every Q/K spike traverses the comparator once; every
            // retained V spike traverses the mask gate.
            sops: q_spikes + k_spikes + retained,
            adds: matches, // token-dim accumulation increments
            cmps: steps + c as u64, // as-ok: widening for 64-bit stat/cycle math
            sram_reads: qk_reads + retained,
            sram_writes: retained,
            ..Default::default()
        };
        (SmamOutput { mask, acc, masked_v }, stats)
    }

    /// Dense bitmap baseline: walks all C*L Hadamard positions (ablation A1).
    /// Allocates its outputs; the bitmap-mode hot loop uses
    /// [`Self::run_dense_baseline_into`].
    pub fn run_dense_baseline(
        &self,
        q: &EncodedSpikes,
        k: &EncodedSpikes,
        v: &EncodedSpikes,
        cfg: &AccelConfig,
    ) -> (SmamOutput, UnitStats) {
        self.run_dense_baseline_into(q, k, v, cfg, &mut ExecScratch::new())
    }

    /// [`Self::run_dense_baseline`] with the output storage recycled
    /// through `scratch`, so a long-lived bitmap-mode accelerator keeps
    /// the same take/put balance as the encoded datapath.
    pub fn run_dense_baseline_into(
        &self,
        q: &EncodedSpikes,
        k: &EncodedSpikes,
        v: &EncodedSpikes,
        cfg: &AccelConfig,
        scratch: &mut ExecScratch,
    ) -> (SmamOutput, UnitStats) {
        Self::check_shapes(q, k, v);
        let (qb, kb) = (q.to_bitmap(), k.to_bitmap());
        let c = q.channels;
        let l = q.tokens;
        let mut mask = scratch.take_bool(c);
        let mut acc = scratch.take_u32(c);
        let mut masked_v = scratch.take_enc(v.channels, v.tokens);
        for ch in 0..c {
            let mut count = 0u32;
            for t in 0..l {
                if qb.get(ch, t) && kb.get(ch, t) {
                    count += 1;
                }
            }
            acc[ch] = count;
            mask[ch] = count >= self.v_th;
            if mask[ch] {
                masked_v.extend_channel_from(ch, v, ch);
            }
        }
        let positions = (c * l) as u64; // as-ok: widening for 64-bit stat/cycle math
        let retained = masked_v.count_spikes() as u64; // as-ok: widening for 64-bit stat/cycle math
        let stats = UnitStats {
            cycles: div_ceil(positions, cfg.smam_comparators as u64).max(1) // as-ok: widening for 64-bit stat/cycle math
                + div_ceil(c as u64, cfg.smam_comparators as u64), // as-ok: widening for 64-bit stat/cycle math
            sops: q.count_spikes() as u64 + k.count_spikes() as u64 + retained, // as-ok: widening for 64-bit stat/cycle math
            adds: acc.iter().map(|&x| x as u64).sum(), // as-ok: widening for 64-bit stat/cycle math
            cmps: positions + c as u64, // as-ok: widening for 64-bit stat/cycle math
            sram_reads: 2 * positions + retained,
            sram_writes: retained,
            ..Default::default()
        };
        (SmamOutput { mask, acc, masked_v }, stats)
    }

    /// Incremental (decode-mode) SDSA: mask the single new token's Q row
    /// against the cached K stream and aggregate the attended cached V
    /// rows — the autoregressive twin of [`Self::run_mapped_into`].
    ///
    /// Causal row-wise semantics (the decoder variant of Fig. 4): for the
    /// new position and each cached position `p` (the cache already holds
    /// the new token's own K/V row, so `p` ranges over the full causal
    /// prefix *including self*), per head `h` (a contiguous channel range,
    /// [`HeadShard::head_channels`]) the comparator counts
    /// `|Q_new ∩ K_p|` restricted to `h`'s channels; when the count
    /// reaches the mask-neuron threshold `v_th`, position `p` is
    /// *attended* for head `h` and its V spikes in `h`'s channels are
    /// OR-ed into the output row. Cost is O(cache length) per token — the
    /// whole point of caching K/V instead of recomputing the prefix.
    ///
    /// Dual-engine: `cfg.engine` resolves once per step from the measured
    /// Q-plus-cached-K density. The CSR engine runs one two-pointer merge
    /// per cached position over the full channel axis, bucketing matches
    /// per head on the fly (heads are sorted contiguous ranges, so one
    /// monotone boundary pointer suffices); the bitmap engine ANDs the
    /// packed Q row against each cached K word row with per-head masked
    /// popcounts, `words_per_row` word ops per position. Output spikes,
    /// per-head counts, `sops` and `adds` are bit-identical between
    /// engines by construction; comparator steps and SRAM traffic charge
    /// whichever engine ran. Decode is latency-bound on one token, so the
    /// step runs on a single resident comparator array (no head→core
    /// sharding): `cycles = ceil(steps/comps) + ceil(heads·positions/
    /// comps) + ceil(v_ops/comps)`.
    ///
    /// Returns the `[D, 1]` output spike row and the step's charges.
    pub fn run_incremental_into(
        &self,
        q: &EncodedSpikes,
        cache: &KvCacheStream,
        heads: usize,
        cfg: &AccelConfig,
        scratch: &mut ExecScratch,
    ) -> (EncodedSpikes, UnitStats) {
        let d = q.channels;
        assert_eq!(q.tokens, 1, "incremental SDSA takes a single-token Q row");
        assert_eq!(cache.dim(), d, "Q/cache channel mismatch");
        let n = cache.len();
        assert!(n > 0, "the cache must already hold the new token's own K/V row");
        let heads = heads.max(1).min(d.max(1));
        let comps = cfg.smam_comparators as u64; // as-ok: widening for 64-bit stat/cycle math

        let q_spikes = q.count_spikes() as u64; // as-ok: widening for 64-bit stat/cycle math
        let k_cached = cache.k_spikes();
        // Engine resolution from the step's measured density over the
        // Q row plus all cached K rows (empty work => 0.0 => CSR).
        let positions_total = d * (n + 1);
        let density = if positions_total == 0 {
            0.0
        } else {
            (q_spikes + k_cached) as f64 / positions_total as f64 // as-ok: measured-density ratio
        };
        let engine = cfg.engine.pick(density);

        // Sorted spiking channels of the Q row, streamed once per step.
        let mut q_row = scratch.take_usize();
        q_row.clear();
        q_row.extend((0..d).filter(|&c| q.channel_len(c) > 0));
        // Exclusive end channel of each head, for monotone head lookup.
        let mut head_end = scratch.take_usize();
        head_end.clear();
        head_end.extend((0..heads).map(|h| HeadShard::head_channels(h, heads, d).end));

        let mut head_acc = scratch.take_u32(heads);
        let mut head_fire = scratch.take_bool(heads);
        let mut out_mask = scratch.take_bool(d);
        let (mut steps, mut matches, mut retained, mut v_ops) = (0u64, 0u64, 0u64, 0u64);

        match engine {
            EngineKind::Csr => {
                for p in 0..n {
                    head_acc[..heads].fill(0);
                    let kl = cache.k_row(p);
                    let (mut i, mut j, mut cur) = (0usize, 0usize, 0usize);
                    while i < q_row.len() && j < kl.len() {
                        steps += 1;
                        let (qc, kc) = (q_row[i], usize::from(kl[j]));
                        match qc.cmp(&kc) {
                            std::cmp::Ordering::Equal => {
                                matches += 1;
                                while qc >= head_end[cur] {
                                    cur += 1;
                                }
                                head_acc[cur] += 1;
                                i += 1;
                                j += 1;
                            }
                            std::cmp::Ordering::Less => i += 1,
                            std::cmp::Ordering::Greater => j += 1,
                        }
                    }
                    let mut any = false;
                    for h in 0..heads {
                        head_fire[h] = head_acc[h] >= self.v_th;
                        any |= head_fire[h];
                    }
                    if any {
                        let vl = cache.v_row(p);
                        v_ops += vl.len() as u64; // as-ok: widening for 64-bit stat/cycle math
                        let mut cur = 0usize;
                        for &vc in vl {
                            let c = usize::from(vc);
                            while c >= head_end[cur] {
                                cur += 1;
                            }
                            if head_fire[cur] {
                                out_mask[c] = true;
                                retained += 1;
                            }
                        }
                    }
                }
            }
            EngineKind::Bitmap => {
                let wpr = cache.words_per_row();
                // Packed Q row + per-head channel masks, built once per step.
                let mut q_words = scratch.take_u64(wpr);
                let mut head_masks = scratch.take_u64(heads * wpr);
                for &c in q_row.iter() {
                    q_words[c / WORD_BITS] |= 1u64 << (c % WORD_BITS);
                }
                for h in 0..heads {
                    for c in HeadShard::head_channels(h, heads, d) {
                        head_masks[h * wpr + c / WORD_BITS] |= 1u64 << (c % WORD_BITS);
                    }
                }
                for p in 0..n {
                    // One AND+popcount word pass per cached row; per-head
                    // bucketing is wiring in the popcount tree, so the
                    // charge matches `intersect_head_bitmap`'s per-row
                    // word-op count.
                    steps += wpr as u64; // as-ok: widening for 64-bit stat/cycle math
                    let kw = cache.k_word_row(p);
                    let mut any = false;
                    for h in 0..heads {
                        let hm = &head_masks[h * wpr..(h + 1) * wpr];
                        let mut count = 0u32;
                        for w in 0..wpr {
                            count += ((q_words[w] & kw[w]) & hm[w]).count_ones();
                        }
                        matches += u64::from(count);
                        head_acc[h] = count;
                        head_fire[h] = count >= self.v_th;
                        any |= head_fire[h];
                    }
                    if any {
                        let vw = cache.v_word_row(p);
                        v_ops += wpr as u64; // as-ok: widening for 64-bit stat/cycle math
                        for w in 0..wpr {
                            let mut fired = 0u64;
                            for h in 0..heads {
                                if head_fire[h] {
                                    fired |= head_masks[h * wpr + w];
                                }
                            }
                            let mut bits = vw[w] & fired;
                            retained += u64::from(bits.count_ones());
                            while bits != 0 {
                                let b = bits.trailing_zeros() as usize; // as-ok: u32 bit index widening
                                out_mask[w * WORD_BITS + b] = true;
                                bits &= bits - 1;
                            }
                        }
                    }
                }
                scratch.put_u64(head_masks);
                scratch.put_u64(q_words);
            }
        }

        let mut out = scratch.take_enc(d, 1);
        for (c, &m) in out_mask.iter().enumerate() {
            if m {
                out.push(c, 0);
            }
        }
        let out_spikes = out.count_spikes() as u64; // as-ok: widening for 64-bit stat/cycle math

        let threshold_cmps = (heads * n) as u64; // as-ok: widening for 64-bit stat/cycle math
        let qk_reads = match engine {
            // Q row pinned in the comparator-side register file (read
            // once); every cached K spike streams through per step.
            EngineKind::Csr => q_spikes + k_cached,
            EngineKind::Bitmap => {
                let wpr = cache.words_per_row() as u64; // as-ok: widening for 64-bit stat/cycle math
                wpr + wpr * n as u64 // as-ok: widening for 64-bit stat/cycle math
            }
        };
        let stats = UnitStats {
            cycles: div_ceil(steps, comps).max(1)
                + div_ceil(threshold_cmps, comps)
                + div_ceil(v_ops, comps),
            // Workload SOPs are engine-independent: the Q row and every
            // cached K spike traverse the comparator, retained V spikes
            // traverse the mask gate.
            sops: q_spikes + k_cached + retained,
            adds: matches,
            cmps: steps + threshold_cmps,
            sram_reads: qk_reads + v_ops,
            sram_writes: out_spikes,
            ..Default::default()
        };

        scratch.put_bool(out_mask);
        scratch.put_bool(head_fire);
        scratch.put_u32(head_acc);
        scratch.put_usize(head_end);
        scratch.put_usize(q_row);
        (out, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spike::SpikeMatrix;
    use crate::util::Prng;

    fn random_encoded(rng: &mut Prng, c: usize, l: usize, p: f64) -> EncodedSpikes {
        let mut m = SpikeMatrix::zeros(c, l);
        for ci in 0..c {
            for li in 0..l {
                if rng.bernoulli(p) {
                    m.set(ci, li, true);
                }
            }
        }
        EncodedSpikes::from_bitmap(&m)
    }

    #[test]
    fn intersection_counts_match_hadamard_sum() {
        let mut rng = Prng::new(7);
        let cfg = AccelConfig::small();
        let smam = SpikeMaskAddModule::new(2);
        for &p in &[0.1, 0.3, 0.7] {
            let q = random_encoded(&mut rng, 6, 64, p);
            let k = random_encoded(&mut rng, 6, 64, p);
            let v = random_encoded(&mut rng, 6, 64, p);
            let (out, _) = smam.run(&q, &k, &v, &cfg);
            let (qb, kb) = (q.to_bitmap(), k.to_bitmap());
            for ch in 0..6 {
                let want: u32 = (0..64).filter(|&t| qb.get(ch, t) && kb.get(ch, t)).count() as u32;
                assert_eq!(out.acc[ch], want, "channel {ch}");
                assert_eq!(out.mask[ch], want >= 2);
            }
        }
    }

    #[test]
    fn masked_v_clears_or_retains_whole_channels() {
        let mut rng = Prng::new(8);
        let cfg = AccelConfig::small();
        let q = random_encoded(&mut rng, 4, 32, 0.5);
        let k = random_encoded(&mut rng, 4, 32, 0.5);
        let v = random_encoded(&mut rng, 4, 32, 0.4);
        let (out, _) = SpikeMaskAddModule::new(3).run(&q, &k, &v, &cfg);
        for ch in 0..4 {
            if out.mask[ch] {
                assert_eq!(out.masked_v.channel_addrs(ch), v.channel_addrs(ch));
            } else {
                assert!(out.masked_v.channel_addrs(ch).is_empty());
            }
        }
    }

    #[test]
    fn dense_baseline_agrees() {
        let mut rng = Prng::new(9);
        let cfg = AccelConfig::small();
        let smam = SpikeMaskAddModule::new(2);
        let q = random_encoded(&mut rng, 8, 64, 0.2);
        let k = random_encoded(&mut rng, 8, 64, 0.2);
        let v = random_encoded(&mut rng, 8, 64, 0.2);
        let (a, s_sparse) = smam.run(&q, &k, &v, &cfg);
        let (b, s_dense) = smam.run_dense_baseline(&q, &k, &v, &cfg);
        assert_eq!(a.mask, b.mask);
        assert_eq!(a.acc, b.acc);
        assert_eq!(a.masked_v, b.masked_v);
        // At 80% sparsity the encoded path must be far cheaper.
        assert!(s_sparse.cycles < s_dense.cycles);
    }

    #[test]
    fn comparator_steps_bounded_by_list_lengths() {
        let mut rng = Prng::new(10);
        let cfg = AccelConfig::paper();
        let q = random_encoded(&mut rng, 1, 64, 0.5);
        let k = random_encoded(&mut rng, 1, 64, 0.5);
        let v = EncodedSpikes::empty(1, 64);
        let (_, stats) = SpikeMaskAddModule::new(1).run(&q, &k, &v, &cfg);
        let bound = (q.count_spikes() + k.count_spikes()) as u64 + 1;
        assert!(stats.cmps <= bound + 1, "cmps {} > bound {}", stats.cmps, bound);
    }

    #[test]
    fn empty_q_or_k_never_fires() {
        let cfg = AccelConfig::small();
        let q = EncodedSpikes::empty(3, 16);
        let mut k = EncodedSpikes::empty(3, 16);
        k.push(0, 5);
        let mut v = EncodedSpikes::empty(3, 16);
        v.push(0, 1);
        let (out, _) = SpikeMaskAddModule::new(1).run(&q, &k, &v, &cfg);
        assert!(out.mask.iter().all(|&m| !m));
        assert_eq!(out.masked_v.count_spikes(), 0);
    }

    #[test]
    fn threshold_zero_always_fires() {
        let cfg = AccelConfig::small();
        let q = EncodedSpikes::empty(2, 8);
        let k = EncodedSpikes::empty(2, 8);
        let mut v = EncodedSpikes::empty(2, 8);
        v.push(1, 3);
        let (out, _) = SpikeMaskAddModule::new(0).run(&q, &k, &v, &cfg);
        assert!(out.mask.iter().all(|&m| m));
        assert_eq!(out.masked_v.channel_addrs(1), &[3u16][..]);
    }

    #[test]
    fn sharded_outputs_bit_identical_to_serial() {
        let mut rng = Prng::new(21);
        let cfg = AccelConfig::paper();
        let smam = SpikeMaskAddModule::new(2);
        let q = random_encoded(&mut rng, 384, 64, 0.2);
        let k = random_encoded(&mut rng, 384, 64, 0.2);
        let v = random_encoded(&mut rng, 384, 64, 0.2);
        let (serial, s_serial) = smam.run(&q, &k, &v, &cfg);
        for shard in [
            HeadShard { heads: 8, cores: 2 },
            HeadShard { heads: 8, cores: 8 },
            HeadShard { heads: 3, cores: 2 }, // uneven head split
            HeadShard { heads: 500, cores: 4 }, // more heads than channels: clamped
        ] {
            let (out, st) = smam.run_sharded(&q, &k, &v, &cfg, shard);
            assert_eq!(out.mask, serial.mask, "{shard:?}");
            assert_eq!(out.acc, serial.acc, "{shard:?}");
            assert_eq!(out.masked_v, serial.masked_v, "{shard:?}");
            // Same work, concurrent arrays: ops identical, cycles no worse
            // than one core running all heads back to back.
            assert_eq!(st.sops, s_serial.sops, "{shard:?}");
            assert_eq!(st.adds, s_serial.adds, "{shard:?}");
            assert_eq!(st.cmps, s_serial.cmps, "{shard:?}");
        }
    }

    #[test]
    fn pool_dispatch_bit_identical_with_recycled_scratch() {
        let mut rng = Prng::new(24);
        let cfg = AccelConfig::paper();
        let smam = SpikeMaskAddModule::new(2);
        let q = random_encoded(&mut rng, 384, 64, 0.3);
        let k = random_encoded(&mut rng, 384, 64, 0.3);
        let v = random_encoded(&mut rng, 384, 64, 0.3);
        let shard = HeadShard { heads: 8, cores: 4 };
        let (want, want_stats) = smam.run_sharded(&q, &k, &v, &cfg, shard);
        let pool = WorkerPool::new(3);
        let mut scratch = ExecScratch::new();
        let mut warm_misses = 0;
        for round in 0..3 {
            let (out, stats) =
                smam.run_sharded_into(&q, &k, &v, &cfg, shard, Some(&pool), &mut scratch);
            assert_eq!(out.mask, want.mask, "round {round}");
            assert_eq!(out.acc, want.acc, "round {round}");
            assert_eq!(out.masked_v, want.masked_v, "round {round}");
            assert_eq!(stats, want_stats, "round {round}");
            // Hand the outputs back, as the SDEB core does.
            scratch.put_bool(out.mask);
            scratch.put_u32(out.acc);
            scratch.put_enc(out.masked_v);
            if round == 0 {
                warm_misses = scratch.stats().misses;
            }
        }
        assert_eq!(
            scratch.stats().misses,
            warm_misses,
            "warm SDSA passes must not allocate scratch objects"
        );
    }

    #[test]
    fn sharded_degenerate_plan_matches_serial_cycles() {
        let mut rng = Prng::new(22);
        let cfg = AccelConfig::small();
        let smam = SpikeMaskAddModule::new(2);
        let q = random_encoded(&mut rng, 64, 64, 0.3);
        let k = random_encoded(&mut rng, 64, 64, 0.3);
        let v = random_encoded(&mut rng, 64, 64, 0.3);
        let (_, s1) = smam.run(&q, &k, &v, &cfg);
        let (_, s2) = smam.run_sharded(&q, &k, &v, &cfg, HeadShard::serial());
        assert_eq!(s1, s2, "heads=1/cores=1 must reproduce serial accounting");
    }

    #[test]
    fn sharding_across_cores_cuts_cycles() {
        let mut rng = Prng::new(23);
        let cfg = AccelConfig::paper();
        let smam = SpikeMaskAddModule::new(2);
        let q = random_encoded(&mut rng, 384, 64, 0.3);
        let k = random_encoded(&mut rng, 384, 64, 0.3);
        let v = random_encoded(&mut rng, 384, 64, 0.3);
        let (_, one_core) = smam.run_sharded(&q, &k, &v, &cfg, HeadShard { heads: 8, cores: 1 });
        let (_, two_core) = smam.run_sharded(&q, &k, &v, &cfg, HeadShard { heads: 8, cores: 2 });
        assert!(
            two_core.cycles < one_core.cycles,
            "2 cores {} !< 1 core {}",
            two_core.cycles,
            one_core.cycles
        );
    }

    #[test]
    fn mapped_policies_bit_identical_values_any_assignment() {
        use crate::accel::{Mapper, MappingPolicy};
        use crate::hw::{CoreTopology, FabricPartition};
        let mut rng = Prng::new(25);
        let cfg = AccelConfig::paper();
        let smam = SpikeMaskAddModule::new(2);
        let q = random_encoded(&mut rng, 384, 64, 0.25);
        let k = random_encoded(&mut rng, 384, 64, 0.25);
        let v = random_encoded(&mut rng, 384, 64, 0.25);
        let (want, want_stats) = smam.run(&q, &k, &v, &cfg);
        let mut scratch = ExecScratch::new();
        for policy in MappingPolicy::ALL {
            for cores in [1usize, 2, 4, 8] {
                for partition in [FabricPartition::Replicated, FabricPartition::Split] {
                    let topo = CoreTopology {
                        partition,
                        ..CoreTopology::with_sdeb_cores(cores)
                    };
                    let mapper = Mapper::new(8, topo, policy);
                    let (out, st) =
                        smam.run_mapped_into(&q, &k, &v, &cfg, &mapper, 1, None, &mut scratch);
                    assert_eq!(out.mask, want.mask, "{policy:?} cores={cores}");
                    assert_eq!(out.acc, want.acc, "{policy:?} cores={cores}");
                    assert_eq!(out.masked_v, want.masked_v, "{policy:?} cores={cores}");
                    // Work is conserved under every assignment.
                    assert_eq!(st.sops, want_stats.sops, "{policy:?} cores={cores}");
                    assert_eq!(st.adds, want_stats.adds, "{policy:?} cores={cores}");
                    assert_eq!(st.cmps, want_stats.cmps, "{policy:?} cores={cores}");
                    scratch.put_bool(out.mask);
                    scratch.put_u32(out.acc);
                    scratch.put_enc(out.masked_v);
                }
            }
        }
    }

    #[test]
    fn mapped_round_robin_matches_legacy_shard_accounting() {
        use crate::accel::{Mapper, MappingPolicy};
        use crate::hw::CoreTopology;
        let mut rng = Prng::new(26);
        let cfg = AccelConfig::paper();
        let smam = SpikeMaskAddModule::new(2);
        let q = random_encoded(&mut rng, 384, 64, 0.3);
        let k = random_encoded(&mut rng, 384, 64, 0.3);
        let v = random_encoded(&mut rng, 384, 64, 0.3);
        for cores in [1usize, 2, 4] {
            let (want, want_st) =
                smam.run_sharded(&q, &k, &v, &cfg, HeadShard { heads: 8, cores });
            let mapper = Mapper::new(
                8,
                CoreTopology::with_sdeb_cores(cores),
                MappingPolicy::HeadRoundRobin,
            );
            let mut scratch = ExecScratch::new();
            let (out, st) = smam.run_mapped_into(&q, &k, &v, &cfg, &mapper, 0, None, &mut scratch);
            assert_eq!(out.mask, want.mask, "cores={cores}");
            assert_eq!(st, want_st, "round-robin mapping must reproduce HeadShard cycles");
        }
    }

    #[test]
    fn load_balanced_never_slower_than_round_robin_busiest_core() {
        use crate::accel::{Mapper, MappingPolicy};
        use crate::hw::CoreTopology;
        let mut rng = Prng::new(27);
        let cfg = AccelConfig::paper();
        let smam = SpikeMaskAddModule::new(2);
        // Skewed tensor: low channels dense, high channels sparse, so
        // round-robin's static split is measurably unbalanced.
        let mut mq = SpikeMatrix::zeros(384, 64);
        let mut mk = SpikeMatrix::zeros(384, 64);
        for c in 0..384 {
            let p = if c < 96 { 0.8 } else { 0.05 };
            for t in 0..64 {
                if rng.bernoulli(p) {
                    mq.set(c, t, true);
                }
                if rng.bernoulli(p) {
                    mk.set(c, t, true);
                }
            }
        }
        let q = EncodedSpikes::from_bitmap(&mq);
        let k = EncodedSpikes::from_bitmap(&mk);
        let v = random_encoded(&mut rng, 384, 64, 0.2);
        let topo = CoreTopology::with_sdeb_cores(4);
        let mut scratch = ExecScratch::new();
        let rr = Mapper::new(8, topo, MappingPolicy::HeadRoundRobin);
        let lb = Mapper::new(8, topo, MappingPolicy::LoadBalanced);
        let (o1, s_rr) = smam.run_mapped_into(&q, &k, &v, &cfg, &rr, 0, None, &mut scratch);
        let (o2, s_lb) = smam.run_mapped_into(&q, &k, &v, &cfg, &lb, 0, None, &mut scratch);
        assert_eq!(o1.mask, o2.mask);
        assert_eq!(o1.masked_v, o2.masked_v);
        assert!(
            s_lb.cycles <= s_rr.cycles,
            "LPT {} !<= round-robin {}",
            s_lb.cycles,
            s_rr.cycles
        );
    }

    #[test]
    fn head_channel_ranges_partition_exactly() {
        for (heads, channels) in [(1usize, 64usize), (8, 384), (3, 64), (5, 7)] {
            let mut next = 0;
            for h in 0..heads {
                let r = HeadShard::head_channels(h, heads, channels);
                assert_eq!(r.start, next, "heads={heads} channels={channels} h={h}");
                next = r.end;
            }
            assert_eq!(next, channels);
        }
    }

    #[test]
    fn bitmap_engine_bit_identical_values() {
        use crate::accel::{Mapper, MappingPolicy};
        use crate::hw::CoreTopology;
        let mut rng = Prng::new(31);
        let cfg = AccelConfig::small();
        let mut cfg_bm = cfg;
        cfg_bm.engine = crate::hw::EngineSelect::Bitmap;
        let smam = SpikeMaskAddModule::new(2);
        let mapper = Mapper::new(8, CoreTopology::with_sdeb_cores(2), MappingPolicy::HeadRoundRobin);
        let mut scratch = ExecScratch::new();
        for &p in &[0.0, 0.05, 0.5, 1.0] {
            let q = random_encoded(&mut rng, 64, 70, p); // 2 words/row
            let k = random_encoded(&mut rng, 64, 70, p);
            let v = random_encoded(&mut rng, 64, 70, p);
            let (want, want_st) = smam.run(&q, &k, &v, &cfg);
            let (out, st) =
                smam.run_mapped_into(&q, &k, &v, &cfg_bm, &mapper, 0, None, &mut scratch);
            assert_eq!(out.mask, want.mask, "p={p}");
            assert_eq!(out.acc, want.acc, "p={p}");
            assert_eq!(out.masked_v, want.masked_v, "p={p}");
            // Matches (adds) and SOPs are workload properties, identical
            // across engines; cmps/reads charge word ops instead.
            assert_eq!(st.adds, want_st.adds, "p={p}");
            assert_eq!(st.sops, want_st.sops, "p={p}");
            assert_eq!(st.cmps, (64 * 2 + 64) as u64, "word ops + threshold compares");
            scratch.put_bool(out.mask);
            scratch.put_u32(out.acc);
            scratch.put_enc(out.masked_v);
        }
    }

    #[test]
    fn adaptive_engine_mixes_heads_and_stays_bit_identical() {
        use crate::accel::{Mapper, MappingPolicy};
        use crate::hw::{CoreTopology, EngineSelect};
        let mut rng = Prng::new(32);
        // Skewed density: heads over low channels are dense (bitmap
        // territory), heads over high channels nearly empty (CSR).
        let (c, l) = (64usize, 64usize);
        let mut mq = SpikeMatrix::zeros(c, l);
        let mut mk = SpikeMatrix::zeros(c, l);
        for ch in 0..c {
            let p = if ch < 16 { 0.7 } else { 0.01 };
            for t in 0..l {
                if rng.bernoulli(p) {
                    mq.set(ch, t, true);
                }
                if rng.bernoulli(p) {
                    mk.set(ch, t, true);
                }
            }
        }
        let q = EncodedSpikes::from_bitmap(&mq);
        let k = EncodedSpikes::from_bitmap(&mk);
        let v = random_encoded(&mut rng, c, l, 0.2);
        let cfg = AccelConfig::small();
        let mut cfg_ad = cfg;
        cfg_ad.engine = EngineSelect::Adaptive { threshold: 0.25 };
        let smam = SpikeMaskAddModule::new(2);
        let (want, _) = smam.run(&q, &k, &v, &cfg);
        // Confirm the plan genuinely mixes at this threshold: head 0
        // (channels 0..8 at density ~0.7) picks bitmap, head 7 CSR.
        let heads = 8;
        let mut loads = Vec::new();
        Mapper::head_loads_into(&q, &k, heads, &mut loads);
        let dense_head = loads[0] as f64 / (2 * 8 * l) as f64;
        let sparse_head = loads[heads - 1] as f64 / (2 * 8 * l) as f64;
        assert!(dense_head >= 0.25 && sparse_head < 0.25, "test premise: mixed plan");
        let mut scratch = ExecScratch::new();
        for cores in [1usize, 2, 4] {
            for policy in MappingPolicy::ALL {
                let mapper = Mapper::new(heads, CoreTopology::with_sdeb_cores(cores), policy);
                let (out, _) =
                    smam.run_mapped_into(&q, &k, &v, &cfg_ad, &mapper, 0, None, &mut scratch);
                assert_eq!(out.mask, want.mask, "{policy:?} cores={cores}");
                assert_eq!(out.acc, want.acc, "{policy:?} cores={cores}");
                assert_eq!(out.masked_v, want.masked_v, "{policy:?} cores={cores}");
                scratch.put_bool(out.mask);
                scratch.put_u32(out.acc);
                scratch.put_enc(out.masked_v);
            }
        }
    }

    #[test]
    fn engine_cycle_crossover_matches_the_model() {
        use crate::accel::{Mapper, MappingPolicy};
        use crate::hw::{CoreTopology, EngineSelect};
        let mut rng = Prng::new(33);
        let cfg = AccelConfig::small();
        let mut cfg_bm = cfg;
        cfg_bm.engine = EngineSelect::Bitmap;
        let smam = SpikeMaskAddModule::new(2);
        let mapper = Mapper::new(8, CoreTopology::with_sdeb_cores(1), MappingPolicy::HeadRoundRobin);
        let mut scratch = ExecScratch::new();
        // Dense regime: word-parallelism must win.
        let q = random_encoded(&mut rng, 384, 64, 0.9);
        let k = random_encoded(&mut rng, 384, 64, 0.9);
        let v = random_encoded(&mut rng, 384, 64, 0.9);
        let (_, st_csr) = smam.run_mapped_into(&q, &k, &v, &cfg, &mapper, 0, None, &mut scratch);
        let (_, st_bm) = smam.run_mapped_into(&q, &k, &v, &cfg_bm, &mapper, 0, None, &mut scratch);
        assert!(
            st_bm.cycles < st_csr.cycles,
            "dense: bitmap {} !< csr {}",
            st_bm.cycles,
            st_csr.cycles
        );
        // Sparse regime: address streaming must win. (At p=0.005 even
        // the |Q|+|K| upper bound on merge steps stays under the word
        // engine's 384-word floor after the shared div_ceil terms.)
        let q = random_encoded(&mut rng, 384, 64, 0.005);
        let k = random_encoded(&mut rng, 384, 64, 0.005);
        let v = random_encoded(&mut rng, 384, 64, 0.005);
        let (_, st_csr) = smam.run_mapped_into(&q, &k, &v, &cfg, &mapper, 0, None, &mut scratch);
        let (_, st_bm) = smam.run_mapped_into(&q, &k, &v, &cfg_bm, &mapper, 0, None, &mut scratch);
        assert!(
            st_csr.cycles < st_bm.cycles,
            "sparse: csr {} !< bitmap {}",
            st_csr.cycles,
            st_bm.cycles
        );
    }

    #[test]
    fn adaptive_empty_input_selects_csr_and_never_nans() {
        use crate::accel::{Mapper, MappingPolicy};
        use crate::hw::{CoreTopology, EngineSelect};
        let mut cfg = AccelConfig::small();
        cfg.engine = EngineSelect::adaptive();
        let smam = SpikeMaskAddModule::new(1);
        let mapper = Mapper::new(8, CoreTopology::with_sdeb_cores(2), MappingPolicy::LoadBalanced);
        let mut scratch = ExecScratch::new();
        let q = EncodedSpikes::empty(16, 32);
        let k = EncodedSpikes::empty(16, 32);
        let v = EncodedSpikes::empty(16, 32);
        let (out, st) = smam.run_mapped_into(&q, &k, &v, &cfg, &mapper, 0, None, &mut scratch);
        assert!(out.mask.iter().all(|&m| !m));
        assert_eq!(out.masked_v.count_spikes(), 0);
        // All-empty heads have density 0.0 (defined, not NaN) => pure CSR
        // accounting: no word reads appear anywhere in the stats.
        assert_eq!(st.sram_reads, 0);
        assert_eq!(st.sops, 0);
    }

    #[test]
    fn bitmap_engine_steady_state_reuses_scratch() {
        use crate::accel::{Mapper, MappingPolicy};
        use crate::hw::CoreTopology;
        let mut rng = Prng::new(34);
        let mut cfg = AccelConfig::small();
        cfg.engine = crate::hw::EngineSelect::Bitmap;
        let smam = SpikeMaskAddModule::new(2);
        let mapper = Mapper::new(4, CoreTopology::with_sdeb_cores(2), MappingPolicy::HeadRoundRobin);
        let q = random_encoded(&mut rng, 32, 64, 0.5);
        let k = random_encoded(&mut rng, 32, 64, 0.5);
        let v = random_encoded(&mut rng, 32, 64, 0.5);
        let mut scratch = ExecScratch::new();
        let mut warm_misses = 0;
        for round in 0..3 {
            let (out, _) = smam.run_mapped_into(&q, &k, &v, &cfg, &mapper, 0, None, &mut scratch);
            scratch.put_bool(out.mask);
            scratch.put_u32(out.acc);
            scratch.put_enc(out.masked_v);
            if round == 0 {
                warm_misses = scratch.stats().misses;
            }
        }
        assert_eq!(
            scratch.stats().misses,
            warm_misses,
            "warm bitmap-engine passes must not allocate (bitmaps pooled)"
        );
    }

    /// Build a decode cache from dense per-position channel lists.
    fn cache_from_rows(rows_k: &[Vec<usize>], rows_v: &[Vec<usize>], d: usize) -> KvCacheStream {
        let mut s = KvCacheStream::new(rows_k.len().max(1), d);
        for (kr, vr) in rows_k.iter().zip(rows_v) {
            let mut ke = EncodedSpikes::empty(d, 1);
            for &c in kr {
                ke.push(c, 0);
            }
            let mut ve = EncodedSpikes::empty(d, 1);
            for &c in vr {
                ve.push(c, 0);
            }
            s.append_into(&ke, &ve);
        }
        s
    }

    fn random_rows(rng: &mut Prng, n: usize, d: usize, p: f64) -> Vec<Vec<usize>> {
        (0..n).map(|_| (0..d).filter(|_| rng.bernoulli(p)).collect()).collect()
    }

    /// Dense row-wise reference of the decoder SDSA semantics.
    fn naive_incremental(
        q_chans: &[usize],
        s: &KvCacheStream,
        heads: usize,
        v_th: u32,
        d: usize,
    ) -> Vec<bool> {
        let mut q = vec![false; d];
        for &c in q_chans {
            q[c] = true;
        }
        let mut out = vec![false; d];
        for p in 0..s.len() {
            for h in 0..heads {
                let r = HeadShard::head_channels(h, heads, d);
                let count = s
                    .k_row(p)
                    .iter()
                    .filter(|&&kc| r.contains(&usize::from(kc)) && q[usize::from(kc)])
                    .count() as u32;
                if count >= v_th {
                    for &vc in s.v_row(p) {
                        if r.contains(&usize::from(vc)) {
                            out[usize::from(vc)] = true;
                        }
                    }
                }
            }
        }
        out
    }

    fn enc_row(d: usize, chans: &[usize]) -> EncodedSpikes {
        let mut e = EncodedSpikes::empty(d, 1);
        for &c in chans {
            e.push(c, 0);
        }
        e
    }

    #[test]
    fn incremental_matches_naive_rowwise_reference_on_both_engines() {
        use crate::hw::EngineSelect;
        let mut rng = Prng::new(41);
        let mut scratch = ExecScratch::new();
        let d = 70; // 2 words/row: exercises cross-word head boundaries
        for &p in &[0.05, 0.3, 0.8] {
            for &heads in &[1usize, 3, 8] {
                for &v_th in &[1u32, 2, 4] {
                    let rows_k = random_rows(&mut rng, 5, d, p);
                    let rows_v = random_rows(&mut rng, 5, d, p);
                    let cache = cache_from_rows(&rows_k, &rows_v, d);
                    let q_chans: Vec<usize> =
                        (0..d).filter(|_| rng.bernoulli(p)).collect();
                    let q = enc_row(d, &q_chans);
                    let want = naive_incremental(&q_chans, &cache, heads, v_th, d);
                    let smam = SpikeMaskAddModule::new(v_th);
                    let mut cfg_csr = AccelConfig::small();
                    cfg_csr.engine = EngineSelect::Csr;
                    let mut cfg_bm = AccelConfig::small();
                    cfg_bm.engine = EngineSelect::Bitmap;
                    let (o_csr, st_csr) =
                        smam.run_incremental_into(&q, &cache, heads, &cfg_csr, &mut scratch);
                    let (o_bm, st_bm) =
                        smam.run_incremental_into(&q, &cache, heads, &cfg_bm, &mut scratch);
                    let got: Vec<bool> = (0..d).map(|c| o_csr.channel_len(c) > 0).collect();
                    assert_eq!(got, want, "p={p} heads={heads} v_th={v_th}");
                    assert_eq!(o_csr, o_bm, "engines must agree bit-exactly");
                    // Workload charges are engine-independent; the
                    // comparator-step and SRAM charges are not.
                    assert_eq!(st_csr.sops, st_bm.sops);
                    assert_eq!(st_csr.adds, st_bm.adds);
                    assert_eq!(st_csr.sram_writes, st_bm.sram_writes);
                    scratch.put_enc(o_csr);
                    scratch.put_enc(o_bm);
                }
            }
        }
    }

    #[test]
    fn incremental_threshold_zero_attends_every_position() {
        let d = 16;
        let cache = cache_from_rows(
            &[vec![], vec![1, 2]],
            &[vec![0, 7], vec![9]],
            d,
        );
        let q = enc_row(d, &[]);
        let cfg = AccelConfig::small();
        let mut scratch = ExecScratch::new();
        let (out, st) =
            SpikeMaskAddModule::new(0).run_incremental_into(&q, &cache, 2, &cfg, &mut scratch);
        // Every position attended for every head: output is the OR of V.
        assert_eq!(out.channel_addrs(0), &[0u16][..]);
        assert!(out.channel_len(7) > 0 && out.channel_len(9) > 0);
        assert_eq!(out.count_spikes(), 3);
        assert_eq!(st.sram_writes, 3);
    }

    #[test]
    fn incremental_empty_q_never_attends_at_positive_threshold() {
        let d = 16;
        let cache = cache_from_rows(&[vec![0, 5], vec![3]], &[vec![1], vec![2]], d);
        let q = enc_row(d, &[]);
        let cfg = AccelConfig::small();
        let mut scratch = ExecScratch::new();
        let (out, st) =
            SpikeMaskAddModule::new(1).run_incremental_into(&q, &cache, 4, &cfg, &mut scratch);
        assert_eq!(out.count_spikes(), 0);
        assert_eq!(st.adds, 0);
        assert!(st.cycles >= 1, "charged floor cycle");
    }

    #[test]
    fn incremental_cost_grows_with_cache_length() {
        let mut rng = Prng::new(42);
        let d = 64;
        let cfg = AccelConfig::small();
        let smam = SpikeMaskAddModule::new(2);
        let mut scratch = ExecScratch::new();
        let rows_k = random_rows(&mut rng, 32, d, 0.3);
        let rows_v = random_rows(&mut rng, 32, d, 0.3);
        let q = enc_row(d, &(0..d).filter(|_| rng.bernoulli(0.3)).collect::<Vec<_>>());
        let short = cache_from_rows(&rows_k[..4], &rows_v[..4], d);
        let long = cache_from_rows(&rows_k, &rows_v, d);
        let (o1, st_short) = smam.run_incremental_into(&q, &short, 4, &cfg, &mut scratch);
        let (o2, st_long) = smam.run_incremental_into(&q, &long, 4, &cfg, &mut scratch);
        assert!(
            st_long.cycles > st_short.cycles && st_long.sops > st_short.sops,
            "decode cost must scale with the causal prefix"
        );
        scratch.put_enc(o1);
        scratch.put_enc(o2);
    }

    #[test]
    fn incremental_steady_state_reuses_scratch() {
        let mut rng = Prng::new(43);
        let d = 70;
        let cfg = AccelConfig::small();
        let smam = SpikeMaskAddModule::new(2);
        let rows_k = random_rows(&mut rng, 6, d, 0.4);
        let rows_v = random_rows(&mut rng, 6, d, 0.4);
        let cache = cache_from_rows(&rows_k, &rows_v, d);
        let q = enc_row(d, &(0..d).filter(|_| rng.bernoulli(0.4)).collect::<Vec<_>>());
        let mut scratch = ExecScratch::new();
        let mut warm_misses = 0;
        for round in 0..3 {
            let (out, _) = smam.run_incremental_into(&q, &cache, 4, &cfg, &mut scratch);
            scratch.put_enc(out);
            if round == 0 {
                warm_misses = scratch.stats().misses;
            }
        }
        assert_eq!(
            scratch.stats().misses,
            warm_misses,
            "warm incremental SDSA passes must not allocate"
        );
    }

    #[test]
    #[should_panic(expected = "SMAM V token space mismatch")]
    fn mismatched_v_token_space_panics() {
        let cfg = AccelConfig::small();
        let q = EncodedSpikes::empty(2, 16);
        let k = EncodedSpikes::empty(2, 16);
        let mut v = EncodedSpikes::empty(2, 8); // wrong token space
        v.push(0, 7);
        SpikeMaskAddModule::new(0).run(&q, &k, &v, &cfg);
    }

    #[test]
    #[should_panic(expected = "SMAM V token space mismatch")]
    fn dense_baseline_checks_v_token_space_too() {
        let cfg = AccelConfig::small();
        let q = EncodedSpikes::empty(2, 16);
        let k = EncodedSpikes::empty(2, 16);
        let v = EncodedSpikes::empty(2, 32);
        SpikeMaskAddModule::new(0).run_dense_baseline(&q, &k, &v, &cfg);
    }
}
