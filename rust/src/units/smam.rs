//! Spike Mask-Add Module (SMAM, Fig. 4): the dual-spike-input engine for
//! Spike-Driven Self-Attention.
//!
//! Per channel c, the Hadamard product of binary Q_s[:,c] and K_s[:,c]
//! accumulated along the token dimension equals the size of the
//! intersection of their encoded address lists. The hardware realises it as
//! a two-pointer comparator (Fig. 4(a)): take one encoded spike from each
//! memory; on address match output '1' (one accumulation, Fig. 4(b)) and
//! advance both; otherwise retain the larger address and advance the
//! smaller — each comparison consumes exactly one encoded spike, so a
//! channel finishes in |Q_c| + |K_c| comparator steps. The accumulated
//! count is compared against the firing threshold to produce the mask bit
//! S[c]; V_s's per-channel ESS bank is then cleared or retained (Fig. 4(c)).
//! Retention is an offset-range copy out of V's CSR arena — no per-channel
//! heap clones.

use crate::hw::{AccelConfig, UnitStats};
use crate::spike::EncodedSpikes;
use crate::util::div_ceil;

#[derive(Clone, Copy, Debug)]
pub struct SpikeMaskAddModule {
    /// Integer firing threshold of the mask neuron (accumulation counts).
    pub v_th: u32,
}

/// Result of an SDSA pass.
#[derive(Clone, Debug)]
pub struct SmamOutput {
    /// Per-channel mask S (Fig. 4(b)).
    pub mask: Vec<bool>,
    /// Per-channel Q.K intersection counts (the token-dim accumulation).
    pub acc: Vec<u32>,
    /// Masked V_s: channels with S=0 cleared, others retained verbatim.
    pub masked_v: EncodedSpikes,
}

impl SpikeMaskAddModule {
    pub fn new(v_th: u32) -> Self {
        Self { v_th }
    }

    fn check_shapes(q: &EncodedSpikes, k: &EncodedSpikes, v: &EncodedSpikes) {
        assert_eq!(q.channels, k.channels);
        assert_eq!(q.channels, v.channels);
        assert_eq!(q.tokens, k.tokens);
        // A mismatched V token space would silently produce a masked_v
        // whose declared token range disagrees with Q/K's address space.
        assert_eq!(q.tokens, v.tokens, "SMAM V token space mismatch");
    }

    /// Run SDSA mask-add over encoded Q_s, K_s, V_s (all `[C, L]`).
    pub fn run(
        &self,
        q: &EncodedSpikes,
        k: &EncodedSpikes,
        v: &EncodedSpikes,
        cfg: &AccelConfig,
    ) -> (SmamOutput, UnitStats) {
        Self::check_shapes(q, k, v);

        let c = q.channels;
        let mut mask = vec![false; c];
        let mut acc = vec![0u32; c];
        let mut masked_v = EncodedSpikes::empty(v.channels, v.tokens);
        let mut comparator_steps: u64 = 0;
        let mut matches: u64 = 0;

        for ch in 0..c {
            let (ql, kl) = (q.channel_addrs(ch), k.channel_addrs(ch));
            // Two-pointer merge-join; each iteration is one comparator step
            // consuming one encoded spike (the smaller address, or both on
            // a match — the hardware still spends one cycle on the pair).
            let (mut i, mut j) = (0usize, 0usize);
            let mut count = 0u32;
            while i < ql.len() && j < kl.len() {
                comparator_steps += 1;
                match ql[i].cmp(&kl[j]) {
                    std::cmp::Ordering::Equal => {
                        count += 1;
                        matches += 1;
                        i += 1;
                        j += 1;
                    }
                    std::cmp::Ordering::Less => i += 1,
                    std::cmp::Ordering::Greater => j += 1,
                }
            }
            acc[ch] = count;
            // Fire determination (threshold compare, Fig. 4(b)).
            mask[ch] = count >= self.v_th;
            if mask[ch] {
                masked_v.extend_channel_from(ch, v, ch);
            }
        }

        let q_spikes = q.count_spikes() as u64;
        let k_spikes = k.count_spikes() as u64;
        let retained = masked_v.count_spikes() as u64;
        let stats = UnitStats {
            // comparator steps spread over the comparator array, plus one
            // threshold compare per channel
            cycles: div_ceil(comparator_steps, cfg.smam_comparators as u64).max(1)
                + div_ceil(c as u64, cfg.smam_comparators as u64),
            // SOPs: every Q/K spike traverses the comparator once; every
            // retained V spike traverses the mask gate.
            sops: q_spikes + k_spikes + retained,
            adds: matches, // token-dim accumulation increments
            cmps: comparator_steps + c as u64,
            sram_reads: q_spikes + k_spikes + retained,
            sram_writes: retained,
            ..Default::default()
        };
        (SmamOutput { mask, acc, masked_v }, stats)
    }

    /// Dense bitmap baseline: walks all C*L Hadamard positions (ablation A1).
    pub fn run_dense_baseline(
        &self,
        q: &EncodedSpikes,
        k: &EncodedSpikes,
        v: &EncodedSpikes,
        cfg: &AccelConfig,
    ) -> (SmamOutput, UnitStats) {
        Self::check_shapes(q, k, v);
        let (qb, kb) = (q.to_bitmap(), k.to_bitmap());
        let c = q.channels;
        let l = q.tokens;
        let mut mask = vec![false; c];
        let mut acc = vec![0u32; c];
        let mut masked_v = EncodedSpikes::empty(v.channels, v.tokens);
        for ch in 0..c {
            let mut count = 0u32;
            for t in 0..l {
                if qb.get(ch, t) && kb.get(ch, t) {
                    count += 1;
                }
            }
            acc[ch] = count;
            mask[ch] = count >= self.v_th;
            if mask[ch] {
                masked_v.extend_channel_from(ch, v, ch);
            }
        }
        let positions = (c * l) as u64;
        let retained = masked_v.count_spikes() as u64;
        let stats = UnitStats {
            cycles: div_ceil(positions, cfg.smam_comparators as u64).max(1)
                + div_ceil(c as u64, cfg.smam_comparators as u64),
            sops: q.count_spikes() as u64 + k.count_spikes() as u64 + retained,
            adds: acc.iter().map(|&x| x as u64).sum(),
            cmps: positions + c as u64,
            sram_reads: 2 * positions + retained,
            sram_writes: retained,
            ..Default::default()
        };
        (SmamOutput { mask, acc, masked_v }, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spike::SpikeMatrix;
    use crate::util::Prng;

    fn random_encoded(rng: &mut Prng, c: usize, l: usize, p: f64) -> EncodedSpikes {
        let mut m = SpikeMatrix::zeros(c, l);
        for ci in 0..c {
            for li in 0..l {
                if rng.bernoulli(p) {
                    m.set(ci, li, true);
                }
            }
        }
        EncodedSpikes::from_bitmap(&m)
    }

    #[test]
    fn intersection_counts_match_hadamard_sum() {
        let mut rng = Prng::new(7);
        let cfg = AccelConfig::small();
        let smam = SpikeMaskAddModule::new(2);
        for &p in &[0.1, 0.3, 0.7] {
            let q = random_encoded(&mut rng, 6, 64, p);
            let k = random_encoded(&mut rng, 6, 64, p);
            let v = random_encoded(&mut rng, 6, 64, p);
            let (out, _) = smam.run(&q, &k, &v, &cfg);
            let (qb, kb) = (q.to_bitmap(), k.to_bitmap());
            for ch in 0..6 {
                let want: u32 = (0..64).filter(|&t| qb.get(ch, t) && kb.get(ch, t)).count() as u32;
                assert_eq!(out.acc[ch], want, "channel {ch}");
                assert_eq!(out.mask[ch], want >= 2);
            }
        }
    }

    #[test]
    fn masked_v_clears_or_retains_whole_channels() {
        let mut rng = Prng::new(8);
        let cfg = AccelConfig::small();
        let q = random_encoded(&mut rng, 4, 32, 0.5);
        let k = random_encoded(&mut rng, 4, 32, 0.5);
        let v = random_encoded(&mut rng, 4, 32, 0.4);
        let (out, _) = SpikeMaskAddModule::new(3).run(&q, &k, &v, &cfg);
        for ch in 0..4 {
            if out.mask[ch] {
                assert_eq!(out.masked_v.channel_addrs(ch), v.channel_addrs(ch));
            } else {
                assert!(out.masked_v.channel_addrs(ch).is_empty());
            }
        }
    }

    #[test]
    fn dense_baseline_agrees() {
        let mut rng = Prng::new(9);
        let cfg = AccelConfig::small();
        let smam = SpikeMaskAddModule::new(2);
        let q = random_encoded(&mut rng, 8, 64, 0.2);
        let k = random_encoded(&mut rng, 8, 64, 0.2);
        let v = random_encoded(&mut rng, 8, 64, 0.2);
        let (a, s_sparse) = smam.run(&q, &k, &v, &cfg);
        let (b, s_dense) = smam.run_dense_baseline(&q, &k, &v, &cfg);
        assert_eq!(a.mask, b.mask);
        assert_eq!(a.acc, b.acc);
        assert_eq!(a.masked_v, b.masked_v);
        // At 80% sparsity the encoded path must be far cheaper.
        assert!(s_sparse.cycles < s_dense.cycles);
    }

    #[test]
    fn comparator_steps_bounded_by_list_lengths() {
        let mut rng = Prng::new(10);
        let cfg = AccelConfig::paper();
        let q = random_encoded(&mut rng, 1, 64, 0.5);
        let k = random_encoded(&mut rng, 1, 64, 0.5);
        let v = EncodedSpikes::empty(1, 64);
        let (_, stats) = SpikeMaskAddModule::new(1).run(&q, &k, &v, &cfg);
        let bound = (q.count_spikes() + k.count_spikes()) as u64 + 1;
        assert!(stats.cmps <= bound + 1, "cmps {} > bound {}", stats.cmps, bound);
    }

    #[test]
    fn empty_q_or_k_never_fires() {
        let cfg = AccelConfig::small();
        let q = EncodedSpikes::empty(3, 16);
        let mut k = EncodedSpikes::empty(3, 16);
        k.push(0, 5);
        let mut v = EncodedSpikes::empty(3, 16);
        v.push(0, 1);
        let (out, _) = SpikeMaskAddModule::new(1).run(&q, &k, &v, &cfg);
        assert!(out.mask.iter().all(|&m| !m));
        assert_eq!(out.masked_v.count_spikes(), 0);
    }

    #[test]
    fn threshold_zero_always_fires() {
        let cfg = AccelConfig::small();
        let q = EncodedSpikes::empty(2, 8);
        let k = EncodedSpikes::empty(2, 8);
        let mut v = EncodedSpikes::empty(2, 8);
        v.push(1, 3);
        let (out, _) = SpikeMaskAddModule::new(0).run(&q, &k, &v, &cfg);
        assert!(out.mask.iter().all(|&m| m));
        assert_eq!(out.masked_v.channel_addrs(1), &[3u16][..]);
    }

    #[test]
    #[should_panic(expected = "SMAM V token space mismatch")]
    fn mismatched_v_token_space_panics() {
        let cfg = AccelConfig::small();
        let q = EncodedSpikes::empty(2, 16);
        let k = EncodedSpikes::empty(2, 16);
        let mut v = EncodedSpikes::empty(2, 8); // wrong token space
        v.push(0, 7);
        SpikeMaskAddModule::new(0).run(&q, &k, &v, &cfg);
    }

    #[test]
    #[should_panic(expected = "SMAM V token space mismatch")]
    fn dense_baseline_checks_v_token_space_too() {
        let cfg = AccelConfig::small();
        let q = EncodedSpikes::empty(2, 16);
        let k = EncodedSpikes::empty(2, 16);
        let v = EncodedSpikes::empty(2, 32);
        SpikeMaskAddModule::new(0).run_dense_baseline(&q, &k, &v, &cfg);
    }
}
