//! The accelerator's compute units (paper §III, Figs. 2-5).
//!
//! Every unit is implemented *functionally* (it computes the real values,
//! so the whole network runs end-to-end on the simulator) and charges
//! cycles/ops per the paper's dataflow into a [`crate::hw::UnitStats`].
//! The cycle model assumes one operation per lane per cycle at the
//! configured parallelism — the same assumption behind the paper's
//! 1,536 neurons/cycle peak.

pub mod adder;
pub mod sea;
pub mod slu;
pub mod smam;
pub mod smu;
pub mod tile_engine;

pub use adder::AdderModule;
pub use sea::SpikeEncodingArray;
pub use slu::SpikeLinearUnit;
pub use smam::{HeadShard, SmamOutput, SpikeMaskAddModule};
pub use smu::SpikeMaxpoolUnit;
pub use tile_engine::{QuantizedConv, TileEngine};
