//! Spike Linear Unit (SLU, Fig. 5): multiplication-free linear layers on
//! encoded spike input.
//!
//! For every encoded spike (channel c fired at token l), the weight row
//! W[c, :] is read from the weight SRAM and accumulated into output row l.
//! Zeros are never touched; accumulation runs on the `lanes`-wide adder
//! array (the Spike Linear Array), and the Saturation-Truncation Module
//! (Fig. 5(b)) drops the wide accumulator back into the 10-bit activation
//! format.
//!
//! Dual-engine datapath: next to the CSR address-streaming kernel
//! ([`SpikeLinearUnit::forward_into`]) sits a word-parallel packed-bitmap
//! kernel ([`SpikeLinearUnit::forward_bitmap_into`]) that scans `u64`
//! words with trailing-zeros extraction instead of streaming addresses —
//! bit-identical output, engine-specific cycle accounting (DESIGN.md
//! "Dual-engine datapath & selection").

use crate::hw::{AccelConfig, UnitStats};
use crate::quant::{QFormat, QTensor, QuantizedLinear, SaturationTruncation, ACT_FRAC, MEM_BITS};
use crate::scratch::ExecScratch;
use crate::spike::bitmap::WORD_BITS;
use crate::spike::{EncodedSpikes, PackedBitmap};
use crate::util::div_ceil;

#[derive(Clone, Debug, Default)]
/// The Spike Linear Array plus its Saturation-Truncation Module.
pub struct SpikeLinearUnit {
    /// Saturation counters (exposed for quantization diagnostics).
    pub sat: SaturationTruncation,
    /// Reused accumulator buffer (perf: avoids per-call allocation).
    acc: Vec<i64>,
}

impl SpikeLinearUnit {
    /// Fresh unit with zeroed saturation counters.
    pub fn new() -> Self {
        Self::default()
    }

    /// Y[l, :] = sum over fired channels c of W[c, :] + bias.
    ///
    /// `x` is `[C_in, L]` encoded; returns `[L, C_out]` in the wide
    /// activation format (input for the next LIF / residual adder).
    /// Allocates the output tensor; the hot loop uses
    /// [`Self::forward_into`].
    pub fn forward(
        &mut self,
        x: &EncodedSpikes,
        layer: &QuantizedLinear,
        cfg: &AccelConfig,
    ) -> (QTensor, UnitStats) {
        self.forward_into(x, layer, cfg, &mut ExecScratch::new())
    }

    /// [`Self::forward`] with the output tensor recycled through `scratch`
    /// (bit-identical output).
    pub fn forward_into(
        &mut self,
        x: &EncodedSpikes,
        layer: &QuantizedLinear,
        cfg: &AccelConfig,
        scratch: &mut ExecScratch,
    ) -> (QTensor, UnitStats) {
        assert_eq!(x.channels, layer.in_dim, "SLU input channel mismatch");
        let l = x.tokens;
        let n_out = layer.out_dim;

        // Accumulators preloaded with the bias (at accumulator scale);
        // the buffer is owned by the unit and reused across calls.
        self.acc.clear();
        self.acc.reserve(l * n_out);
        for _ in 0..l {
            self.acc.extend_from_slice(&layer.bias);
        }
        let acc = &mut self.acc;

        let mut total_spikes: u64 = 0;
        for c in 0..x.channels {
            let list = x.channel_addrs(c);
            if list.is_empty() {
                continue;
            }
            let row = layer.row(c);
            total_spikes += list.len() as u64; // as-ok: widening for 64-bit stat/cycle math
            for &tok in list {
                let base = tok as usize * n_out; // as-ok: narrow-int index widening
                let dst = &mut acc[base..base + n_out];
                for (d, &w) in dst.iter_mut().zip(row) {
                    *d += w as i64; // as-ok: widening into i64 accumulator math
                }
            }
        }

        // Saturation-truncation into the wide activation format.
        let out_fmt = QFormat::new(MEM_BITS, ACT_FRAC);
        let shift = layer.acc_frac();
        let mut out = scratch.take_tensor(&[l, n_out], ACT_FRAC);
        let sat = &mut self.sat;
        for (o, &a) in out.data.iter_mut().zip(self.acc.iter()) {
            *o = sat.convert(a, shift, out_fmt);
        }

        let sops = total_spikes * n_out as u64; // as-ok: widening for 64-bit stat/cycle math
        let stats = UnitStats {
            cycles: div_ceil(sops, cfg.lanes as u64).max(1), // as-ok: widening for 64-bit stat/cycle math
            sops,
            adds: sops,
            sram_reads: total_spikes + sops, // ESS addresses + weight rows
            sram_writes: (l * n_out) as u64, // as-ok: widening for 64-bit stat/cycle math
            ..Default::default()
        };
        (out, stats)
    }

    /// Word-scan accumulation core shared by the executed bitmap engine
    /// and the (graduated) bitmap baseline: preloads the bias, then for
    /// every set bit of every word accumulates the weight row — the same
    /// i64 additions as the CSR kernel, so values are bit-identical by
    /// construction (addition over i64 is exact and order-free here:
    /// both engines visit channels in ascending order). Returns the
    /// spike count.
    fn accumulate_bitmap(&mut self, x: &PackedBitmap, layer: &QuantizedLinear) -> u64 {
        assert_eq!(x.channels(), layer.in_dim, "SLU input channel mismatch");
        let l = x.tokens();
        let n_out = layer.out_dim;
        self.acc.clear();
        self.acc.reserve(l * n_out);
        for _ in 0..l {
            self.acc.extend_from_slice(&layer.bias);
        }
        let mut total_spikes: u64 = 0;
        for c in 0..x.channels() {
            let row_w = layer.row(c);
            for (wi, &word) in x.row(c).iter().enumerate() {
                let mut bits = word;
                while bits != 0 {
                    let tok = wi * WORD_BITS + bits.trailing_zeros() as usize; // as-ok: u32 bit index widening
                    bits &= bits - 1;
                    total_spikes += 1;
                    let base = tok * n_out;
                    let dst = &mut self.acc[base..base + n_out];
                    for (d, &w) in dst.iter_mut().zip(row_w) {
                        *d += w as i64; // as-ok: widening into i64 accumulator math
                    }
                }
            }
        }
        total_spikes
    }

    /// Saturation-truncation of the accumulator buffer into a pooled
    /// `[l, n_out]` activation tensor (the shared tail of every engine).
    fn saturate_acc_into(
        &mut self,
        l: usize,
        n_out: usize,
        layer: &QuantizedLinear,
        scratch: &mut ExecScratch,
    ) -> QTensor {
        let out_fmt = QFormat::new(MEM_BITS, ACT_FRAC);
        let shift = layer.acc_frac();
        let mut out = scratch.take_tensor(&[l, n_out], ACT_FRAC);
        let sat = &mut self.sat;
        for (o, &a) in out.data.iter_mut().zip(self.acc.iter()) {
            *o = sat.convert(a, shift, out_fmt);
        }
        out
    }

    /// The packed-bitmap engine: Y from a [`PackedBitmap`] input via the
    /// word-scan kernel. Allocating convenience around
    /// [`Self::forward_bitmap_into`].
    pub fn forward_bitmap(
        &mut self,
        x: &PackedBitmap,
        layer: &QuantizedLinear,
        cfg: &AccelConfig,
    ) -> (QTensor, UnitStats) {
        self.forward_bitmap_into(x, layer, cfg, &mut ExecScratch::new())
    }

    /// The executed word-parallel engine
    /// ([`EngineKind::Bitmap`](crate::hw::EngineKind)): scans each
    /// channel's `ceil(L/64)` packed
    /// words, extracting set bits with trailing-zeros, and accumulates
    /// exactly the CSR kernel's weight rows — bit-identical to
    /// [`Self::forward_into`] on the same spikes.
    ///
    /// Cycle model: the word scan streams `C x ceil(L/64)` words through
    /// the lane array (one word probe per lane per cycle) before the same
    /// `sops / lanes` accumulation term as the CSR engine; word probes
    /// are charged as `cmps` and the SRAM traffic reads words instead of
    /// per-spike addresses. At high density the word term beats the CSR
    /// engine's per-address stream; at low density it is pure overhead —
    /// the crossover the adaptive policy thresholds on.
    pub fn forward_bitmap_into(
        &mut self,
        x: &PackedBitmap,
        layer: &QuantizedLinear,
        cfg: &AccelConfig,
        scratch: &mut ExecScratch,
    ) -> (QTensor, UnitStats) {
        let (l, n_out) = (x.tokens(), layer.out_dim);
        let total_spikes = self.accumulate_bitmap(x, layer);
        let out = self.saturate_acc_into(l, n_out, layer, scratch);

        let words_total = (x.channels() * x.words_per_row()) as u64; // as-ok: widening for 64-bit stat/cycle math
        let sops = total_spikes * n_out as u64; // as-ok: widening for 64-bit stat/cycle math
        let stats = UnitStats {
            cycles: div_ceil(words_total, cfg.lanes as u64) // as-ok: widening for 64-bit stat/cycle math
                + div_ceil(sops, cfg.lanes as u64).max(1), // as-ok: widening for 64-bit stat/cycle math
            sops,
            adds: sops,
            cmps: words_total, // word fetch + scan probes
            sram_reads: words_total + sops, // packed words + weight rows
            sram_writes: (l * n_out) as u64, // as-ok: widening for 64-bit stat/cycle math
            ..Default::default()
        };
        (out, stats)
    }

    /// Dense baseline: a non-spiking linear engine that performs every
    /// C_in x L x C_out MAC regardless of sparsity (what a conventional
    /// ANN accelerator charges for the same layer).
    pub fn forward_dense_baseline(
        &mut self,
        x: &EncodedSpikes,
        layer: &QuantizedLinear,
        cfg: &AccelConfig,
    ) -> (QTensor, UnitStats) {
        let (out, mut stats) = self.forward(x, layer, cfg);
        let total = (x.channels * x.tokens * layer.out_dim) as u64; // as-ok: widening for 64-bit stat/cycle math
        stats.macs = total;
        stats.adds = total;
        stats.sram_reads = (x.channels * x.tokens) as u64 + total; // as-ok: widening for 64-bit stat/cycle math
        stats.cycles = div_ceil(total, cfg.lanes as u64).max(1); // as-ok: widening for 64-bit stat/cycle math
        (out, stats)
    }

    /// Bitmap baseline: reads every input position, checks for a spike,
    /// then accumulates — what a conventional SNN accelerator without
    /// position encoding does (ablation A1). Since the dual-engine PR
    /// this is a real executed path: the input is materialized into a
    /// scratch-pooled [`PackedBitmap`] and accumulated by the word-scan
    /// kernel (bit-identical values), while the stats keep charging the
    /// modelled scalar per-position cost this ablation represents.
    pub fn forward_bitmap_baseline(
        &mut self,
        x: &EncodedSpikes,
        layer: &QuantizedLinear,
        cfg: &AccelConfig,
    ) -> (QTensor, UnitStats) {
        self.forward_bitmap_baseline_into(x, layer, cfg, &mut ExecScratch::new())
    }

    /// [`Self::forward_bitmap_baseline`] with the output tensor recycled
    /// through `scratch`.
    pub fn forward_bitmap_baseline_into(
        &mut self,
        x: &EncodedSpikes,
        layer: &QuantizedLinear,
        cfg: &AccelConfig,
        scratch: &mut ExecScratch,
    ) -> (QTensor, UnitStats) {
        assert_eq!(x.channels, layer.in_dim, "SLU input channel mismatch");
        // Executed through the bitmap round-trip + word-scan kernel; the
        // stats below still charge the modelled *scalar* per-position
        // cost (every position a read + zero-check before the sparse
        // accumulation) — the A1 ablation this baseline represents.
        let mut bm = scratch.take_bitmap(x.channels, x.tokens);
        bm.fill_from_encoded(x);
        let total_spikes = self.accumulate_bitmap(&bm, layer);
        scratch.put_bitmap(bm);
        let (l, n_out) = (x.tokens, layer.out_dim);
        let out = self.saturate_acc_into(l, n_out, layer, scratch);

        let sops = total_spikes * n_out as u64; // as-ok: widening for 64-bit stat/cycle math
        let positions = (x.channels * x.tokens) as u64; // as-ok: widening for 64-bit stat/cycle math
        let stats = UnitStats {
            cycles: div_ceil(positions, cfg.lanes as u64) // as-ok: widening for 64-bit stat/cycle math
                + div_ceil(sops, cfg.lanes as u64).max(1), // as-ok: widening for 64-bit stat/cycle math
            sops,
            adds: sops,
            cmps: positions,
            sram_reads: positions + sops,
            sram_writes: (l * n_out) as u64, // as-ok: widening for 64-bit stat/cycle math
            ..Default::default()
        };
        (out, stats)
    }
}

/// Dense reference (i64 exact): Y = X_s W + b on the bitmap — used by
/// tests to prove the encoded path computes the true linear layer.
pub fn dense_reference(x: &EncodedSpikes, layer: &QuantizedLinear) -> Vec<i64> {
    let bitmap = x.to_bitmap();
    let l = x.tokens;
    let mut acc = vec![0i64; l * layer.out_dim];
    for tok in 0..l {
        for o in 0..layer.out_dim {
            acc[tok * layer.out_dim + o] = layer.bias[o];
        }
        for c in 0..x.channels {
            if bitmap.get(c, tok) {
                for o in 0..layer.out_dim {
                    acc[tok * layer.out_dim + o] += layer.row(c)[o] as i64; // as-ok: widening into i64 accumulator math
                }
            }
        }
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rshift_round;
    use crate::spike::SpikeMatrix;
    use crate::util::Prng;

    fn random_encoded(rng: &mut Prng, c: usize, l: usize, p: f64) -> EncodedSpikes {
        let mut m = SpikeMatrix::zeros(c, l);
        for ci in 0..c {
            for li in 0..l {
                if rng.bernoulli(p) {
                    m.set(ci, li, true);
                }
            }
        }
        EncodedSpikes::from_bitmap(&m)
    }

    fn random_layer(rng: &mut Prng, c_in: usize, c_out: usize) -> QuantizedLinear {
        let w: Vec<f32> = (0..c_in * c_out).map(|_| rng.next_f32_signed()).collect();
        let b: Vec<f32> = (0..c_out).map(|_| rng.next_f32_signed() * 0.5).collect();
        QuantizedLinear::from_f32(&w, &b, c_in, c_out, 0)
    }

    #[test]
    fn matches_dense_reference() {
        let mut rng = Prng::new(11);
        let cfg = AccelConfig::small();
        for &p in &[0.0, 0.15, 0.5, 1.0] {
            let x = random_encoded(&mut rng, 24, 16, p);
            let layer = random_layer(&mut rng, 24, 12);
            let mut slu = SpikeLinearUnit::new();
            let (out, _) = slu.forward(&x, &layer, &cfg);
            let want = dense_reference(&x, &layer);
            let fmt = QFormat::new(MEM_BITS, ACT_FRAC);
            for (i, (&got, &acc)) in out.data.iter().zip(want.iter()).enumerate() {
                let expect =
                    crate::quant::sat(rshift_round(acc, layer.acc_frac() - ACT_FRAC), fmt.bits);
                assert_eq!(got, expect, "element {i} at sparsity {p}");
            }
        }
    }

    #[test]
    fn zero_input_yields_bias_rows() {
        let mut rng = Prng::new(12);
        let cfg = AccelConfig::small();
        let layer = random_layer(&mut rng, 8, 6);
        let x = EncodedSpikes::empty(8, 4);
        let mut slu = SpikeLinearUnit::new();
        let (out, stats) = slu.forward(&x, &layer, &cfg);
        assert_eq!(stats.sops, 0);
        let fmt = QFormat::new(MEM_BITS, ACT_FRAC);
        for tok in 0..4 {
            for o in 0..6 {
                let expect = crate::quant::sat(
                    rshift_round(layer.bias[o], layer.acc_frac() - ACT_FRAC),
                    fmt.bits,
                );
                assert_eq!(out.data[tok * 6 + o], expect);
            }
        }
    }

    #[test]
    fn cycles_proportional_to_spikes() {
        let mut rng = Prng::new(13);
        let cfg = AccelConfig::paper();
        let layer = random_layer(&mut rng, 64, 64);
        let sparse = random_encoded(&mut rng, 64, 64, 0.1);
        let denser = random_encoded(&mut rng, 64, 64, 0.8);
        let mut slu = SpikeLinearUnit::new();
        let (_, s1) = slu.forward(&sparse, &layer, &cfg);
        let (_, s2) = slu.forward(&denser, &layer, &cfg);
        assert!(s2.cycles > 3 * s1.cycles, "{} vs {}", s2.cycles, s1.cycles);
    }

    #[test]
    fn bitmap_baseline_same_values_more_cycles() {
        let mut rng = Prng::new(14);
        let cfg = AccelConfig::small();
        let layer = random_layer(&mut rng, 32, 16);
        let x = random_encoded(&mut rng, 32, 32, 0.1);
        let mut a = SpikeLinearUnit::new();
        let mut b = SpikeLinearUnit::new();
        let (o1, s1) = a.forward(&x, &layer, &cfg);
        let (o2, s2) = b.forward_bitmap_baseline(&x, &layer, &cfg);
        assert_eq!(o1, o2);
        assert!(s2.cycles > s1.cycles);
        assert!(s2.sram_reads > s1.sram_reads);
    }

    #[test]
    fn bitmap_engine_bit_identical_to_csr() {
        let mut rng = Prng::new(15);
        let cfg = AccelConfig::small();
        let layer = random_layer(&mut rng, 32, 16);
        for &p in &[0.0, 0.05, 0.5, 1.0] {
            let x = random_encoded(&mut rng, 32, 70, p); // 2 words/row
            let bm = PackedBitmap::from_encoded(&x);
            let mut a = SpikeLinearUnit::new();
            let mut b = SpikeLinearUnit::new();
            let (o1, s1) = a.forward(&x, &layer, &cfg);
            let (o2, s2) = b.forward_bitmap(&bm, &layer, &cfg);
            assert_eq!(o1, o2, "engines must agree at density {p}");
            assert_eq!(a.sat.saturations, b.sat.saturations);
            assert_eq!(s1.sops, s2.sops);
            assert_eq!(s1.adds, s2.adds);
            // The word engine charges its word-scan floor.
            assert_eq!(s2.cmps, 32 * 2);
        }
    }

    #[test]
    fn bitmap_engine_cycle_floor_is_the_word_scan() {
        // Empty input: the CSR engine idles at 1 cycle; the word engine
        // still pays for scanning every packed word.
        let cfg = AccelConfig::small(); // 64 lanes
        let x = EncodedSpikes::empty(128, 70);
        let bm = PackedBitmap::from_encoded(&x);
        let layer = {
            let mut rng = Prng::new(16);
            random_layer(&mut rng, 128, 8)
        };
        let mut slu = SpikeLinearUnit::new();
        let (_, s) = slu.forward_bitmap(&bm, &layer, &cfg);
        // 128 channels x 2 words = 256 words over 64 lanes = 4 cycles,
        // plus the .max(1) accumulate term.
        assert_eq!(s.cycles, 4 + 1);
        assert_eq!(s.sops, 0);
    }

    #[test]
    fn graduated_baseline_executes_and_charges_scalar_cost() {
        // The baseline now runs through the bitmap kernel but its stats
        // still model scalar per-position checking (ablation A1) — the
        // "bitmap charges strictly more cycles" claim must be unchanged.
        let mut rng = Prng::new(17);
        let cfg = AccelConfig::small();
        let layer = random_layer(&mut rng, 32, 16);
        let x = random_encoded(&mut rng, 32, 32, 0.1);
        let mut a = SpikeLinearUnit::new();
        let mut b = SpikeLinearUnit::new();
        let (_, s_enc) = a.forward(&x, &layer, &cfg);
        let mut scratch = ExecScratch::new();
        let (_, s_base) = b.forward_bitmap_baseline_into(&x, &layer, &cfg, &mut scratch);
        let positions = (32 * 32) as u64;
        assert_eq!(s_base.cmps, positions);
        assert_eq!(s_base.sram_reads, positions + s_base.sops);
        assert_eq!(
            s_base.cycles,
            crate::util::div_ceil(positions, cfg.lanes as u64)
                + crate::util::div_ceil(s_base.sops, cfg.lanes as u64).max(1)
        );
        assert!(s_base.cycles > s_enc.cycles);
        // The materialized bitmap went back to the pool (the output
        // tensor is live with the caller), so nothing leaks.
        assert_eq!(scratch.pooled_objects(), 1);
    }

    #[test]
    fn saturation_reported() {
        // Huge bias at tiny shift forces saturation.
        let layer = QuantizedLinear {
            in_dim: 1,
            out_dim: 1,
            w: vec![511],
            w_frac: 0,
            in_frac: 0,
            bias: vec![1 << 22],
        };
        let mut x = EncodedSpikes::empty(1, 1);
        x.push(0, 0);
        let mut slu = SpikeLinearUnit::new();
        let (out, _) = slu.forward(&x, &layer, &AccelConfig::small());
        assert_eq!(out.data[0], (1 << (MEM_BITS - 1)) - 1);
        assert!(slu.sat.saturations > 0);
    }
}
