//! Adder Module + ResBuffer (Fig. 1): residual additions in the value
//! (membrane) domain. Two flavours appear in the Spike-driven Transformer
//! dataflow:
//! * value + value — e.g. `u + SDSA_out` around the encoder blocks;
//! * value + spike — the SPS residual `RPE(s4) + s4`, where the binary
//!   spike contributes `1.0` (one activation-format LSB step of 2^ACT_FRAC).

use crate::hw::{AccelConfig, UnitStats};
use crate::quant::{sat, QTensor, ACT_FRAC, MEM_BITS};
use crate::scratch::ExecScratch;
use crate::spike::EncodedSpikes;
use crate::util::div_ceil;

#[derive(Clone, Copy, Debug, Default)]
/// The residual Adder Module (value-domain element-wise adds).
pub struct AdderModule;

impl AdderModule {
    /// New adder.
    pub fn new() -> Self {
        Self
    }

    /// Elementwise saturating add of two tensors in the same format.
    /// Allocates the output; the hot loop uses [`Self::add_into`].
    pub fn add(&self, a: &QTensor, b: &QTensor, cfg: &AccelConfig) -> (QTensor, UnitStats) {
        self.add_into(a, b, cfg, &mut ExecScratch::new())
    }

    /// [`Self::add`] with the output tensor recycled through `scratch`.
    pub fn add_into(
        &self,
        a: &QTensor,
        b: &QTensor,
        cfg: &AccelConfig,
        scratch: &mut ExecScratch,
    ) -> (QTensor, UnitStats) {
        assert_eq!(a.shape, b.shape, "adder shape mismatch");
        assert_eq!(a.frac, b.frac, "adder frac mismatch");
        let mut out = scratch.take_tensor(&a.shape, a.frac);
        for ((o, &x), &y) in out.data.iter_mut().zip(&a.data).zip(&b.data) {
            *o = sat(x as i64 + y as i64, MEM_BITS); // as-ok: widening into i64 accumulator math
        }
        let n = a.len() as u64; // as-ok: widening for 64-bit stat/cycle math
        let stats = UnitStats {
            cycles: div_ceil(n, cfg.lanes as u64).max(1), // as-ok: widening for 64-bit stat/cycle math
            adds: n,
            sram_reads: 2 * n,
            sram_writes: n,
            ..Default::default()
        };
        (out, stats)
    }

    /// value + spike residual: adds 1.0 (in activation format) at every
    /// encoded spike position. `values` is `[C, L]` row-major; `spikes`
    /// is the `[C, L]` encoded tensor. Touches only spike positions.
    /// Allocates the output; the hot loop uses [`Self::add_spikes_into`].
    pub fn add_spikes(
        &self,
        values: &QTensor,
        spikes: &EncodedSpikes,
        cfg: &AccelConfig,
    ) -> (QTensor, UnitStats) {
        self.add_spikes_into(values, spikes, cfg, &mut ExecScratch::new())
    }

    /// [`Self::add_spikes`] with the output tensor recycled through
    /// `scratch`.
    pub fn add_spikes_into(
        &self,
        values: &QTensor,
        spikes: &EncodedSpikes,
        cfg: &AccelConfig,
        scratch: &mut ExecScratch,
    ) -> (QTensor, UnitStats) {
        assert_eq!(values.shape, [spikes.channels, spikes.tokens]);
        assert_eq!(values.frac, ACT_FRAC);
        let one = 1i64 << ACT_FRAC;
        let mut out = scratch.take_tensor_copy(values);
        let mut n_spikes: u64 = 0;
        for c in 0..spikes.channels {
            let list = spikes.channel_addrs(c);
            n_spikes += list.len() as u64; // as-ok: widening for 64-bit stat/cycle math
            for &l in list {
                let idx = c * spikes.tokens + l as usize; // as-ok: narrow-int index widening
                out.data[idx] = sat(out.data[idx] as i64 + one, MEM_BITS); // as-ok: widening into i64 accumulator math
            }
        }
        let stats = UnitStats {
            cycles: div_ceil(n_spikes, cfg.lanes as u64).max(1), // as-ok: widening for 64-bit stat/cycle math
            adds: n_spikes,
            sops: n_spikes,
            sram_reads: n_spikes,
            sram_writes: n_spikes,
            ..Default::default()
        };
        (out, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QFormat;
    use crate::spike::SpikeMatrix;

    #[test]
    fn add_is_elementwise() {
        let fmt = QFormat::new(MEM_BITS, ACT_FRAC);
        let a = QTensor::from_f32(&[1.0, -2.0], &[2], fmt);
        let b = QTensor::from_f32(&[0.5, 0.5], &[2], fmt);
        let (out, stats) = AdderModule::new().add(&a, &b, &AccelConfig::small());
        assert_eq!(out.to_f32(), vec![1.5, -1.5]);
        assert_eq!(stats.adds, 2);
    }

    #[test]
    fn add_saturates() {
        let max = (1 << (MEM_BITS - 1)) - 1;
        let a = QTensor { shape: vec![1], frac: ACT_FRAC, data: vec![max] };
        let (out, _) = AdderModule::new().add(&a, &a, &AccelConfig::small());
        assert_eq!(out.data[0], max);
    }

    #[test]
    fn add_spikes_only_touches_spike_positions() {
        let fmt = QFormat::new(MEM_BITS, ACT_FRAC);
        let vals = QTensor::from_f32(&[0.0, 0.25, -1.0, 2.0], &[2, 2], fmt);
        let mut m = SpikeMatrix::zeros(2, 2);
        m.set(0, 1, true);
        m.set(1, 0, true);
        let enc = EncodedSpikes::from_bitmap(&m);
        let (out, stats) = AdderModule::new().add_spikes(&vals, &enc, &AccelConfig::small());
        assert_eq!(out.to_f32(), vec![0.0, 1.25, 0.0, 2.0]);
        assert_eq!(stats.adds, 2);
        assert_eq!(stats.sops, 2);
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn mismatched_shapes_panic() {
        let a = QTensor::zeros(&[2], ACT_FRAC);
        let b = QTensor::zeros(&[3], ACT_FRAC);
        AdderModule::new().add(&a, &b, &AccelConfig::small());
    }
}
