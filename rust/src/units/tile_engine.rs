//! Tile Engine: the dense convolution engine of the SPS Core (Fig. 1,
//! after [13]). It performs the Conv-BN (folded) stages of Spiking Patch
//! Splitting on `tile_macs` parallel MAC units. The first stage consumes
//! analog pixels; later stages consume binary spike maps (still routed
//! through the Tile Engine — the paper's encoding optimisations target
//! maxpool/linear/SDSA, not conv).

use crate::hw::{AccelConfig, UnitStats};
use crate::quant::{quantize_bias, quantize_weights, QFormat, QTensor, SaturationTruncation, ACT_FRAC, MEM_BITS};
use crate::scratch::ExecScratch;
use crate::util::div_ceil;

/// A BN-folded, quantized 3x3 (or kxk) SAME convolution.
#[derive(Clone, Debug)]
pub struct QuantizedConv {
    /// Output channels.
    pub c_out: usize,
    /// Input channels.
    pub c_in: usize,
    /// Kernel height.
    pub kh: usize,
    /// Kernel width.
    pub kw: usize,
    /// `[c_out][c_in][kh][kw]` row-major.
    pub w: Vec<i32>,
    /// Scatter layout `[c_in][kh][kw][c_out]` (i64, built once) — the
    /// contiguous-output-channel view the optimized conv kernel walks.
    pub wt: Vec<i64>,
    /// Same scatter layout in i32 (the overflow-checked fast path).
    pub wt32: Vec<i32>,
    /// Weight fraction bits.
    pub w_frac: i32,
    /// Input fraction bits.
    pub in_frac: i32,
    /// Bias at accumulator scale (`w_frac + in_frac`).
    pub bias: Vec<i64>,
}

impl QuantizedConv {
    /// Quantize a float convolution layer.
    pub fn from_f32(
        w: &[f32],
        bias: &[f32],
        c_out: usize,
        c_in: usize,
        kh: usize,
        kw: usize,
        in_frac: i32,
    ) -> Self {
        assert_eq!(w.len(), c_out * c_in * kh * kw);
        assert_eq!(bias.len(), c_out);
        let (wq, w_frac) = quantize_weights(w);
        let mut wt = vec![0i64; c_out * c_in * kh * kw];
        for o in 0..c_out {
            for i in 0..c_in {
                for ky in 0..kh {
                    for kx in 0..kw {
                        wt[((i * kh + ky) * kw + kx) * c_out + o] =
                            wq[((o * c_in + i) * kh + ky) * kw + kx] as i64; // as-ok: widening into i64 accumulator math
                    }
                }
            }
        }
        let wt32 = wt.iter().map(|&v| v as i32).collect(); // as-ok: lossless, quantized |w| <= 512
        Self { c_out, c_in, kh, kw, w: wq, wt, wt32, w_frac, in_frac, bias: quantize_bias(bias, w_frac + in_frac) }
    }
}

#[derive(Clone, Debug, Default)]
/// The dense MAC Tile Engine of the SPS Core.
pub struct TileEngine {
    /// Saturation counters (quantization diagnostics).
    pub sat: SaturationTruncation,
    /// Reused HWC accumulator buffers (perf: avoids per-call allocation).
    acc: Vec<i64>,
    acc32: Vec<i32>,
}

impl TileEngine {
    /// Fresh engine.
    pub fn new() -> Self {
        Self::default()
    }

    /// SAME-padded stride-1 convolution over `input` `[C_in, H, W]`
    /// (values at `conv.in_frac`). Output `[C_out, H, W]` in the wide
    /// activation format, ready for the SEA / LIF array.
    ///
    /// `spike_input` marks binary inputs: MACs degenerate to adds and SOPs
    /// are counted as spikes x fan-out, matching the SOP definition.
    /// Allocates the output; the hot loop uses [`Self::conv2d_into`].
    pub fn conv2d(
        &mut self,
        input: &QTensor,
        conv: &QuantizedConv,
        cfg: &AccelConfig,
        spike_input: bool,
    ) -> (QTensor, UnitStats) {
        self.conv2d_into(input, conv, cfg, spike_input, &mut ExecScratch::new())
    }

    /// [`Self::conv2d`] with the output tensor recycled through `scratch`
    /// (bit-identical output).
    pub fn conv2d_into(
        &mut self,
        input: &QTensor,
        conv: &QuantizedConv,
        cfg: &AccelConfig,
        spike_input: bool,
        scratch: &mut ExecScratch,
    ) -> (QTensor, UnitStats) {
        assert_eq!(input.shape.len(), 3, "expect [C,H,W]");
        let (c_in, h, w) = (input.shape[0], input.shape[1], input.shape[2]);
        assert_eq!(c_in, conv.c_in, "conv input channel mismatch");
        assert_eq!(input.frac, conv.in_frac, "input frac mismatch");
        let (ph, pw) = (conv.kh / 2, conv.kw / 2);

        let mut out = scratch.take_tensor(&[conv.c_out, h, w], ACT_FRAC);
        let out_fmt = QFormat::new(MEM_BITS, ACT_FRAC);
        let mut nonzero_inputs: u64 = 0;

        // Scatter-form convolution (perf pass, EXPERIMENTS.md §Perf): walk
        // the (sparse) input once; each nonzero input scatters its w-row
        // into an HWC-layout accumulator so the inner output-channel loop
        // is contiguous (SIMD-friendly). Exact i64 accumulation — integer
        // adds commute, so this is bit-identical to the direct form.
        let n_out = conv.c_out;
        // i32 accumulators are 2x SIMD-wider than i64 and provably cannot
        // overflow here: |acc| <= |bias| (24-bit) + taps * max|in| * max|w|.
        let max_in = input.data.iter().map(|&v| (v as i64).abs()).max().unwrap_or(0).max(1); // as-ok: widening into i64 accumulator math
        let worst = (1i64 << 23) + (c_in * conv.kh * conv.kw) as i64 * max_in * 512; // as-ok: widening into i64 accumulator math
        let use_i32 = worst < i32::MAX as i64 / 2; // as-ok: widening into i64 accumulator math
        let shift = conv.w_frac + conv.in_frac;
        let taps = conv.kh * conv.kw;

        if use_i32 {
            self.acc32.clear();
            self.acc32.resize(h * w * n_out, 0);
            let acc = &mut self.acc32;
            let wt = &conv.wt32;
            for pos in 0..h * w {
                for (a, &b) in acc[pos * n_out..(pos + 1) * n_out].iter_mut().zip(&conv.bias) {
                    *a = i32::try_from(b).expect("bias outside the guarded i32 accumulator range");
                }
            }
            for i in 0..c_in {
                let plane = &input.data[i * h * w..(i + 1) * h * w];
                for (pos, &v) in plane.iter().enumerate() {
                    if v == 0 {
                        continue;
                    }
                    nonzero_inputs += 1;
                    let (y, x) = (pos / w, pos % w);
                    for ky in 0..conv.kh {
                        let oy = y + ph;
                        if oy < ky || oy - ky >= h {
                            continue;
                        }
                        let oy = oy - ky;
                        for kx in 0..conv.kw {
                            let ox = x + pw;
                            if ox < kx || ox - kx >= w {
                                continue;
                            }
                            let ox = ox - kx;
                            let dst =
                                &mut acc[(oy * w + ox) * n_out..(oy * w + ox + 1) * n_out];
                            let src = &wt[((i * taps) + ky * conv.kw + kx) * n_out
                                ..((i * taps) + ky * conv.kw + kx + 1) * n_out];
                            if v == 1 {
                                for (d, &s) in dst.iter_mut().zip(src) {
                                    *d += s;
                                }
                            } else {
                                for (d, &s) in dst.iter_mut().zip(src) {
                                    *d += v * s;
                                }
                            }
                        }
                    }
                }
            }
            let sat = &mut self.sat;
            for o in 0..n_out {
                for pos in 0..h * w {
                    out.data[o * h * w + pos] =
                        sat.convert(acc[pos * n_out + o] as i64, shift, out_fmt); // as-ok: widening into i64 accumulator math
                }
            }
        } else {
            self.acc.clear();
            self.acc.resize(h * w * n_out, 0);
            let acc = &mut self.acc;
            let wt = &conv.wt;
            for pos in 0..h * w {
                acc[pos * n_out..(pos + 1) * n_out].copy_from_slice(&conv.bias);
            }
            for i in 0..c_in {
                let plane = &input.data[i * h * w..(i + 1) * h * w];
                for (pos, &v) in plane.iter().enumerate() {
                    if v == 0 {
                        continue;
                    }
                    nonzero_inputs += 1;
                    let (y, x) = (pos / w, pos % w);
                    for ky in 0..conv.kh {
                        let oy = y + ph;
                        if oy < ky || oy - ky >= h {
                            continue;
                        }
                        let oy = oy - ky;
                        for kx in 0..conv.kw {
                            let ox = x + pw;
                            if ox < kx || ox - kx >= w {
                                continue;
                            }
                            let ox = ox - kx;
                            let dst =
                                &mut acc[(oy * w + ox) * n_out..(oy * w + ox + 1) * n_out];
                            let src = &wt[((i * taps) + ky * conv.kw + kx) * n_out
                                ..((i * taps) + ky * conv.kw + kx + 1) * n_out];
                            let vv = v as i64; // as-ok: widening into i64 accumulator math
                            for (d, &s) in dst.iter_mut().zip(src) {
                                *d += vv * s;
                            }
                        }
                    }
                }
            }
            let sat = &mut self.sat;
            for o in 0..n_out {
                for pos in 0..h * w {
                    out.data[o * h * w + pos] = sat.convert(acc[pos * n_out + o], shift, out_fmt);
                }
            }
        }

        let total_macs = (conv.c_out * h * w * c_in * conv.kh * conv.kw) as u64; // as-ok: widening for 64-bit stat/cycle math
        let fan_out = (conv.c_out * conv.kh * conv.kw) as u64; // as-ok: widening for 64-bit stat/cycle math
        let sops = if spike_input { nonzero_inputs * fan_out } else { total_macs };
        let stats = UnitStats {
            cycles: div_ceil(total_macs, cfg.tile_macs as u64).max(1), // as-ok: widening for 64-bit stat/cycle math
            sops,
            macs: if spike_input { 0 } else { total_macs },
            adds: if spike_input { total_macs } else { 0 },
            sram_reads: (input.len() as u64) + total_macs, // acts + weights // as-ok: widening for 64-bit stat/cycle math
            sram_writes: out.len() as u64, // as-ok: widening for 64-bit stat/cycle math
            ..Default::default()
        };
        (out, stats)
    }
}

/// Float reference convolution used by tests.
pub fn conv2d_f32_reference(
    input: &[f32],
    c_in: usize,
    h: usize,
    w: usize,
    wts: &[f32],
    bias: &[f32],
    c_out: usize,
    kh: usize,
    kw: usize,
) -> Vec<f32> {
    let (ph, pw) = (kh / 2, kw / 2);
    let mut out = vec![0f32; c_out * h * w];
    for o in 0..c_out {
        for oy in 0..h {
            for ox in 0..w {
                let mut acc = bias[o];
                for i in 0..c_in {
                    for ky in 0..kh {
                        let iy = oy as isize + ky as isize - ph as isize; // as-ok: signed padding-window arithmetic
                        if iy < 0 || iy >= h as isize { // as-ok: signed padding-window arithmetic
                            continue;
                        }
                        for kx in 0..kw {
                            let ix = ox as isize + kx as isize - pw as isize; // as-ok: signed padding-window arithmetic
                            if ix < 0 || ix >= w as isize { // as-ok: signed padding-window arithmetic
                                continue;
                            }
                            acc += input[(i * h + iy as usize) * w + ix as usize] // as-ok: narrow-int index widening
                                * wts[((o * c_in + i) * kh + ky) * kw + kx];
                        }
                    }
                }
                out[(o * h + oy) * w + ox] = acc;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    #[test]
    fn identity_kernel_passthrough() {
        // 1x1 kernel with weight 1.0 reproduces the input.
        let conv = QuantizedConv::from_f32(&[1.0], &[0.0], 1, 1, 1, 1, ACT_FRAC);
        let input = QTensor::from_f32(
            &[0.5, -0.25, 1.0, 0.0],
            &[1, 2, 2],
            QFormat::new(MEM_BITS, ACT_FRAC),
        );
        let mut te = TileEngine::new();
        let (out, _) = te.conv2d(&input, &conv, &AccelConfig::small(), false);
        assert_eq!(out.data, input.data);
    }

    #[test]
    fn matches_float_reference_within_quantization() {
        let mut rng = Prng::new(21);
        let (c_in, c_out, h, w) = (3, 6, 8, 8);
        let wts: Vec<f32> = (0..c_out * c_in * 9).map(|_| rng.next_f32_signed() * 0.3).collect();
        let bias: Vec<f32> = (0..c_out).map(|_| rng.next_f32_signed() * 0.2).collect();
        let inp: Vec<f32> = (0..c_in * h * w).map(|_| rng.next_f32_signed()).collect();

        let conv = QuantizedConv::from_f32(&wts, &bias, c_out, c_in, 3, 3, ACT_FRAC);
        let qin = QTensor::from_f32(&inp, &[c_in, h, w], QFormat::new(MEM_BITS, ACT_FRAC));
        let mut te = TileEngine::new();
        let (out, _) = te.conv2d(&qin, &conv, &AccelConfig::small(), false);

        // Reference on the *quantized* input, float weights.
        let want = conv2d_f32_reference(&qin.to_f32(), c_in, h, w, &wts, &bias, c_out, 3, 3);
        let got = out.to_f32();
        let mut max_err = 0f32;
        for (g, t) in got.iter().zip(&want) {
            max_err = max_err.max((g - t).abs());
        }
        // error budget: weight rounding (27 taps) + output rounding
        let w_scale = 2f32.powi(-conv.w_frac);
        let budget = 27.0 * w_scale * 0.5 * 1.2 + 2f32.powi(-ACT_FRAC);
        assert!(max_err <= budget, "max_err {max_err} > budget {budget}");
    }

    #[test]
    fn spike_input_counts_sops_by_fanout() {
        let mut rng = Prng::new(22);
        let (c_in, c_out, h, w) = (4, 4, 4, 4);
        let wts: Vec<f32> = (0..c_out * c_in * 9).map(|_| rng.next_f32_signed()).collect();
        let conv = QuantizedConv::from_f32(&wts, &vec![0.0; c_out], c_out, c_in, 3, 3, 0);
        let mut data = vec![0i32; c_in * h * w];
        data[3] = 1;
        data[20] = 1; // two spikes
        let qin = QTensor { shape: vec![c_in, h, w], frac: 0, data };
        let mut te = TileEngine::new();
        let (_, stats) = te.conv2d(&qin, &conv, &AccelConfig::small(), true);
        assert_eq!(stats.sops, 2 * (c_out * 9) as u64);
        assert_eq!(stats.macs, 0);
    }

    #[test]
    fn cycles_use_all_macs() {
        let conv = QuantizedConv::from_f32(&vec![0.1; 8 * 8 * 9], &vec![0.0; 8], 8, 8, 3, 3, 0);
        let qin = QTensor::zeros(&[8, 16, 16], 0);
        let mut te = TileEngine::new();
        let cfg = AccelConfig::small(); // 32 MACs
        let (_, stats) = te.conv2d(&qin, &conv, &cfg, true);
        let total = (8 * 16 * 16 * 8 * 9) as u64;
        assert_eq!(stats.cycles, div_ceil(total, 32));
    }
}
