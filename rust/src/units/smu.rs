//! Spike Maxpooling Unit (SMU, Fig. 3): maxpooling over binary spikes via
//! encoded positions. A kernel output is '1' iff its window covers at least
//! one spike address, so the unit touches only the (few) encoded spikes and
//! reuses each spike for every overlapping kernel simultaneously — the
//! "or" of Fig. 3 — instead of comparing all values in every window.
//!
//! Cycle model: one encoded spike per SMU per cycle; `smu_units` channels
//! are pooled concurrently. A conventional (dense) maxpool module for
//! non-spike input is also provided for the SPS Core's Maxpooling Array and
//! as the redundancy-elimination baseline (ablation A1).

use crate::hw::{AccelConfig, UnitStats};
use crate::scratch::ExecScratch;
use crate::spike::bitmap::WORD_BITS;
use crate::spike::{EncodedSpikes, PackedBitmap, TokenGrid};
use crate::util::div_ceil;

#[derive(Clone, Copy, Debug)]
/// The Spike Maxpooling Unit array (spike-input pooling).
pub struct SpikeMaxpoolUnit {
    /// Pooling kernel side.
    pub kernel: usize,
    /// Pooling stride.
    pub stride: usize,
}

impl SpikeMaxpoolUnit {
    /// A pooling array with the given kernel and stride.
    pub fn new(kernel: usize, stride: usize) -> Self {
        assert!(kernel >= 1 && stride >= 1);
        Self { kernel, stride }
    }

    /// Pool `input` (addresses on `grid`) to the pooled grid.
    ///
    /// Allocates fresh output storage; the hot loop uses
    /// [`Self::pool_into`].
    pub fn pool(
        &self,
        input: &EncodedSpikes,
        grid: TokenGrid,
        cfg: &AccelConfig,
    ) -> (EncodedSpikes, UnitStats) {
        self.pool_into(input, grid, cfg, &mut ExecScratch::new())
    }

    /// [`Self::pool`] with the output arena and coverage buffers recycled
    /// through `scratch` (bit-identical output).
    pub fn pool_into(
        &self,
        input: &EncodedSpikes,
        grid: TokenGrid,
        cfg: &AccelConfig,
        scratch: &mut ExecScratch,
    ) -> (EncodedSpikes, UnitStats) {
        assert_eq!(input.tokens, grid.tokens(), "grid/token mismatch");
        let out_grid = grid.pooled(self.kernel, self.stride);
        let mut out = scratch.take_enc(input.channels, out_grid.tokens());
        let mut covered = scratch.take_bool(out_grid.tokens());
        let mut cover_buf = scratch.take_usize();
        let mut or_ops: u64 = 0;

        for c in 0..input.channels {
            let list = input.channel_addrs(c);
            if list.is_empty() {
                continue;
            }
            covered.fill(false);
            for &addr in list {
                let (y, x) = grid.coords(addr as usize); // as-ok: narrow-int index widening
                grid.covering_outputs(y, x, self.kernel, self.stride, &mut cover_buf);
                or_ops += cover_buf.len() as u64; // as-ok: widening for 64-bit stat/cycle math
                for &o in &cover_buf {
                    covered[o] = true;
                }
            }
            for (o, &hit) in covered.iter().enumerate() {
                if hit {
                    out.push(c, o);
                }
            }
        }

        let spikes = input.count_spikes() as u64; // as-ok: widening for 64-bit stat/cycle math
        let stats = UnitStats {
            // one spike per SMU per cycle, channels spread over the array
            cycles: div_ceil(spikes, cfg.smu_units as u64).max(1), // as-ok: widening for 64-bit stat/cycle math
            sops: spikes,
            adds: spikes * 2, // window-address arithmetic per spike
            cmps: or_ops,     // the per-kernel "or" updates
            sram_reads: spikes,
            sram_writes: out.storage_words() as u64, // as-ok: widening for 64-bit stat/cycle math
            ..Default::default()
        };
        scratch.put_bool(covered);
        scratch.put_usize(cover_buf);
        (out, stats)
    }

    /// The packed-bitmap engine on an already-materialized input
    /// (allocating convenience around [`Self::pool_bitmap_into`]).
    pub fn pool_bitmap(
        &self,
        input: &PackedBitmap,
        grid: TokenGrid,
        cfg: &AccelConfig,
    ) -> (EncodedSpikes, UnitStats) {
        self.pool_bitmap_into(input, grid, cfg, &mut ExecScratch::new())
    }

    /// Word-parallel pooling engine
    /// ([`EngineKind::Bitmap`](crate::hw::EngineKind)): a window output
    /// fires iff any of its `kernel` row-segments is nonzero, probed as
    /// one [`PackedBitmap::extract_bits`] gather per window row instead
    /// of per-spike address arithmetic. Bit-identical output to
    /// [`Self::pool_into`] on the same spikes.
    ///
    /// Cycle model: `C x out_tokens x kernel` word gathers spread over
    /// the `smu_units` array (one gather per unit per cycle) — dense in
    /// the *window* count but 64-way parallel in the token dimension,
    /// sitting between the spike-proportional encoded engine and the
    /// per-position dense baseline.
    pub fn pool_bitmap_into(
        &self,
        input: &PackedBitmap,
        grid: TokenGrid,
        cfg: &AccelConfig,
        scratch: &mut ExecScratch,
    ) -> (EncodedSpikes, UnitStats) {
        assert_eq!(input.tokens(), grid.tokens(), "grid/token mismatch");
        assert!(self.kernel <= WORD_BITS, "window row wider than one word");
        let out_grid = grid.pooled(self.kernel, self.stride);
        let mut out = scratch.take_enc(input.channels(), out_grid.tokens());
        let mut word_ops: u64 = 0;
        for c in 0..input.channels() {
            for oy in 0..out_grid.height {
                for ox in 0..out_grid.width {
                    let mut any = false;
                    for ky in 0..self.kernel {
                        word_ops += 1;
                        let start = grid.addr(oy * self.stride + ky, ox * self.stride);
                        any |= input.extract_bits(c, start, self.kernel) != 0;
                    }
                    if any {
                        out.push(c, out_grid.addr(oy, ox));
                    }
                }
            }
        }
        let stats = UnitStats {
            cycles: div_ceil(word_ops, cfg.smu_units as u64).max(1), // as-ok: widening for 64-bit stat/cycle math
            sops: input.count_ones() as u64, // as-ok: widening for 64-bit stat/cycle math
            cmps: word_ops, // per-window-row word probes
            sram_reads: word_ops,
            sram_writes: out.storage_words() as u64, // as-ok: widening for 64-bit stat/cycle math
            ..Default::default()
        };
        (out, stats)
    }

    /// Conventional dense maxpool on a binary bitmap (baseline): every
    /// window position compares all kernel*kernel values. Allocates the
    /// output; the bitmap-mode hot loop uses
    /// [`Self::pool_dense_baseline_into`].
    pub fn pool_dense_baseline(
        &self,
        input: &EncodedSpikes,
        grid: TokenGrid,
        cfg: &AccelConfig,
    ) -> (EncodedSpikes, UnitStats) {
        self.pool_dense_baseline_into(input, grid, cfg, &mut ExecScratch::new())
    }

    /// [`Self::pool_dense_baseline`] with the output arena recycled
    /// through `scratch` (keeps bitmap-mode take/put balance).
    pub fn pool_dense_baseline_into(
        &self,
        input: &EncodedSpikes,
        grid: TokenGrid,
        cfg: &AccelConfig,
        scratch: &mut ExecScratch,
    ) -> (EncodedSpikes, UnitStats) {
        let bitmap = input.to_bitmap();
        let out_grid = grid.pooled(self.kernel, self.stride);
        let mut out = scratch.take_enc(input.channels, out_grid.tokens());
        let mut cmps: u64 = 0;
        for c in 0..input.channels {
            for oy in 0..out_grid.height {
                for ox in 0..out_grid.width {
                    let mut any = false;
                    for ky in 0..self.kernel {
                        for kx in 0..self.kernel {
                            cmps += 1;
                            any |= bitmap.get(c, grid.addr(oy * self.stride + ky, ox * self.stride + kx));
                        }
                    }
                    if any {
                        out.push(c, out_grid.addr(oy, ox));
                    }
                }
            }
        }
        let reads = input.channels as u64 * grid.tokens() as u64; // as-ok: widening for 64-bit stat/cycle math
        let stats = UnitStats {
            cycles: div_ceil(cmps, cfg.smu_units as u64).max(1), // as-ok: widening for 64-bit stat/cycle math
            sops: input.count_spikes() as u64, // as-ok: widening for 64-bit stat/cycle math
            cmps,
            sram_reads: reads,
            sram_writes: out.storage_words() as u64, // as-ok: widening for 64-bit stat/cycle math
            ..Default::default()
        };
        (out, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spike::SpikeMatrix;
    use crate::util::Prng;

    fn random_encoded(rng: &mut Prng, c: usize, g: TokenGrid, p: f64) -> EncodedSpikes {
        let mut m = SpikeMatrix::zeros(c, g.tokens());
        for ci in 0..c {
            for l in 0..g.tokens() {
                if rng.bernoulli(p) {
                    m.set(ci, l, true);
                }
            }
        }
        EncodedSpikes::from_bitmap(&m)
    }

    /// Reference: dense OR-maxpool on the bitmap.
    fn dense_ref(input: &EncodedSpikes, g: TokenGrid, kernel: usize, stride: usize) -> SpikeMatrix {
        let bm = input.to_bitmap();
        let og = g.pooled(kernel, stride);
        let mut out = SpikeMatrix::zeros(input.channels, og.tokens());
        for c in 0..input.channels {
            for oy in 0..og.height {
                for ox in 0..og.width {
                    let mut any = false;
                    for ky in 0..kernel {
                        for kx in 0..kernel {
                            any |= bm.get(c, g.addr(oy * stride + ky, ox * stride + kx));
                        }
                    }
                    out.set(c, og.addr(oy, ox), any);
                }
            }
        }
        out
    }

    #[test]
    fn matches_dense_reference_2x2_s2() {
        let mut rng = Prng::new(3);
        let g = TokenGrid::new(8, 8);
        let smu = SpikeMaxpoolUnit::new(2, 2);
        for &p in &[0.0, 0.1, 0.4, 1.0] {
            let enc = random_encoded(&mut rng, 5, g, p);
            let (out, _) = smu.pool(&enc, g, &AccelConfig::small());
            assert_eq!(out.to_bitmap(), dense_ref(&enc, g, 2, 2));
            assert!(out.is_well_formed());
        }
    }

    #[test]
    fn matches_dense_reference_2x2_s1_fig3() {
        // The paper's Fig. 3 configuration: kernel 2x2, stride 1, with
        // overlap reuse.
        let mut rng = Prng::new(4);
        let g = TokenGrid::new(6, 6);
        let smu = SpikeMaxpoolUnit::new(2, 1);
        let enc = random_encoded(&mut rng, 3, g, 0.2);
        let (out, _) = smu.pool(&enc, g, &AccelConfig::small());
        assert_eq!(out.to_bitmap(), dense_ref(&enc, g, 2, 1));
    }

    #[test]
    fn single_spike_covers_multiple_kernels() {
        // Fig. 3's m01 example: one interior spike lights several outputs.
        let g = TokenGrid::new(4, 4);
        let mut m = SpikeMatrix::zeros(1, 16);
        m.set(0, g.addr(1, 1), true);
        let enc = EncodedSpikes::from_bitmap(&m);
        let (out, _) = SpikeMaxpoolUnit::new(2, 1).pool(&enc, g, &AccelConfig::small());
        assert_eq!(out.count_spikes(), 4); // covered by 4 overlapping kernels
    }

    #[test]
    fn sparse_cheaper_than_dense_baseline() {
        let mut rng = Prng::new(5);
        let g = TokenGrid::new(16, 16);
        let smu = SpikeMaxpoolUnit::new(2, 2);
        let cfg = AccelConfig::small();
        let enc = random_encoded(&mut rng, 8, g, 0.1); // 90% sparsity
        let (o1, s_sparse) = smu.pool(&enc, g, &cfg);
        let (o2, s_dense) = smu.pool_dense_baseline(&enc, g, &cfg);
        assert_eq!(o1, o2, "sparse and dense must agree");
        assert!(
            s_sparse.cycles < s_dense.cycles,
            "sparse {} !< dense {}",
            s_sparse.cycles,
            s_dense.cycles
        );
    }

    #[test]
    fn bitmap_engine_bit_identical_to_encoded() {
        let mut rng = Prng::new(6);
        let cfg = AccelConfig::small();
        for &(h, w, k, s) in &[(8usize, 8usize, 2usize, 2usize), (6, 6, 2, 1), (9, 12, 3, 3)] {
            let g = TokenGrid::new(h, w);
            let smu = SpikeMaxpoolUnit::new(k, s);
            for &p in &[0.0, 0.1, 0.5, 1.0] {
                let enc = random_encoded(&mut rng, 5, g, p);
                let bm = PackedBitmap::from_encoded(&enc);
                let (o1, s1) = smu.pool(&enc, g, &cfg);
                let (o2, s2) = smu.pool_bitmap(&bm, g, &cfg);
                assert_eq!(o1, o2, "engines must agree at ({h},{w},{k},{s}) p={p}");
                assert!(o2.is_well_formed());
                assert_eq!(s1.sops, s2.sops);
            }
        }
    }

    #[test]
    fn bitmap_engine_cost_is_window_bound() {
        // The word engine's cost depends on the window count, not the
        // spike count: empty and full inputs charge identical cycles.
        let g = TokenGrid::new(8, 8);
        let cfg = AccelConfig::small(); // 16 SMUs
        let smu = SpikeMaxpoolUnit::new(2, 2);
        let empty = PackedBitmap::zeros(4, 64);
        let mut full = PackedBitmap::zeros(4, 64);
        for c in 0..4 {
            for l in 0..64 {
                full.set(c, l);
            }
        }
        let (_, s_empty) = smu.pool_bitmap(&empty, g, &cfg);
        let (_, s_full) = smu.pool_bitmap(&full, g, &cfg);
        assert_eq!(s_empty.cycles, s_full.cycles);
        // 4 channels x 16 windows x 2 rows = 128 gathers over 16 units.
        assert_eq!(s_empty.cmps, 128);
        assert_eq!(s_empty.cycles, 8);
    }

    #[test]
    fn empty_input_is_one_cycle() {
        let g = TokenGrid::new(8, 8);
        let enc = EncodedSpikes::empty(4, 64);
        let (out, stats) = SpikeMaxpoolUnit::new(2, 2).pool(&enc, g, &AccelConfig::small());
        assert_eq!(out.count_spikes(), 0);
        assert_eq!(stats.cycles, 1);
        assert_eq!(stats.sops, 0);
    }
}
