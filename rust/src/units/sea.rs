//! Spike Encoding Array (SEA, Fig. 2): an array of Spike Encoding Units,
//! each a LIF neuron whose fire decision writes the *current token address*
//! into the ESS instead of a bitmap bit.
//!
//! Cycle model: `lanes` SEUs update in parallel, one neuron-timestep per
//! lane per cycle; encoded addresses stream to the ESS banks as a side
//! effect (one SRAM write per spike plus one segment header per new
//! 256-token segment).

use crate::hw::{AccelConfig, UnitStats};
use crate::lif::{LifArray, LifParams};
use crate::scratch::ExecScratch;
use crate::spike::EncodedSpikes;
use crate::util::div_ceil;

/// A bank of SEUs covering a `[channels, tokens]` activation tile.
#[derive(Clone, Debug)]
pub struct SpikeEncodingArray {
    /// Channels of this encode site.
    pub channels: usize,
    /// Tokens of this encode site.
    pub tokens: usize,
    lif: LifArray,
}

impl SpikeEncodingArray {
    /// An SEA over a `[channels, tokens]` site with LIF parameters.
    pub fn new(channels: usize, tokens: usize, params: LifParams) -> Self {
        Self { channels, tokens, lif: LifArray::new(channels * tokens, params) }
    }

    /// Reset temporal state between images.
    pub fn reset(&mut self) {
        self.lif.reset();
    }

    /// Encode one timestep of spatial input (`[C, L]` row-major, activation
    /// format). Returns the encoded spikes and the cycle/op record.
    ///
    /// Allocates a fresh arena; the hot loop uses [`Self::encode_into`].
    pub fn encode(&mut self, spa: &[i32], cfg: &AccelConfig) -> (EncodedSpikes, UnitStats) {
        self.encode_into(spa, cfg, &mut ExecScratch::new())
    }

    /// [`Self::encode`] writing into a recycled arena from `scratch`
    /// (bit-identical output; no allocation once the pool is warm).
    pub fn encode_into(
        &mut self,
        spa: &[i32],
        cfg: &AccelConfig,
        scratch: &mut ExecScratch,
    ) -> (EncodedSpikes, UnitStats) {
        assert_eq!(spa.len(), self.channels * self.tokens);
        let mut enc = scratch.take_enc(self.channels, self.tokens);
        for c in 0..self.channels {
            for l in 0..self.tokens {
                let idx = c * self.tokens + l;
                if self.lif.step_one(idx, spa[idx]) {
                    enc.push(c, l);
                }
            }
        }
        let n = spa.len() as u64; // as-ok: widening for 64-bit stat/cycle math
        let stats = UnitStats {
            cycles: div_ceil(n, cfg.lanes as u64), // as-ok: widening for 64-bit stat/cycle math
            adds: n,                                  // Eq. (2) membrane add
            cmps: n,                                  // Eq. (3) threshold
            sram_reads: n,                            // spatial input read
            sram_writes: enc.storage_words() as u64,  // encoded addresses // as-ok: widening for 64-bit stat/cycle math
            ..Default::default()
        };
        (enc, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{QFormat, ACT_FRAC, MEM_BITS};

    fn act(v: f32) -> i32 {
        QFormat::new(MEM_BITS, ACT_FRAC).from_f32(v)
    }

    #[test]
    fn encodes_fired_positions_in_order() {
        let mut sea = SpikeEncodingArray::new(2, 4, LifParams::default());
        let spa = vec![
            act(1.5), act(0.0), act(2.0), act(0.1), // ch0: fires at 0, 2
            act(0.0), act(1.0), act(0.0), act(0.0), // ch1: fires at 1
        ];
        let (enc, stats) = sea.encode(&spa, &AccelConfig::small());
        assert_eq!(enc.channel_addrs(0), &[0u16, 2][..]);
        assert_eq!(enc.channel_addrs(1), &[1u16][..]);
        assert!(enc.is_well_formed());
        assert_eq!(stats.adds, 8);
        assert_eq!(stats.cmps, 8);
        assert_eq!(stats.cycles, 1); // 8 neurons / 64 lanes
    }

    #[test]
    fn temporal_state_carries_across_timesteps() {
        let mut sea = SpikeEncodingArray::new(1, 1, LifParams::default());
        let cfg = AccelConfig::small();
        // 0.6 then 0.6 then 0.6: fires on the third step (0.6,0.9,1.05).
        let (e1, _) = sea.encode(&[act(0.6)], &cfg);
        let (e2, _) = sea.encode(&[act(0.6)], &cfg);
        let (e3, _) = sea.encode(&[act(0.6)], &cfg);
        assert_eq!(e1.count_spikes(), 0);
        assert_eq!(e2.count_spikes(), 0);
        assert_eq!(e3.count_spikes(), 1);
    }

    #[test]
    fn cycles_scale_with_lanes() {
        let mut sea = SpikeEncodingArray::new(48, 64, LifParams::default());
        let spa = vec![0; 48 * 64];
        let (_, s_small) = sea.encode(&spa, &AccelConfig::small()); // 64 lanes
        sea.reset();
        let (_, s_big) = sea.encode(&spa, &AccelConfig::paper()); // 1536 lanes
        assert_eq!(s_small.cycles, 48);
        assert_eq!(s_big.cycles, 2);
    }

    #[test]
    fn reset_clears_membranes() {
        let mut sea = SpikeEncodingArray::new(1, 1, LifParams::default());
        let cfg = AccelConfig::small();
        sea.encode(&[act(0.9)], &cfg);
        sea.reset();
        let (enc, _) = sea.encode(&[act(0.9)], &cfg);
        assert_eq!(enc.count_spikes(), 0); // no leftover membrane
    }
}
