//! Fixed-point primitives: saturation, rounding shifts, Q-format metadata.

/// Saturate `v` into a signed `bits`-wide integer range.
#[inline]
pub fn sat(v: i64, bits: u32) -> i32 {
    debug_assert!(bits >= 2 && bits <= 32);
    let hi = (1i64 << (bits - 1)) - 1;
    let lo = -(1i64 << (bits - 1));
    v.clamp(lo, hi) as i32
}

/// Arithmetic right shift with round-to-nearest (ties away from zero);
/// negative `shift` is a left shift. Mirrors the RTL rounding stage.
#[inline]
pub fn rshift_round(v: i64, shift: i32) -> i64 {
    if shift <= 0 {
        return v << (-shift) as u32;
    }
    let s = shift as u32;
    let bias = 1i64 << (s - 1);
    if v >= 0 {
        (v + bias) >> s
    } else {
        -((-v + bias) >> s)
    }
}

/// A power-of-two-scaled signed fixed-point format: value = raw * 2^-frac.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QFormat {
    /// Total bits.
    pub bits: u32,
    /// Fraction bits.
    pub frac: i32,
}

impl QFormat {
    /// A format with `bits` total and `frac` fraction bits.
    pub const fn new(bits: u32, frac: i32) -> Self {
        Self { bits, frac }
    }

    /// Largest representable real value.
    pub fn max_value(&self) -> f64 {
        (((1i64 << (self.bits - 1)) - 1) as f64) * self.scale()
    }

    /// Value of one LSB step (2^-frac).
    pub fn scale(&self) -> f64 {
        2f64.powi(-self.frac)
    }

    /// Quantize a real value (round-to-nearest, saturating).
    pub fn from_f32(&self, v: f32) -> i32 {
        let raw = (v as f64 / self.scale()).round() as i64;
        sat(raw, self.bits)
    }

    /// Decode a raw integer value.
    pub fn to_f32(&self, raw: i32) -> f32 {
        (raw as f64 * self.scale()) as f32
    }
}

/// The Saturation-Truncation Module of Fig. 5(b): re-scale a wide
/// accumulator into a narrower output format, counting saturation events
/// (useful for quantization debugging and the paper's bit-width ablation).
#[derive(Clone, Debug, Default)]
pub struct SaturationTruncation {
    /// Conversions that clipped.
    pub saturations: u64,
    /// Total conversions.
    pub conversions: u64,
}

impl SaturationTruncation {
    /// Zeroed counters.
    pub fn new() -> Self {
        Self::default()
    }

    /// Convert `acc` (at `acc_frac` fractional bits) into `out` format.
    #[inline]
    pub fn convert(&mut self, acc: i64, acc_frac: i32, out: QFormat) -> i32 {
        let shifted = rshift_round(acc, acc_frac - out.frac);
        let clamped = sat(shifted, out.bits);
        self.conversions += 1;
        if clamped as i64 != shifted {
            self.saturations += 1;
        }
        clamped
    }

    /// Fraction of conversions that clipped.
    pub fn saturation_rate(&self) -> f64 {
        if self.conversions == 0 {
            0.0
        } else {
            self.saturations as f64 / self.conversions as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sat_clamps_both_sides() {
        assert_eq!(sat(511, 10), 511);
        assert_eq!(sat(512, 10), 511);
        assert_eq!(sat(-512, 10), -512);
        assert_eq!(sat(-513, 10), -512);
        assert_eq!(sat(0, 10), 0);
    }

    #[test]
    fn rshift_round_nearest() {
        assert_eq!(rshift_round(5, 1), 3); // 2.5 -> 3
        assert_eq!(rshift_round(4, 1), 2);
        assert_eq!(rshift_round(-5, 1), -3); // -2.5 -> -3 (away from zero)
        assert_eq!(rshift_round(6, 2), 2); // 1.5 -> 2
        assert_eq!(rshift_round(3, 0), 3);
        assert_eq!(rshift_round(3, -2), 12); // left shift
    }

    #[test]
    fn qformat_roundtrip() {
        let q = QFormat::new(10, 6);
        assert_eq!(q.from_f32(1.0), 64);
        assert_eq!(q.to_f32(64), 1.0);
        assert_eq!(q.from_f32(100.0), 511); // saturates
        assert_eq!(q.from_f32(-100.0), -512);
        let v = 0.421_f32;
        let err = (q.to_f32(q.from_f32(v)) - v).abs();
        assert!(err <= q.scale() as f32 / 2.0 + 1e-6);
    }

    #[test]
    fn sat_trunc_counts() {
        let mut st = SaturationTruncation::new();
        let out = QFormat::new(10, 6);
        // acc at frac 12 representing 2.0 -> fits
        assert_eq!(st.convert(2 << 12, 12, out), 128);
        // representing 100.0 -> saturates to 511
        assert_eq!(st.convert(100 << 12, 12, out), 511);
        assert_eq!(st.saturations, 1);
        assert_eq!(st.conversions, 2);
        assert!((st.saturation_rate() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn sat_trunc_negative_saturation() {
        let mut st = SaturationTruncation::new();
        let out = QFormat::new(10, 6);
        assert_eq!(st.convert(-(100i64 << 12), 12, out), -512);
        assert_eq!(st.saturations, 1);
    }
}
