//! Weight/bias quantization: per-layer power-of-two scales so the
//! accumulator re-scaling is a pure shift (hardware-friendly, matching the
//! paper's Saturation-Truncation stage).

use super::fixed::{sat, QFormat};
use super::{ACT_FRAC, WEIGHT_BITS};

/// Pick the largest fractional-bit count such that `max|w| * 2^frac` fits in
/// a signed `bits` integer. Clamped to [0, 20] to bound the shift network.
pub fn weight_frac(weights: &[f32], bits: u32) -> i32 {
    let max_abs = weights.iter().fold(0f32, |m, &w| m.max(w.abs()));
    if max_abs == 0.0 {
        return 20;
    }
    let limit = ((1i64 << (bits - 1)) - 1) as f32;
    let mut frac = (limit / max_abs).log2().floor() as i32;
    frac = frac.clamp(0, 20);
    frac
}

/// Quantize a weight array with a per-layer power-of-two scale.
/// Returns (quantized, frac).
pub fn quantize_weights(weights: &[f32]) -> (Vec<i32>, i32) {
    let frac = weight_frac(weights, WEIGHT_BITS);
    let fmt = QFormat::new(WEIGHT_BITS, frac);
    (weights.iter().map(|&w| fmt.from_f32(w)).collect(), frac)
}

/// Quantize biases at the accumulator scale `acc_frac` (wide, 24-bit) so
/// they can be added before the saturation-truncation shift.
pub fn quantize_bias(bias: &[f32], acc_frac: i32) -> Vec<i64> {
    let scale = 2f64.powi(acc_frac);
    bias.iter()
        .map(|&b| sat(((b as f64) * scale).round() as i64, 24) as i64)
        .collect()
}

/// A fully-quantized linear layer: weights at `w_frac`, bias at the
/// accumulator scale (`w_frac + in_frac`), plus the bookkeeping needed to
/// drop the result back into the activation format.
#[derive(Clone, Debug)]
pub struct QuantizedLinear {
    /// Input width.
    pub in_dim: usize,
    /// Output width.
    pub out_dim: usize,
    /// Row-major `[in_dim][out_dim]` — row `c` is the weight row the SLU
    /// accumulates when input channel `c` spikes (Fig. 5).
    pub w: Vec<i32>,
    /// Weight fraction bits.
    pub w_frac: i32,
    /// Input fractional bits (0 for binary spike inputs).
    pub in_frac: i32,
    /// Bias at accumulator scale.
    pub bias: Vec<i64>,
}

impl QuantizedLinear {
    /// Quantize a float linear layer.
    pub fn from_f32(w: &[f32], bias: &[f32], in_dim: usize, out_dim: usize, in_frac: i32) -> Self {
        assert_eq!(w.len(), in_dim * out_dim);
        assert_eq!(bias.len(), out_dim);
        let (wq, w_frac) = quantize_weights(w);
        let acc_frac = w_frac + in_frac;
        Self { in_dim, out_dim, w: wq, w_frac, in_frac, bias: quantize_bias(bias, acc_frac) }
    }

    /// Accumulator fractional bits (input scale x weight scale).
    #[inline]
    pub fn acc_frac(&self) -> i32 {
        self.w_frac + self.in_frac
    }

    /// Shift to go from accumulator scale to activation scale.
    #[inline]
    pub fn out_shift(&self) -> i32 {
        self.acc_frac() - ACT_FRAC
    }

    #[inline]
    /// Weight row of input channel `c`.
    pub fn row(&self, c: usize) -> &[i32] {
        &self.w[c * self.out_dim..(c + 1) * self.out_dim]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weight_frac_fits_max() {
        let w = [0.5f32, -0.25, 0.1];
        let frac = weight_frac(&w, 10);
        let limit = 511f32;
        assert!(0.5 * 2f32.powi(frac) <= limit);
        assert!(0.5 * 2f32.powi(frac + 1) > limit);
    }

    #[test]
    fn quantize_weights_max_uses_range() {
        let w = [1.0f32, -1.0, 0.5];
        let (q, frac) = quantize_weights(&w);
        assert_eq!(frac, 8); // 1.0 * 2^8 = 256 <= 511 < 1.0 * 2^9
        assert_eq!(q, vec![256, -256, 128]);
    }

    #[test]
    fn zero_weights_dont_panic() {
        let (q, frac) = quantize_weights(&[0.0, 0.0]);
        assert_eq!(q, vec![0, 0]);
        assert_eq!(frac, 20);
    }

    #[test]
    fn quantized_linear_layout() {
        let w = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]; // [3][2]
        let l = QuantizedLinear::from_f32(&w, &[0.0, 0.0], 3, 2, 0);
        assert_eq!(l.row(1).len(), 2);
        let scale = 2f32.powi(l.w_frac);
        assert_eq!(l.row(1)[0], (3.0 * scale).round() as i32);
        assert_eq!(l.out_shift(), l.w_frac - ACT_FRAC);
    }

    #[test]
    fn bias_at_accumulator_scale() {
        let b = quantize_bias(&[1.0, -0.5], 8);
        assert_eq!(b, vec![256, -128]);
    }

    #[test]
    fn quantization_error_bounded() {
        let mut xs = Vec::new();
        for i in 0..100 {
            xs.push((i as f32 - 50.0) / 37.0);
        }
        let (q, frac) = quantize_weights(&xs);
        let scale = 2f32.powi(-frac);
        for (orig, &qi) in xs.iter().zip(&q) {
            assert!((orig - qi as f32 * scale).abs() <= scale / 2.0 + 1e-6);
        }
    }
}
