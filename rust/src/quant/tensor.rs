//! Dense integer tensors carrying fixed-point values through the pipeline.

use super::fixed::QFormat;

/// A dense row-major integer tensor with a shared Q-format.
#[derive(Clone, Debug, PartialEq)]
pub struct QTensor {
    /// Tensor shape.
    pub shape: Vec<usize>,
    /// Fraction bits of the fixed-point values.
    pub frac: i32,
    /// Raw fixed-point values, row-major.
    pub data: Vec<i32>,
}

impl QTensor {
    /// All-zero tensor.
    pub fn zeros(shape: &[usize], frac: i32) -> Self {
        let n: usize = shape.iter().product();
        Self { shape: shape.to_vec(), frac, data: vec![0; n] }
    }

    /// Quantize float values into `fmt`.
    pub fn from_f32(values: &[f32], shape: &[usize], fmt: QFormat) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(values.len(), n, "shape/value mismatch");
        Self {
            shape: shape.to_vec(),
            frac: fmt.frac,
            data: values.iter().map(|&v| fmt.from_f32(v)).collect(),
        }
    }

    /// Element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when the tensor is empty.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Decode back to floats.
    pub fn to_f32(&self) -> Vec<f32> {
        let scale = 2f32.powi(-self.frac);
        self.data.iter().map(|&v| v as f32 * scale).collect()
    }

    /// Row-major index of a 2-D element.
    #[inline]
    pub fn idx2(&self, i: usize, j: usize) -> usize {
        debug_assert_eq!(self.shape.len(), 2);
        i * self.shape[1] + j
    }

    /// Fraction of zero entries (used by the sparsity reports).
    pub fn sparsity(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        let zeros = self.data.iter().filter(|&&v| v == 0).count();
        zeros as f64 / self.data.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_f32_quantizes() {
        let fmt = QFormat::new(10, 6);
        let t = QTensor::from_f32(&[1.0, -0.5, 0.0, 20.0], &[2, 2], fmt);
        assert_eq!(t.data, vec![64, -32, 0, 511]);
        assert_eq!(t.shape, vec![2, 2]);
    }

    #[test]
    fn roundtrip_to_f32() {
        let fmt = QFormat::new(10, 6);
        let t = QTensor::from_f32(&[0.25, -1.0], &[2], fmt);
        assert_eq!(t.to_f32(), vec![0.25, -1.0]);
    }

    #[test]
    fn sparsity_counts_zeros() {
        let t = QTensor { shape: vec![4], frac: 0, data: vec![0, 1, 0, 2] };
        assert_eq!(t.sparsity(), 0.5);
        assert_eq!(QTensor::zeros(&[3], 0).sparsity(), 1.0);
    }

    #[test]
    #[should_panic(expected = "shape/value mismatch")]
    fn shape_mismatch_panics() {
        QTensor::from_f32(&[1.0], &[2], QFormat::new(10, 6));
    }
}
