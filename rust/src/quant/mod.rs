//! Fixed-point quantization substrate (paper §IV-A: 10-bit weights and
//! activations, 8-bit encoded spikes).
//!
//! All on-chip arithmetic in the simulator and the golden executor runs on
//! `i32` lanes carrying power-of-two-scaled fixed-point values, so the two
//! are bit-exact by construction. The [`fixed::SaturationTruncation`] module
//! models the unit of the same name in Fig. 5(b).

pub mod fixed;
pub mod quantizer;
pub mod tensor;

pub use fixed::{rshift_round, sat, QFormat, SaturationTruncation};
pub use quantizer::{quantize_bias, quantize_weights, QuantizedLinear};
pub use tensor::QTensor;

/// Bit width of weights and activations (paper: 10-bit quantization).
pub const ACT_BITS: u32 = 10;
/// Bit width of weights.
pub const WEIGHT_BITS: u32 = 10;
/// Bit width of an encoded spike address (paper: 8-bit encoded spikes).
pub const ADDR_BITS: u32 = 8;
/// Tokens addressable per encoding segment (2^ADDR_BITS).
pub const SEGMENT_TOKENS: usize = 1 << ADDR_BITS as usize;
/// Fractional bits of the shared activation format (Q3.6 in 10 bits).
pub const ACT_FRAC: i32 = 6;
/// Membrane accumulators are kept wider than activations (16-bit) before
/// saturation-truncation back to the activation format.
pub const MEM_BITS: u32 = 16;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constants_are_consistent() {
        assert_eq!(SEGMENT_TOKENS, 256);
        assert!(ACT_FRAC < ACT_BITS as i32);
        assert!(MEM_BITS > ACT_BITS);
    }
}
