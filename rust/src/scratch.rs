//! The steady-state scratch pool (`ExecScratch`): recycled frame storage
//! for the simulator's hot loop.
//!
//! Every timestep of every block used to allocate fresh [`EncodedSpikes`]
//! arenas, [`QTensor`] outputs and SMAM mask/acc vectors, then drop them —
//! thousands of heap round-trips per inference that have nothing to do
//! with the modelled hardware. `ExecScratch` is a set of per-type free
//! lists owned by the [`Accelerator`](crate::accel::Accelerator) (one per
//! pipeline stage, so the overlapped producer and consumer threads never
//! share one): units *take* storage, consumers *put* it back once drained,
//! and after warm-up the hot loop performs no arena/tensor allocations at
//! all.
//!
//! Determinism/bit-exactness contract: every `take_*` returns storage in
//! exactly the state a fresh allocation would have (zeroed buffers, empty
//! arenas of the requested geometry), so pooled and fresh execution are
//! bit-identical by construction. The [`ScratchStats`] counters let tests
//! assert the steady-state claim: after warm-up, `misses` stops growing.
//!
//! See `DESIGN.md` "Steady-state memory model" for the lifecycle rules
//! (who takes, who puts, how tensors migrate between stage pools).

use crate::quant::QTensor;
use crate::spike::{EncodedSpikes, PackedBitmap};

/// Hit/miss counters of one (or a sum of) scratch pool(s).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ScratchStats {
    /// Takes served from the free lists (no heap object created).
    pub hits: u64,
    /// Takes that had to allocate a fresh object (pool was empty).
    pub misses: u64,
}

impl ScratchStats {
    /// Fraction of takes served from the pool (1.0 when nothing missed;
    /// 0.0 before any take).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Combine two counters (e.g. the SPS-stage and SDEB-stage pools).
    pub fn merged(self, other: ScratchStats) -> ScratchStats {
        ScratchStats { hits: self.hits + other.hits, misses: self.misses + other.misses }
    }
}

/// Per-type free lists recycling the hot loop's frame storage.
///
/// Single-threaded by design: the controller owns one instance per
/// pipeline stage and hands `&mut` references down the call tree, so the
/// overlapped executor's producer and consumer threads each mutate their
/// own pool. Capacities only ever grow (a reused buffer keeps its largest
/// size), so the per-request allocation count converges to zero.
#[derive(Debug, Default)]
pub struct ExecScratch {
    encs: Vec<EncodedSpikes>,
    tensors: Vec<QTensor>,
    bufs_i32: Vec<Vec<i32>>,
    bufs_bool: Vec<Vec<bool>>,
    bufs_u32: Vec<Vec<u32>>,
    bufs_u64: Vec<Vec<u64>>,
    bufs_usize: Vec<Vec<usize>>,
    bitmaps: Vec<PackedBitmap>,
    hits: u64,
    misses: u64,
}

impl ExecScratch {
    /// An empty pool (everything misses until objects are put back).
    pub fn new() -> Self {
        Self::default()
    }

    /// Hit/miss counters so far.
    pub fn stats(&self) -> ScratchStats {
        ScratchStats { hits: self.hits, misses: self.misses }
    }

    /// Number of objects currently resting in the free lists (all
    /// classes). The leak canary: between requests every object is at
    /// rest, so a put/take imbalance anywhere in the datapath shows up as
    /// unbounded growth of this count across warm requests.
    pub fn pooled_objects(&self) -> usize {
        self.encs.len()
            + self.tensors.len()
            + self.bufs_i32.len()
            + self.bufs_bool.len()
            + self.bufs_u32.len()
            + self.bufs_u64.len()
            + self.bufs_usize.len()
            + self.bitmaps.len()
    }

    #[inline]
    fn count(&mut self, hit: bool) {
        if hit {
            self.hits += 1;
        } else {
            self.misses += 1;
        }
    }

    /// Take an empty `[channels, tokens]` encoded tensor, reusing a pooled
    /// arena's capacity when one is available (`EncodedSpikes::reset`).
    pub fn take_enc(&mut self, channels: usize, tokens: usize) -> EncodedSpikes {
        match self.encs.pop() {
            Some(mut e) => {
                self.count(true);
                e.reset(channels, tokens);
                e
            }
            None => {
                self.count(false);
                EncodedSpikes::empty(channels, tokens)
            }
        }
    }

    /// Return a drained encoded tensor to the pool (its arena capacity is
    /// kept for the next [`Self::take_enc`]).
    pub fn put_enc(&mut self, e: EncodedSpikes) {
        self.encs.push(e);
    }

    /// Take an all-zero tensor of `shape` at `frac` fraction bits —
    /// bit-identical to `QTensor::zeros`, minus the allocation.
    pub fn take_tensor(&mut self, shape: &[usize], frac: i32) -> QTensor {
        let mut t = self.pop_tensor();
        t.shape.clear();
        t.shape.extend_from_slice(shape);
        t.frac = frac;
        let n: usize = shape.iter().product();
        t.data.clear();
        t.data.resize(n, 0);
        t
    }

    /// Take a tensor holding a copy of `src` (shape, frac and values).
    pub fn take_tensor_copy(&mut self, src: &QTensor) -> QTensor {
        let mut t = self.pop_tensor();
        t.shape.clear();
        t.shape.extend_from_slice(&src.shape);
        t.frac = src.frac;
        t.data.clear();
        t.data.extend_from_slice(&src.data);
        t
    }

    fn pop_tensor(&mut self) -> QTensor {
        match self.tensors.pop() {
            Some(t) => {
                self.count(true);
                t
            }
            None => {
                self.count(false);
                QTensor { shape: Vec::new(), frac: 0, data: Vec::new() }
            }
        }
    }

    /// Return a tensor to the pool (both its shape and data capacity are
    /// kept).
    pub fn put_tensor(&mut self, t: QTensor) {
        self.tensors.push(t);
    }

    /// Take a zeroed `Vec<i32>` of `len` (transpose/scatter buffers).
    pub fn take_i32(&mut self, len: usize) -> Vec<i32> {
        let hit = !self.bufs_i32.is_empty();
        self.count(hit);
        let mut v = self.bufs_i32.pop().unwrap_or_default();
        v.clear();
        v.resize(len, 0);
        v
    }

    /// Return an i32 buffer to the pool.
    pub fn put_i32(&mut self, v: Vec<i32>) {
        self.bufs_i32.push(v);
    }

    /// Take an all-`false` `Vec<bool>` of `len` (SMAM masks, SMU coverage).
    pub fn take_bool(&mut self, len: usize) -> Vec<bool> {
        let hit = !self.bufs_bool.is_empty();
        self.count(hit);
        let mut v = self.bufs_bool.pop().unwrap_or_default();
        v.clear();
        v.resize(len, false);
        v
    }

    /// Return a bool buffer to the pool.
    pub fn put_bool(&mut self, v: Vec<bool>) {
        self.bufs_bool.push(v);
    }

    /// Take a zeroed `Vec<u32>` of `len` (SMAM accumulation counts).
    pub fn take_u32(&mut self, len: usize) -> Vec<u32> {
        let hit = !self.bufs_u32.is_empty();
        self.count(hit);
        let mut v = self.bufs_u32.pop().unwrap_or_default();
        v.clear();
        v.resize(len, 0);
        v
    }

    /// Return a u32 buffer to the pool.
    pub fn put_u32(&mut self, v: Vec<u32>) {
        self.bufs_u32.push(v);
    }

    /// Take a zeroed `Vec<u64>` of `len` (per-head comparator tallies).
    pub fn take_u64(&mut self, len: usize) -> Vec<u64> {
        let hit = !self.bufs_u64.is_empty();
        self.count(hit);
        let mut v = self.bufs_u64.pop().unwrap_or_default();
        v.clear();
        v.resize(len, 0);
        v
    }

    /// Return a u64 buffer to the pool.
    pub fn put_u64(&mut self, v: Vec<u64>) {
        self.bufs_u64.push(v);
    }

    /// Take an empty `Vec<usize>` with pooled capacity (SMU window lists).
    pub fn take_usize(&mut self) -> Vec<usize> {
        let hit = !self.bufs_usize.is_empty();
        self.count(hit);
        let mut v = self.bufs_usize.pop().unwrap_or_default();
        v.clear();
        v
    }

    /// Return a usize buffer to the pool.
    pub fn put_usize(&mut self, v: Vec<usize>) {
        self.bufs_usize.push(v);
    }

    /// Take an all-zero `[channels, tokens]` packed bitmap, reusing a
    /// pooled word arena when one is available (`PackedBitmap::reset`) —
    /// the bitmap engine's hand-off buffer, so steady-state engine
    /// switching allocates nothing.
    pub fn take_bitmap(&mut self, channels: usize, tokens: usize) -> PackedBitmap {
        match self.bitmaps.pop() {
            Some(mut b) => {
                self.count(true);
                b.reset(channels, tokens);
                b
            }
            None => {
                self.count(false);
                PackedBitmap::zeros(channels, tokens)
            }
        }
    }

    /// Return a packed bitmap to the pool (its word capacity is kept for
    /// the next [`Self::take_bitmap`]).
    pub fn put_bitmap(&mut self, b: PackedBitmap) {
        self.bitmaps.push(b);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::ACT_FRAC;

    #[test]
    fn take_tensor_matches_fresh_zeros() {
        let mut s = ExecScratch::new();
        let t = s.take_tensor(&[2, 3], ACT_FRAC);
        assert_eq!(t, QTensor::zeros(&[2, 3], ACT_FRAC));
        assert_eq!(s.stats(), ScratchStats { hits: 0, misses: 1 });
    }

    #[test]
    fn put_then_take_is_a_hit_and_state_is_fresh() {
        let mut s = ExecScratch::new();
        let mut t = s.take_tensor(&[4], 0);
        t.data[2] = 99; // dirty it
        s.put_tensor(t);
        let t2 = s.take_tensor(&[2, 2], 5);
        assert_eq!(t2, QTensor::zeros(&[2, 2], 5), "reused tensor must be zeroed");
        assert_eq!(s.stats(), ScratchStats { hits: 1, misses: 1 });
        assert!((s.stats().hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn take_tensor_copy_duplicates_source() {
        let mut s = ExecScratch::new();
        let src = QTensor { shape: vec![3], frac: 2, data: vec![1, -2, 3] };
        let t = s.take_tensor_copy(&src);
        assert_eq!(t, src);
    }

    #[test]
    fn enc_pool_reuses_arena_as_empty() {
        let mut s = ExecScratch::new();
        let mut e = s.take_enc(2, 16);
        e.push(0, 3);
        e.push(1, 7);
        s.put_enc(e);
        let e2 = s.take_enc(3, 8);
        assert_eq!(e2, EncodedSpikes::empty(3, 8), "reused arena must be empty");
        assert!(e2.is_well_formed());
        assert_eq!(s.stats().hits, 1);
    }

    #[test]
    fn plain_buffers_come_back_zeroed() {
        let mut s = ExecScratch::new();
        let mut b = s.take_bool(4);
        b[1] = true;
        s.put_bool(b);
        assert_eq!(s.take_bool(6), vec![false; 6]);
        let mut u = s.take_u32(2);
        u[0] = 7;
        s.put_u32(u);
        assert_eq!(s.take_u32(3), vec![0u32; 3]);
        let mut i = s.take_i32(2);
        i[0] = -1;
        s.put_i32(i);
        assert_eq!(s.take_i32(5), vec![0i32; 5]);
        let mut w = s.take_u64(2);
        w[1] = 9;
        s.put_u64(w);
        assert_eq!(s.take_u64(2), vec![0u64; 2]);
    }

    #[test]
    fn steady_state_stops_missing() {
        let mut s = ExecScratch::new();
        // Warm-up: one take per class.
        let t = s.take_tensor(&[8], 0);
        let e = s.take_enc(4, 16);
        s.put_tensor(t);
        s.put_enc(e);
        let warm = s.stats();
        for _ in 0..10 {
            let t = s.take_tensor(&[8], 0);
            let e = s.take_enc(4, 16);
            s.put_tensor(t);
            s.put_enc(e);
        }
        assert_eq!(s.stats().misses, warm.misses, "steady state must not allocate");
        assert_eq!(s.stats().hits, warm.hits + 20);
    }

    #[test]
    fn bitmap_pool_reuses_words_as_zeroed() {
        let mut s = ExecScratch::new();
        let mut b = s.take_bitmap(2, 70);
        b.set(1, 65); // dirty it
        s.put_bitmap(b);
        let b2 = s.take_bitmap(3, 64);
        assert_eq!(b2, PackedBitmap::zeros(3, 64), "reused bitmap must be zeroed");
        assert_eq!(s.stats(), ScratchStats { hits: 1, misses: 1 });
        s.put_bitmap(b2);
        assert_eq!(s.pooled_objects(), 1, "bitmaps count toward the leak canary");
    }

    #[test]
    fn merged_stats_sum() {
        let a = ScratchStats { hits: 3, misses: 1 };
        let b = ScratchStats { hits: 2, misses: 2 };
        assert_eq!(a.merged(b), ScratchStats { hits: 5, misses: 3 });
    }
}
