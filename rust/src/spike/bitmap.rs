//! Packed `u64` spike bitmap — the word-parallel second engine of the
//! dual-engine datapath (DESIGN.md "Dual-engine datapath & selection").
//!
//! [`PackedBitmap`] stores the binary spike matrix `[C, L]` channel-major
//! with 64 token positions per machine word, so the unit kernels that
//! consume it replace per-address scalar work with word AND / popcount /
//! trailing-zeros scans: a Q∩K intersection over one channel costs
//! `ceil(L/64)` word ops regardless of density, which beats the CSR
//! merge-join once `|Q|+|K|` per channel exceeds the word count — the
//! FireFly-T-style dense engine that the
//! [`EngineSelect`](crate::hw::EngineSelect) policy switches to at high
//! density.
//!
//! The bitmap is built from / decoded to [`EncodedSpikes`] at the
//! existing round-trip points, and both directions are exercised by the
//! differential harness (`tests/diff_engines.rs`): every kernel here is
//! bit-identical in values to its CSR twin; only the cycle/cost fields
//! of `UnitStats` may differ.

use crate::spike::{EncodedSpikes, SpikeMatrix};

/// Bits per storage word of the packed bitmap engine.
pub const WORD_BITS: usize = 64;

/// A binary spike matrix `[channels, tokens]` packed 64 tokens per `u64`,
/// channel-major: channel `c` occupies the word row
/// `words[c*words_per_row .. (c+1)*words_per_row]`, token `l` is bit
/// `l % 64` of word `l / 64`. Tail bits past `tokens` are always zero
/// (an invariant every mutator preserves, so popcounts never overcount).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PackedBitmap {
    channels: usize,
    tokens: usize,
    words_per_row: usize,
    words: Vec<u64>,
}

impl PackedBitmap {
    /// An all-zero bitmap of the given shape.
    pub fn zeros(channels: usize, tokens: usize) -> Self {
        let words_per_row = tokens.div_ceil(WORD_BITS);
        Self {
            channels,
            tokens,
            words_per_row,
            words: vec![0u64; channels * words_per_row],
        }
    }

    /// Reshape in place to an all-zero bitmap of the given shape, reusing
    /// the word storage (the [`ExecScratch`](crate::scratch::ExecScratch)
    /// recycling point — steady state allocates nothing once the vector
    /// has grown to the largest shape seen).
    pub fn reset(&mut self, channels: usize, tokens: usize) {
        self.channels = channels;
        self.tokens = tokens;
        self.words_per_row = tokens.div_ceil(WORD_BITS);
        self.words.clear();
        self.words.resize(channels * self.words_per_row, 0);
    }

    /// Channel count.
    pub fn channels(&self) -> usize {
        self.channels
    }

    /// Token count per channel.
    pub fn tokens(&self) -> usize {
        self.tokens
    }

    /// Words per channel row (`ceil(tokens/64)`), the word-parallel
    /// engine's per-channel work unit.
    pub fn words_per_row(&self) -> usize {
        self.words_per_row
    }

    /// Total backing words — the engine's SRAM footprint in 64-bit words.
    pub fn storage_words(&self) -> usize {
        self.words.len()
    }

    /// Set every bit listed in `src` (one of the two engine hand-off
    /// points; the other is [`Self::decode_into`]). The bitmap must
    /// already have `src`'s shape and is NOT cleared first — callers
    /// recycling through scratch reset it via [`Self::reset`].
    pub fn fill_from_encoded(&mut self, src: &EncodedSpikes) {
        assert_eq!(
            (self.channels, self.tokens),
            (src.channels, src.tokens),
            "bitmap/encoded shape mismatch"
        );
        for c in 0..src.channels {
            let row = c * self.words_per_row;
            for &addr in src.channel_addrs(c) {
                let a = addr as usize; // as-ok: narrow-int index widening
                self.words[row + a / WORD_BITS] |= 1u64 << (a % WORD_BITS);
            }
        }
    }

    /// A fresh bitmap holding `src`'s spikes (allocating convenience for
    /// tests/benches; the hot path pairs `reset` + `fill_from_encoded`
    /// on a scratch-pooled bitmap).
    pub fn from_encoded(src: &EncodedSpikes) -> Self {
        let mut b = Self::zeros(src.channels, src.tokens);
        b.fill_from_encoded(src);
        b
    }

    /// Decode back to the CSR arena (addresses emerge sorted because bits
    /// are scanned in word order, low bit first). `out` must be empty and
    /// already shaped `[channels, tokens]` — the `take_enc` contract.
    pub fn decode_into(&self, out: &mut EncodedSpikes) {
        assert_eq!(
            (self.channels, self.tokens),
            (out.channels, out.tokens),
            "bitmap/encoded shape mismatch"
        );
        for c in 0..self.channels {
            let row = &self.words[c * self.words_per_row..(c + 1) * self.words_per_row];
            for (wi, &w) in row.iter().enumerate() {
                let mut bits = w;
                while bits != 0 {
                    let l = wi * WORD_BITS + bits.trailing_zeros() as usize; // as-ok: u32 bit index widening
                    out.push(c, l);
                    bits &= bits - 1;
                }
            }
        }
    }

    /// Bit at `(channel, token)`.
    pub fn get(&self, c: usize, l: usize) -> bool {
        assert!(c < self.channels && l < self.tokens, "index out of range");
        (self.words[c * self.words_per_row + l / WORD_BITS] >> (l % WORD_BITS)) & 1 == 1
    }

    /// Set bit `(channel, token)` to 1.
    pub fn set(&mut self, c: usize, l: usize) {
        assert!(c < self.channels && l < self.tokens, "index out of range");
        self.words[c * self.words_per_row + l / WORD_BITS] |= 1u64 << (l % WORD_BITS);
    }

    /// The packed word row of one channel.
    pub fn row(&self, c: usize) -> &[u64] {
        assert!(c < self.channels, "channel out of range");
        &self.words[c * self.words_per_row..(c + 1) * self.words_per_row]
    }

    /// Total spike count (word-parallel popcount over the arena).
    pub fn count_ones(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum() // as-ok: u32 popcount widening
    }

    /// Spike density in `[0, 1]`; `0.0` for an empty shape (the engine
    /// selector's no-NaN guarantee — see `EncodedSpikes::density`).
    pub fn density(&self) -> f64 {
        let total = self.channels * self.tokens;
        if total == 0 {
            return 0.0;
        }
        self.count_ones() as f64 / total as f64 // as-ok: count → f64 for a ratio
    }

    /// Gather `len` bits of channel `c` starting at token `start` into the
    /// low bits of a `u64` (`len <= 64`; positions past `tokens` read as
    /// zero). One- or two-word fetch — the SMU's window probe: a pooling
    /// window row is nonzero iff any covered token fired.
    pub fn extract_bits(&self, c: usize, start: usize, len: usize) -> u64 {
        assert!(len <= WORD_BITS, "cannot extract more than one word");
        assert!(c < self.channels, "channel out of range");
        if len == 0 || start >= self.tokens {
            return 0;
        }
        let row = c * self.words_per_row;
        let (wi, bit) = (start / WORD_BITS, start % WORD_BITS);
        let mut v = self.words[row + wi] >> bit;
        if bit != 0 && wi + 1 < self.words_per_row {
            v |= self.words[row + wi + 1] << (WORD_BITS - bit);
        }
        if len < WORD_BITS {
            v &= (1u64 << len) - 1;
        }
        // Mask off positions past the end of the token space.
        let avail = self.tokens - start;
        if avail < len && avail < WORD_BITS {
            v &= (1u64 << avail) - 1;
        }
        v
    }

    /// XOR every word of `other` into `self` (shape-asserted). The
    /// temporal-delta apply/undo primitive: `prev ^= delta` reconstructs
    /// the current frame from the previous one, and XOR-ing twice restores
    /// it — both directions are exercised by the `spike::delta` round-trip
    /// tests. Tail bits stay zero because both operands keep theirs zero.
    pub fn xor_with(&mut self, other: &Self) {
        assert_eq!(
            (self.channels, self.tokens),
            (other.channels, other.tokens),
            "bitmap shape mismatch"
        );
        for (w, &o) in self.words.iter_mut().zip(&other.words) {
            *w ^= o;
        }
    }

    /// Popcount of the AND of two channel rows — the SMAM's word-parallel
    /// Q∩K intersection for one channel: `ceil(L/64)` word ops replace the
    /// CSR merge-join's `|Q|+|K|` comparator steps.
    pub fn and_popcount_row(&self, c: usize, other: &Self, oc: usize) -> u32 {
        let (a, b) = (self.row(c), other.row(oc));
        assert_eq!(a.len(), b.len(), "row width mismatch");
        a.iter().zip(b).map(|(x, y)| (x & y).count_ones()).sum()
    }

    /// Dense `SpikeMatrix` view (test/debug helper).
    pub fn to_matrix(&self) -> SpikeMatrix {
        let mut m = SpikeMatrix::zeros(self.channels, self.tokens);
        for c in 0..self.channels {
            for l in 0..self.tokens {
                if self.get(c, l) {
                    m.set(c, l, true);
                }
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    fn random_encoded(rng: &mut Prng, c: usize, l: usize, p: f64) -> EncodedSpikes {
        let mut m = SpikeMatrix::zeros(c, l);
        for ci in 0..c {
            for li in 0..l {
                if rng.bernoulli(p) {
                    m.set(ci, li, true);
                }
            }
        }
        EncodedSpikes::from_bitmap(&m)
    }

    #[test]
    fn round_trip_is_lossless() {
        let mut rng = Prng::new(7);
        for &(c, l, p) in &[(3usize, 10usize, 0.3), (5, 64, 0.5), (4, 130, 0.1), (2, 1, 1.0)] {
            let enc = random_encoded(&mut rng, c, l, p);
            let bm = PackedBitmap::from_encoded(&enc);
            assert_eq!(bm.count_ones(), enc.count_spikes());
            let mut back = EncodedSpikes::empty(c, l);
            bm.decode_into(&mut back);
            assert_eq!(back, enc, "decode(encode(x)) != x at ({c},{l},{p})");
            assert!(back.is_well_formed());
        }
    }

    #[test]
    fn get_set_and_word_layout() {
        let mut bm = PackedBitmap::zeros(2, 130);
        assert_eq!(bm.words_per_row(), 3);
        assert_eq!(bm.storage_words(), 6);
        bm.set(0, 0);
        bm.set(0, 63);
        bm.set(0, 64);
        bm.set(1, 129);
        assert_eq!(bm.row(0)[0], 1 | (1 << 63));
        assert_eq!(bm.row(0)[1], 1);
        assert_eq!(bm.row(1)[2], 1 << 1);
        assert!(bm.get(0, 63) && bm.get(0, 64) && bm.get(1, 129));
        assert!(!bm.get(1, 0));
        assert_eq!(bm.count_ones(), 4);
    }

    #[test]
    fn reset_reuses_storage_and_clears() {
        let mut bm = PackedBitmap::zeros(4, 64);
        bm.set(3, 63);
        bm.reset(2, 10);
        assert_eq!((bm.channels(), bm.tokens()), (2, 10));
        assert_eq!(bm.count_ones(), 0, "reset must clear old bits");
        bm.reset(4, 64);
        assert_eq!(bm.count_ones(), 0);
    }

    #[test]
    fn extract_bits_spans_word_boundaries() {
        let mut bm = PackedBitmap::zeros(1, 130);
        bm.set(0, 62);
        bm.set(0, 63);
        bm.set(0, 64);
        bm.set(0, 65);
        // Straddle the word 0 / word 1 boundary.
        assert_eq!(bm.extract_bits(0, 62, 4), 0b1111);
        assert_eq!(bm.extract_bits(0, 63, 2), 0b11);
        assert_eq!(bm.extract_bits(0, 0, 62), 0);
        // Aligned reads and zero-length reads.
        assert_eq!(bm.extract_bits(0, 64, 2), 0b11);
        assert_eq!(bm.extract_bits(0, 64, 0), 0);
        // Past-the-end positions read as zero.
        bm.set(0, 129);
        assert_eq!(bm.extract_bits(0, 128, 64), 0b10);
        assert_eq!(bm.extract_bits(0, 200, 8), 0);
    }

    #[test]
    fn and_popcount_matches_scalar_intersection() {
        let mut rng = Prng::new(9);
        let a = random_encoded(&mut rng, 4, 100, 0.4);
        let b = random_encoded(&mut rng, 4, 100, 0.4);
        let (ba, bb) = (PackedBitmap::from_encoded(&a), PackedBitmap::from_encoded(&b));
        for c in 0..4 {
            let mut scalar = 0u32;
            for l in 0..100 {
                if ba.get(c, l) && bb.get(c, l) {
                    scalar += 1;
                }
            }
            assert_eq!(ba.and_popcount_row(c, &bb, c), scalar, "channel {c}");
        }
    }

    #[test]
    fn density_is_defined_for_empty_shapes() {
        assert_eq!(PackedBitmap::zeros(0, 0).density(), 0.0);
        assert_eq!(PackedBitmap::zeros(3, 0).density(), 0.0);
        assert_eq!(PackedBitmap::zeros(0, 7).density(), 0.0);
        let mut bm = PackedBitmap::zeros(2, 4);
        bm.set(0, 0);
        bm.set(1, 3);
        assert!((bm.density() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn xor_with_is_an_involution() {
        let mut rng = Prng::new(11);
        let a = PackedBitmap::from_encoded(&random_encoded(&mut rng, 3, 70, 0.3));
        let b = PackedBitmap::from_encoded(&random_encoded(&mut rng, 3, 70, 0.3));
        let mut x = a.clone();
        x.xor_with(&b);
        // Tail bits stay zero, so the popcount is the symmetric difference.
        let mut diff = 0usize;
        for c in 0..3 {
            for l in 0..70 {
                if a.get(c, l) != b.get(c, l) {
                    diff += 1;
                }
            }
        }
        assert_eq!(x.count_ones(), diff);
        x.xor_with(&b);
        assert_eq!(x, a, "xor twice must restore the original");
    }

    #[test]
    fn tail_bits_stay_zero() {
        // tokens=10 leaves 54 tail bits in the single row word; a full
        // matrix must popcount to exactly channels*tokens.
        let mut m = SpikeMatrix::zeros(3, 10);
        for c in 0..3 {
            for l in 0..10 {
                m.set(c, l, true);
            }
        }
        let bm = PackedBitmap::from_encoded(&EncodedSpikes::from_bitmap(&m));
        assert_eq!(bm.count_ones(), 30);
        assert_eq!(bm.extract_bits(0, 5, 10), 0b11111);
    }
}
