//! Spike-stream KV cache for autoregressive decode (ISSUE 10).
//!
//! The decoder keeps, per SDEB block and per SNN timestep, the K and V
//! spike rows of every already-processed token position. Two dual
//! representations are held side by side, one per SMAM engine:
//!
//! * **Position-major CSR** ([`EncodedSpikes`]): the arena's *channels*
//!   are token positions (capacity `max_seq_len`) and the stored
//!   *addresses* are embedding-channel indices (`u16 < D`). Appending
//!   token `p` is a single [`EncodedSpikes::extend_channel`] call — the
//!   same packed ESS banks as the vision path, just transposed so the
//!   causal scan of the incremental SMAM walks channels `0..len` in
//!   order and the append never reshuffles existing rows.
//! * **Packed word rows** (`Vec<u64>`, `ceil(D/64)` words per position):
//!   the bitmap engine's resident copy, so dense decode steps can AND +
//!   popcount against per-head word masks instead of merging address
//!   lists. Values are bit-identical between the two views by
//!   construction (both are written from the same incoming row).
//!
//! Pooling: the arenas live for the whole decode session and are reset
//! with [`EncodedSpikes::clear_reuse`]; the word buffer is sized once at
//! construction. Steady-state decode therefore appends without any heap
//! allocation (`append_into` is covered by the `xtask lint`
//! alloc-in-into rule).

use crate::spike::EncodedSpikes;

/// Storage charged by one [`KvCacheStream::append_into`] call, so the
/// caller can bill the ESS write port for the cache growth.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct KvAppendStats {
    /// Spikes appended to the K stream.
    pub k_spikes: u64,
    /// Spikes appended to the V stream.
    pub v_spikes: u64,
    /// CSR storage words (addresses + segment headers) the append grew
    /// the two arenas by — the ESS-format footprint of the new row pair.
    pub words: u64,
}

/// One block × timestep lane of the cache: appended K and V spike rows
/// for positions `0..len()`, in both CSR and packed-word form.
#[derive(Clone, Debug)]
pub struct KvCacheStream {
    /// Position-major K rows: channel `p` holds the sorted embedding
    /// channels that spiked in K at position `p`.
    k: EncodedSpikes,
    /// Position-major V rows, same layout as `k`.
    v: EncodedSpikes,
    /// Packed K rows, `words_per_row` u64 words per position.
    k_words: Vec<u64>,
    /// Packed V rows, same layout as `k_words`.
    v_words: Vec<u64>,
    /// Staging row reused across appends (embedding channels of one row).
    row_buf: Vec<u16>,
    /// Embedding dimension `D` (the address space of each row).
    dim: usize,
    /// Maximum cached positions (the arena's channel capacity).
    max_seq_len: usize,
    /// Words per packed row: `ceil(dim / 64)`.
    words_per_row: usize,
    /// Cached positions so far.
    len: usize,
}

impl KvCacheStream {
    /// An empty stream able to hold up to `max_seq_len` positions of
    /// `dim`-channel spike rows. The packed-word buffer is fully sized
    /// here so appends never allocate.
    pub fn new(max_seq_len: usize, dim: usize) -> Self {
        assert!(max_seq_len > 0, "kv cache needs at least one position");
        let u16_space = usize::from(u16::MAX) + 1;
        assert!(dim > 0 && dim <= u16_space, "embedding dim must fit u16 addresses");
        let words_per_row = dim.div_ceil(64);
        Self {
            k: EncodedSpikes::empty(max_seq_len, dim),
            v: EncodedSpikes::empty(max_seq_len, dim),
            k_words: vec![0u64; max_seq_len * words_per_row],
            v_words: vec![0u64; max_seq_len * words_per_row],
            row_buf: Vec::with_capacity(dim),
            dim,
            max_seq_len,
            words_per_row,
            len: 0,
        }
    }

    /// Cached positions so far (grows by exactly one per decode step).
    pub fn len(&self) -> usize {
        self.len
    }

    /// True before the first append.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Embedding dimension of each cached row.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Position capacity of the stream.
    pub fn max_seq_len(&self) -> usize {
        self.max_seq_len
    }

    /// Packed u64 words per cached row.
    pub fn words_per_row(&self) -> usize {
        self.words_per_row
    }

    /// Total K spikes cached (O(1) — arena spike counter).
    pub fn k_spikes(&self) -> u64 {
        self.k.count_spikes() as u64 // as-ok: widening spike count for stats
    }

    /// Total V spikes cached (O(1)).
    pub fn v_spikes(&self) -> u64 {
        self.v.count_spikes() as u64 // as-ok: widening spike count for stats
    }

    /// Total CSR storage words (addresses + segment headers) of both
    /// streams — the ESS footprint of this lane.
    pub fn storage_words(&self) -> u64 {
        (self.k.storage_words() + self.v.storage_words()) as u64 // as-ok: widening word counts for stats
    }

    /// Sorted embedding channels of the K row at position `p`.
    pub fn k_row(&self, p: usize) -> &[u16] {
        assert!(p < self.len, "k_row({p}) past cache length {}", self.len);
        self.k.channel_addrs(p)
    }

    /// Sorted embedding channels of the V row at position `p`.
    pub fn v_row(&self, p: usize) -> &[u16] {
        assert!(p < self.len, "v_row({p}) past cache length {}", self.len);
        self.v.channel_addrs(p)
    }

    /// Packed K row at position `p` (`words_per_row` words).
    pub fn k_word_row(&self, p: usize) -> &[u64] {
        assert!(p < self.len, "k_word_row({p}) past cache length {}", self.len);
        &self.k_words[p * self.words_per_row..(p + 1) * self.words_per_row]
    }

    /// Packed V row at position `p` (`words_per_row` words).
    pub fn v_word_row(&self, p: usize) -> &[u64] {
        assert!(p < self.len, "v_word_row({p}) past cache length {}", self.len);
        &self.v_words[p * self.words_per_row..(p + 1) * self.words_per_row]
    }

    /// Append the new token's K and V spike rows (each a `[dim, 1]`
    /// channel-major encode from the SEA) as the next cached position.
    /// Returns the storage charged. Steady-state: no allocation — the
    /// staging row and word buffer are reused, the arenas grow in place.
    pub fn append_into(&mut self, k_new: &EncodedSpikes, v_new: &EncodedSpikes) -> KvAppendStats {
        assert!(self.len < self.max_seq_len, "kv cache overflow at {} positions", self.len);
        let before = self.storage_words();
        let p = self.len;
        let k_spikes = Self::append_row(&mut self.k, &mut self.k_words, &mut self.row_buf, k_new, p, self.words_per_row, self.dim);
        let v_spikes = Self::append_row(&mut self.v, &mut self.v_words, &mut self.row_buf, v_new, p, self.words_per_row, self.dim);
        self.len += 1;
        KvAppendStats { k_spikes, v_spikes, words: self.storage_words() - before }
    }

    /// Transpose one `[dim, 1]` encode into position row `p` of `enc` +
    /// its packed mirror. Returns the spike count of the row.
    fn append_row(
        enc: &mut EncodedSpikes,
        words: &mut [u64],
        row_buf: &mut Vec<u16>,
        new: &EncodedSpikes,
        p: usize,
        words_per_row: usize,
        dim: usize,
    ) -> u64 {
        assert_eq!(new.channels, dim, "row channel count");
        assert_eq!(new.tokens, 1, "decode appends single-token rows");
        row_buf.clear();
        let wrow = &mut words[p * words_per_row..(p + 1) * words_per_row];
        for c in 0..dim {
            if new.channel_len(c) > 0 {
                let addr = u16::try_from(c).expect("dim checked <= u16 space at construction");
                row_buf.push(addr);
                wrow[c / 64] |= 1u64 << (c % 64);
            }
        }
        enc.extend_channel(p, row_buf);
        row_buf.len() as u64 // as-ok: widening spike count for stats
    }

    /// Drop all cached positions but keep every arena and buffer
    /// capacity, so the next session appends allocation-free.
    pub fn reset(&mut self) {
        // Zero only the words the session actually touched.
        let used = self.len * self.words_per_row;
        for w in &mut self.k_words[..used] {
            *w = 0;
        }
        for w in &mut self.v_words[..used] {
            *w = 0;
        }
        self.k.clear_reuse();
        self.v.clear_reuse();
        self.len = 0;
    }
}

/// The full decode-session cache: one [`KvCacheStream`] per
/// `(block, timestep)` pair, plus the token counter the per-stream
/// lengths are checked against (`finish_token`).
#[derive(Clone, Debug)]
pub struct KvCache {
    streams: Vec<KvCacheStream>,
    blocks: usize,
    timesteps: usize,
    tokens: usize,
}

impl KvCache {
    /// Build an empty cache for `blocks × timesteps` lanes of up to
    /// `max_seq_len` positions at embedding dim `dim`.
    pub fn new(blocks: usize, timesteps: usize, max_seq_len: usize, dim: usize) -> Self {
        assert!(blocks > 0 && timesteps > 0, "cache needs at least one lane");
        let streams =
            (0..blocks * timesteps).map(|_| KvCacheStream::new(max_seq_len, dim)).collect();
        Self { streams, blocks, timesteps, tokens: 0 }
    }

    /// Number of SDEB blocks covered.
    pub fn blocks(&self) -> usize {
        self.blocks
    }

    /// Number of SNN timesteps covered.
    pub fn timesteps(&self) -> usize {
        self.timesteps
    }

    /// Tokens fully processed so far (every lane has exactly this many
    /// cached positions between `finish_token` calls).
    pub fn tokens(&self) -> usize {
        self.tokens
    }

    /// The lane of `(block, timestep)`.
    pub fn stream(&self, block: usize, t: usize) -> &KvCacheStream {
        assert!(block < self.blocks && t < self.timesteps, "lane ({block},{t}) out of range");
        &self.streams[block * self.timesteps + t]
    }

    /// Mutable lane of `(block, timestep)` — the decode step appends here.
    pub fn stream_mut(&mut self, block: usize, t: usize) -> &mut KvCacheStream {
        assert!(block < self.blocks && t < self.timesteps, "lane ({block},{t}) out of range");
        &mut self.streams[block * self.timesteps + t]
    }

    /// Close out one decoded token: every lane must have grown to
    /// exactly `tokens() + 1` positions (the cache-length ==
    /// tokens-emitted invariant), then the counter advances.
    pub fn finish_token(&mut self) -> anyhow::Result<()> {
        let want = self.tokens + 1;
        for (i, s) in self.streams.iter().enumerate() {
            anyhow::ensure!(
                s.len() == want,
                "kv lane {} holds {} positions after token {} (want {want})",
                i,
                s.len(),
                self.tokens
            );
        }
        self.tokens = want;
        Ok(())
    }

    /// Total CSR storage words across all lanes (session ESS footprint).
    pub fn storage_words(&self) -> u64 {
        self.streams.iter().map(|s| s.storage_words()).sum()
    }

    /// Reset every lane for a fresh session, keeping all capacity.
    pub fn reset(&mut self) {
        for s in &mut self.streams {
            s.reset();
        }
        self.tokens = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a `[dim, 1]` channel-major encode with spikes at `chans`.
    fn row(dim: usize, chans: &[usize]) -> EncodedSpikes {
        let mut e = EncodedSpikes::empty(dim, 1);
        for &c in chans {
            e.push(c, 0);
        }
        e
    }

    #[test]
    fn append_preserves_order_and_both_views_agree() {
        let mut s = KvCacheStream::new(8, 70);
        let st = s.append_into(&row(70, &[0, 3, 69]), &row(70, &[5]));
        assert_eq!(st.k_spikes, 3);
        assert_eq!(st.v_spikes, 1);
        assert!(st.words >= 4, "4 addresses plus headers, got {}", st.words);
        s.append_into(&row(70, &[64]), &row(70, &[]));
        assert_eq!(s.len(), 2);
        assert_eq!(s.k_row(0), &[0u16, 3, 69]);
        assert_eq!(s.k_row(1), &[64u16]);
        assert_eq!(s.v_row(0), &[5u16]);
        assert_eq!(s.v_row(1), &[] as &[u16]);
        // packed mirror carries the same bits (dim 70 -> 2 words per row)
        assert_eq!(s.words_per_row(), 2);
        assert_eq!(s.k_word_row(0)[0], (1u64 << 0) | (1 << 3));
        assert_eq!(s.k_word_row(0)[1], 1u64 << (69 - 64));
        assert_eq!(s.k_word_row(1)[1], 1u64 << 0);
        assert_eq!(s.v_word_row(0)[0], 1u64 << 5);
        assert_eq!(s.k_spikes(), 4);
        assert_eq!(s.v_spikes(), 1);
    }

    #[test]
    fn reset_reuses_arena_across_sessions() {
        let mut s = KvCacheStream::new(4, 32);
        for _ in 0..4 {
            s.append_into(&row(32, &[1, 2]), &row(32, &[7]));
        }
        assert_eq!(s.len(), 4);
        s.reset();
        assert!(s.is_empty());
        assert_eq!(s.k_spikes(), 0);
        assert_eq!(s.storage_words(), 0);
        // A second session sees a truly fresh stream, including the
        // packed rows the first session dirtied.
        s.append_into(&row(32, &[9]), &row(32, &[]));
        assert_eq!(s.k_row(0), &[9u16]);
        assert_eq!(s.k_word_row(0), &[1u64 << 9]);
        assert_eq!(s.v_word_row(0), &[0u64]);
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn overflow_past_max_seq_len_panics() {
        let mut s = KvCacheStream::new(1, 8);
        s.append_into(&row(8, &[0]), &row(8, &[0]));
        s.append_into(&row(8, &[1]), &row(8, &[1]));
    }

    #[test]
    fn cache_enforces_length_equals_tokens_invariant() {
        let mut c = KvCache::new(2, 2, 8, 16);
        // Token 0: append to every lane, then finish.
        for b in 0..2 {
            for t in 0..2 {
                c.stream_mut(b, t).append_into(&row(16, &[b + t]), &row(16, &[3]));
            }
        }
        c.finish_token().unwrap();
        assert_eq!(c.tokens(), 1);
        // Token 1: miss one lane -> finish_token reports the bad lane.
        c.stream_mut(0, 0).append_into(&row(16, &[5]), &row(16, &[]));
        let err = c.finish_token().unwrap_err().to_string();
        assert!(err.contains("positions after token 1"), "{err}");
        assert_eq!(c.tokens(), 1, "failed finish must not advance");
    }

    #[test]
    fn cache_reset_clears_every_lane() {
        let mut c = KvCache::new(1, 2, 4, 8);
        c.stream_mut(0, 0).append_into(&row(8, &[0]), &row(8, &[1]));
        c.stream_mut(0, 1).append_into(&row(8, &[2]), &row(8, &[3]));
        c.finish_token().unwrap();
        assert!(c.storage_words() > 0);
        c.reset();
        assert_eq!(c.tokens(), 0);
        assert_eq!(c.storage_words(), 0);
        assert!(c.stream(0, 0).is_empty() && c.stream(0, 1).is_empty());
    }
}
