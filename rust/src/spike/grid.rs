//! Token-grid geometry: the reshape `I[C,H,W] -> I'[C,L]` of §III-A and the
//! kernel-coverage arithmetic the SMU needs (§III-B).

/// A 2-D token grid flattened row-major into L = H*W addresses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TokenGrid {
    /// Grid height.
    pub height: usize,
    /// Grid width.
    pub width: usize,
}

impl TokenGrid {
    /// A `height x width` token grid.
    pub fn new(height: usize, width: usize) -> Self {
        Self { height, width }
    }

    #[inline]
    /// Total token count.
    pub fn tokens(&self) -> usize {
        self.height * self.width
    }

    #[inline]
    /// Flatten `(y, x)` to a token address.
    pub fn addr(&self, y: usize, x: usize) -> usize {
        debug_assert!(y < self.height && x < self.width);
        y * self.width + x
    }

    #[inline]
    /// Recover `(y, x)` from a token address.
    pub fn coords(&self, addr: usize) -> (usize, usize) {
        debug_assert!(addr < self.tokens());
        (addr / self.width, addr % self.width)
    }

    /// Output grid of a `kernel`x`kernel`, stride `stride`, VALID pool.
    pub fn pooled(&self, kernel: usize, stride: usize) -> TokenGrid {
        assert!(kernel <= self.height && kernel <= self.width);
        TokenGrid::new(
            (self.height - kernel) / stride + 1,
            (self.width - kernel) / stride + 1,
        )
    }

    /// All pool-output addresses whose kernel window covers input (y, x) —
    /// the "overlapping data is reused to determine the output of multiple
    /// kernels simultaneously" rule of Fig. 3.
    pub fn covering_outputs(
        &self,
        y: usize,
        x: usize,
        kernel: usize,
        stride: usize,
        out: &mut Vec<usize>,
    ) {
        out.clear();
        let og = self.pooled(kernel, stride);
        // Output rows oy with oy*stride <= y <= oy*stride + kernel - 1.
        let oy_lo = y.saturating_sub(kernel - 1).div_ceil(stride);
        let ox_lo = x.saturating_sub(kernel - 1).div_ceil(stride);
        let oy_hi = (y / stride).min(og.height - 1);
        let ox_hi = (x / stride).min(og.width - 1);
        for oy in oy_lo..=oy_hi {
            for ox in ox_lo..=ox_hi {
                out.push(og.addr(oy, ox));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn addr_coords_roundtrip() {
        let g = TokenGrid::new(4, 5);
        for a in 0..g.tokens() {
            let (y, x) = g.coords(a);
            assert_eq!(g.addr(y, x), a);
        }
    }

    #[test]
    fn pooled_dims() {
        let g = TokenGrid::new(8, 8);
        assert_eq!(g.pooled(2, 2), TokenGrid::new(4, 4));
        assert_eq!(g.pooled(2, 1), TokenGrid::new(7, 7));
        assert_eq!(g.pooled(3, 1), TokenGrid::new(6, 6));
    }

    #[test]
    fn covering_outputs_2x2_stride1_interior() {
        // Fig. 3's example: an interior spike is covered by up to 4 kernels
        // for 2x2/stride-1.
        let g = TokenGrid::new(4, 4);
        let mut out = Vec::new();
        g.covering_outputs(1, 1, 2, 1, &mut out);
        let og = g.pooled(2, 1);
        assert_eq!(
            out,
            vec![og.addr(0, 0), og.addr(0, 1), og.addr(1, 0), og.addr(1, 1)]
        );
    }

    #[test]
    fn covering_outputs_corner() {
        let g = TokenGrid::new(4, 4);
        let mut out = Vec::new();
        g.covering_outputs(0, 0, 2, 1, &mut out);
        assert_eq!(out, vec![0]);
        g.covering_outputs(3, 3, 2, 1, &mut out);
        let og = g.pooled(2, 1);
        assert_eq!(out, vec![og.addr(2, 2)]);
    }

    #[test]
    fn covering_outputs_stride2_partition() {
        // stride == kernel: every input belongs to exactly one window.
        let g = TokenGrid::new(8, 8);
        let mut out = Vec::new();
        for y in 0..8 {
            for x in 0..8 {
                g.covering_outputs(y, x, 2, 2, &mut out);
                assert_eq!(out.len(), 1, "({y},{x}) -> {out:?}");
                assert_eq!(out[0], g.pooled(2, 2).addr(y / 2, x / 2));
            }
        }
    }

    #[test]
    fn covering_matches_bruteforce() {
        let g = TokenGrid::new(6, 7);
        let (kernel, stride) = (3, 2);
        let og = g.pooled(kernel, stride);
        let mut out = Vec::new();
        for y in 0..g.height {
            for x in 0..g.width {
                g.covering_outputs(y, x, kernel, stride, &mut out);
                let mut brute = Vec::new();
                for oy in 0..og.height {
                    for ox in 0..og.width {
                        let (y0, x0) = (oy * stride, ox * stride);
                        if y >= y0 && y < y0 + kernel && x >= x0 && x < x0 + kernel {
                            brute.push(og.addr(oy, ox));
                        }
                    }
                }
                assert_eq!(out, brute, "mismatch at ({y},{x})");
            }
        }
    }
}
