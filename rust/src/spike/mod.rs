//! Spike tensors and the paper's position-encoding scheme (§III-A).
//!
//! Two representations of a binary spike matrix `[C, L]` (C channels,
//! L = H*W flattened tokens):
//! * [`SpikeMatrix`] — the conventional bitmap a baseline accelerator
//!   would stream;
//! * [`EncodedSpikes`] — the paper's format: per channel, the *sorted token
//!   addresses* of the spikes. Stored as one flat CSR-style arena (a single
//!   contiguous address stream plus a channel offset table), matching the
//!   ESS's packed banks of 8-bit addresses; token spaces larger than 256
//!   are split into segments with one header word each (DESIGN.md), which
//!   the storage model accounts for.

pub mod bitmap;
pub mod delta;
pub mod encoding;
pub mod grid;
pub mod kvcache;

pub use bitmap::PackedBitmap;
pub use delta::{csr_delta_into, xor_delta_into, DeltaPlan};
pub use encoding::{EncodedSpikes, EncodedSpikesBuilder, SpikeMatrix};
pub use grid::TokenGrid;
pub use kvcache::{KvAppendStats, KvCache, KvCacheStream};
