//! Spike tensors and the paper's position-encoding scheme (§III-A).
//!
//! Two representations of a binary spike matrix `[C, L]` (C channels,
//! L = H*W flattened tokens):
//! * [`SpikeMatrix`] — the conventional bitmap a baseline accelerator
//!   would stream;
//! * [`EncodedSpikes`] — the paper's format: per channel, the *sorted token
//!   addresses* of the spikes, stored bank-per-channel in the ESS. Encoded
//!   addresses are 8-bit; token spaces larger than 256 are split into
//!   segments (DESIGN.md), which the storage model accounts for.

pub mod encoding;
pub mod grid;

pub use encoding::{EncodedSpikes, SpikeMatrix};
pub use grid::TokenGrid;
