//! Bitmap and position-encoded spike matrices + round-trip conversion.
//!
//! [`EncodedSpikes`] stores the position-encoded stream as a flat CSR-style
//! arena: one contiguous address vector for all channels plus a channel
//! offset table, mirroring how the ESS banks hold one packed stream of
//! 8-bit addresses + segment headers rather than per-channel heap objects
//! (DESIGN.md "ESS layout"). Consumers borrow per-channel slices via
//! [`EncodedSpikes::channel_addrs`]; producers append in channel-major
//! order via [`EncodedSpikes::push`] / [`EncodedSpikesBuilder`].

use std::fmt;
use std::ops::Range;

use crate::quant::SEGMENT_TOKENS;

/// Conventional binary spike matrix, channel-major `[C, L]`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SpikeMatrix {
    /// Channel count (C).
    pub channels: usize,
    /// Token count (L).
    pub tokens: usize,
    data: Vec<bool>,
}

impl SpikeMatrix {
    /// All-zero matrix.
    pub fn zeros(channels: usize, tokens: usize) -> Self {
        Self { channels, tokens, data: vec![false; channels * tokens] }
    }

    /// Build from a row-major `[C, L]` 0/1 integer slice.
    pub fn from_binary(values: &[i32], channels: usize, tokens: usize) -> Self {
        assert_eq!(values.len(), channels * tokens);
        Self {
            channels,
            tokens,
            data: values.iter().map(|&v| v != 0).collect(),
        }
    }

    #[inline]
    /// Read one position.
    pub fn get(&self, c: usize, l: usize) -> bool {
        self.data[c * self.tokens + l]
    }

    #[inline]
    /// Set one position.
    pub fn set(&mut self, c: usize, l: usize, v: bool) {
        self.data[c * self.tokens + l] = v;
    }

    /// Number of set positions.
    pub fn count_spikes(&self) -> usize {
        self.data.iter().filter(|&&b| b).count()
    }

    /// Fraction of zeros — the sparsity the paper's Fig. 6 reports.
    pub fn sparsity(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        1.0 - self.count_spikes() as f64 / self.data.len() as f64 // as-ok: reporting ratio, not datapath state
    }

    /// One channel's bitmap row.
    pub fn channel(&self, c: usize) -> &[bool] {
        &self.data[c * self.tokens..(c + 1) * self.tokens]
    }
}

/// Position-encoded spikes (§III-A): per channel, the sorted token
/// addresses of the spikes, stored as one flat CSR arena.
///
/// Layout invariants:
/// * `addrs` holds every channel's addresses back to back, channel-major;
/// * channel `c` occupies `channel_range(c)`, strictly increasing within;
/// * `seg_headers[c]` is the number of distinct 256-token segments channel
///   `c` touches (one stored header word each, see [`Self::storage_words`]).
///
/// The offset table is finalized lazily: entries for channels at or before
/// the build cursor are exact, later entries are implicitly `addrs.len()`
/// (all-empty tail). Every accessor goes through [`Self::offset`], so the
/// laziness is invisible to consumers.
///
/// ```
/// use spikeformer_accel::spike::EncodedSpikes;
///
/// // A [2, 8] spike tile built channel-major, addresses increasing.
/// let mut e = EncodedSpikes::empty(2, 8);
/// e.push(0, 3);
/// e.push(0, 5);
/// e.push(1, 0);
/// assert_eq!(e.channel_addrs(0), &[3, 5]);
/// assert_eq!(e.channel_addrs(1), &[0]);
/// assert_eq!(e.count_spikes(), 3);
/// // ESS storage: one word per spike plus one header word per distinct
/// // 256-token segment each channel touches (here: one per channel).
/// assert_eq!(e.storage_words(), 3 + 2);
/// assert!((e.sparsity() - 13.0 / 16.0).abs() < 1e-12);
/// ```
#[derive(Clone)]
pub struct EncodedSpikes {
    /// Channel count (C).
    pub channels: usize,
    /// Token space size (L).
    pub tokens: usize,
    /// Flat token-address stream, all channels back to back.
    addrs: Vec<u16>,
    /// Channel start offsets (`channels + 1` entries); entries after `cur`
    /// are stale and resolved by `offset()`.
    offsets: Vec<u32>,
    /// Per-channel segment-header word counts (precomputed on push so
    /// `storage_words()` is O(channels)).
    seg_headers: Vec<u32>,
    /// Highest channel appended so far (build cursor).
    cur: usize,
}

impl EncodedSpikes {
    /// An encoded tensor with no spikes.
    pub fn empty(channels: usize, tokens: usize) -> Self {
        assert!(tokens <= u16::MAX as usize + 1, "token space exceeds u16"); // as-ok: narrow-int index widening
        Self {
            channels,
            tokens,
            addrs: Vec::new(),
            offsets: vec![0; channels + 1],
            seg_headers: vec![0; channels],
            cur: 0,
        }
    }

    /// Start a builder over a `[channels, tokens]` tile.
    pub fn builder(channels: usize, tokens: usize) -> EncodedSpikesBuilder {
        EncodedSpikesBuilder { enc: Self::empty(channels, tokens) }
    }

    /// Resolve an offset-table entry, treating entries past the build
    /// cursor as the current end of the arena (empty trailing channels).
    #[inline]
    fn offset(&self, i: usize) -> usize {
        if i > self.cur {
            self.addrs.len()
        } else {
            self.offsets[i] as usize // as-ok: narrow-int index widening
        }
    }

    /// Finalize offsets up to channel `c` and move the cursor there.
    #[inline]
    fn advance_to(&mut self, c: usize) {
        if c > self.cur {
            let end =
                u32::try_from(self.addrs.len()).expect("CSR arena exceeds the u32 offset space");
            for o in &mut self.offsets[self.cur + 1..=c] {
                *o = end;
            }
            self.cur = c;
        }
    }

    /// Encode a bitmap — the software mirror of the SEA (Fig. 2), which in
    /// hardware happens as a side effect of the LIF fire decision.
    pub fn from_bitmap(m: &SpikeMatrix) -> Self {
        let mut enc = Self::empty(m.channels, m.tokens);
        for c in 0..m.channels {
            for (l, &fired) in m.channel(c).iter().enumerate() {
                if fired {
                    enc.push(c, l);
                }
            }
        }
        enc
    }

    /// Decode back to a bitmap (used by tests and the baseline datapath).
    pub fn to_bitmap(&self) -> SpikeMatrix {
        let mut m = SpikeMatrix::zeros(self.channels, self.tokens);
        for c in 0..self.channels {
            for &l in self.channel_addrs(c) {
                m.set(c, l as usize, true); // as-ok: narrow-int index widening
            }
        }
        m
    }

    /// Arena index range of channel `c`.
    ///
    /// Real (not debug) bounds check: `offset()` would silently resolve an
    /// out-of-range channel to an empty slice, hiding shape mismatches the
    /// old per-channel `Vec` indexing made loud.
    #[inline]
    pub fn channel_range(&self, c: usize) -> Range<usize> {
        assert!(c < self.channels, "channel {c} out of range ({} channels)", self.channels);
        self.offset(c)..self.offset(c + 1)
    }

    /// Borrowed, strictly increasing token addresses of channel `c`.
    #[inline]
    pub fn channel_addrs(&self, c: usize) -> &[u16] {
        &self.addrs[self.channel_range(c)]
    }

    /// Spike count of channel `c` (O(1)).
    #[inline]
    pub fn channel_len(&self, c: usize) -> usize {
        self.channel_range(c).len()
    }

    /// The whole flat address arena (all channels back to back).
    #[inline]
    pub fn addrs(&self) -> &[u16] {
        &self.addrs
    }

    /// Iterate per-channel address slices in channel order.
    pub fn iter_channels(&self) -> impl Iterator<Item = &[u16]> + '_ {
        (0..self.channels).map(move |c| self.channel_addrs(c))
    }

    #[inline]
    /// Total spikes (O(1): the arena length).
    pub fn count_spikes(&self) -> usize {
        self.addrs.len()
    }

    /// Fraction of ones — the density statistic the adaptive engine
    /// selector ([`EngineSelect`](crate::hw::EngineSelect)) compares
    /// against its crossover threshold. Defined (0.0) for empty shapes,
    /// so the selector can never NaN-select; an empty tensor always takes
    /// the CSR engine.
    pub fn density(&self) -> f64 {
        let total = self.channels * self.tokens;
        if total == 0 {
            return 0.0;
        }
        self.count_spikes() as f64 / total as f64 // as-ok: reporting ratio, not datapath state
    }

    /// Fraction of zeros — the Fig. 6 measurement.
    pub fn sparsity(&self) -> f64 {
        let total = self.channels * self.tokens;
        if total == 0 {
            return 0.0;
        }
        1.0 - self.count_spikes() as f64 / total as f64 // as-ok: reporting ratio, not datapath state
    }

    /// Push a spike. Spikes must arrive channel-major and in increasing
    /// token order within a channel (the SEA scans addresses sequentially,
    /// §III-A: "stored sequentially according to address order") — exactly
    /// the order every producer in the datapath already emits.
    pub fn push(&mut self, c: usize, l: usize) {
        assert!(c < self.channels, "channel {c} out of range");
        assert!(c >= self.cur, "channel-major push order violated: {c} < {}", self.cur);
        debug_assert!(l < self.tokens, "address {l} out of token range {}", self.tokens);
        self.advance_to(c);
        let start = self.offsets[c] as usize; // as-ok: narrow-int index widening
        let seg = l / SEGMENT_TOKENS;
        if self.addrs.len() == start {
            self.seg_headers[c] += 1; // first spike of the channel
        } else {
            let last = *self.addrs.last().unwrap() as usize; // as-ok: narrow-int index widening
            debug_assert!(last < l, "out-of-order push: {last} >= {l}");
            if last / SEGMENT_TOKENS != seg {
                self.seg_headers[c] += 1; // channel enters a new segment
            }
        }
        // `empty`/`reset` assert tokens <= u16::MAX + 1 and `l < tokens` is
        // the push contract, so this only fires on an invariant violation.
        let addr = u16::try_from(l).expect("spike address exceeds the u16 token space");
        self.addrs.push(addr);
    }

    /// Bulk-append a strictly increasing address slice to channel `c`
    /// (same ordering contract as [`Self::push`]).
    pub fn extend_channel(&mut self, c: usize, new: &[u16]) {
        assert!(c < self.channels, "channel {c} out of range");
        assert!(c >= self.cur, "channel-major extend order violated");
        self.advance_to(c);
        let start = self.offsets[c] as usize; // as-ok: narrow-int index widening
        let mut prev: Option<u16> = self.addrs.get(start..).and_then(|s| s.last().copied());
        let mut prev_seg = prev.map_or(usize::MAX, |p| p as usize / SEGMENT_TOKENS); // as-ok: narrow-int index widening
        for &a in new {
            debug_assert!((a as usize) < self.tokens, "address {a} out of range"); // as-ok: narrow-int index widening
            debug_assert!(prev.map_or(true, |p| p < a), "out-of-order extend");
            let seg = a as usize / SEGMENT_TOKENS; // as-ok: narrow-int index widening
            if seg != prev_seg {
                self.seg_headers[c] += 1;
                prev_seg = seg;
            }
            prev = Some(a);
        }
        self.addrs.extend_from_slice(new);
    }

    /// Copy channel `src_c` of `src` into (empty) channel `c` of `self` as
    /// one offset-range copy out of the source arena — the SMAM mask gate's
    /// retain path (Fig. 4(c)) without per-channel clones or re-scans: the
    /// precomputed segment-header count travels with the slice.
    pub fn extend_channel_from(&mut self, c: usize, src: &EncodedSpikes, src_c: usize) {
        assert!(c < self.channels, "channel {c} out of range");
        assert!(c >= self.cur, "channel-major extend order violated");
        assert_eq!(self.tokens, src.tokens, "token-space mismatch");
        self.advance_to(c);
        assert_eq!(
            self.offsets[c] as usize, // as-ok: narrow-int index widening
            self.addrs.len(),
            "extend_channel_from target channel must be empty"
        );
        let range = src.channel_range(src_c);
        self.addrs.extend_from_slice(&src.addrs[range]);
        self.seg_headers[c] += src.seg_headers[src_c];
    }

    /// Drop every spike in place, keeping the `[channels, tokens]` geometry
    /// AND every allocation (arena, offset table, header counts) — a
    /// drained arena keeps its capacity for the next producer. The scratch
    /// pool's same-geometry reuse primitive ([`Self::reset`] layers the
    /// reshape on top); equivalent to `*self = Self::empty(..)` minus the
    /// heap round-trip.
    pub fn clear_reuse(&mut self) {
        self.addrs.clear();
        self.offsets.fill(0);
        self.seg_headers.fill(0);
        self.cur = 0;
    }

    /// Reset to an empty `[channels, tokens]` tensor, reusing the existing
    /// allocations (the tables only reallocate if `channels` grows past
    /// their capacity). Bit-identical to [`Self::empty`] afterwards; this
    /// is what `ExecScratch::take_enc` calls on a pooled arena.
    pub fn reset(&mut self, channels: usize, tokens: usize) {
        assert!(tokens <= u16::MAX as usize + 1, "token space exceeds u16"); // as-ok: narrow-int index widening
        self.channels = channels;
        self.tokens = tokens;
        self.offsets.resize(channels + 1, 0);
        self.seg_headers.resize(channels, 0);
        self.clear_reuse();
    }

    /// Number of 8-bit words the ESS stores for this tensor, including one
    /// segment-header word per non-empty 256-token segment of each channel
    /// (how 8-bit addresses cover token spaces > 256; DESIGN.md). O(channels):
    /// header counts are maintained incrementally on push.
    pub fn storage_words(&self) -> usize {
        self.addrs.len() + self.seg_headers.iter().map(|&h| h as usize).sum::<usize>() // as-ok: narrow-int index widening
    }

    /// ESS storage words of channel `c` alone (addresses + that channel's
    /// segment headers) — the per-channel cost the temporal delta plan
    /// ([`DeltaPlan`](crate::spike::DeltaPlan)) compares a changed-address
    /// stream against. O(1): both terms are maintained incrementally.
    pub fn channel_storage_words(&self, c: usize) -> usize {
        self.channel_len(c) + self.seg_headers[c] as usize // as-ok: narrow-int index widening
    }

    /// Validity check used by property tests: offsets contiguous and
    /// monotone, addresses strictly sorted and in range per channel, and
    /// segment-header counts consistent with the addresses.
    pub fn is_well_formed(&self) -> bool {
        if self.offsets.len() != self.channels + 1 || self.seg_headers.len() != self.channels {
            return false;
        }
        if self.offset(0) != 0 {
            return false;
        }
        let mut prev_end = 0usize;
        for c in 0..self.channels {
            let (s, e) = (self.offset(c), self.offset(c + 1));
            if s != prev_end || e < s || e > self.addrs.len() {
                return false;
            }
            prev_end = e;
            let list = &self.addrs[s..e];
            if !list.windows(2).all(|w| w[0] < w[1]) {
                return false;
            }
            if !list.iter().all(|&l| (l as usize) < self.tokens) { // as-ok: narrow-int index widening
                return false;
            }
            let mut segs = 0u32;
            let mut prev_seg = usize::MAX;
            for &l in list {
                let seg = l as usize / SEGMENT_TOKENS; // as-ok: narrow-int index widening
                if seg != prev_seg {
                    segs += 1;
                    prev_seg = seg;
                }
            }
            if segs != self.seg_headers[c] {
                return false;
            }
        }
        prev_end == self.addrs.len()
    }
}

impl PartialEq for EncodedSpikes {
    fn eq(&self, other: &Self) -> bool {
        // Stale offset entries differ between construction histories, so
        // compare the resolved channel boundaries, not the raw tables.
        self.channels == other.channels
            && self.tokens == other.tokens
            && self.addrs == other.addrs
            && (0..=self.channels).all(|i| self.offset(i) == other.offset(i))
    }
}

impl Eq for EncodedSpikes {}

impl fmt::Debug for EncodedSpikes {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("EncodedSpikes")
            .field("channels", &self.channels)
            .field("tokens", &self.tokens)
            .field("channel_addrs", &self.iter_channels().collect::<Vec<_>>())
            .finish()
    }
}

/// Incremental builder over the CSR arena; same ordering contract as
/// [`EncodedSpikes::push`], separated out so call sites that construct a
/// tensor in one pass read as build-then-freeze.
#[derive(Clone, Debug)]
pub struct EncodedSpikesBuilder {
    enc: EncodedSpikes,
}

impl EncodedSpikesBuilder {
    /// Append one spike (channel-major, increasing address order).
    pub fn push(&mut self, c: usize, l: usize) -> &mut Self {
        self.enc.push(c, l);
        self
    }

    /// Bulk-append one channel's sorted addresses.
    pub fn extend_channel(&mut self, c: usize, addrs: &[u16]) -> &mut Self {
        self.enc.extend_channel(c, addrs);
        self
    }

    /// Finalize into the built tensor.
    pub fn finish(self) -> EncodedSpikes {
        self.enc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    fn random_bitmap(rng: &mut Prng, c: usize, l: usize, p: f64) -> SpikeMatrix {
        let mut m = SpikeMatrix::zeros(c, l);
        for ci in 0..c {
            for li in 0..l {
                if rng.bernoulli(p) {
                    m.set(ci, li, true);
                }
            }
        }
        m
    }

    #[test]
    fn roundtrip_bitmap_encoded() {
        let mut rng = Prng::new(1);
        for &p in &[0.0, 0.1, 0.5, 1.0] {
            let m = random_bitmap(&mut rng, 7, 33, p);
            let enc = EncodedSpikes::from_bitmap(&m);
            assert!(enc.is_well_formed());
            assert_eq!(enc.to_bitmap(), m);
            assert_eq!(enc.count_spikes(), m.count_spikes());
        }
    }

    #[test]
    fn sparsity_matches() {
        let mut m = SpikeMatrix::zeros(2, 4);
        m.set(0, 1, true);
        m.set(1, 3, true);
        assert!((m.sparsity() - 0.75).abs() < 1e-12);
        assert!((EncodedSpikes::from_bitmap(&m).sparsity() - 0.75).abs() < 1e-12);
        assert!((EncodedSpikes::from_bitmap(&m).density() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn density_and_sparsity_are_defined_for_empty_shapes() {
        // The adaptive engine selector divides by channels*tokens; every
        // empty shape must yield a finite value (0.0 => CSR engine), never
        // NaN. Covers the zero-channel, zero-token, and zero-both corners.
        for &(c, l) in &[(0usize, 0usize), (0, 8), (8, 0)] {
            let enc = EncodedSpikes::empty(c, l);
            assert_eq!(enc.density(), 0.0, "density must be 0.0 at ({c},{l})");
            assert_eq!(enc.sparsity(), 0.0, "sparsity must be 0.0 at ({c},{l})");
            assert!(enc.density().is_finite() && enc.sparsity().is_finite());
        }
        let m = SpikeMatrix::zeros(0, 0);
        assert_eq!(m.sparsity(), 0.0);
    }

    #[test]
    fn storage_words_single_segment() {
        // 64 tokens => one segment per non-empty channel.
        let mut m = SpikeMatrix::zeros(2, 64);
        m.set(0, 0, true);
        m.set(0, 5, true);
        let enc = EncodedSpikes::from_bitmap(&m);
        assert_eq!(enc.storage_words(), 2 + 1); // 2 addresses + 1 header
    }

    #[test]
    fn storage_words_multi_segment() {
        // 1024 tokens: spikes in segments 0 and 3 of one channel.
        let mut m = SpikeMatrix::zeros(1, 1024);
        m.set(0, 10, true);
        m.set(0, 800, true);
        let enc = EncodedSpikes::from_bitmap(&m);
        assert_eq!(enc.storage_words(), 2 + 2);
    }

    #[test]
    fn push_in_order() {
        let mut enc = EncodedSpikes::empty(1, 16);
        enc.push(0, 2);
        enc.push(0, 9);
        assert!(enc.is_well_formed());
        assert_eq!(enc.channel_addrs(0), &[2u16, 9][..]);
    }

    #[test]
    fn arena_is_flat_and_channel_slices_borrow_it() {
        let mut enc = EncodedSpikes::empty(4, 32);
        enc.push(0, 1);
        enc.push(0, 7);
        enc.push(2, 3); // channel 1 stays empty
        assert_eq!(enc.addrs(), &[1u16, 7, 3][..]);
        assert_eq!(enc.channel_range(0), 0..2);
        assert_eq!(enc.channel_addrs(1), &[][..]);
        assert_eq!(enc.channel_range(2), 2..3);
        assert_eq!(enc.channel_addrs(3), &[][..]);
        assert_eq!(enc.channel_len(2), 1);
        assert!(enc.is_well_formed());
    }

    #[test]
    fn builder_equals_from_bitmap() {
        let mut rng = Prng::new(3);
        let m = random_bitmap(&mut rng, 5, 40, 0.3);
        let mut b = EncodedSpikes::builder(5, 40);
        for c in 0..5 {
            for l in 0..40 {
                if m.get(c, l) {
                    b.push(c, l);
                }
            }
        }
        assert_eq!(b.finish(), EncodedSpikes::from_bitmap(&m));
    }

    #[test]
    fn extend_channel_from_copies_slice_and_headers() {
        let mut src = EncodedSpikes::empty(2, 1024);
        src.push(1, 5);
        src.push(1, 700); // two segments
        let mut dst = EncodedSpikes::empty(2, 1024);
        dst.extend_channel_from(1, &src, 1);
        assert_eq!(dst.channel_addrs(1), src.channel_addrs(1));
        assert_eq!(dst.storage_words(), src.storage_words());
        assert!(dst.is_well_formed());
    }

    #[test]
    fn extend_channel_appends_in_order() {
        let mut enc = EncodedSpikes::empty(3, 64);
        enc.extend_channel(0, &[1, 4]);
        enc.extend_channel(0, &[9]);
        enc.extend_channel(2, &[0, 63]);
        assert_eq!(enc.channel_addrs(0), &[1u16, 4, 9][..]);
        assert_eq!(enc.channel_addrs(2), &[0u16, 63][..]);
        assert!(enc.is_well_formed());
    }

    #[test]
    #[should_panic(expected = "channel-major")]
    fn earlier_channel_push_panics() {
        let mut enc = EncodedSpikes::empty(4, 16);
        enc.push(2, 0);
        enc.push(1, 0);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "out-of-order")]
    fn out_of_order_address_push_panics() {
        let mut enc = EncodedSpikes::empty(1, 16);
        enc.push(0, 5);
        enc.push(0, 3);
    }

    #[test]
    fn clear_reuse_restores_empty_state() {
        let mut rng = Prng::new(5);
        let m = random_bitmap(&mut rng, 4, 40, 0.4);
        let mut enc = EncodedSpikes::from_bitmap(&m);
        enc.clear_reuse();
        assert_eq!(enc, EncodedSpikes::empty(4, 40));
        assert!(enc.is_well_formed());
        assert_eq!(enc.storage_words(), 0);
        // A cleared arena accepts a fresh build identical to from-scratch.
        enc.push(1, 3);
        enc.push(1, 9);
        assert_eq!(enc.channel_addrs(1), &[3u16, 9][..]);
        assert!(enc.is_well_formed());
    }

    #[test]
    fn reset_reshapes_and_empties() {
        let mut rng = Prng::new(6);
        let m = random_bitmap(&mut rng, 8, 300, 0.3);
        let mut enc = EncodedSpikes::from_bitmap(&m);
        enc.reset(3, 64);
        assert_eq!(enc, EncodedSpikes::empty(3, 64));
        assert!(enc.is_well_formed());
        // Growing the channel count also works (tables resize).
        enc.reset(16, 128);
        assert_eq!(enc, EncodedSpikes::empty(16, 128));
        enc.push(15, 100);
        assert!(enc.is_well_formed());
        assert_eq!(enc.storage_words(), 2); // 1 address + 1 segment header
    }

    #[test]
    fn from_binary_values() {
        let m = SpikeMatrix::from_binary(&[1, 0, 0, 1], 2, 2);
        assert!(m.get(0, 0) && m.get(1, 1));
        assert!(!m.get(0, 1) && !m.get(1, 0));
    }
}
