//! Bitmap and position-encoded spike matrices + round-trip conversion.

use crate::quant::SEGMENT_TOKENS;

/// Conventional binary spike matrix, channel-major `[C, L]`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SpikeMatrix {
    pub channels: usize,
    pub tokens: usize,
    data: Vec<bool>,
}

impl SpikeMatrix {
    pub fn zeros(channels: usize, tokens: usize) -> Self {
        Self { channels, tokens, data: vec![false; channels * tokens] }
    }

    /// Build from a row-major `[C, L]` 0/1 integer slice.
    pub fn from_binary(values: &[i32], channels: usize, tokens: usize) -> Self {
        assert_eq!(values.len(), channels * tokens);
        Self {
            channels,
            tokens,
            data: values.iter().map(|&v| v != 0).collect(),
        }
    }

    #[inline]
    pub fn get(&self, c: usize, l: usize) -> bool {
        self.data[c * self.tokens + l]
    }

    #[inline]
    pub fn set(&mut self, c: usize, l: usize, v: bool) {
        self.data[c * self.tokens + l] = v;
    }

    pub fn count_spikes(&self) -> usize {
        self.data.iter().filter(|&&b| b).count()
    }

    /// Fraction of zeros — the sparsity the paper's Fig. 6 reports.
    pub fn sparsity(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        1.0 - self.count_spikes() as f64 / self.data.len() as f64
    }

    pub fn channel(&self, c: usize) -> &[bool] {
        &self.data[c * self.tokens..(c + 1) * self.tokens]
    }
}

/// Position-encoded spikes: per channel, sorted token addresses (§III-A).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct EncodedSpikes {
    pub channels: usize,
    pub tokens: usize,
    /// `lists[c]` = strictly increasing token addresses of channel c.
    pub lists: Vec<Vec<u16>>,
}

impl EncodedSpikes {
    pub fn empty(channels: usize, tokens: usize) -> Self {
        assert!(tokens <= u16::MAX as usize + 1, "token space exceeds u16");
        Self { channels, tokens, lists: vec![Vec::new(); channels] }
    }

    /// Encode a bitmap — the software mirror of the SEA (Fig. 2), which in
    /// hardware happens as a side effect of the LIF fire decision.
    pub fn from_bitmap(m: &SpikeMatrix) -> Self {
        let mut enc = Self::empty(m.channels, m.tokens);
        for c in 0..m.channels {
            let ch = m.channel(c);
            let list = &mut enc.lists[c];
            for (l, &fired) in ch.iter().enumerate() {
                if fired {
                    list.push(l as u16);
                }
            }
        }
        enc
    }

    /// Decode back to a bitmap (used by tests and the baseline datapath).
    pub fn to_bitmap(&self) -> SpikeMatrix {
        let mut m = SpikeMatrix::zeros(self.channels, self.tokens);
        for (c, list) in self.lists.iter().enumerate() {
            for &l in list {
                m.set(c, l as usize, true);
            }
        }
        m
    }

    pub fn count_spikes(&self) -> usize {
        self.lists.iter().map(Vec::len).sum()
    }

    pub fn sparsity(&self) -> f64 {
        let total = self.channels * self.tokens;
        if total == 0 {
            return 0.0;
        }
        1.0 - self.count_spikes() as f64 / total as f64
    }

    /// Push a spike; addresses must arrive in increasing token order (the
    /// SEA scans addresses sequentially, §III-A: "stored sequentially
    /// according to address order").
    pub fn push(&mut self, c: usize, l: usize) {
        debug_assert!(l < self.tokens);
        let list = &mut self.lists[c];
        debug_assert!(list.last().map_or(true, |&last| (last as usize) < l), "out-of-order push");
        list.push(l as u16);
    }

    /// Number of 8-bit words the ESS stores for this tensor, including one
    /// segment-header word per non-empty 256-token segment of each channel
    /// (how 8-bit addresses cover token spaces > 256; DESIGN.md).
    pub fn storage_words(&self) -> usize {
        let mut words = 0;
        for list in &self.lists {
            words += list.len();
            let mut seg_prev = usize::MAX;
            for &l in list {
                let seg = l as usize / SEGMENT_TOKENS;
                if seg != seg_prev {
                    words += 1; // segment header
                    seg_prev = seg;
                }
            }
        }
        words
    }

    /// Validity check used by property tests: strictly sorted, in range.
    pub fn is_well_formed(&self) -> bool {
        self.lists.len() == self.channels
            && self.lists.iter().all(|list| {
                list.windows(2).all(|w| w[0] < w[1])
                    && list.iter().all(|&l| (l as usize) < self.tokens)
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    fn random_bitmap(rng: &mut Prng, c: usize, l: usize, p: f64) -> SpikeMatrix {
        let mut m = SpikeMatrix::zeros(c, l);
        for ci in 0..c {
            for li in 0..l {
                if rng.bernoulli(p) {
                    m.set(ci, li, true);
                }
            }
        }
        m
    }

    #[test]
    fn roundtrip_bitmap_encoded() {
        let mut rng = Prng::new(1);
        for &p in &[0.0, 0.1, 0.5, 1.0] {
            let m = random_bitmap(&mut rng, 7, 33, p);
            let enc = EncodedSpikes::from_bitmap(&m);
            assert!(enc.is_well_formed());
            assert_eq!(enc.to_bitmap(), m);
            assert_eq!(enc.count_spikes(), m.count_spikes());
        }
    }

    #[test]
    fn sparsity_matches() {
        let mut m = SpikeMatrix::zeros(2, 4);
        m.set(0, 1, true);
        m.set(1, 3, true);
        assert!((m.sparsity() - 0.75).abs() < 1e-12);
        assert!((EncodedSpikes::from_bitmap(&m).sparsity() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn storage_words_single_segment() {
        // 64 tokens => one segment per non-empty channel.
        let mut m = SpikeMatrix::zeros(2, 64);
        m.set(0, 0, true);
        m.set(0, 5, true);
        let enc = EncodedSpikes::from_bitmap(&m);
        assert_eq!(enc.storage_words(), 2 + 1); // 2 addresses + 1 header
    }

    #[test]
    fn storage_words_multi_segment() {
        // 1024 tokens: spikes in segments 0 and 3 of one channel.
        let mut m = SpikeMatrix::zeros(1, 1024);
        m.set(0, 10, true);
        m.set(0, 800, true);
        let enc = EncodedSpikes::from_bitmap(&m);
        assert_eq!(enc.storage_words(), 2 + 2);
    }

    #[test]
    fn push_in_order() {
        let mut enc = EncodedSpikes::empty(1, 16);
        enc.push(0, 2);
        enc.push(0, 9);
        assert!(enc.is_well_formed());
        assert_eq!(enc.lists[0], vec![2, 9]);
    }

    #[test]
    fn from_binary_values() {
        let m = SpikeMatrix::from_binary(&[1, 0, 0, 1], 2, 2);
        assert!(m.get(0, 0) && m.get(1, 1));
        assert!(!m.get(0, 1) && !m.get(1, 0));
    }
}
