//! Temporal delta encoding between consecutive spike frames (DESIGN.md
//! "Temporal reuse & delta streaming").
//!
//! A spike-driven transformer runs T highly-correlated timesteps of the
//! same image, so the frame a SDEB core loads at timestep `t` usually
//! differs from the one it loaded at `t-1` in only a few addresses. This
//! module provides the two delta kernels of the `--temporal-delta` path:
//!
//! * [`xor_delta_into`] — the word-parallel kernel on [`PackedBitmap`]:
//!   XOR the two frames word by word and extract the changed bits with
//!   the PR 7 trailing-zeros word-scan;
//! * [`csr_delta_into`] — the address-streaming twin on
//!   [`EncodedSpikes`]: a two-pointer symmetric-difference merge over the
//!   sorted per-channel address slices.
//!
//! Both emit the same encoded delta (enforced by the tests below), and
//! applying a delta is a plain [`PackedBitmap::xor_with`]:
//! `prev ⊕ delta = curr`, and XOR-ing again restores `prev`.
//!
//! [`DeltaPlan`] is the per-channel decision — ship the delta only when
//! its ESS word cost (changed addresses + headers of the segments they
//! touch) undercuts a full re-store of the channel — and
//! [`moved_words`] is the per-tensor measurement the SDEB core charges
//! its input load with. Counting kernels are allocation-free; the
//! `*_into` emitters follow the `take_enc` contract (empty, pre-shaped
//! output arena) like every other hot-path producer.

use crate::quant::SEGMENT_TOKENS;
use crate::spike::bitmap::WORD_BITS;
use crate::spike::{EncodedSpikes, PackedBitmap};

/// Packed words covered by one 256-token address segment. The word-scan
/// segment accounting below relies on `WORD_BITS` dividing
/// `SEGMENT_TOKENS` so no word straddles a segment boundary (asserted in
/// the tests).
const WORDS_PER_SEGMENT: usize = SEGMENT_TOKENS / WORD_BITS;

/// Per-(channel) transfer decision of the temporal-reuse path — the
/// delta analogue of the PR 7 `EnginePlan`: given the measured cost of
/// shipping only the changed addresses versus re-storing the channel in
/// full, pick whichever moves fewer ESS words. Chosen independently per
/// channel because temporal correlation is channel-local: a channel
/// whose firing pattern repeats verbatim costs zero words under
/// [`DeltaPlan::Delta`] even while a neighbouring channel churns.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DeltaPlan {
    /// Re-store the channel's full address stream (the PR 5 behaviour;
    /// also the forced choice for the first frame, which has no
    /// predecessor to diff against).
    Full,
    /// Ship only the XOR delta against the previous frame.
    Delta,
}

impl DeltaPlan {
    /// Pick the cheaper transfer for one channel. Ties go to `Full`: at
    /// equal cost the straight re-store needs no reconstruction step.
    pub fn choose(delta_words: usize, full_words: usize) -> Self {
        if delta_words < full_words {
            DeltaPlan::Delta
        } else {
            DeltaPlan::Full
        }
    }

    /// ESS words the chosen plan moves for this channel.
    pub fn moved_words(delta_words: usize, full_words: usize) -> usize {
        delta_words.min(full_words)
    }

    /// Short display name (bench tables, reports).
    pub fn name(&self) -> &'static str {
        match self {
            DeltaPlan::Full => "full",
            DeltaPlan::Delta => "delta",
        }
    }
}

/// ESS words channel `c`'s XOR delta would move: one word per changed
/// address plus one header word per distinct 256-token segment a change
/// touches — the same storage rule [`EncodedSpikes::storage_words`]
/// charges a full stream with. Counting only; nothing is materialized.
pub fn channel_delta_words(prev: &PackedBitmap, curr: &PackedBitmap, c: usize) -> usize {
    let (a, b) = (prev.row(c), curr.row(c));
    assert_eq!(a.len(), b.len(), "frame shape mismatch");
    let mut addrs = 0usize;
    let mut segs = 0usize;
    let mut prev_seg = usize::MAX;
    for (wi, (&x, &y)) in a.iter().zip(b).enumerate() {
        let d = x ^ y;
        if d == 0 {
            continue;
        }
        addrs += d.count_ones() as usize; // as-ok: u32 popcount widening
        let seg = wi / WORDS_PER_SEGMENT;
        if seg != prev_seg {
            segs += 1;
            prev_seg = seg;
        }
    }
    addrs + segs
}

/// ESS words the whole-tensor input load moves under the per-channel
/// [`DeltaPlan`]: for every channel, the cheaper of its XOR delta against
/// the previous frame and a full re-store (`full` is the current frame's
/// encoded form, whose per-channel cost is
/// [`EncodedSpikes::channel_storage_words`]). This is the quantity the
/// SDEB core charges the ESS store with when `--temporal-delta` is on;
/// it never exceeds `full.storage_words()`.
pub fn moved_words(prev: &PackedBitmap, curr: &PackedBitmap, full: &EncodedSpikes) -> usize {
    assert_eq!(
        (curr.channels(), curr.tokens()),
        (full.channels, full.tokens),
        "bitmap/encoded shape mismatch"
    );
    let mut total = 0usize;
    for c in 0..full.channels {
        let delta = channel_delta_words(prev, curr, c);
        total += DeltaPlan::moved_words(delta, full.channel_storage_words(c));
    }
    total
}

/// Materialize the XOR delta of two frames into `out` (changed addresses,
/// channel-major, sorted — the word-scan emits low bit first). `out` must
/// be empty and shaped like the frames (the `take_enc` contract). The
/// result satisfies `prev ⊕ out = curr` under
/// [`PackedBitmap::xor_with`].
pub fn xor_delta_into(prev: &PackedBitmap, curr: &PackedBitmap, out: &mut EncodedSpikes) {
    assert_eq!(
        (prev.channels(), prev.tokens()),
        (curr.channels(), curr.tokens()),
        "frame shape mismatch"
    );
    assert_eq!(
        (curr.channels(), curr.tokens()),
        (out.channels, out.tokens),
        "bitmap/encoded shape mismatch"
    );
    for c in 0..curr.channels() {
        let (a, b) = (prev.row(c), curr.row(c));
        for (wi, (&x, &y)) in a.iter().zip(b).enumerate() {
            let mut bits = x ^ y;
            while bits != 0 {
                let l = wi * WORD_BITS + bits.trailing_zeros() as usize; // as-ok: u32 bit index widening
                out.push(c, l);
                bits &= bits - 1;
            }
        }
    }
}

/// The CSR twin of [`xor_delta_into`]: per channel, a two-pointer
/// symmetric-difference merge over the two sorted address slices —
/// addresses present in exactly one frame are the changed ones. Same
/// output contract; bit-identical to the word-parallel kernel (the
/// engine-duality property the tests enforce).
pub fn csr_delta_into(prev: &EncodedSpikes, curr: &EncodedSpikes, out: &mut EncodedSpikes) {
    assert_eq!(
        (prev.channels, prev.tokens),
        (curr.channels, curr.tokens),
        "frame shape mismatch"
    );
    assert_eq!(
        (curr.channels, curr.tokens),
        (out.channels, out.tokens),
        "frame shape mismatch"
    );
    for c in 0..curr.channels {
        let (a, b) = (prev.channel_addrs(c), curr.channel_addrs(c));
        let (mut i, mut j) = (0usize, 0usize);
        while i < a.len() && j < b.len() {
            match a[i].cmp(&b[j]) {
                std::cmp::Ordering::Less => {
                    out.push(c, a[i] as usize); // as-ok: narrow-int index widening
                    i += 1;
                }
                std::cmp::Ordering::Greater => {
                    out.push(c, b[j] as usize); // as-ok: narrow-int index widening
                    j += 1;
                }
                std::cmp::Ordering::Equal => {
                    i += 1;
                    j += 1;
                }
            }
        }
        for &l in &a[i..] {
            out.push(c, l as usize); // as-ok: narrow-int index widening
        }
        for &l in &b[j..] {
            out.push(c, l as usize); // as-ok: narrow-int index widening
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spike::SpikeMatrix;
    use crate::util::Prng;

    fn random_encoded(rng: &mut Prng, c: usize, l: usize, p: f64) -> EncodedSpikes {
        let mut m = SpikeMatrix::zeros(c, l);
        for ci in 0..c {
            for li in 0..l {
                if rng.bernoulli(p) {
                    m.set(ci, li, true);
                }
            }
        }
        EncodedSpikes::from_bitmap(&m)
    }

    /// Flip each position of `base` with probability `flip` — the
    /// temporally-correlated next frame.
    fn correlated_next(rng: &mut Prng, base: &EncodedSpikes, flip: f64) -> EncodedSpikes {
        let mut m = base.to_bitmap();
        for c in 0..m.channels {
            for l in 0..m.tokens {
                if rng.bernoulli(flip) {
                    let v = m.get(c, l);
                    m.set(c, l, !v);
                }
            }
        }
        EncodedSpikes::from_bitmap(&m)
    }

    #[test]
    fn word_bits_divide_the_segment() {
        // channel_delta_words maps word index -> segment by integer
        // division; a word must never straddle two segments.
        assert_eq!(SEGMENT_TOKENS % WORD_BITS, 0);
        assert!(WORDS_PER_SEGMENT >= 1);
    }

    #[test]
    fn identical_frames_have_zero_delta() {
        let mut rng = Prng::new(21);
        let e = random_encoded(&mut rng, 6, 300, 0.3);
        let bm = PackedBitmap::from_encoded(&e);
        for c in 0..6 {
            assert_eq!(channel_delta_words(&bm, &bm, c), 0);
        }
        assert_eq!(moved_words(&bm, &bm, &e), 0);
        let mut out = EncodedSpikes::empty(6, 300);
        xor_delta_into(&bm, &bm, &mut out);
        assert_eq!(out.count_spikes(), 0);
        let mut out2 = EncodedSpikes::empty(6, 300);
        csr_delta_into(&e, &e, &mut out2);
        assert_eq!(out2.count_spikes(), 0);
    }

    #[test]
    fn xor_and_csr_kernels_agree() {
        let mut rng = Prng::new(22);
        for &(c, l, p, flip) in
            &[(4usize, 64usize, 0.2, 0.05), (3, 300, 0.5, 0.3), (2, 1024, 0.05, 1.0)]
        {
            let prev = random_encoded(&mut rng, c, l, p);
            let curr = correlated_next(&mut rng, &prev, flip);
            let (pb, cb) = (PackedBitmap::from_encoded(&prev), PackedBitmap::from_encoded(&curr));
            let mut via_xor = EncodedSpikes::empty(c, l);
            xor_delta_into(&pb, &cb, &mut via_xor);
            let mut via_csr = EncodedSpikes::empty(c, l);
            csr_delta_into(&prev, &curr, &mut via_csr);
            assert_eq!(via_xor, via_csr, "kernel mismatch at ({c},{l},{p},{flip})");
            assert!(via_xor.is_well_formed());
        }
    }

    #[test]
    fn counting_kernel_matches_materialized_delta() {
        let mut rng = Prng::new(23);
        let prev = random_encoded(&mut rng, 5, 700, 0.2);
        let curr = correlated_next(&mut rng, &prev, 0.1);
        let (pb, cb) = (PackedBitmap::from_encoded(&prev), PackedBitmap::from_encoded(&curr));
        let mut delta = EncodedSpikes::empty(5, 700);
        xor_delta_into(&pb, &cb, &mut delta);
        for c in 0..5 {
            assert_eq!(
                channel_delta_words(&pb, &cb, c),
                delta.channel_storage_words(c),
                "channel {c}: count-only kernel must price exactly the \
                 words the materialized delta stores"
            );
        }
    }

    #[test]
    fn delta_applies_and_round_trips() {
        let mut rng = Prng::new(24);
        let prev = random_encoded(&mut rng, 4, 200, 0.3);
        let curr = correlated_next(&mut rng, &prev, 0.15);
        let (pb, cb) = (PackedBitmap::from_encoded(&prev), PackedBitmap::from_encoded(&curr));
        let mut delta = EncodedSpikes::empty(4, 200);
        xor_delta_into(&pb, &cb, &mut delta);
        let delta_bm = PackedBitmap::from_encoded(&delta);
        let mut frame = pb.clone();
        frame.xor_with(&delta_bm);
        assert_eq!(frame, cb, "prev ^ delta must reconstruct curr");
        frame.xor_with(&delta_bm);
        assert_eq!(frame, pb, "applying the delta twice must restore prev");
    }

    #[test]
    fn plan_picks_the_cheaper_transfer() {
        assert_eq!(DeltaPlan::choose(3, 10), DeltaPlan::Delta);
        assert_eq!(DeltaPlan::choose(10, 3), DeltaPlan::Full);
        assert_eq!(DeltaPlan::choose(4, 4), DeltaPlan::Full, "ties re-store");
        assert_eq!(DeltaPlan::moved_words(3, 10), 3);
        assert_eq!(DeltaPlan::moved_words(10, 3), 3);
        assert_eq!(DeltaPlan::Delta.name(), "delta");
        assert_eq!(DeltaPlan::Full.name(), "full");
    }

    #[test]
    fn moved_words_never_exceeds_a_full_restore() {
        let mut rng = Prng::new(25);
        for &flip in &[0.0, 0.05, 0.5, 1.0] {
            let prev = random_encoded(&mut rng, 6, 400, 0.4);
            let curr = correlated_next(&mut rng, &prev, flip);
            let (pb, cb) =
                (PackedBitmap::from_encoded(&prev), PackedBitmap::from_encoded(&curr));
            let moved = moved_words(&pb, &cb, &curr);
            assert!(
                moved <= curr.storage_words(),
                "moved {moved} > full {} at flip {flip}",
                curr.storage_words()
            );
        }
    }

    #[test]
    fn uncorrelated_frames_fall_back_to_full_per_channel() {
        // An all-ones -> all-zeros step: the delta (every address) is
        // strictly worse than re-storing the (empty) current frame, so the
        // per-channel min must take the full side.
        let mut m = SpikeMatrix::zeros(2, 64);
        for l in 0..64 {
            m.set(0, l, true);
        }
        let prev = EncodedSpikes::from_bitmap(&m);
        let curr = EncodedSpikes::empty(2, 64);
        let (pb, cb) = (PackedBitmap::from_encoded(&prev), PackedBitmap::from_encoded(&curr));
        assert_eq!(channel_delta_words(&pb, &cb, 0), 64 + 1);
        assert_eq!(moved_words(&pb, &cb, &curr), 0, "empty full stream wins");
    }
}
