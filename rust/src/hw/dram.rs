//! Shared external-memory bus model (the DRAM side of Fig. 1's
//! Input/Output Buffers).
//!
//! Every off-chip transfer — the input image load, the weight-streaming
//! DMA traffic planned by [`DmaEngine`](crate::accel::DmaEngine), the
//! output drain — shares one bus of `bytes_per_cycle` bandwidth. The bus
//! serves requests in issue order (FIFO arbitration): a transfer asked
//! for at release time `r` starts at `max(bus_free, r)`, occupies the bus
//! for [`DramBus::transfer_cycles`] cycles, and advances the busy
//! interval. [`BusTimeline`] records the per-client byte/cycle/stall
//! accounting that ends up in the run's [`MemoryReport`].
//!
//! The executed pipeline integrates this model *analytically inside the
//! schedule recurrence*
//! ([`PipelineExecution`](crate::accel::PipelineExecution)): a stage's
//! start/finish is gated on its weights being resident, so the whole
//! memory system stays bit-deterministic — same model, same config, same
//! schedule — exactly like the compute lanes.

use crate::util::div_ceil;

/// The shared external-memory bus: a bandwidth plus the transfer-time
/// rule every client sees.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DramBus {
    /// Bus bandwidth in bytes per cycle. `usize::MAX` is the idealized
    /// unlimited-bandwidth bus (transfers complete instantaneously),
    /// used by the memory-invariance tests to recover the pre-memory
    /// schedule bit-exactly.
    pub bytes_per_cycle: usize,
}

impl DramBus {
    /// A bus of `bytes_per_cycle` bandwidth.
    pub fn new(bytes_per_cycle: usize) -> Self {
        Self { bytes_per_cycle }
    }

    /// Cycles a transfer of `bytes` occupies the bus. Zero-byte transfers
    /// are free, and the `usize::MAX` idealization completes any transfer
    /// in zero cycles (so an unlimited bus can never stall a consumer —
    /// see the module docs).
    pub fn transfer_cycles(&self, bytes: u64) -> u64 {
        if bytes == 0 || self.bytes_per_cycle == usize::MAX {
            0
        } else {
            div_ceil(bytes, self.bytes_per_cycle as u64)
        }
    }
}

/// One bus client's accumulated traffic and stall accounting.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ClientStats {
    /// Client name (`input`, `weights.block0`, `output`, ...).
    pub name: String,
    /// Bytes moved over the bus for this client.
    pub bytes: u64,
    /// Bus-busy cycles spent on this client's transfers.
    pub busy_cycles: u64,
    /// Consumer cycles lost waiting on this client's transfers (compute
    /// ready but weights not yet resident).
    pub stall_cycles: u64,
}

/// FIFO busy-interval accounting for one run over a [`DramBus`].
///
/// Requests are served strictly in issue order; each returns its
/// `(start, done)` interval so the schedule recurrence can gate the
/// consuming stage on `done`.
#[derive(Clone, Debug)]
pub struct BusTimeline {
    bus: DramBus,
    free_at: u64,
    clients: Vec<ClientStats>,
}

impl BusTimeline {
    /// An idle timeline over `bus`.
    pub fn new(bus: DramBus) -> Self {
        Self { bus, free_at: 0, clients: Vec::new() }
    }

    fn client_mut(&mut self, name: &str) -> &mut ClientStats {
        if let Some(i) = self.clients.iter().position(|c| c.name == name) {
            &mut self.clients[i]
        } else {
            self.clients.push(ClientStats { name: name.to_string(), ..Default::default() });
            self.clients.last_mut().unwrap()
        }
    }

    /// Issue a transfer of `bytes` for `client`, not starting before
    /// `release` (e.g. the cycle its destination buffer slot frees).
    /// Returns the `(start, done)` busy interval under FIFO arbitration.
    pub fn request(&mut self, client: &str, bytes: u64, release: u64) -> (u64, u64) {
        let start = self.free_at.max(release);
        let cycles = self.bus.transfer_cycles(bytes);
        let done = start + cycles;
        self.free_at = done;
        let c = self.client_mut(client);
        c.bytes += bytes;
        c.busy_cycles += cycles;
        (start, done)
    }

    /// [`Self::request`] with the busy time given explicitly instead of
    /// derived from `bytes` — the head/tail prefetch split of one weight
    /// stream uses this so the two pieces cost exactly
    /// `transfer_cycles(total)` cycles overall (per-piece ceil division
    /// would overcharge a cycle whenever the split point is unaligned).
    pub fn request_with_cycles(
        &mut self,
        client: &str,
        bytes: u64,
        cycles: u64,
        release: u64,
    ) -> (u64, u64) {
        let start = self.free_at.max(release);
        let done = start + cycles;
        self.free_at = done;
        let c = self.client_mut(client);
        c.bytes += bytes;
        c.busy_cycles += cycles;
        (start, done)
    }

    /// Record a transfer whose timing was charged elsewhere (the input
    /// load keeps its historical `io.input` cycle accounting) while still
    /// occupying the bus until `done_at` for arbitration purposes. The
    /// busy time booked is the interval the transfer adds on top of the
    /// current bus occupancy, so seeding an idle timeline books exactly
    /// `done_at` cycles.
    pub fn seed(&mut self, client: &str, bytes: u64, done_at: u64) {
        let added = done_at.saturating_sub(self.free_at);
        self.free_at = self.free_at.max(done_at);
        let c = self.client_mut(client);
        c.bytes += bytes;
        c.busy_cycles += added;
    }

    /// Record traffic whose timing is fully accounted elsewhere and which
    /// nothing queues behind (the output drain after the last consumer):
    /// books bytes and busy cycles without advancing the FIFO cursor.
    pub fn book(&mut self, client: &str, bytes: u64, busy_cycles: u64) {
        let c = self.client_mut(client);
        c.bytes += bytes;
        c.busy_cycles += busy_cycles;
    }

    /// Attribute `cycles` of consumer stall to `client`.
    pub fn add_stall(&mut self, client: &str, cycles: u64) {
        self.client_mut(client).stall_cycles += cycles;
    }

    /// The cycle at which the bus next goes idle.
    pub fn free_at(&self) -> u64 {
        self.free_at
    }

    /// Finish the run: fold the accounting into a [`MemoryReport`]. The
    /// regime-classification and spike-traffic fields start at their
    /// defaults; the executor/controller populate them afterwards.
    pub fn into_report(self) -> MemoryReport {
        MemoryReport {
            bytes_per_cycle: self.bus.bytes_per_cycle,
            clients: self.clients,
            ..Default::default()
        }
    }
}

/// Per-run external-memory accounting: what moved over the shared bus,
/// for whom, and how many cycles the executed schedule lost waiting on
/// it. Carried on
/// [`PipelineExecution`](crate::accel::PipelineExecution) and surfaced
/// through [`RunReport`](crate::accel::RunReport).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct MemoryReport {
    /// Bus bandwidth the run was scheduled against.
    pub bytes_per_cycle: usize,
    /// Per-client traffic/stall rows, in first-transfer order.
    pub clients: Vec<ClientStats>,
    /// Blocks whose weight sets stream once and stay resident (DMA regime
    /// classification — see [`DmaEngine`](crate::accel::DmaEngine)).
    pub resident_blocks: usize,
    /// Blocks whose fitting sets stream once but are later evicted by the
    /// slot rotation (the Thrash regime under weight-resident timestep
    /// scheduling).
    pub thrash_blocks: usize,
    /// Blocks whose oversized sets re-stream on every use.
    pub streaming_blocks: usize,
    /// Weight bytes that stream once per inference and then sit on chip
    /// for all their uses (Resident + Thrash working sets).
    pub resident_bytes: u64,
    /// ESS words (as bytes) the SDEB input loads would move with every
    /// frame re-stored in full — the delta-off baseline, recorded on
    /// every run.
    pub spike_bytes_full: u64,
    /// ESS words (as bytes) the SDEB input loads actually moved under the
    /// per-channel [`DeltaPlan`](crate::spike::DeltaPlan). Equals
    /// [`Self::spike_bytes_full`] when `--temporal-delta` is off.
    pub spike_bytes_moved: u64,
}

impl MemoryReport {
    /// Total bytes moved across all clients.
    pub fn total_bytes(&self) -> u64 {
        self.clients.iter().map(|c| c.bytes).sum()
    }

    /// Total bus-busy cycles across all clients.
    pub fn busy_cycles(&self) -> u64 {
        self.clients.iter().map(|c| c.busy_cycles).sum()
    }

    /// Total consumer stall cycles (compute ready, weights not resident).
    pub fn stall_cycles(&self) -> u64 {
        self.clients.iter().map(|c| c.stall_cycles).sum()
    }

    /// Bytes streamed by the weight DMA clients (`weights.*`).
    pub fn weight_bytes(&self) -> u64 {
        self.clients
            .iter()
            .filter(|c| c.name.starts_with("weights."))
            .map(|c| c.bytes)
            .sum()
    }

    /// Total bytes the temporal-reuse metric tracks per inference: the
    /// weight DMA traffic plus the (possibly delta-compressed) SDEB input
    /// spike traffic — the quantity the PR 8 acceptance test compares
    /// against the PR 5 baseline.
    pub fn streamed_bytes(&self) -> u64 {
        self.weight_bytes() + self.spike_bytes_moved
    }

    /// Stall cycles as a fraction of `wall_cycles` (0 when idle).
    pub fn stall_fraction(&self, wall_cycles: u64) -> f64 {
        if wall_cycles == 0 {
            0.0
        } else {
            self.stall_cycles() as f64 / wall_cycles as f64
        }
    }

    /// Bus utilization over `wall_cycles` (busy / wall, 0 when idle).
    pub fn bus_utilization(&self, wall_cycles: u64) -> f64 {
        if wall_cycles == 0 {
            0.0
        } else {
            self.busy_cycles() as f64 / wall_cycles as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_cycles_round_up() {
        let bus = DramBus::new(16);
        assert_eq!(bus.transfer_cycles(0), 0);
        assert_eq!(bus.transfer_cycles(1), 1);
        assert_eq!(bus.transfer_cycles(16), 1);
        assert_eq!(bus.transfer_cycles(17), 2);
        assert_eq!(bus.transfer_cycles(6144), 384);
    }

    #[test]
    fn unlimited_bus_is_instantaneous() {
        let bus = DramBus::new(usize::MAX);
        assert_eq!(bus.transfer_cycles(u64::MAX / 2), 0);
        assert_eq!(bus.transfer_cycles(1), 0);
    }

    #[test]
    fn fifo_arbitration_serializes_transfers() {
        let mut tl = BusTimeline::new(DramBus::new(8));
        let (s1, d1) = tl.request("a", 64, 0); // 8 cycles
        assert_eq!((s1, d1), (0, 8));
        // Released early but the bus is busy: queues behind `a`.
        let (s2, d2) = tl.request("b", 16, 4);
        assert_eq!((s2, d2), (8, 10));
        // Released late: the bus idles until the release.
        let (s3, d3) = tl.request("a", 8, 100);
        assert_eq!((s3, d3), (100, 101));
        assert_eq!(tl.free_at(), 101);
    }

    #[test]
    fn zero_byte_request_is_free_but_still_ordered() {
        let mut tl = BusTimeline::new(DramBus::new(8));
        // On an idle bus a zero-byte transfer starts and finishes at its
        // release cycle, books no busy time, but does create the client row.
        let (s, d) = tl.request("ctrl", 0, 5);
        assert_eq!((s, d), (5, 5));
        assert_eq!(tl.free_at(), 5, "zero-byte transfer must not hold the bus");
        // A later real transfer released earlier still queues behind the
        // FIFO cursor the zero-byte request advanced to.
        let (s2, d2) = tl.request("a", 8, 0);
        assert_eq!((s2, d2), (5, 6));
        let r = tl.into_report();
        let ctrl = r.clients.iter().find(|c| c.name == "ctrl").unwrap();
        assert_eq!((ctrl.bytes, ctrl.busy_cycles), (0, 0));
    }

    #[test]
    fn back_to_back_same_cycle_requests_serialize_in_issue_order() {
        let mut tl = BusTimeline::new(DramBus::new(4));
        // Three transfers all released at cycle 0: FIFO order is issue
        // order, each starting exactly where the previous one finished.
        let (s1, d1) = tl.request("a", 4, 0);
        let (s2, d2) = tl.request("b", 4, 0);
        let (s3, d3) = tl.request("c", 4, 0);
        assert_eq!((s1, d1), (0, 1));
        assert_eq!((s2, d2), (1, 2));
        assert_eq!((s3, d3), (2, 3));
        assert_eq!(tl.free_at(), 3);
        // No gaps and no overlap: total busy equals the contiguous span.
        assert_eq!(tl.into_report().busy_cycles(), 3);
    }

    #[test]
    fn idealized_bus_timeline_never_stalls_or_occupies() {
        let mut tl = BusTimeline::new(DramBus::new(usize::MAX));
        // Huge transfers through the full timeline path complete in zero
        // cycles: starts clamp to the release time only.
        let (s1, d1) = tl.request("weights.block0", u64::MAX / 4, 0);
        assert_eq!((s1, d1), (0, 0));
        let (s2, d2) = tl.request("weights.block1", u64::MAX / 4, 42);
        assert_eq!((s2, d2), (42, 42));
        assert_eq!(tl.free_at(), 42);
        let r = tl.into_report();
        assert_eq!(r.busy_cycles(), 0, "idealized bus books no busy time");
        assert_eq!(r.total_bytes(), (u64::MAX / 4) * 2, "bytes are still accounted");
        assert_eq!(r.bus_utilization(100), 0.0);
    }

    #[test]
    fn report_accumulates_per_client() {
        let mut tl = BusTimeline::new(DramBus::new(4));
        tl.seed("input", 100, 25);
        tl.request("weights.block0", 40, 0); // 10 cycles, starts at 25
        tl.request("weights.block0", 40, 0);
        tl.add_stall("weights.block0", 7);
        let r = tl.into_report();
        assert_eq!(r.total_bytes(), 180);
        assert_eq!(r.weight_bytes(), 80);
        assert_eq!(r.stall_cycles(), 7);
        assert_eq!(r.busy_cycles(), 25 + 20);
        let w = r.clients.iter().find(|c| c.name == "weights.block0").unwrap();
        assert_eq!(w.busy_cycles, 20);
        assert_eq!(w.bytes, 80);
    }

    #[test]
    fn fractions_are_zero_safe() {
        let r = MemoryReport::default();
        assert_eq!(r.stall_fraction(0), 0.0);
        assert_eq!(r.bus_utilization(0), 0.0);
        assert_eq!(r.weight_bytes(), 0);
        assert_eq!(r.streamed_bytes(), 0);
    }

    #[test]
    fn split_stream_with_explicit_cycles_costs_the_unsplit_total() {
        // A 100-byte stream on a 16 B/cyc bus costs ceil(100/16) = 7
        // cycles. Split head/tail at an unaligned point, the two
        // request_with_cycles pieces must book exactly those 7 cycles
        // (per-piece ceil would book ceil(60/16)+ceil(40/16) = 4+3 = 7
        // here but 8 for e.g. 50/50) and the same 100 bytes.
        let bus = DramBus::new(16);
        let total = bus.transfer_cycles(100);
        let tail_c = bus.transfer_cycles(50);
        let head_c = total - tail_c;
        let mut tl = BusTimeline::new(bus);
        let (s1, d1) = tl.request_with_cycles("weights.block0", 50, head_c, 0);
        let (s2, d2) = tl.request_with_cycles("weights.block0", 50, tail_c, 0);
        assert_eq!((s1, d1), (0, head_c));
        assert_eq!((s2, d2), (head_c, total));
        let r = tl.into_report();
        assert_eq!(r.weight_bytes(), 100);
        assert_eq!(r.busy_cycles(), total);
    }

    #[test]
    fn streamed_bytes_adds_spike_traffic_to_weights() {
        let mut tl = BusTimeline::new(DramBus::new(8));
        tl.request("weights.block0", 64, 0);
        let mut r = tl.into_report();
        assert_eq!(r.streamed_bytes(), 64);
        r.spike_bytes_full = 40;
        r.spike_bytes_moved = 10;
        assert_eq!(r.streamed_bytes(), 74);
    }
}
