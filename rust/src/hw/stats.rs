//! Per-unit operation/cycle accounting. Every compute unit returns a
//! [`UnitStats`]; the controller sums them per phase and the energy model
//! converts the op counts into Joules.

use std::ops::{Add, AddAssign};

#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
/// One unit's cycle/operation accounting record.
pub struct UnitStats {
    /// Cycles the unit was busy (its own pipeline view).
    pub cycles: u64,
    /// Synaptic operations: one spike traversing one unique synapse
    /// (the paper's SOP definition, §IV-B).
    pub sops: u64,
    /// Integer additions (accumulators, residual adders, membrane updates).
    pub adds: u64,
    /// Address/threshold comparisons.
    pub cmps: u64,
    /// Dense multiply-accumulates (Tile Engine only).
    pub macs: u64,
    /// On-chip SRAM word reads.
    pub sram_reads: u64,
    /// On-chip SRAM word writes.
    pub sram_writes: u64,
    /// External-memory traffic in bytes (Input/Output Buffer side).
    pub dram_bytes: u64,
}

impl UnitStats {
    /// All-zero record.
    pub fn new() -> Self {
        Self::default()
    }

    /// Busy-time in seconds at `freq_mhz`.
    pub fn seconds(&self, freq_mhz: f64) -> f64 {
        self.cycles as f64 / (freq_mhz * 1e6)
    }

    /// This record with `bytes` of additional external-memory traffic —
    /// how the report folds the weight-streaming DMA's bus traffic (which
    /// lives outside the compute phases) into the energy accounting.
    pub fn with_dram_bytes(mut self, bytes: u64) -> Self {
        self.dram_bytes += bytes;
        self
    }
}

impl Add for UnitStats {
    type Output = UnitStats;
    fn add(self, o: UnitStats) -> UnitStats {
        UnitStats {
            cycles: self.cycles + o.cycles,
            sops: self.sops + o.sops,
            adds: self.adds + o.adds,
            cmps: self.cmps + o.cmps,
            macs: self.macs + o.macs,
            sram_reads: self.sram_reads + o.sram_reads,
            sram_writes: self.sram_writes + o.sram_writes,
            dram_bytes: self.dram_bytes + o.dram_bytes,
        }
    }
}

impl AddAssign for UnitStats {
    fn add_assign(&mut self, o: UnitStats) {
        *self = *self + o;
    }
}

/// A named breakdown of stats per pipeline phase (SPS conv, SMU, SDSA, ...).
#[derive(Clone, Debug, Default)]
pub struct PhaseStats {
    /// `(phase name, accumulated stats)` in first-recorded order.
    pub phases: Vec<(String, UnitStats)>,
}

impl PhaseStats {
    /// An empty breakdown.
    pub fn new() -> Self {
        Self::default()
    }

    /// Accumulate `stats` into `phase` (created on first use, order kept).
    pub fn add(&mut self, phase: &str, stats: UnitStats) {
        if let Some((_, s)) = self.phases.iter_mut().find(|(n, _)| n == phase) {
            *s += stats;
        } else {
            self.phases.push((phase.to_string(), stats));
        }
    }

    /// Sum of every phase's stats.
    pub fn total(&self) -> UnitStats {
        self.phases.iter().fold(UnitStats::new(), |acc, (_, s)| acc + *s)
    }

    /// One phase's stats (zeros when the phase never ran).
    pub fn get(&self, phase: &str) -> UnitStats {
        self.phases
            .iter()
            .find(|(n, _)| n == phase)
            .map(|(_, s)| *s)
            .unwrap_or_default()
    }

    /// Summed cycles of every phase whose name starts with `prefix` —
    /// e.g. `cycles_matching("sdeb.")` is the SDEB pipeline stage's total,
    /// which the executed-vs-estimated reconciliation tests compare
    /// against the per-timestep stage traces.
    pub fn cycles_matching(&self, prefix: &str) -> u64 {
        self.phases
            .iter()
            .filter(|(n, _)| n.starts_with(prefix))
            .map(|(_, s)| s.cycles)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_assign_sums_fields() {
        let mut a = UnitStats { cycles: 1, sops: 2, ..Default::default() };
        a += UnitStats { cycles: 10, adds: 5, ..Default::default() };
        assert_eq!(a.cycles, 11);
        assert_eq!(a.sops, 2);
        assert_eq!(a.adds, 5);
    }

    #[test]
    fn with_dram_bytes_adds_traffic_only() {
        let s = UnitStats { cycles: 5, dram_bytes: 10, ..Default::default() };
        let t = s.with_dram_bytes(90);
        assert_eq!(t.dram_bytes, 100);
        assert_eq!(t.cycles, 5);
    }

    #[test]
    fn seconds_at_200mhz() {
        let s = UnitStats { cycles: 200_000_000, ..Default::default() };
        assert!((s.seconds(200.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn phase_stats_merges_same_name() {
        let mut p = PhaseStats::new();
        p.add("slu", UnitStats { cycles: 5, ..Default::default() });
        p.add("slu", UnitStats { cycles: 7, ..Default::default() });
        p.add("smam", UnitStats { cycles: 1, ..Default::default() });
        assert_eq!(p.get("slu").cycles, 12);
        assert_eq!(p.total().cycles, 13);
        assert_eq!(p.phases.len(), 2);
    }

    #[test]
    fn cycles_matching_sums_prefixed_phases() {
        let mut p = PhaseStats::new();
        p.add("sdeb.qkv", UnitStats { cycles: 5, ..Default::default() });
        p.add("sdeb.mlp", UnitStats { cycles: 7, ..Default::default() });
        p.add("sps.conv", UnitStats { cycles: 100, ..Default::default() });
        assert_eq!(p.cycles_matching("sdeb."), 12);
        assert_eq!(p.cycles_matching("sps."), 100);
        assert_eq!(p.cycles_matching("io."), 0);
    }
}
