//! On-chip SRAM bank model (the ESS and the various buffers of Fig. 1).
//!
//! Tracks occupancy and access counts; accesses are single-cycle per port,
//! and capacity violations are hard errors so simulator configs that don't
//! fit the modelled BRAM are caught instead of silently mis-measured.

use anyhow::{bail, Result};

#[derive(Clone, Debug)]
/// One SRAM bank (or bank group) with occupancy and access counters.
pub struct SramBank {
    /// Bank name (for overflow errors and reports).
    pub name: String,
    /// Capacity in words (one word = one encoded spike or one activation).
    pub words: usize,
    /// Current occupancy in words.
    pub used: usize,
    /// Word reads so far.
    pub reads: u64,
    /// Word writes so far.
    pub writes: u64,
    /// High-water mark of occupancy (for utilisation reports).
    pub peak_used: usize,
}

impl SramBank {
    /// A bank of `words` capacity.
    pub fn new(name: &str, words: usize) -> Self {
        Self { name: name.to_string(), words, used: 0, reads: 0, writes: 0, peak_used: 0 }
    }

    /// Allocate `n` words (e.g. store an encoded spike list).
    pub fn alloc(&mut self, n: usize) -> Result<()> {
        if self.used + n > self.words {
            bail!(
                "SRAM bank `{}` overflow: {} + {} > {} words",
                self.name,
                self.used,
                n,
                self.words
            );
        }
        self.used += n;
        self.peak_used = self.peak_used.max(self.used);
        self.writes += n as u64;
        Ok(())
    }

    /// Allocate `n` words but charge only `written` word writes — the
    /// delta-store path, where the bank must hold the full tensor (the
    /// prior frame's copy is patched in place) yet only the changed
    /// addresses cross the write ports. `written` never exceeds `n`.
    pub fn alloc_delta(&mut self, n: usize, written: usize) -> Result<()> {
        debug_assert!(written <= n, "delta writes exceed the full store in `{}`", self.name);
        if self.used + n > self.words {
            bail!(
                "SRAM bank `{}` overflow: {} + {} > {} words",
                self.name,
                self.used,
                n,
                self.words
            );
        }
        self.used += n;
        self.peak_used = self.peak_used.max(self.used);
        self.writes += written as u64;
        Ok(())
    }

    /// Free `n` words (consumed by a downstream unit / double-buffer swap).
    pub fn free(&mut self, n: usize) {
        debug_assert!(n <= self.used, "freeing more than allocated in `{}`", self.name);
        self.used = self.used.saturating_sub(n);
    }

    /// Record `n` word reads.
    pub fn read(&mut self, n: usize) {
        self.reads += n as u64;
    }

    /// Record `n` word writes without occupancy tracking — streamed
    /// traffic that passes through the bank transiently (the weight DMA
    /// refilling a ping/pong slot), where occupancy is governed by the
    /// slot discipline rather than alloc/free pairs.
    pub fn record_stream_writes(&mut self, n: u64) {
        self.writes += n;
    }

    /// Peak occupancy fraction.
    pub fn utilization(&self) -> f64 {
        if self.words == 0 {
            0.0
        } else {
            self.peak_used as f64 / self.words as f64
        }
    }

    /// Clear access counters between runs.
    pub fn reset_counters(&mut self) {
        self.reads = 0;
        self.writes = 0;
        self.used = 0;
        self.peak_used = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_tracks_occupancy() {
        let mut b = SramBank::new("ess0", 100);
        b.alloc(60).unwrap();
        assert_eq!(b.used, 60);
        b.free(20);
        assert_eq!(b.used, 40);
        assert_eq!(b.peak_used, 60);
        assert_eq!(b.writes, 60);
    }

    #[test]
    fn overflow_is_error() {
        let mut b = SramBank::new("ess0", 10);
        b.alloc(8).unwrap();
        let err = b.alloc(3).unwrap_err();
        assert!(err.to_string().contains("overflow"));
    }

    #[test]
    fn utilization_is_peak_based() {
        let mut b = SramBank::new("buf", 200);
        b.alloc(100).unwrap();
        b.free(100);
        assert!((b.utilization() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn delta_alloc_reserves_full_but_charges_partial() {
        let mut b = SramBank::new("ess0", 100);
        b.alloc_delta(60, 12).unwrap();
        assert_eq!(b.used, 60);
        assert_eq!(b.peak_used, 60);
        assert_eq!(b.writes, 12);
        // Capacity is still checked against the full reservation.
        assert!(b.alloc_delta(50, 0).is_err());
    }

    #[test]
    fn stream_writes_bypass_occupancy() {
        let mut b = SramBank::new("weight", 8);
        b.record_stream_writes(1000); // far beyond capacity: transient traffic
        assert_eq!(b.writes, 1000);
        assert_eq!(b.used, 0);
        assert_eq!(b.peak_used, 0);
    }

    #[test]
    fn reset_clears_counters() {
        let mut b = SramBank::new("buf", 10);
        b.alloc(5).unwrap();
        b.read(3);
        b.reset_counters();
        assert_eq!((b.reads, b.writes, b.used, b.peak_used), (0, 0, 0, 0));
    }
}
