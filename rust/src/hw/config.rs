//! Accelerator configuration. `AccelConfig::paper()` is the operating point
//! of Table I: 1,536 parallel spiking neurons at 200 MHz on a Virtex
//! UltraScale part, arranged as the Fig. 1 core topology (one SPS core
//! overlapped with two SDEB cores through ping/pong ESS halves).
//!
//! The topology itself is a first-class, sweepable parameter
//! ([`CoreTopology`]): core counts, the buffer-ring depth of the
//! SPS→SDEB pipeline, and how the SMAM comparator fabric relates to the
//! SDEB-core count are all explicit, so scaling scenarios beyond the
//! paper's fixed two-core instance (Bishop-style heterogeneous pools,
//! FireFly-T-style engine replication) are one config edit away.

use anyhow::{bail, Result};

/// Module-level alias of [`EngineSelect::DEFAULT_ADAPTIVE_THRESHOLD`] so
/// benches and tools can import the calibrated crossover density without
/// naming the policy enum (re-exported from [`crate::hw`]).
pub const DEFAULT_ADAPTIVE_THRESHOLD: f64 = EngineSelect::DEFAULT_ADAPTIVE_THRESHOLD;

/// Which of the two datapath engines executed a work unit — the value an
/// [`EngineSelect`] policy resolves to once a density measurement is in
/// hand. Every spike-consuming unit kernel (SLU/SMU/SMAM) has one
/// implementation per kind, bit-identical in values and differing only
/// in `UnitStats` cost accounting.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum EngineKind {
    /// Address-streaming CSR engine: scalar loops over encoded `u16`
    /// spike addresses (the paper's position-encoded datapath).
    #[default]
    Csr,
    /// Word-parallel packed-`u64` bitmap engine: AND/popcount/
    /// trailing-zeros scans over [`PackedBitmap`](crate::spike::PackedBitmap)
    /// rows (the FireFly-T-style dense engine).
    Bitmap,
}

impl EngineKind {
    /// Short display name (bench tables, reports).
    pub fn name(&self) -> &'static str {
        match self {
            EngineKind::Csr => "csr",
            EngineKind::Bitmap => "bitmap",
        }
    }
}

/// Engine-selection policy of the dual-engine datapath (DESIGN.md
/// "Dual-engine datapath & selection"): decides per (block, head,
/// timestep) work unit whether the CSR or the packed-bitmap engine runs,
/// from the measured spike density of that unit's inputs.
///
/// The adaptive crossover threshold is calibrated by the `units_micro`
/// density sweep (`BENCH_encoding.json`, key `crossover`): below it the
/// CSR merge-join touches fewer positions than the `ceil(L/64)`
/// words-per-row floor of the bitmap engine; above it word-parallelism
/// wins.
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub enum EngineSelect {
    /// Always the CSR address-streaming engine (the paper's datapath;
    /// the default, and bit-identical to every pre-dual-engine release).
    #[default]
    Csr,
    /// Always the packed-bitmap engine.
    Bitmap,
    /// Pick per work unit: bitmap when measured input density >=
    /// `threshold`, CSR otherwise. The comparison is written so a NaN
    /// density (impossible by construction — `density()` is total) would
    /// still fall through to CSR.
    Adaptive {
        /// Spike-density crossover in `[0, 1]` (validated).
        threshold: f64,
    },
}

impl EngineSelect {
    /// Default adaptive crossover density. First-principles estimate from
    /// the cycle model at the paper point (L = 64 tokens, one word per
    /// bitmap row): the SMAM merge-join charges `|Q|+|K| ~ 2·d·L`
    /// comparator steps per channel vs the bitmap engine's 1 word op, so
    /// the curves cross near `d = 1/(2L) · 64/64 ≈ 0.008`; the SLU's
    /// word-scan overhead pushes the blended crossover up. Calibrated
    /// empirically by `cargo bench --bench units_micro -- --json`
    /// (`BENCH_encoding.json`, key `crossover`).
    pub const DEFAULT_ADAPTIVE_THRESHOLD: f64 = 0.02;

    /// The adaptive policy at the default calibrated threshold.
    pub fn adaptive() -> Self {
        EngineSelect::Adaptive { threshold: Self::DEFAULT_ADAPTIVE_THRESHOLD }
    }

    /// Resolve the policy for one work unit whose inputs have the given
    /// measured spike density. Total: every input (including 0.0 from
    /// empty tensors, and even a hypothetical NaN) yields an engine.
    pub fn pick(&self, density: f64) -> EngineKind {
        match *self {
            EngineSelect::Csr => EngineKind::Csr,
            EngineSelect::Bitmap => EngineKind::Bitmap,
            EngineSelect::Adaptive { threshold } => {
                if density >= threshold {
                    EngineKind::Bitmap
                } else {
                    EngineKind::Csr
                }
            }
        }
    }

    /// Short display name (CLI echo, bench tables).
    pub fn name(&self) -> &'static str {
        match self {
            EngineSelect::Csr => "csr",
            EngineSelect::Bitmap => "bitmap",
            EngineSelect::Adaptive { .. } => "adaptive",
        }
    }
}

impl std::str::FromStr for EngineSelect {
    type Err = String;

    /// Parse the `--engine` CLI value: `csr`, `bitmap`, or `adaptive`
    /// (at the default threshold; `--engine-threshold` overrides it).
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "csr" => Ok(EngineSelect::Csr),
            "bitmap" => Ok(EngineSelect::Bitmap),
            "adaptive" => Ok(EngineSelect::adaptive()),
            other => Err(format!(
                "unknown engine '{other}' (expected csr|bitmap|adaptive)"
            )),
        }
    }
}

/// How the SMAM comparator fabric maps onto the SDEB cores.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum FabricPartition {
    /// Every SDEB core owns a full `smam_comparators`-wide array (the
    /// paper's physical replication: each core is a complete SEA/ESS/SMAM
    /// complement, so adding cores adds fabric).
    #[default]
    Replicated,
    /// The configured `smam_comparators` fabric is split evenly across the
    /// SDEB cores (iso-fabric scaling: adding cores buys concurrency but
    /// each comparator array narrows). Modelling note: today the
    /// partition narrows the **SMAM** accounting only (via
    /// [`CoreTopology::comparators_per_core`]); the SLU/SEA lane arrays
    /// keep charging at the configured width —
    /// [`CoreTopology::lanes_per_core`] is a planning helper for sweeps
    /// and resource estimates, not yet wired into the datapath.
    Split,
}

/// Core counts and pipeline shape of one accelerator instance.
///
/// The paper's Fig. 1 instance is `sps_cores = 1`, `sdeb_cores = 2`,
/// `pipeline_depth = 2` (ping/pong ESS halves): the SPS stage of timestep
/// `t+1` overlaps the SDEB stage of timestep `t`, and each block's SDSA
/// heads are sharded across the two SDEB cores' comparator arrays. This
/// struct generalizes that fixed shape into a swept axis; the
/// [`Mapper`](crate::accel::Mapper) decides which core runs which
/// block × head × timestep work unit.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CoreTopology {
    /// SPS (patch-embedding) cores. The schedule recurrence round-robins
    /// timesteps across them; the paper instance has one.
    pub sps_cores: usize,
    /// SDEB cores whose SMAM comparator arrays process attention heads
    /// concurrently (and whose count bounds the SDSA shard width).
    pub sdeb_cores: usize,
    /// Depth of the SPS→SDEB buffer ring: how many timesteps' encoded
    /// outputs can be in flight. 2 is the paper's ping/pong pair.
    pub pipeline_depth: usize,
    /// Comparator-fabric partition across SDEB cores.
    pub partition: FabricPartition,
}

impl Default for CoreTopology {
    fn default() -> Self {
        Self::paper()
    }
}

impl CoreTopology {
    /// The paper's Fig. 1 topology: one SPS core, two SDEB cores,
    /// ping/pong (depth-2) double buffering, replicated comparator arrays.
    pub fn paper() -> Self {
        Self {
            sps_cores: 1,
            sdeb_cores: 2,
            pipeline_depth: 2,
            partition: FabricPartition::Replicated,
        }
    }

    /// The paper topology with a different SDEB-core count (the
    /// `--sdeb-cores` sweep axis).
    pub fn with_sdeb_cores(sdeb_cores: usize) -> Self {
        Self { sdeb_cores, ..Self::paper() }
    }

    /// Comparators available to one SDEB core's SMAM array under this
    /// topology's partition (never below 1).
    pub fn comparators_per_core(&self, cfg: &AccelConfig) -> usize {
        match self.partition {
            FabricPartition::Replicated => cfg.smam_comparators,
            FabricPartition::Split => {
                (cfg.smam_comparators / self.sdeb_cores.max(1)).max(1)
            }
        }
    }

    /// Spiking-neuron lanes available to one SDEB core under this
    /// topology's partition (never below 1). Replicated cores each see the
    /// full SLA width, mirroring the comparator rule. Planning helper for
    /// sweeps/resource estimates — the SLU cycle accounting itself is not
    /// (yet) partition-aware; see [`FabricPartition::Split`].
    pub fn lanes_per_core(&self, cfg: &AccelConfig) -> usize {
        match self.partition {
            FabricPartition::Replicated => cfg.lanes,
            FabricPartition::Split => (cfg.lanes / self.sdeb_cores.max(1)).max(1),
        }
    }

    /// Structural invariants: every count nonzero and the pipeline deep
    /// enough to overlap. (Fabric-dependent checks — e.g. that a Split
    /// partition leaves each core at least one comparator — live in
    /// [`AccelConfig::validate`], which knows the comparator budget.)
    pub fn validate(&self) -> Result<()> {
        if self.sps_cores == 0 {
            bail!("topology needs at least one SPS core");
        }
        if self.sdeb_cores == 0 {
            bail!("topology needs at least one SDEB core");
        }
        if self.pipeline_depth < 2 {
            bail!(
                "pipeline_depth {} < 2: the SPS and SDEB stages cannot overlap \
                 without at least a ping/pong buffer pair",
                self.pipeline_depth
            );
        }
        Ok(())
    }
}

/// Structural parameters of the accelerator instance.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AccelConfig {
    /// Parallel spiking-neuron lanes (SEU array width == SLA adder width).
    pub lanes: usize,
    /// Clock frequency in MHz.
    pub freq_mhz: f64,
    /// Dense MAC units in the SPS Tile Engine.
    pub tile_macs: usize,
    /// Address comparators in the SMAM (one per concurrently-processed
    /// channel of the Q/K intersection).
    pub smam_comparators: usize,
    /// Spike Maxpooling Units in the Maxpooling Array.
    pub smu_units: usize,
    /// ESS banks (one per channel group; encoded spikes are banked by
    /// channel so the SLU can parallelise over input channels, §III-D).
    pub ess_banks: usize,
    /// Words per ESS bank (8-bit encoded addresses + segment headers).
    pub ess_bank_words: usize,
    /// External-memory interface bytes/cycle (Input/Output Buffer side) —
    /// the shared [`DramBus`](crate::hw::DramBus) bandwidth every client
    /// (input load, weight-streaming DMA, output drain) arbitrates for.
    /// `usize::MAX` is the idealized unlimited bus (the `--dram-bw` sweep
    /// axis; see `DESIGN.md` "Memory system & DMA").
    pub dram_bytes_per_cycle: usize,
    /// Weight-buffer capacity in words (one word = one 10-bit weight in a
    /// 16-bit memory word). The buffer feeds the Tile Engine and the
    /// Spike Linear Array; each SDEB core sees its own full-size copy
    /// (mirroring the replicated ESS complement).
    pub weight_buffer_words: usize,
    /// Ping/pong slots the weight buffer is divided into for the
    /// streaming DMA's double buffering (2 = the classic pair). A block
    /// working set larger than one slot cannot be double-buffered and
    /// must stream through per use — see
    /// [`DmaEngine`](crate::accel::DmaEngine).
    pub weight_slots: usize,
    /// Core counts and pipeline shape (Fig. 1 generalized).
    pub topology: CoreTopology,
    /// Engine-selection policy of the dual-engine spike datapath (the
    /// `--engine` CLI axis). [`EngineSelect::Csr`] reproduces the
    /// paper's address-streaming datapath bit- and cycle-exactly; the
    /// other policies swap in the packed-bitmap engine per work unit
    /// with bit-identical values and engine-specific cycle accounting.
    pub engine: EngineSelect,
    /// Temporal-reuse delta streaming for the SDEB input spike load (the
    /// `--temporal-delta` CLI flag; see DESIGN.md "Temporal reuse & delta
    /// streaming"). When on, each SDEB core compares timestep `t`'s input
    /// spike frame against timestep `t-1`'s and charges the ESS store for
    /// only the changed addresses whenever the per-channel XOR delta is
    /// cheaper than a full re-store. Values, phases and `UnitStats` are
    /// bit-identical with the flag on or off — only the modelled spike
    /// traffic (SRAM write counters, `MemoryReport` spike bytes) moves.
    /// Default off until the `units_micro` delta bench proves the
    /// crossover on a given workload.
    pub temporal_delta: bool,
}

impl AccelConfig {
    /// The paper's implementation point (Table I "Ours").
    ///
    /// ```
    /// use spikeformer_accel::hw::AccelConfig;
    ///
    /// let hw = AccelConfig::paper();
    /// assert!(hw.validate().is_ok());
    /// // 1,536 lanes x 200 MHz = the paper's 307.2 GSOP/s headline peak.
    /// assert!((hw.peak_gsops() - 307.2).abs() < 1e-9);
    /// // Fig. 1's instance: one SPS core overlapped with two SDEB cores
    /// // through a ping/pong ESS pair, fed over a 16 B/cycle bus.
    /// assert_eq!(hw.topology.sdeb_cores, 2);
    /// assert_eq!(hw.dram_bytes_per_cycle, 16);
    /// ```
    pub fn paper() -> Self {
        Self {
            lanes: 1536,
            freq_mhz: 200.0,
            tile_macs: 576,
            smam_comparators: 384,
            smu_units: 256,
            ess_banks: 384,
            ess_bank_words: 4096,
            dram_bytes_per_cycle: 16,
            weight_buffer_words: 2 * 1024 * 1024,
            weight_slots: 2,
            topology: CoreTopology::paper(),
            engine: EngineSelect::Csr,
            temporal_delta: false,
        }
    }

    /// A scaled-down instance used by fast unit/integration tests.
    pub fn small() -> Self {
        Self {
            lanes: 64,
            freq_mhz: 200.0,
            tile_macs: 32,
            smam_comparators: 16,
            smu_units: 16,
            ess_banks: 16,
            ess_bank_words: 2048,
            dram_bytes_per_cycle: 8,
            weight_buffer_words: 512 * 1024,
            weight_slots: 2,
            topology: CoreTopology::paper(),
            engine: EngineSelect::Csr,
            temporal_delta: false,
        }
    }

    /// Scale the compute fabric to a different lane count, keeping the
    /// proportions (and topology) of the paper instance (used by the
    /// parallelism sweep). Panics on a degenerate lane count — sweeps
    /// should never silently produce an invalid instance.
    pub fn with_lanes(lanes: usize) -> Self {
        let p = Self::paper();
        let ratio = lanes as f64 / p.lanes as f64;
        let scale = |v: usize| ((v as f64 * ratio).round() as usize).max(1);
        let cfg = Self {
            lanes,
            freq_mhz: p.freq_mhz,
            tile_macs: scale(p.tile_macs),
            smam_comparators: scale(p.smam_comparators),
            smu_units: scale(p.smu_units),
            ess_banks: scale(p.ess_banks),
            ess_bank_words: p.ess_bank_words,
            dram_bytes_per_cycle: p.dram_bytes_per_cycle,
            weight_buffer_words: p.weight_buffer_words,
            weight_slots: p.weight_slots,
            topology: p.topology,
            engine: p.engine,
            temporal_delta: p.temporal_delta,
        };
        cfg.validate().expect("scaled AccelConfig invalid");
        cfg
    }

    /// This instance with a different core topology (validated).
    pub fn with_topology(mut self, topology: CoreTopology) -> Self {
        topology.validate().expect("invalid CoreTopology");
        self.topology = topology;
        self
    }

    /// Structural invariants of the fabric: nonzero unit counts, the
    /// comparator array no wider than the lane array, and a valid
    /// topology. `with_lanes` enforces this on every swept instance.
    pub fn validate(&self) -> Result<()> {
        if self.lanes == 0 {
            bail!("lanes must be nonzero");
        }
        if self.tile_macs == 0 {
            bail!("tile_macs must be nonzero");
        }
        if self.smam_comparators == 0 {
            bail!("smam_comparators must be nonzero");
        }
        if self.smam_comparators > self.lanes {
            bail!(
                "smam_comparators {} > lanes {}: the comparator array cannot \
                 outrun the neuron fabric that feeds it",
                self.smam_comparators,
                self.lanes
            );
        }
        if self.smu_units == 0 {
            bail!("smu_units must be nonzero");
        }
        if self.ess_banks == 0 || self.ess_bank_words == 0 {
            bail!("ESS must have nonzero banks and words per bank");
        }
        if self.dram_bytes_per_cycle == 0 {
            bail!("dram_bytes_per_cycle must be nonzero");
        }
        if self.weight_buffer_words == 0 {
            bail!("weight_buffer_words must be nonzero");
        }
        if self.weight_slots < 2 {
            bail!(
                "weight_slots {} < 2: the streaming DMA cannot double-buffer \
                 through fewer than a ping/pong pair",
                self.weight_slots
            );
        }
        if self.weight_buffer_words < self.weight_slots {
            bail!(
                "weight buffer of {} words cannot be cut into {} slots",
                self.weight_buffer_words,
                self.weight_slots
            );
        }
        if !(self.freq_mhz > 0.0) {
            bail!("freq_mhz must be positive");
        }
        if let EngineSelect::Adaptive { threshold } = self.engine {
            if !threshold.is_finite() || !(0.0..=1.0).contains(&threshold) {
                bail!(
                    "adaptive engine threshold {} must be a finite density \
                     in [0, 1]",
                    threshold
                );
            }
        }
        if self.topology.partition == FabricPartition::Split
            && self.topology.sdeb_cores > self.smam_comparators
        {
            bail!(
                "Split partition over {} SDEB cores cannot be cut from {} \
                 comparators (each core needs at least one)",
                self.topology.sdeb_cores,
                self.smam_comparators
            );
        }
        self.topology.validate()
    }

    /// Words one weight-buffer ping/pong slot holds — the residency
    /// threshold of the streaming DMA: a block working set larger than
    /// this cannot be double-buffered and streams through per use.
    pub fn weight_slot_words(&self) -> usize {
        (self.weight_buffer_words / self.weight_slots.max(1)).max(1)
    }

    /// Peak throughput in GSOP/s: every lane retires one synaptic
    /// operation per cycle. 1536 lanes x 200 MHz = 307.2 GSOP/s, the
    /// paper's headline peak.
    pub fn peak_gsops(&self) -> f64 {
        self.lanes as f64 * self.freq_mhz * 1e6 / 1e9
    }

    /// Seconds for a cycle count at this clock.
    pub fn seconds(&self, cycles: u64) -> f64 {
        cycles as f64 / (self.freq_mhz * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_peak_is_307_2_gsops() {
        let c = AccelConfig::paper();
        assert!((c.peak_gsops() - 307.2).abs() < 1e-9);
    }

    #[test]
    fn with_lanes_scales_proportionally() {
        let half = AccelConfig::with_lanes(768);
        assert_eq!(half.tile_macs, 288);
        assert_eq!(half.smam_comparators, 192);
        assert!((half.peak_gsops() - 153.6).abs() < 1e-9);
    }

    #[test]
    fn with_lanes_identity() {
        assert_eq!(AccelConfig::with_lanes(1536), AccelConfig::paper());
    }

    #[test]
    fn seconds_at_clock() {
        let c = AccelConfig::paper();
        assert!((c.seconds(200_000_000) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn paper_topology_is_the_fig1_instance() {
        let t = AccelConfig::paper().topology;
        assert_eq!(t.sps_cores, 1);
        assert_eq!(t.sdeb_cores, 2);
        assert_eq!(t.pipeline_depth, 2);
        assert_eq!(t.partition, FabricPartition::Replicated);
        assert!(t.validate().is_ok());
    }

    #[test]
    fn replicated_partition_keeps_full_arrays() {
        let cfg = AccelConfig::paper();
        let t = CoreTopology::with_sdeb_cores(4);
        assert_eq!(t.comparators_per_core(&cfg), 384);
        assert_eq!(t.lanes_per_core(&cfg), 1536);
    }

    #[test]
    fn split_partition_divides_the_fabric() {
        let cfg = AccelConfig::paper();
        let t = CoreTopology {
            partition: FabricPartition::Split,
            ..CoreTopology::with_sdeb_cores(4)
        };
        assert_eq!(t.comparators_per_core(&cfg), 96);
        assert_eq!(t.lanes_per_core(&cfg), 384);
        // Splitting below one comparator clamps rather than hitting zero.
        let mut tiny = AccelConfig::small();
        tiny.smam_comparators = 2;
        let wide = CoreTopology {
            partition: FabricPartition::Split,
            ..CoreTopology::with_sdeb_cores(8)
        };
        assert_eq!(wide.comparators_per_core(&tiny), 1);
    }

    #[test]
    fn validate_rejects_degenerate_instances() {
        let mut c = AccelConfig::small();
        c.lanes = 0;
        assert!(c.validate().is_err());

        let mut c = AccelConfig::small();
        c.smam_comparators = c.lanes + 1;
        assert!(c.validate().is_err(), "comparators must not exceed lanes");

        let mut c = AccelConfig::small();
        c.ess_banks = 0;
        assert!(c.validate().is_err());

        let mut c = AccelConfig::small();
        c.dram_bytes_per_cycle = 0;
        assert!(c.validate().is_err());

        let mut c = AccelConfig::small();
        c.weight_buffer_words = 0;
        assert!(c.validate().is_err());

        let mut c = AccelConfig::small();
        c.weight_slots = 1;
        assert!(c.validate().is_err(), "one slot cannot double-buffer");

        assert!(AccelConfig::small().validate().is_ok());
        assert!(AccelConfig::paper().validate().is_ok());
    }

    #[test]
    fn weight_slot_words_divides_the_buffer() {
        let p = AccelConfig::paper();
        assert_eq!(p.weight_slot_words(), 1024 * 1024);
        let s = AccelConfig::small();
        assert_eq!(s.weight_slot_words(), 256 * 1024);
        // An unlimited-bandwidth bus is a valid config (the invariance
        // tests' idealization).
        let mut c = AccelConfig::small();
        c.dram_bytes_per_cycle = usize::MAX;
        assert!(c.validate().is_ok());
    }

    #[test]
    fn validate_rejects_oversplit_fabric() {
        let mut c = AccelConfig::small(); // 16 comparators
        c.smam_comparators = 2;
        c.topology = CoreTopology {
            partition: FabricPartition::Split,
            ..CoreTopology::with_sdeb_cores(8)
        };
        assert!(c.validate().is_err(), "8 cores cannot split 2 comparators");
        c.topology.sdeb_cores = 2;
        assert!(c.validate().is_ok());
    }

    #[test]
    fn topology_validate_rejects_zero_cores_and_shallow_pipes() {
        assert!(CoreTopology { sps_cores: 0, ..CoreTopology::paper() }.validate().is_err());
        assert!(CoreTopology { sdeb_cores: 0, ..CoreTopology::paper() }.validate().is_err());
        assert!(
            CoreTopology { pipeline_depth: 1, ..CoreTopology::paper() }.validate().is_err(),
            "depth 1 cannot double-buffer"
        );
        assert!(CoreTopology { pipeline_depth: 4, ..CoreTopology::paper() }.validate().is_ok());
    }

    #[test]
    fn with_lanes_smallest_swept_instance_is_valid() {
        // The degenerate end of the sweep: every scaled count clamps to
        // >= 1 and the result still validates.
        let tiny = AccelConfig::with_lanes(1);
        assert!(tiny.validate().is_ok());
        assert_eq!(tiny.smam_comparators, 1);
    }

    #[test]
    #[should_panic(expected = "scaled AccelConfig invalid")]
    fn with_lanes_zero_panics() {
        let _ = AccelConfig::with_lanes(0);
    }

    #[test]
    fn engine_select_pick_is_total() {
        let a = EngineSelect::Adaptive { threshold: 0.1 };
        assert_eq!(a.pick(0.05), EngineKind::Csr);
        assert_eq!(a.pick(0.1), EngineKind::Bitmap, "threshold is inclusive");
        assert_eq!(a.pick(0.9), EngineKind::Bitmap);
        // The empty-input density (0.0) and even a NaN fall to CSR: the
        // selector never panics or mis-selects on degenerate density.
        assert_eq!(a.pick(0.0), EngineKind::Csr);
        assert_eq!(a.pick(f64::NAN), EngineKind::Csr);
        assert_eq!(EngineSelect::Csr.pick(1.0), EngineKind::Csr);
        assert_eq!(EngineSelect::Bitmap.pick(0.0), EngineKind::Bitmap);
    }

    #[test]
    fn engine_select_parses_and_defaults() {
        assert_eq!("csr".parse::<EngineSelect>().unwrap(), EngineSelect::Csr);
        assert_eq!("bitmap".parse::<EngineSelect>().unwrap(), EngineSelect::Bitmap);
        assert_eq!(
            "adaptive".parse::<EngineSelect>().unwrap(),
            EngineSelect::Adaptive { threshold: EngineSelect::DEFAULT_ADAPTIVE_THRESHOLD }
        );
        assert!("simd".parse::<EngineSelect>().is_err());
        assert_eq!(EngineSelect::default(), EngineSelect::Csr);
        assert_eq!(AccelConfig::paper().engine, EngineSelect::Csr);
        assert_eq!(EngineSelect::adaptive().name(), "adaptive");
        assert_eq!(EngineKind::Bitmap.name(), "bitmap");
    }

    #[test]
    fn temporal_delta_defaults_off_everywhere() {
        assert!(!AccelConfig::paper().temporal_delta);
        assert!(!AccelConfig::small().temporal_delta);
        assert!(!AccelConfig::with_lanes(512).temporal_delta);
        // The module-level alias tracks the policy constant.
        assert_eq!(DEFAULT_ADAPTIVE_THRESHOLD, EngineSelect::DEFAULT_ADAPTIVE_THRESHOLD);
    }

    #[test]
    fn validate_rejects_degenerate_adaptive_thresholds() {
        for bad in [f64::NAN, f64::INFINITY, -0.5, 1.5] {
            let mut c = AccelConfig::small();
            c.engine = EngineSelect::Adaptive { threshold: bad };
            assert!(c.validate().is_err(), "threshold {bad} must be rejected");
        }
        let mut c = AccelConfig::small();
        c.engine = EngineSelect::adaptive();
        assert!(c.validate().is_ok());
        c.engine = EngineSelect::Bitmap;
        assert!(c.validate().is_ok());
    }
}
