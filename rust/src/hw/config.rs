//! Accelerator configuration. `AccelConfig::paper()` is the operating point
//! of Table I: 1,536 parallel spiking neurons at 200 MHz on a Virtex
//! UltraScale part.

/// Structural parameters of the accelerator instance.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AccelConfig {
    /// Parallel spiking-neuron lanes (SEU array width == SLA adder width).
    pub lanes: usize,
    /// Clock frequency in MHz.
    pub freq_mhz: f64,
    /// Dense MAC units in the SPS Tile Engine.
    pub tile_macs: usize,
    /// Address comparators in the SMAM (one per concurrently-processed
    /// channel of the Q/K intersection).
    pub smam_comparators: usize,
    /// Spike Maxpooling Units in the Maxpooling Array.
    pub smu_units: usize,
    /// ESS banks (one per channel group; encoded spikes are banked by
    /// channel so the SLU can parallelise over input channels, §III-D).
    pub ess_banks: usize,
    /// Words per ESS bank (8-bit encoded addresses + segment headers).
    pub ess_bank_words: usize,
    /// External-memory interface bytes/cycle (Input/Output Buffer side).
    pub dram_bytes_per_cycle: usize,
}

impl AccelConfig {
    /// The paper's implementation point (Table I "Ours").
    pub fn paper() -> Self {
        Self {
            lanes: 1536,
            freq_mhz: 200.0,
            tile_macs: 576,
            smam_comparators: 384,
            smu_units: 256,
            ess_banks: 384,
            ess_bank_words: 4096,
            dram_bytes_per_cycle: 16,
        }
    }

    /// A scaled-down instance used by fast unit/integration tests.
    pub fn small() -> Self {
        Self {
            lanes: 64,
            freq_mhz: 200.0,
            tile_macs: 32,
            smam_comparators: 16,
            smu_units: 16,
            ess_banks: 16,
            ess_bank_words: 2048,
            dram_bytes_per_cycle: 8,
        }
    }

    /// Scale the compute fabric to a different lane count, keeping the
    /// proportions of the paper instance (used by the parallelism sweep).
    pub fn with_lanes(lanes: usize) -> Self {
        let p = Self::paper();
        let ratio = lanes as f64 / p.lanes as f64;
        let scale = |v: usize| ((v as f64 * ratio).round() as usize).max(1);
        Self {
            lanes,
            freq_mhz: p.freq_mhz,
            tile_macs: scale(p.tile_macs),
            smam_comparators: scale(p.smam_comparators),
            smu_units: scale(p.smu_units),
            ess_banks: scale(p.ess_banks),
            ess_bank_words: p.ess_bank_words,
            dram_bytes_per_cycle: p.dram_bytes_per_cycle,
        }
    }

    /// Peak throughput in GSOP/s: every lane retires one synaptic
    /// operation per cycle. 1536 lanes x 200 MHz = 307.2 GSOP/s, the
    /// paper's headline peak.
    pub fn peak_gsops(&self) -> f64 {
        self.lanes as f64 * self.freq_mhz * 1e6 / 1e9
    }

    /// Seconds for a cycle count at this clock.
    pub fn seconds(&self, cycles: u64) -> f64 {
        cycles as f64 / (self.freq_mhz * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_peak_is_307_2_gsops() {
        let c = AccelConfig::paper();
        assert!((c.peak_gsops() - 307.2).abs() < 1e-9);
    }

    #[test]
    fn with_lanes_scales_proportionally() {
        let half = AccelConfig::with_lanes(768);
        assert_eq!(half.tile_macs, 288);
        assert_eq!(half.smam_comparators, 192);
        assert!((half.peak_gsops() - 153.6).abs() < 1e-9);
    }

    #[test]
    fn with_lanes_identity() {
        assert_eq!(AccelConfig::with_lanes(1536), AccelConfig::paper());
    }

    #[test]
    fn seconds_at_clock() {
        let c = AccelConfig::paper();
        assert!((c.seconds(200_000_000) - 1.0).abs() < 1e-12);
    }
}
