//! FPGA resource model: LUT/FF/BRAM cost per structure, calibrated so the
//! paper configuration reproduces the Table I utilisation column
//! (453,266 LUT / 94,120 FF / 784 BRAM on Virtex UltraScale).
//!
//! The per-structure costs are engineering estimates for 10-bit datapaths:
//! a 10x10 MAC with its pipeline ~ 180 LUT, a 24-bit accumulate lane
//! ~ 120 LUT, an SEU (adder + threshold compare + address counter) ~ 55 LUT,
//! an 8-bit two-pointer comparator ~ 90 LUT, an SMU ~ 40 LUT. BRAM counts
//! allocate the ESS banks, the weight buffer and the I/O + residual
//! buffers. A fixed controller/interconnect overhead absorbs the rest.

use super::config::AccelConfig;

#[derive(Clone, Copy, Debug, Default, PartialEq)]
/// FPGA resource totals.
pub struct Resources {
    /// Lookup tables.
    pub lut: u64,
    /// Flip-flops.
    pub ff: u64,
    /// Block RAMs (36 Kb).
    pub bram: u64,
}

#[derive(Clone, Copy, Debug)]
/// Per-structure FPGA cost model calibrated against Table I.
pub struct ResourceModel {
    /// LUTs per Tile Engine MAC.
    pub lut_per_mac: u64,
    /// LUTs per SLA adder lane.
    pub lut_per_sla_lane: u64,
    /// LUTs per spike-encoding unit.
    pub lut_per_seu: u64,
    /// LUTs per SMAM comparator.
    pub lut_per_smam_cmp: u64,
    /// LUTs per maxpooling unit.
    pub lut_per_smu: u64,
    /// Fixed control/interconnect LUTs.
    pub lut_overhead: u64,
    /// FFs per neuron lane.
    pub ff_per_lane: u64,
    /// FFs per MAC.
    pub ff_per_mac: u64,
    /// Fixed control FFs.
    pub ff_overhead: u64,
    /// BRAMs per ESS bank.
    pub bram_per_ess_bank: u64,
    /// BRAMs for the weight buffer.
    pub bram_weight_buffer: u64,
    /// BRAMs for the I/O buffers.
    pub bram_io_buffers: u64,
    /// BRAMs for the ResBuffer.
    pub bram_res_buffer: u64,
}

impl Default for ResourceModel {
    fn default() -> Self {
        Self {
            lut_per_mac: 180,
            lut_per_sla_lane: 120,
            lut_per_seu: 55,
            lut_per_smam_cmp: 90,
            lut_per_smu: 40,
            lut_overhead: 35_986,
            ff_per_lane: 30,
            ff_per_mac: 40,
            ff_overhead: 25_000,
            bram_per_ess_bank: 1,
            bram_weight_buffer: 256,
            bram_io_buffers: 96,
            bram_res_buffer: 48,
        }
    }
}

impl ResourceModel {
    /// Estimate the utilisation of an accelerator instance.
    pub fn estimate(&self, c: &AccelConfig) -> Resources {
        let lut = self.lut_per_mac * c.tile_macs as u64
            + self.lut_per_sla_lane * c.lanes as u64
            + self.lut_per_seu * c.lanes as u64
            + self.lut_per_smam_cmp * c.smam_comparators as u64
            + self.lut_per_smu * c.smu_units as u64
            + self.lut_overhead;
        let ff = self.ff_per_lane * c.lanes as u64
            + self.ff_per_mac * c.tile_macs as u64
            + self.ff_overhead;
        let bram = self.bram_per_ess_bank * c.ess_banks as u64
            + self.bram_weight_buffer
            + self.bram_io_buffers
            + self.bram_res_buffer;
        Resources { lut, ff, bram }
    }
}

/// Table I utilisation reported by the paper for the "Ours" column.
pub const PAPER_RESOURCES: Resources = Resources { lut: 453_266, ff: 94_120, bram: 784 };

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_config_matches_table1_within_2pct() {
        let est = ResourceModel::default().estimate(&AccelConfig::paper());
        let pct = |a: u64, b: u64| (a as f64 - b as f64).abs() / b as f64;
        assert!(pct(est.lut, PAPER_RESOURCES.lut) < 0.02, "LUT {est:?}");
        assert!(pct(est.ff, PAPER_RESOURCES.ff) < 0.02, "FF {est:?}");
        assert_eq!(est.bram, PAPER_RESOURCES.bram, "BRAM {est:?}");
    }

    #[test]
    fn smaller_instance_uses_less() {
        let m = ResourceModel::default();
        let small = m.estimate(&AccelConfig::with_lanes(256));
        let full = m.estimate(&AccelConfig::paper());
        assert!(small.lut < full.lut);
        assert!(small.ff < full.ff);
        assert!(small.bram < full.bram);
    }

    #[test]
    fn resources_monotonic_in_lanes() {
        let m = ResourceModel::default();
        let mut prev = 0;
        for lanes in [128, 256, 512, 1024, 1536] {
            let r = m.estimate(&AccelConfig::with_lanes(lanes));
            assert!(r.lut > prev);
            prev = r.lut;
        }
    }
}
