//! Hardware-modelling substrate: the accelerator configuration, cycle and
//! operation accounting, SRAM bank models, and the energy / FPGA-resource
//! models calibrated against the paper's Table I column.
//!
//! Substitution #1 (DESIGN.md): the paper's Virtex UltraScale RTL is
//! replaced by this cycle-level model. Units charge cycles/ops exactly as
//! the Figs. 2-5 dataflows describe; energy and LUT/FF/BRAM come from
//! per-structure cost functions whose totals are validated against the
//! paper's reported implementation results.

pub mod config;
pub mod dram;
pub mod energy;
pub mod resources;
pub mod sram;
pub mod stats;

pub use config::{
    AccelConfig, CoreTopology, EngineKind, EngineSelect, FabricPartition,
    DEFAULT_ADAPTIVE_THRESHOLD,
};
pub use dram::{BusTimeline, ClientStats, DramBus, MemoryReport};
pub use energy::EnergyModel;
pub use resources::{ResourceModel, Resources};
pub use sram::SramBank;
pub use stats::UnitStats;
