//! Energy model: converts [`UnitStats`] op counts into Joules.
//!
//! Per-operation energies are representative 16-nm FPGA figures chosen so
//! that the paper operating point (full 1,536-lane activity at 200 MHz)
//! lands on the reported 25.6 GSOP/W — i.e. ~12 W total at the 307.2 GSOP/s
//! peak. The *ratios* between op classes (MAC >> add > compare,
//! SRAM read/write ~ a few pJ, DRAM ~ two orders more) follow standard
//! architecture-textbook numbers, so baseline comparisons remain fair.

use super::stats::UnitStats;

#[derive(Clone, Copy, Debug)]
/// Per-operation energy costs (pJ) plus static power.
pub struct EnergyModel {
    /// 10-bit add (SLU accumulate, residual adder, membrane update), pJ.
    pub pj_add: f64,
    /// 8-bit address / threshold compare, pJ.
    pub pj_cmp: f64,
    /// 10x10-bit MAC in the Tile Engine, pJ.
    pub pj_mac: f64,
    /// On-chip SRAM read/write (per word), pJ.
    pub pj_sram_read: f64,
    /// On-chip SRAM write (per word), pJ.
    pub pj_sram_write: f64,
    /// External memory, pJ per byte.
    pub pj_dram_byte: f64,
    /// Static + clock-tree power, W.
    pub static_w: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        Self {
            pj_add: 12.0,
            pj_cmp: 3.5,
            pj_mac: 30.0,
            pj_sram_read: 14.0,
            pj_sram_write: 20.0,
            pj_dram_byte: 160.0,
            static_w: 2.5,
        }
    }
}

impl EnergyModel {
    /// Energy of streaming `bytes` over the external-memory bus, in
    /// Joules. This is exactly the `pj_dram_byte` term of
    /// [`Self::dynamic_j`] — the report folds the weight-streaming DMA's
    /// traffic into its power/efficiency numbers by adding the streamed
    /// bytes to the stats record it prices
    /// ([`UnitStats::with_dram_bytes`](super::stats::UnitStats::with_dram_bytes)),
    /// and this helper prices the same bytes standalone (a unit test pins
    /// the two paths equal so they cannot diverge).
    ///
    /// ```
    /// use spikeformer_accel::hw::EnergyModel;
    ///
    /// let m = EnergyModel::default();
    /// // One paper-scale encoder block's working set is ~3.5 MB per
    /// // stream; at 160 pJ/byte that is ~0.57 mJ of DRAM energy per use.
    /// let j = m.weight_stream_j(3_545_856);
    /// assert!((j - 3_545_856.0 * 160.0e-12).abs() < 1e-9);
    /// // Streaming energy is linear in bytes.
    /// assert!((m.weight_stream_j(2) - 2.0 * m.weight_stream_j(1)).abs() < 1e-18);
    /// ```
    pub fn weight_stream_j(&self, bytes: u64) -> f64 {
        bytes as f64 * self.pj_dram_byte * 1e-12
    }

    /// Informational: SRAM write energy saved by a delta spike store that
    /// moved `moved` of `full` words, in Joules. The report's energy
    /// basis already counts only the moved words (the cores charge
    /// `sram_writes` through the delta-aware store), so this helper
    /// exists for analysis output — it is never added to or subtracted
    /// from a stats record.
    pub fn spike_store_saved_j(&self, full: u64, moved: u64) -> f64 {
        full.saturating_sub(moved) as f64 * self.pj_sram_write * 1e-12
    }

    /// Dynamic energy of a stats record, in Joules.
    pub fn dynamic_j(&self, s: &UnitStats) -> f64 {
        (s.adds as f64 * self.pj_add
            + s.cmps as f64 * self.pj_cmp
            + s.macs as f64 * self.pj_mac
            + s.sram_reads as f64 * self.pj_sram_read
            + s.sram_writes as f64 * self.pj_sram_write
            + s.dram_bytes as f64 * self.pj_dram_byte)
            * 1e-12
    }

    /// Total energy including static power over `seconds`.
    pub fn total_j(&self, s: &UnitStats, seconds: f64) -> f64 {
        self.dynamic_j(s) + self.static_w * seconds
    }

    /// Average power in W for a stats record spanning `seconds`.
    pub fn avg_power_w(&self, s: &UnitStats, seconds: f64) -> f64 {
        if seconds <= 0.0 {
            return 0.0;
        }
        self.total_j(s, seconds) / seconds
    }

    /// Energy efficiency in GSOP/W for a workload.
    pub fn gsop_per_w(&self, s: &UnitStats, seconds: f64) -> f64 {
        let w = self.avg_power_w(s, seconds);
        if w <= 0.0 {
            return 0.0;
        }
        (s.sops as f64 / seconds) / 1e9 / w
    }

    /// Peak energy efficiency (the number Table I reports): all lanes
    /// retiring one SOP/cycle, each SOP being one add + one ESS read with
    /// encoded outputs amortised to one write per 4 SOPs.
    pub fn peak_gsop_per_w(&self, cfg: &crate::hw::AccelConfig) -> f64 {
        let sops = (cfg.lanes as f64 * cfg.freq_mhz * 1e6) as u64;
        let s = UnitStats {
            cycles: (cfg.freq_mhz * 1e6) as u64,
            sops,
            adds: sops,
            sram_reads: sops,
            sram_writes: sops / 4,
            ..Default::default()
        };
        self.gsop_per_w(&s, 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_efficiency_close_to_paper() {
        // Full-tilt workload: 1536 lanes x 200 MHz for one second; each SOP
        // is one add plus amortized ESS traffic (one read per SOP, one
        // write per ~4 SOPs as encoded outputs are sparser than inputs).
        let m = EnergyModel::default();
        let sops = 1536u64 * 200_000_000;
        let s = UnitStats {
            cycles: 200_000_000,
            sops,
            adds: sops,
            sram_reads: sops,
            sram_writes: sops / 4,
            ..Default::default()
        };
        let eff = m.gsop_per_w(&s, 1.0);
        assert!(
            (eff - 25.6).abs() / 25.6 < 0.05,
            "peak efficiency {eff:.2} GSOP/W should be within 5% of 25.6"
        );
    }

    #[test]
    fn peak_efficiency_helper_matches_paper() {
        let m = EnergyModel::default();
        let eff = m.peak_gsop_per_w(&crate::hw::AccelConfig::paper());
        assert!((eff - 25.6).abs() / 25.6 < 0.05, "peak {eff:.2}");
    }

    #[test]
    fn weight_stream_j_matches_dynamic_j_dram_term() {
        // The report charges streamed weights by folding bytes into the
        // stats record; the standalone helper must price them identically.
        let m = EnergyModel::default();
        for bytes in [0u64, 1, 4096, 3_545_856] {
            let s = UnitStats { dram_bytes: bytes, ..Default::default() };
            assert!((m.weight_stream_j(bytes) - m.dynamic_j(&s)).abs() < 1e-24, "{bytes}");
        }
    }

    #[test]
    fn spike_store_savings_price_the_write_term() {
        let m = EnergyModel::default();
        let s = UnitStats { sram_writes: 70, ..Default::default() };
        assert!((m.spike_store_saved_j(100, 30) - m.dynamic_j(&s)).abs() < 1e-24);
        assert_eq!(m.spike_store_saved_j(30, 30), 0.0);
        assert_eq!(m.spike_store_saved_j(30, 100), 0.0, "moved > full saturates to zero");
    }

    #[test]
    fn dynamic_energy_additive() {
        let m = EnergyModel::default();
        let a = UnitStats { adds: 100, ..Default::default() };
        let b = UnitStats { cmps: 50, ..Default::default() };
        let ab = a + b;
        let sum = m.dynamic_j(&a) + m.dynamic_j(&b);
        assert!((m.dynamic_j(&ab) - sum).abs() < 1e-18);
    }

    #[test]
    fn static_power_dominates_idle() {
        let m = EnergyModel::default();
        let idle = UnitStats::default();
        assert!((m.avg_power_w(&idle, 2.0) - m.static_w).abs() < 1e-12);
    }

    #[test]
    fn mac_costs_more_than_add() {
        let m = EnergyModel::default();
        assert!(m.pj_mac > 2.0 * m.pj_add);
        assert!(m.pj_dram_byte > 10.0 * m.pj_sram_read);
    }
}
