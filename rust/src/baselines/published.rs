//! Published comparison points, verbatim from Table I of the paper.

use crate::metrics::AccelRow;

/// ISCAS 2022 [14]: event-driven FC accelerator with on-chip sparse
/// weights (Kintex UltraScale). Starred values are averages over the
/// paper's reported operating conditions.
pub fn iscas22_row() -> AccelRow {
    AccelRow {
        name: "ISCAS[14]".into(),
        year: 2022,
        network: "FC".into(),
        dataset: "MNIST".into(),
        platform: "Kintex Ultra.".into(),
        lut: 416_296,
        ff: 95_000,
        bram: 216,
        freq_mhz: 140.0,
        gsops: 179.0,
        gsop_per_w: 21.49,
    }
}

/// TCAD 2022 Skydiver [15]: spatio-temporal workload-balanced CNN
/// accelerator (Zynq-7000).
pub fn tcad22_row() -> AccelRow {
    AccelRow {
        name: "TCAD[15]".into(),
        year: 2022,
        network: "CNN".into(),
        dataset: "MNIST".into(),
        platform: "Zynq7000".into(),
        lut: 45_986,
        ff: 20_544,
        bram: 262,
        freq_mhz: 200.0,
        gsops: 22.6,
        gsop_per_w: 19.3,
    }
}

/// AICAS 2023 FrameFire [16]: SNN inference for video segmentation
/// (Zynq UltraScale).
pub fn aicas23_row() -> AccelRow {
    AccelRow {
        name: "AICAS[16]".into(),
        year: 2023,
        network: "CNN".into(),
        dataset: "MLND".into(),
        platform: "Zynq Ultra.".into(),
        lut: 41_930,
        ff: 16_237,
        bram: 128,
        freq_mhz: 200.0,
        gsops: 23.2,
        gsop_per_w: 19.3,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::improvement;

    #[test]
    fn paper_improvement_factors_reproduce() {
        // "up to 13.24x throughput": 307.2 / 23.2 (AICAS) = 13.24
        assert!((improvement(307.2, aicas23_row().gsops) - 13.24).abs() < 0.01);
        // "up to 1.33x energy efficiency": 25.6 / 19.3 = 1.326
        assert!((improvement(25.6, tcad22_row().gsop_per_w) - 1.33).abs() < 0.01);
    }

    #[test]
    fn rows_are_distinct() {
        assert_ne!(iscas22_row(), tcad22_row());
        assert_ne!(tcad22_row(), aicas23_row());
    }
}
