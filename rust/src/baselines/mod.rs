//! Baseline accelerators for Table I (substitution #3, DESIGN.md).
//!
//! Two kinds of baseline:
//! * [`published`] — the comparison columns exactly as reported by the
//!   cited papers (ISCAS'22 [14], TCAD'22 Skydiver [15], AICAS'23
//!   FrameFire [16]); these are the numbers Table I compares against.
//! * [`simulated`] — small cycle-level models of the same accelerator
//!   *styles* (event-driven FC, spatio-temporal-balanced CNN) running on
//!   our own hw substrate, used to sanity-check that the published
//!   operating points are consistent with their architectures and to give
//!   the ablation benches a same-framework comparison.
//!
//! The in-datapath baseline (bitmap processing without position encoding)
//! lives in [`crate::accel::DatapathMode::Bitmap`].

pub mod published;
pub mod simulated;

pub use published::{aicas23_row, iscas22_row, tcad22_row};
pub use simulated::{EventDrivenFcModel, SkydiverCnnModel};
