//! Cycle-level models of the baseline accelerator *styles*, on our own hw
//! substrate, so the Table I comparison can also be made within a single
//! framework (ablation benches) rather than only against published numbers.
//!
//! Both models process bitmap spikes (no position encoding — that is the
//! paper's contribution) but are event-driven: they skip zero activations
//! at the cost of a zero-check per position, which is exactly the
//! architecture class [14]-[16] describe.

use crate::hw::{EnergyModel, UnitStats};
use crate::spike::SpikeMatrix;
use crate::util::{div_ceil, Prng};

/// An event-driven fully-connected SNN accelerator in the style of
/// ISCAS'22 [14]: `lanes` parallel accumulators, one weight row per spike.
#[derive(Clone, Debug)]
pub struct EventDrivenFcModel {
    /// Parallel event lanes.
    pub lanes: usize,
    /// Clock frequency, MHz.
    pub freq_mhz: f64,
    /// Layer widths, e.g. [784, 512, 256, 10] for MNIST.
    pub layers: Vec<usize>,
}

impl EventDrivenFcModel {
    /// The ISCAS'22-like operating point.
    pub fn iscas22_like() -> Self {
        Self { lanes: 1280, freq_mhz: 140.0, layers: vec![784, 512, 256, 10] }
    }

    /// Run `timesteps` of one inference with input spike rate `rate`;
    /// hidden-layer rates decay by ~0.5x per layer, which matches reported
    /// MNIST FC sparsities.
    pub fn run(&self, timesteps: usize, rate: f64, seed: u64) -> UnitStats {
        let mut rng = Prng::new(seed);
        let mut stats = UnitStats::default();
        for _t in 0..timesteps {
            let mut r = rate;
            for w in self.layers.windows(2) {
                let (n_in, n_out) = (w[0], w[1]);
                let mut spikes = 0u64;
                for _ in 0..n_in {
                    if rng.bernoulli(r) {
                        spikes += 1;
                    }
                }
                let sops = spikes * n_out as u64;
                stats.sops += sops;
                stats.adds += sops;
                // zero-check every position (bitmap), then event-driven work
                stats.cmps += n_in as u64;
                stats.sram_reads += n_in as u64 + sops;
                stats.sram_writes += n_out as u64;
                stats.cycles += div_ceil(n_in as u64, self.lanes as u64)
                    + div_ceil(sops, self.lanes as u64).max(1);
                // membrane update + fire for the output neurons
                stats.adds += n_out as u64;
                stats.cmps += n_out as u64;
                r *= 0.5;
            }
        }
        stats
    }

    /// Achieved GSOP/s for a run.
    pub fn gsops(&self, stats: &UnitStats) -> f64 {
        let secs = stats.cycles as f64 / (self.freq_mhz * 1e6);
        stats.sops as f64 / secs / 1e9
    }

    /// Achieved GSOP/W for a run.
    pub fn gsop_per_w(&self, stats: &UnitStats, energy: &EnergyModel) -> f64 {
        let secs = stats.cycles as f64 / (self.freq_mhz * 1e6);
        energy.gsop_per_w(stats, secs)
    }
}

/// A Skydiver-style [15] spatio-temporally balanced spiking-CNN
/// accelerator: channel-parallel convolution over bitmap spike maps.
#[derive(Clone, Debug)]
pub struct SkydiverCnnModel {
    /// Dense MAC units.
    pub macs: usize,
    /// Clock frequency, MHz.
    pub freq_mhz: f64,
    /// (c_in, c_out, side) per conv layer, 3x3 kernels.
    pub convs: Vec<(usize, usize, usize)>,
}

impl SkydiverCnnModel {
    /// The Skydiver-like operating point.
    pub fn tcad22_like() -> Self {
        Self {
            macs: 128,
            freq_mhz: 200.0,
            convs: vec![(1, 16, 28), (16, 32, 14), (32, 32, 7)],
        }
    }

    /// Simulate `timesteps` at spike `rate` (seeded).
    pub fn run(&self, timesteps: usize, rate: f64, seed: u64) -> UnitStats {
        let mut rng = Prng::new(seed);
        let mut stats = UnitStats::default();
        for _t in 0..timesteps {
            let mut r = rate;
            for &(c_in, c_out, side) in &self.convs {
                let positions = (c_in * side * side) as u64;
                let mut m = SpikeMatrix::zeros(c_in, side * side);
                for c in 0..c_in {
                    for l in 0..side * side {
                        if rng.bernoulli(r) {
                            m.set(c, l, true);
                        }
                    }
                }
                let spikes = m.count_spikes() as u64;
                let fan_out = (c_out * 9) as u64;
                let sops = spikes * fan_out;
                stats.sops += sops;
                stats.adds += sops;
                stats.cmps += positions;
                stats.sram_reads += positions + sops;
                stats.sram_writes += (c_out * side * side) as u64;
                stats.cycles += div_ceil(positions, self.macs as u64)
                    + div_ceil(sops, self.macs as u64).max(1);
                r *= 0.6;
            }
        }
        stats
    }

    /// Achieved GSOP/s for a run.
    pub fn gsops(&self, stats: &UnitStats) -> f64 {
        let secs = stats.cycles as f64 / (self.freq_mhz * 1e6);
        stats.sops as f64 / secs / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fc_model_order_of_magnitude_matches_published() {
        // ISCAS'22 reports 179 GSOP/s average; the style model should land
        // in the same regime (tens to ~200 GSOP/s), not at our 307.2 peak.
        let m = EventDrivenFcModel::iscas22_like();
        let stats = m.run(4, 0.3, 1);
        let g = m.gsops(&stats);
        assert!(g > 20.0 && g < 250.0, "FC model at {g:.1} GSOP/s");
    }

    #[test]
    fn cnn_model_order_of_magnitude_matches_published() {
        // Skydiver reports 22.6 GSOP/s with 128 MACs at 200 MHz.
        let m = SkydiverCnnModel::tcad22_like();
        let stats = m.run(4, 0.25, 2);
        let g = m.gsops(&stats);
        assert!(g > 5.0 && g < 60.0, "CNN model at {g:.1} GSOP/s");
    }

    #[test]
    fn deterministic_for_seed() {
        let m = EventDrivenFcModel::iscas22_like();
        assert_eq!(m.run(2, 0.3, 9), m.run(2, 0.3, 9));
    }

    #[test]
    fn more_timesteps_more_work() {
        let m = SkydiverCnnModel::tcad22_like();
        let s1 = m.run(1, 0.25, 3);
        let s4 = m.run(4, 0.25, 3);
        assert!(s4.sops > 2 * s1.sops);
        assert!(s4.cycles > 2 * s1.cycles);
    }
}
