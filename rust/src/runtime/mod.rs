//! PJRT runtime: loads the AOT-compiled JAX model (`artifacts/*.hlo.txt`)
//! and executes it on the CPU PJRT client via the `xla` crate. This is the
//! L2/L1 cross-validation path: the same folded weights run (a) here as
//! baked HLO constants and (b) through the rust quantized pipeline, and the
//! float-vs-quantized logits are compared in `examples/cifar_inference`.
//!
//! Interchange is HLO *text* (see `python/compile/aot.py` for why).
//!
//! The `xla` crate is unavailable in the offline build, so the real client
//! is gated behind the `xla` cargo feature; the default build exposes an
//! API-identical stub whose constructor errors (DESIGN.md).

pub mod pjrt;

pub use pjrt::{LoadedHlo, PjrtRuntime};
