//! Thin wrapper over the `xla` crate: CPU PJRT client + compiled
//! executables loaded from HLO text files.
//!
//! The `xla` crate is not vendored in the offline build environment, so
//! the real implementation is gated behind the `xla` cargo feature
//! (DESIGN.md "Dependency gates"). The dependency itself is intentionally
//! undeclared — even an optional dep must resolve at lock time — so
//! enabling the feature also requires adding `xla = "..."` to
//! `[dependencies]` on a machine that can fetch it. The default build
//! ships an API-identical stub whose constructors return a descriptive
//! error; every caller in the repo already treats PJRT availability as
//! optional (artifact-gated tests skip, CLI subcommands report the error).

#[cfg(feature = "xla")]
mod enabled {
    use std::path::Path;

    use anyhow::{Context, Result};

    /// A PJRT client (CPU plugin).
    pub struct PjrtRuntime {
        client: xla::PjRtClient,
    }

    impl PjrtRuntime {
        /// Construct the CPU client.
        pub fn cpu() -> Result<Self> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(Self { client })
        }

        /// Platform name.
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Visible device count.
        pub fn device_count(&self) -> usize {
            self.client.device_count()
        }

        /// Load + compile an HLO text file (as produced by `compile/aot.py`).
        pub fn load_hlo(&self, path: &Path) -> Result<LoadedHlo> {
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?;
            Ok(LoadedHlo { exe })
        }
    }

    /// A compiled executable. The jax side lowers with `return_tuple=True`,
    /// so outputs arrive as a 1-tuple literal.
    pub struct LoadedHlo {
        exe: xla::PjRtLoadedExecutable,
    }

    impl LoadedHlo {
        /// Execute with f32 inputs given as (data, shape) pairs; returns the
        /// flattened f32 outputs of the result tuple.
        pub fn run_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
            let mut literals = Vec::with_capacity(inputs.len());
            for (data, shape) in inputs {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                let lit = xla::Literal::vec1(data)
                    .reshape(&dims)
                    .with_context(|| format!("reshaping input to {shape:?}"))?;
                literals.push(lit);
            }
            let result = self
                .exe
                .execute::<xla::Literal>(&literals)
                .context("executing PJRT computation")?;
            let out = result[0][0].to_literal_sync().context("fetching result")?;
            let tuple = out.to_tuple().context("untupling result")?;
            let mut vecs = Vec::with_capacity(tuple.len());
            for lit in tuple {
                vecs.push(lit.to_vec::<f32>().context("reading f32 output")?);
            }
            Ok(vecs)
        }
    }
}

#[cfg(not(feature = "xla"))]
mod disabled {
    use std::path::Path;

    use anyhow::{bail, Result};

    const UNAVAILABLE: &str =
        "PJRT runtime unavailable: this binary was built without the `xla` cargo feature \
         (the xla crate is not vendored offline — see rust/DESIGN.md)";

    /// Stub PJRT client; construction always fails with a clear message.
    #[derive(Debug)]
    pub struct PjrtRuntime {
        _priv: (),
    }

    impl PjrtRuntime {
        /// Construct the CPU client (stub: errors without the `xla` feature).
        pub fn cpu() -> Result<Self> {
            bail!(UNAVAILABLE)
        }

        /// Platform name.
        pub fn platform(&self) -> String {
            "unavailable".to_string()
        }

        /// Visible device count.
        pub fn device_count(&self) -> usize {
            0
        }

        /// Load and compile an HLO text file.
        pub fn load_hlo(&self, path: &Path) -> Result<LoadedHlo> {
            bail!("cannot load {}: {UNAVAILABLE}", path.display())
        }
    }

    /// Stub executable (never constructible through the stub runtime).
    #[derive(Debug)]
    pub struct LoadedHlo {
        _priv: (),
    }

    impl LoadedHlo {
        /// Execute with f32 inputs, returning f32 outputs.
        pub fn run_f32(&self, _inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
            bail!(UNAVAILABLE)
        }
    }
}

#[cfg(feature = "xla")]
pub use enabled::{LoadedHlo, PjrtRuntime};

#[cfg(not(feature = "xla"))]
pub use disabled::{LoadedHlo, PjrtRuntime};

#[cfg(all(test, feature = "xla"))]
mod tests {
    use super::*;

    fn artifacts() -> Option<std::path::PathBuf> {
        let p = std::path::Path::new("artifacts");
        if p.join("model.hlo.txt").exists() {
            Some(p.to_path_buf())
        } else {
            None
        }
    }

    #[test]
    fn cpu_client_comes_up() {
        let rt = PjrtRuntime::cpu().unwrap();
        assert!(rt.device_count() >= 1);
        assert!(rt.platform().to_lowercase().contains("cpu"));
    }

    #[test]
    fn loads_and_runs_model_hlo() {
        let Some(dir) = artifacts() else { return };
        let rt = PjrtRuntime::cpu().unwrap();
        let model = rt.load_hlo(&dir.join("model.hlo.txt")).unwrap();
        let img = vec![0.1f32; 3 * 32 * 32];
        let outs = model.run_f32(&[(&img, &[1, 3, 32, 32])]).unwrap();
        assert_eq!(outs.len(), 1);
        assert_eq!(outs[0].len(), 10);
        assert!(outs[0].iter().all(|v| v.is_finite()));
    }

    #[test]
    fn sdsa_micro_hlo_matches_semantics() {
        let Some(dir) = artifacts() else { return };
        let rt = PjrtRuntime::cpu().unwrap();
        let sdsa = rt.load_hlo(&dir.join("sdsa.hlo.txt")).unwrap();
        // q == k == single spike per channel -> acc = 1 < vth=2 -> all zero
        let l = 64;
        let c = 64;
        let mut q = vec![0f32; l * c];
        for ch in 0..c {
            q[ch] = 1.0; // token 0 fires in every channel
        }
        let v = vec![1f32; l * c];
        let outs = sdsa
            .run_f32(&[(&q, &[l, c]), (&q, &[l, c]), (&v, &[l, c])])
            .unwrap();
        assert!(outs[0].iter().all(|&x| x == 0.0), "acc=1 < vth=2 must mask all");
        // q == k == two spikes per channel -> acc = 2 >= 2 -> V passes
        let mut q2 = q.clone();
        for ch in 0..c {
            q2[c + ch] = 1.0; // token 1 also fires
        }
        let outs = sdsa
            .run_f32(&[(&q2, &[l, c]), (&q2, &[l, c]), (&v, &[l, c])])
            .unwrap();
        assert!(outs[0].iter().all(|&x| x == 1.0), "acc=2 >= vth=2 must retain V");
    }
}

#[cfg(all(test, not(feature = "xla")))]
mod stub_tests {
    use super::*;

    #[test]
    fn stub_construction_fails_loudly() {
        let err = PjrtRuntime::cpu().unwrap_err();
        assert!(err.to_string().contains("xla"), "{err}");
    }
}
