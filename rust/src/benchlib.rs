//! Minimal self-timed benchmark harness (criterion is unavailable offline):
//! warmup + N timed iterations, median/mean/min reporting, and a tabular
//! printer shared by the bench binaries under `rust/benches/`.

use std::time::Instant;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Case name as printed.
    pub name: String,
    /// Timed iterations.
    pub iters: usize,
    /// Median seconds per iteration.
    pub median_s: f64,
    /// Mean seconds per iteration.
    pub mean_s: f64,
    /// Fastest iteration, seconds.
    pub min_s: f64,
}

impl BenchResult {
    /// One-line tabular rendering.
    pub fn line(&self) -> String {
        format!(
            "{:<40} iters={:<4} median={:>12}  mean={:>12}  min={:>12}",
            self.name,
            self.iters,
            fmt_time(self.median_s),
            fmt_time(self.mean_s),
            fmt_time(self.min_s)
        )
    }
}

/// Human-readable seconds.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Time `f` with `warmup` untimed runs and `iters` timed runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    assert!(iters >= 1);
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = times[times.len() / 2];
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let result = BenchResult {
        name: name.to_string(),
        iters,
        median_s: median,
        mean_s: mean,
        min_s: times[0],
    };
    println!("{}", result.line());
    result
}

/// Section header for bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// A black-box sink preventing the optimizer from deleting benched work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_positive_times() {
        let r = bench("spin", 1, 5, || {
            let mut acc = 0u64;
            for i in 0..1000 {
                acc = acc.wrapping_add(black_box(i));
            }
            black_box(acc);
        });
        assert!(r.median_s > 0.0);
        assert!(r.min_s <= r.median_s);
        assert_eq!(r.iters, 5);
    }

    #[test]
    fn fmt_time_ranges() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with(" ms"));
        assert!(fmt_time(2e-6).ends_with(" us"));
        assert!(fmt_time(2e-9).ends_with(" ns"));
    }
}
