//! Minimal self-timed benchmark harness (criterion is unavailable offline):
//! warmup + N timed iterations, median/mean/min reporting, and a tabular
//! printer shared by the bench binaries under `rust/benches/`.

use std::time::Instant;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Case name as printed.
    pub name: String,
    /// Timed iterations.
    pub iters: usize,
    /// Median seconds per iteration.
    pub median_s: f64,
    /// Mean seconds per iteration.
    pub mean_s: f64,
    /// Fastest iteration, seconds.
    pub min_s: f64,
}

impl BenchResult {
    /// One-line tabular rendering.
    pub fn line(&self) -> String {
        format!(
            "{:<40} iters={:<4} median={:>12}  mean={:>12}  min={:>12}",
            self.name,
            self.iters,
            fmt_time(self.median_s),
            fmt_time(self.mean_s),
            fmt_time(self.min_s)
        )
    }
}

/// Human-readable seconds.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Time `f` with `warmup` untimed runs and `iters` timed runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    assert!(iters >= 1);
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = times[times.len() / 2];
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let result = BenchResult {
        name: name.to_string(),
        iters,
        median_s: median,
        mean_s: mean,
        min_s: times[0],
    };
    println!("{}", result.line());
    result
}

/// Section header for bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// A black-box sink preventing the optimizer from deleting benched work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Value of `--key N` in a raw argument list (`None` when the flag is
/// absent or its value fails to parse). Shared by the bench binaries and
/// examples for the `--workers N` pool-sizing knob.
pub fn arg_value(args: &[String], key: &str) -> Option<usize> {
    args.iter()
        .position(|a| a == key)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
}

/// String value of `--key VALUE` in a raw argument list (`None` when the
/// flag is absent). Used for the `--mapping POLICY` topology knob.
pub fn arg_str(args: &[String], key: &str) -> Option<String> {
    args.iter().position(|a| a == key).and_then(|i| args.get(i + 1)).cloned()
}

/// Apply the shared bench topology/memory flags to `hw` and parse the
/// mapping policy: `--sdeb-cores N` and `--pipeline-depth N` override
/// `hw.topology`, `--dram-bw N|max` overrides the external-memory bus
/// bandwidth (`max` = the unlimited-bandwidth idealization), and the
/// combined config is validated. `--mapping POLICY` selects the SDSA
/// head→core policy. Panics on invalid values — bench binaries fail loud
/// rather than sweeping a config they did not ask for. (The CLI has a
/// `Result`-returning equivalent in `main.rs`.)
pub fn apply_topology_args(
    args: &[String],
    hw: &mut crate::hw::AccelConfig,
) -> crate::accel::MappingPolicy {
    if let Some(cores) = arg_value(args, "--sdeb-cores") {
        hw.topology.sdeb_cores = cores;
    }
    if let Some(depth) = arg_value(args, "--pipeline-depth") {
        hw.topology.pipeline_depth = depth;
    }
    if let Some(bw) = arg_str(args, "--dram-bw") {
        hw.dram_bytes_per_cycle = if bw == "max" {
            usize::MAX
        } else {
            bw.parse().expect("bad --dram-bw value")
        };
    }
    hw.validate().expect("bad --sdeb-cores/--pipeline-depth/--dram-bw config");
    arg_str(args, "--mapping")
        .map(|p| p.parse().expect("bad --mapping policy"))
        .unwrap_or_default()
}

/// Parse the top level of a JSON object into `(key, raw value text)`
/// pairs, preserving order. Both keys and values are kept verbatim —
/// escape sequences are not interpreted, so entries round-trip
/// byte-exactly through [`merge_bench_json`]; only the top-level
/// structure is interpreted. Returns `None` for anything that isn't a
/// well-formed object — callers then start a fresh file. ASCII-oriented
/// (the bench writers only emit ASCII).
pub fn parse_json_object(text: &str) -> Option<Vec<(String, String)>> {
    let t = text.trim();
    if !t.starts_with('{') || !t.ends_with('}') {
        return None;
    }
    let inner = &t[1..t.len() - 1];
    let bytes = inner.as_bytes();
    let mut out = Vec::new();
    let mut i = 0usize;
    loop {
        while i < bytes.len() && (bytes[i].is_ascii_whitespace() || bytes[i] == b',') {
            i += 1;
        }
        if i >= bytes.len() {
            break;
        }
        if bytes[i] != b'"' {
            return None;
        }
        let (key, next) = scan_json_string(inner, i)?;
        i = next;
        while i < bytes.len() && bytes[i].is_ascii_whitespace() {
            i += 1;
        }
        if i >= bytes.len() || bytes[i] != b':' {
            return None;
        }
        i += 1;
        while i < bytes.len() && bytes[i].is_ascii_whitespace() {
            i += 1;
        }
        let start = i;
        let mut depth = 0i64;
        while i < bytes.len() {
            match bytes[i] {
                b'"' => {
                    let (_, next) = scan_json_string(inner, i)?;
                    i = next;
                    continue;
                }
                b'{' | b'[' => depth += 1,
                b'}' | b']' => {
                    depth -= 1;
                    if depth < 0 {
                        return None;
                    }
                }
                b',' if depth == 0 => break,
                _ => {}
            }
            i += 1;
        }
        if depth != 0 || i == start {
            return None;
        }
        out.push((key, inner[start..i].trim().to_string()));
    }
    Some(out)
}

/// Scan one double-quoted JSON string starting at `start` (which must be
/// the opening quote); returns the content **verbatim** (escape sequences
/// preserved, not interpreted — keys round-trip byte-exactly through the
/// merger) and the index just past the closing quote.
fn scan_json_string(s: &str, start: usize) -> Option<(String, usize)> {
    let bytes = s.as_bytes();
    if bytes.get(start) != Some(&b'"') {
        return None;
    }
    let mut i = start + 1;
    while i < bytes.len() {
        match bytes[i] {
            b'\\' => {
                bytes.get(i + 1)?;
                i += 2;
            }
            b'"' => return Some((s[start + 1..i].to_string(), i + 1)),
            _ => i += 1,
        }
    }
    None
}

/// Merge `entry_json` (one section's raw JSON value) under `key` into the
/// top-level object stored at `path`, preserving every other key — bench
/// `--json` writers extend `BENCH_*.json` files instead of clobbering
/// each other's sections. A missing or malformed file starts fresh.
///
/// Keys are matched and re-emitted **verbatim** (escape sequences in
/// existing files are preserved byte-exactly, never re-encoded); the
/// caller-supplied `key` must therefore contain no characters needing
/// JSON escaping (`"` or `\`) — the bench writers use plain ASCII names.
pub fn merge_bench_json(path: &str, key: &str, entry_json: &str) -> std::io::Result<()> {
    debug_assert!(!key.contains(['"', '\\']), "bench section keys must not need escaping");
    let existing = std::fs::read_to_string(path).unwrap_or_default();
    let mut entries = parse_json_object(&existing).unwrap_or_default();
    let trimmed = entry_json.trim().to_string();
    if let Some(slot) = entries.iter_mut().find(|(k, _)| k == key) {
        slot.1 = trimmed;
    } else {
        entries.push((key.to_string(), trimmed));
    }
    let mut out = String::from("{\n");
    for (i, (k, v)) in entries.iter().enumerate() {
        out.push_str(&format!(
            "  \"{k}\": {v}{}\n",
            if i + 1 == entries.len() { "" } else { "," }
        ));
    }
    out.push_str("}\n");
    std::fs::write(path, out)
}

/// Open-loop arrival process for load benches and the CLI `serve`
/// command: where request *offsets* (seconds from session start) come
/// from. Always seeded/explicit, so a given spec replays bit-identically.
#[derive(Clone, Debug, PartialEq)]
pub enum ArrivalSpec {
    /// Poisson process at `rate_rps` requests per second (seeded
    /// exponential inter-arrival gaps).
    Poisson {
        /// Mean arrival rate, requests per second.
        rate_rps: f64,
    },
    /// `burst` simultaneous requests every `period_s` seconds — the
    /// worst case for a release-a-batch-and-wait scheduler.
    Burst {
        /// Requests per burst.
        burst: usize,
        /// Seconds between bursts.
        period_s: f64,
    },
    /// Explicit offsets (seconds, one per request), e.g. replayed from a
    /// production trace file. Wraps around if shorter than the request
    /// count, shifting each wrap by the trace's span.
    Trace(Vec<f64>),
}

impl ArrivalSpec {
    /// Parse `poisson:RATE`, `burst:N:PERIOD_S`, or `trace:FILE` (one
    /// float offset per line; `#` comments and blank lines ignored).
    pub fn parse(s: &str) -> Result<ArrivalSpec, String> {
        if let Some(rate) = s.strip_prefix("poisson:") {
            let rate_rps: f64 =
                rate.parse().map_err(|e| format!("bad poisson rate `{rate}`: {e}"))?;
            if !(rate_rps.is_finite() && rate_rps > 0.0) {
                return Err(format!("poisson rate must be positive, got `{rate}`"));
            }
            return Ok(ArrivalSpec::Poisson { rate_rps });
        }
        if let Some(rest) = s.strip_prefix("burst:") {
            let (n, period) = rest
                .split_once(':')
                .ok_or_else(|| format!("burst spec `{rest}` needs N:PERIOD_S"))?;
            let burst: usize = n.parse().map_err(|e| format!("bad burst size `{n}`: {e}"))?;
            let period_s: f64 =
                period.parse().map_err(|e| format!("bad burst period `{period}`: {e}"))?;
            if burst == 0 || !(period_s.is_finite() && period_s >= 0.0) {
                return Err(format!("burst spec `{rest}` needs N >= 1 and PERIOD_S >= 0"));
            }
            return Ok(ArrivalSpec::Burst { burst, period_s });
        }
        if let Some(file) = s.strip_prefix("trace:") {
            let text = std::fs::read_to_string(file)
                .map_err(|e| format!("cannot read trace `{file}`: {e}"))?;
            let mut offsets: Vec<f64> = Vec::new();
            for (ln, line) in text.lines().enumerate() {
                let line = line.trim();
                if line.is_empty() || line.starts_with('#') {
                    continue;
                }
                let v: f64 = line
                    .parse()
                    .map_err(|e| format!("trace `{file}` line {}: {e}", ln + 1))?;
                if !(v.is_finite() && v >= 0.0) {
                    return Err(format!("trace `{file}` line {}: offsets must be >= 0", ln + 1));
                }
                // The wrap logic in `arrival_offsets` shifts each lap by
                // the *last* offset, which is only the trace's span when
                // offsets are sorted — refuse out-of-order timestamps.
                if offsets.last().is_some_and(|&prev| v < prev) {
                    return Err(format!(
                        "trace `{file}` line {}: offsets must be non-decreasing ({v} after {})",
                        ln + 1,
                        offsets.last().unwrap()
                    ));
                }
                offsets.push(v);
            }
            if offsets.is_empty() {
                return Err(format!("trace `{file}` has no offsets"));
            }
            return Ok(ArrivalSpec::Trace(offsets));
        }
        Err(format!("unknown arrival spec `{s}` (poisson:RATE | burst:N:PERIOD_S | trace:FILE)"))
    }
}

/// Generate `n` non-decreasing arrival offsets (seconds from session
/// start) for a spec. Deterministic in `(spec, n, seed)`.
pub fn arrival_offsets(spec: &ArrivalSpec, n: usize, seed: u64) -> Vec<f64> {
    match spec {
        ArrivalSpec::Poisson { rate_rps } => {
            let rate = *rate_rps;
            let mut rng = crate::util::Prng::new(seed);
            let mut t = 0.0f64;
            (0..n)
                .map(|_| {
                    // Exponential inter-arrival gap via inverse CDF;
                    // 1 - u is in (0, 1] so the log is always finite.
                    let u = rng.next_f64();
                    t += -(1.0 - u).ln() / rate;
                    t
                })
                .collect()
        }
        ArrivalSpec::Burst { burst, period_s } => {
            let (burst, period) = ((*burst).max(1), *period_s);
            (0..n).map(|i| (i / burst) as f64 * period).collect()
        }
        ArrivalSpec::Trace(offsets) => {
            // Wrap: repeat the trace shifted by its span per lap.
            let span = offsets.last().copied().unwrap_or(0.0);
            (0..n)
                .map(|i| {
                    let lap = i / offsets.len();
                    offsets[i % offsets.len()] + lap as f64 * span
                })
                .collect()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_positive_times() {
        let r = bench("spin", 1, 5, || {
            let mut acc = 0u64;
            for i in 0..1000 {
                acc = acc.wrapping_add(black_box(i));
            }
            black_box(acc);
        });
        assert!(r.median_s > 0.0);
        assert!(r.min_s <= r.median_s);
        assert_eq!(r.iters, 5);
    }

    #[test]
    fn fmt_time_ranges() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with(" ms"));
        assert!(fmt_time(2e-6).ends_with(" us"));
        assert!(fmt_time(2e-9).ends_with(" ns"));
    }

    #[test]
    fn parse_json_object_roundtrips_sections() {
        let text = r#"{
  "alpha": {"x": 1, "list": [1, 2, {"y": "a,b"}]},
  "beta": [3, 4],
  "gamma": "str, with: punctuation}"
}"#;
        let entries = parse_json_object(text).unwrap();
        assert_eq!(entries.len(), 3);
        assert_eq!(entries[0].0, "alpha");
        assert_eq!(entries[0].1, r#"{"x": 1, "list": [1, 2, {"y": "a,b"}]}"#);
        assert_eq!(entries[1], ("beta".to_string(), "[3, 4]".to_string()));
        assert_eq!(entries[2].1, r#""str, with: punctuation}""#);
    }

    #[test]
    fn parse_json_object_rejects_malformed() {
        assert!(parse_json_object("").is_none());
        assert!(parse_json_object("not json").is_none());
        assert!(parse_json_object(r#"{"unterminated": "#).is_none());
        assert!(parse_json_object(r#"{"bad": ]}"#).is_none());
        assert_eq!(parse_json_object("{}").unwrap().len(), 0);
    }

    #[test]
    fn escaped_keys_round_trip_verbatim() {
        let text = "{\n  \"with \\\"quote\\\" and \\n escape\": 1,\n  \"plain\": 2\n}";
        let entries = parse_json_object(text).unwrap();
        assert_eq!(entries[0].0, "with \\\"quote\\\" and \\n escape");
        assert_eq!(entries[0].1, "1");
        // Re-emitting (as merge_bench_json does) reproduces the key
        // byte-exactly, so escapes are never corrupted.
        let emitted = format!("\"{}\"", entries[0].0);
        assert_eq!(emitted, "\"with \\\"quote\\\" and \\n escape\"");
    }

    #[test]
    fn merge_bench_json_updates_one_key_and_keeps_the_rest() {
        let dir = std::env::temp_dir().join(format!(
            "benchlib_merge_test_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_test.json");
        let path = path.to_str().unwrap();
        merge_bench_json(path, "first", r#"{"v": 1}"#).unwrap();
        merge_bench_json(path, "second", "[1, 2]").unwrap();
        merge_bench_json(path, "first", r#"{"v": 2}"#).unwrap();
        let entries = parse_json_object(&std::fs::read_to_string(path).unwrap()).unwrap();
        assert_eq!(
            entries,
            vec![
                ("first".to_string(), r#"{"v": 2}"#.to_string()),
                ("second".to_string(), "[1, 2]".to_string()),
            ]
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn poisson_arrivals_are_seeded_monotone_and_near_rate() {
        let spec = ArrivalSpec::parse("poisson:100").unwrap();
        let a = arrival_offsets(&spec, 2000, 7);
        let b = arrival_offsets(&spec, 2000, 7);
        assert_eq!(a, b, "same seed must replay bit-identically");
        let c = arrival_offsets(&spec, 2000, 8);
        assert_ne!(a, c, "different seed must differ");
        assert!(a.windows(2).all(|w| w[0] <= w[1]), "offsets are non-decreasing");
        // 2000 arrivals at 100 rps should span ~20 s; allow wide slack.
        let span = *a.last().unwrap();
        assert!((15.0..25.0).contains(&span), "poisson span {span} far from 20 s");
    }

    #[test]
    fn burst_arrivals_group_exactly() {
        let spec = ArrivalSpec::parse("burst:4:0.5").unwrap();
        let offs = arrival_offsets(&spec, 10, 0);
        assert_eq!(
            offs,
            vec![0.0, 0.0, 0.0, 0.0, 0.5, 0.5, 0.5, 0.5, 1.0, 1.0],
            "4-wide bursts every 0.5 s"
        );
    }

    #[test]
    fn trace_arrivals_wrap_with_span_shift() {
        let spec = ArrivalSpec::Trace(vec![0.0, 0.1, 0.4]);
        let offs = arrival_offsets(&spec, 5, 0);
        assert_eq!(offs, vec![0.0, 0.1, 0.4, 0.4, 0.5], "second lap shifts by the 0.4 s span");
    }

    #[test]
    fn trace_files_parse_with_comments() {
        let dir = std::env::temp_dir()
            .join(format!("benchlib_trace_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.txt");
        std::fs::write(&path, "# offsets\n0.0\n\n0.25\n1.5\n").unwrap();
        let spec = ArrivalSpec::parse(&format!("trace:{}", path.display())).unwrap();
        assert_eq!(spec, ArrivalSpec::Trace(vec![0.0, 0.25, 1.5]));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn trace_file_rejects_empty_and_non_monotone() {
        let dir = std::env::temp_dir()
            .join(format!("benchlib_trace_edge_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let empty = dir.join("empty.txt");
        std::fs::write(&empty, "").unwrap();
        let err = ArrivalSpec::parse(&format!("trace:{}", empty.display())).unwrap_err();
        assert!(err.contains("no offsets"), "empty file: {err}");
        let comments = dir.join("comments.txt");
        std::fs::write(&comments, "# only\n\n# comments\n").unwrap();
        let err = ArrivalSpec::parse(&format!("trace:{}", comments.display())).unwrap_err();
        assert!(err.contains("no offsets"), "comments-only file: {err}");
        let unsorted = dir.join("unsorted.txt");
        std::fs::write(&unsorted, "0.0\n2.0\n1.0\n").unwrap();
        let err = ArrivalSpec::parse(&format!("trace:{}", unsorted.display())).unwrap_err();
        assert!(
            err.contains("non-decreasing") && err.contains("line 3"),
            "out-of-order timestamps must name the offending line: {err}"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn trace_file_trailing_newline_and_huge_gaps_parse() {
        let dir = std::env::temp_dir()
            .join(format!("benchlib_trace_edge2_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        // Trailing newline (and no trailing newline) parse identically.
        let a = dir.join("nl.txt");
        std::fs::write(&a, "0.0\n0.5\n").unwrap();
        let b = dir.join("nonl.txt");
        std::fs::write(&b, "0.0\n0.5").unwrap();
        let sa = ArrivalSpec::parse(&format!("trace:{}", a.display())).unwrap();
        let sb = ArrivalSpec::parse(&format!("trace:{}", b.display())).unwrap();
        assert_eq!(sa, sb);
        assert_eq!(sa, ArrivalSpec::Trace(vec![0.0, 0.5]));
        // Huge but finite gaps are legal; the wrap shifts by the span.
        let big = dir.join("big.txt");
        std::fs::write(&big, "0.0\n1e6\n").unwrap();
        let spec = ArrivalSpec::parse(&format!("trace:{}", big.display())).unwrap();
        let offs = arrival_offsets(&spec, 4, 0);
        assert_eq!(offs, vec![0.0, 1e6, 1e6, 2e6]);
        assert!(offs.windows(2).all(|w| w[0] <= w[1]));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn arrival_spec_rejects_malformed() {
        assert!(ArrivalSpec::parse("poisson:0").is_err());
        assert!(ArrivalSpec::parse("poisson:abc").is_err());
        assert!(ArrivalSpec::parse("burst:0:1.0").is_err());
        assert!(ArrivalSpec::parse("burst:4").is_err());
        assert!(ArrivalSpec::parse("trace:/no/such/file").is_err());
        assert!(ArrivalSpec::parse("uniform:5").is_err());
    }
}
