//! Table I row type and formatter: "COMPARISON WITH OTHER SNN ACCELERATORS".

/// One column of Table I.
#[derive(Clone, Debug, PartialEq)]
pub struct AccelRow {
    /// Accelerator name.
    pub name: String,
    /// Publication year.
    pub year: u32,
    /// Workload network family.
    pub network: String,
    /// Evaluation dataset.
    pub dataset: String,
    /// FPGA platform.
    pub platform: String,
    /// LUT usage.
    pub lut: u64,
    /// Flip-flop usage.
    pub ff: u64,
    /// BRAM usage.
    pub bram: u64,
    /// Clock frequency, MHz.
    pub freq_mhz: f64,
    /// Peak throughput, GSOP/s.
    pub gsops: f64,
    /// Peak efficiency, GSOP/W.
    pub gsop_per_w: f64,
}

/// Render rows in the paper's Table I layout (metrics as rows, designs as
/// columns).
pub fn format_table1(rows: &[AccelRow]) -> String {
    let mut out = String::new();
    let headers: Vec<String> = rows.iter().map(|r| r.name.clone()).collect();
    let field = |label: &str, vals: Vec<String>| {
        let mut line = format!("{label:<12}");
        for v in vals {
            line.push_str(&format!("{v:>16}"));
        }
        line.push('\n');
        line
    };
    out.push_str(&field("", headers));
    out.push_str(&field("Year", rows.iter().map(|r| r.year.to_string()).collect()));
    out.push_str(&field("Network", rows.iter().map(|r| r.network.clone()).collect()));
    out.push_str(&field("Dataset", rows.iter().map(|r| r.dataset.clone()).collect()));
    out.push_str(&field("Platform", rows.iter().map(|r| r.platform.clone()).collect()));
    out.push_str(&field("LUT", rows.iter().map(|r| r.lut.to_string()).collect()));
    out.push_str(&field("FF", rows.iter().map(|r| r.ff.to_string()).collect()));
    out.push_str(&field("BRAM", rows.iter().map(|r| r.bram.to_string()).collect()));
    out.push_str(&field(
        "Freq.(MHz)",
        rows.iter().map(|r| format!("{:.0}", r.freq_mhz)).collect(),
    ));
    out.push_str(&field("GSOP/s", rows.iter().map(|r| format!("{:.1}", r.gsops)).collect()));
    out.push_str(&field(
        "GSOP/W",
        rows.iter().map(|r| format!("{:.2}", r.gsop_per_w)).collect(),
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn formats_all_fields() {
        let row = AccelRow {
            name: "Ours".into(),
            year: 2024,
            network: "Trans.".into(),
            dataset: "Cifar-10".into(),
            platform: "Virtex Ultra.".into(),
            lut: 453_266,
            ff: 94_120,
            bram: 784,
            freq_mhz: 200.0,
            gsops: 307.2,
            gsop_per_w: 25.6,
        };
        let t = format_table1(&[row]);
        for needle in ["Ours", "453266", "94120", "784", "200", "307.2", "25.60"] {
            assert!(t.contains(needle), "missing {needle} in\n{t}");
        }
    }
}
