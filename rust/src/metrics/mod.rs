//! Metrics & reporting: the Table I row type, table formatting, and
//! derived-quantity helpers shared by the benches.

pub mod table;

pub use table::{format_table1, AccelRow};

/// GSOP/s from a SOP count and modelled seconds.
pub fn gsops(sops: u64, seconds: f64) -> f64 {
    if seconds <= 0.0 {
        return 0.0;
    }
    sops as f64 / seconds / 1e9
}

/// Improvement factor a/b with guards.
pub fn improvement(a: f64, b: f64) -> f64 {
    if b <= 0.0 {
        return 0.0;
    }
    a / b
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gsops_math() {
        assert!((gsops(307_200_000_000, 1.0) - 307.2).abs() < 1e-9);
        assert_eq!(gsops(10, 0.0), 0.0);
    }

    #[test]
    fn improvement_factor() {
        assert!((improvement(307.2, 23.2) - 13.24).abs() < 0.01);
        assert!((improvement(25.6, 19.3) - 1.33).abs() < 0.01);
    }
}
