//! Hand-rolled CLI argument parsing (clap is unavailable offline): a
//! subcommand plus `--key value` / `--flag` options.

use std::collections::HashMap;

use anyhow::{bail, Result};

#[derive(Clone, Debug, Default)]
/// Parsed command line: a subcommand plus options and flags.
pub struct Args {
    /// The subcommand (first positional).
    pub command: String,
    /// `--key value` pairs.
    pub options: HashMap<String, String>,
    /// Bare `--flag` switches.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Self> {
        let mut it = argv.into_iter().peekable();
        let command = it.next().unwrap_or_else(|| "help".to_string());
        let mut options = HashMap::new();
        let mut flags = Vec::new();
        while let Some(arg) = it.next() {
            if let Some(key) = arg.strip_prefix("--") {
                match it.peek() {
                    Some(v) if !v.starts_with("--") => {
                        options.insert(key.to_string(), it.next().unwrap());
                    }
                    _ => flags.push(key.to_string()),
                }
            } else {
                bail!("unexpected positional argument `{arg}`");
            }
        }
        Ok(Self { command, options, flags })
    }

    /// Parse from the process arguments.
    pub fn from_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    /// Look up an option value.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    /// Option value, or a default when absent.
    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Option parsed as `usize`, or a default when absent.
    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    /// Was the bare flag passed?
    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

/// The `help` text.
pub const USAGE: &str = "\
sdt-accel — sparse accelerator for the Spike-driven Transformer

USAGE: sdt-accel <COMMAND> [OPTIONS]

COMMANDS:
  run        single inference on the cycle simulator (random or trained model)
             --weights DIR   use trained artifacts (default artifacts/weights)
             --config tiny|paper   model scale with random weights
             --seed N        image seed
             --workers N     size of the persistent SDEB worker pool
                             (default: sized to the topology)
             --sdeb-cores N  SDEB cores in the topology (default 2, the
                             paper's Fig. 1 instance)
             --pipeline-depth N   ESS buffer-ring depth (default 2 = ping/pong)
             --mapping P     SDSA head->core policy: round-robin |
                             block-affinity | load-balanced
             --dram-bw N     external-memory bus bytes/cycle (default 16,
                             the paper's interface; `max` = unlimited —
                             weight streaming can never stall)
             --engine E      spike datapath engine: csr | bitmap |
                             adaptive (per-tensor density pick; values are
                             bit-identical across engines)
             --engine-threshold X   adaptive crossover density in [0,1]
                             (implies --engine adaptive; default 0.02)
             --temporal-delta   charge the SDEB input load with only the
                             addresses that changed since the previous
                             timestep (per-channel XOR delta vs full
                             re-store; values stay bit-identical)
             --serial        charge phases serially instead of executing
                             the overlapped core pipeline (ablation; no
                             memory lane)
             --decode        autoregressive decode session instead of a
                             vision inference: prefill a random prompt,
                             then greedy generation over the spike-stream
                             KV cache (reports TTFT / inter-token latency
                             / tokens/s; logits bit-identical to full
                             recompute)
             --config tiny-decoder|paper-decoder   decoder model scale
                             (decode mode only; default tiny-decoder)
             --prompt-len N  prompt tokens to prefill (default 8)
             --gen-len N     tokens to generate (default 8)
  accuracy   held-out accuracy: quantized simulator vs float PJRT model
             --weights DIR   --limit N
  table1     regenerate Table I (comparison with SNN accelerators)
  fig6       regenerate Fig. 6 (module sparsity)
             --weights DIR   --limit N
  serve      batched serving demo through the coordinator
             --workers N --requests N --backend sim|golden|pjrt --batch N
             --continuous    continuous in-flight batching: workers refill
                             drained lanes between stage passes instead of
                             waiting for a whole batch to finish
             --lanes N       per-worker in-flight lane cap (default 4;
                             continuous mode only)
             --fleet L1,L2,..   heterogeneous sim fleet: one worker per
                             lane count, speed-aware dispatch (overrides
                             --workers; sim backend only)
             --arrival S     open-loop arrivals: poisson:RATE |
                             burst:N:PERIOD_S | trace:FILE (one offset per
                             line); default submits every request at once
             --admission N   bounded admission queue: a push over capacity
                             sheds the oldest lowest-class request
             --priority-split F   fraction of traffic in the High class
                             (and the same fraction Low); seeded draws
             --slo MS        latency SLO for per-class attainment reports
                             (also the deadline on High requests)
             --seed N        arrival + priority draw seed
             --pool-workers N   per-simulator SDEB worker pool size
             --sdeb-cores N --mapping P   topology/mapping of sim workers
             --dram-bw N     sim workers' bus bytes/cycle (or `max`)
             --engine E --engine-threshold X   sim workers' spike engine
             --temporal-delta   delta-charge sim workers' SDEB input loads
             --serial        serial-charging simulator workers (ablation)
  sweep      lane-count x SDEB-core-count parallelism sweep (ablation A2)
  help       this message
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn parses_command_options_flags() {
        let a = parse(&["serve", "--workers", "4", "--verbose", "--batch", "8"]);
        assert_eq!(a.command, "serve");
        assert_eq!(a.get("workers"), Some("4"));
        assert_eq!(a.usize_or("batch", 1).unwrap(), 8);
        assert!(a.has_flag("verbose"));
        assert!(!a.has_flag("quiet"));
    }

    #[test]
    fn defaults_when_missing() {
        let a = parse(&["run"]);
        assert_eq!(a.get_or("config", "tiny"), "tiny");
        assert_eq!(a.usize_or("seed", 7).unwrap(), 7);
    }

    #[test]
    fn rejects_positional() {
        assert!(Args::parse(["run".to_string(), "oops".to_string()]).is_err());
    }

    #[test]
    fn empty_means_help() {
        let a = Args::parse(Vec::<String>::new()).unwrap();
        assert_eq!(a.command, "help");
    }
}
