//! Deterministic xoshiro256** PRNG.
//!
//! The `rand` crate is unavailable offline; tests, workload generators and
//! the property harness all need reproducible randomness, so we carry a
//! small, well-known generator ourselves.

#[derive(Clone, Debug)]
/// Deterministic 64-bit PRNG (reproducible across platforms; no external crates).
pub struct Prng {
    s: [u64; 4],
}

impl Prng {
    /// Seeded generator.
    pub fn new(seed: u64) -> Self {
        // splitmix64 expansion of the seed, as recommended by the authors.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    /// Next raw 64-bit draw.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [-1, 1).
    pub fn next_f32_signed(&mut self) -> f32 {
        (self.next_f64() * 2.0 - 1.0) as f32
    }

    /// Uniform integer in [lo, hi) (hi > lo).
    pub fn gen_range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + (self.next_u64() % (hi - lo) as u64) as usize
    }

    /// Bernoulli(p) spike.
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box-Muller (one value per call, unpaired).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Prng::new(7);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn bernoulli_rate_close() {
        let mut r = Prng::new(11);
        let n = 20_000;
        let hits = (0..n).filter(|_| r.bernoulli(0.3)).count();
        let rate = hits as f64 / n as f64;
        assert!((rate - 0.3).abs() < 0.02, "rate={rate}");
    }

    #[test]
    fn gen_range_bounds() {
        let mut r = Prng::new(3);
        for _ in 0..1000 {
            let v = r.gen_range(5, 17);
            assert!((5..17).contains(&v));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Prng::new(5);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }
}
