//! Minimal property-testing harness (the `proptest` crate is unavailable
//! offline). A property is a closure over a [`Prng`]; the harness runs it
//! for `cases` seeds and, on failure, retries with a fixed seed schedule to
//! report the smallest failing seed — enough for the coordinator/unit
//! invariants this repo checks (routing, batching, encoding round-trips).

use super::prng::Prng;

/// Run `prop` for `cases` deterministic seeds; panic with the failing seed.
pub fn check<F: Fn(&mut Prng) -> Result<(), String>>(name: &str, cases: u64, prop: F) {
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Prng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property `{name}` failed (case {case}, seed {seed:#x}): {msg}");
        }
    }
}

/// Assert-style helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

/// Assert equality with a readable message.
#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!(
                "{} != {} ({:?} vs {:?})",
                stringify!($a),
                stringify!($b),
                a,
                b
            ));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("sum_commutes", 32, |rng| {
            let a = rng.gen_range(0, 100) as u64;
            let b = rng.gen_range(0, 100) as u64;
            prop_assert!(a + b == b + a, "{a}+{b}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property `always_fails` failed")]
    fn failing_property_reports_seed() {
        check("always_fails", 4, |_| Err("nope".into()));
    }
}
