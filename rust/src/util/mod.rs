//! Small shared utilities: a deterministic PRNG (no `rand` offline), a
//! minimal property-testing harness (no `proptest` offline), and math
//! helpers used across the simulator.

pub mod prng;
pub mod proptest;
pub mod sync;

pub use prng::Prng;

/// Ceiling division for scheduling math (`ops / lanes` rounded up).
#[inline]
pub fn div_ceil(a: u64, b: u64) -> u64 {
    debug_assert!(b > 0);
    // Overflow-safe form: `(a + b - 1)` wraps when `b` is huge (e.g. the
    // unlimited-bandwidth bus, `usize::MAX` bytes/cycle).
    if a == 0 {
        0
    } else {
        1 + (a - 1) / b
    }
}

/// Mean of an f64 slice; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// p-th percentile (0..=100) of a slice, nearest-rank on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn div_ceil_rounds_up() {
        assert_eq!(div_ceil(10, 3), 4);
        assert_eq!(div_ceil(9, 3), 3);
        assert_eq!(div_ceil(1, 1536), 1);
        assert_eq!(div_ceil(0, 4), 0);
        // No overflow at the unlimited-bandwidth extreme.
        assert_eq!(div_ceil(6144, u64::MAX), 1);
        assert_eq!(div_ceil(u64::MAX, 1), u64::MAX);
    }

    #[test]
    fn mean_and_percentile() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(mean(&[]), 0.0);
    }
}
