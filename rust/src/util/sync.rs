//! Synchronization shim: `std` primitives normally, `loom` under `--cfg loom`.
//!
//! The concurrency core (the caller-helping [`WorkerPool`], the overlapped
//! executor's producer/consumer handoff, and the ESS ping/pong ring model)
//! imports `Arc`/`Mutex`/`Condvar`/atomics/`thread` from this module instead
//! of `std::sync` directly. A normal build resolves every name to `std`, so
//! the shim compiles to nothing. A build with `RUSTFLAGS="--cfg loom"`
//! resolves them to [loom](https://docs.rs/loom)'s permutation-testing
//! doubles, which lets `rust/tests/loom_sync.rs` exhaustively explore thread
//! interleavings of the scoped spawn / `drain_and_wait` protocol and the
//! ring's release/acquire ordering.
//!
//! `loom` is **not** declared in `Cargo.toml` — like the `xla` gate
//! documented there, even an optional dependency must resolve at lock time,
//! which would break the offline build. The loom CI job adds it on a
//! networked machine first:
//!
//! ```text
//! cargo add loom@0.7 --package spikeformer_accel --target 'cfg(loom)'
//! RUSTFLAGS="--cfg loom" LOOM_MAX_PREEMPTIONS=3 \
//!     cargo test --release --test loom_sync
//! ```
//!
//! A `--target 'cfg(loom)'` dependency never resolves for real targets, so
//! the normal build/test matrix is unaffected even after `cargo add`.
//!
//! Two deliberate asymmetries:
//!
//! * **`mpsc` is always `std`.** loom does not model channels; the executor's
//!   bounded-channel handoff is model-checked through the equivalent
//!   [`SlotRing`](crate::accel::buffers::SlotRing) primitive instead.
//! * **Poison handling is identical.** loom's `Mutex::lock` returns the same
//!   `LockResult` shape as `std`, so callers need no `cfg` of their own.
//!
//! [`WorkerPool`]: crate::accel::WorkerPool

#[cfg(not(loom))]
pub use std::sync::{Arc, Condvar, Mutex, MutexGuard};

#[cfg(loom)]
pub use loom::sync::{Arc, Condvar, Mutex, MutexGuard};

/// Atomic integer types and memory orderings (std or loom doubles).
pub mod atomic {
    #[cfg(not(loom))]
    pub use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};

    #[cfg(loom)]
    pub use loom::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
}

/// Thread spawning and handles (std or loom's model-checked scheduler).
pub mod thread {
    #[cfg(not(loom))]
    pub use std::thread::{spawn, yield_now, JoinHandle};

    #[cfg(loom)]
    pub use loom::thread::{spawn, yield_now, JoinHandle};
}

/// Multi-producer single-consumer channels. Always `std`: loom has no
/// channel model, so channel-based protocols are loom-checked via the
/// atomics they are equivalent to (see module docs).
pub use std::sync::mpsc;

#[cfg(test)]
mod tests {
    use super::atomic::{AtomicUsize, Ordering};
    use super::{Arc, Condvar, Mutex};

    #[test]
    fn shim_resolves_to_working_primitives() {
        // Under a normal build this pins the re-export surface the
        // concurrency core depends on: lock-poisoning API shape, condvar
        // wait/notify, atomics, and thread spawn/join all come from here.
        let pair = Arc::new((Mutex::new(0usize), Condvar::new()));
        let hits = Arc::new(AtomicUsize::new(0));
        let (p2, h2) = (Arc::clone(&pair), Arc::clone(&hits));
        let t = super::thread::spawn(move || {
            let (lock, cv) = &*p2;
            let mut g = lock.lock().unwrap();
            *g += 1;
            h2.fetch_add(1, Ordering::SeqCst);
            cv.notify_one();
        });
        let (lock, cv) = &*pair;
        let mut g = lock.lock().unwrap();
        while *g == 0 {
            g = cv.wait(g).unwrap();
        }
        drop(g);
        t.join().unwrap();
        assert_eq!(hits.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn mpsc_is_always_std() {
        let (tx, rx) = super::mpsc::sync_channel::<u32>(1);
        tx.send(7).unwrap();
        assert_eq!(rx.recv().unwrap(), 7);
    }
}
