//! The persistent SDEB worker pool: host threads that live as long as the
//! [`Accelerator`](super::Accelerator) and execute scoped task batches for
//! the overlapped executor's SPS producer stage and the SMAM's per-core
//! head shards.
//!
//! Before this pool existed, every inference spawned a fresh producer
//! thread (`std::thread::scope` in the executor) and every SDSA pass
//! spawned one thread per SDEB core — OS thread churn on the hottest path
//! of the simulator, gated by a size heuristic. The pool replaces both:
//! threads are spawned once per accelerator and fed through a shared
//! injector queue.
//!
//! Deadlock freedom by construction: [`WorkerPool::scope`] enqueues its
//! tasks for the pool **and** lets the calling thread drain its own queue
//! before waiting, so a scope always completes even when every worker is
//! busy (e.g. the lone worker is running the long-lived SPS producer while
//! the consumer thread scopes SMAM shards — the consumer then runs the
//! shards inline, bit-identically, because results never depend on *where*
//! a task ran).
//!
//! Panic policy: task panics are caught, the scope is poisoned, and
//! [`WorkerPool::scope`] re-panics after every task of the scope finished
//! — borrows held by sibling tasks stay valid for their full run.

use std::collections::VecDeque;
use std::marker::PhantomData;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};

use crate::util::sync::atomic::{AtomicBool, Ordering};
use crate::util::sync::thread::{spawn, JoinHandle};
use crate::util::sync::{Arc, Condvar, Mutex};

type Task = Box<dyn FnOnce() + Send + 'static>;

/// Shared state of one `scope` call: its task queue and completion count.
struct ScopeState {
    queue: Mutex<VecDeque<Task>>,
    /// Tasks spawned but not yet finished (condvar-guarded).
    pending: Mutex<usize>,
    done_cv: Condvar,
    panicked: AtomicBool,
}

impl ScopeState {
    fn new() -> Self {
        Self {
            queue: Mutex::new(VecDeque::new()),
            pending: Mutex::new(0),
            done_cv: Condvar::new(),
            panicked: AtomicBool::new(false),
        }
    }

    fn pop(&self) -> Option<Task> {
        self.queue.lock().unwrap().pop_front()
    }

    /// Run one task, recording panics and signalling completion.
    fn run_one(&self, task: Task) {
        if catch_unwind(AssertUnwindSafe(task)).is_err() {
            self.panicked.store(true, Ordering::SeqCst);
        }
        let mut pending = self.pending.lock().unwrap();
        *pending -= 1;
        if *pending == 0 {
            self.done_cv.notify_all();
        }
    }

    /// Caller-side completion: help execute the scope's own queue, then
    /// wait for tasks the pool workers picked up.
    fn drain_and_wait(&self) {
        while let Some(task) = self.pop() {
            self.run_one(task);
        }
        let mut pending = self.pending.lock().unwrap();
        while *pending != 0 {
            pending = self.done_cv.wait(pending).unwrap();
        }
    }
}

/// State shared between the pool handle and its worker threads.
struct PoolShared {
    /// One entry per outstanding task (workers pop a scope, then one task).
    injector: Mutex<VecDeque<Arc<ScopeState>>>,
    work_cv: Condvar,
    shutdown: AtomicBool,
}

fn worker_loop(shared: Arc<PoolShared>) {
    loop {
        let scope = {
            let mut injector = shared.injector.lock().unwrap();
            loop {
                if let Some(scope) = injector.pop_front() {
                    break scope;
                }
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                injector = shared.work_cv.wait(injector).unwrap();
            }
        };
        // The caller may have already drained this entry's task; that's
        // fine — stale notifications are no-ops.
        if let Some(task) = scope.pop() {
            scope.run_one(task);
        }
    }
}

/// A fixed-size pool of persistent worker threads executing scoped task
/// batches (see the module docs for the dispatch and safety model).
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn a pool of `workers` threads (clamped to at least 1).
    pub fn new(workers: usize) -> Self {
        let shared = Arc::new(PoolShared {
            injector: Mutex::new(VecDeque::new()),
            work_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let handles = (0..workers.max(1))
            .map(|_| {
                let shared = Arc::clone(&shared);
                spawn(move || worker_loop(shared))
            })
            .collect();
        Self { shared, handles }
    }

    /// Number of persistent worker threads.
    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Run `f` with a scope handle on which borrowed tasks can be spawned;
    /// returns only after every spawned task completed (the calling thread
    /// helps drain the scope's queue, so progress never depends on a free
    /// worker). Panics if `f` or any task panicked.
    pub fn scope<'env, 'pool, R, F>(&'pool self, f: F) -> R
    where
        F: FnOnce(&PoolScope<'env, 'pool>) -> R,
    {
        let scope =
            PoolScope { state: Arc::new(ScopeState::new()), shared: &self.shared, _env: PhantomData };
        let result = catch_unwind(AssertUnwindSafe(|| f(&scope)));
        // Always complete every spawned task before unwinding: sibling
        // tasks may borrow from the caller's frame.
        scope.state.drain_and_wait();
        match result {
            Ok(r) => {
                if scope.state.panicked.load(Ordering::SeqCst) {
                    panic!("worker pool task panicked");
                }
                r
            }
            Err(payload) => resume_unwind(payload),
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.work_cv.notify_all();
        for handle in self.handles.drain(..) {
            let _ = handle.join();
        }
    }
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool").field("workers", &self.handles.len()).finish()
    }
}

/// Spawn handle passed to the closure of [`WorkerPool::scope`]; tasks may
/// borrow anything that outlives the `scope` call (`'env`).
pub struct PoolScope<'env, 'pool> {
    state: Arc<ScopeState>,
    shared: &'pool Arc<PoolShared>,
    _env: PhantomData<&'env mut &'env ()>,
}

impl<'env> PoolScope<'env, '_> {
    /// Enqueue a task for the pool (the caller drains leftovers itself at
    /// scope end, so spawning never blocks and never deadlocks).
    pub fn spawn<F>(&self, task: F)
    where
        F: FnOnce() + Send + 'env,
    {
        let task: Box<dyn FnOnce() + Send + 'env> = Box::new(task);
        // SAFETY: `WorkerPool::scope` does not return (or resume an
        // unwind) before `drain_and_wait` observed every spawned task
        // finished, so the 'env borrows captured by the task are live for
        // the task's whole execution. The queue and scope state are
        // private, so a task cannot escape its scope.
        let task: Task = unsafe {
            std::mem::transmute::<Box<dyn FnOnce() + Send + 'env>, Task>(task)
        };
        *self.state.pending.lock().unwrap() += 1;
        self.state.queue.lock().unwrap().push_back(task);
        self.shared.injector.lock().unwrap().push_back(Arc::clone(&self.state));
        self.shared.work_cv.notify_one();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn tasks_write_disjoint_borrowed_slots() {
        let pool = WorkerPool::new(3);
        let mut slots = vec![0usize; 8];
        pool.scope(|s| {
            for (i, slot) in slots.iter_mut().enumerate() {
                s.spawn(move || *slot = i + 1);
            }
        });
        assert_eq!(slots, (1..=8).collect::<Vec<_>>());
    }

    #[test]
    fn caller_drains_when_no_worker_is_free() {
        // One worker, parked on a long task; the scope's other tasks must
        // still finish (the caller runs them inline).
        let pool = WorkerPool::new(1);
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let hits = AtomicUsize::new(0);
        pool.scope(|s| {
            let gate2 = Arc::clone(&gate);
            s.spawn(move || {
                let (lock, cv) = &*gate2;
                let mut open = lock.lock().unwrap();
                while !*open {
                    open = cv.wait(open).unwrap();
                }
            });
            for _ in 0..4 {
                s.spawn(|| {
                    hits.fetch_add(1, Ordering::SeqCst);
                });
            }
            // Open the gate so the parked worker task can finish too.
            let (lock, cv) = &*gate;
            *lock.lock().unwrap() = true;
            cv.notify_all();
        });
        assert_eq!(hits.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn scope_returns_closure_value() {
        let pool = WorkerPool::new(2);
        let r = pool.scope(|s| {
            s.spawn(|| {});
            41 + 1
        });
        assert_eq!(r, 42);
    }

    #[test]
    fn sequential_scopes_reuse_the_same_threads() {
        let pool = WorkerPool::new(2);
        assert_eq!(pool.workers(), 2);
        let counter = AtomicUsize::new(0);
        for _ in 0..50 {
            pool.scope(|s| {
                s.spawn(|| {
                    counter.fetch_add(1, Ordering::SeqCst);
                });
            });
        }
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn task_panic_propagates_after_scope_completes() {
        let pool = WorkerPool::new(1);
        let finished = Arc::new(AtomicBool::new(false));
        let finished2 = Arc::clone(&finished);
        let result = catch_unwind(AssertUnwindSafe(|| {
            pool.scope(|s| {
                s.spawn(|| panic!("boom"));
                s.spawn(move || finished2.store(true, Ordering::SeqCst));
            });
        }));
        assert!(result.is_err(), "scope must re-panic on task panic");
        assert!(finished.load(Ordering::SeqCst), "sibling tasks still ran to completion");
    }

    #[test]
    fn zero_requested_workers_clamps_to_one() {
        let pool = WorkerPool::new(0);
        assert_eq!(pool.workers(), 1);
        let mut x = 0;
        pool.scope(|s| s.spawn(|| x = 7));
        assert_eq!(x, 7);
    }
}
