//! Autoregressive decode sessions: prefill/decode split over the
//! spike-stream KV cache (ISSUE 10, DESIGN.md "Decode & KV cache").
//!
//! A [`DecodeSession`] owns a decoder-shaped unit complement — one
//! single-token [`SdebCore`] per block, a head SEA, the
//! [`KvCache`] and its own scratch/sink/buffer state — and processes one
//! token position at a time: `u0` is the token's embedding row (static
//! across SNN timesteps), each `(block, timestep)` runs
//! [`SdebCore::run_decode_timestep`] appending K/V to its cache lane and
//! masking the new Q row against the cached causal prefix, and the head
//! readout pools this token's spikes into per-position logits.
//!
//! Bit-identity contract (proved by `tests/decode_incremental.rs`): the
//! session is *prefix-deterministic* — after processing tokens
//! `t_0..t_p` its logits, unit stats and cache state are bit-identical
//! to a fresh session replaying the same prefix, and its logits match
//! the dense [`GoldenDecoder`](crate::model::GoldenDecoder) oracle.
//! Prefill is literally a loop of single-token steps, so cumulative
//! charges decompose additively and TTFT/ITL fall out of one counter.

use anyhow::{ensure, Context, Result};

use crate::hw::AccelConfig;
use crate::model::QuantizedModel;
use crate::quant::ACT_FRAC;
use crate::scratch::ExecScratch;
use crate::spike::KvCache;
use crate::units::SpikeEncodingArray;

use super::buffers::BufferSet;
use super::executor::head_readout;
use super::report::StatSink;
use super::sdeb_core::SdebCore;

/// Greedy (deterministic first-max) token choice over logits.
pub fn argmax(logits: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, &v) in logits.iter().enumerate() {
        if v > logits[best] {
            best = i;
        }
    }
    best
}

/// Outcome of one [`Accelerator::decode`](super::Accelerator::decode)
/// run: the generated tokens plus the latency decomposition the decode
/// bench reports (TTFT = prefill cycles, ITL = per-token cycles).
#[derive(Clone, Debug)]
pub struct DecodeReport {
    /// Prompt tokens consumed by prefill.
    pub prompt_len: usize,
    /// Tokens generated after the prompt.
    pub gen_len: usize,
    /// The generated token ids (greedy argmax).
    pub generated: Vec<usize>,
    /// Modelled cycles spent in prefill — the time-to-first-token proxy.
    pub prefill_cycles: u64,
    /// Modelled cycles of each generation step — the inter-token
    /// latencies (grow with the causal prefix).
    pub token_cycles: Vec<u64>,
    /// Total modelled cycles of the session.
    pub total_cycles: u64,
    /// Final CSR storage words held by the KV cache.
    pub cache_words: u64,
    /// (module, spike sparsity) table accumulated over the session.
    pub sparsity: Vec<(String, f64)>,
}

/// One autoregressive inference session: per-block single-token SDEB
/// cores, the session-lifetime KV cache, and the accumulated charges.
///
/// The session state is the per-site LIF membranes plus the cache; both
/// persist across token positions and reset together ([`Self::reset`]),
/// so steady-state sessions allocate nothing (arena pooling via
/// `clear_reuse`, scratch via [`ExecScratch`]).
pub struct DecodeSession {
    cores: Vec<SdebCore>,
    sea_head: SpikeEncodingArray,
    cache: KvCache,
    buffers: BufferSet,
    sink: StatSink,
    scratch: ExecScratch,
    head_counts: Vec<u64>,
    pos: usize,
    heads: usize,
    timesteps: usize,
    dim: usize,
    max_seq_len: usize,
}

impl DecodeSession {
    /// Build a session for `model` (which must be decoder-shaped) on the
    /// `hw` instance.
    pub fn new(model: &QuantizedModel, hw: &AccelConfig) -> Result<Self> {
        let cfg = &model.cfg;
        let shape = cfg.decoder_shape()?;
        ensure!(model.embed.is_some(), "model `{}` has no embedding table", cfg.name);
        let d = cfg.embed_dim;
        let cores = (0..cfg.num_blocks)
            .map(|b| SdebCore::new(b, 1, d, cfg.mlp_hidden, cfg.attn_v_th, cfg.lif_params()))
            .collect();
        Ok(Self {
            cores,
            sea_head: SpikeEncodingArray::new(d, 1, cfg.lif_params()),
            cache: KvCache::new(cfg.num_blocks, cfg.timesteps, shape.max_seq_len, d),
            buffers: BufferSet::new(hw),
            sink: StatSink::new(),
            scratch: ExecScratch::new(),
            head_counts: vec![0u64; d],
            pos: 0,
            heads: cfg.num_heads,
            timesteps: cfg.timesteps,
            dim: d,
            max_seq_len: shape.max_seq_len,
        })
    }

    /// Token positions processed so far (prompt + generated).
    pub fn pos(&self) -> usize {
        self.pos
    }

    /// Total modelled cycles accumulated so far (sum over phases — the
    /// decode path is serial, so phase cycles add).
    pub fn cycles(&self) -> u64 {
        self.sink.phases.total().cycles
    }

    /// The session's accumulated stat sink (phase charges + sparsity).
    pub fn sink(&self) -> &StatSink {
        &self.sink
    }

    /// CSR storage words currently held by the KV cache.
    pub fn cache_words(&self) -> u64 {
        self.cache.storage_words()
    }

    /// Process one token and return the logits at its position.
    ///
    /// This is *the* decode primitive: prefill and generation both loop
    /// over it, so cumulative charges decompose additively per position.
    pub fn step(&mut self, model: &QuantizedModel, hw: &AccelConfig, token: usize) -> Result<Vec<f32>> {
        ensure!(
            self.pos < self.max_seq_len,
            "decode session full: {} positions (max_seq_len)",
            self.max_seq_len
        );
        let d = self.dim;
        let row = model.embed_row(token)?;
        self.head_counts.fill(0);
        for t in 0..self.timesteps {
            // u0 is the embedding row, identical at every timestep.
            let mut u = self.scratch.take_tensor(&[1, d], ACT_FRAC);
            u.data.copy_from_slice(row);
            for (bi, blk) in model.blocks.iter().enumerate() {
                u = self.cores[bi].run_decode_timestep(
                    blk,
                    u,
                    hw,
                    self.heads,
                    t,
                    self.cache.stream_mut(bi, t),
                    self.buffers.sdeb_for(bi),
                    &mut self.sink,
                    &mut self.scratch,
                )?;
            }
            head_readout(
                &mut self.sea_head,
                &u,
                1,
                d,
                hw,
                &mut self.sink,
                &mut self.head_counts,
                &mut self.scratch,
            );
            self.scratch.put_tensor(u);
        }
        self.cache.finish_token().context("kv cache invariant after decode step")?;
        self.pos += 1;

        // Host-side head on this position's pooled spike rates.
        let denom = self.timesteps as f32; // as-ok: small count to f32 rate denominator
        let mut logits = model.head_b.clone();
        for (c, &cnt) in self.head_counts.iter().enumerate() {
            let rate = cnt as f32 / denom; // as-ok: spike count to rate
            if rate != 0.0 {
                for (k, lg) in logits.iter_mut().enumerate() {
                    *lg += rate * model.head_w[c * model.cfg.num_classes + k];
                }
            }
        }
        Ok(logits)
    }

    /// Consume the whole prompt (a loop of [`Self::step`]) and return
    /// the logits at its last position — the first generation decision.
    pub fn prefill(
        &mut self,
        model: &QuantizedModel,
        hw: &AccelConfig,
        prompt: &[usize],
    ) -> Result<Vec<f32>> {
        ensure!(!prompt.is_empty(), "prefill needs at least one prompt token");
        let mut last = Vec::new();
        for &tok in prompt {
            last = self.step(model, hw, tok)?;
        }
        Ok(last)
    }

    /// Process `token` and greedily pick the next one from its logits.
    pub fn decode_step(
        &mut self,
        model: &QuantizedModel,
        hw: &AccelConfig,
        token: usize,
    ) -> Result<(usize, Vec<f32>)> {
        let logits = self.step(model, hw, token)?;
        Ok((argmax(&logits), logits))
    }

    /// Reset all session state (LIF membranes, cache, charges) for a
    /// fresh sequence, keeping every arena/buffer capacity.
    pub fn reset(&mut self) {
        for c in &mut self.cores {
            c.reset();
        }
        self.sea_head.reset();
        self.cache.reset();
        self.buffers.reset();
        self.sink = StatSink::new();
        self.head_counts.fill(0);
        self.pos = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::SdtModelConfig;

    fn setup() -> (QuantizedModel, AccelConfig) {
        let cfg = SdtModelConfig::tiny_decoder();
        (QuantizedModel::random(&cfg, 11), AccelConfig::small())
    }

    #[test]
    fn session_is_prefix_deterministic() {
        let (model, hw) = setup();
        let mut a = DecodeSession::new(&model, &hw).unwrap();
        let mut b = DecodeSession::new(&model, &hw).unwrap();
        let la = a.prefill(&model, &hw, &[1, 5, 2]).unwrap();
        let lb = b.prefill(&model, &hw, &[1, 5, 2]).unwrap();
        assert_eq!(la, lb);
        assert_eq!(a.cycles(), b.cycles());
        assert_eq!(a.cache_words(), b.cache_words());
    }

    #[test]
    fn reset_restores_a_fresh_session_bit_exactly() {
        let (model, hw) = setup();
        let mut s = DecodeSession::new(&model, &hw).unwrap();
        let first = s.prefill(&model, &hw, &[3, 1, 4]).unwrap();
        let cycles = s.cycles();
        s.reset();
        assert_eq!(s.pos(), 0);
        assert_eq!(s.cache_words(), 0);
        let again = s.prefill(&model, &hw, &[3, 1, 4]).unwrap();
        assert_eq!(first, again, "reset session must replay bit-exactly");
        assert_eq!(s.cycles(), cycles);
    }

    #[test]
    fn step_cost_grows_with_the_prefix() {
        let (model, hw) = setup();
        let mut s = DecodeSession::new(&model, &hw).unwrap();
        s.step(&model, &hw, 0).unwrap();
        let early = s.cycles();
        for p in 1..8 {
            s.step(&model, &hw, p % model.cfg.vocab()).unwrap();
        }
        let before = s.cycles();
        s.step(&model, &hw, 1).unwrap();
        let late_step = s.cycles() - before;
        assert!(
            late_step > early / 2,
            "attention over a deeper prefix cannot be nearly free"
        );
        assert_eq!(s.pos(), 9);
    }

    #[test]
    fn session_rejects_overflow_and_vision_models() {
        let (model, hw) = setup();
        let mut s = DecodeSession::new(&model, &hw).unwrap();
        let max = model.cfg.decoder_shape().unwrap().max_seq_len;
        for p in 0..max {
            s.step(&model, &hw, p % model.cfg.vocab()).unwrap();
        }
        assert!(s.step(&model, &hw, 0).is_err(), "past max_seq_len");
        let vision = QuantizedModel::random(&SdtModelConfig::tiny(), 1);
        assert!(DecodeSession::new(&vision, &hw).is_err());
    }

    #[test]
    fn argmax_is_first_max_deterministic() {
        assert_eq!(argmax(&[0.0, 2.0, 2.0, 1.0]), 1);
        assert_eq!(argmax(&[-1.0]), 0);
    }
}
