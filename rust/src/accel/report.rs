//! Run reporting: phase-tagged stat collection during inference and the
//! final [`RunReport`] with throughput/energy/sparsity numbers.

use crate::hw::stats::PhaseStats;
use crate::hw::{AccelConfig, EnergyModel, UnitStats};
use crate::spike::EncodedSpikes;

/// Collects stats and sparsity during a run (borrowed by the cores).
#[derive(Clone, Debug, Default)]
pub struct StatSink {
    pub phases: PhaseStats,
    /// (module, zeros, total) accumulated over timesteps.
    sparsity_acc: Vec<(String, u64, u64)>,
}

impl StatSink {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, phase: &str, stats: UnitStats) {
        self.phases.add(phase, stats);
    }

    /// Record the sparsity of an encoded spike tensor under `name`.
    pub fn sparsity(&mut self, name: &str, enc: &EncodedSpikes) {
        let total = (enc.channels * enc.tokens) as u64;
        let zeros = total - enc.count_spikes() as u64;
        if let Some(r) = self.sparsity_acc.iter_mut().find(|r| r.0 == name) {
            r.1 += zeros;
            r.2 += total;
        } else {
            self.sparsity_acc.push((name.to_string(), zeros, total));
        }
    }

    pub fn sparsity_table(&self) -> Vec<(String, f64)> {
        self.sparsity_acc
            .iter()
            .map(|(n, z, t)| (n.clone(), if *t == 0 { 0.0 } else { *z as f64 / *t as f64 }))
            .collect()
    }
}

/// Final report for one inference (or one batch).
#[derive(Clone, Debug)]
pub struct RunReport {
    pub logits: Vec<f32>,
    pub phases: PhaseStats,
    pub total: UnitStats,
    /// Modelled wall-clock at the configured frequency.
    pub seconds: f64,
    /// Achieved throughput in GSOP/s.
    pub gsops: f64,
    /// Modelled average power (W) and efficiency (GSOP/W).
    pub power_w: f64,
    pub gsop_per_w: f64,
    /// (module, sparsity) — the Fig. 6 measurement.
    pub sparsity: Vec<(String, f64)>,
}

impl RunReport {
    pub fn from_sink(
        logits: Vec<f32>,
        sink: StatSink,
        cfg: &AccelConfig,
        energy: &EnergyModel,
    ) -> Self {
        let total = sink.phases.total();
        let seconds = cfg.seconds(total.cycles);
        let gsops = if seconds > 0.0 { total.sops as f64 / seconds / 1e9 } else { 0.0 };
        let power_w = energy.avg_power_w(&total, seconds);
        let gsop_per_w = energy.gsop_per_w(&total, seconds);
        Self {
            logits,
            sparsity: sink.sparsity_table(),
            phases: sink.phases,
            total,
            seconds,
            gsops,
            power_w,
            gsop_per_w,
        }
    }

    pub fn argmax(&self) -> usize {
        self.logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0)
    }

    /// Pretty multi-line summary for CLI/bench output.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "cycles={}  time={:.3} ms  sops={}  achieved={:.2} GSOP/s  power={:.2} W  eff={:.2} GSOP/W\n",
            self.total.cycles,
            self.seconds * 1e3,
            self.total.sops,
            self.gsops,
            self.power_w,
            self.gsop_per_w
        );
        for (name, st) in &self.phases.phases {
            s.push_str(&format!(
                "  {:<16} cycles={:<10} sops={:<12} reads={:<12} writes={}\n",
                name, st.cycles, st.sops, st.sram_reads, st.sram_writes
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spike::SpikeMatrix;

    #[test]
    fn sparsity_accumulates_over_calls() {
        let mut sink = StatSink::new();
        let mut m = SpikeMatrix::zeros(1, 4);
        m.set(0, 0, true); // 75% sparse
        let enc = EncodedSpikes::from_bitmap(&m);
        sink.sparsity("x", &enc);
        sink.sparsity("x", &EncodedSpikes::empty(1, 4)); // 100% sparse
        let t = sink.sparsity_table();
        assert_eq!(t.len(), 1);
        assert!((t[0].1 - 0.875).abs() < 1e-12);
    }

    #[test]
    fn report_computes_throughput() {
        let mut sink = StatSink::new();
        sink.add(
            "slu",
            UnitStats { cycles: 2_000_000, sops: 3_072_000_000, adds: 10, ..Default::default() },
        );
        let cfg = AccelConfig::paper();
        let r = RunReport::from_sink(vec![0.0], sink, &cfg, &EnergyModel::default());
        assert!((r.seconds - 0.01).abs() < 1e-9);
        assert!((r.gsops - 307.2).abs() < 0.1);
        assert_eq!(r.argmax(), 0);
        assert!(r.summary().contains("slu"));
    }
}
