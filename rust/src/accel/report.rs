//! Run reporting: phase-tagged stat collection during inference and the
//! final [`RunReport`] with throughput/energy/sparsity numbers.

use crate::hw::stats::PhaseStats;
use crate::hw::{AccelConfig, EnergyModel, MemoryReport, UnitStats};
use crate::spike::EncodedSpikes;

use super::executor::PipelineExecution;

/// Collects stats and sparsity during a run (borrowed by the cores).
#[derive(Clone, Debug, Default)]
pub struct StatSink {
    /// Phase-tagged stats.
    pub phases: PhaseStats,
    /// Words a full re-store of every SDEB input tensor would write
    /// (the `--temporal-delta` denominator; recorded whether or not the
    /// flag is on).
    pub spike_full_words: u64,
    /// Words actually moved into the ESS for the SDEB input tensors —
    /// equal to [`Self::spike_full_words`] with `--temporal-delta` off,
    /// smaller when the per-channel XOR delta wins.
    pub spike_moved_words: u64,
    /// (module, zeros, total) accumulated over timesteps.
    sparsity_acc: Vec<(String, u64, u64)>,
}

impl StatSink {
    /// Empty sink.
    pub fn new() -> Self {
        Self::default()
    }

    /// Accumulate `stats` under `phase`.
    pub fn add(&mut self, phase: &str, stats: UnitStats) {
        self.phases.add(phase, stats);
    }

    /// Record one SDEB input store: `full` words for a full re-store,
    /// `moved` words actually written (equal with `--temporal-delta` off).
    pub fn spike_traffic(&mut self, full: u64, moved: u64) {
        self.spike_full_words += full;
        self.spike_moved_words += moved;
    }

    /// Record the sparsity of an encoded spike tensor under `name`.
    pub fn sparsity(&mut self, name: &str, enc: &EncodedSpikes) {
        let total = (enc.channels * enc.tokens) as u64; // as-ok: widening for 64-bit stat/cycle math
        let zeros = total - enc.count_spikes() as u64; // as-ok: widening for 64-bit stat/cycle math
        if let Some(r) = self.sparsity_acc.iter_mut().find(|r| r.0 == name) {
            r.1 += zeros;
            r.2 += total;
        } else {
            self.sparsity_acc.push((name.to_string(), zeros, total));
        }
    }

    /// Merge another sink into this one (phases via [`PhaseStats::add`],
    /// sparsity accumulators by name). Used by the overlapped executor to
    /// combine per-stage sinks in a deterministic order.
    pub fn absorb(&mut self, other: StatSink) {
        for (name, st) in other.phases.phases {
            self.phases.add(&name, st);
        }
        self.spike_full_words += other.spike_full_words;
        self.spike_moved_words += other.spike_moved_words;
        for (name, zeros, total) in other.sparsity_acc {
            if let Some(r) = self.sparsity_acc.iter_mut().find(|r| r.0 == name) {
                r.1 += zeros;
                r.2 += total;
            } else {
                self.sparsity_acc.push((name, zeros, total));
            }
        }
    }

    /// `(name, sparsity)` rows accumulated so far — the Fig. 6 measurement.
    pub fn sparsity_table(&self) -> Vec<(String, f64)> {
        self.sparsity_acc
            .iter()
            .map(|(n, z, t)| (n.clone(), if *t == 0 { 0.0 } else { *z as f64 / *t as f64 })) // as-ok: reporting ratio, not datapath state
            .collect()
    }
}

/// Final report for one inference (or one batch).
#[derive(Clone, Debug)]
pub struct RunReport {
    /// Classification logits (bit-identical to the golden executor).
    pub logits: Vec<f32>,
    /// Per-phase stat breakdown.
    pub phases: PhaseStats,
    /// Summed unit-busy stats across phases (the serial-equivalent cost;
    /// see [`Self::wall_cycles`] for the overlapped finish time).
    pub total: UnitStats,
    /// Modelled busy time (serial-equivalent) at the configured frequency.
    pub seconds: f64,
    /// Achieved throughput in GSOP/s over the busy time.
    pub gsops: f64,
    /// Modelled average power (W).
    pub power_w: f64,
    /// Modelled efficiency (GSOP/W).
    pub gsop_per_w: f64,
    /// (module, sparsity) — the Fig. 6 measurement.
    pub sparsity: Vec<(String, f64)>,
    /// The executed core-overlap schedule (`None` for serial-mode runs):
    /// per-stage traces, ring depth, executed finish cycles, speedup,
    /// weight-streaming stalls and the per-client memory accounting
    /// (see [`Self::memory`]).
    pub pipeline: Option<PipelineExecution>,
}

impl RunReport {
    /// Assemble a serial-mode report (no overlap schedule).
    pub fn from_sink(
        logits: Vec<f32>,
        sink: StatSink,
        cfg: &AccelConfig,
        energy: &EnergyModel,
    ) -> Self {
        Self::assemble(logits, sink, cfg, energy, None)
    }

    /// Assemble a report for an overlapped run, attaching the executed
    /// pipeline schedule produced by the
    /// [`executor`](super::executor).
    pub fn from_sink_pipelined(
        logits: Vec<f32>,
        sink: StatSink,
        execution: PipelineExecution,
        cfg: &AccelConfig,
        energy: &EnergyModel,
    ) -> Self {
        Self::assemble(logits, sink, cfg, energy, Some(execution))
    }

    fn assemble(
        logits: Vec<f32>,
        sink: StatSink,
        cfg: &AccelConfig,
        energy: &EnergyModel,
        pipeline: Option<PipelineExecution>,
    ) -> Self {
        let total = sink.phases.total();
        let seconds = cfg.seconds(total.cycles);
        let gsops = if seconds > 0.0 { total.sops as f64 / seconds / 1e9 } else { 0.0 }; // as-ok: reporting ratio, not datapath state
        // Energy charges the now-real weight-streaming traffic alongside
        // the compute phases' op counts: the streamed bytes live outside
        // the phase breakdown (they are a schedule lane, not a compute
        // phase), so they are folded in here — priced by the same
        // `pj_dram_byte` term `EnergyModel::weight_stream_j` exposes.
        let weight_bytes = pipeline
            .as_ref()
            .and_then(|p| p.memory.as_ref())
            .map(|m| m.weight_bytes())
            .unwrap_or(0);
        let energy_basis = total.with_dram_bytes(weight_bytes);
        let power_w = energy.avg_power_w(&energy_basis, seconds);
        let gsop_per_w = energy.gsop_per_w(&energy_basis, seconds);
        Self {
            logits,
            sparsity: sink.sparsity_table(),
            phases: sink.phases,
            total,
            seconds,
            gsops,
            power_w,
            gsop_per_w,
            pipeline,
        }
    }

    /// Per-client external-memory accounting (weight-streaming DMA, input
    /// load, output drain) of the executed schedule — borrowed from the
    /// pipeline record, which owns it. `None` for serial-mode runs, which
    /// predate the memory system and stay the memory-blind ablation
    /// baseline.
    pub fn memory(&self) -> Option<&MemoryReport> {
        self.pipeline.as_ref().and_then(|p| p.memory.as_ref())
    }

    /// Modelled wall-clock cycles of the run: the executed overlap
    /// schedule's finish time when one was run, otherwise the serial sum.
    pub fn wall_cycles(&self) -> u64 {
        self.pipeline.as_ref().map(|p| p.executed_cycles).unwrap_or(self.total.cycles)
    }

    /// Modelled wall-clock seconds (executed overlap when present; equal
    /// to [`Self::seconds`] for serial runs).
    pub fn wall_seconds(&self) -> f64 {
        if self.total.cycles == 0 {
            return self.seconds;
        }
        self.seconds * self.wall_cycles() as f64 / self.total.cycles as f64 // as-ok: reporting ratio, not datapath state
    }

    /// Achieved GSOP/s over the wall clock — the overlapped-schedule
    /// throughput basis, vs [`Self::gsops`]'s serial-equivalent busy-time
    /// basis. Identical for serial runs.
    pub fn wall_gsops(&self) -> f64 {
        let s = self.wall_seconds();
        if s > 0.0 {
            self.total.sops as f64 / s / 1e9 // as-ok: reporting ratio, not datapath state
        } else {
            0.0
        }
    }

    /// Index of the winning logit.
    pub fn argmax(&self) -> usize {
        self.logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0)
    }

    /// Pretty multi-line summary for CLI/bench output.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "cycles={}  time={:.3} ms  sops={}  achieved={:.2} GSOP/s  power={:.2} W  eff={:.2} GSOP/W\n",
            self.total.cycles,
            self.seconds * 1e3,
            self.total.sops,
            self.gsops,
            self.power_w,
            self.gsop_per_w
        );
        if let Some(p) = &self.pipeline {
            s.push_str(&format!(
                "pipelined: executed={} cycles  serial-equivalent={}  speedup={:.2}x  bottleneck={} (fill={})  wall={:.2} GSOP/s\n",
                p.executed_cycles,
                p.serialized_cycles,
                p.speedup(),
                p.bottleneck(),
                p.fill_cycles(),
                self.wall_gsops()
            ));
        }
        if let Some(m) = self.memory() {
            let wall = self.wall_cycles();
            s.push_str(&format!(
                "memory: weights={:.2} MB streamed  stall={} cycles ({:.1}% of wall)  bus util={:.1}% @ {} B/cyc\n",
                m.weight_bytes() as f64 / 1e6, // as-ok: reporting ratio, not datapath state
                m.stall_cycles(),
                100.0 * m.stall_fraction(wall),
                100.0 * m.bus_utilization(wall),
                if m.bytes_per_cycle == usize::MAX {
                    "inf".to_string()
                } else {
                    m.bytes_per_cycle.to_string()
                }
            ));
            s.push_str(&format!(
                "temporal: regimes resident={} thrash={} streaming={}  resident={:.2} MB  spike stores={:.3} MB moved / {:.3} MB full\n",
                m.resident_blocks,
                m.thrash_blocks,
                m.streaming_blocks,
                m.resident_bytes as f64 / 1e6, // as-ok: reporting ratio, not datapath state
                m.spike_bytes_moved as f64 / 1e6, // as-ok: reporting ratio, not datapath state
                m.spike_bytes_full as f64 / 1e6, // as-ok: reporting ratio, not datapath state
            ));
        }
        for (name, st) in &self.phases.phases {
            s.push_str(&format!(
                "  {:<16} cycles={:<10} sops={:<12} reads={:<12} writes={}\n",
                name, st.cycles, st.sops, st.sram_reads, st.sram_writes
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spike::SpikeMatrix;

    #[test]
    fn sparsity_accumulates_over_calls() {
        let mut sink = StatSink::new();
        let mut m = SpikeMatrix::zeros(1, 4);
        m.set(0, 0, true); // 75% sparse
        let enc = EncodedSpikes::from_bitmap(&m);
        sink.sparsity("x", &enc);
        sink.sparsity("x", &EncodedSpikes::empty(1, 4)); // 100% sparse
        let t = sink.sparsity_table();
        assert_eq!(t.len(), 1);
        assert!((t[0].1 - 0.875).abs() < 1e-12);
    }

    #[test]
    fn report_computes_throughput() {
        let mut sink = StatSink::new();
        sink.add(
            "slu",
            UnitStats { cycles: 2_000_000, sops: 3_072_000_000, adds: 10, ..Default::default() },
        );
        let cfg = AccelConfig::paper();
        let r = RunReport::from_sink(vec![0.0], sink, &cfg, &EnergyModel::default());
        assert!((r.seconds - 0.01).abs() < 1e-9);
        assert!((r.gsops - 307.2).abs() < 0.1);
        assert_eq!(r.argmax(), 0);
        assert!(r.summary().contains("slu"));
    }
}
