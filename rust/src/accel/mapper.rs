//! The mapping scheduler: assigns SDSA work units (block × head ×
//! timestep tiles) to physical SDEB cores under an explicit policy.
//!
//! The paper's Fig. 1 instance hardwires one assignment — head `h` of the
//! active block runs on core `h % 2` — which this module generalizes into
//! a swept design axis. A [`Mapper`] is built from the instance's
//! [`CoreTopology`] plus a [`MappingPolicy`]; at each block's SDSA pass it
//! produces a head→core assignment that the
//! [`SpikeMaskAddModule`](crate::units::SpikeMaskAddModule) executes
//! (cycles = max over cores, ops summed — see `run_mapped_into`).
//!
//! Because the SDSA mask is channel-local, *every* assignment is
//! value-exact: policies change only which comparator array does the work,
//! i.e. the modelled cycle count, never a logit. That makes the policy an
//! honest scheduling knob (Bishop maps spiking-transformer layers onto
//! heterogeneous core pools the same way) rather than a numerics hazard.
//!
//! Policies:
//!
//! * [`MappingPolicy::HeadRoundRobin`] — head `h` on core `h % cores`; the
//!   paper's static assignment and the default (bit-identical schedules to
//!   the pre-topology executor at `sdeb_cores = 2`).
//! * [`MappingPolicy::BlockAffinity`] — the round-robin start rotates with
//!   the block index, so consecutive blocks' head streams land on
//!   different home cores (keeps per-core weight/ESS working sets
//!   block-affine when blocks outnumber cores).
//! * [`MappingPolicy::LoadBalanced`] — greedy longest-processing-time
//!   assignment using the *actual* per-head encoded-spike counts of this
//!   timestep's Q/K tensors as the load measure: heads are placed
//!   heaviest-first onto the currently least-loaded core. Deterministic
//!   (ties break toward the lower head / core index).

use std::str::FromStr;

use anyhow::{bail, Error, Result};

use crate::hw::{AccelConfig, CoreTopology};
use crate::spike::EncodedSpikes;
use crate::units::HeadShard;

/// Core counts up to this use stack storage in the load-balanced
/// assignment loop (no per-pass heap allocation on the hot path).
const MAX_STACK_CORES: usize = 64;

/// Which SDEB core runs which head: the scheduling policy axis of the
/// topology sweep.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum MappingPolicy {
    /// Head `h` on core `h % cores` (the paper's static assignment).
    #[default]
    HeadRoundRobin,
    /// Round-robin with the start core rotated by the block index.
    BlockAffinity,
    /// Greedy heaviest-head-first onto the least-loaded core, using
    /// per-head Q+K encoded-spike counts as the load measure.
    LoadBalanced,
}

impl MappingPolicy {
    /// All policies, for sweeps.
    pub const ALL: [MappingPolicy; 3] =
        [Self::HeadRoundRobin, Self::BlockAffinity, Self::LoadBalanced];

    /// Stable CLI name (`--mapping` value).
    pub fn name(&self) -> &'static str {
        match self {
            Self::HeadRoundRobin => "round-robin",
            Self::BlockAffinity => "block-affinity",
            Self::LoadBalanced => "load-balanced",
        }
    }
}

impl FromStr for MappingPolicy {
    type Err = Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "round-robin" | "head-round-robin" | "rr" => Ok(Self::HeadRoundRobin),
            "block-affinity" | "affinity" => Ok(Self::BlockAffinity),
            "load-balanced" | "balanced" | "lpt" => Ok(Self::LoadBalanced),
            other => bail!(
                "unknown mapping policy `{other}` (expected round-robin, \
                 block-affinity or load-balanced)"
            ),
        }
    }
}

/// One schedulable tile of SDSA work: one attention head of one encoder
/// block at one timestep.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WorkUnit {
    /// Encoder block index.
    pub block: usize,
    /// Attention head index within the block.
    pub head: usize,
    /// Timestep index.
    pub timestep: usize,
}

/// The mapping scheduler bound to one model/instance pair: knows the head
/// count, the core topology and the policy, and emits head→core
/// assignments for each block's SDSA pass.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Mapper {
    /// Attention heads per block (`SdtModelConfig::num_heads`).
    pub heads: usize,
    /// The instance's core topology.
    pub topology: CoreTopology,
    /// The assignment policy.
    pub policy: MappingPolicy,
}

impl Mapper {
    /// A mapper for `heads` attention heads on `topology` under `policy`.
    pub fn new(heads: usize, topology: CoreTopology, policy: MappingPolicy) -> Self {
        Self { heads: heads.max(1), topology, policy }
    }

    /// The degenerate serial plan: one head on one core (used by the
    /// serial-charging ablation path).
    pub fn serial() -> Self {
        Self {
            heads: 1,
            topology: CoreTopology { sdeb_cores: 1, ..CoreTopology::paper() },
            policy: MappingPolicy::HeadRoundRobin,
        }
    }

    /// Effective head count over `channels` channels (a head needs at
    /// least one channel).
    pub fn effective_heads(&self, channels: usize) -> usize {
        self.heads.max(1).min(channels.max(1))
    }

    /// Effective core count for `heads` heads (no core without a head).
    pub fn effective_cores(&self, heads: usize) -> usize {
        self.topology.sdeb_cores.max(1).min(heads)
    }

    /// Write the head→core assignment for block `block`'s SDSA pass into
    /// `assign` (resized to `heads`). `loads[h]` is the per-head load
    /// measure (Q+K encoded-spike counts); only [`MappingPolicy::LoadBalanced`]
    /// reads it, and an empty slice falls back to uniform loads.
    ///
    /// Every head is assigned exactly one core in `0..cores` — the
    /// coverage property the mapping tests pin down.
    pub fn assign_heads_into(
        &self,
        block: usize,
        heads: usize,
        cores: usize,
        loads: &[u64],
        assign: &mut Vec<usize>,
    ) {
        let cores = cores.max(1);
        assign.clear();
        assign.resize(heads, 0);
        match self.policy {
            MappingPolicy::HeadRoundRobin => {
                for (h, slot) in assign.iter_mut().enumerate() {
                    *slot = h % cores;
                }
            }
            MappingPolicy::BlockAffinity => {
                for (h, slot) in assign.iter_mut().enumerate() {
                    *slot = (block + h) % cores;
                }
            }
            MappingPolicy::LoadBalanced => {
                // Greedy LPT without sorting: each round picks the
                // heaviest unassigned head (ties toward the lower head
                // index) and places it on the least-loaded core (ties
                // toward the lower core index). O(heads^2 + heads*cores)
                // with heads and cores both small; fully deterministic.
                use std::cmp::Reverse;
                const UNASSIGNED: usize = usize::MAX;
                assign.fill(UNASSIGNED);
                // Stack storage keeps the steady-state hot path
                // allocation-free (the heap fallback only exists for
                // fabrics wider than any swept instance).
                let mut small = [0u64; MAX_STACK_CORES];
                let mut big: Vec<u64>;
                let core_load: &mut [u64] = if cores <= MAX_STACK_CORES {
                    &mut small[..cores]
                } else {
                    big = vec![0u64; cores]; // alloc-ok: cold fallback, fabrics wider than MAX_STACK_CORES
                    &mut big
                };
                let load_of = |h: usize| loads.get(h).copied().unwrap_or(1);
                for _ in 0..heads {
                    // min_by_key returns the FIRST minimum, giving both
                    // tie-breaks deterministically.
                    let pick = (0..heads)
                        .filter(|&h| assign[h] == UNASSIGNED)
                        .min_by_key(|&h| Reverse(load_of(h)))
                        .expect("an unassigned head remains each round");
                    let best = (0..cores)
                        .min_by_key(|&c| core_load[c])
                        .expect("at least one core");
                    assign[pick] = best;
                    core_load[best] += load_of(pick);
                }
            }
        }
    }

    /// Per-head Q+K encoded-spike counts over `heads` contiguous head
    /// ranges of `q`/`k`'s channel space — the [`MappingPolicy::LoadBalanced`]
    /// load measure. Written into `loads` (resized to `heads`).
    pub fn head_loads_into(q: &EncodedSpikes, k: &EncodedSpikes, heads: usize, loads: &mut Vec<u64>) {
        loads.clear();
        loads.resize(heads, 0);
        let c = q.channels;
        for (h, load) in loads.iter_mut().enumerate() {
            for ch in HeadShard::head_channels(h, heads, c) {
                *load += (q.channel_len(ch) + k.channel_len(ch)) as u64; // as-ok: widening for 64-bit stat/cycle math
            }
        }
    }

    /// Enumerate the full work-unit → core map for `blocks` blocks over
    /// `timesteps` timesteps, using uniform loads for
    /// [`MappingPolicy::LoadBalanced`] (runtime assignment uses the actual
    /// per-timestep spike counts; this static view is for reports and the
    /// coverage tests).
    pub fn plan(&self, blocks: usize, timesteps: usize) -> Vec<(WorkUnit, usize)> {
        let heads = self.heads.max(1);
        let cores = self.effective_cores(heads);
        let mut out = Vec::with_capacity(blocks * heads * timesteps);
        let mut assign = Vec::new();
        for t in 0..timesteps {
            for b in 0..blocks {
                self.assign_heads_into(b, heads, cores, &[], &mut assign);
                for (h, &core) in assign.iter().enumerate() {
                    out.push((WorkUnit { block: b, head: h, timestep: t }, core));
                }
            }
        }
        out
    }

    /// Comparators per SDEB core under this topology (see
    /// [`CoreTopology::comparators_per_core`]).
    pub fn comparators_per_core(&self, cfg: &AccelConfig) -> usize {
        self.topology.comparators_per_core(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spike::SpikeMatrix;
    use crate::util::Prng;

    fn mapper(heads: usize, cores: usize, policy: MappingPolicy) -> Mapper {
        Mapper::new(heads, CoreTopology::with_sdeb_cores(cores), policy)
    }

    #[test]
    fn round_robin_matches_legacy_modulo_assignment() {
        let m = mapper(8, 2, MappingPolicy::HeadRoundRobin);
        let mut assign = Vec::new();
        m.assign_heads_into(0, 8, 2, &[], &mut assign);
        assert_eq!(assign, vec![0, 1, 0, 1, 0, 1, 0, 1]);
        // Block index must not perturb round-robin (the legacy behaviour).
        m.assign_heads_into(3, 8, 2, &[], &mut assign);
        assert_eq!(assign, vec![0, 1, 0, 1, 0, 1, 0, 1]);
    }

    #[test]
    fn block_affinity_rotates_start_core() {
        let m = mapper(4, 4, MappingPolicy::BlockAffinity);
        let mut assign = Vec::new();
        m.assign_heads_into(0, 4, 4, &[], &mut assign);
        assert_eq!(assign, vec![0, 1, 2, 3]);
        m.assign_heads_into(1, 4, 4, &[], &mut assign);
        assert_eq!(assign, vec![1, 2, 3, 0]);
    }

    #[test]
    fn load_balanced_puts_heavy_heads_on_distinct_cores() {
        let m = mapper(4, 2, MappingPolicy::LoadBalanced);
        let mut assign = Vec::new();
        // Two heavy heads (0, 1) must not share a core.
        m.assign_heads_into(0, 4, 2, &[100, 90, 1, 1], &mut assign);
        assert_ne!(assign[0], assign[1]);
        // Loads {100} vs {90, 1, 1}: max core load 100 (optimal here).
        let load0: u64 = [100u64, 90, 1, 1]
            .iter()
            .zip(&assign)
            .filter(|(_, &c)| c == 0)
            .map(|(l, _)| l)
            .sum();
        let load1: u64 = 100 + 90 + 1 + 1 - load0;
        assert_eq!(load0.max(load1), 100);
    }

    #[test]
    fn load_balanced_is_deterministic_on_ties() {
        let m = mapper(6, 3, MappingPolicy::LoadBalanced);
        let (mut a, mut b) = (Vec::new(), Vec::new());
        m.assign_heads_into(0, 6, 3, &[5; 6], &mut a);
        m.assign_heads_into(0, 6, 3, &[5; 6], &mut b);
        assert_eq!(a, b);
        // Uniform loads round-robin by construction of the tie-breaks.
        assert_eq!(a, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn lpt_head_ties_break_toward_lower_head_index() {
        let m = mapper(4, 2, MappingPolicy::LoadBalanced);
        let mut assign = Vec::new();
        // Heads 1 and 2 tie at the top weight. Placement order must be
        // h1 (first of the tie) -> core 0, h2 -> core 1, then h0 (7) onto
        // the core-load tie {9, 9} -> core 0, then h3 -> core 1.
        m.assign_heads_into(0, 4, 2, &[7, 9, 9, 1], &mut assign);
        assert_eq!(assign, vec![0, 0, 1, 1]);
    }

    #[test]
    fn lpt_core_ties_break_toward_lower_core_index() {
        let m = mapper(2, 3, MappingPolicy::LoadBalanced);
        let mut assign = Vec::new();
        // All three cores start tied at zero load: the heaviest head must
        // land on core 0, the next on core 1; core 2 stays empty.
        m.assign_heads_into(0, 2, 3, &[5, 3], &mut assign);
        assert_eq!(assign, vec![0, 1]);
    }

    #[test]
    fn lpt_short_load_slice_defaults_missing_heads_to_unit_load() {
        let m = mapper(3, 2, MappingPolicy::LoadBalanced);
        let mut assign = Vec::new();
        // Only head 0 has a measured load; heads 1 and 2 default to 1 and
        // tie-break by head index: h0(10) -> core 0, h1 -> core 1, h2 ->
        // core 1 (1 < 10).
        m.assign_heads_into(0, 3, 2, &[10], &mut assign);
        assert_eq!(assign, vec![0, 1, 1]);
    }

    #[test]
    fn every_policy_covers_all_work_units_exactly_once() {
        for policy in MappingPolicy::ALL {
            for (heads, cores, blocks, timesteps) in
                [(8, 2, 2, 4), (3, 2, 1, 2), (8, 8, 3, 1), (5, 3, 4, 2)]
            {
                let m = mapper(heads, cores, policy);
                let plan = m.plan(blocks, timesteps);
                assert_eq!(plan.len(), heads * blocks * timesteps, "{policy:?}");
                for b in 0..blocks {
                    for h in 0..heads {
                        for t in 0..timesteps {
                            let unit = WorkUnit { block: b, head: h, timestep: t };
                            let hits: Vec<usize> = plan
                                .iter()
                                .filter(|(u, _)| *u == unit)
                                .map(|(_, c)| *c)
                                .collect();
                            assert_eq!(hits.len(), 1, "{policy:?} {unit:?}");
                            assert!(hits[0] < cores, "{policy:?} {unit:?} -> core {}", hits[0]);
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn head_loads_sum_q_and_k_spikes_per_head_range() {
        let mut rng = Prng::new(3);
        let mut mq = SpikeMatrix::zeros(8, 16);
        let mut mk = SpikeMatrix::zeros(8, 16);
        for c in 0..8 {
            for t in 0..16 {
                if rng.bernoulli(0.4) {
                    mq.set(c, t, true);
                }
                if rng.bernoulli(0.4) {
                    mk.set(c, t, true);
                }
            }
        }
        let q = EncodedSpikes::from_bitmap(&mq);
        let k = EncodedSpikes::from_bitmap(&mk);
        let mut loads = Vec::new();
        Mapper::head_loads_into(&q, &k, 4, &mut loads);
        assert_eq!(loads.len(), 4);
        let total: u64 = loads.iter().sum();
        assert_eq!(total, (q.count_spikes() + k.count_spikes()) as u64);
        // Head 0 covers channels 0..2 under the balanced split.
        let want0 =
            (q.channel_len(0) + q.channel_len(1) + k.channel_len(0) + k.channel_len(1)) as u64;
        assert_eq!(loads[0], want0);
    }

    #[test]
    fn policy_parses_from_cli_names() {
        assert_eq!("round-robin".parse::<MappingPolicy>().unwrap(), MappingPolicy::HeadRoundRobin);
        assert_eq!("block-affinity".parse::<MappingPolicy>().unwrap(), MappingPolicy::BlockAffinity);
        assert_eq!("load-balanced".parse::<MappingPolicy>().unwrap(), MappingPolicy::LoadBalanced);
        assert_eq!("lpt".parse::<MappingPolicy>().unwrap(), MappingPolicy::LoadBalanced);
        assert!("nope".parse::<MappingPolicy>().is_err());
        for p in MappingPolicy::ALL {
            assert_eq!(p.name().parse::<MappingPolicy>().unwrap(), p);
        }
    }

    #[test]
    fn serial_mapper_is_one_head_one_core() {
        let m = Mapper::serial();
        assert_eq!(m.effective_heads(64), 1);
        assert_eq!(m.effective_cores(1), 1);
    }
}
