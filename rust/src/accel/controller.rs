//! The Controller (Fig. 1): sequences the SPS Core, the SDEB Cores and the
//! head over all timesteps of an inference, owns the buffer complement, and
//! assembles the final [`RunReport`].

use anyhow::Result;

use crate::hw::{AccelConfig, EnergyModel, UnitStats};
use crate::quant::{QFormat, QTensor, ACT_FRAC, MEM_BITS};
use crate::units::SpikeEncodingArray;
use crate::model::QuantizedModel;
use crate::util::div_ceil;

use super::buffers::BufferSet;
use super::report::{RunReport, StatSink};
use super::sdeb_core::SdebCore;
use super::sps_core::SpsCore;

/// Which datapath the spike-consuming units use (ablation A1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DatapathMode {
    /// The paper's position-encoded spike processing.
    Encoded,
    /// Conventional bitmap processing (zero-checking every position).
    Bitmap,
}

/// A full accelerator instance bound to one quantized model.
pub struct Accelerator {
    pub hw: AccelConfig,
    pub energy: EnergyModel,
    pub mode: DatapathMode,
    model: QuantizedModel,
    sps: SpsCore,
    sdebs: Vec<SdebCore>,
    sea_head: SpikeEncodingArray,
}

impl Accelerator {
    pub fn new(model: QuantizedModel, hw: AccelConfig) -> Self {
        Self::with_mode(model, hw, DatapathMode::Encoded)
    }

    pub fn with_mode(model: QuantizedModel, hw: AccelConfig, mode: DatapathMode) -> Self {
        let cfg = &model.cfg;
        let params = cfg.lif_params();
        let (l, d) = (cfg.num_tokens(), cfg.embed_dim);
        let sps = SpsCore::new(&model, params);
        let sdebs = (0..cfg.num_blocks)
            .map(|i| SdebCore::new(i, l, d, cfg.mlp_hidden, cfg.attn_v_th, params))
            .collect();
        let sea_head = SpikeEncodingArray::new(d, l, params);
        Self { hw, energy: EnergyModel::default(), mode, model, sps, sdebs, sea_head }
    }

    pub fn model(&self) -> &QuantizedModel {
        &self.model
    }

    fn reset(&mut self) {
        self.sps.reset();
        for s in &mut self.sdebs {
            s.reset();
        }
        self.sea_head.reset();
    }

    /// Run a full inference of one image (f32 CHW pixels).
    pub fn infer(&mut self, image: &[f32]) -> Result<RunReport> {
        let cfg = self.model.cfg.clone();
        assert_eq!(image.len(), cfg.in_channels * cfg.img_size * cfg.img_size);
        self.reset();

        let mut buffers = BufferSet::new(&self.hw);
        let mut sink = StatSink::new();

        // External input transfer: 10-bit activations packed 2 B/value.
        let in_bytes = image.len() * 2;
        let st = buffers.load_external(in_bytes, &self.hw)?;
        sink.add("io.input", st);

        let act = QFormat::new(MEM_BITS, ACT_FRAC);
        let qimg =
            QTensor::from_f32(image, &[cfg.in_channels, cfg.img_size, cfg.img_size], act);

        let (l, d) = (cfg.num_tokens(), cfg.embed_dim);
        let mut head_counts = vec![0u64; d];

        for _t in 0..cfg.timesteps {
            let (u0_cl, _enc3) =
                self.sps.run_timestep(&self.model, &qimg, &self.hw, self.mode, &mut buffers, &mut sink)?;

            // [D, L] -> [L, D] for the SDEB residual stream.
            let mut u = QTensor::zeros(&[l, d], ACT_FRAC);
            for c in 0..d {
                for tok in 0..l {
                    u.data[tok * d + c] = u0_cl.data[c * l + tok];
                }
            }

            for (bi, core) in self.sdebs.iter_mut().enumerate() {
                u = core.run_timestep(
                    &self.model.blocks[bi],
                    u,
                    &self.hw,
                    self.mode,
                    &mut buffers,
                    &mut sink,
                )?;
            }

            // Head LIF + pooled spike counting (output side).
            let mut u_cl = vec![0i32; d * l];
            for tok in 0..l {
                for c in 0..d {
                    u_cl[c * l + tok] = u.data[tok * d + c];
                }
            }
            let (s_out, st) = self.sea_head.encode(&u_cl, &self.hw);
            sink.add("head.encode", st);
            sink.sparsity("head.in.spikes", &s_out);
            for (c, count) in head_counts.iter_mut().enumerate() {
                *count += s_out.channel_len(c) as u64;
            }
        }

        // Host/output-side classification head on pooled rates.
        let denom = (cfg.timesteps * l) as f32;
        let mut logits = self.model.head_b.clone();
        for c in 0..d {
            let rate = head_counts[c] as f32 / denom;
            if rate != 0.0 {
                for k in 0..cfg.num_classes {
                    logits[k] += rate * self.model.head_w[c * cfg.num_classes + k];
                }
            }
        }

        // Output transfer (logits as f32).
        let out_bytes = cfg.num_classes * 4;
        sink.add(
            "io.output",
            UnitStats {
                cycles: div_ceil(out_bytes as u64, self.hw.dram_bytes_per_cycle as u64),
                dram_bytes: out_bytes as u64,
                ..Default::default()
            },
        );

        Ok(RunReport::from_sink(logits, sink, &self.hw, &self.energy))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{GoldenExecutor, SdtModelConfig};
    use crate::util::Prng;

    fn random_image(seed: u64) -> Vec<f32> {
        let mut rng = Prng::new(seed);
        (0..3 * 32 * 32).map(|_| rng.next_f32_signed()).collect()
    }

    #[test]
    fn accelerator_matches_golden_bit_exactly() {
        let cfg = SdtModelConfig::tiny();
        let model = QuantizedModel::random(&cfg, 11);
        let golden = GoldenExecutor::new(&model).infer(&random_image(4));
        let mut accel = Accelerator::new(model.clone(), AccelConfig::small());
        let report = accel.infer(&random_image(4)).unwrap();
        assert_eq!(report.logits, golden.logits, "encoded datapath != golden");
    }

    #[test]
    fn repeated_inference_is_deterministic() {
        let cfg = SdtModelConfig::tiny();
        let model = QuantizedModel::random(&cfg, 11);
        let mut accel = Accelerator::new(model, AccelConfig::small());
        let a = accel.infer(&random_image(5)).unwrap();
        let b = accel.infer(&random_image(5)).unwrap();
        assert_eq!(a.logits, b.logits);
        assert_eq!(a.total.cycles, b.total.cycles);
    }

    #[test]
    fn bitmap_mode_same_logits_more_cycles() {
        let cfg = SdtModelConfig::tiny();
        let model = QuantizedModel::random(&cfg, 11);
        let img = random_image(6);
        let mut enc = Accelerator::new(model.clone(), AccelConfig::small());
        let mut bmp = Accelerator::with_mode(model, AccelConfig::small(), DatapathMode::Bitmap);
        let r1 = enc.infer(&img).unwrap();
        let r2 = bmp.infer(&img).unwrap();
        assert_eq!(r1.logits, r2.logits);
        assert!(
            r2.total.cycles > r1.total.cycles,
            "bitmap {} !> encoded {}",
            r2.total.cycles,
            r1.total.cycles
        );
    }

    #[test]
    fn report_contains_fig6_modules() {
        let cfg = SdtModelConfig::tiny();
        let model = QuantizedModel::random(&cfg, 11);
        let mut accel = Accelerator::new(model, AccelConfig::small());
        let r = accel.infer(&random_image(7)).unwrap();
        let names: Vec<&str> = r.sparsity.iter().map(|(n, _)| n.as_str()).collect();
        for want in ["block0.q.spikes", "block0.k.spikes", "block0.v.spikes", "block0.sdsa.spikes"] {
            assert!(names.contains(&want), "missing {want}");
        }
        assert!(r.gsops > 0.0);
        assert!(r.gsop_per_w > 0.0);
    }
}
