//! The Controller (Fig. 1): sequences the SPS Core, the SDEB Cores and the
//! head over all timesteps of an inference, owns the buffer complement, and
//! assembles the final [`RunReport`].
//!
//! Two execution strategies are available ([`ExecMode`]):
//!
//! * **Overlapped** (default) — the core pipeline the paper's Fig. 1
//!   implies, generalized over the configured
//!   [`CoreTopology`](crate::hw::CoreTopology): the SPS stage of timestep
//!   `t+1` runs concurrently with the SDEB stage of timestep `t` against
//!   an ESS buffer ring (the paper's ping/pong pair at depth 2), and each
//!   block's SDSA heads are mapped across the SDEB cores' comparator
//!   arrays by the [`Mapper`](super::mapper::Mapper) scheduler. Executed
//!   by [`super::executor`]; the report carries the executed
//!   [`PipelineExecution`](super::executor::PipelineExecution).
//! * **Serial** — every phase charged back to back on one timeline (the
//!   conservative accounting this repo used originally). Kept as the
//!   ablation baseline; logits are bit-identical to the overlapped path.
//!
//! The accelerator is a **steady-state runtime**: it owns a persistent
//! [`WorkerPool`] (the SPS producer and SMAM head shards never spawn OS
//! threads per call), per-stage [`ExecScratch`] pools (arenas and tensors
//! recycle across timesteps, blocks and requests), and the modelled
//! [`BufferSet`]. [`Accelerator::infer_batch`] additionally runs a
//! released batch stage-major — every image through a block back to back
//! while that block's weight working set is hot — with per-image
//! [`RunReport`]s bit-identical to the per-call path.

use anyhow::{anyhow, Result};

use crate::hw::{AccelConfig, EnergyModel, UnitStats};
use crate::quant::{QFormat, QTensor, ACT_FRAC, MEM_BITS};
use crate::scratch::{ExecScratch, ScratchStats};
use crate::units::SpikeEncodingArray;
use crate::model::QuantizedModel;
use crate::util::div_ceil;

use super::buffers::BufferSet;
use super::decode::{argmax, DecodeReport, DecodeSession};
use super::dma::DmaEngine;
use super::executor::{self, PipelineExecution};
use super::mapper::{Mapper, MappingPolicy};
use super::report::{RunReport, StatSink};
use super::sdeb_core::SdebCore;
use super::sps_core::SpsCore;
use super::workers::WorkerPool;

/// Which datapath the spike-consuming units use (ablation A1).
///
/// Orthogonal to [`EngineSelect`](crate::hw::EngineSelect): the engine
/// policy picks *how* the encoded datapath executes (CSR address
/// streaming vs the packed-`u64` word engine, bit-identically), and is
/// only consulted under [`DatapathMode::Encoded`]. `DatapathMode::Bitmap`
/// is the scalar per-position ablation baseline and always charges the
/// conventional zero-checking cost regardless of the engine setting.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DatapathMode {
    /// The paper's position-encoded spike processing (engine-selectable).
    Encoded,
    /// Conventional bitmap processing (zero-checking every position).
    Bitmap,
}

/// How the controller schedules the cores over timesteps.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum ExecMode {
    /// Overlapped SPS→SDEB pipeline with the SDSA heads mapped across the
    /// topology's SDEB cores (default; the paper's two-core instance at
    /// the default [`CoreTopology`](crate::hw::CoreTopology)).
    #[default]
    Overlapped,
    /// Serial phase charging (the `--serial` ablation escape hatch).
    Serial,
}

/// One batch lane's unit complement: its own LIF state so a batched
/// forward can interleave images stage-major while every image still sees
/// exactly the per-call temporal dynamics.
struct BatchLane {
    sps: SpsCore,
    sdebs: Vec<SdebCore>,
    sea_head: SpikeEncodingArray,
}

impl BatchLane {
    fn new(model: &QuantizedModel) -> Self {
        let cfg = &model.cfg;
        let params = cfg.lif_params();
        let (l, d) = (cfg.num_tokens(), cfg.embed_dim);
        Self {
            sps: SpsCore::new(model, params),
            sdebs: (0..cfg.num_blocks)
                .map(|i| SdebCore::new(i, l, d, cfg.mlp_hidden, cfg.attn_v_th, params))
                .collect(),
            sea_head: SpikeEncodingArray::new(d, l, params),
        }
    }

    fn reset(&mut self) {
        self.sps.reset();
        for s in &mut self.sdebs {
            s.reset();
        }
        self.sea_head.reset();
    }
}

/// One in-flight continuous-batching request: a checked-out [`BatchLane`]
/// plus everything [`Accelerator::run_batched`] keeps per image, so a
/// request admitted mid-flight accumulates exactly the accounting a
/// batch-resident image would — retirement assembles a [`RunReport`]
/// bit-identical to the per-call path.
struct ActiveLane {
    id: u64,
    lane: BatchLane,
    /// Next timestep this lane will execute (retires at `cfg.timesteps`).
    t: usize,
    qimg: QTensor,
    io_in: UnitStats,
    sps_sink: StatSink,
    sdeb_sink: StatSink,
    sps_per_t: Vec<u64>,
    sdeb_segs: Vec<Vec<u64>>,
    head_counts: Vec<u64>,
}

/// One in-flight autoregressive decode request: a checked-out
/// [`DecodeSession`] plus the report bookkeeping. Advanced one token per
/// [`Accelerator::lane_step`] pass, so decode requests interleave with
/// whatever else is in flight and their per-token latencies are observable
/// at lane granularity.
struct ActiveDecode {
    id: u64,
    session: DecodeSession,
    prompt: Vec<usize>,
    /// Prompt tokens consumed so far (prefill cursor).
    fed: usize,
    /// Generation steps still to run after prefill.
    remaining: usize,
    /// Next token to feed (the previous position's argmax).
    next_token: Option<usize>,
    generated: Vec<usize>,
    prefill_cycles: u64,
    token_cycles: Vec<u64>,
}

impl ActiveDecode {
    /// Feed exactly one token position (prompt or generated) through the
    /// session — the per-pass work quantum of a decode lane.
    fn advance(&mut self, model: &QuantizedModel, hw: &AccelConfig) -> Result<()> {
        if self.fed < self.prompt.len() {
            let logits = self.session.step(model, hw, self.prompt[self.fed])?;
            self.fed += 1;
            if self.fed == self.prompt.len() {
                self.prefill_cycles = self.session.cycles();
                self.next_token = Some(argmax(&logits));
            }
        } else if self.remaining > 0 {
            let tok = self.next_token.take().expect("argmax from the previous position");
            self.generated.push(tok);
            let before = self.session.cycles();
            let (next, _) = self.session.decode_step(model, hw, tok)?;
            self.token_cycles.push(self.session.cycles() - before);
            self.next_token = Some(next);
            self.remaining -= 1;
        }
        Ok(())
    }

    fn finished(&self) -> bool {
        self.fed == self.prompt.len() && self.remaining == 0
    }

    /// Assemble the completed lane's report and hand the session back.
    fn retire(self) -> (u64, DecodeReport, DecodeSession) {
        let report = DecodeReport {
            prompt_len: self.prompt.len(),
            gen_len: self.generated.len(),
            generated: self.generated,
            prefill_cycles: self.prefill_cycles,
            token_cycles: self.token_cycles,
            total_cycles: self.session.cycles(),
            cache_words: self.session.cache_words(),
            sparsity: self.session.sink().sparsity_table(),
        };
        (self.id, report, self.session)
    }
}

/// A full accelerator instance bound to one quantized model.
pub struct Accelerator {
    /// Structural hardware parameters of this instance.
    pub hw: AccelConfig,
    /// Per-operation energy model used for the report's power numbers.
    pub energy: EnergyModel,
    /// Datapath selection (encoded vs bitmap baseline).
    pub mode: DatapathMode,
    /// Execution strategy (overlapped pipeline vs serial charging).
    pub exec: ExecMode,
    /// The work-unit → core mapping scheduler (topology + policy).
    mapper: Mapper,
    model: QuantizedModel,
    sps: SpsCore,
    sdebs: Vec<SdebCore>,
    sea_head: SpikeEncodingArray,
    /// Persistent SDEB worker pool shared by the overlapped executor's SPS
    /// producer and the SMAM head shards.
    pool: WorkerPool,
    /// Modelled SRAM complement, persistent across requests (counters are
    /// reset per inference).
    buffers: BufferSet,
    /// SPS-stage scratch pool (owned by the producer side).
    scratch_sps: ExecScratch,
    /// SDEB-stage + head scratch pool (owned by the consumer side).
    scratch_sdeb: ExecScratch,
    /// Per-image unit lanes for [`Self::infer_batch`], grown on demand and
    /// reused across batches.
    lanes: Vec<BatchLane>,
    /// In-flight continuous-batching requests ([`Self::lane_admit`] /
    /// [`Self::lane_step`]); empty outside continuous serving.
    active: Vec<ActiveLane>,
    /// Pooled decode sessions recycled (via reset) across
    /// [`Self::decode`] calls and decode lanes.
    decode_pool: Vec<DecodeSession>,
    /// In-flight autoregressive decode requests, advanced one token per
    /// [`Self::lane_step`] pass alongside the vision lanes.
    decode_active: Vec<ActiveDecode>,
    /// Completed decode lanes awaiting [`Self::take_decoded`].
    decode_done: Vec<(u64, DecodeReport)>,
}

impl Accelerator {
    /// Overlapped, encoded-datapath instance (the default configuration).
    pub fn new(model: QuantizedModel, hw: AccelConfig) -> Self {
        Self::with_modes(model, hw, DatapathMode::Encoded, ExecMode::Overlapped)
    }

    /// Choose the datapath, keeping the overlapped executor.
    pub fn with_mode(model: QuantizedModel, hw: AccelConfig, mode: DatapathMode) -> Self {
        Self::with_modes(model, hw, mode, ExecMode::Overlapped)
    }

    /// Choose both the datapath and the execution strategy.
    pub fn with_modes(
        model: QuantizedModel,
        hw: AccelConfig,
        mode: DatapathMode,
        exec: ExecMode,
    ) -> Self {
        Self::with_runtime(model, hw, mode, exec, 0)
    }

    /// Choose the datapath, execution strategy and worker-pool size in
    /// one shot (`pool_workers == 0` keeps the model-derived default) —
    /// no throwaway default pool is spawned first.
    pub fn with_runtime(
        model: QuantizedModel,
        hw: AccelConfig,
        mode: DatapathMode,
        exec: ExecMode,
        pool_workers: usize,
    ) -> Self {
        let cfg = &model.cfg;
        let params = cfg.lif_params();
        let (l, d) = (cfg.num_tokens(), cfg.embed_dim);
        let sps = SpsCore::new(&model, params);
        let sdebs: Vec<SdebCore> = (0..cfg.num_blocks)
            .map(|i| SdebCore::new(i, l, d, cfg.mlp_hidden, cfg.attn_v_th, params))
            .collect();
        let sea_head = SpikeEncodingArray::new(d, l, params);
        // Default pool sizing: the long-lived SPS producer occupies one
        // worker, and each SDSA pass spawns `sdeb_cores - 1` head jobs
        // (the consumer thread runs the first core inline) — so
        // `sdeb_cores` workers give the full modelled fan-out. Correctness
        // never depends on this: a short pool degrades to caller-helping
        // inline execution, bit-identically.
        let workers = if pool_workers > 0 {
            pool_workers
        } else {
            cfg.num_blocks.max(hw.topology.sdeb_cores).max(1)
        };
        let pool = WorkerPool::new(workers);
        let buffers = BufferSet::new(&hw);
        let mapper = Mapper::new(cfg.num_heads, hw.topology, MappingPolicy::default());
        Self {
            hw,
            energy: EnergyModel::default(),
            mode,
            exec,
            mapper,
            model,
            sps,
            sdebs,
            sea_head,
            pool,
            buffers,
            scratch_sps: ExecScratch::new(),
            scratch_sdeb: ExecScratch::new(),
            lanes: Vec::new(),
            active: Vec::new(),
            decode_pool: Vec::new(),
            decode_active: Vec::new(),
            decode_done: Vec::new(),
        }
    }

    /// Resize the persistent worker pool (clamped to at least 1 thread;
    /// a no-op when the pool already has that many workers). The
    /// CLI/bench `--workers` knob; construction-time sizing should use
    /// [`Self::with_runtime`] instead, which never spawns a throwaway
    /// default pool.
    pub fn with_pool_workers(mut self, workers: usize) -> Self {
        let workers = workers.max(1);
        if workers != self.pool.workers() {
            self.pool = WorkerPool::new(workers);
        }
        self
    }

    /// Number of persistent worker-pool threads.
    pub fn pool_workers(&self) -> usize {
        self.pool.workers()
    }

    /// Choose the SDSA head→core mapping policy (default
    /// [`MappingPolicy::HeadRoundRobin`], the paper's static assignment).
    /// The topology itself comes from
    /// [`AccelConfig::topology`](crate::hw::AccelConfig).
    pub fn with_mapping(mut self, policy: MappingPolicy) -> Self {
        self.mapper.policy = policy;
        self
    }

    /// Combined scratch-pool hit/miss counters of both pipeline stages —
    /// the steady-state claim's measurement: after warm-up, `misses`
    /// stops growing.
    pub fn scratch_stats(&self) -> ScratchStats {
        self.scratch_sps.stats().merged(self.scratch_sdeb.stats())
    }

    /// Objects resting in both stage pools between requests — constant in
    /// steady state; growth across warm requests means a put/take leak
    /// somewhere in the datapath.
    pub fn pooled_scratch_objects(&self) -> usize {
        self.scratch_sps.pooled_objects() + self.scratch_sdeb.pooled_objects()
    }

    /// The quantized model this instance is bound to.
    pub fn model(&self) -> &QuantizedModel {
        &self.model
    }

    /// The mapping scheduler the overlapped executor uses (head count from
    /// the model, topology from the hardware config, policy from
    /// [`Self::with_mapping`]).
    ///
    /// Note the semantic shift from the pre-topology executor: the SDSA
    /// shard width is now `hw.topology.sdeb_cores` (default 2, the
    /// paper's instance) rather than implicitly the encoder block count —
    /// identical for the paper's two-block models; a model with a
    /// different block count should set the topology explicitly.
    pub fn mapper(&self) -> Mapper {
        self.mapper
    }

    fn reset(&mut self) {
        self.sps.reset();
        for s in &mut self.sdebs {
            s.reset();
        }
        self.sea_head.reset();
    }

    /// Quantize one image into a recycled tensor (same values as
    /// `QTensor::from_f32`).
    fn quantize_image(scratch: &mut ExecScratch, image: &[f32], shape: &[usize]) -> QTensor {
        let act = QFormat::new(MEM_BITS, ACT_FRAC);
        let mut qimg = scratch.take_tensor(shape, ACT_FRAC);
        for (o, &v) in qimg.data.iter_mut().zip(image) {
            *o = act.from_f32(v);
        }
        qimg
    }

    /// Host/output-side classification head on pooled rates.
    fn head_logits(&self, head_counts: &[u64]) -> Vec<f32> {
        let cfg = &self.model.cfg;
        let (l, d) = (cfg.num_tokens(), cfg.embed_dim);
        let denom = (cfg.timesteps * l) as f32; // as-ok: reporting rate, not datapath state
        let mut logits = self.model.head_b.clone();
        for c in 0..d {
            let rate = head_counts[c] as f32 / denom; // as-ok: reporting rate, not datapath state
            if rate != 0.0 {
                for k in 0..cfg.num_classes {
                    logits[k] += rate * self.model.head_w[c * cfg.num_classes + k];
                }
            }
        }
        logits
    }

    /// Output transfer stats (logits as f32).
    fn io_output_stats(&self) -> UnitStats {
        let out_bytes = self.model.cfg.num_classes * 4;
        UnitStats {
            cycles: div_ceil(out_bytes as u64, self.hw.dram_bytes_per_cycle as u64), // as-ok: widening for 64-bit stat/cycle math
            dram_bytes: out_bytes as u64, // as-ok: widening for 64-bit stat/cycle math
            ..Default::default()
        }
    }

    /// Run a full inference of one image (f32 CHW pixels).
    pub fn infer(&mut self, image: &[f32]) -> Result<RunReport> {
        if !self.active.is_empty() {
            return Err(anyhow!(
                "continuous lanes in flight; drain lane_step before infer"
            ));
        }
        let cfg = self.model.cfg.clone();
        assert_eq!(image.len(), cfg.in_channels * cfg.img_size * cfg.img_size);
        self.reset();
        self.buffers.reset();

        let mut sink = StatSink::new();

        // External input transfer: 10-bit activations packed 2 B/value.
        let in_bytes = image.len() * 2;
        let io_in = self.buffers.load_external(in_bytes, &self.hw)?;
        let io_in_cycles = io_in.cycles;
        sink.add("io.input", io_in);

        let qimg = Self::quantize_image(
            &mut self.scratch_sps,
            image,
            &[cfg.in_channels, cfg.img_size, cfg.img_size],
        );

        let (head_counts, execution) = match self.exec {
            ExecMode::Overlapped => {
                let outcome = executor::run_overlapped(
                    &self.model,
                    &self.hw,
                    self.mode,
                    self.mapper,
                    &self.pool,
                    &mut self.sps,
                    &mut self.sdebs,
                    &mut self.sea_head,
                    &mut self.buffers,
                    &mut self.scratch_sps,
                    &mut self.scratch_sdeb,
                    &qimg,
                )?;
                sink.absorb(outcome.sink);
                (outcome.head_counts, Some((outcome.sps_per_timestep, outcome.sdeb_segments)))
            }
            ExecMode::Serial => {
                let counts = self.run_serial(&qimg, &mut sink)?;
                (counts, None)
            }
        };
        self.scratch_sps.put_tensor(qimg);

        let logits = self.head_logits(&head_counts);

        let io_out = self.io_output_stats();
        let io_out_cycles = io_out.cycles;
        sink.add("io.output", io_out);

        Ok(match execution {
            Some((sps_per, sdeb_segments)) => {
                // Weight-streaming memory lane: plan the block working
                // sets' movement over the shared bus and gate the
                // executed schedule on weights-resident.
                let dma = DmaEngine::new(&self.model, &self.hw);
                let mut exec = PipelineExecution::with_memory(
                    io_in_cycles,
                    io_out_cycles,
                    sps_per,
                    sdeb_segments,
                    &self.hw.topology,
                    Some(&dma),
                );
                if let Some(m) = exec.memory.as_mut() {
                    // SDEB-input store traffic measured by the cores
                    // (words are 2 B, like streamed weights).
                    m.spike_bytes_full = sink.spike_full_words * super::dma::WEIGHT_STREAM_BYTES;
                    m.spike_bytes_moved =
                        sink.spike_moved_words * super::dma::WEIGHT_STREAM_BYTES;
                    // The streamed words pass through the weight buffer.
                    self.buffers
                        .weight
                        .record_stream_writes(m.weight_bytes() / super::dma::WEIGHT_STREAM_BYTES);
                }
                RunReport::from_sink_pipelined(logits, sink, exec, &self.hw, &self.energy)
            }
            None => RunReport::from_sink(logits, sink, &self.hw, &self.energy),
        })
    }

    /// Batched forward with batch-level weight reuse: the whole batch
    /// walks each pipeline stage back to back (SPS, then block 0 for
    /// every image, block 1 for every image, ..., head), so a stage's
    /// weight working set is loaded once per batch instead of once per
    /// image. Per-image [`RunReport`]s — logits, `UnitStats`, phase
    /// breakdown and executed pipeline schedule — are bit-identical to
    /// calling [`Self::infer`] per image, because every image runs on its
    /// own unit lane (own LIF state) and all accounting is image-local.
    ///
    /// Serial-mode instances (and batches of one) fall back to the
    /// per-call path.
    pub fn infer_batch(&mut self, images: &[Vec<f32>]) -> Result<Vec<RunReport>> {
        if images.len() <= 1 || self.exec == ExecMode::Serial {
            return images.iter().map(|img| self.infer(img)).collect();
        }
        self.run_batched(images)
    }

    /// The stage-major batched loop behind [`Self::infer_batch`].
    fn run_batched(&mut self, images: &[Vec<f32>]) -> Result<Vec<RunReport>> {
        if !self.active.is_empty() {
            return Err(anyhow!(
                "continuous lanes in flight; drain lane_step before infer_batch"
            ));
        }
        let cfg = self.model.cfg.clone();
        let n = images.len();
        let (l, d) = (cfg.num_tokens(), cfg.embed_dim);
        let mapper = self.mapper;
        let sdeb_rings = self.buffers.sdeb.len().max(1);
        while self.lanes.len() < n {
            self.lanes.push(BatchLane::new(&self.model));
        }

        // Per-image admission: input transfer + quantization, exactly as
        // the per-call path charges them.
        let mut io_ins = Vec::with_capacity(n);
        let mut qimgs = Vec::with_capacity(n);
        for img in images {
            assert_eq!(img.len(), cfg.in_channels * cfg.img_size * cfg.img_size);
            self.buffers.reset();
            io_ins.push(self.buffers.load_external(img.len() * 2, &self.hw)?);
            qimgs.push(Self::quantize_image(
                &mut self.scratch_sps,
                img,
                &[cfg.in_channels, cfg.img_size, cfg.img_size],
            ));
        }
        for lane in self.lanes[..n].iter_mut() {
            lane.reset();
        }

        let mut sps_sinks: Vec<StatSink> = (0..n).map(|_| StatSink::new()).collect();
        let mut sdeb_sinks: Vec<StatSink> = (0..n).map(|_| StatSink::new()).collect();
        let mut sps_per_t: Vec<Vec<u64>> =
            (0..n).map(|_| Vec::with_capacity(cfg.timesteps)).collect();
        // Per-image, per-timestep SDEB segments (one per block + head),
        // mirroring the per-call executor so the memory lane gates the
        // same block boundaries and reports stay bit-identical.
        let mut sdeb_segs: Vec<Vec<Vec<u64>>> =
            (0..n).map(|_| Vec::with_capacity(cfg.timesteps)).collect();
        let mut head_counts: Vec<Vec<u64>> = (0..n).map(|_| vec![0u64; d]).collect();
        let mut streams: Vec<Option<QTensor>> = (0..n).map(|_| None).collect();

        for t in 0..cfg.timesteps {
            // SPS stage, whole batch (conv weight working set stays hot).
            for i in 0..n {
                let before = sps_sinks[i].phases.total().cycles;
                // Panic parity with the overlapped executor's producer
                // task: a panicking SPS stage surfaces as an inference
                // error from `infer_batch` too, so batched and per-call
                // inference fail identically on a corrupt model.
                let sps_res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    self.lanes[i].sps.run_timestep(
                        &self.model,
                        &qimgs[i],
                        &self.hw,
                        self.mode,
                        t,
                        &mut self.buffers.sps,
                        &mut sps_sinks[i],
                        &mut self.scratch_sps,
                    )
                }));
                let (u0_cl, enc3) = match sps_res {
                    Ok(res) => res?,
                    Err(_) => return Err(anyhow!("SPS pipeline stage panicked")),
                };
                sps_per_t[i].push(sps_sinks[i].phases.total().cycles - before);
                let mut u = self.scratch_sps.take_tensor(&[l, d], ACT_FRAC);
                executor::u0_to_token_major_into(&u0_cl, l, d, &mut u);
                self.scratch_sps.put_tensor(u0_cl);
                self.scratch_sps.put_enc(enc3);
                streams[i] = Some(u);
            }
            // SDEB stage, block-major: every image through block `bi`
            // back to back while its Q/K/V/O/MLP weights are hot.
            let mut seg_cursor: Vec<u64> =
                sdeb_sinks.iter().map(|s| s.phases.total().cycles).collect();
            for i in 0..n {
                sdeb_segs[i].push(Vec::with_capacity(cfg.num_blocks + 1));
            }
            for bi in 0..cfg.num_blocks {
                for i in 0..n {
                    let u = streams[i].take().expect("token tensor present");
                    let u = self.lanes[i].sdebs[bi].run_timestep(
                        &self.model.blocks[bi],
                        u,
                        &self.hw,
                        self.mode,
                        t,
                        Some(mapper),
                        Some(&self.pool),
                        &mut self.buffers.sdeb[bi % sdeb_rings],
                        &mut sdeb_sinks[i],
                        &mut self.scratch_sdeb,
                    )?;
                    streams[i] = Some(u);
                    let now = sdeb_sinks[i].phases.total().cycles;
                    sdeb_segs[i].last_mut().unwrap().push(now - seg_cursor[i]);
                    seg_cursor[i] = now;
                }
            }
            // Head readout, whole batch.
            for i in 0..n {
                let u = streams[i].take().expect("token tensor present");
                executor::head_readout(
                    &mut self.lanes[i].sea_head,
                    &u,
                    l,
                    d,
                    &self.hw,
                    &mut sdeb_sinks[i],
                    &mut head_counts[i],
                    &mut self.scratch_sdeb,
                );
                self.scratch_sps.put_tensor(u);
                let now = sdeb_sinks[i].phases.total().cycles;
                sdeb_segs[i].last_mut().unwrap().push(now - seg_cursor[i]);
                seg_cursor[i] = now;
            }
        }

        // Assemble per-image reports in exactly the per-call order:
        // io.input, SPS phases, SDEB/head phases, io.output. The memory
        // lane is per-image too (each image streams its own weight
        // traffic, exactly as the per-call path charges it — batch-level
        // weight reuse is a host-side optimization, not a modelled one).
        let dma = DmaEngine::new(&self.model, &self.hw);
        let mut reports = Vec::with_capacity(n);
        for i in 0..n {
            let mut sink = StatSink::new();
            let io_in = io_ins[i];
            let io_in_cycles = io_in.cycles;
            sink.add("io.input", io_in);
            sink.absorb(std::mem::take(&mut sps_sinks[i]));
            sink.absorb(std::mem::take(&mut sdeb_sinks[i]));
            let logits = self.head_logits(&head_counts[i]);
            let io_out = self.io_output_stats();
            let io_out_cycles = io_out.cycles;
            sink.add("io.output", io_out);
            let mut exec = PipelineExecution::with_memory(
                io_in_cycles,
                io_out_cycles,
                std::mem::take(&mut sps_per_t[i]),
                std::mem::take(&mut sdeb_segs[i]),
                &self.hw.topology,
                Some(&dma),
            );
            if let Some(m) = exec.memory.as_mut() {
                m.spike_bytes_full = sink.spike_full_words * super::dma::WEIGHT_STREAM_BYTES;
                m.spike_bytes_moved = sink.spike_moved_words * super::dma::WEIGHT_STREAM_BYTES;
                self.buffers
                    .weight
                    .record_stream_writes(m.weight_bytes() / super::dma::WEIGHT_STREAM_BYTES);
            }
            reports.push(RunReport::from_sink_pipelined(logits, sink, exec, &self.hw, &self.energy));
        }
        for qimg in qimgs {
            self.scratch_sps.put_tensor(qimg);
        }
        Ok(reports)
    }

    /// Admit one request into a continuous-batching lane. The request
    /// joins the in-flight set at timestep 0 and advances one timestep per
    /// [`Self::lane_step`] pass alongside whatever else is in flight —
    /// admission happens *between stage passes*, not at batch boundaries.
    ///
    /// Input transfer and quantization are charged here, exactly as
    /// [`Self::infer_batch`] charges them at batch admission. Requires the
    /// overlapped executor ([`ExecMode::Overlapped`]); request ids must be
    /// unique within the in-flight set.
    pub fn lane_admit(&mut self, id: u64, image: &[f32]) -> Result<()> {
        if self.exec == ExecMode::Serial {
            return Err(anyhow!("continuous lanes require the overlapped executor"));
        }
        let cfg = self.model.cfg.clone();
        let want = cfg.in_channels * cfg.img_size * cfg.img_size;
        if image.len() != want {
            return Err(anyhow!(
                "lane_admit: image has {} pixels, model wants {want}",
                image.len()
            ));
        }
        if self.active.iter().any(|a| a.id == id) {
            return Err(anyhow!("lane_admit: request id {id} already in flight"));
        }
        self.buffers.reset();
        let io_in = self.buffers.load_external(image.len() * 2, &self.hw)?;
        let qimg = Self::quantize_image(
            &mut self.scratch_sps,
            image,
            &[cfg.in_channels, cfg.img_size, cfg.img_size],
        );
        let mut lane = self.lanes.pop().unwrap_or_else(|| BatchLane::new(&self.model));
        lane.reset();
        self.active.push(ActiveLane {
            id,
            lane,
            t: 0,
            qimg,
            io_in,
            sps_sink: StatSink::new(),
            sdeb_sink: StatSink::new(),
            sps_per_t: Vec::with_capacity(cfg.timesteps),
            sdeb_segs: Vec::with_capacity(cfg.timesteps),
            head_counts: vec![0u64; cfg.embed_dim],
        });
        Ok(())
    }

    /// Advance every in-flight lane by one timestep (stage-major across
    /// the set, like one timestep of [`Self::run_batched`]) and retire the
    /// lanes that completed their final timestep, returning their
    /// `(id, report)` pairs. Reports are bit-identical to a fresh
    /// [`Self::infer`] of the same image.
    ///
    /// On error the whole in-flight set is aborted (abort semantics: the
    /// partially-run requests are dropped and their unit lanes are
    /// rebuilt on demand); the caller owns re-submission policy.
    pub fn lane_step(&mut self) -> Result<Vec<(u64, RunReport)>> {
        let mut done = Vec::new();
        if !self.active.is_empty() {
            let timesteps = self.model.cfg.timesteps;
            let mut active = std::mem::take(&mut self.active);
            if let Err(e) = self.step_pass(&mut active) {
                drop(active);
                return Err(e);
            }
            for a in active {
                if a.t >= timesteps {
                    done.push(self.retire_lane(a));
                } else {
                    self.active.push(a);
                }
            }
        }
        self.step_decode_lanes()?;
        Ok(done)
    }

    /// Number of requests currently in flight on continuous lanes.
    pub fn lanes_in_flight(&self) -> usize {
        self.active.len()
    }

    /// Number of autoregressive decode requests currently in flight.
    pub fn decode_lanes_in_flight(&self) -> usize {
        self.decode_active.len()
    }

    /// Run one full autoregressive request serially: prefill the prompt,
    /// then greedily generate `gen_len` tokens, each decode step masking
    /// the new position against the session's spike-stream KV cache.
    /// Bit-identical to driving a [`DecodeSession`] by hand (and, on the
    /// logits, to the dense golden decoder) — the session is checked out
    /// of the same pool the decode lanes use, so steady-state calls
    /// allocate nothing.
    pub fn decode(&mut self, prompt: &[usize], gen_len: usize) -> Result<DecodeReport> {
        let max_seq_len = self.model.cfg.decoder_shape()?.max_seq_len;
        if prompt.is_empty() {
            return Err(anyhow!("decode: empty prompt"));
        }
        if prompt.len() + gen_len > max_seq_len {
            return Err(anyhow!(
                "decode: {} prompt + {gen_len} generated tokens exceed max_seq_len {max_seq_len}",
                prompt.len()
            ));
        }
        let mut session = self.checkout_decode_session()?;
        let logits = session.prefill(&self.model, &self.hw, prompt)?;
        let prefill_cycles = session.cycles();
        let mut next = argmax(&logits);
        let mut generated = Vec::with_capacity(gen_len);
        let mut token_cycles = Vec::with_capacity(gen_len);
        for _ in 0..gen_len {
            generated.push(next);
            let before = session.cycles();
            let (n2, _) = session.decode_step(&self.model, &self.hw, next)?;
            token_cycles.push(session.cycles() - before);
            next = n2;
        }
        let report = DecodeReport {
            prompt_len: prompt.len(),
            gen_len,
            generated,
            prefill_cycles,
            token_cycles,
            total_cycles: session.cycles(),
            cache_words: session.cache_words(),
            sparsity: session.sink().sparsity_table(),
        };
        session.reset();
        self.decode_pool.push(session);
        Ok(report)
    }

    /// Admit one autoregressive request into a decode lane. The request
    /// advances one token position per [`Self::lane_step`] pass — prompt
    /// tokens first (prefill), then greedy generation — interleaved with
    /// any vision lanes in flight. Completed requests are queued for
    /// [`Self::take_decoded`]. Requires a decoder-shaped model; ids must
    /// be unique within the in-flight decode set.
    pub fn lane_admit_decode(&mut self, id: u64, prompt: &[usize], gen_len: usize) -> Result<()> {
        let max_seq_len = self.model.cfg.decoder_shape()?.max_seq_len;
        if prompt.is_empty() {
            return Err(anyhow!("lane_admit_decode: empty prompt"));
        }
        if prompt.len() + gen_len > max_seq_len {
            return Err(anyhow!(
                "lane_admit_decode: {} prompt + {gen_len} generated tokens exceed max_seq_len {max_seq_len}",
                prompt.len()
            ));
        }
        if self.decode_active.iter().any(|a| a.id == id) {
            return Err(anyhow!("lane_admit_decode: request id {id} already in flight"));
        }
        let session = self.checkout_decode_session()?;
        self.decode_active.push(ActiveDecode {
            id,
            session,
            prompt: prompt.to_vec(),
            fed: 0,
            remaining: gen_len,
            next_token: None,
            generated: Vec::with_capacity(gen_len),
            prefill_cycles: 0,
            token_cycles: Vec::with_capacity(gen_len),
        });
        Ok(())
    }

    /// Drain the completed decode-lane reports accumulated by
    /// [`Self::lane_step`] since the last drain.
    pub fn take_decoded(&mut self) -> Vec<(u64, DecodeReport)> {
        std::mem::take(&mut self.decode_done)
    }

    /// Check a pooled decode session out (or build the first one).
    /// Pooled sessions were reset on return, so checkout is free.
    fn checkout_decode_session(&mut self) -> Result<DecodeSession> {
        match self.decode_pool.pop() {
            Some(s) => Ok(s),
            None => DecodeSession::new(&self.model, &self.hw),
        }
    }

    /// Advance every in-flight decode lane by one token position and
    /// retire the finished ones into the [`Self::take_decoded`] queue.
    /// Abort semantics mirror the vision lanes: on error the whole
    /// in-flight decode set is dropped.
    fn step_decode_lanes(&mut self) -> Result<()> {
        if self.decode_active.is_empty() {
            return Ok(());
        }
        let mut lanes = std::mem::take(&mut self.decode_active);
        for a in lanes.iter_mut() {
            a.advance(&self.model, &self.hw)?;
        }
        for a in lanes {
            if a.finished() {
                let (id, report, mut session) = a.retire();
                session.reset();
                self.decode_pool.push(session);
                self.decode_done.push((id, report));
            } else {
                self.decode_active.push(a);
            }
        }
        Ok(())
    }

    /// One stage-major pass over the in-flight set: SPS for every lane,
    /// then every lane through block 0, block 1, ..., then head readout —
    /// the [`Self::run_batched`] timestep body, except each lane runs its
    /// *own* timestep `a.t` (lanes admitted mid-flight lag the rest).
    fn step_pass(&mut self, active: &mut [ActiveLane]) -> Result<()> {
        let cfg = self.model.cfg.clone();
        let (l, d) = (cfg.num_tokens(), cfg.embed_dim);
        let mapper = self.mapper;
        let sdeb_rings = self.buffers.sdeb.len().max(1);
        let n = active.len();
        let mut streams: Vec<Option<QTensor>> = (0..n).map(|_| None).collect();

        // SPS stage, every in-flight lane (conv weights stay hot).
        for (i, a) in active.iter_mut().enumerate() {
            let before = a.sps_sink.phases.total().cycles;
            // Panic parity with the overlapped executor's producer task
            // (see `run_batched`).
            let sps_res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                a.lane.sps.run_timestep(
                    &self.model,
                    &a.qimg,
                    &self.hw,
                    self.mode,
                    a.t,
                    &mut self.buffers.sps,
                    &mut a.sps_sink,
                    &mut self.scratch_sps,
                )
            }));
            let (u0_cl, enc3) = match sps_res {
                Ok(res) => res?,
                Err(_) => return Err(anyhow!("SPS pipeline stage panicked")),
            };
            a.sps_per_t.push(a.sps_sink.phases.total().cycles - before);
            let mut u = self.scratch_sps.take_tensor(&[l, d], ACT_FRAC);
            executor::u0_to_token_major_into(&u0_cl, l, d, &mut u);
            self.scratch_sps.put_tensor(u0_cl);
            self.scratch_sps.put_enc(enc3);
            streams[i] = Some(u);
        }
        // SDEB stage, block-major across the in-flight set.
        let mut seg_cursor: Vec<u64> =
            active.iter().map(|a| a.sdeb_sink.phases.total().cycles).collect();
        for a in active.iter_mut() {
            a.sdeb_segs.push(Vec::with_capacity(cfg.num_blocks + 1));
        }
        for bi in 0..cfg.num_blocks {
            for (i, a) in active.iter_mut().enumerate() {
                let u = streams[i].take().expect("token tensor present");
                let u = a.lane.sdebs[bi].run_timestep(
                    &self.model.blocks[bi],
                    u,
                    &self.hw,
                    self.mode,
                    a.t,
                    Some(mapper),
                    Some(&self.pool),
                    &mut self.buffers.sdeb[bi % sdeb_rings],
                    &mut a.sdeb_sink,
                    &mut self.scratch_sdeb,
                )?;
                streams[i] = Some(u);
                let now = a.sdeb_sink.phases.total().cycles;
                a.sdeb_segs.last_mut().unwrap().push(now - seg_cursor[i]);
                seg_cursor[i] = now;
            }
        }
        // Head readout, then advance each lane's clock.
        for (i, a) in active.iter_mut().enumerate() {
            let u = streams[i].take().expect("token tensor present");
            executor::head_readout(
                &mut a.lane.sea_head,
                &u,
                l,
                d,
                &self.hw,
                &mut a.sdeb_sink,
                &mut a.head_counts,
                &mut self.scratch_sdeb,
            );
            self.scratch_sps.put_tensor(u);
            let now = a.sdeb_sink.phases.total().cycles;
            a.sdeb_segs.last_mut().unwrap().push(now - seg_cursor[i]);
            seg_cursor[i] = now;
            a.t += 1;
        }
        Ok(())
    }

    /// Assemble a completed lane's [`RunReport`] — the `run_batched`
    /// report assembly, verbatim — and return its unit lane to the pool.
    fn retire_lane(&mut self, a: ActiveLane) -> (u64, RunReport) {
        let mut sink = StatSink::new();
        let io_in_cycles = a.io_in.cycles;
        sink.add("io.input", a.io_in);
        sink.absorb(a.sps_sink);
        sink.absorb(a.sdeb_sink);
        let logits = self.head_logits(&a.head_counts);
        let io_out = self.io_output_stats();
        let io_out_cycles = io_out.cycles;
        sink.add("io.output", io_out);
        let dma = DmaEngine::new(&self.model, &self.hw);
        let mut exec = PipelineExecution::with_memory(
            io_in_cycles,
            io_out_cycles,
            a.sps_per_t,
            a.sdeb_segs,
            &self.hw.topology,
            Some(&dma),
        );
        if let Some(m) = exec.memory.as_mut() {
            m.spike_bytes_full = sink.spike_full_words * super::dma::WEIGHT_STREAM_BYTES;
            m.spike_bytes_moved = sink.spike_moved_words * super::dma::WEIGHT_STREAM_BYTES;
            self.buffers
                .weight
                .record_stream_writes(m.weight_bytes() / super::dma::WEIGHT_STREAM_BYTES);
        }
        self.scratch_sps.put_tensor(a.qimg);
        self.lanes.push(a.lane);
        (a.id, RunReport::from_sink_pipelined(logits, sink, exec, &self.hw, &self.energy))
    }

    /// The serial timestep loop: every phase charged back to back, no
    /// head sharding — the original conservative accounting (scratch
    /// recycling still applies; it changes host behaviour only).
    fn run_serial(&mut self, qimg: &QTensor, sink: &mut StatSink) -> Result<Vec<u64>> {
        let cfg = self.model.cfg.clone();
        let (l, d) = (cfg.num_tokens(), cfg.embed_dim);
        let mut head_counts = vec![0u64; d];

        for t in 0..cfg.timesteps {
            let (u0_cl, enc3) = self.sps.run_timestep(
                &self.model,
                qimg,
                &self.hw,
                self.mode,
                t,
                &mut self.buffers.sps,
                sink,
                &mut self.scratch_sps,
            )?;
            let mut u = self.scratch_sps.take_tensor(&[l, d], ACT_FRAC);
            executor::u0_to_token_major_into(&u0_cl, l, d, &mut u);
            self.scratch_sps.put_tensor(u0_cl);
            self.scratch_sps.put_enc(enc3);

            for (bi, core) in self.sdebs.iter_mut().enumerate() {
                u = core.run_timestep(
                    &self.model.blocks[bi],
                    u,
                    &self.hw,
                    self.mode,
                    t,
                    None,
                    None,
                    self.buffers.sdeb_for(bi),
                    sink,
                    &mut self.scratch_sdeb,
                )?;
            }

            executor::head_readout(
                &mut self.sea_head,
                &u,
                l,
                d,
                &self.hw,
                sink,
                &mut head_counts,
                &mut self.scratch_sdeb,
            );
            // The final residual stream came from the SDEB pool but the
            // next timestep's token tensor is taken from the SPS pool —
            // return it there to keep both pools balanced (mirrors the
            // overlapped executor's return ring).
            self.scratch_sps.put_tensor(u);
        }
        Ok(head_counts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{GoldenExecutor, SdtModelConfig};
    use crate::util::Prng;

    fn random_image(seed: u64) -> Vec<f32> {
        let mut rng = Prng::new(seed);
        (0..3 * 32 * 32).map(|_| rng.next_f32_signed()).collect()
    }

    #[test]
    fn accelerator_matches_golden_bit_exactly() {
        let cfg = SdtModelConfig::tiny();
        let model = QuantizedModel::random(&cfg, 11);
        let golden = GoldenExecutor::new(&model).infer(&random_image(4));
        let mut accel = Accelerator::new(model.clone(), AccelConfig::small());
        let report = accel.infer(&random_image(4)).unwrap();
        assert_eq!(report.logits, golden.logits, "encoded datapath != golden");
        assert!(report.pipeline.is_some(), "default path must execute the overlap");
    }

    #[test]
    fn repeated_inference_is_deterministic() {
        let cfg = SdtModelConfig::tiny();
        let model = QuantizedModel::random(&cfg, 11);
        let mut accel = Accelerator::new(model, AccelConfig::small());
        let a = accel.infer(&random_image(5)).unwrap();
        let b = accel.infer(&random_image(5)).unwrap();
        assert_eq!(a.logits, b.logits);
        assert_eq!(a.total.cycles, b.total.cycles);
        assert_eq!(a.wall_cycles(), b.wall_cycles(), "overlap schedule must be deterministic");
    }

    #[test]
    fn bitmap_mode_same_logits_more_cycles() {
        let cfg = SdtModelConfig::tiny();
        let model = QuantizedModel::random(&cfg, 11);
        let img = random_image(6);
        let mut enc = Accelerator::new(model.clone(), AccelConfig::small());
        let mut bmp = Accelerator::with_mode(model, AccelConfig::small(), DatapathMode::Bitmap);
        let r1 = enc.infer(&img).unwrap();
        let r2 = bmp.infer(&img).unwrap();
        assert_eq!(r1.logits, r2.logits);
        assert!(
            r2.total.cycles > r1.total.cycles,
            "bitmap {} !> encoded {}",
            r2.total.cycles,
            r1.total.cycles
        );
    }

    #[test]
    fn engine_select_matches_golden_end_to_end() {
        use crate::hw::EngineSelect;
        let cfg = SdtModelConfig::tiny();
        let model = QuantizedModel::random(&cfg, 11);
        let img = random_image(10);
        let golden = GoldenExecutor::new(&model).infer(&img);
        let mut reports = Vec::new();
        for engine in [EngineSelect::Csr, EngineSelect::Bitmap, EngineSelect::adaptive()] {
            let mut hw = AccelConfig::small();
            hw.engine = engine;
            hw.validate().unwrap();
            let mut accel = Accelerator::new(model.clone(), hw);
            let r = accel.infer(&img).unwrap();
            assert_eq!(
                r.logits,
                golden.logits,
                "engine {} diverged from golden",
                engine.name()
            );
            reports.push(r);
        }
        // The engines agree on values but not on cost: a pure-bitmap run
        // charges a different cycle total than pure-CSR on this workload.
        assert_ne!(reports[0].total.cycles, reports[1].total.cycles);
    }

    #[test]
    fn report_contains_fig6_modules() {
        let cfg = SdtModelConfig::tiny();
        let model = QuantizedModel::random(&cfg, 11);
        let mut accel = Accelerator::new(model, AccelConfig::small());
        let r = accel.infer(&random_image(7)).unwrap();
        let names: Vec<&str> = r.sparsity.iter().map(|(n, _)| n.as_str()).collect();
        for want in ["block0.q.spikes", "block0.k.spikes", "block0.v.spikes", "block0.sdsa.spikes"] {
            assert!(names.contains(&want), "missing {want}");
        }
        assert!(r.gsops > 0.0);
        assert!(r.gsop_per_w > 0.0);
    }

    #[test]
    fn serial_mode_has_no_pipeline_record() {
        let cfg = SdtModelConfig::tiny();
        let model = QuantizedModel::random(&cfg, 11);
        let mut accel = Accelerator::with_modes(
            model,
            AccelConfig::small(),
            DatapathMode::Encoded,
            ExecMode::Serial,
        );
        let r = accel.infer(&random_image(8)).unwrap();
        assert!(r.pipeline.is_none());
        assert_eq!(r.wall_cycles(), r.total.cycles);
    }

    #[test]
    fn continuous_lanes_match_per_call_reports_bit_exactly() {
        let cfg = SdtModelConfig::tiny();
        let model = QuantizedModel::random(&cfg, 11);
        let imgs: Vec<Vec<f32>> = (0..3).map(|s| random_image(40 + s)).collect();
        let mut fresh = Accelerator::new(model.clone(), AccelConfig::small());
        let want: Vec<_> = imgs.iter().map(|img| fresh.infer(img).unwrap()).collect();

        let mut accel = Accelerator::new(model, AccelConfig::small());
        // Staggered admission: lane 2 joins after the others have run a
        // pass — the in-flight refill continuous serving relies on.
        accel.lane_admit(0, &imgs[0]).unwrap();
        accel.lane_admit(1, &imgs[1]).unwrap();
        assert!(accel.infer(&imgs[0]).is_err(), "infer must refuse while lanes are in flight");
        let mut got: Vec<Option<RunReport>> = vec![None, None, None];
        let mut admitted_third = false;
        while got.iter().any(|g| g.is_none()) {
            for (id, report) in accel.lane_step().unwrap() {
                let slot = usize::try_from(id).unwrap();
                assert!(got[slot].is_none(), "request {id} retired twice");
                got[slot] = Some(report);
            }
            if !admitted_third {
                accel.lane_admit(2, &imgs[2]).unwrap();
                admitted_third = true;
            }
        }
        assert_eq!(accel.lanes_in_flight(), 0);
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            let g = g.as_ref().unwrap();
            assert_eq!(g.logits, w.logits, "image {i}: logits diverge");
            assert_eq!(g.total.cycles, w.total.cycles, "image {i}: cycles diverge");
            assert_eq!(g.wall_cycles(), w.wall_cycles(), "image {i}: schedule diverges");
        }
        // Lanes returned to the pool; per-call path usable again.
        accel.infer(&imgs[0]).unwrap();
    }

    #[test]
    fn lane_admit_rejects_bad_input_and_duplicates() {
        let cfg = SdtModelConfig::tiny();
        let model = QuantizedModel::random(&cfg, 11);
        let mut accel = Accelerator::new(model.clone(), AccelConfig::small());
        assert!(accel.lane_admit(0, &[0.0; 7]).is_err(), "wrong pixel count must be refused");
        accel.lane_admit(0, &random_image(1)).unwrap();
        assert!(accel.lane_admit(0, &random_image(2)).is_err(), "duplicate id must be refused");
        let mut serial = Accelerator::with_modes(
            model,
            AccelConfig::small(),
            DatapathMode::Encoded,
            ExecMode::Serial,
        );
        assert!(serial.lane_admit(0, &random_image(3)).is_err(), "serial exec has no lanes");
    }

    #[test]
    fn pool_workers_knob_clamps_and_reports() {
        let cfg = SdtModelConfig::tiny();
        let model = QuantizedModel::random(&cfg, 11);
        let accel = Accelerator::new(model.clone(), AccelConfig::small());
        // Default sizing covers the topology's SDSA fan-out (2 SDEB cores
        // in the paper topology) as well as one worker per block.
        let topo_cores = AccelConfig::small().topology.sdeb_cores;
        assert_eq!(accel.pool_workers(), cfg.num_blocks.max(topo_cores));
        let accel = accel.with_pool_workers(0);
        assert_eq!(accel.pool_workers(), 1, "pool size clamps to >= 1");
        let sized = Accelerator::with_runtime(
            model.clone(),
            AccelConfig::small(),
            DatapathMode::Encoded,
            ExecMode::Overlapped,
            3,
        );
        assert_eq!(sized.pool_workers(), 3, "with_runtime sizes the pool directly");
        let mut accel = Accelerator::new(model, AccelConfig::small()).with_pool_workers(4);
        assert_eq!(accel.pool_workers(), 4);
        accel.infer(&random_image(9)).unwrap(); // oversized pool still correct
    }

    #[test]
    fn serial_decode_matches_a_manual_session() {
        let cfg = SdtModelConfig::tiny_decoder();
        let model = QuantizedModel::random(&cfg, 11);
        let hw = AccelConfig::small();
        let mut accel = Accelerator::new(model.clone(), hw);
        let prompt = [1usize, 5, 2];
        let r = accel.decode(&prompt, 4).unwrap();
        assert_eq!(r.prompt_len, 3);
        assert_eq!(r.gen_len, 4);
        assert_eq!(r.generated.len(), 4);
        assert_eq!(r.token_cycles.len(), 4);

        // Drive a session by hand: the controller path must be a pure
        // wrapper around it (bit-identical trace).
        let mut session = DecodeSession::new(&model, &hw).unwrap();
        let logits = session.prefill(&model, &hw, &prompt).unwrap();
        assert_eq!(r.prefill_cycles, session.cycles());
        let mut next = argmax(&logits);
        for (i, tc) in r.token_cycles.iter().enumerate() {
            assert_eq!(r.generated[i], next, "token {i} diverged");
            let before = session.cycles();
            let (n2, _) = session.decode_step(&model, &hw, next).unwrap();
            assert_eq!(*tc, session.cycles() - before, "token {i} cycle charge diverged");
            next = n2;
        }
        assert_eq!(r.total_cycles, session.cycles());
        assert_eq!(r.cache_words, session.cache_words());

        // Second call reuses the pooled (reset) session bit-exactly.
        let again = accel.decode(&prompt, 4).unwrap();
        assert_eq!(again.generated, r.generated);
        assert_eq!(again.total_cycles, r.total_cycles);
    }

    #[test]
    fn decode_lanes_interleave_and_match_serial_decode() {
        let cfg = SdtModelConfig::tiny_decoder();
        let model = QuantizedModel::random(&cfg, 11);
        let hw = AccelConfig::small();
        let mut fresh = Accelerator::new(model.clone(), hw);
        let want_a = fresh.decode(&[1, 5, 2], 3).unwrap();
        let want_b = fresh.decode(&[4, 0], 5).unwrap();

        let mut accel = Accelerator::new(model, hw);
        accel.lane_admit_decode(7, &[1, 5, 2], 3).unwrap();
        // A vision lane in flight at the same time: decoder models keep
        // the vision front-end, so both request kinds share the runtime.
        accel.lane_admit(1, &random_image(3)).unwrap();
        assert_eq!(accel.decode_lanes_in_flight(), 1);
        let mut vision_done = false;
        let mut decoded = Vec::new();
        let mut admitted_second = false;
        while decoded.len() < 2 {
            for (id, _report) in accel.lane_step().unwrap() {
                assert_eq!(id, 1);
                vision_done = true;
            }
            decoded.extend(accel.take_decoded());
            if !admitted_second {
                accel.lane_admit_decode(9, &[4, 0], 5).unwrap();
                admitted_second = true;
            }
        }
        assert!(vision_done, "vision lane must retire alongside decode lanes");
        assert_eq!(accel.decode_lanes_in_flight(), 0);
        decoded.sort_by_key(|(id, _)| *id);
        let (id_a, got_a) = &decoded[0];
        let (id_b, got_b) = &decoded[1];
        assert_eq!((*id_a, *id_b), (7, 9));
        assert_eq!(got_a.generated, want_a.generated);
        assert_eq!(got_a.prefill_cycles, want_a.prefill_cycles);
        assert_eq!(got_a.token_cycles, want_a.token_cycles);
        assert_eq!(got_a.total_cycles, want_a.total_cycles);
        assert_eq!(got_b.generated, want_b.generated);
        assert_eq!(got_b.total_cycles, want_b.total_cycles);
    }

    #[test]
    fn decode_admission_rejects_bad_requests() {
        let cfg = SdtModelConfig::tiny_decoder();
        let model = QuantizedModel::random(&cfg, 11);
        let mut accel = Accelerator::new(model.clone(), AccelConfig::small());
        let max = cfg.decoder.as_ref().unwrap().max_seq_len;
        assert!(accel.decode(&[], 2).is_err(), "empty prompt");
        assert!(accel.decode(&[1], max).is_err(), "prompt + gen exceeds max_seq_len");
        assert!(accel.lane_admit_decode(0, &[], 2).is_err(), "empty prompt lane");
        assert!(accel.lane_admit_decode(0, &[1], max).is_err(), "overlong lane");
        accel.lane_admit_decode(0, &[1], 1).unwrap();
        assert!(accel.lane_admit_decode(0, &[2], 1).is_err(), "duplicate id");
        let vision = QuantizedModel::random(&SdtModelConfig::tiny(), 1);
        let mut v = Accelerator::new(vision, AccelConfig::small());
        assert!(v.decode(&[1], 1).is_err(), "vision models cannot decode");
        assert!(v.lane_admit_decode(0, &[1], 1).is_err());
    }
}
