//! The Controller (Fig. 1): sequences the SPS Core, the SDEB Cores and the
//! head over all timesteps of an inference, owns the buffer complement, and
//! assembles the final [`RunReport`].
//!
//! Two execution strategies are available ([`ExecMode`]):
//!
//! * **Overlapped** (default) — the two-core pipeline the paper's Fig. 1
//!   implies: the SPS stage of timestep `t+1` runs concurrently with the
//!   SDEB stage of timestep `t` against ping/pong buffer halves, and each
//!   block's SDSA heads are sharded across the SDEB cores' comparator
//!   arrays. Executed by [`super::executor`]; the report carries the
//!   executed [`PipelineExecution`](super::executor::PipelineExecution).
//! * **Serial** — every phase charged back to back on one timeline (the
//!   conservative accounting this repo used originally). Kept as the
//!   ablation baseline; logits are bit-identical to the overlapped path.

use anyhow::Result;

use crate::hw::{AccelConfig, EnergyModel, UnitStats};
use crate::quant::{QFormat, QTensor, ACT_FRAC, MEM_BITS};
use crate::units::{HeadShard, SpikeEncodingArray};
use crate::model::QuantizedModel;
use crate::util::div_ceil;

use super::buffers::BufferSet;
use super::executor::{self, PipelineExecution};
use super::report::{RunReport, StatSink};
use super::sdeb_core::SdebCore;
use super::sps_core::SpsCore;

/// Which datapath the spike-consuming units use (ablation A1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DatapathMode {
    /// The paper's position-encoded spike processing.
    Encoded,
    /// Conventional bitmap processing (zero-checking every position).
    Bitmap,
}

/// How the controller schedules the cores over timesteps.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum ExecMode {
    /// Two-core overlapped pipeline with per-head SDEB sharding (default).
    #[default]
    Overlapped,
    /// Serial phase charging (the `--serial` ablation escape hatch).
    Serial,
}

/// A full accelerator instance bound to one quantized model.
pub struct Accelerator {
    /// Structural hardware parameters of this instance.
    pub hw: AccelConfig,
    /// Per-operation energy model used for the report's power numbers.
    pub energy: EnergyModel,
    /// Datapath selection (encoded vs bitmap baseline).
    pub mode: DatapathMode,
    /// Execution strategy (overlapped pipeline vs serial charging).
    pub exec: ExecMode,
    model: QuantizedModel,
    sps: SpsCore,
    sdebs: Vec<SdebCore>,
    sea_head: SpikeEncodingArray,
}

impl Accelerator {
    /// Overlapped, encoded-datapath instance (the default configuration).
    pub fn new(model: QuantizedModel, hw: AccelConfig) -> Self {
        Self::with_modes(model, hw, DatapathMode::Encoded, ExecMode::Overlapped)
    }

    /// Choose the datapath, keeping the overlapped executor.
    pub fn with_mode(model: QuantizedModel, hw: AccelConfig, mode: DatapathMode) -> Self {
        Self::with_modes(model, hw, mode, ExecMode::Overlapped)
    }

    /// Choose both the datapath and the execution strategy.
    pub fn with_modes(
        model: QuantizedModel,
        hw: AccelConfig,
        mode: DatapathMode,
        exec: ExecMode,
    ) -> Self {
        let cfg = &model.cfg;
        let params = cfg.lif_params();
        let (l, d) = (cfg.num_tokens(), cfg.embed_dim);
        let sps = SpsCore::new(&model, params);
        let sdebs = (0..cfg.num_blocks)
            .map(|i| SdebCore::new(i, l, d, cfg.mlp_hidden, cfg.attn_v_th, params))
            .collect();
        let sea_head = SpikeEncodingArray::new(d, l, params);
        Self { hw, energy: EnergyModel::default(), mode, exec, model, sps, sdebs, sea_head }
    }

    /// The quantized model this instance is bound to.
    pub fn model(&self) -> &QuantizedModel {
        &self.model
    }

    /// The head-to-core shard plan the overlapped executor uses.
    pub fn shard_plan(&self) -> HeadShard {
        HeadShard {
            heads: self.model.cfg.num_heads.max(1),
            cores: self.sdebs.len().max(1),
        }
    }

    fn reset(&mut self) {
        self.sps.reset();
        for s in &mut self.sdebs {
            s.reset();
        }
        self.sea_head.reset();
    }

    /// Run a full inference of one image (f32 CHW pixels).
    pub fn infer(&mut self, image: &[f32]) -> Result<RunReport> {
        let cfg = self.model.cfg.clone();
        assert_eq!(image.len(), cfg.in_channels * cfg.img_size * cfg.img_size);
        self.reset();

        let mut buffers = BufferSet::new(&self.hw);
        let mut sink = StatSink::new();

        // External input transfer: 10-bit activations packed 2 B/value.
        let in_bytes = image.len() * 2;
        let io_in = buffers.load_external(in_bytes, &self.hw)?;
        let io_in_cycles = io_in.cycles;
        sink.add("io.input", io_in);

        let act = QFormat::new(MEM_BITS, ACT_FRAC);
        let qimg =
            QTensor::from_f32(image, &[cfg.in_channels, cfg.img_size, cfg.img_size], act);

        let (head_counts, execution) = match self.exec {
            ExecMode::Overlapped => {
                let shard = self.shard_plan();
                let outcome = executor::run_overlapped(
                    &self.model,
                    &self.hw,
                    self.mode,
                    shard,
                    &mut self.sps,
                    &mut self.sdebs,
                    &mut self.sea_head,
                    &mut buffers,
                    &qimg,
                )?;
                sink.absorb(outcome.sink);
                (outcome.head_counts, Some((outcome.sps_per_timestep, outcome.sdeb_per_timestep)))
            }
            ExecMode::Serial => {
                let counts = self.run_serial(&qimg, &mut buffers, &mut sink)?;
                (counts, None)
            }
        };

        // Host/output-side classification head on pooled rates.
        let (l, d) = (cfg.num_tokens(), cfg.embed_dim);
        let denom = (cfg.timesteps * l) as f32;
        let mut logits = self.model.head_b.clone();
        for c in 0..d {
            let rate = head_counts[c] as f32 / denom;
            if rate != 0.0 {
                for k in 0..cfg.num_classes {
                    logits[k] += rate * self.model.head_w[c * cfg.num_classes + k];
                }
            }
        }

        // Output transfer (logits as f32).
        let out_bytes = cfg.num_classes * 4;
        let io_out = UnitStats {
            cycles: div_ceil(out_bytes as u64, self.hw.dram_bytes_per_cycle as u64),
            dram_bytes: out_bytes as u64,
            ..Default::default()
        };
        let io_out_cycles = io_out.cycles;
        sink.add("io.output", io_out);

        Ok(match execution {
            Some((sps_per, sdeb_per)) => {
                let exec =
                    PipelineExecution::new(io_in_cycles, io_out_cycles, sps_per, sdeb_per);
                RunReport::from_sink_pipelined(logits, sink, exec, &self.hw, &self.energy)
            }
            None => RunReport::from_sink(logits, sink, &self.hw, &self.energy),
        })
    }

    /// The serial timestep loop: every phase charged back to back, no
    /// head sharding — the original conservative accounting.
    fn run_serial(
        &mut self,
        qimg: &QTensor,
        buffers: &mut BufferSet,
        sink: &mut StatSink,
    ) -> Result<Vec<u64>> {
        let cfg = &self.model.cfg;
        let (l, d) = (cfg.num_tokens(), cfg.embed_dim);
        let mut head_counts = vec![0u64; d];

        for t in 0..cfg.timesteps {
            let pong = t % 2 == 1;
            let (u0_cl, _enc3) = self.sps.run_timestep(
                &self.model,
                qimg,
                &self.hw,
                self.mode,
                pong,
                &mut buffers.sps,
                sink,
            )?;

            let mut u = executor::u0_to_token_major(&u0_cl, l, d);
            for (bi, core) in self.sdebs.iter_mut().enumerate() {
                u = core.run_timestep(
                    &self.model.blocks[bi],
                    u,
                    &self.hw,
                    self.mode,
                    pong,
                    None,
                    &mut buffers.sdeb,
                    sink,
                )?;
            }

            executor::head_readout(
                &mut self.sea_head,
                &u,
                l,
                d,
                &self.hw,
                sink,
                &mut head_counts,
            );
        }
        Ok(head_counts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{GoldenExecutor, SdtModelConfig};
    use crate::util::Prng;

    fn random_image(seed: u64) -> Vec<f32> {
        let mut rng = Prng::new(seed);
        (0..3 * 32 * 32).map(|_| rng.next_f32_signed()).collect()
    }

    #[test]
    fn accelerator_matches_golden_bit_exactly() {
        let cfg = SdtModelConfig::tiny();
        let model = QuantizedModel::random(&cfg, 11);
        let golden = GoldenExecutor::new(&model).infer(&random_image(4));
        let mut accel = Accelerator::new(model.clone(), AccelConfig::small());
        let report = accel.infer(&random_image(4)).unwrap();
        assert_eq!(report.logits, golden.logits, "encoded datapath != golden");
        assert!(report.pipeline.is_some(), "default path must execute the overlap");
    }

    #[test]
    fn repeated_inference_is_deterministic() {
        let cfg = SdtModelConfig::tiny();
        let model = QuantizedModel::random(&cfg, 11);
        let mut accel = Accelerator::new(model, AccelConfig::small());
        let a = accel.infer(&random_image(5)).unwrap();
        let b = accel.infer(&random_image(5)).unwrap();
        assert_eq!(a.logits, b.logits);
        assert_eq!(a.total.cycles, b.total.cycles);
        assert_eq!(a.wall_cycles(), b.wall_cycles(), "overlap schedule must be deterministic");
    }

    #[test]
    fn bitmap_mode_same_logits_more_cycles() {
        let cfg = SdtModelConfig::tiny();
        let model = QuantizedModel::random(&cfg, 11);
        let img = random_image(6);
        let mut enc = Accelerator::new(model.clone(), AccelConfig::small());
        let mut bmp = Accelerator::with_mode(model, AccelConfig::small(), DatapathMode::Bitmap);
        let r1 = enc.infer(&img).unwrap();
        let r2 = bmp.infer(&img).unwrap();
        assert_eq!(r1.logits, r2.logits);
        assert!(
            r2.total.cycles > r1.total.cycles,
            "bitmap {} !> encoded {}",
            r2.total.cycles,
            r1.total.cycles
        );
    }

    #[test]
    fn report_contains_fig6_modules() {
        let cfg = SdtModelConfig::tiny();
        let model = QuantizedModel::random(&cfg, 11);
        let mut accel = Accelerator::new(model, AccelConfig::small());
        let r = accel.infer(&random_image(7)).unwrap();
        let names: Vec<&str> = r.sparsity.iter().map(|(n, _)| n.as_str()).collect();
        for want in ["block0.q.spikes", "block0.k.spikes", "block0.v.spikes", "block0.sdsa.spikes"] {
            assert!(names.contains(&want), "missing {want}");
        }
        assert!(r.gsops > 0.0);
        assert!(r.gsop_per_w > 0.0);
    }

    #[test]
    fn serial_mode_has_no_pipeline_record() {
        let cfg = SdtModelConfig::tiny();
        let model = QuantizedModel::random(&cfg, 11);
        let mut accel = Accelerator::with_modes(
            model,
            AccelConfig::small(),
            DatapathMode::Encoded,
            ExecMode::Serial,
        );
        let r = accel.infer(&random_image(8)).unwrap();
        assert!(r.pipeline.is_none());
        assert_eq!(r.wall_cycles(), r.total.cycles);
    }
}
