//! SDEB Core (Fig. 1 right): SEA/ESS encoding, the Spike Linear Array for
//! Q/K/V/O and the MLP, the SMAM for spike-driven self-attention, and the
//! residual Adder — one instance per encoder block, with persistent LIF
//! state across timesteps.

use anyhow::Result;

use crate::hw::{AccelConfig, UnitStats};
use crate::lif::LifParams;
use crate::quant::QTensor;
use crate::spike::EncodedSpikes;
use crate::units::{AdderModule, HeadShard, SpikeEncodingArray, SpikeLinearUnit, SpikeMaskAddModule};
use crate::model::QuantizedBlock;

use super::buffers::CoreBuffers;
use super::controller::DatapathMode;
use super::report::StatSink;

/// One encoder block's SDEB core: SEAs for every encode site, the SLU,
/// the SMAM and the residual Adder, with persistent LIF state.
pub struct SdebCore {
    index: usize,
    sea_in: SpikeEncodingArray,
    sea_q: SpikeEncodingArray,
    sea_k: SpikeEncodingArray,
    sea_v: SpikeEncodingArray,
    sea_mlp_in: SpikeEncodingArray,
    sea_mlp_hidden: SpikeEncodingArray,
    slu: SpikeLinearUnit,
    smam: SpikeMaskAddModule,
    adder: AdderModule,
    tokens: usize,
    dim: usize,
}

impl SdebCore {
    /// Build the block's unit complement.
    pub fn new(
        index: usize,
        tokens: usize,
        dim: usize,
        mlp_hidden: usize,
        attn_v_th: u32,
        params: LifParams,
    ) -> Self {
        Self {
            index,
            sea_in: SpikeEncodingArray::new(dim, tokens, params),
            sea_q: SpikeEncodingArray::new(dim, tokens, params),
            sea_k: SpikeEncodingArray::new(dim, tokens, params),
            sea_v: SpikeEncodingArray::new(dim, tokens, params),
            sea_mlp_in: SpikeEncodingArray::new(dim, tokens, params),
            sea_mlp_hidden: SpikeEncodingArray::new(mlp_hidden, tokens, params),
            slu: SpikeLinearUnit::new(),
            smam: SpikeMaskAddModule::new(attn_v_th),
            adder: AdderModule::new(),
            tokens,
            dim,
        }
    }

    /// Clear every encode site's LIF membrane state (between inferences).
    pub fn reset(&mut self) {
        self.sea_in.reset();
        self.sea_q.reset();
        self.sea_k.reset();
        self.sea_v.reset();
        self.sea_mlp_in.reset();
        self.sea_mlp_hidden.reset();
    }

    /// Transpose a token-major `[L, C]` value tensor into the channel-major
    /// `[C, L]` layout the SEA/ESS banks use.
    fn to_cl(&self, v: &QTensor, c: usize) -> Vec<i32> {
        let l = self.tokens;
        debug_assert_eq!(v.data.len(), l * c);
        let mut out = vec![0i32; c * l];
        for tok in 0..l {
            for ch in 0..c {
                out[ch * l + tok] = v.data[tok * c + ch];
            }
        }
        out
    }

    fn slu_forward(
        &mut self,
        x: &EncodedSpikes,
        layer: &crate::quant::QuantizedLinear,
        cfg: &AccelConfig,
        mode: DatapathMode,
    ) -> (QTensor, UnitStats) {
        match mode {
            DatapathMode::Encoded => self.slu.forward(x, layer, cfg),
            DatapathMode::Bitmap => self.slu.forward_bitmap_baseline(x, layer, cfg),
        }
    }

    /// One timestep of the block. `u` is the `[L, D]` residual-stream value
    /// tensor (token-major); updated in place (returned).
    ///
    /// `pong` is the timestep parity selecting the ESS half of `buffers`.
    /// `shard` — when `Some` and the datapath is encoded — runs the SDSA
    /// pass with heads sharded across SDEB-core comparator arrays
    /// ([`SpikeMaskAddModule::run_sharded`]); `None` keeps the serial
    /// single-array accounting. Values are bit-identical either way.
    pub fn run_timestep(
        &mut self,
        blk: &QuantizedBlock,
        u: QTensor,
        cfg: &AccelConfig,
        mode: DatapathMode,
        pong: bool,
        shard: Option<HeadShard>,
        buffers: &mut CoreBuffers,
        sink: &mut StatSink,
    ) -> Result<QTensor> {
        let bi = self.index;
        let d = self.dim;

        // SEA encode the residual stream.
        let u_cl = self.to_cl(&u, d);
        let (s_in, st) = self.sea_in.encode(&u_cl, cfg);
        sink.add("sdeb.encode", st);
        sink.sparsity(&format!("block{bi}.in.spikes"), &s_in);
        buffers.store_encoded(&s_in, pong)?;

        // Q/K/V projections on the Spike Linear Array + SEA fire.
        let (qv, st) = self.slu_forward(&s_in, &blk.q, cfg, mode);
        sink.add("sdeb.qkv", st);
        let (q_s, st) = self.sea_q.encode(&self.to_cl(&qv, d), cfg);
        sink.add("sdeb.encode", st);
        let (kv, st) = self.slu_forward(&s_in, &blk.k, cfg, mode);
        sink.add("sdeb.qkv", st);
        let (k_s, st) = self.sea_k.encode(&self.to_cl(&kv, d), cfg);
        sink.add("sdeb.encode", st);
        let (vv, st) = self.slu_forward(&s_in, &blk.v, cfg, mode);
        sink.add("sdeb.qkv", st);
        let (v_s, st) = self.sea_v.encode(&self.to_cl(&vv, d), cfg);
        sink.add("sdeb.encode", st);
        sink.sparsity(&format!("block{bi}.q.spikes"), &q_s);
        sink.sparsity(&format!("block{bi}.k.spikes"), &k_s);
        sink.sparsity(&format!("block{bi}.v.spikes"), &v_s);
        buffers.store_encoded(&q_s, pong)?;
        buffers.store_encoded(&k_s, pong)?;
        buffers.store_encoded(&v_s, pong)?;

        // SMAM: dual-spike mask-add (the SDSA engine), optionally with
        // heads sharded across the idle cores' comparator arrays.
        let (smam_out, st) = match (mode, shard) {
            (DatapathMode::Encoded, Some(sh)) => self.smam.run_sharded(&q_s, &k_s, &v_s, cfg, sh),
            (DatapathMode::Encoded, None) => self.smam.run(&q_s, &k_s, &v_s, cfg),
            (DatapathMode::Bitmap, _) => self.smam.run_dense_baseline(&q_s, &k_s, &v_s, cfg),
        };
        sink.add("sdeb.smam", st);
        sink.sparsity(&format!("block{bi}.sdsa.spikes"), &smam_out.masked_v);

        // Output projection + residual.
        let (ov, st) = self.slu_forward(&smam_out.masked_v, &blk.o, cfg, mode);
        sink.add("sdeb.proj", st);
        let (u, st) = self.adder.add(&u, &ov, cfg);
        sink.add("sdeb.residual", st);

        // MLP: encode -> SLU -> encode -> SLU -> residual.
        let (s2, st) = self.sea_mlp_in.encode(&self.to_cl(&u, d), cfg);
        sink.add("sdeb.encode", st);
        sink.sparsity(&format!("block{bi}.mlp.in.spikes"), &s2);
        buffers.store_encoded(&s2, pong)?;
        let (hv, st) = self.slu_forward(&s2, &blk.mlp1, cfg, mode);
        sink.add("sdeb.mlp", st);
        let h = blk.mlp1.out_dim;
        let (s3, st) = self.sea_mlp_hidden.encode(&self.to_cl(&hv, h), cfg);
        sink.add("sdeb.encode", st);
        sink.sparsity(&format!("block{bi}.mlp.hidden.spikes"), &s3);
        buffers.store_encoded(&s3, pong)?;
        let (m2, st) = self.slu_forward(&s3, &blk.mlp2, cfg, mode);
        sink.add("sdeb.mlp", st);
        let (u, st) = self.adder.add(&u, &m2, cfg);
        sink.add("sdeb.residual", st);

        Ok(u)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::buffers::BufferSet;
    use crate::model::{QuantizedModel, SdtModelConfig};
    use crate::quant::{QFormat, ACT_FRAC, MEM_BITS};
    use crate::util::Prng;

    fn setup() -> (QuantizedModel, QTensor, AccelConfig) {
        let cfg = SdtModelConfig::tiny();
        let model = QuantizedModel::random(&cfg, 6);
        let mut rng = Prng::new(2);
        let vals: Vec<f32> = (0..64 * 64).map(|_| rng.next_f32_signed() * 1.5).collect();
        let u = QTensor::from_f32(&vals, &[64, 64], QFormat::new(MEM_BITS, ACT_FRAC));
        (model, u, AccelConfig::small())
    }

    #[test]
    fn block_preserves_shape_and_format() {
        let (model, u, hw) = setup();
        let mc = &model.cfg;
        let mut core =
            SdebCore::new(0, 64, 64, mc.mlp_hidden, mc.attn_v_th, mc.lif_params());
        let mut buffers = BufferSet::new(&hw);
        let mut sink = StatSink::new();
        let out = core
            .run_timestep(&model.blocks[0], u, &hw, DatapathMode::Encoded, false, None, &mut buffers.sdeb, &mut sink)
            .unwrap();
        assert_eq!(out.shape, vec![64, 64]);
        assert_eq!(out.frac, ACT_FRAC);
        for phase in ["sdeb.encode", "sdeb.qkv", "sdeb.smam", "sdeb.mlp", "sdeb.residual"] {
            assert!(sink.phases.get(phase).cycles > 0, "phase {phase} missing");
        }
    }

    #[test]
    fn encoded_and_bitmap_modes_agree_on_values() {
        let (model, u, hw) = setup();
        let mc = &model.cfg;
        let mut c1 = SdebCore::new(0, 64, 64, mc.mlp_hidden, mc.attn_v_th, mc.lif_params());
        let mut c2 = SdebCore::new(0, 64, 64, mc.mlp_hidden, mc.attn_v_th, mc.lif_params());
        let mut b1 = BufferSet::new(&hw);
        let mut b2 = BufferSet::new(&hw);
        let mut s1 = StatSink::new();
        let mut s2 = StatSink::new();
        let o1 = c1
            .run_timestep(&model.blocks[0], u.clone(), &hw, DatapathMode::Encoded, false, None, &mut b1.sdeb, &mut s1)
            .unwrap();
        let o2 = c2
            .run_timestep(&model.blocks[0], u, &hw, DatapathMode::Bitmap, false, None, &mut b2.sdeb, &mut s2)
            .unwrap();
        assert_eq!(o1, o2);
    }

    #[test]
    fn timesteps_carry_lif_state() {
        let (model, u, hw) = setup();
        let mc = &model.cfg;
        let mut core =
            SdebCore::new(0, 64, 64, mc.mlp_hidden, mc.attn_v_th, mc.lif_params());
        let mut buffers = BufferSet::new(&hw);
        let mut sink = StatSink::new();
        let o1 = core
            .run_timestep(&model.blocks[0], u.clone(), &hw, DatapathMode::Encoded, false, None, &mut buffers.sdeb, &mut sink)
            .unwrap();
        // Same input, different membrane state -> (almost surely) different output.
        let o2 = core
            .run_timestep(&model.blocks[0], u.clone(), &hw, DatapathMode::Encoded, false, None, &mut buffers.sdeb, &mut sink)
            .unwrap();
        core.reset();
        let o3 = core
            .run_timestep(&model.blocks[0], u, &hw, DatapathMode::Encoded, false, None, &mut buffers.sdeb, &mut sink)
            .unwrap();
        assert_eq!(o1, o3, "reset must restore t=0 behaviour");
        let _ = o2;
    }
}
