//! SDEB Core (Fig. 1 right): SEA/ESS encoding, the Spike Linear Array for
//! Q/K/V/O and the MLP, the SMAM for spike-driven self-attention, and the
//! residual Adder — one instance per encoder block, with persistent LIF
//! state across timesteps.

use anyhow::Result;

use crate::hw::{AccelConfig, EngineKind, UnitStats};
use crate::lif::LifParams;
use crate::quant::QTensor;
use crate::scratch::ExecScratch;
use crate::spike::{KvCacheStream, PackedBitmap};
use crate::units::{
    AdderModule, SmamOutput, SpikeEncodingArray, SpikeLinearUnit, SpikeMaskAddModule,
};
use crate::model::QuantizedBlock;

use super::buffers::CoreBuffers;
use super::mapper::Mapper;
use super::controller::DatapathMode;
use super::report::StatSink;
use super::workers::WorkerPool;

/// One encoder block's SDEB core: SEAs for every encode site, the SLU,
/// the SMAM and the residual Adder, with persistent LIF state.
pub struct SdebCore {
    index: usize,
    sea_in: SpikeEncodingArray,
    sea_q: SpikeEncodingArray,
    sea_k: SpikeEncodingArray,
    sea_v: SpikeEncodingArray,
    sea_mlp_in: SpikeEncodingArray,
    sea_mlp_hidden: SpikeEncodingArray,
    slu: SpikeLinearUnit,
    smam: SpikeMaskAddModule,
    adder: AdderModule,
    tokens: usize,
    dim: usize,
    // Previous timestep's SDEB input bitmap for `--temporal-delta`: the
    // buffer is kept across `reset()` (recycled, not reallocated) while
    // the flag below gates its validity.
    prev_in: Option<PackedBitmap>,
    prev_in_valid: bool,
}

impl SdebCore {
    /// Build the block's unit complement.
    pub fn new(
        index: usize,
        tokens: usize,
        dim: usize,
        mlp_hidden: usize,
        attn_v_th: u32,
        params: LifParams,
    ) -> Self {
        Self {
            index,
            sea_in: SpikeEncodingArray::new(dim, tokens, params),
            sea_q: SpikeEncodingArray::new(dim, tokens, params),
            sea_k: SpikeEncodingArray::new(dim, tokens, params),
            sea_v: SpikeEncodingArray::new(dim, tokens, params),
            sea_mlp_in: SpikeEncodingArray::new(dim, tokens, params),
            sea_mlp_hidden: SpikeEncodingArray::new(mlp_hidden, tokens, params),
            slu: SpikeLinearUnit::new(),
            smam: SpikeMaskAddModule::new(attn_v_th),
            adder: AdderModule::new(),
            tokens,
            dim,
            prev_in: None,
            prev_in_valid: false,
        }
    }

    /// Clear every encode site's LIF membrane state (between inferences).
    pub fn reset(&mut self) {
        self.prev_in_valid = false;
        self.sea_in.reset();
        self.sea_q.reset();
        self.sea_k.reset();
        self.sea_v.reset();
        self.sea_mlp_in.reset();
        self.sea_mlp_hidden.reset();
    }

    /// Transpose a token-major `[L, C]` value tensor into the channel-major
    /// `[C, L]` layout the SEA/ESS banks use, into a recycled buffer.
    fn to_cl_into(&self, v: &QTensor, c: usize, out: &mut Vec<i32>) {
        let l = self.tokens;
        debug_assert_eq!(v.data.len(), l * c);
        // No clear(): a same-sized recycled buffer skips the resize memset
        // — the transpose below overwrites every element.
        out.resize(c * l, 0);
        for tok in 0..l {
            for ch in 0..c {
                out[ch * l + tok] = v.data[tok * c + ch];
            }
        }
    }

    fn slu_forward(
        &mut self,
        x: &crate::spike::EncodedSpikes,
        layer: &crate::quant::QuantizedLinear,
        cfg: &AccelConfig,
        mode: DatapathMode,
        scratch: &mut ExecScratch,
    ) -> (QTensor, UnitStats) {
        match mode {
            // Encoded mode is the dual-engine dispatch point: the
            // `cfg.engine` policy reads this tensor's measured density
            // (per block and timestep) and picks CSR address streaming
            // or the word-parallel bitmap kernel — values bit-identical,
            // stats charging whichever engine ran.
            DatapathMode::Encoded => match cfg.engine.pick(x.density()) {
                EngineKind::Csr => self.slu.forward_into(x, layer, cfg, scratch),
                EngineKind::Bitmap => {
                    let mut bm = scratch.take_bitmap(x.channels, x.tokens);
                    bm.fill_from_encoded(x);
                    let out = self.slu.forward_bitmap_into(&bm, layer, cfg, scratch);
                    scratch.put_bitmap(bm);
                    out
                }
            },
            // The A1 scalar ablation overrides engine selection: it
            // models the no-position-encoding baseline, not the word
            // engine.
            DatapathMode::Bitmap => self.slu.forward_bitmap_baseline_into(x, layer, cfg, scratch),
        }
    }

    /// One timestep of the block. `u` is the `[L, D]` residual-stream value
    /// tensor (token-major); consumed and returned to `scratch`, with the
    /// updated stream handed back (also from `scratch`).
    ///
    /// `t` is the timestep index selecting the ESS ring slot of `buffers`
    /// (`t % depth`). `mapper` — when `Some` and the datapath is encoded —
    /// runs the SDSA pass with heads mapped across the topology's SDEB
    /// comparator arrays under the mapper's policy
    /// ([`SpikeMaskAddModule::run_mapped_into`]), dispatching the
    /// non-first cores on `pool` when one is given; `None` keeps the
    /// serial single-array accounting. Values are bit-identical in every
    /// combination.
    #[allow(clippy::too_many_arguments)]
    pub fn run_timestep(
        &mut self,
        blk: &QuantizedBlock,
        u: QTensor,
        cfg: &AccelConfig,
        mode: DatapathMode,
        t: usize,
        mapper: Option<Mapper>,
        pool: Option<&WorkerPool>,
        buffers: &mut CoreBuffers,
        sink: &mut StatSink,
        scratch: &mut ExecScratch,
    ) -> Result<QTensor> {
        let bi = self.index;
        let d = self.dim;
        // One channel-major transpose buffer, reused by every encode site.
        let mut cl = scratch.take_i32(0);

        // SEA encode the residual stream.
        self.to_cl_into(&u, d, &mut cl);
        let (s_in, st) = self.sea_in.encode_into(&cl, cfg, scratch);
        sink.add("sdeb.encode", st);
        sink.sparsity(&format!("block{bi}.in.spikes"), &s_in);
        // Temporal-delta accounting for the ESS input store: with the flag
        // on, only the per-channel cheaper of (XOR delta vs full re-store)
        // crosses the write ports; values are untouched either way — this
        // is charging, not datapath state.
        let full_words = s_in.storage_words();
        let mut moved_words = full_words;
        if cfg.temporal_delta {
            let mut curr = scratch.take_bitmap(s_in.channels, s_in.tokens);
            curr.fill_from_encoded(&s_in);
            if self.prev_in_valid {
                if let Some(prev) = self.prev_in.as_ref() {
                    moved_words = crate::spike::delta::moved_words(prev, &curr, &s_in);
                }
            }
            if let Some(old) = self.prev_in.replace(curr) {
                scratch.put_bitmap(old);
            }
            self.prev_in_valid = true;
        }
        sink.spike_traffic(full_words as u64, moved_words as u64); // as-ok: widening for 64-bit stat/cycle math
        buffers.store_encoded_moved(&s_in, moved_words, t)?;

        // Q/K/V projections on the Spike Linear Array + SEA fire.
        let (qv, st) = self.slu_forward(&s_in, &blk.q, cfg, mode, scratch);
        sink.add("sdeb.qkv", st);
        self.to_cl_into(&qv, d, &mut cl);
        let (q_s, st) = self.sea_q.encode_into(&cl, cfg, scratch);
        scratch.put_tensor(qv);
        sink.add("sdeb.encode", st);
        let (kv, st) = self.slu_forward(&s_in, &blk.k, cfg, mode, scratch);
        sink.add("sdeb.qkv", st);
        self.to_cl_into(&kv, d, &mut cl);
        let (k_s, st) = self.sea_k.encode_into(&cl, cfg, scratch);
        scratch.put_tensor(kv);
        sink.add("sdeb.encode", st);
        let (vv, st) = self.slu_forward(&s_in, &blk.v, cfg, mode, scratch);
        sink.add("sdeb.qkv", st);
        self.to_cl_into(&vv, d, &mut cl);
        let (v_s, st) = self.sea_v.encode_into(&cl, cfg, scratch);
        scratch.put_tensor(vv);
        sink.add("sdeb.encode", st);
        sink.sparsity(&format!("block{bi}.q.spikes"), &q_s);
        sink.sparsity(&format!("block{bi}.k.spikes"), &k_s);
        sink.sparsity(&format!("block{bi}.v.spikes"), &v_s);
        buffers.store_encoded(&q_s, t)?;
        buffers.store_encoded(&k_s, t)?;
        buffers.store_encoded(&v_s, t)?;
        scratch.put_enc(s_in);

        // SMAM: dual-spike mask-add (the SDSA engine), optionally with
        // heads mapped across the idle cores' comparator arrays by the
        // topology scheduler.
        let (smam_out, st) = match (mode, mapper) {
            (DatapathMode::Encoded, Some(m)) => {
                self.smam.run_mapped_into(&q_s, &k_s, &v_s, cfg, &m, bi, pool, scratch)
            }
            (DatapathMode::Encoded, None) => {
                self.smam.run_mapped_into(&q_s, &k_s, &v_s, cfg, &Mapper::serial(), bi, None, scratch)
            }
            (DatapathMode::Bitmap, _) => {
                self.smam.run_dense_baseline_into(&q_s, &k_s, &v_s, cfg, scratch)
            }
        };
        sink.add("sdeb.smam", st);
        sink.sparsity(&format!("block{bi}.sdsa.spikes"), &smam_out.masked_v);
        let SmamOutput { mask, acc, masked_v } = smam_out;
        scratch.put_bool(mask);
        scratch.put_u32(acc);
        scratch.put_enc(q_s);
        scratch.put_enc(k_s);
        scratch.put_enc(v_s);

        // Output projection + residual.
        let (ov, st) = self.slu_forward(&masked_v, &blk.o, cfg, mode, scratch);
        sink.add("sdeb.proj", st);
        scratch.put_enc(masked_v);
        let (u2, st) = self.adder.add_into(&u, &ov, cfg, scratch);
        sink.add("sdeb.residual", st);
        scratch.put_tensor(u);
        scratch.put_tensor(ov);
        let u = u2;

        // MLP: encode -> SLU -> encode -> SLU -> residual.
        self.to_cl_into(&u, d, &mut cl);
        let (s2, st) = self.sea_mlp_in.encode_into(&cl, cfg, scratch);
        sink.add("sdeb.encode", st);
        sink.sparsity(&format!("block{bi}.mlp.in.spikes"), &s2);
        buffers.store_encoded(&s2, t)?;
        let (hv, st) = self.slu_forward(&s2, &blk.mlp1, cfg, mode, scratch);
        sink.add("sdeb.mlp", st);
        scratch.put_enc(s2);
        let h = blk.mlp1.out_dim;
        self.to_cl_into(&hv, h, &mut cl);
        let (s3, st) = self.sea_mlp_hidden.encode_into(&cl, cfg, scratch);
        scratch.put_tensor(hv);
        sink.add("sdeb.encode", st);
        sink.sparsity(&format!("block{bi}.mlp.hidden.spikes"), &s3);
        buffers.store_encoded(&s3, t)?;
        let (m2, st) = self.slu_forward(&s3, &blk.mlp2, cfg, mode, scratch);
        sink.add("sdeb.mlp", st);
        scratch.put_enc(s3);
        let (u3, st) = self.adder.add_into(&u, &m2, cfg, scratch);
        sink.add("sdeb.residual", st);
        scratch.put_tensor(u);
        scratch.put_tensor(m2);
        scratch.put_i32(cl);

        Ok(u3)
    }

    /// One decode-mode timestep of the block for a single new token.
    ///
    /// The autoregressive twin of [`Self::run_timestep`], with three
    /// deliberate differences (DESIGN.md "Decode & KV cache"):
    /// * the core must be built with `tokens == 1` — `u` is the new
    ///   token's `[1, D]` residual-stream row;
    /// * the K/V spike rows are appended to this `(block, timestep)`
    ///   lane's [`KvCacheStream`] (charged as ESS writes under the
    ///   `sdeb.kvcache` phase) instead of the transient ESS ring, and the
    ///   SDSA pass is [`SpikeMaskAddModule::run_incremental_into`] over
    ///   the cached causal prefix;
    /// * temporal-delta charging is skipped: consecutive *positions* are
    ///   different tokens, not re-presentations of one input, so the
    ///   input store always moves its full words.
    ///
    /// Always runs the encoded datapath (the A1 bitmap-baseline ablation
    /// is vision-only); `cfg.engine` still resolves CSR vs word engine
    /// per work unit inside the SLU and the incremental SMAM.
    #[allow(clippy::too_many_arguments)]
    pub fn run_decode_timestep(
        &mut self,
        blk: &QuantizedBlock,
        u: QTensor,
        cfg: &AccelConfig,
        heads: usize,
        t: usize,
        cache: &mut KvCacheStream,
        buffers: &mut CoreBuffers,
        sink: &mut StatSink,
        scratch: &mut ExecScratch,
    ) -> Result<QTensor> {
        assert_eq!(self.tokens, 1, "decode cores process one token position at a time");
        let bi = self.index;
        let d = self.dim;
        let mut cl = scratch.take_i32(0);

        // SEA encode the new token's residual row.
        self.to_cl_into(&u, d, &mut cl);
        let (s_in, st) = self.sea_in.encode_into(&cl, cfg, scratch);
        sink.add("sdeb.encode", st);
        sink.sparsity(&format!("block{bi}.in.spikes"), &s_in);
        let full_words = s_in.storage_words() as u64; // as-ok: widening for 64-bit stat/cycle math
        sink.spike_traffic(full_words, full_words);
        buffers.store_encoded(&s_in, t)?;

        // Q/K/V projections + SEA fire, exactly as the vision path.
        let (qv, st) = self.slu_forward(&s_in, &blk.q, cfg, DatapathMode::Encoded, scratch);
        sink.add("sdeb.qkv", st);
        self.to_cl_into(&qv, d, &mut cl);
        let (q_s, st) = self.sea_q.encode_into(&cl, cfg, scratch);
        scratch.put_tensor(qv);
        sink.add("sdeb.encode", st);
        let (kv, st) = self.slu_forward(&s_in, &blk.k, cfg, DatapathMode::Encoded, scratch);
        sink.add("sdeb.qkv", st);
        self.to_cl_into(&kv, d, &mut cl);
        let (k_s, st) = self.sea_k.encode_into(&cl, cfg, scratch);
        scratch.put_tensor(kv);
        sink.add("sdeb.encode", st);
        let (vv, st) = self.slu_forward(&s_in, &blk.v, cfg, DatapathMode::Encoded, scratch);
        sink.add("sdeb.qkv", st);
        self.to_cl_into(&vv, d, &mut cl);
        let (v_s, st) = self.sea_v.encode_into(&cl, cfg, scratch);
        scratch.put_tensor(vv);
        sink.add("sdeb.encode", st);
        sink.sparsity(&format!("block{bi}.q.spikes"), &q_s);
        sink.sparsity(&format!("block{bi}.k.spikes"), &k_s);
        sink.sparsity(&format!("block{bi}.v.spikes"), &v_s);
        buffers.store_encoded(&q_s, t)?;
        scratch.put_enc(s_in);

        // K/V rows join the session-lifetime cache (ESS write charge);
        // the transient ring never sees them in decode mode.
        let app = cache.append_into(&k_s, &v_s);
        sink.add(
            "sdeb.kvcache",
            UnitStats { sram_writes: app.words, ..Default::default() },
        );
        scratch.put_enc(k_s);
        scratch.put_enc(v_s);

        // Incremental SDSA: the new Q row against the cached K stream
        // (which now includes this token's own row).
        let (attn, st) = self.smam.run_incremental_into(&q_s, cache, heads, cfg, scratch);
        sink.add("sdeb.smam", st);
        sink.sparsity(&format!("block{bi}.sdsa.spikes"), &attn);
        scratch.put_enc(q_s);

        // Output projection + residual.
        let (ov, st) = self.slu_forward(&attn, &blk.o, cfg, DatapathMode::Encoded, scratch);
        sink.add("sdeb.proj", st);
        scratch.put_enc(attn);
        let (u2, st) = self.adder.add_into(&u, &ov, cfg, scratch);
        sink.add("sdeb.residual", st);
        scratch.put_tensor(u);
        scratch.put_tensor(ov);
        let u = u2;

        // MLP: encode -> SLU -> encode -> SLU -> residual.
        self.to_cl_into(&u, d, &mut cl);
        let (s2, st) = self.sea_mlp_in.encode_into(&cl, cfg, scratch);
        sink.add("sdeb.encode", st);
        sink.sparsity(&format!("block{bi}.mlp.in.spikes"), &s2);
        buffers.store_encoded(&s2, t)?;
        let (hv, st) = self.slu_forward(&s2, &blk.mlp1, cfg, DatapathMode::Encoded, scratch);
        sink.add("sdeb.mlp", st);
        scratch.put_enc(s2);
        let h = blk.mlp1.out_dim;
        self.to_cl_into(&hv, h, &mut cl);
        let (s3, st) = self.sea_mlp_hidden.encode_into(&cl, cfg, scratch);
        scratch.put_tensor(hv);
        sink.add("sdeb.encode", st);
        sink.sparsity(&format!("block{bi}.mlp.hidden.spikes"), &s3);
        buffers.store_encoded(&s3, t)?;
        let (m2, st) = self.slu_forward(&s3, &blk.mlp2, cfg, DatapathMode::Encoded, scratch);
        sink.add("sdeb.mlp", st);
        scratch.put_enc(s3);
        let (u3, st) = self.adder.add_into(&u, &m2, cfg, scratch);
        sink.add("sdeb.residual", st);
        scratch.put_tensor(u);
        scratch.put_tensor(m2);
        scratch.put_i32(cl);

        Ok(u3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::buffers::BufferSet;
    use crate::model::{QuantizedModel, SdtModelConfig};
    use crate::quant::{QFormat, ACT_FRAC, MEM_BITS};
    use crate::util::Prng;

    fn setup() -> (QuantizedModel, QTensor, AccelConfig) {
        let cfg = SdtModelConfig::tiny();
        let model = QuantizedModel::random(&cfg, 6);
        let mut rng = Prng::new(2);
        let vals: Vec<f32> = (0..64 * 64).map(|_| rng.next_f32_signed() * 1.5).collect();
        let u = QTensor::from_f32(&vals, &[64, 64], QFormat::new(MEM_BITS, ACT_FRAC));
        (model, u, AccelConfig::small())
    }

    #[test]
    fn block_preserves_shape_and_format() {
        let (model, u, hw) = setup();
        let mc = &model.cfg;
        let mut core =
            SdebCore::new(0, 64, 64, mc.mlp_hidden, mc.attn_v_th, mc.lif_params());
        let mut buffers = BufferSet::new(&hw);
        let mut sink = StatSink::new();
        let mut scratch = ExecScratch::new();
        let out = core
            .run_timestep(
                &model.blocks[0],
                u,
                &hw,
                DatapathMode::Encoded,
                0,
                None,
                None,
                buffers.sdeb_for(0),
                &mut sink,
                &mut scratch,
            )
            .unwrap();
        assert_eq!(out.shape, vec![64, 64]);
        assert_eq!(out.frac, ACT_FRAC);
        for phase in ["sdeb.encode", "sdeb.qkv", "sdeb.smam", "sdeb.mlp", "sdeb.residual"] {
            assert!(sink.phases.get(phase).cycles > 0, "phase {phase} missing");
        }
    }

    #[test]
    fn encoded_and_bitmap_modes_agree_on_values() {
        let (model, u, hw) = setup();
        let mc = &model.cfg;
        let mut c1 = SdebCore::new(0, 64, 64, mc.mlp_hidden, mc.attn_v_th, mc.lif_params());
        let mut c2 = SdebCore::new(0, 64, 64, mc.mlp_hidden, mc.attn_v_th, mc.lif_params());
        let mut b1 = BufferSet::new(&hw);
        let mut b2 = BufferSet::new(&hw);
        let mut s1 = StatSink::new();
        let mut s2 = StatSink::new();
        let mut sc1 = ExecScratch::new();
        let mut sc2 = ExecScratch::new();
        let o1 = c1
            .run_timestep(&model.blocks[0], u.clone(), &hw, DatapathMode::Encoded, 0, None, None, b1.sdeb_for(0), &mut s1, &mut sc1)
            .unwrap();
        let o2 = c2
            .run_timestep(&model.blocks[0], u, &hw, DatapathMode::Bitmap, 0, None, None, b2.sdeb_for(0), &mut s2, &mut sc2)
            .unwrap();
        assert_eq!(o1, o2);
    }

    #[test]
    fn engine_select_never_changes_block_values() {
        use crate::hw::EngineSelect;
        let (model, u, hw) = setup();
        let mc = &model.cfg;
        let run = |engine: EngineSelect| {
            let mut hw = hw.clone();
            hw.engine = engine;
            let mut core =
                SdebCore::new(0, 64, 64, mc.mlp_hidden, mc.attn_v_th, mc.lif_params());
            let mut buffers = BufferSet::new(&hw);
            let mut sink = StatSink::new();
            let mut scratch = ExecScratch::new();
            let out = core
                .run_timestep(
                    &model.blocks[0],
                    u.clone(),
                    &hw,
                    DatapathMode::Encoded,
                    0,
                    None,
                    None,
                    buffers.sdeb_for(0),
                    &mut sink,
                    &mut scratch,
                )
                .unwrap();
            (out, sink.phases.get("sdeb.qkv").cycles)
        };
        let (csr, csr_cycles) = run(EngineSelect::Csr);
        let (bitmap, bitmap_cycles) = run(EngineSelect::Bitmap);
        let (adaptive, _) = run(EngineSelect::adaptive());
        assert_eq!(csr, bitmap, "bitmap engine must be bit-identical");
        assert_eq!(csr, adaptive, "adaptive engine must be bit-identical");
        assert_ne!(
            csr_cycles, bitmap_cycles,
            "the two engines should charge different QKV cycle counts on this shape"
        );
    }

    #[test]
    fn decode_timestep_appends_cache_and_charges_kvcache_phase() {
        let cfg = SdtModelConfig::tiny_decoder();
        let model = QuantizedModel::random(&cfg, 9);
        let hw = AccelConfig::small();
        let mut core = SdebCore::new(0, 1, 64, cfg.mlp_hidden, cfg.attn_v_th, cfg.lif_params());
        let mut cache = KvCacheStream::new(cfg.decoder_shape().unwrap().max_seq_len, 64);
        let mut buffers = BufferSet::new(&hw);
        let mut sink = StatSink::new();
        let mut scratch = ExecScratch::new();
        for p in 0..3 {
            let row = model.embed_row(p).unwrap();
            let u = QTensor { shape: vec![1, 64], frac: ACT_FRAC, data: row.to_vec() };
            let out = core
                .run_decode_timestep(
                    &model.blocks[0],
                    u,
                    &hw,
                    cfg.num_heads,
                    0,
                    &mut cache,
                    buffers.sdeb_for(0),
                    &mut sink,
                    &mut scratch,
                )
                .unwrap();
            assert_eq!(out.shape, vec![1, 64]);
            assert_eq!(cache.len(), p + 1, "cache grows by one per position");
            scratch.put_tensor(out);
        }
        assert!(sink.phases.get("sdeb.kvcache").sram_writes > 0, "cache writes charged");
        assert!(sink.phases.get("sdeb.smam").cycles > 0);
        // Decode SMAM cost at position p reflects a 3-deep causal scan.
        assert!(sink.phases.get("sdeb.smam").sops > 0);
    }

    #[test]
    fn timesteps_carry_lif_state() {
        let (model, u, hw) = setup();
        let mc = &model.cfg;
        let mut core =
            SdebCore::new(0, 64, 64, mc.mlp_hidden, mc.attn_v_th, mc.lif_params());
        let mut buffers = BufferSet::new(&hw);
        let mut sink = StatSink::new();
        let mut scratch = ExecScratch::new();
        let o1 = core
            .run_timestep(&model.blocks[0], u.clone(), &hw, DatapathMode::Encoded, 0, None, None, buffers.sdeb_for(0), &mut sink, &mut scratch)
            .unwrap();
        // Same input, different membrane state -> (almost surely) different output.
        let o2 = core
            .run_timestep(&model.blocks[0], u.clone(), &hw, DatapathMode::Encoded, 0, None, None, buffers.sdeb_for(0), &mut sink, &mut scratch)
            .unwrap();
        core.reset();
        let o3 = core
            .run_timestep(&model.blocks[0], u, &hw, DatapathMode::Encoded, 0, None, None, buffers.sdeb_for(0), &mut sink, &mut scratch)
            .unwrap();
        assert_eq!(o1, o3, "reset must restore t=0 behaviour");
        let _ = o2;
    }
}
