//! Analytic pipelined-schedule estimator (cross-check).
//!
//! The real accelerator double-buffers between the SPS Core and the SDEB
//! Core (Fig. 1: each core has its own SEA/ESS pair), so timestep t+1's
//! SPS work overlaps timestep t's SDEB work, and the external I/O overlaps
//! compute. Since the overlapped [`executor`](super::executor) landed, the
//! controller **executes** that schedule and reports the measured
//! [`PipelineExecution`](super::executor::PipelineExecution); this module
//! re-times a recorded [`PhaseStats`] under a closed-form steady-state
//! model and serves as the independent cross-check — the executed and
//! estimated pipelined cycle counts must agree within the fill-latency
//! bound (see `PipelineExecution::reconciles_with`, enforced by
//! `tests/pipeline_overlap.rs`).

use crate::hw::stats::PhaseStats;

/// Which pipeline stage a phase belongs to.
fn stage_of(phase: &str) -> Stage {
    if phase.starts_with("io.") {
        Stage::Io
    } else if phase.starts_with("sps.") {
        Stage::Sps
    } else {
        Stage::Sdeb // sdeb.* and head.*
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Stage {
    Io,
    Sps,
    Sdeb,
}

/// Result of re-timing a run under the two-core overlap model.
#[derive(Clone, Copy, Debug)]
pub struct PipelineEstimate {
    /// Total cycles charged serially.
    pub serialized_cycles: u64,
    /// max(io, sps, sdeb) + pipeline fill (one stage latency of each
    /// non-bottleneck stage, amortised over timesteps).
    pub pipelined_cycles: u64,
    /// The I/O stage's total cycles.
    pub io_cycles: u64,
    /// The SPS stage's total cycles.
    pub sps_cycles: u64,
    /// The SDEB stage's total cycles.
    pub sdeb_cycles: u64,
}

impl PipelineEstimate {
    /// Serialized over pipelined cycles.
    pub fn speedup(&self) -> f64 {
        if self.pipelined_cycles == 0 {
            return 1.0;
        }
        self.serialized_cycles as f64 / self.pipelined_cycles as f64 // as-ok: reporting ratio, not datapath state
    }

    /// Which stage bounds the pipelined schedule.
    pub fn bottleneck(&self) -> &'static str {
        let m = self.io_cycles.max(self.sps_cycles).max(self.sdeb_cycles);
        if m == self.sdeb_cycles {
            "sdeb"
        } else if m == self.sps_cycles {
            "sps"
        } else {
            "io"
        }
    }
}

/// Estimate the pipelined schedule for a run of `timesteps` timesteps.
///
/// Model: the three stages form a linear pipeline over timesteps; the
/// steady-state period is the slowest stage's per-timestep cycles, plus a
/// fill of one per-timestep latency for each upstream stage.
pub fn estimate(phases: &PhaseStats, timesteps: usize) -> PipelineEstimate {
    let t = timesteps.max(1) as u64; // as-ok: widening for 64-bit stat/cycle math
    let (mut io, mut sps, mut sdeb) = (0u64, 0u64, 0u64);
    for (name, st) in &phases.phases {
        match stage_of(name) {
            Stage::Io => io += st.cycles,
            Stage::Sps => sps += st.cycles,
            Stage::Sdeb => sdeb += st.cycles,
        }
    }
    let serialized = io + sps + sdeb;
    let bottleneck = io.max(sps).max(sdeb);
    // steady state: bottleneck dominates; fill: one timestep of each
    // non-bottleneck stage entering the pipe.
    let fill = (io + sps + sdeb - bottleneck) / t;
    let pipelined = bottleneck + fill;
    PipelineEstimate {
        serialized_cycles: serialized,
        pipelined_cycles: pipelined.min(serialized),
        io_cycles: io,
        sps_cycles: sps,
        sdeb_cycles: sdeb,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::UnitStats;

    fn stats(cycles: u64) -> UnitStats {
        UnitStats { cycles, ..Default::default() }
    }

    #[test]
    fn balanced_stages_overlap_fully() {
        let mut p = PhaseStats::new();
        p.add("sps.conv", stats(1000));
        p.add("sdeb.qkv", stats(1000));
        let e = estimate(&p, 4);
        assert_eq!(e.serialized_cycles, 2000);
        // bottleneck 1000 + fill 1000/4 = 1250
        assert_eq!(e.pipelined_cycles, 1250);
        assert!((e.speedup() - 1.6).abs() < 1e-12);
    }

    #[test]
    fn skewed_pipeline_bounded_by_bottleneck() {
        let mut p = PhaseStats::new();
        p.add("io.input", stats(10));
        p.add("sps.conv", stats(100));
        p.add("sdeb.mlp", stats(5000));
        let e = estimate(&p, 4);
        assert_eq!(e.bottleneck(), "sdeb");
        assert!(e.pipelined_cycles >= 5000);
        assert!(e.pipelined_cycles < e.serialized_cycles);
    }

    #[test]
    fn single_stage_no_speedup() {
        let mut p = PhaseStats::new();
        p.add("sps.conv", stats(777));
        let e = estimate(&p, 2);
        assert_eq!(e.pipelined_cycles, 777);
        assert_eq!(e.serialized_cycles, 777);
        assert!((e.speedup() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn never_exceeds_serialized() {
        let mut p = PhaseStats::new();
        p.add("io.input", stats(3));
        p.add("sps.encode", stats(5));
        p.add("sdeb.smam", stats(2));
        let e = estimate(&p, 1);
        assert!(e.pipelined_cycles <= e.serialized_cycles);
    }

    #[test]
    fn real_run_speedup_between_1_and_3() {
        use crate::accel::Accelerator;
        use crate::hw::AccelConfig;
        use crate::model::{QuantizedModel, SdtModelConfig};
        use crate::util::Prng;
        let cfg = SdtModelConfig::tiny();
        let model = QuantizedModel::random(&cfg, 3);
        let mut accel = Accelerator::new(model, AccelConfig::paper());
        let mut rng = Prng::new(1);
        let img: Vec<f32> = (0..3 * 32 * 32).map(|_| rng.next_f32_signed()).collect();
        let r = accel.infer(&img).unwrap();
        let e = estimate(&r.phases, 2);
        assert!(e.speedup() >= 1.0 && e.speedup() <= 3.0, "{e:?}");
    }
}
