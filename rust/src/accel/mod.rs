//! Accelerator top level (Fig. 1): the SPS Core (Tile Engine + Maxpooling
//! Array + SEA/ESS), the SDEB Core (SEA/ESS + SMAM + Spike Linear Array),
//! the ResBuffer/Adder Module, the Controller that sequences them, and the
//! buffer/SRAM complement. [`Accelerator::infer`] runs a full quantized
//! Spike-driven Transformer inference with cycle/energy/sparsity accounting
//! and returns the same logits as the dense golden executor — bit-exactly.
//!
//! By default the controller **executes** the paper's core overlap: the
//! SPS stage of timestep `t+1` runs concurrently with the SDEB stage of
//! timestep `t` ([`executor`]), with attention heads mapped across the
//! SDEB cores by the [`mapper`] scheduler and the ESS modelled as an
//! explicit buffer ring ([`buffers::CoreBuffers`]) whose depth comes from
//! the instance's [`CoreTopology`](crate::hw::CoreTopology) (the paper's
//! ping/pong pair is depth 2). The analytic re-timer ([`pipeline`])
//! remains as a cross-check on the executed schedule. `ExecMode::Serial`
//! preserves the original serial charging for ablations.

pub mod buffers;
pub mod controller;
pub mod decode;
pub mod dma;
pub mod executor;
pub mod mapper;
pub mod pipeline;
pub mod report;
pub mod sdeb_core;
pub mod sps_core;
pub mod workers;

pub use buffers::SlotRing;
pub use controller::{Accelerator, DatapathMode, ExecMode};
pub use decode::{DecodeReport, DecodeSession};
pub use dma::{BlockPlan, DmaEngine, WeightResidency, WEIGHT_STREAM_BYTES};
pub use mapper::{Mapper, MappingPolicy, WorkUnit};
pub use workers::WorkerPool;
pub use executor::PipelineExecution;
pub use pipeline::{estimate as pipeline_estimate, PipelineEstimate};
pub use report::RunReport;
pub use sdeb_core::SdebCore;
pub use sps_core::SpsCore;
