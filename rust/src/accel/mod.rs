//! Accelerator top level (Fig. 1): the SPS Core (Tile Engine + Maxpooling
//! Array + SEA/ESS), the SDEB Core (SEA/ESS + SMAM + Spike Linear Array),
//! the ResBuffer/Adder Module, the Controller that sequences them, and the
//! buffer/SRAM complement. [`Accelerator::infer`] runs a full quantized
//! Spike-driven Transformer inference with cycle/energy/sparsity accounting
//! and returns the same logits as the dense golden executor — bit-exactly.

pub mod buffers;
pub mod controller;
pub mod pipeline;
pub mod report;
pub mod sdeb_core;
pub mod sps_core;

pub use controller::{Accelerator, DatapathMode};
pub use pipeline::{estimate as pipeline_estimate, PipelineEstimate};
pub use report::RunReport;
pub use sdeb_core::SdebCore;
pub use sps_core::SpsCore;
