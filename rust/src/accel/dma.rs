//! Weight-streaming DMA engine: plans which weight working sets move
//! over the shared [`DramBus`](crate::hw::DramBus), when their transfers
//! may start, and whether they stay resident on chip.
//!
//! The paper's Fig. 1 dataflow keeps the compute cores fed through the
//! Input/Output Buffers; this module makes that feeding explicit. Each
//! SDEB core owns a weight buffer of
//! [`AccelConfig::weight_buffer_words`] words cut into
//! [`AccelConfig::weight_slots`] ping/pong slots (the same double-buffer
//! discipline as the ESS ring), and each encoder block's working set —
//! its Q/K/V/O and MLP matrices plus biases, 10-bit weights packed into
//! 16-bit memory words — is classified per core:
//!
//! * **Resident** — every set hosted on the core fits one slot and the
//!   core hosts no more sets than slots: each set streams **once per
//!   inference** (a prefetch ahead of its first use) and then stays on
//!   chip.
//! * **Thrash** — every set fits one slot but the core hosts more sets
//!   than slots, so the cyclic rotation evicts each set eventually. Since
//!   PR 8's weight-resident timestep scheduling, any set that *fits a
//!   slot* also streams **once per inference**: the controller
//!   interchanges the loops for fitting blocks (block-outer,
//!   timestep-inner — dataflow-valid because block `b` at timestep `t`
//!   needs only block `b-1`'s output at `t`, already complete, and its
//!   own LIF state at `t-1`, sequential within the block), so the set is
//!   hot across all T of its uses before the rotation reclaims its slot.
//!   The transfer for a first use may start once the slot it refills
//!   frees — when the use `weight_slots` back on that core finishes —
//!   the ping/pong prefetch running one working set ahead.
//! * **Streaming** — the set is larger than one slot: it cannot stay
//!   resident at all and streams through on **every** use. The head of
//!   the next use's stream (up to one slot's worth, with the transfer's
//!   bus cycles split so head + tail cost exactly the unsplit transfer)
//!   prefetches into the slot freed `weight_slots` uses back; the tail is
//!   gated on the core's previous use finishing.
//!
//! The SPS Core's convolution weights are **pinned**: they are reused by
//! every timestep, live in the SPS core's own buffer, and are charged at
//! model-load time rather than per inference (the `pinned_sps_words`
//! field of [`DmaEngine`] reports the footprint). The per-inference streamed traffic is the
//! SDEB side, which is exactly where the paper-scale working sets
//! (≈1.77 M words per block vs a 1 M-word slot) outgrow the on-chip
//! buffer.
//!
//! **Block→core affinity.** Weight placement follows the ESS-ring
//! convention (`core = block % sdeb_cores`, the same rule as
//! [`BufferSet::sdeb_for`](super::buffers::BufferSet::sdeb_for)): the
//! weight-heavy consumers — the SLU's Q/K/V/O and MLP passes — are
//! block-granular and run on the block's host core. The SDSA head→core
//! [`MappingPolicy`](super::MappingPolicy) moves **SMAM comparator**
//! work only, which consumes spikes, not weights — so the memory plan
//! (and the resulting `MemoryReport`) is deliberately invariant under
//! `--mapping`.
//!
//! The plan is a pure function of the model and the hardware config, so
//! the executed schedule that consumes it
//! ([`PipelineExecution`](super::PipelineExecution)) stays
//! bit-deterministic.

use crate::hw::AccelConfig;
use crate::model::QuantizedModel;

/// Bytes one weight word occupies on the external bus (10-bit weights
/// packed into 16-bit memory words, the same packing as the 10-bit input
/// activations).
pub const WEIGHT_STREAM_BYTES: u64 = 2;

/// How a block's weight working set behaves on its host core's weight
/// buffer (see the module docs for the three regimes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WeightResidency {
    /// Streams once per inference, then stays on chip.
    Resident,
    /// Fits a slot but shares the core with more sets than slots: streams
    /// once per inference under the block-outer timestep schedule (hot
    /// across all its uses), then is evicted by the slot rotation.
    Thrash,
    /// Larger than a slot: streams through on every use, the head of each
    /// stream prefetched into the freed ping/pong slot one use ahead.
    Streaming,
}

/// One encoder block's planned weight movement.
#[derive(Clone, Debug)]
pub struct BlockPlan {
    /// Working-set size in weight words (matrices + biases).
    pub words: u64,
    /// Working-set size in bus bytes ([`WEIGHT_STREAM_BYTES`] per word).
    pub bytes: u64,
    /// The SDEB core hosting this block (`block % sdeb_cores`).
    pub core: usize,
    /// Residency classification on that core.
    pub residency: WeightResidency,
}

impl BlockPlan {
    /// Does this set re-stream on every use (vs once per inference)?
    /// Only sets larger than a slot do: fitting sets — Resident *and*
    /// Thrash — stream once under the weight-resident timestep schedule
    /// (block-outer loop order keeps a fitting set hot across all its
    /// uses; see the module docs).
    pub fn streams_every_use(&self) -> bool {
        self.residency == WeightResidency::Streaming
    }
}

/// The weight-streaming plan for one (model, hardware config) pair.
///
/// ```
/// use spikeformer_accel::accel::{DmaEngine, WeightResidency};
/// use spikeformer_accel::hw::AccelConfig;
/// use spikeformer_accel::model::{QuantizedModel, SdtModelConfig};
///
/// let model = QuantizedModel::random(&SdtModelConfig::tiny(), 1);
/// let dma = DmaEngine::new(&model, &AccelConfig::small());
/// // tiny's single encoder block fits a ping/pong slot and has the core
/// // to itself, so its weights stream exactly once per inference.
/// assert_eq!(dma.blocks.len(), 1);
/// assert_eq!(dma.blocks[0].residency, WeightResidency::Resident);
/// assert!(dma.blocks[0].bytes > 0);
/// // One inference therefore streams one working set.
/// assert_eq!(dma.streamed_bytes_per_inference(model.cfg.timesteps), dma.blocks[0].bytes);
/// ```
#[derive(Clone, Debug)]
pub struct DmaEngine {
    /// Bus bandwidth the plan schedules against (bytes/cycle).
    pub bytes_per_cycle: usize,
    /// Ping/pong slots per SDEB-core weight buffer.
    pub slots: usize,
    /// Capacity of one slot in bus bytes — how much of an oversized
    /// Streaming set the executor may prefetch into the freed ping/pong
    /// slot ahead of the block's previous use finishing.
    pub slot_bytes: u64,
    /// Per-block movement plans, in block order.
    pub blocks: Vec<BlockPlan>,
    /// Input-image transfer size in bytes (10-bit activations packed
    /// 2 B/value) — the bus client the weight DMA queues behind.
    pub input_bytes: u64,
    /// Output logits transfer size in bytes (f32).
    pub output_bytes: u64,
    /// Pinned SPS convolution-weight footprint in words (charged at model
    /// load, not per inference — see the module docs).
    pub pinned_sps_words: u64,
}

impl DmaEngine {
    /// Plan the weight movement of `model` on `hw`.
    pub fn new(model: &QuantizedModel, hw: &AccelConfig) -> Self {
        let cfg = &model.cfg;
        let cores = hw.topology.sdeb_cores.max(1);
        let slot_words = hw.weight_slot_words() as u64; // as-ok: widening for 64-bit stat/cycle math
        let slots = hw.weight_slots.max(2);

        let words: Vec<u64> = model.blocks.iter().map(block_set_words).collect();
        // Per-core classification: any oversized set forces the whole
        // core into streaming mode (it transiently needs the full
        // buffer); otherwise residency is a pure slot-count question.
        let mut residency = vec![WeightResidency::Resident; words.len()];
        for c in 0..cores {
            let hosted: Vec<usize> = (0..words.len()).filter(|b| b % cores == c).collect();
            let any_oversized = hosted.iter().any(|&b| words[b] > slot_words);
            for &b in &hosted {
                residency[b] = if any_oversized {
                    WeightResidency::Streaming
                } else if hosted.len() > slots {
                    WeightResidency::Thrash
                } else {
                    WeightResidency::Resident
                };
            }
        }

        let blocks = words
            .iter()
            .zip(&residency)
            .enumerate()
            .map(|(b, (&w, &r))| BlockPlan {
                words: w,
                bytes: w * WEIGHT_STREAM_BYTES,
                core: b % cores,
                residency: r,
            })
            .collect();

        let pinned_sps_words = model
            .sps_convs
            .iter()
            .map(|c| (c.w.len() + c.bias.len()) as u64) // as-ok: widening for 64-bit stat/cycle math
            .sum();

        Self {
            bytes_per_cycle: hw.dram_bytes_per_cycle,
            slots,
            slot_bytes: slot_words * WEIGHT_STREAM_BYTES,
            blocks,
            input_bytes: (cfg.in_channels * cfg.img_size * cfg.img_size * 2) as u64, // as-ok: widening for 64-bit stat/cycle math
            output_bytes: (cfg.num_classes * 4) as u64, // as-ok: widening for 64-bit stat/cycle math
            pinned_sps_words,
        }
    }

    /// Total weight bytes one inference of `timesteps` timesteps streams
    /// over the bus under this plan: every fitting set (Resident and
    /// Thrash — the weight-resident timestep schedule) once, oversized
    /// Streaming sets once per use.
    pub fn streamed_bytes_per_inference(&self, timesteps: usize) -> u64 {
        self.blocks
            .iter()
            .map(|b| if b.streams_every_use() { b.bytes * timesteps as u64 } else { b.bytes }) // as-ok: widening for 64-bit stat/cycle math
            .sum()
    }

    /// Per-block regime classification counts `(resident, thrash,
    /// streaming)` — the roofline-readability numbers surfaced in
    /// [`MemoryReport`](crate::hw::MemoryReport) and the run summary.
    pub fn regime_counts(&self) -> (usize, usize, usize) {
        let mut counts = (0usize, 0usize, 0usize);
        for b in &self.blocks {
            match b.residency {
                WeightResidency::Resident => counts.0 += 1,
                WeightResidency::Thrash => counts.1 += 1,
                WeightResidency::Streaming => counts.2 += 1,
            }
        }
        counts
    }

    /// Bytes of weight working sets that stream once per inference and
    /// then sit on chip for all their uses (Resident + Thrash blocks
    /// under the weight-resident timestep schedule).
    pub fn resident_bytes(&self) -> u64 {
        self.blocks.iter().filter(|b| !b.streams_every_use()).map(|b| b.bytes).sum()
    }

    /// Does any block re-stream per use (i.e. does the plan generate
    /// sustained, rather than fill-time-only, weight traffic)?
    pub fn has_sustained_traffic(&self) -> bool {
        self.blocks.iter().any(|b| b.streams_every_use())
    }

    /// This plan re-scheduled against a different bus bandwidth (the
    /// residency classification is bandwidth-independent, so sweeps can
    /// retime one recorded run across the whole `--dram-bw` axis).
    pub fn with_bandwidth(mut self, bytes_per_cycle: usize) -> Self {
        self.bytes_per_cycle = bytes_per_cycle;
        self
    }
}

/// Weight words of one encoder block's working set: the Q/K/V/O
/// projections and both MLP matrices, plus their biases.
fn block_set_words(blk: &crate::model::QuantizedBlock) -> u64 {
    [&blk.q, &blk.k, &blk.v, &blk.o, &blk.mlp1, &blk.mlp2]
        .iter()
        .map(|l| (l.w.len() + l.bias.len()) as u64) // as-ok: widening for 64-bit stat/cycle math
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::CoreTopology;
    use crate::model::SdtModelConfig;

    fn model(blocks: usize) -> QuantizedModel {
        let cfg = SdtModelConfig { num_blocks: blocks, ..SdtModelConfig::tiny() };
        QuantizedModel::random(&cfg, 3)
    }

    #[test]
    fn tiny_blocks_are_resident() {
        let m = model(2);
        let dma = DmaEngine::new(&m, &AccelConfig::small());
        // 2 blocks over 2 cores: one fitting set each -> resident.
        assert!(dma.blocks.iter().all(|b| b.residency == WeightResidency::Resident));
        assert!(!dma.has_sustained_traffic());
        // Words: 4 * (64*64 + 64) + (64*128 + 128) + (128*64 + 64).
        assert_eq!(dma.blocks[0].words, 4 * 4160 + 8320 + 8256);
        assert_eq!(dma.blocks[0].bytes, dma.blocks[0].words * 2);
        assert_eq!(
            dma.streamed_bytes_per_inference(4),
            dma.blocks[0].bytes + dma.blocks[1].bytes
        );
    }

    #[test]
    fn paper_blocks_exceed_a_slot_and_stream() {
        let cfg = SdtModelConfig::paper();
        let m = QuantizedModel::random(&cfg, 3);
        let hw = AccelConfig::paper();
        let dma = DmaEngine::new(&m, &hw);
        // 4*(384*384+384) + (384*1536+1536) + (1536*384+384) words.
        assert_eq!(dma.blocks[0].words, 1_772_928);
        assert!(dma.blocks[0].words > hw.weight_slot_words() as u64);
        assert!(dma.blocks.iter().all(|b| b.residency == WeightResidency::Streaming));
        assert!(dma.has_sustained_traffic());
        assert_eq!(
            dma.streamed_bytes_per_inference(cfg.timesteps),
            2 * dma.blocks[0].bytes * cfg.timesteps as u64
        );
        assert!(dma.pinned_sps_words > 0);
        assert_eq!(dma.regime_counts(), (0, 0, 2));
        assert_eq!(dma.resident_bytes(), 0);
    }

    #[test]
    fn crowded_core_thrashes() {
        // 3 fitting sets on one core with 2 slots: cyclic eviction.
        let m = model(3);
        let hw = AccelConfig::small()
            .with_topology(CoreTopology::with_sdeb_cores(1));
        let dma = DmaEngine::new(&m, &hw);
        assert!(dma.blocks.iter().all(|b| b.residency == WeightResidency::Thrash));
        assert!(dma.blocks.iter().all(|b| b.core == 0));
        // Weight-resident timestep scheduling: fitting sets stream once
        // per inference even when the slot rotation evicts them later.
        let once: u64 = dma.blocks.iter().map(|b| b.bytes).sum();
        assert_eq!(dma.streamed_bytes_per_inference(4), once);
        assert!(!dma.has_sustained_traffic());
        assert_eq!(dma.regime_counts(), (0, 3, 0));
        assert_eq!(dma.resident_bytes(), once);
        // Spreading the same blocks over 3 cores restores residency.
        let dma = DmaEngine::new(
            &m,
            &AccelConfig::small().with_topology(CoreTopology::with_sdeb_cores(3)),
        );
        assert!(dma.blocks.iter().all(|b| b.residency == WeightResidency::Resident));
    }

    #[test]
    fn oversized_set_poisons_its_core_only() {
        // Shrink the buffer so tiny sets (33,216 words) exceed a slot.
        let m = model(2);
        let mut hw = AccelConfig::small();
        hw.weight_buffer_words = 40_000; // slot = 20,000 < 33,216
        let dma = DmaEngine::new(&m, &hw);
        assert!(dma.blocks.iter().all(|b| b.residency == WeightResidency::Streaming));
    }

    #[test]
    fn bandwidth_retarget_keeps_classification() {
        let m = model(1);
        let dma = DmaEngine::new(&m, &AccelConfig::small());
        let wide = dma.clone().with_bandwidth(usize::MAX);
        assert_eq!(wide.bytes_per_cycle, usize::MAX);
        assert_eq!(wide.blocks[0].residency, dma.blocks[0].residency);
        assert_eq!(wide.blocks[0].bytes, dma.blocks[0].bytes);
    }
}
