//! SPS Core (Fig. 1 left): Tile Engine convolutions, SEA encoding, the
//! Maxpooling Array (SMUs for spike input), the RPE convolution and the
//! residual Adder — producing the token tensor the SDEB Core consumes.

use anyhow::Result;

use crate::hw::{AccelConfig, EngineKind};
use crate::lif::LifParams;
use crate::quant::{QTensor, ACT_FRAC};
use crate::scratch::ExecScratch;
use crate::spike::{EncodedSpikes, TokenGrid};
use crate::units::{AdderModule, SpikeEncodingArray, SpikeMaxpoolUnit, TileEngine};
use crate::model::QuantizedModel;

use super::buffers::CoreBuffers;
use super::controller::DatapathMode;
use super::report::StatSink;

/// The SPS Core: owns the Tile Engine, per-stage SEAs, the Maxpooling
/// Array and the residual Adder, with persistent LIF state across
/// timesteps.
pub struct SpsCore {
    tile: TileEngine,
    seas: Vec<SpikeEncodingArray>,
    smu: SpikeMaxpoolUnit,
    adder: AdderModule,
    sides: [usize; 4],
    dims: [usize; 4],
}

impl SpsCore {
    /// Build the core's unit complement for one model's stage geometry.
    pub fn new(model: &QuantizedModel, params: LifParams) -> Self {
        let cfg = &model.cfg;
        let dims = cfg.stage_dims();
        let sides = cfg.stage_sides();
        let seas = (0..4)
            .map(|i| SpikeEncodingArray::new(dims[i], sides[i] * sides[i], params))
            .collect();
        Self {
            tile: TileEngine::new(),
            seas,
            smu: SpikeMaxpoolUnit::new(2, 2),
            adder: AdderModule::new(),
            sides,
            dims,
        }
    }

    /// Clear all per-stage LIF membrane state (between inferences).
    pub fn reset(&mut self) {
        for sea in &mut self.seas {
            sea.reset();
        }
    }

    /// Run one timestep of SPS on the quantized input image.
    ///
    /// `t` is the timestep index: it selects which slot of this core's ESS
    /// buffer ring (`t % depth`) receives the encoded tensors — the
    /// paper's ping/pong parity at depth 2. All intermediate tensors and
    /// arenas are recycled through `scratch` (the returned pair is taken
    /// from it too — the caller puts both back once consumed). Returns
    /// `u0` as `[D, L]` channel-major values plus the stage-3 output
    /// spikes (needed by the controller for sparsity reporting).
    #[allow(clippy::too_many_arguments)]
    pub fn run_timestep(
        &mut self,
        model: &QuantizedModel,
        image: &QTensor,
        cfg: &AccelConfig,
        mode: DatapathMode,
        t: usize,
        buffers: &mut CoreBuffers,
        sink: &mut StatSink,
        scratch: &mut ExecScratch,
    ) -> Result<(QTensor, EncodedSpikes)> {
        let mut cur = scratch.take_tensor_copy(image);
        let mut enc_prev: Option<EncodedSpikes> = None;

        for i in 0..4 {
            let spike_input = i > 0;
            let (y, conv_stats) =
                self.tile.conv2d_into(&cur, &model.sps_convs[i], cfg, spike_input, scratch);
            sink.add("sps.conv", conv_stats);

            let (mut enc, sea_stats) = self.seas[i].encode_into(&y.data, cfg, scratch);
            scratch.put_tensor(y);
            sink.add("sps.encode", sea_stats);

            let side = self.sides[i];
            if i == 1 || i == 3 {
                let grid = TokenGrid::new(side, side);
                let (pooled, mp_stats) = match mode {
                    // Encoded mode picks the maxpool engine from this
                    // stage's measured density: CSR address merging or
                    // word-gather pooling over the packed bitmap.
                    DatapathMode::Encoded => match cfg.engine.pick(enc.density()) {
                        EngineKind::Csr => self.smu.pool_into(&enc, grid, cfg, scratch),
                        EngineKind::Bitmap => {
                            let mut bm = scratch.take_bitmap(enc.channels, enc.tokens);
                            bm.fill_from_encoded(&enc);
                            let out = self.smu.pool_bitmap_into(&bm, grid, cfg, scratch);
                            scratch.put_bitmap(bm);
                            out
                        }
                    },
                    DatapathMode::Bitmap => {
                        self.smu.pool_dense_baseline_into(&enc, grid, cfg, scratch)
                    }
                };
                sink.add("sps.maxpool", mp_stats);
                scratch.put_enc(std::mem::replace(&mut enc, pooled));
            }
            // Post-pool sparsity: matches the golden executor and the JAX
            // model's aux records (Fig. 6 measures what later layers see).
            sink.sparsity(&format!("sps.stage{i}.spikes"), &enc);
            buffers.store_encoded(&enc, t)?;

            // Next conv consumes the spike map as a dense binary tensor;
            // scatter the encoded addresses straight into a zeroed buffer
            // instead of round-tripping through a bitmap object.
            let s = if i == 1 || i == 3 { side / 2 } else { side };
            debug_assert_eq!(enc.tokens, s * s);
            let mut next = scratch.take_tensor(&[self.dims[i], s, s], 0);
            for c in 0..enc.channels {
                let base = c * enc.tokens;
                for &a in enc.channel_addrs(c) {
                    next.data[base + a as usize] = 1; // as-ok: narrow-int index widening
                }
            }
            scratch.put_tensor(std::mem::replace(&mut cur, next));
            if let Some(prev) = enc_prev.replace(enc) {
                scratch.put_enc(prev);
            }
        }

        let enc3 = enc_prev.expect("four stages ran");
        let (mut rpe, rpe_stats) =
            self.tile.conv2d_into(&cur, &model.sps_convs[4], cfg, true, scratch);
        scratch.put_tensor(cur);
        sink.add("sps.conv", rpe_stats);

        // Residual: u0 = RPE(s4) + s4 in the value domain ([D, L] layout).
        // The RPE output [D, s, s] is reshaped to [D, L] in place.
        let d = model.cfg.embed_dim;
        let l = model.cfg.num_tokens();
        debug_assert_eq!(rpe.data.len(), d * l);
        rpe.shape.clear();
        rpe.shape.extend_from_slice(&[d, l]);
        rpe.frac = ACT_FRAC;
        let (u0, add_stats) = self.adder.add_spikes_into(&rpe, &enc3, cfg, scratch);
        scratch.put_tensor(rpe);
        sink.add("sps.residual", add_stats);

        Ok((u0, enc3))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::buffers::BufferSet;
    use crate::model::SdtModelConfig;
    use crate::quant::{QFormat, MEM_BITS};
    use crate::util::Prng;

    fn setup() -> (QuantizedModel, QTensor) {
        let cfg = SdtModelConfig::tiny();
        let model = QuantizedModel::random(&cfg, 5);
        let mut rng = Prng::new(1);
        let img: Vec<f32> = (0..3 * 32 * 32).map(|_| rng.next_f32_signed()).collect();
        let q = QTensor::from_f32(&img, &[3, 32, 32], QFormat::new(MEM_BITS, ACT_FRAC));
        (model, q)
    }

    #[test]
    fn sps_produces_token_tensor() {
        let (model, img) = setup();
        let hw = AccelConfig::small();
        let mut core = SpsCore::new(&model, model.cfg.lif_params());
        let mut buffers = BufferSet::new(&hw);
        let mut sink = StatSink::new();
        let mut scratch = ExecScratch::new();
        let (u0, enc3) = core
            .run_timestep(
                &model,
                &img,
                &hw,
                DatapathMode::Encoded,
                0,
                &mut buffers.sps,
                &mut sink,
                &mut scratch,
            )
            .unwrap();
        assert_eq!(u0.shape, vec![64, 64]);
        assert_eq!(enc3.channels, 64);
        assert_eq!(enc3.tokens, 64);
        assert!(sink.phases.get("sps.conv").cycles > 0);
        assert!(sink.phases.get("sps.encode").adds > 0);
    }

    #[test]
    fn bitmap_mode_same_values_more_maxpool_cycles() {
        let (model, img) = setup();
        let hw = AccelConfig::small();
        let mut b1 = BufferSet::new(&hw);
        let mut b2 = BufferSet::new(&hw);
        let mut s1 = StatSink::new();
        let mut s2 = StatSink::new();
        let mut sc1 = ExecScratch::new();
        let mut sc2 = ExecScratch::new();
        let mut c1 = SpsCore::new(&model, model.cfg.lif_params());
        let mut c2 = SpsCore::new(&model, model.cfg.lif_params());
        let (u1, _) = c1
            .run_timestep(&model, &img, &hw, DatapathMode::Encoded, 0, &mut b1.sps, &mut s1, &mut sc1)
            .unwrap();
        let (u2, _) = c2
            .run_timestep(&model, &img, &hw, DatapathMode::Bitmap, 0, &mut b2.sps, &mut s2, &mut sc2)
            .unwrap();
        assert_eq!(u1, u2, "datapath modes must agree on values");
        assert!(s2.phases.get("sps.maxpool").cycles >= s1.phases.get("sps.maxpool").cycles);
    }

    #[test]
    fn maxpool_engines_agree_on_values() {
        use crate::hw::EngineSelect;
        let (model, img) = setup();
        let run = |engine: EngineSelect| {
            let mut hw = AccelConfig::small();
            hw.engine = engine;
            let mut core = SpsCore::new(&model, model.cfg.lif_params());
            let mut buffers = BufferSet::new(&hw);
            let mut sink = StatSink::new();
            let mut scratch = ExecScratch::new();
            core.run_timestep(
                &model,
                &img,
                &hw,
                DatapathMode::Encoded,
                0,
                &mut buffers.sps,
                &mut sink,
                &mut scratch,
            )
            .unwrap()
        };
        let (u_csr, e_csr) = run(EngineSelect::Csr);
        let (u_bm, e_bm) = run(EngineSelect::Bitmap);
        let (u_ad, e_ad) = run(EngineSelect::adaptive());
        assert_eq!(u_csr, u_bm, "bitmap maxpool must be bit-identical");
        assert_eq!(e_csr, e_bm);
        assert_eq!(u_csr, u_ad, "adaptive maxpool must be bit-identical");
        assert_eq!(e_csr, e_ad);
    }

    #[test]
    fn repeated_timesteps_reuse_scratch_after_warmup() {
        let (model, img) = setup();
        let hw = AccelConfig::small();
        let mut core = SpsCore::new(&model, model.cfg.lif_params());
        let mut buffers = BufferSet::new(&hw);
        let mut sink = StatSink::new();
        let mut scratch = ExecScratch::new();
        let run = |core: &mut SpsCore,
                   buffers: &mut BufferSet,
                   sink: &mut StatSink,
                   scratch: &mut ExecScratch| {
            let (u0, enc3) = core
                .run_timestep(
                    &model,
                    &img,
                    &hw,
                    DatapathMode::Encoded,
                    0,
                    &mut buffers.sps,
                    sink,
                    scratch,
                )
                .unwrap();
            scratch.put_tensor(u0);
            scratch.put_enc(enc3);
        };
        run(&mut core, &mut buffers, &mut sink, &mut scratch);
        let warm = scratch.stats();
        for _ in 0..3 {
            run(&mut core, &mut buffers, &mut sink, &mut scratch);
        }
        assert_eq!(scratch.stats().misses, warm.misses, "warm SPS timesteps must not allocate");
        assert!(scratch.stats().hits > warm.hits);
    }
}
