//! SPS Core (Fig. 1 left): Tile Engine convolutions, SEA encoding, the
//! Maxpooling Array (SMUs for spike input), the RPE convolution and the
//! residual Adder — producing the token tensor the SDEB Core consumes.

use anyhow::Result;

use crate::hw::AccelConfig;
use crate::lif::LifParams;
use crate::quant::{QTensor, ACT_FRAC};
use crate::spike::{EncodedSpikes, TokenGrid};
use crate::units::{AdderModule, SpikeEncodingArray, SpikeMaxpoolUnit, TileEngine};
use crate::model::QuantizedModel;

use super::buffers::CoreBuffers;
use super::controller::DatapathMode;
use super::report::StatSink;

/// The SPS Core: owns the Tile Engine, per-stage SEAs, the Maxpooling
/// Array and the residual Adder, with persistent LIF state across
/// timesteps.
pub struct SpsCore {
    tile: TileEngine,
    seas: Vec<SpikeEncodingArray>,
    smu: SpikeMaxpoolUnit,
    adder: AdderModule,
    sides: [usize; 4],
    dims: [usize; 4],
}

impl SpsCore {
    /// Build the core's unit complement for one model's stage geometry.
    pub fn new(model: &QuantizedModel, params: LifParams) -> Self {
        let cfg = &model.cfg;
        let dims = cfg.stage_dims();
        let sides = cfg.stage_sides();
        let seas = (0..4)
            .map(|i| SpikeEncodingArray::new(dims[i], sides[i] * sides[i], params))
            .collect();
        Self {
            tile: TileEngine::new(),
            seas,
            smu: SpikeMaxpoolUnit::new(2, 2),
            adder: AdderModule::new(),
            sides,
            dims,
        }
    }

    /// Clear all per-stage LIF membrane state (between inferences).
    pub fn reset(&mut self) {
        for sea in &mut self.seas {
            sea.reset();
        }
    }

    /// Run one timestep of SPS on the quantized input image.
    ///
    /// `pong` is the timestep parity selecting which ESS half of `buffers`
    /// (this core's double-buffered pair) receives the encoded tensors.
    /// Returns `u0` as `[D, L]` channel-major values plus the stage-3
    /// output spikes (needed by the controller for sparsity reporting).
    pub fn run_timestep(
        &mut self,
        model: &QuantizedModel,
        image: &QTensor,
        cfg: &AccelConfig,
        mode: DatapathMode,
        pong: bool,
        buffers: &mut CoreBuffers,
        sink: &mut StatSink,
    ) -> Result<(QTensor, EncodedSpikes)> {
        let mut cur = image.clone();
        let mut enc_prev: Option<EncodedSpikes> = None;

        for i in 0..4 {
            let spike_input = i > 0;
            let (y, conv_stats) = self.tile.conv2d(&cur, &model.sps_convs[i], cfg, spike_input);
            sink.add("sps.conv", conv_stats);

            let (mut enc, sea_stats) = self.seas[i].encode(&y.data, cfg);
            sink.add("sps.encode", sea_stats);

            let side = self.sides[i];
            if i == 1 || i == 3 {
                let grid = TokenGrid::new(side, side);
                let (pooled, mp_stats) = match mode {
                    DatapathMode::Encoded => self.smu.pool(&enc, grid, cfg),
                    DatapathMode::Bitmap => self.smu.pool_dense_baseline(&enc, grid, cfg),
                };
                sink.add("sps.maxpool", mp_stats);
                enc = pooled;
            }
            // Post-pool sparsity: matches the golden executor and the JAX
            // model's aux records (Fig. 6 measures what later layers see).
            sink.sparsity(&format!("sps.stage{i}.spikes"), &enc);
            buffers.store_encoded(&enc, pong)?;

            // Next conv consumes the spike map as a dense binary tensor;
            // scatter the encoded addresses straight into a zeroed buffer
            // instead of round-tripping through a bitmap object.
            let s = if i == 1 || i == 3 { side / 2 } else { side };
            debug_assert_eq!(enc.tokens, s * s);
            let mut data = vec![0i32; self.dims[i] * enc.tokens];
            for c in 0..enc.channels {
                let base = c * enc.tokens;
                for &a in enc.channel_addrs(c) {
                    data[base + a as usize] = 1;
                }
            }
            cur = QTensor { shape: vec![self.dims[i], s, s], frac: 0, data };
            enc_prev = Some(enc);
        }

        let enc3 = enc_prev.expect("four stages ran");
        let (rpe, rpe_stats) = self.tile.conv2d(&cur, &model.sps_convs[4], cfg, true);
        sink.add("sps.conv", rpe_stats);

        // Residual: u0 = RPE(s4) + s4 in the value domain ([D, L] layout).
        let d = model.cfg.embed_dim;
        let l = model.cfg.num_tokens();
        let rpe_cl = QTensor { shape: vec![d, l], frac: ACT_FRAC, data: rpe.data.clone() };
        let (u0, add_stats) = self.adder.add_spikes(&rpe_cl, &enc3, cfg);
        sink.add("sps.residual", add_stats);

        Ok((u0, enc3))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::buffers::BufferSet;
    use crate::model::SdtModelConfig;
    use crate::quant::{QFormat, MEM_BITS};
    use crate::util::Prng;

    fn setup() -> (QuantizedModel, QTensor) {
        let cfg = SdtModelConfig::tiny();
        let model = QuantizedModel::random(&cfg, 5);
        let mut rng = Prng::new(1);
        let img: Vec<f32> = (0..3 * 32 * 32).map(|_| rng.next_f32_signed()).collect();
        let q = QTensor::from_f32(&img, &[3, 32, 32], QFormat::new(MEM_BITS, ACT_FRAC));
        (model, q)
    }

    #[test]
    fn sps_produces_token_tensor() {
        let (model, img) = setup();
        let hw = AccelConfig::small();
        let mut core = SpsCore::new(&model, model.cfg.lif_params());
        let mut buffers = BufferSet::new(&hw);
        let mut sink = StatSink::new();
        let (u0, enc3) = core
            .run_timestep(&model, &img, &hw, DatapathMode::Encoded, false, &mut buffers.sps, &mut sink)
            .unwrap();
        assert_eq!(u0.shape, vec![64, 64]);
        assert_eq!(enc3.channels, 64);
        assert_eq!(enc3.tokens, 64);
        assert!(sink.phases.get("sps.conv").cycles > 0);
        assert!(sink.phases.get("sps.encode").adds > 0);
    }

    #[test]
    fn bitmap_mode_same_values_more_maxpool_cycles() {
        let (model, img) = setup();
        let hw = AccelConfig::small();
        let mut b1 = BufferSet::new(&hw);
        let mut b2 = BufferSet::new(&hw);
        let mut s1 = StatSink::new();
        let mut s2 = StatSink::new();
        let mut c1 = SpsCore::new(&model, model.cfg.lif_params());
        let mut c2 = SpsCore::new(&model, model.cfg.lif_params());
        let (u1, _) = c1
            .run_timestep(&model, &img, &hw, DatapathMode::Encoded, false, &mut b1.sps, &mut s1)
            .unwrap();
        let (u2, _) = c2
            .run_timestep(&model, &img, &hw, DatapathMode::Bitmap, false, &mut b2.sps, &mut s2)
            .unwrap();
        assert_eq!(u1, u2, "datapath modes must agree on values");
        assert!(s2.phases.get("sps.maxpool").cycles >= s1.phases.get("sps.maxpool").cycles);
    }
}
