//! The overlapped multi-core pipeline executor (Fig. 1's throughput
//! trick, executed rather than estimated, generalized over the instance's
//! [`CoreTopology`]).
//!
//! The real accelerator buffers between the SPS Core and the SDEB Cores
//! through an ESS ring: while the SDEB stage consumes timestep `t` out of
//! one ring slot, the SPS stage already produces timestep `t+1` into the
//! next (the paper's instance is a depth-2 ping/pong pair). This module
//! *runs* that schedule — the SPS stage as a long-lived task on the
//! accelerator's persistent [`WorkerPool`] (no per-inference thread
//! spawn), the SDEB + head stage on the calling thread, a bounded channel
//! of capacity `depth - 1` standing in for the ring handoff — and records
//! per-timestep stage cycles so the executed schedule
//! ([`PipelineExecution`]) can be reconciled against the analytic
//! [`PipelineEstimate`](super::pipeline::PipelineEstimate), which is now a
//! cross-check rather than the only source of truth.
//!
//! Within the SDEB stage, the SDSA pass maps attention heads across the
//! topology's SDEB-core comparator arrays under the
//! [`Mapper`](super::mapper::Mapper)'s policy instead of walking all
//! channels on one array — the FireFly-T-style dual-engine overlap plus
//! Bishop-style heterogeneous-core scheduling named in the ROADMAP.
//!
//! Steady-state memory model (DESIGN.md): each stage recycles its frame
//! storage through its own [`ExecScratch`] pool, and the `[L, D]` token
//! tensors handed producer→consumer circulate through a small ring — the
//! consumer returns each drained tensor over a second channel, the
//! producer blocks on that return once its `depth` pre-taken ring slots
//! are in flight (host run-ahead bounded at the modelled buffer-ring
//! depth), and everything drains back into the SPS pool at the end of the
//! run. After warm-up an inference performs no thread spawns and no
//! arena/tensor allocations.
//!
//! All cycle numbers come from [`UnitStats`](crate::hw::UnitStats)
//! accounting, never from host wall clocks, so overlapped runs stay
//! bit-deterministic: same image, same model, same report.

// Routed through the sync shim: `mpsc` stays `std` under every cfg (loom
// has no channel model); the handoff discipline this channel implements is
// loom-checked via `buffers::SlotRing` instead.
use crate::util::sync::mpsc;

use anyhow::{anyhow, Result};

use crate::hw::dram::{BusTimeline, DramBus, MemoryReport};
use crate::hw::{AccelConfig, CoreTopology};
use crate::model::QuantizedModel;
use crate::quant::{QTensor, ACT_FRAC};
use crate::scratch::ExecScratch;
use crate::units::SpikeEncodingArray;

use super::buffers::BufferSet;
use super::controller::DatapathMode;
use super::dma::{DmaEngine, WeightResidency};
use super::mapper::Mapper;
use super::report::StatSink;
use super::sdeb_core::SdebCore;
use super::sps_core::SpsCore;
use super::workers::WorkerPool;

/// The executed overlap schedule of one inference: per-timestep stage
/// cycles plus the resulting finish time under the topology's buffer
/// ring and the shared external-memory bus.
///
/// The schedule recurrence models a depth-`N` ring pipeline with `P` SPS
/// cores: the SPS stage of timestep `i` may start once the same core's
/// previous timestep (`i - P`) is done *and* the ESS ring slot it writes
/// has been drained (the SDEB stage of timestep `i - N`); the SDEB stage
/// of timestep `i` may start once its input is produced and its own
/// previous timestep is done (the SDEB side is sequential in time — LIF
/// state carries across timesteps). External input precedes the first SPS
/// timestep; output transfer follows the last SDEB timestep. The paper's
/// instance is `N = 2`, `P = 1` — the classic ping/pong recurrence.
///
/// **Memory lane** (when built through [`Self::with_memory`]): each
/// encoder block's segment of the SDEB stage additionally waits for its
/// weight working set, streamed over the shared
/// [`DramBus`](crate::hw::DramBus) by the
/// [`DmaEngine`](super::DmaEngine)'s plan — a segment's finish time is
/// `max(compute-ready + compute, weights-resident, prefetch-issued)`,
/// the excess is recorded as stall, and every transfer queues FIFO
/// behind the input load and earlier weight streams. Resident blocks
/// stream once; Thrash blocks stream once at first use (the block-outer
/// loop order keeps a fitting block's set live across all timesteps);
/// Streaming blocks re-stream every use, with the head of the transfer
/// (up to one slot) prefetched into the slot freed `slots` uses ago
/// while the tail waits for the previous use's slot. At unlimited bandwidth
/// (`dram_bytes_per_cycle == usize::MAX`) every transfer completes
/// instantly and the schedule is bit-identical to the memory-blind
/// recurrence — the invariance the memory tests pin down.
#[derive(Clone, Debug)]
pub struct PipelineExecution {
    /// Number of timesteps executed.
    pub timesteps: usize,
    /// Buffer-ring depth of the modelled schedule (2 = ping/pong).
    pub depth: usize,
    /// SPS cores round-robining timesteps in the modelled schedule.
    pub sps_cores: usize,
    /// Cycles of the external input transfer (before the first timestep).
    pub io_input_cycles: u64,
    /// Cycles of the external output transfer (after the last timestep).
    pub io_output_cycles: u64,
    /// Per-timestep SPS-stage cycles (`sps.*` phases).
    pub sps_per_timestep: Vec<u64>,
    /// Per-timestep SDEB-stage cycles (`sdeb.*` + `head.*` phases).
    pub sdeb_per_timestep: Vec<u64>,
    /// Per-timestep SDEB-stage segments: one entry per encoder block (in
    /// block order) plus a final head-readout segment. Sums to
    /// [`Self::sdeb_per_timestep`]. Aggregate-trace constructors
    /// ([`Self::new`], [`Self::with_topology`]) record one opaque segment
    /// per timestep.
    pub sdeb_segments: Vec<Vec<u64>>,
    /// Finish time of the overlapped schedule, in cycles.
    pub executed_cycles: u64,
    /// What the same work costs charged serially (sum of all stages).
    pub serialized_cycles: u64,
    /// Cycles the schedule spent with compute ready but weights not yet
    /// resident (0 without a memory plan or at unlimited bandwidth).
    pub stall_cycles: u64,
    /// Per-client external-memory accounting of the run (`None` for
    /// schedules built without a memory plan).
    pub memory: Option<MemoryReport>,
}

impl PipelineExecution {
    /// Build the execution record under the paper's depth-2 / one-SPS-core
    /// recurrence (see [`Self::with_topology`] for the general form).
    pub fn new(
        io_input_cycles: u64,
        io_output_cycles: u64,
        sps_per_timestep: Vec<u64>,
        sdeb_per_timestep: Vec<u64>,
    ) -> Self {
        let segments = sdeb_per_timestep.iter().map(|&c| vec![c]).collect();
        Self::with_shape(io_input_cycles, io_output_cycles, sps_per_timestep, segments, 2, 1, None)
    }

    /// Build the execution record under `topology`'s ring depth and SPS
    /// core count (no memory lane — the PR 4 schedule).
    pub fn with_topology(
        io_input_cycles: u64,
        io_output_cycles: u64,
        sps_per_timestep: Vec<u64>,
        sdeb_per_timestep: Vec<u64>,
        topology: &CoreTopology,
    ) -> Self {
        let segments = sdeb_per_timestep.iter().map(|&c| vec![c]).collect();
        Self::with_shape(
            io_input_cycles,
            io_output_cycles,
            sps_per_timestep,
            segments,
            topology.pipeline_depth,
            topology.sps_cores,
            None,
        )
    }

    /// Build the execution record with the memory lane active:
    /// `sdeb_segments[t]` holds one compute-cycle entry per encoder block
    /// (in block order) plus a final head-readout segment, and `dma` is
    /// the weight-streaming plan whose transfers gate each block segment
    /// (see the type docs).
    pub fn with_memory(
        io_input_cycles: u64,
        io_output_cycles: u64,
        sps_per_timestep: Vec<u64>,
        sdeb_segments: Vec<Vec<u64>>,
        topology: &CoreTopology,
        dma: Option<&DmaEngine>,
    ) -> Self {
        Self::with_shape(
            io_input_cycles,
            io_output_cycles,
            sps_per_timestep,
            sdeb_segments,
            topology.pipeline_depth,
            topology.sps_cores,
            dma,
        )
    }

    /// The generalized schedule recurrence (see the type docs).
    fn with_shape(
        io_input_cycles: u64,
        io_output_cycles: u64,
        sps_per_timestep: Vec<u64>,
        sdeb_segments: Vec<Vec<u64>>,
        depth: usize,
        sps_cores: usize,
        dma: Option<&DmaEngine>,
    ) -> Self {
        assert_eq!(sps_per_timestep.len(), sdeb_segments.len(), "stage trace length mismatch");
        let depth = depth.max(2);
        let sps_cores = sps_cores.max(1);
        let t = sps_per_timestep.len();
        let nblocks = dma.map(|d| d.blocks.len()).unwrap_or(0);
        if let Some(d) = dma {
            for seg in &sdeb_segments {
                assert_eq!(
                    seg.len(),
                    d.blocks.len() + 1,
                    "memory-lane schedules need one segment per block plus the head"
                );
            }
        }

        // Weight-streaming machinery: the shared bus (input first, then
        // weight transfers in consumption order) and the per-core /
        // per-block state the slot discipline needs.
        let mut timeline = dma.map(|d| {
            let mut tl = BusTimeline::new(DramBus::new(d.bytes_per_cycle));
            tl.seed("input", d.input_bytes, io_input_cycles);
            tl
        });
        // Completion times of recent uses, per SDEB core (for slot
        // release; only the last `slots` ever matter, so the history is
        // capped there) and per block (streamed-once tracking). Client
        // names are built once, not per transfer.
        let cores = dma.map(|d| d.blocks.iter().map(|b| b.core).max().unwrap_or(0) + 1).unwrap_or(1);
        let history = dma.map(|d| d.slots).unwrap_or(2).max(1);
        let mut core_use_done: Vec<Vec<u64>> = vec![Vec::new(); cores];
        let mut first_use_streamed = vec![false; nblocks];
        let client_names: Vec<String> =
            (0..nblocks).map(|b| format!("weights.block{b}")).collect();
        let mut stall_cycles = 0u64;

        let mut sps_done = vec![0u64; t];
        let mut sdeb_done = vec![0u64; t];
        let mut sdeb_per_timestep = vec![0u64; t];
        for i in 0..t {
            sdeb_per_timestep[i] = sdeb_segments[i].iter().sum();
            // Ring: the slot written at timestep i was last written at
            // i - depth and must have been consumed by SDEB(i - depth).
            let buffer_free = if i >= depth { sdeb_done[i - depth] } else { 0 };
            // Timesteps round-robin over the SPS cores; a core's next
            // timestep waits for its own previous one (i - sps_cores).
            let prev_sps =
                if i >= sps_cores { sps_done[i - sps_cores] } else { io_input_cycles };
            sps_done[i] = prev_sps.max(buffer_free) + sps_per_timestep[i];

            // SDEB side: the block segments run back to back on the
            // consumer chain, each gated on its weights when streaming.
            let prev_sdeb = if i > 0 { sdeb_done[i - 1] } else { 0 };
            let mut pos = sps_done[i].max(prev_sdeb);
            match (dma, timeline.as_mut()) {
                (Some(d), Some(tl)) => {
                    for (b, plan) in d.blocks.iter().enumerate() {
                        let compute = sdeb_segments[i][b];
                        let needs_stream =
                            plan.streams_every_use() || !first_use_streamed[b];
                        let done = if needs_stream {
                            first_use_streamed[b] = true;
                            // Slot release: when may the transfer start
                            // overwriting on-chip state? (module docs of
                            // `accel::dma` — the stall formula.)
                            let recent = &core_use_done[plan.core];
                            let prev_use = recent.last().copied().unwrap_or(0);
                            let slot_free = if recent.len() >= d.slots {
                                recent[recent.len() - d.slots]
                            } else {
                                0
                            };
                            let client = &client_names[b];
                            let tdone = match plan.residency {
                                // Fitting sets stream once, released at
                                // their slot's ring position (0 for a
                                // Resident core that never rotates).
                                WeightResidency::Resident => {
                                    tl.request(client, plan.bytes, 0).1
                                }
                                WeightResidency::Thrash => {
                                    tl.request(client, plan.bytes, slot_free).1
                                }
                                // Oversized set: head/tail prefetch split.
                                // Up to one slot of the stream moves into
                                // the ping/pong slot freed `slots` uses
                                // back, overlapping the previous use; the
                                // tail waits for that use to finish. The
                                // cycle split keeps head + tail at exactly
                                // transfer_cycles(bytes), so the split
                                // never costs more than the unsplit (PR 5)
                                // stream at any bandwidth.
                                WeightResidency::Streaming => {
                                    let head_bytes = plan.bytes.min(d.slot_bytes);
                                    let tail_bytes = plan.bytes - head_bytes;
                                    if d.slots >= 2 && head_bytes > 0 && tail_bytes > 0 {
                                        let bus = DramBus::new(d.bytes_per_cycle);
                                        let tail_cycles = bus.transfer_cycles(tail_bytes);
                                        let head_cycles =
                                            bus.transfer_cycles(plan.bytes) - tail_cycles;
                                        tl.request_with_cycles(
                                            client, head_bytes, head_cycles, slot_free,
                                        );
                                        tl.request_with_cycles(
                                            client, tail_bytes, tail_cycles, prev_use,
                                        )
                                        .1
                                    } else {
                                        tl.request(client, plan.bytes, prev_use).1
                                    }
                                }
                            };
                            let done = (pos + compute).max(tdone);
                            let stall = done - (pos + compute);
                            if stall > 0 {
                                tl.add_stall(client, stall);
                                stall_cycles += stall;
                            }
                            done
                        } else {
                            pos + compute
                        };
                        let recent = &mut core_use_done[plan.core];
                        if recent.len() == history {
                            recent.remove(0);
                        }
                        recent.push(done);
                        pos = done;
                    }
                    // Head readout: weightless final segment.
                    pos += sdeb_segments[i][nblocks];
                }
                _ => {
                    pos += sdeb_per_timestep[i];
                }
            }
            sdeb_done[i] = pos;
        }
        let last_done = sdeb_done.last().copied().unwrap_or(io_input_cycles);
        let executed_cycles = last_done + io_output_cycles;
        let memory = match (dma, timeline) {
            (Some(d), Some(mut tl)) => {
                tl.book("output", d.output_bytes, io_output_cycles);
                let mut m = tl.into_report();
                let (resident, thrash, streaming) = d.regime_counts();
                m.resident_blocks = resident;
                m.thrash_blocks = thrash;
                m.streaming_blocks = streaming;
                m.resident_bytes = d.resident_bytes();
                Some(m)
            }
            _ => None,
        };
        let serialized_cycles = io_input_cycles
            + io_output_cycles
            + sps_per_timestep.iter().sum::<u64>()
            + sdeb_per_timestep.iter().sum::<u64>();
        Self {
            timesteps: t,
            depth,
            sps_cores,
            io_input_cycles,
            io_output_cycles,
            sps_per_timestep,
            sdeb_per_timestep,
            sdeb_segments,
            executed_cycles,
            serialized_cycles,
            stall_cycles,
            memory,
        }
    }

    /// Total SPS-stage cycles across timesteps.
    pub fn sps_cycles(&self) -> u64 {
        self.sps_per_timestep.iter().sum()
    }

    /// Total SDEB-stage cycles across timesteps.
    pub fn sdeb_cycles(&self) -> u64 {
        self.sdeb_per_timestep.iter().sum()
    }

    /// The slower stage's total — the steady-state lower bound on the
    /// executed schedule.
    pub fn bottleneck_cycles(&self) -> u64 {
        self.sps_cycles().max(self.sdeb_cycles())
    }

    /// Which stage bounds the executed schedule.
    pub fn bottleneck(&self) -> &'static str {
        if self.sdeb_cycles() >= self.sps_cycles() {
            "sdeb"
        } else {
            "sps"
        }
    }

    /// Cycles the executed schedule spends beyond the bottleneck stage's
    /// own total (pipeline fill + drain + I/O).
    pub fn fill_cycles(&self) -> u64 {
        self.executed_cycles.saturating_sub(self.bottleneck_cycles())
    }

    /// Speedup of the executed schedule over serial charging.
    pub fn speedup(&self) -> f64 {
        if self.executed_cycles == 0 {
            return 1.0;
        }
        self.serialized_cycles as f64 / self.executed_cycles as f64 // as-ok: reporting ratio, not datapath state
    }

    /// Modelled wall-clock seconds of the executed schedule at `cfg`'s
    /// frequency.
    pub fn wall_seconds(&self, cfg: &AccelConfig) -> f64 {
        cfg.seconds(self.executed_cycles)
    }

    /// Fraction of the executed schedule spent stalled on weight
    /// streaming (0 without a memory plan) — the roofline bench's y-axis.
    pub fn stall_fraction(&self) -> f64 {
        if self.executed_cycles == 0 {
            0.0
        } else {
            self.stall_cycles as f64 / self.executed_cycles as f64 // as-ok: reporting ratio, not datapath state
        }
    }

    /// The fill-latency bound used to reconcile executed cycles against
    /// the analytic estimator: both lie in `[bottleneck, serialized]`, and
    /// they may differ by at most the I/O transfers plus one worst-case
    /// timestep of each stage entering/draining the pipe — plus whatever
    /// the memory lane stalled, which the (memory-blind) estimator cannot
    /// see.
    pub fn fill_latency_bound(&self) -> u64 {
        self.io_input_cycles
            + self.io_output_cycles
            + self.sps_per_timestep.iter().copied().max().unwrap_or(0)
            + self.sdeb_per_timestep.iter().copied().max().unwrap_or(0)
            + self.stall_cycles
    }

    /// Does the executed schedule agree with the analytic re-timer within
    /// the fill-latency bound? The estimator amortises fill as an average
    /// timestep while the executed schedule pays the actual first/last
    /// timesteps, so exact equality is not expected — but a disagreement
    /// beyond one worst-case timestep of each stage plus I/O means one of
    /// the two models is wrong.
    pub fn reconciles_with(&self, est: &super::pipeline::PipelineEstimate) -> bool {
        self.executed_cycles.abs_diff(est.pipelined_cycles) <= self.fill_latency_bound()
    }
}

/// Everything the overlapped run hands back to the controller.
pub(crate) struct OverlapOutcome {
    /// Merged stage sinks (SPS phases first, then SDEB/head), ready for
    /// the controller to wrap with the I/O phases.
    pub sink: StatSink,
    /// Per-output-channel pooled spike counts from the head LIF.
    pub head_counts: Vec<u64>,
    /// Per-timestep SPS-stage cycles.
    pub sps_per_timestep: Vec<u64>,
    /// Per-timestep SDEB-stage segments: one entry per encoder block plus
    /// a final head-readout segment (what the memory lane gates on).
    pub sdeb_segments: Vec<Vec<u64>>,
}

/// Transpose the SPS core's `[D, L]` channel-major output into the
/// `[L, D]` token-major residual stream the SDEB cores consume, writing
/// into a recycled tensor (every element is overwritten).
pub(crate) fn u0_to_token_major_into(u0_cl: &QTensor, l: usize, d: usize, out: &mut QTensor) {
    out.shape.clear();
    out.shape.extend_from_slice(&[l, d]);
    out.frac = ACT_FRAC;
    // No clear(): a same-sized recycled buffer skips the resize memset —
    // the transpose below overwrites every element.
    out.data.resize(l * d, 0);
    for c in 0..d {
        for tok in 0..l {
            out.data[tok * d + c] = u0_cl.data[c * l + tok];
        }
    }
}

/// Head LIF + pooled spike counting on the final residual stream of one
/// timestep (shared by the serial, overlapped and batched paths).
#[allow(clippy::too_many_arguments)]
pub(crate) fn head_readout(
    sea_head: &mut SpikeEncodingArray,
    u: &QTensor,
    l: usize,
    d: usize,
    hw: &AccelConfig,
    sink: &mut StatSink,
    head_counts: &mut [u64],
    scratch: &mut ExecScratch,
) {
    let mut u_cl = scratch.take_i32(d * l);
    for tok in 0..l {
        for c in 0..d {
            u_cl[c * l + tok] = u.data[tok * d + c];
        }
    }
    let (s_out, st) = sea_head.encode_into(&u_cl, hw, scratch);
    sink.add("head.encode", st);
    sink.sparsity("head.in.spikes", &s_out);
    for (c, count) in head_counts.iter_mut().enumerate() {
        *count += s_out.channel_len(c) as u64; // as-ok: widening for 64-bit stat/cycle math
    }
    scratch.put_enc(s_out);
    scratch.put_i32(u_cl);
}

/// The producer task's final state: its stage sink and trace, plus the
/// ring tensors and return-channel receiver handed back for draining.
type ProducerOut = (Result<(StatSink, Vec<u64>)>, Vec<QTensor>, mpsc::Receiver<QTensor>);

/// Run all timesteps with the SPS stage of timestep `t+1` overlapping the
/// SDEB stage of timestep `t`.
///
/// The SPS producer runs as one long-lived task on the persistent worker
/// `pool` against its slots of the ESS buffer ring and its own scratch
/// pool; the SDEB consumer runs on the calling thread against the
/// per-core SDEB rings, mapping each block's SDSA heads across the
/// topology's comparator arrays per `mapper` (non-first cores also
/// dispatched on `pool`). A bounded channel of capacity `depth - 1`
/// enforces the ring depth; drained token tensors flow back to the
/// producer over a return channel (see the module docs). Stage sinks are
/// merged in a fixed order, so the result is deterministic regardless of
/// thread interleaving.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_overlapped(
    model: &QuantizedModel,
    hw: &AccelConfig,
    mode: DatapathMode,
    mapper: Mapper,
    pool: &WorkerPool,
    sps: &mut SpsCore,
    sdebs: &mut [SdebCore],
    sea_head: &mut SpikeEncodingArray,
    buffers: &mut BufferSet,
    scratch_sps: &mut ExecScratch,
    scratch_sdeb: &mut ExecScratch,
    qimg: &QTensor,
) -> Result<OverlapOutcome> {
    let cfg = &model.cfg;
    let (l, d) = (cfg.num_tokens(), cfg.embed_dim);
    let timesteps = cfg.timesteps;
    let depth = hw.topology.pipeline_depth.max(2);

    let BufferSet { sps: sps_buf, sdeb: sdeb_buf, .. } = buffers;
    let sdeb_rings = sdeb_buf.len().max(1);
    let (tx, rx) = mpsc::sync_channel::<QTensor>(depth - 1);
    let (ret_tx, ret_rx) = mpsc::channel::<QTensor>();

    // Pre-take the ring: exactly `depth` slots per run keeps the take/put
    // counts deterministic (anything beyond the ring depth waits on the
    // return channel, matching the modelled buffer-ring bound).
    let ring: Vec<QTensor> =
        (0..depth).map(|_| scratch_sps.take_tensor(&[l, d], ACT_FRAC)).collect();

    let mut producer_out: Option<ProducerOut> = None;

    let consumer_res = pool.scope(|s| {
        let slot = &mut producer_out;
        // Reborrow for the producer task: the original `scratch_sps`
        // reference is needed again after the scope for the ring drain.
        let scratch_sps: &mut ExecScratch = &mut *scratch_sps;
        s.spawn(move || {
            let mut ring = ring;
            let ret_rx = ret_rx;
            // Panic parity with the pre-pool `thread::scope` producer: a
            // panicking SPS stage surfaces as an inference error on the
            // calling thread, not a poisoned worker pool.
            let task = || -> Result<(StatSink, Vec<u64>)> {
                let mut sink = StatSink::new();
                let mut per_t = Vec::with_capacity(timesteps);
                for t in 0..timesteps {
                    let before = sink.phases.total().cycles;
                    let (u0_cl, enc3) = sps.run_timestep(
                        model,
                        qimg,
                        hw,
                        mode,
                        t,
                        sps_buf,
                        &mut sink,
                        scratch_sps,
                    )?;
                    per_t.push(sink.phases.total().cycles - before);
                    let mut out = match ring.pop() {
                        Some(buf) => buf,
                        None => match ret_rx.recv() {
                            Ok(buf) => buf,
                            Err(_) => {
                                scratch_sps.put_tensor(u0_cl);
                                scratch_sps.put_enc(enc3);
                                break; // consumer bailed; its error surfaces below
                            }
                        },
                    };
                    u0_to_token_major_into(&u0_cl, l, d, &mut out);
                    scratch_sps.put_tensor(u0_cl);
                    scratch_sps.put_enc(enc3);
                    if tx.send(out).is_err() {
                        break; // consumer bailed; its error surfaces below
                    }
                }
                Ok((sink, per_t))
            };
            let res = match std::panic::catch_unwind(std::panic::AssertUnwindSafe(task)) {
                Ok(res) => res,
                Err(_) => Err(anyhow!("SPS pipeline stage panicked")),
            };
            *slot = Some((res, ring, ret_rx));
        });

        // Consumer: the SDEB stage + head readout on the calling thread,
        // recording one compute segment per block (plus the head) so the
        // memory lane can gate each block on its weight transfer.
        let consumer_res = (|| -> Result<(StatSink, Vec<Vec<u64>>, Vec<u64>)> {
            let mut sink = StatSink::new();
            let mut segments = Vec::with_capacity(timesteps);
            let mut head_counts = vec![0u64; d];
            for t in 0..timesteps {
                let Ok(mut u) = rx.recv() else {
                    break; // producer failed; its error takes precedence
                };
                let mut seg = Vec::with_capacity(sdebs.len() + 1);
                let mut before = sink.phases.total().cycles;
                for (bi, core) in sdebs.iter_mut().enumerate() {
                    u = core.run_timestep(
                        &model.blocks[bi],
                        u,
                        hw,
                        mode,
                        t,
                        Some(mapper),
                        Some(pool),
                        &mut sdeb_buf[bi % sdeb_rings],
                        &mut sink,
                        scratch_sdeb,
                    )?;
                    let now = sink.phases.total().cycles;
                    seg.push(now - before);
                    before = now;
                }
                head_readout(sea_head, &u, l, d, hw, &mut sink, &mut head_counts, scratch_sdeb);
                seg.push(sink.phases.total().cycles - before);
                segments.push(seg);
                // Hand the drained tensor back to the producer ring (the
                // receiver outlives the producer task, so this cannot
                // fail outside a producer panic).
                let _ = ret_tx.send(u);
            }
            Ok((sink, segments, head_counts))
        })();
        // Unblock a producer stuck in `send`/`recv` if the consumer bailed
        // early.
        drop(rx);
        drop(ret_tx);
        consumer_res
    });

    let (producer_res, leftovers, ret_rx) =
        producer_out.ok_or_else(|| anyhow!("SPS pipeline stage never ran"))?;
    // Drain every circulating token tensor back into the SPS pool so the
    // next request's ring takes are pool hits.
    for buf in leftovers {
        scratch_sps.put_tensor(buf);
    }
    while let Ok(buf) = ret_rx.try_recv() {
        scratch_sps.put_tensor(buf);
    }
    drop(ret_rx);
    let (sps_sink, sps_per_timestep) = producer_res?;
    let (sdeb_sink, sdeb_segments, head_counts) = consumer_res?;
    debug_assert_eq!(sps_per_timestep.len(), timesteps);
    debug_assert_eq!(sdeb_segments.len(), timesteps);

    // Deterministic merge: SPS phases first (the order the serial
    // controller would have recorded them), then SDEB/head.
    let mut sink = StatSink::new();
    sink.absorb(sps_sink);
    sink.absorb(sdeb_sink);
    Ok(OverlapOutcome { sink, head_counts, sps_per_timestep, sdeb_segments })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_balanced_two_stage() {
        // Two equal stages, 4 timesteps, no I/O: steady state is one
        // stage's total plus one fill timestep of the other.
        let e = PipelineExecution::new(0, 0, vec![100; 4], vec![100; 4]);
        assert_eq!(e.serialized_cycles, 800);
        assert_eq!(e.executed_cycles, 500); // 100 fill + 4*100 steady
        assert_eq!(e.fill_cycles(), 100);
        assert!((e.speedup() - 1.6).abs() < 1e-12);
    }

    #[test]
    fn schedule_bottleneck_bounds() {
        let e = PipelineExecution::new(10, 5, vec![50, 60, 55], vec![500, 480, 510]);
        assert_eq!(e.bottleneck(), "sdeb");
        assert!(e.executed_cycles >= e.bottleneck_cycles());
        assert!(e.executed_cycles <= e.serialized_cycles);
        // SDEB dominates: executed = io_in + sps[0] + sum(sdeb) + io_out.
        assert_eq!(e.executed_cycles, 10 + 50 + 1490 + 5);
    }

    #[test]
    fn schedule_ping_pong_depth_limits_runahead() {
        // A fast producer may run at most 2 timesteps ahead of the
        // consumer: sps[2] must wait for sdeb[0] to free its half.
        let e = PipelineExecution::new(0, 0, vec![1, 1, 1], vec![100, 100, 100]);
        // sps_done = [1, 2, 102]; sdeb_done = [101, 201, 301].
        assert_eq!(e.executed_cycles, 301);
    }

    #[test]
    fn schedule_topology_depth_2_matches_legacy_recurrence() {
        let topo = CoreTopology::paper();
        let a = PipelineExecution::new(10, 5, vec![50, 60, 55], vec![500, 480, 510]);
        let b = PipelineExecution::with_topology(
            10,
            5,
            vec![50, 60, 55],
            vec![500, 480, 510],
            &topo,
        );
        assert_eq!(a.executed_cycles, b.executed_cycles);
        assert_eq!(a.depth, 2);
        assert_eq!(a.sps_cores, 1);
    }

    #[test]
    fn schedule_deeper_ring_relaxes_runahead() {
        // Fast producer, slow consumer: at depth 2, sps[2] waits for
        // sdeb[0]; at depth 4 all four producer timesteps run ahead.
        let sps = vec![1u64, 1, 1, 1];
        let sdeb = vec![100u64, 100, 100, 100];
        let d2 = PipelineExecution::new(0, 0, sps.clone(), sdeb.clone());
        let d4 = PipelineExecution::with_topology(
            0,
            0,
            sps,
            sdeb,
            &CoreTopology { pipeline_depth: 4, ..CoreTopology::paper() },
        );
        // Consumer-bound either way, but the deeper ring can never be
        // slower and the producer stalls disappear from the recurrence.
        assert!(d4.executed_cycles <= d2.executed_cycles);
        // sdeb_done = [101, 201, 301, 401] at depth 4 (sps all done by 4).
        assert_eq!(d4.executed_cycles, 401);
    }

    #[test]
    fn schedule_multiple_sps_cores_overlap_sps_timesteps() {
        // SPS-bound workload: two SPS cores nearly halve the SPS critical
        // path (timesteps round-robin across cores).
        let sps = vec![100u64; 4];
        let sdeb = vec![1u64; 4];
        let one = PipelineExecution::new(0, 0, sps.clone(), sdeb.clone());
        let topo = CoreTopology { sps_cores: 2, pipeline_depth: 4, ..CoreTopology::paper() };
        let two = PipelineExecution::with_topology(0, 0, sps, sdeb, &topo);
        assert_eq!(one.executed_cycles, 401); // serial SPS chain
        // Cores A/B each run 2 timesteps: sps_done = [100, 100, 200, 200];
        // sdeb_done = [101, 102, 201, 202].
        assert_eq!(two.executed_cycles, 202);
        assert_eq!(two.sps_cores, 2);
    }

    #[test]
    fn schedule_single_timestep_is_serial() {
        let e = PipelineExecution::new(7, 3, vec![40], vec![90]);
        assert_eq!(e.executed_cycles, e.serialized_cycles);
        assert!((e.speedup() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn fill_latency_bound_is_io_plus_worst_timesteps() {
        let e = PipelineExecution::new(10, 5, vec![50, 60], vec![70, 80]);
        assert_eq!(e.fill_latency_bound(), 10 + 5 + 60 + 80);
    }

    fn synthetic_dma(bytes: u64, residency: WeightResidency, bw: usize, nblocks: usize) -> DmaEngine {
        use super::super::dma::BlockPlan;
        DmaEngine {
            bytes_per_cycle: bw,
            slots: 2,
            // No prefetch capacity: these pinned-value tests exercise the
            // unsplit (PR 5) stream timing; the prefetch split has its own
            // tests below.
            slot_bytes: 0,
            blocks: (0..nblocks)
                .map(|b| BlockPlan { words: bytes / 2, bytes, core: b % 2, residency })
                .collect(),
            input_bytes: 64,
            output_bytes: 40,
            pinned_sps_words: 1000,
        }
    }

    /// Segments: 2 blocks of 50 cycles plus a 10-cycle head, 3 timesteps.
    fn segs(t: usize) -> Vec<Vec<u64>> {
        (0..t).map(|_| vec![50, 50, 10]).collect()
    }

    #[test]
    fn memory_lane_unlimited_bandwidth_matches_plain_schedule() {
        let topo = CoreTopology::paper();
        let dma = synthetic_dma(1_000_000, WeightResidency::Streaming, usize::MAX, 2);
        let plain = PipelineExecution::with_topology(8, 3, vec![100; 3], vec![110; 3], &topo);
        let mem = PipelineExecution::with_memory(8, 3, vec![100; 3], segs(3), &topo, Some(&dma));
        assert_eq!(mem.executed_cycles, plain.executed_cycles);
        assert_eq!(mem.stall_cycles, 0);
        let report = mem.memory.expect("memory lane records a report");
        // Traffic is still fully accounted even though it never stalls.
        assert_eq!(report.weight_bytes(), 2 * 3 * 1_000_000);
        assert_eq!(report.busy_cycles(), 8 + 3, "only the seeded I/O occupies the ideal bus");
    }

    #[test]
    fn memory_lane_stalls_when_bus_is_slow() {
        let topo = CoreTopology::paper();
        // 1000-byte sets over a 1 B/cycle bus: 1000-cycle transfers vs
        // 50-cycle block segments — heavily bandwidth-bound.
        let dma = synthetic_dma(1000, WeightResidency::Streaming, 1, 2);
        let plain = PipelineExecution::with_topology(8, 3, vec![100; 3], vec![110; 3], &topo);
        let mem = PipelineExecution::with_memory(8, 3, vec![100; 3], segs(3), &topo, Some(&dma));
        assert!(mem.stall_cycles > 0, "slow bus must stall the consumer");
        assert!(mem.executed_cycles > plain.executed_cycles);
        // The injected stalls bound the schedule delay (subadditivity:
        // every other recurrence constraint is monotone).
        assert!(mem.executed_cycles <= plain.executed_cycles + mem.stall_cycles);
        let report = mem.memory.as_ref().unwrap();
        assert_eq!(report.stall_cycles(), mem.stall_cycles);
        assert!(mem.stall_fraction() > 0.0);
        // A bandwidth-bound schedule may exceed the serial *compute* sum —
        // serial charging never modelled memory.
        assert!(mem.fill_latency_bound() >= mem.stall_cycles);
    }

    #[test]
    fn memory_lane_monotone_in_bandwidth() {
        let topo = CoreTopology::paper();
        let mut last = None;
        for bw in [1usize, 2, 4, 8, 16, 64, 1024, usize::MAX] {
            let dma = synthetic_dma(5000, WeightResidency::Streaming, bw, 2);
            let e = PipelineExecution::with_memory(8, 3, vec![100; 3], segs(3), &topo, Some(&dma));
            if let Some(prev) = last {
                assert!(
                    e.executed_cycles <= prev,
                    "bw {bw}: {} > previous {prev}",
                    e.executed_cycles
                );
            }
            last = Some(e.executed_cycles);
        }
    }

    #[test]
    fn resident_sets_stream_once_streaming_sets_every_use() {
        let topo = CoreTopology::paper();
        let res = synthetic_dma(1000, WeightResidency::Resident, usize::MAX, 2);
        let e = PipelineExecution::with_memory(8, 3, vec![100; 3], segs(3), &topo, Some(&res));
        assert_eq!(e.memory.unwrap().weight_bytes(), 2 * 1000, "once per block");
        let stream = synthetic_dma(1000, WeightResidency::Streaming, usize::MAX, 2);
        let e = PipelineExecution::with_memory(8, 3, vec![100; 3], segs(3), &topo, Some(&stream));
        assert_eq!(e.memory.unwrap().weight_bytes(), 2 * 3 * 1000, "once per use");
    }

    #[test]
    fn memory_lane_segments_sum_to_stage_trace() {
        let topo = CoreTopology::paper();
        let dma = synthetic_dma(100, WeightResidency::Resident, 8, 2);
        let e = PipelineExecution::with_memory(8, 3, vec![100; 3], segs(3), &topo, Some(&dma));
        assert_eq!(e.sdeb_per_timestep, vec![110; 3]);
        assert_eq!(e.sdeb_segments, segs(3));
    }

    #[test]
    fn token_major_transpose_reuses_buffer() {
        let u0 = QTensor { shape: vec![2, 3], frac: ACT_FRAC, data: vec![1, 2, 3, 4, 5, 6] };
        let mut out = QTensor { shape: vec![9], frac: 0, data: vec![7; 9] };
        u0_to_token_major_into(&u0, 3, 2, &mut out);
        assert_eq!(out.shape, vec![3, 2]);
        assert_eq!(out.frac, ACT_FRAC);
        // [D=2, L=3] channel-major -> [L=3, D=2] token-major.
        assert_eq!(out.data, vec![1, 4, 2, 5, 3, 6]);
    }
}
