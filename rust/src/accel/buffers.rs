//! The buffer complement of Fig. 1: Input/Output Buffers at the external
//! interface, the double-buffered ESS halves inside each core, the weight
//! buffer feeding the Tile Engine / SLA, and the ResBuffer for residual
//! operands.
//!
//! Each core's encoded-spike storage is modelled as an explicit ping/pong
//! pair ([`CoreBuffers`]): timestep `t` writes one half while the
//! overlapped consumer still drains the other, which is what lets the
//! [`executor`](super::executor) run the SPS stage of timestep `t+1`
//! concurrently with the SDEB stage of timestep `t`.

use anyhow::Result;

use crate::hw::{AccelConfig, SramBank, UnitStats};
use crate::spike::EncodedSpikes;
use crate::util::div_ceil;

/// One core's double-buffered ESS complement: two physical bank halves,
/// alternated by timestep parity (Fig. 1: each core owns its SEA/ESS pair,
/// duplicated so produce and consume can overlap).
#[derive(Clone, Debug)]
pub struct CoreBuffers {
    /// The half written on even timesteps.
    pub ping: SramBank,
    /// The half written on odd timesteps.
    pub pong: SramBank,
}

impl CoreBuffers {
    /// Build both halves, each sized to the core's full ESS complement
    /// (`ess_banks * ess_bank_words` words).
    ///
    /// Modelling note: double buffering here *duplicates* the physical
    /// banks rather than splitting one complement in half. The resource
    /// model's ESS BRAM term stays calibrated to the paper's reported
    /// Table I totals (which describe the real, already-double-buffered
    /// chip), so `ResourceModel` charges the ESS once — see
    /// DESIGN.md "Substitutions".
    pub fn new(prefix: &str, words: usize) -> Self {
        Self {
            ping: SramBank::new(&format!("{prefix}_ping"), words),
            pong: SramBank::new(&format!("{prefix}_pong"), words),
        }
    }

    /// Store an encoded tensor into the half selected by `pong` (the
    /// caller passes the timestep parity). The previous tensor of the same
    /// site is freed by the consumer within the layer pass, so occupancy
    /// is transient — but the capacity check is a hard error, catching
    /// configs whose ESS cannot hold one tensor.
    pub fn store_encoded(&mut self, enc: &EncodedSpikes, pong: bool) -> Result<()> {
        let words = enc.storage_words();
        let bank = if pong { &mut self.pong } else { &mut self.ping };
        bank.alloc(words)?;
        bank.free(words); // consumed within the layer pass (double buffer)
        Ok(())
    }

    /// Reset both halves' access counters.
    pub fn reset_counters(&mut self) {
        self.ping.reset_counters();
        self.pong.reset_counters();
    }

    /// Total writes across both halves (for reports/tests).
    pub fn writes(&self) -> u64 {
        self.ping.writes + self.pong.writes
    }
}

/// All modelled SRAM structures plus external-transfer accounting.
#[derive(Clone, Debug)]
pub struct BufferSet {
    /// Input Buffer at the external interface.
    pub input: SramBank,
    /// Output Buffer at the external interface.
    pub output: SramBank,
    /// ResBuffer holding residual operands.
    pub res: SramBank,
    /// Weight buffer feeding the Tile Engine and the Spike Linear Array.
    pub weight: SramBank,
    /// The SPS Core's double-buffered ESS halves.
    pub sps: CoreBuffers,
    /// The SDEB Cores' double-buffered ESS halves.
    pub sdeb: CoreBuffers,
}

impl BufferSet {
    /// Build the full complement for one accelerator instance.
    pub fn new(cfg: &AccelConfig) -> Self {
        let ess_words = cfg.ess_banks * cfg.ess_bank_words;
        Self {
            input: SramBank::new("input_buffer", 64 * 1024),
            output: SramBank::new("output_buffer", 16 * 1024),
            res: SramBank::new("res_buffer", 64 * 1024),
            weight: SramBank::new("weight_buffer", 2 * 1024 * 1024),
            sps: CoreBuffers::new("ess_sps", ess_words),
            sdeb: CoreBuffers::new("ess_sdeb", ess_words),
        }
    }

    /// Charge an external->input-buffer transfer of `bytes`.
    pub fn load_external(&mut self, bytes: usize, cfg: &AccelConfig) -> Result<UnitStats> {
        self.input.alloc(bytes.min(self.input.words - self.input.used))?;
        Ok(UnitStats {
            cycles: div_ceil(bytes as u64, cfg.dram_bytes_per_cycle as u64).max(1),
            dram_bytes: bytes as u64,
            sram_writes: bytes as u64,
            ..Default::default()
        })
    }

    /// Reset all access counters (between inferences).
    pub fn reset(&mut self) {
        for b in [&mut self.input, &mut self.output, &mut self.res, &mut self.weight] {
            b.reset_counters();
        }
        self.sps.reset_counters();
        self.sdeb.reset_counters();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spike::SpikeMatrix;

    #[test]
    fn external_load_charges_dram() {
        let cfg = AccelConfig::paper();
        let mut b = BufferSet::new(&cfg);
        let s = b.load_external(3 * 32 * 32 * 2, &cfg).unwrap();
        assert_eq!(s.dram_bytes, 6144);
        assert_eq!(s.cycles, 384); // 6144 / 16 B-per-cycle
    }

    #[test]
    fn ess_capacity_enforced() {
        let mut cfg = AccelConfig::small();
        cfg.ess_banks = 1;
        cfg.ess_bank_words = 4;
        let mut b = BufferSet::new(&cfg);
        let mut m = SpikeMatrix::zeros(1, 64);
        for l in 0..8 {
            m.set(0, l, true);
        }
        let enc = EncodedSpikes::from_bitmap(&m);
        assert!(b.sps.store_encoded(&enc, false).is_err());
        assert!(b.sps.store_encoded(&enc, true).is_err(), "pong half same capacity");
    }

    #[test]
    fn store_encoded_double_buffers() {
        let cfg = AccelConfig::small();
        let mut b = BufferSet::new(&cfg);
        let mut m = SpikeMatrix::zeros(4, 64);
        m.set(0, 3, true);
        let enc = EncodedSpikes::from_bitmap(&m);
        for t in 0..1000 {
            b.sdeb.store_encoded(&enc, t % 2 == 1).unwrap(); // never overflows
        }
        assert_eq!(b.sdeb.ping.used, 0);
        assert_eq!(b.sdeb.pong.used, 0);
        assert!(b.sdeb.ping.writes > 0 && b.sdeb.pong.writes > 0, "both halves exercised");
    }

    #[test]
    fn parity_selects_halves() {
        let mut cb = CoreBuffers::new("t", 1024);
        let mut m = SpikeMatrix::zeros(1, 16);
        m.set(0, 1, true);
        let enc = EncodedSpikes::from_bitmap(&m);
        cb.store_encoded(&enc, false).unwrap();
        assert!(cb.ping.writes > 0);
        assert_eq!(cb.pong.writes, 0);
        cb.store_encoded(&enc, true).unwrap();
        assert!(cb.pong.writes > 0);
    }
}
