//! The buffer complement of Fig. 1: Input/Output Buffers at the external
//! interface, the ESS buffer ring inside each core, the weight buffer
//! feeding the Tile Engine / SLA, and the ResBuffer for residual operands.
//!
//! Each core's encoded-spike storage is modelled as an explicit ring of
//! bank slots ([`CoreBuffers`]) whose depth comes from the instance's
//! [`CoreTopology`](crate::hw::CoreTopology): timestep `t` writes slot
//! `t % depth` while the overlapped consumer still drains earlier slots,
//! which is what lets the [`executor`](super::executor) run the SPS stage
//! of timestep `t+1` concurrently with the SDEB stage of timestep `t`. The
//! paper's instance is depth 2 — the classic ping/pong pair — and deeper
//! rings let a fast producer run further ahead.
//!
//! The SDEB side holds one ring **per SDEB core** ([`BufferSet::sdeb`]):
//! each physical core owns its SEA/ESS complement (Fig. 1), so encoder
//! block `b`'s traffic lands in core `b % sdeb_cores`'s ring.

use anyhow::Result;

use crate::hw::{AccelConfig, SramBank, UnitStats};
use crate::spike::EncodedSpikes;
use crate::util::div_ceil;
use crate::util::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// Executable model of the ping/pong handoff: a single-producer
/// single-consumer ring of `depth` slots with release/acquire publication.
///
/// [`CoreBuffers`] models the ESS ring's *capacity* (bank words, access
/// counters); `SlotRing` models its *synchronization protocol* — the
/// ordering discipline that lets the SPS producer of timestep `t + 1` hand
/// a filled slot to the SDEB consumer of timestep `t` without locks. The
/// overlapped executor realizes the same discipline through a bounded
/// `mpsc` channel of `depth - 1` plus a pre-filled return ring; loom has no
/// channel model, so `rust/tests/loom_sync.rs` model-checks the protocol on
/// this primitive instead (see `util::sync` for the loom build recipe).
///
/// Protocol: the producer writes the payload into slot `head % depth` with
/// `Relaxed`, then publishes by storing `head + 1` with `Release`; the
/// consumer `Acquire`-loads `head` (which makes the payload write visible),
/// reads the slot, then retires it by storing `tail + 1` with `Release`,
/// which the producer `Acquire`-loads before reusing the slot. Weakening
/// any of the four orderings is a bug loom can exhibit as a stale read.
#[derive(Debug)]
pub struct SlotRing {
    slots: Box<[AtomicU64]>,
    /// Number of payloads published (monotonic; producer-owned).
    head: AtomicUsize,
    /// Number of payloads consumed (monotonic; consumer-owned).
    tail: AtomicUsize,
}

impl SlotRing {
    /// Build a ring of `depth` slots (clamped to at least 2, matching
    /// [`CoreBuffers::new`] — produce and consume cannot overlap through
    /// fewer).
    pub fn new(depth: usize) -> Self {
        let depth = depth.max(2);
        Self {
            slots: (0..depth).map(|_| AtomicU64::new(0)).collect(),
            head: AtomicUsize::new(0),
            tail: AtomicUsize::new(0),
        }
    }

    /// Ring depth (number of slots).
    pub fn depth(&self) -> usize {
        self.slots.len()
    }

    /// Producer side: publish `value` into the next slot. Returns `false`
    /// when the ring is full (the producer has run a full `depth` ahead of
    /// the consumer — exactly the back-pressure the executor's bounded
    /// channel applies to the SPS stage).
    pub fn try_publish(&self, value: u64) -> bool {
        let head = self.head.load(Ordering::Relaxed); // producer-owned
        let tail = self.tail.load(Ordering::Acquire); // consumer retired up to here
        if head.wrapping_sub(tail) >= self.slots.len() {
            return false;
        }
        self.slots[head % self.slots.len()].store(value, Ordering::Relaxed);
        // Publication point: makes the payload store above visible to the
        // consumer's Acquire load of `head`.
        self.head.store(head.wrapping_add(1), Ordering::Release);
        true
    }

    /// Consumer side: take the oldest published payload, or `None` when the
    /// ring is empty.
    pub fn try_consume(&self) -> Option<u64> {
        let tail = self.tail.load(Ordering::Relaxed); // consumer-owned
        let head = self.head.load(Ordering::Acquire); // producer published up to here
        if tail == head {
            return None;
        }
        let value = self.slots[tail % self.slots.len()].load(Ordering::Relaxed);
        // Retirement point: tells the producer this slot may be rewritten.
        self.tail.store(tail.wrapping_add(1), Ordering::Release);
        Some(value)
    }

    /// Payloads published but not yet consumed.
    pub fn in_flight(&self) -> usize {
        self.head.load(Ordering::Acquire).wrapping_sub(self.tail.load(Ordering::Acquire))
    }
}

/// One core's ESS buffer ring: `depth` physical bank slots, selected by
/// timestep (`slot = t % depth`). Depth 2 is Fig. 1's ping/pong pair,
/// duplicated so produce and consume can overlap.
#[derive(Clone, Debug)]
pub struct CoreBuffers {
    /// The ring of bank slots, written round-robin by timestep.
    pub slots: Vec<SramBank>,
}

impl CoreBuffers {
    /// Build a ring of `depth` slots, each sized to the core's full ESS
    /// complement (`ess_banks * ess_bank_words` words). `depth` is
    /// defensively clamped to at least 2 (produce/consume cannot overlap
    /// through fewer slots) — validating constructors reject such configs
    /// up front via [`CoreTopology::validate`](crate::hw::CoreTopology::validate).
    ///
    /// Modelling note: the ring *duplicates* the physical banks rather
    /// than splitting one complement into `depth` parts. The resource
    /// model's ESS BRAM term stays calibrated to the paper's reported
    /// Table I totals (which describe the real, already-double-buffered
    /// chip), so `ResourceModel` charges the ESS once — see
    /// DESIGN.md "Substitutions".
    pub fn new(prefix: &str, words: usize, depth: usize) -> Self {
        let depth = depth.max(2);
        Self {
            slots: (0..depth).map(|i| SramBank::new(&format!("{prefix}_slot{i}"), words)).collect(),
        }
    }

    /// Ring depth (number of slots).
    pub fn depth(&self) -> usize {
        self.slots.len()
    }

    /// Store an encoded tensor into the slot of timestep `t` (`t % depth`;
    /// callers may pass the timestep directly). The previous tensor of the
    /// same site is freed by the consumer within the layer pass, so
    /// occupancy is transient — but the capacity check is a hard error,
    /// catching configs whose ESS cannot hold one tensor.
    pub fn store_encoded(&mut self, enc: &EncodedSpikes, t: usize) -> Result<()> {
        let words = enc.storage_words();
        let depth = self.slots.len();
        let bank = &mut self.slots[t % depth];
        bank.alloc(words)?;
        bank.free(words); // consumed within the layer pass (buffer ring)
        Ok(())
    }

    /// Store an encoded tensor of which only `moved_words` actually cross
    /// the write ports — the `--temporal-delta` path, where the slot still
    /// reserves the full tensor (the previous frame's copy is patched in
    /// place) but only the changed addresses are written. With
    /// `moved_words == enc.storage_words()` this is exactly
    /// [`Self::store_encoded`].
    pub fn store_encoded_moved(
        &mut self,
        enc: &EncodedSpikes,
        moved_words: usize,
        t: usize,
    ) -> Result<()> {
        let words = enc.storage_words();
        let depth = self.slots.len();
        let bank = &mut self.slots[t % depth];
        bank.alloc_delta(words, moved_words.min(words))?;
        bank.free(words); // consumed within the layer pass (buffer ring)
        Ok(())
    }

    /// Reset every slot's access counters.
    pub fn reset_counters(&mut self) {
        for s in &mut self.slots {
            s.reset_counters();
        }
    }

    /// Total writes across all slots (for reports/tests).
    pub fn writes(&self) -> u64 {
        self.slots.iter().map(|s| s.writes).sum()
    }
}

/// All modelled SRAM structures plus external-transfer accounting.
#[derive(Clone, Debug)]
pub struct BufferSet {
    /// Input Buffer at the external interface.
    pub input: SramBank,
    /// Output Buffer at the external interface.
    pub output: SramBank,
    /// ResBuffer holding residual operands.
    pub res: SramBank,
    /// Weight buffer feeding the Tile Engine and the Spike Linear Array
    /// (sized by [`AccelConfig::weight_buffer_words`]; its ping/pong slot
    /// discipline is modelled by the
    /// [`DmaEngine`](super::DmaEngine), and streamed refills land on its
    /// write counter via
    /// [`SramBank::record_stream_writes`]).
    pub weight: SramBank,
    /// The SPS Core's ESS buffer ring.
    pub sps: CoreBuffers,
    /// One ESS buffer ring per SDEB core (encoder block `b` uses ring
    /// `b % sdeb_cores` — see [`Self::sdeb_for`]).
    pub sdeb: Vec<CoreBuffers>,
}

impl BufferSet {
    /// Build the full complement for one accelerator instance: ring depth
    /// and SDEB-core count come from `cfg.topology`.
    pub fn new(cfg: &AccelConfig) -> Self {
        let ess_words = cfg.ess_banks * cfg.ess_bank_words;
        let depth = cfg.topology.pipeline_depth;
        let sdeb_cores = cfg.topology.sdeb_cores.max(1);
        Self {
            input: SramBank::new("input_buffer", 64 * 1024),
            output: SramBank::new("output_buffer", 16 * 1024),
            res: SramBank::new("res_buffer", 64 * 1024),
            weight: SramBank::new("weight_buffer", cfg.weight_buffer_words),
            sps: CoreBuffers::new("ess_sps", ess_words, depth),
            sdeb: (0..sdeb_cores)
                .map(|c| CoreBuffers::new(&format!("ess_sdeb{c}"), ess_words, depth))
                .collect(),
        }
    }

    /// The ESS ring of the SDEB core that hosts encoder block `block`.
    pub fn sdeb_for(&mut self, block: usize) -> &mut CoreBuffers {
        let n = self.sdeb.len();
        &mut self.sdeb[block % n]
    }

    /// Charge an external->input-buffer transfer of `bytes`.
    pub fn load_external(&mut self, bytes: usize, cfg: &AccelConfig) -> Result<UnitStats> {
        self.input.alloc(bytes.min(self.input.words - self.input.used))?;
        Ok(UnitStats {
            cycles: div_ceil(bytes as u64, cfg.dram_bytes_per_cycle as u64).max(1), // as-ok: widening for 64-bit stat/cycle math
            dram_bytes: bytes as u64, // as-ok: widening for 64-bit stat/cycle math
            sram_writes: bytes as u64, // as-ok: widening for 64-bit stat/cycle math
            ..Default::default()
        })
    }

    /// Reset all access counters (between inferences).
    pub fn reset(&mut self) {
        for b in [&mut self.input, &mut self.output, &mut self.res, &mut self.weight] {
            b.reset_counters();
        }
        self.sps.reset_counters();
        for ring in &mut self.sdeb {
            ring.reset_counters();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::CoreTopology;
    use crate::spike::SpikeMatrix;

    #[test]
    fn external_load_charges_dram() {
        let cfg = AccelConfig::paper();
        let mut b = BufferSet::new(&cfg);
        let s = b.load_external(3 * 32 * 32 * 2, &cfg).unwrap();
        assert_eq!(s.dram_bytes, 6144);
        assert_eq!(s.cycles, 384); // 6144 / 16 B-per-cycle
    }

    #[test]
    fn ess_capacity_enforced() {
        let mut cfg = AccelConfig::small();
        cfg.ess_banks = 1;
        cfg.ess_bank_words = 4;
        let mut b = BufferSet::new(&cfg);
        let mut m = SpikeMatrix::zeros(1, 64);
        for l in 0..8 {
            m.set(0, l, true);
        }
        let enc = EncodedSpikes::from_bitmap(&m);
        assert!(b.sps.store_encoded(&enc, 0).is_err());
        assert!(b.sps.store_encoded(&enc, 1).is_err(), "every ring slot has the same capacity");
    }

    #[test]
    fn store_encoded_cycles_the_ring() {
        let cfg = AccelConfig::small();
        let mut b = BufferSet::new(&cfg);
        let mut m = SpikeMatrix::zeros(4, 64);
        m.set(0, 3, true);
        let enc = EncodedSpikes::from_bitmap(&m);
        for t in 0..1000 {
            b.sdeb_for(0).store_encoded(&enc, t).unwrap(); // never overflows
        }
        for slot in &b.sdeb[0].slots {
            assert_eq!(slot.used, 0);
            assert!(slot.writes > 0, "every ring slot exercised");
        }
    }

    #[test]
    fn delta_store_charges_only_moved_words() {
        let cfg = AccelConfig::small();
        let mut b = BufferSet::new(&cfg);
        let mut m = SpikeMatrix::zeros(4, 64);
        for l in 0..8 {
            m.set(0, l, true);
        }
        let enc = EncodedSpikes::from_bitmap(&m);
        b.sdeb_for(0).store_encoded(&enc, 0).unwrap();
        let full = b.sdeb[0].writes();
        assert_eq!(full, enc.storage_words() as u64);
        b.sdeb_for(0).store_encoded_moved(&enc, 3, 1).unwrap();
        assert_eq!(b.sdeb[0].writes() - full, 3);
        // moved == full degenerates to the plain store.
        b.sdeb_for(0).store_encoded_moved(&enc, enc.storage_words(), 2).unwrap();
        assert_eq!(b.sdeb[0].writes(), 2 * full + 3);
    }

    #[test]
    fn timestep_selects_ring_slot() {
        let mut cb = CoreBuffers::new("t", 1024, 2);
        assert_eq!(cb.depth(), 2);
        let mut m = SpikeMatrix::zeros(1, 16);
        m.set(0, 1, true);
        let enc = EncodedSpikes::from_bitmap(&m);
        cb.store_encoded(&enc, 0).unwrap();
        assert!(cb.slots[0].writes > 0);
        assert_eq!(cb.slots[1].writes, 0);
        cb.store_encoded(&enc, 1).unwrap();
        assert!(cb.slots[1].writes > 0);
        // The ring wraps: timestep 2 lands back in slot 0.
        let w0 = cb.slots[0].writes;
        cb.store_encoded(&enc, 2).unwrap();
        assert!(cb.slots[0].writes > w0);
    }

    #[test]
    fn topology_sizes_the_rings() {
        let mut cfg = AccelConfig::small();
        cfg.topology = CoreTopology {
            pipeline_depth: 3,
            ..CoreTopology::with_sdeb_cores(4)
        };
        let b = BufferSet::new(&cfg);
        assert_eq!(b.sps.depth(), 3);
        assert_eq!(b.sdeb.len(), 4);
        assert!(b.sdeb.iter().all(|r| r.depth() == 3));
    }

    #[test]
    fn slot_ring_full_and_empty_transitions() {
        let ring = SlotRing::new(2);
        assert_eq!(ring.depth(), 2);
        assert_eq!(ring.try_consume(), None, "empty ring yields nothing");
        assert!(ring.try_publish(10));
        assert!(ring.try_publish(11));
        assert!(!ring.try_publish(12), "depth-2 ring is full after two publishes");
        assert_eq!(ring.in_flight(), 2);
        assert_eq!(ring.try_consume(), Some(10));
        assert!(ring.try_publish(12), "retiring a slot frees it for reuse");
        assert_eq!(ring.try_consume(), Some(11));
        assert_eq!(ring.try_consume(), Some(12));
        assert_eq!(ring.try_consume(), None);
        assert_eq!(ring.in_flight(), 0);
    }

    #[test]
    fn slot_ring_depth_clamps_to_two() {
        assert_eq!(SlotRing::new(0).depth(), 2);
        assert_eq!(SlotRing::new(1).depth(), 2);
        assert_eq!(SlotRing::new(3).depth(), 3);
    }

    #[test]
    fn slot_ring_two_threads_fifo() {
        // Cross-thread pump: every value arrives, in order, through a ring
        // shallower than the stream — the ping/pong handoff in miniature.
        let ring = std::sync::Arc::new(SlotRing::new(2));
        let r2 = std::sync::Arc::clone(&ring);
        let consumer = std::thread::spawn(move || {
            let mut got = Vec::new();
            while got.len() < 64 {
                match r2.try_consume() {
                    Some(v) => got.push(v),
                    None => std::thread::yield_now(),
                }
            }
            got
        });
        let mut sent = 0u64;
        while sent < 64 {
            if ring.try_publish(sent) {
                sent += 1;
            } else {
                std::thread::yield_now();
            }
        }
        let got = consumer.join().unwrap();
        assert_eq!(got, (0..64).collect::<Vec<u64>>());
    }

    #[test]
    fn blocks_round_robin_over_sdeb_rings() {
        let cfg = AccelConfig::small(); // 2 SDEB cores
        let mut b = BufferSet::new(&cfg);
        let mut m = SpikeMatrix::zeros(1, 16);
        m.set(0, 1, true);
        let enc = EncodedSpikes::from_bitmap(&m);
        b.sdeb_for(0).store_encoded(&enc, 0).unwrap();
        b.sdeb_for(1).store_encoded(&enc, 0).unwrap();
        b.sdeb_for(2).store_encoded(&enc, 0).unwrap(); // wraps to ring 0
        assert!(b.sdeb[0].writes() > b.sdeb[1].writes());
    }
}
