//! The buffer complement of Fig. 1: Input/Output Buffers at the external
//! interface, the ESS banks inside each core, the weight buffer feeding the
//! Tile Engine / SLA, and the ResBuffer for residual operands.

use anyhow::Result;

use crate::hw::{AccelConfig, SramBank, UnitStats};
use crate::spike::EncodedSpikes;
use crate::util::div_ceil;

/// All modelled SRAM structures plus external-transfer accounting.
#[derive(Clone, Debug)]
pub struct BufferSet {
    pub input: SramBank,
    pub output: SramBank,
    pub res: SramBank,
    pub weight: SramBank,
    /// One logical bank object standing for the `ess_banks` physical banks
    /// of each core (occupancy is tracked in words across all banks).
    pub ess_sps: SramBank,
    pub ess_sdeb: SramBank,
}

impl BufferSet {
    pub fn new(cfg: &AccelConfig) -> Self {
        let ess_words = cfg.ess_banks * cfg.ess_bank_words;
        Self {
            input: SramBank::new("input_buffer", 64 * 1024),
            output: SramBank::new("output_buffer", 16 * 1024),
            res: SramBank::new("res_buffer", 64 * 1024),
            weight: SramBank::new("weight_buffer", 2 * 1024 * 1024),
            ess_sps: SramBank::new("ess_sps", ess_words),
            ess_sdeb: SramBank::new("ess_sdeb", ess_words),
        }
    }

    /// Charge an external->input-buffer transfer of `bytes`.
    pub fn load_external(&mut self, bytes: usize, cfg: &AccelConfig) -> Result<UnitStats> {
        self.input.alloc(bytes.min(self.input.words - self.input.used))?;
        Ok(UnitStats {
            cycles: div_ceil(bytes as u64, cfg.dram_bytes_per_cycle as u64).max(1),
            dram_bytes: bytes as u64,
            sram_writes: bytes as u64,
            ..Default::default()
        })
    }

    /// Store an encoded tensor into an ESS (double-buffered: the previous
    /// tensor of the same site is freed by the consumer).
    pub fn store_encoded(&mut self, enc: &EncodedSpikes, sdeb: bool) -> Result<()> {
        let words = enc.storage_words();
        let bank = if sdeb { &mut self.ess_sdeb } else { &mut self.ess_sps };
        bank.alloc(words)?;
        bank.free(words); // consumed within the layer pass (double buffer)
        Ok(())
    }

    pub fn reset(&mut self) {
        for b in [
            &mut self.input,
            &mut self.output,
            &mut self.res,
            &mut self.weight,
            &mut self.ess_sps,
            &mut self.ess_sdeb,
        ] {
            b.reset_counters();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spike::SpikeMatrix;

    #[test]
    fn external_load_charges_dram() {
        let cfg = AccelConfig::paper();
        let mut b = BufferSet::new(&cfg);
        let s = b.load_external(3 * 32 * 32 * 2, &cfg).unwrap();
        assert_eq!(s.dram_bytes, 6144);
        assert_eq!(s.cycles, 384); // 6144 / 16 B-per-cycle
    }

    #[test]
    fn ess_capacity_enforced() {
        let mut cfg = AccelConfig::small();
        cfg.ess_banks = 1;
        cfg.ess_bank_words = 4;
        let mut b = BufferSet::new(&cfg);
        let mut m = SpikeMatrix::zeros(1, 64);
        for l in 0..8 {
            m.set(0, l, true);
        }
        let enc = EncodedSpikes::from_bitmap(&m);
        assert!(b.store_encoded(&enc, false).is_err());
    }

    #[test]
    fn store_encoded_double_buffers() {
        let cfg = AccelConfig::small();
        let mut b = BufferSet::new(&cfg);
        let mut m = SpikeMatrix::zeros(4, 64);
        m.set(0, 3, true);
        let enc = EncodedSpikes::from_bitmap(&m);
        for _ in 0..1000 {
            b.store_encoded(&enc, true).unwrap(); // never overflows
        }
        assert_eq!(b.ess_sdeb.used, 0);
        assert!(b.ess_sdeb.writes > 0);
    }
}
