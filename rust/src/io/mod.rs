//! Artifact I/O: a minimal NumPy `.npy` reader (numpy is the only
//! interchange producer; serde/npy crates are unavailable offline), the
//! plain-text weight manifest written by `python/compile/train.py`, and the
//! exported model/runtime configuration.

pub mod manifest;
pub mod npy;

pub use manifest::{Manifest, ModelConfigFile};
pub use npy::NpyArray;
