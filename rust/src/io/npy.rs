//! Minimal `.npy` (format version 1.0/2.0) reader for little-endian
//! f32/i32/i64 C-order arrays — the only layouts `train.py` emits.

use std::fs;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// A loaded array: shape + data (converted to f32 or i32 as requested).
#[derive(Clone, Debug, PartialEq)]
pub struct NpyArray {
    /// Tensor shape from the header.
    pub shape: Vec<usize>,
    /// Numpy dtype descriptor.
    pub dtype: String,
    raw: Vec<u8>,
}

impl NpyArray {
    /// Read a `.npy` file.
    pub fn load(path: &Path) -> Result<Self> {
        let bytes = fs::read(path).with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&bytes).with_context(|| format!("parsing {}", path.display()))
    }

    /// Parse from raw bytes.
    pub fn parse(bytes: &[u8]) -> Result<Self> {
        if bytes.len() < 10 || &bytes[..6] != b"\x93NUMPY" {
            bail!("not an npy file (bad magic)");
        }
        let major = bytes[6];
        let (header_len, header_start) = match major {
            1 => (u16::from_le_bytes([bytes[8], bytes[9]]) as usize, 10),
            2 => {
                // The v1 check above only guarantees 10 bytes; a truncated
                // v2 header must be an error, not an index panic.
                if bytes.len() < 12 {
                    bail!("truncated npy v2 header length field");
                }
                (u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]) as usize, 12)
            }
            v => bail!("unsupported npy version {v}"),
        };
        let header_end = header_start + header_len;
        if bytes.len() < header_end {
            bail!("truncated npy header");
        }
        let header = std::str::from_utf8(&bytes[header_start..header_end])
            .context("npy header not utf-8")?;

        let dtype = extract_quoted(header, "'descr':").context("missing descr")?;
        if extract_bool(header, "'fortran_order':")? {
            bail!("fortran-order npy not supported");
        }
        let shape = extract_shape(header).context("missing shape")?;

        let elem = match dtype.as_str() {
            "<f4" | "<i4" => 4,
            "<i8" => 8,
            "|i1" | "|u1" => 1,
            d => bail!("unsupported dtype {d}"),
        };
        // Checked products: a corrupt shape like (2**48, 2**48) must not
        // wrap around usize and pass the length check below.
        let bytes_needed = shape
            .iter()
            .try_fold(elem, |acc: usize, &d| acc.checked_mul(d))
            .context("npy shape overflows usize")?;
        let data = &bytes[header_end..];
        if data.len() < bytes_needed {
            bail!("npy payload too short: {} < {}", data.len(), bytes_needed);
        }
        Ok(Self { shape, dtype, raw: data[..bytes_needed].to_vec() })
    }

    /// Element count.
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    /// True when the array is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Elements decoded as f32.
    pub fn as_f32(&self) -> Result<Vec<f32>> {
        match self.dtype.as_str() {
            "<f4" => Ok(self
                .raw
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect()),
            "<i4" => Ok(self
                .raw
                .chunks_exact(4)
                .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]) as f32)
                .collect()),
            d => bail!("cannot view {d} as f32"),
        }
    }

    /// Elements decoded as i32.
    pub fn as_i32(&self) -> Result<Vec<i32>> {
        match self.dtype.as_str() {
            "<i4" => Ok(self
                .raw
                .chunks_exact(4)
                .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect()),
            "<i8" => Ok(self
                .raw
                .chunks_exact(8)
                .map(|c| {
                    i64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]) as i32
                })
                .collect()),
            "|i1" => Ok(self.raw.iter().map(|&b| b as i8 as i32).collect()),
            "|u1" => Ok(self.raw.iter().map(|&b| b as i32).collect()),
            d => bail!("cannot view {d} as i32"),
        }
    }
}

fn extract_quoted(header: &str, key: &str) -> Option<String> {
    let at = header.find(key)? + key.len();
    let rest = &header[at..];
    let q0 = rest.find('\'')? + 1;
    let q1 = rest[q0..].find('\'')? + q0;
    Some(rest[q0..q1].to_string())
}

fn extract_bool(header: &str, key: &str) -> Result<bool> {
    let at = header.find(key).context("missing key")? + key.len();
    let rest = header[at..].trim_start();
    Ok(rest.starts_with("True"))
}

fn extract_shape(header: &str) -> Option<Vec<usize>> {
    let at = header.find("'shape':")? + "'shape':".len();
    let rest = &header[at..];
    let open = rest.find('(')? + 1;
    let close = rest[open..].find(')')? + open;
    let inner = &rest[open..close];
    let dims: Vec<usize> = inner
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(|s| s.parse().ok())
        .collect::<Option<Vec<_>>>()?;
    Some(dims)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Hand-assemble a v1.0 npy byte stream.
    fn make_npy(descr: &str, shape: &str, payload: &[u8]) -> Vec<u8> {
        let mut header = format!(
            "{{'descr': '{descr}', 'fortran_order': False, 'shape': {shape}, }}"
        );
        let total = 10 + header.len();
        let pad = (64 - total % 64) % 64;
        header.push_str(&" ".repeat(pad));
        header.push('\n');
        let mut out = b"\x93NUMPY\x01\x00".to_vec();
        out.extend((header.len() as u16).to_le_bytes());
        out.extend(header.as_bytes());
        out.extend(payload);
        out
    }

    #[test]
    fn parses_f32_2d() {
        let vals = [1.0f32, -2.5, 3.25, 0.0, 7.0, -0.125];
        let payload: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        let npy = make_npy("<f4", "(2, 3)", &payload);
        let arr = NpyArray::parse(&npy).unwrap();
        assert_eq!(arr.shape, vec![2, 3]);
        assert_eq!(arr.as_f32().unwrap(), vals);
    }

    #[test]
    fn parses_i32_1d_and_scalar_shape() {
        let vals = [5i32, -9];
        let payload: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        let arr = NpyArray::parse(&make_npy("<i4", "(2,)", &payload)).unwrap();
        assert_eq!(arr.shape, vec![2]);
        assert_eq!(arr.as_i32().unwrap(), vals);
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(NpyArray::parse(b"not an npy").is_err());
    }

    #[test]
    fn rejects_fortran_order() {
        let mut npy = make_npy("<f4", "(1,)", &1.0f32.to_le_bytes());
        // Flip the fortran_order flag in the (ASCII) header bytes only.
        let header_len = u16::from_le_bytes([npy[8], npy[9]]) as usize;
        let header = String::from_utf8(npy[10..10 + header_len].to_vec()).unwrap();
        let flipped = header.replace("False", "True ");
        npy[10..10 + header_len].copy_from_slice(flipped.as_bytes());
        assert!(NpyArray::parse(&npy).is_err());
    }

    #[test]
    fn rejects_truncated_payload() {
        let npy = make_npy("<f4", "(4,)", &1.0f32.to_le_bytes());
        assert!(NpyArray::parse(&npy).is_err());
    }

    #[test]
    fn truncated_v2_length_field_is_an_error_not_a_panic() {
        // 10 bytes of a v2.0 file: magic + version, but only 2 of the 4
        // header-length bytes. Used to index out of bounds.
        let npy = b"\x93NUMPY\x02\x00\x40\x00";
        let err = NpyArray::parse(npy).unwrap_err();
        assert!(err.to_string().contains("truncated npy v2"), "{err:#}");
    }

    #[test]
    fn parses_v2_header() {
        let payload = 1.5f32.to_le_bytes();
        let header = "{'descr': '<f4', 'fortran_order': False, 'shape': (1,), }\n";
        let mut npy = b"\x93NUMPY\x02\x00".to_vec();
        npy.extend((header.len() as u32).to_le_bytes());
        npy.extend(header.as_bytes());
        npy.extend(payload);
        let arr = NpyArray::parse(&npy).unwrap();
        assert_eq!(arr.shape, vec![1]);
        assert_eq!(arr.as_f32().unwrap(), vec![1.5]);
    }

    #[test]
    fn huge_shape_product_does_not_wrap() {
        // 2^63 * 4 wraps a u64/usize product to 0, which would make an
        // empty payload "long enough" without the checked_mul guard.
        let npy = make_npy("<f4", "(9223372036854775808,)", &[]);
        let err = NpyArray::parse(&npy).unwrap_err();
        assert!(err.to_string().contains("overflow"), "{err:#}");
    }

    #[test]
    fn i64_downcast() {
        let vals = [42i64, -7];
        let payload: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        let arr = NpyArray::parse(&make_npy("<i8", "(2,)", &payload)).unwrap();
        assert_eq!(arr.as_i32().unwrap(), vec![42, -7]);
    }

    #[test]
    fn roundtrip_real_numpy_file() {
        // If artifacts exist (post `make artifacts`), check a real file.
        let p = std::path::Path::new("artifacts/weights/head.b.npy");
        if p.exists() {
            let arr = NpyArray::load(p).unwrap();
            assert_eq!(arr.shape, vec![10]);
            assert!(arr.as_f32().unwrap().iter().all(|v| v.is_finite()));
        }
    }
}
