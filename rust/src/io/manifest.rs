//! The plain-text weight manifest + model config emitted by
//! `python/compile/train.py`:
//!
//! ```text
//! manifest.txt : <name> <dtype> <ndim> <d0> ... <dn-1> <file>
//! config.txt   : <key> <value>
//! ```

use std::collections::HashMap;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::npy::NpyArray;

/// One manifest entry.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Entry {
    /// Tensor name.
    pub name: String,
    /// Element dtype (e.g. `f32`).
    pub dtype: String,
    /// Tensor shape.
    pub shape: Vec<usize>,
    /// Relative `.npy` file name.
    pub file: String,
}

/// Parsed weight manifest bound to its directory.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
    /// Manifest rows.
    pub entries: Vec<Entry>,
}

impl Manifest {
    /// Read `manifest.txt` from `dir`.
    pub fn load(dir: &Path) -> Result<Self> {
        let text = fs::read_to_string(dir.join("manifest.txt"))
            .with_context(|| format!("reading manifest in {}", dir.display()))?;
        let mut entries = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let parts: Vec<&str> = line.split_whitespace().collect();
            if parts.len() < 4 {
                bail!("manifest line {} malformed: {line:?}", lineno + 1);
            }
            let ndim: usize = parts[2].parse().context("bad ndim")?;
            if parts.len() != 4 + ndim {
                bail!("manifest line {}: expected {} fields", lineno + 1, 4 + ndim);
            }
            let shape = parts[3..3 + ndim]
                .iter()
                .map(|s| s.parse().context("bad dim"))
                .collect::<Result<Vec<usize>>>()?;
            entries.push(Entry {
                name: parts[0].to_string(),
                dtype: parts[1].to_string(),
                shape,
                file: parts[3 + ndim].to_string(),
            });
        }
        Ok(Self { dir: dir.to_path_buf(), entries })
    }

    /// Look up an entry by tensor name.
    pub fn get(&self, name: &str) -> Result<&Entry> {
        self.entries
            .iter()
            .find(|e| e.name == name)
            .with_context(|| format!("weight `{name}` not in manifest"))
    }

    /// Load a named array, verifying the manifest shape against the file.
    pub fn load_array(&self, name: &str) -> Result<NpyArray> {
        let e = self.get(name)?;
        let arr = NpyArray::load(&self.dir.join(&e.file))?;
        if arr.shape != e.shape {
            bail!("`{name}` shape mismatch: manifest {:?} vs file {:?}", e.shape, arr.shape);
        }
        Ok(arr)
    }

    /// Load an entry's data and shape as f32.
    pub fn load_f32(&self, name: &str) -> Result<(Vec<f32>, Vec<usize>)> {
        let arr = self.load_array(name)?;
        Ok((arr.as_f32()?, arr.shape))
    }
}

/// Parsed `config.txt` key/value file.
#[derive(Clone, Debug)]
pub struct ModelConfigFile {
    /// Raw key/value pairs.
    pub kv: HashMap<String, String>,
}

impl ModelConfigFile {
    /// Read `config.txt` from `dir`.
    pub fn load(dir: &Path) -> Result<Self> {
        let text = fs::read_to_string(dir.join("config.txt"))
            .with_context(|| format!("reading config in {}", dir.display()))?;
        Ok(Self::parse(&text))
    }

    /// Parse from text.
    pub fn parse(text: &str) -> Self {
        let mut kv = HashMap::new();
        for line in text.lines() {
            let mut it = line.split_whitespace();
            if let (Some(k), Some(v)) = (it.next(), it.next()) {
                kv.insert(k.to_string(), v.to_string());
            }
        }
        Self { kv }
    }

    /// A key parsed as `usize`.
    pub fn usize(&self, key: &str) -> Result<usize> {
        self.kv
            .get(key)
            .with_context(|| format!("config key `{key}` missing"))?
            .parse()
            .with_context(|| format!("config key `{key}` not an integer"))
    }

    /// A key parsed as `f32`.
    pub fn f32(&self, key: &str) -> Result<f32> {
        self.kv
            .get(key)
            .with_context(|| format!("config key `{key}` missing"))?
            .parse()
            .with_context(|| format!("config key `{key}` not a float"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_config_text() {
        let c = ModelConfigFile::parse("embed_dim 64\ntimesteps 2\nlif_gamma 0.5\n");
        assert_eq!(c.usize("embed_dim").unwrap(), 64);
        assert_eq!(c.usize("timesteps").unwrap(), 2);
        assert!((c.f32("lif_gamma").unwrap() - 0.5).abs() < 1e-9);
        assert!(c.usize("missing").is_err());
    }

    #[test]
    fn manifest_parse_roundtrip() {
        let dir = std::env::temp_dir().join(format!("sfa_manifest_{}", std::process::id()));
        fs::create_dir_all(&dir).unwrap();
        fs::write(dir.join("manifest.txt"), "head.b f32 1 10 head.b.npy\n# comment\n").unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.entries.len(), 1);
        let e = m.get("head.b").unwrap();
        assert_eq!(e.shape, vec![10]);
        assert_eq!(e.file, "head.b.npy");
        assert!(m.get("nope").is_err());
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn malformed_manifest_rejected() {
        let dir = std::env::temp_dir().join(format!("sfa_manifest_bad_{}", std::process::id()));
        fs::create_dir_all(&dir).unwrap();
        fs::write(dir.join("manifest.txt"), "only two\n").unwrap();
        assert!(Manifest::load(&dir).is_err());
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn real_artifacts_if_present() {
        let dir = Path::new("artifacts/weights");
        if dir.join("manifest.txt").exists() {
            let m = Manifest::load(dir).unwrap();
            assert!(m.entries.len() >= 12);
            let (w, shape) = m.load_f32("head.w").unwrap();
            assert_eq!(shape.len(), 2);
            assert_eq!(w.len(), shape[0] * shape[1]);
        }
    }
}
