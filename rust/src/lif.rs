//! Integer Leaky Integrate-and-Fire neuron array (Eqs. (1)-(3)).
//!
//! The membrane potential lives in a wide (16-bit modelled) accumulator at
//! the activation Q-format; the decay `gamma * Mem[t]` is a multiply by a
//! Q0.6 constant followed by a rounding shift, which for the default
//! `gamma = 0.5` degenerates to a single arithmetic shift — exactly what
//! the RTL would synthesize.

use crate::quant::{rshift_round, sat, QFormat, ACT_FRAC, MEM_BITS};

/// Fractional bits of the quantized decay constant.
pub const GAMMA_FRAC: i32 = 6;

/// Quantized LIF constants shared by every neuron of a layer.
#[derive(Clone, Copy, Debug)]
pub struct LifParams {
    /// Firing threshold in the activation format.
    pub v_th: i32,
    /// Reset potential in the activation format.
    pub v_reset: i32,
    /// Decay constant in Q0.GAMMA_FRAC.
    pub gamma_q: i32,
}

impl LifParams {
    /// Quantize float LIF parameters into the integer domain.
    pub fn from_f32(v_th: f32, v_reset: f32, gamma: f32) -> Self {
        let act = QFormat::new(MEM_BITS, ACT_FRAC);
        Self {
            v_th: act.from_f32(v_th),
            v_reset: act.from_f32(v_reset),
            gamma_q: ((gamma as f64) * 2f64.powi(GAMMA_FRAC)).round() as i32,
        }
    }
}

impl Default for LifParams {
    fn default() -> Self {
        Self::from_f32(1.0, 0.0, 0.5)
    }
}

/// A bank of LIF neurons with persistent temporal state Temp[t-1].
#[derive(Clone, Debug)]
pub struct LifArray {
    /// Shared neuron parameters.
    pub params: LifParams,
    /// Temp[t-1] per neuron, activation format, wide accumulator.
    temp: Vec<i32>,
}

impl LifArray {
    /// A bank of `n` neurons at rest.
    pub fn new(n: usize, params: LifParams) -> Self {
        Self { params, temp: vec![0; n] }
    }

    /// Number of neurons.
    pub fn len(&self) -> usize {
        self.temp.len()
    }

    /// True when the bank has no neurons.
    pub fn is_empty(&self) -> bool {
        self.temp.is_empty()
    }

    /// Reset all temporal state (between images).
    pub fn reset(&mut self) {
        self.temp.fill(0);
    }

    /// One timestep for one neuron: returns true iff it fires.
    ///
    /// `spa` is the spatial input in the activation format (wide).
    #[inline]
    pub fn step_one(&mut self, idx: usize, spa: i32) -> bool {
        let p = self.params;
        // Eq. (2): Mem[t] = Spa[t] + Temp[t-1], saturated to the wide format.
        let mem = sat(spa as i64 + self.temp[idx] as i64, MEM_BITS);
        // Eq. (3): S[t] = eps(Mem[t] - Vth).
        let fired = mem >= p.v_th;
        // Eq. (1): Temp[t] = S Vreset + (1-S)(gamma Mem).
        self.temp[idx] = if fired {
            p.v_reset
        } else {
            sat(rshift_round(mem as i64 * p.gamma_q as i64, GAMMA_FRAC), MEM_BITS)
        };
        fired
    }

    /// One timestep for a whole vector of spatial inputs; fills `fired`.
    pub fn step(&mut self, spa: &[i32], fired: &mut Vec<bool>) {
        assert_eq!(spa.len(), self.temp.len());
        fired.clear();
        fired.reserve(spa.len());
        for (i, &s) in spa.iter().enumerate() {
            fired.push(self.step_one(i, s));
        }
    }

    /// Current temporal state (for tests / checkpointing).
    pub fn temp(&self) -> &[i32] {
        &self.temp
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QFormat;

    fn act(v: f32) -> i32 {
        QFormat::new(MEM_BITS, ACT_FRAC).from_f32(v)
    }

    #[test]
    fn fires_at_threshold() {
        let mut a = LifArray::new(1, LifParams::default());
        assert!(a.step_one(0, act(1.0))); // mem == v_th fires (eps(0) = 1)
        assert_eq!(a.temp()[0], 0); // hard reset to v_reset = 0
    }

    #[test]
    fn subthreshold_decays() {
        let mut a = LifArray::new(1, LifParams::default());
        assert!(!a.step_one(0, act(0.6)));
        // temp = 0.6 * 0.5 = 0.3
        assert_eq!(a.temp()[0], act(0.3));
        // 0.3 + 0.6 = 0.9 < 1.0 : still silent
        assert!(!a.step_one(0, act(0.6)));
        // temp = 0.45; 0.45 + 0.6 = 1.05 >= 1.0 : fires
        assert!(a.step_one(0, act(0.6)));
        assert_eq!(a.temp()[0], 0);
    }

    #[test]
    fn negative_input_never_fires() {
        let mut a = LifArray::new(1, LifParams::default());
        for _ in 0..10 {
            assert!(!a.step_one(0, act(-0.5)));
        }
    }

    #[test]
    fn matches_grid_reference() {
        // Cross-check the integer pipeline against a float LIF whose decay
        // is rounded to the quantization grid exactly like the RTL would
        // (ties away from zero).
        let params = LifParams::from_f32(1.0, 0.0, 0.5);
        let mut a = LifArray::new(1, params);
        let grid = 64.0f64; // 2^ACT_FRAC
        let mut temp_f = 0.0f64;
        let mut rng = crate::util::Prng::new(9);
        for _ in 0..200 {
            let spa_raw = (rng.gen_range(0, 257) as i32) - 128; // +-2.0
            let spa_f = spa_raw as f64 / grid;
            let mem_f = spa_f + temp_f;
            let fired_f = mem_f >= 1.0;
            temp_f = if fired_f {
                0.0
            } else {
                let half = mem_f * 0.5 * grid;
                let rounded =
                    if half >= 0.0 { (half + 0.5).floor() } else { (half - 0.5).ceil() };
                rounded / grid
            };
            let fired = a.step_one(0, spa_raw);
            assert_eq!(fired, fired_f);
        }
    }

    #[test]
    fn gamma_zero_is_memoryless() {
        let params = LifParams::from_f32(1.0, 0.0, 0.0);
        let mut a = LifArray::new(1, params);
        assert!(!a.step_one(0, act(0.9)));
        assert_eq!(a.temp()[0], 0);
        assert!(!a.step_one(0, act(0.9)));
    }

    #[test]
    fn reset_clears_state() {
        let mut a = LifArray::new(2, LifParams::default());
        a.step_one(0, act(0.5));
        assert_ne!(a.temp()[0], 0);
        a.reset();
        assert_eq!(a.temp(), &[0, 0]);
    }

    #[test]
    fn vector_step_matches_scalar() {
        let mut a = LifArray::new(3, LifParams::default());
        let mut b = LifArray::new(3, LifParams::default());
        let spa = vec![act(0.4), act(1.2), act(-0.1)];
        let mut fired = Vec::new();
        a.step(&spa, &mut fired);
        let scalar: Vec<bool> = (0..3).map(|i| b.step_one(i, spa[i])).collect();
        assert_eq!(fired, scalar);
        assert_eq!(a.temp(), b.temp());
    }

    #[test]
    fn saturation_on_huge_input() {
        let mut a = LifArray::new(1, LifParams::default());
        assert!(a.step_one(0, i32::MAX / 2)); // saturates, fires, no overflow
    }
}
