//! The coordinator proper: a submission queue feeding worker threads, each
//! owning one backend instance; dynamic batching at the queue head;
//! latency/throughput statistics on completion.
//!
//! Built on std threads + channels (tokio is unavailable offline); the
//! topology — router thread, N workers, response collector — mirrors the
//! vllm-style leader/worker layout the architecture guide calls for.

use crate::util::sync::mpsc::{channel, Receiver, Sender};
use crate::util::sync::thread::JoinHandle;
use crate::util::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::util::{mean, percentile};

use super::backend::BackendFactory;
use super::batcher::{BatchPolicy, DynamicBatcher};
use super::{Request, Response};

/// Serving statistics over one session.
#[derive(Clone, Debug)]
pub struct ServeReport {
    /// Requests completed.
    pub completed: usize,
    /// Wall-clock seconds of the session.
    pub wall_s: f64,
    /// Completed requests per second.
    pub throughput_rps: f64,
    /// Mean request latency, seconds.
    pub latency_mean_s: f64,
    /// Median request latency, seconds.
    pub latency_p50_s: f64,
    /// p99 request latency, seconds.
    pub latency_p99_s: f64,
    /// Batches dispatched.
    pub batches: usize,
    /// Mean batch size.
    pub mean_batch: f64,
    /// Modelled accelerator cycles (simulator backends), summed over workers.
    pub modelled_cycles: u64,
}

impl ServeReport {
    /// One-line rendering for logs and benches.
    pub fn summary(&self) -> String {
        format!(
            "completed={}  wall={:.3}s  throughput={:.1} req/s  latency mean={:.2}ms p50={:.2}ms p99={:.2}ms  batches={} (mean size {:.2})",
            self.completed,
            self.wall_s,
            self.throughput_rps,
            self.latency_mean_s * 1e3,
            self.latency_p50_s * 1e3,
            self.latency_p99_s * 1e3,
            self.batches,
            self.mean_batch
        )
    }
}

enum WorkerMsg {
    Batch(Vec<(Request, Instant)>),
    Stop,
}

/// Multi-worker batching coordinator.
pub struct Coordinator {
    batcher: Arc<Mutex<DynamicBatcher>>,
    workers: Vec<JoinHandle<u64>>,
    work_tx: Sender<WorkerMsg>,
    resp_rx: Receiver<(Response, usize)>,
    dispatched: usize,
}

impl Coordinator {
    /// Spawn one worker per factory; each worker constructs its own
    /// backend in-thread (PJRT handles are not `Send`).
    pub fn new(factories: Vec<BackendFactory>, policy: BatchPolicy) -> Self {
        let (work_tx, work_rx) = channel::<WorkerMsg>();
        let work_rx = Arc::new(Mutex::new(work_rx));
        let (resp_tx, resp_rx) = channel::<(Response, usize)>();
        let mut workers = Vec::new();
        for factory in factories {
            let rx = Arc::clone(&work_rx);
            let tx = resp_tx.clone();
            workers.push(crate::util::sync::thread::spawn(move || -> u64 {
                let mut backend = match factory() {
                    Ok(b) => b,
                    Err(e) => {
                        eprintln!("backend construction failed: {e:#}");
                        return 0;
                    }
                };
                loop {
                    let msg = { rx.lock().unwrap().recv() };
                    match msg {
                        Ok(WorkerMsg::Batch(batch)) => {
                            let size = batch.len();
                            let images: Vec<Vec<f32>> =
                                batch.iter().map(|(r, _)| r.image.clone()).collect();
                            match backend.infer_batch(&images) {
                                Ok(logits) => {
                                    let done = Instant::now();
                                    for ((req, t0), lg) in batch.into_iter().zip(logits) {
                                        let predicted = argmax(&lg);
                                        let resp = Response {
                                            id: req.id,
                                            logits: lg,
                                            predicted,
                                            latency_s: done.duration_since(t0).as_secs_f64(),
                                        };
                                        let _ = tx.send((resp, size));
                                    }
                                }
                                Err(e) => {
                                    eprintln!("worker backend error: {e:#}");
                                }
                            }
                        }
                        Ok(WorkerMsg::Stop) | Err(_) => break,
                    }
                }
                backend.modelled_cycles()
            }));
        }
        Self {
            batcher: Arc::new(Mutex::new(DynamicBatcher::new(policy))),
            workers,
            work_tx,
            resp_rx,
            dispatched: 0,
        }
    }

    /// Enqueue a request.
    pub fn submit(&mut self, req: Request) {
        self.batcher.lock().unwrap().push(req);
        self.pump(false);
    }

    /// Move ready batches from the queue to the workers.
    fn pump(&mut self, flush: bool) {
        let mut b = self.batcher.lock().unwrap();
        loop {
            let batch = if flush {
                let all = b.drain_all();
                if all.is_empty() {
                    None
                } else {
                    // respect max_batch even when flushing
                    let mut rest = all;
                    let take = rest.len().min(b.policy.max_batch);
                    let batch: Vec<_> = rest.drain(..take).collect();
                    for item in rest {
                        b.push_back_with_time(item);
                    }
                    Some(batch)
                }
            } else {
                b.take_batch(Instant::now())
            };
            match batch {
                Some(batch) if !batch.is_empty() => {
                    self.dispatched += batch.len();
                    let _ = self.work_tx.send(WorkerMsg::Batch(batch));
                }
                _ => break,
            }
        }
    }

    /// Flush the queue, wait for all responses, stop workers, and report.
    pub fn finish(mut self, started: Instant) -> Result<(Vec<Response>, ServeReport)> {
        // Flush any waiting partial batches.
        self.pump(true);
        let mut responses = Vec::with_capacity(self.dispatched);
        let mut batch_sizes = Vec::new();
        while responses.len() < self.dispatched {
            let (resp, size) = self.resp_rx.recv()?;
            responses.push(resp);
            batch_sizes.push(size);
        }
        for _ in 0..self.workers.len() {
            let _ = self.work_tx.send(WorkerMsg::Stop);
        }
        let mut modelled_cycles = 0;
        for w in self.workers {
            modelled_cycles += w.join().unwrap_or(0);
        }

        let wall = started.elapsed().as_secs_f64();
        let lats: Vec<f64> = responses.iter().map(|r| r.latency_s).collect();
        // unique batches: every response carries its batch size; weight by 1/size
        let batches = batch_sizes.iter().map(|&s| 1.0 / s as f64).sum::<f64>().round() as usize;
        let report = ServeReport {
            completed: responses.len(),
            wall_s: wall,
            throughput_rps: responses.len() as f64 / wall.max(1e-9),
            latency_mean_s: mean(&lats),
            latency_p50_s: percentile(&lats, 50.0),
            latency_p99_s: percentile(&lats, 99.0),
            batches,
            mean_batch: if batches > 0 { responses.len() as f64 / batches as f64 } else { 0.0 },
            modelled_cycles,
        };
        responses.sort_by_key(|r| r.id);
        Ok((responses, report))
    }
}

impl DynamicBatcher {
    /// Requeue an already-timestamped item at the back (flush splitting).
    pub fn push_back_with_time(&mut self, item: (Request, Instant)) {
        // used only by the coordinator's flush path
        self.push_raw(item);
    }
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::GoldenBackend;
    use crate::hw::AccelConfig;
    use crate::coordinator::backend::SimulatorBackend;
    use crate::model::{QuantizedModel, SdtModelConfig};
    use crate::util::Prng;
    use std::time::Duration;

    fn image(seed: u64) -> Vec<f32> {
        let mut rng = Prng::new(seed);
        (0..3 * 32 * 32).map(|_| rng.next_f32_signed()).collect()
    }

    fn golden_factory(model: QuantizedModel) -> BackendFactory {
        Box::new(move || Ok(Box::new(GoldenBackend::new(model)) as _))
    }

    #[test]
    fn serves_all_requests_in_order() {
        let cfg = SdtModelConfig::tiny();
        let model = QuantizedModel::random(&cfg, 20);
        let backends = vec![golden_factory(model.clone()), golden_factory(model)];
        let policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) };
        let started = Instant::now();
        let mut co = Coordinator::new(backends, policy);
        for i in 0..10 {
            co.submit(Request { id: i, image: image(i) });
        }
        let (responses, report) = co.finish(started).unwrap();
        assert_eq!(responses.len(), 10);
        assert_eq!(report.completed, 10);
        for (i, r) in responses.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert_eq!(r.logits.len(), 10);
            assert!(r.latency_s >= 0.0);
        }
        assert!(report.throughput_rps > 0.0);
    }

    #[test]
    fn identical_requests_get_identical_answers_across_workers() {
        let cfg = SdtModelConfig::tiny();
        let model = QuantizedModel::random(&cfg, 21);
        let backends = vec![
            golden_factory(model.clone()),
            golden_factory(model.clone()),
            golden_factory(model),
        ];
        let started = Instant::now();
        let mut co = Coordinator::new(backends, BatchPolicy { max_batch: 1, max_wait: Duration::ZERO });
        let img = image(42);
        for i in 0..9 {
            co.submit(Request { id: i, image: img.clone() });
        }
        let (responses, _) = co.finish(started).unwrap();
        for r in &responses[1..] {
            assert_eq!(r.logits, responses[0].logits, "worker nondeterminism");
        }
    }

    #[test]
    fn simulator_backend_reports_cycles() {
        let cfg = SdtModelConfig::tiny();
        let model = QuantizedModel::random(&cfg, 22);
        let backends: Vec<BackendFactory> = vec![Box::new(move || {
            Ok(Box::new(SimulatorBackend::new(model, AccelConfig::small())) as _)
        })];
        let started = Instant::now();
        let mut co = Coordinator::new(backends, BatchPolicy::default());
        for i in 0..3 {
            co.submit(Request { id: i, image: image(i) });
        }
        let (_, report) = co.finish(started).unwrap();
        assert!(report.modelled_cycles > 0);
    }
}
